"""Figure 1 (paper Sec. 6.1): synthetic mean estimation, n=100, K=10.

(a) evolution of g(W^l), the exact bias term and 1-p across STL-FW
    iterations (elbow at l = K-1 = 9).
(b, c) final D-SGD error vs heterogeneity m for STL-FW and random d-regular
    topologies at budgets 3 and 9: with d_max=9 STL-FW is insensitive to m.
"""

import time

import numpy as np

from .common import emit, save_rows
from repro.core import topology as T
from repro.core.heterogeneity import label_skew_bias
from repro.core.stl_fw import learn_topology
from repro.data.synthetic import mean_estimation_clusters
from repro.train.trainer import run_mean_estimation


def fig1a(smoke: bool = False) -> None:
    t0 = time.perf_counter()
    task = mean_estimation_clusters(n_nodes=30 if smoke else 100, K=10, m=5.0)
    res = learn_topology(task.Pi, budget=15, lam=0.5)
    rows = []
    for l in range(len(res.objective_trace)):
        rows.append([l, res.objective_trace[l], res.bias_trace[l], res.variance_trace[l]])
    save_rows("fig1a.csv", ["l", "g", "bias", "variance"], rows)
    us = (time.perf_counter() - t0) * 1e6
    elbow_bias = res.bias_trace[9]
    emit("fig1a_stlfw_traces", us, f"bias@l9={elbow_bias:.2e};g@l9={res.objective_trace[9]:.4f}")


def fig1bc(smoke: bool = False) -> None:
    t0 = time.perf_counter()
    n, steps = (30, 10) if smoke else (100, 50)
    rows = []
    finals = {}
    for m in (0.0, 10.0) if smoke else (0.0, 2.0, 5.0, 10.0):
        task = mean_estimation_clusters(n_nodes=n, K=10, m=m)
        for budget in (3, 9):
            res = learn_topology(task.Pi, budget=budget, lam=0.5)
            Wr = T.random_d_regular(n, budget, seed=0)
            for name, W in (("stl-fw", res.W), ("random", Wr)):
                out = run_mean_estimation(task, W, steps=steps, lr=0.15, seed=0)
                rows.append([
                    m, budget, name,
                    out["mean_sq_error"][-1], out["max_sq_error"][-1],
                    out["min_sq_error"][-1],
                ])
                finals[(m, budget, name)] = out["mean_sq_error"][-1]
    save_rows(
        "fig1bc.csv",
        ["m", "budget", "topology", "mse", "max_node_sq_err", "min_node_sq_err"],
        rows,
    )
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    # key claim: at budget 9, stl-fw error barely grows with m while random's does
    ratio_stl = finals[(10.0, 9, "stl-fw")] / max(finals[(0.0, 9, "stl-fw")], 1e-12)
    ratio_rnd = finals[(10.0, 9, "random")] / max(finals[(0.0, 9, "random")], 1e-12)
    emit("fig1bc_dsgd_error_vs_m", us,
         f"stlfw_growth={ratio_stl:.2f}x;random_growth={ratio_rnd:.2f}x")


def main(smoke: bool = False) -> None:
    fig1a(smoke)
    fig1bc(smoke)


if __name__ == "__main__":
    main()

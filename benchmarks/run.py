"""Benchmark harness: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and saves the
full data tables under experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig2
"""

import argparse
import sys
import traceback

from . import (
    bench_example1,
    bench_fig1,
    bench_fig2,
    bench_kernels,
    bench_mixing,
    bench_tables,
    bench_theory,
    bench_thm2,
)

BENCHES = {
    "example1": bench_example1.main,
    "fig1": bench_fig1.main,
    "fig2": bench_fig2.main,
    "tables": bench_tables.main,
    "thm2": bench_thm2.main,
    "theory": bench_theory.main,
    "kernels": bench_kernels.main,
    "mixing": bench_mixing.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            BENCHES[name]()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

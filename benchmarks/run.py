"""Benchmark harness: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and saves the
full data tables under experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig2
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI: tiny sizes,
                                                       # one repeat, every
                                                       # bench still executes
"""

import argparse
import sys
import traceback

from . import common
from . import (
    bench_example1,
    bench_faults,
    bench_fig1,
    bench_fig2,
    bench_kernels,
    bench_mixing,
    bench_obs,
    bench_online,
    bench_stl_fw,
    bench_tables,
    bench_theory,
    bench_thm2,
)

BENCHES = {
    "example1": bench_example1.main,
    "fig1": bench_fig1.main,
    "fig2": bench_fig2.main,
    "tables": bench_tables.main,
    "thm2": bench_thm2.main,
    "theory": bench_theory.main,
    "kernels": bench_kernels.main,
    "mixing": bench_mixing.main,
    "online": bench_online.main,
    "stl_fw": bench_stl_fw.main,
    "faults": bench_faults.main,
    "obs": bench_obs.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny problem sizes and a single repeat per bench -- wall-clock "
        "numbers are meaningless, but every bench code path runs (CI rot "
        "detector)",
    )
    args = ap.parse_args()
    common.set_smoke(args.smoke)
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            BENCHES[name](smoke=args.smoke)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Fault-tolerance benchmarks: convergence under injected faults, and
crash recovery (ISSUE 6).

1. **Fault sweep** -- the Section 6.1 mean-estimation task under a
   crash-rate x straggler-delay x edge-drop grid, every cell on the SAME
   observation stream as the fault-free baseline (equal iteration
   count, so the gap measures the faults, not the data). Per cell:
   tail-median squared error, the convergence gap vs fault-free, mean
   alive fraction, and delivered-vs-dropped comm bytes from the honest
   meter. Every cell asserts ``n_traces == 1``: the degraded-W swap,
   the straggler ring-buffer update, and the post-crash
   renormalization all reach the compiled rollout as data (the
   jit-cache-miss detector of the acceptance criteria).

2. **Straggler sweep** (ISSUE 8) -- bounded-delay gossip under a
   tau_max x straggler-fraction x {wait, degrade} grid, same
   observation stream as the fault-free baseline. Acceptance bars: at
   tau_max <= 4 and <= 25% stragglers the wait policy's tail error
   stays within 10% of fault-free and degrade within 20%; every cell
   -- including a topology refresh landing UNDER staleness -- runs at
   zero retraces, and the delays=0 control arm is BITWISE the fresh
   run (losses AND bytes).

3. **Crash recovery** -- the micro scenario CI runs in --smoke: n=8, a
   scripted node crash + rejoin window (via ``NodeChurn`` ->
   ``FaultPlan.from_node_churn``), one warm topology refresh landing
   mid-run UNDER the faults, then the run is killed at a segment
   boundary and resumed from its checkpoint. Asserts (smoke included):
   retraces == 0 across the degraded swap + refresh, and
   checkpoint-resume is BITWISE equal to the uninterrupted faulty run
   -- which lands the "final loss within 5% of uninterrupted" bar at
   exactly 0% gap (recorded honestly in the JSON).

Writes experiments/bench/BENCH_faults.json.
"""

import json
import os
import tempfile
import time

import numpy as np

from .common import emit, result_dir
from repro.core.mixing import (
    StragglerPolicy,
    schedule_from_result,
    schedule_to_arrays,
)
from repro.core.stl_fw import learn_topology
from repro.data.drift import NodeChurn
from repro.data.synthetic import mean_estimation_clusters
from repro.faults import FaultPlan, run_faulty_mean_estimation
from repro.online import RefreshConfig, TopologyRefresher

LAM = 0.1


def _bench_fault_sweep(results: dict, smoke: bool) -> None:
    if smoke:
        n, K, steps, seg, batch = 8, 4, 120, 20, 2
        crash_rates = (0.0, 0.05)
        tau_maxes = (0, 2)
        edge_drops = (0.0, 0.1)
    else:
        n, K, steps, seg, batch = 32, 8, 600, 50, 2
        crash_rates = (0.0, 0.01, 0.05)
        tau_maxes = (0, 2, 4)
        edge_drops = (0.0, 0.05, 0.15)
    lr = 0.05
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    sched0 = schedule_from_result(res0)
    arrays = schedule_to_arrays(sched0, sched0.n_atoms + 2)
    rng = np.random.default_rng(1)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )
    tail = slice(-max(10, steps // 10), None)

    def run(plan: FaultPlan) -> dict:
        out = run_faulty_mean_estimation(
            task, plan, arrays, lr=lr, seed=2, zs=zs, segment_len=seg
        )
        assert out["n_traces"] == 1, (
            f"fault scenario retraced the rollout: n_traces={out['n_traces']}"
        )
        return out

    base_plan = FaultPlan(n_nodes=n, steps=steps, seed=0)
    t0 = time.perf_counter()
    base = run(base_plan)
    base_err = float(np.median(base["mean_sq_error"][tail]))
    cells = []
    for cr in crash_rates:
        for tau in tau_maxes:
            for ed in edge_drops:
                if cr == 0.0 and tau == 0 and ed == 0.0:
                    continue  # that IS the baseline
                plan = FaultPlan(
                    n_nodes=n, steps=steps, seed=3,
                    crash_rate=cr, mean_outage=6.0,
                    straggler_rate=0.3 if tau else 0.0, tau_max=tau,
                    edge_drop_rate=ed,
                )
                out = run(plan)
                err = float(np.median(out["mean_sq_error"][tail]))
                cells.append({
                    "crash_rate": cr, "tau_max": tau, "edge_drop_rate": ed,
                    "tail_median_err": err,
                    "convergence_gap": err - base_err,
                    "gap_ratio": err / base_err,
                    "alive_frac": out["alive_frac"],
                    "comm": out["comm"],
                    "n_traces": out["n_traces"],
                })
    wall = time.perf_counter() - t0
    worst = max(cells, key=lambda c: c["gap_ratio"])
    results["fault_sweep"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "lam": LAM, "batch": batch,
        "crash_rates": list(crash_rates), "tau_maxes": list(tau_maxes),
        "edge_drop_rates": list(edge_drops),
        "baseline_tail_median_err": base_err,
        "baseline_comm": base["comm"],
        "cells": cells,
        "wall_s": wall,
    }
    emit(
        f"faults_sweep_n{n}", wall / max(len(cells), 1) * 1e6,
        f"{len(cells)}cells_base={base_err:.2e}"
        f"_worst={worst['gap_ratio']:.2f}x@cr{worst['crash_rate']}"
        f"t{worst['tau_max']}e{worst['edge_drop_rate']}_retraces=0",
    )


def _bench_straggler_sweep(results: dict, smoke: bool) -> None:
    """Bounded-delay gossip: tau_max x straggler-rate x policy grid."""
    if smoke:
        n, K, steps, seg, batch = 8, 4, 120, 20, 2
        hard_rate = 0.02
    else:
        n, K, steps, seg, batch = 32, 8, 600, 50, 2
        hard_rate = 0.01  # larger fleets tolerate fewer per-node cuts
    lr = 0.02
    tau_maxes = (2, 4)
    straggler_rates = (0.1, 0.25)
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    sched0 = schedule_from_result(res0)
    arrays = schedule_to_arrays(sched0, sched0.n_atoms + 2)
    rng = np.random.default_rng(6)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )
    tail = slice(-max(10, steps // 3), None)
    kw = dict(lr=lr, seed=2, zs=zs, segment_len=seg)

    def straggler_plan(tau: int, rate: float) -> FaultPlan:
        """Stragglers at ``rate`` with delays <= tau (on-time for a
        deadline of tau), plus a sparse seeded set of HARD stragglers
        whose delay exceeds any deadline in the grid -- the node-steps
        where wait (clamp to tau) and degrade (cut for the step)
        actually disagree. Post-editing ``plan.delays`` follows the
        ``from_node_churn`` precedent of scripting part of a trace."""
        plan = FaultPlan(
            n_nodes=n, steps=steps, seed=8,
            straggler_rate=rate, tau_max=tau,
        )
        srng = np.random.default_rng([8, 99, tau, int(rate * 100)])
        late = srng.random((steps, n)) < hard_rate
        plan.delays[late] = tau + 2
        return plan

    t0 = time.perf_counter()
    plan0 = FaultPlan(n_nodes=n, steps=steps, seed=0)
    base = run_faulty_mean_estimation(task, plan0, arrays, **kw)
    assert base["n_traces"] == 1
    base_err = float(np.median(base["mean_sq_error"][tail]))

    # delays=0 control arm: the stale data plane with an all-zero delay
    # trace must be BITWISE the fresh run -- losses AND bytes
    bitwise_controls = {}
    for mode in ("wait", "degrade"):
        ctrl = run_faulty_mean_estimation(
            task, plan0, arrays,
            staleness=StragglerPolicy(mode=mode, tau_max=4), **kw
        )
        assert ctrl["n_traces"] == 1, ctrl["n_traces"]
        assert np.array_equal(
            ctrl["mean_sq_error"], base["mean_sq_error"]
        ), f"delays=0 {mode} arm diverged bitwise from the fresh run"
        assert ctrl["comm"]["total_bytes"] == base["comm"]["total_bytes"]
        assert ctrl["comm"]["deferred_bytes"] == 0
        assert ctrl["comm"]["dropped_bytes"] == 0
        bitwise_controls[mode] = {
            "bitwise_losses": True,
            "total_bytes": ctrl["comm"]["total_bytes"],
        }

    def assert_comm_closed_form(out, plan, policy) -> None:
        """The metered bytes must equal the closed form from the plan's
        transfer fates, aggregated segment-by-segment exactly as the
        meter ticks (volume conservation + deferred subset)."""
        comm = out["comm"]
        per_step = comm["per_step_bytes"]
        assert comm["total_bytes"] + comm["dropped_bytes"] == steps * per_step
        exp_total = exp_deferred = 0
        for t0 in range(0, steps, seg):
            k = min(seg, steps - t0)
            fates = [
                plan.transfer_fracs(
                    t, deadline=policy.tau_max, mode=policy.mode
                )
                for t in range(t0, t0 + k)
            ]
            on = float(np.mean([f[0] for f in fates]))
            df = float(np.mean([f[1] for f in fates]))
            delivered = int(k * per_step * (on + df))
            exp_total += delivered
            # deferred derives from the truncated delivered volume (the
            # PR 9 CommMeter fix: subset invariant by construction)
            exp_deferred += (
                int(delivered * (df / (on + df))) if on + df > 0 else 0
            )
        assert comm["total_bytes"] == exp_total, (
            comm["total_bytes"], exp_total
        )
        assert comm["deferred_bytes"] == exp_deferred, (
            comm["deferred_bytes"], exp_deferred
        )

    cells = []
    for tau in tau_maxes:
        for rate in straggler_rates:
            plan = straggler_plan(tau, rate)
            for mode in ("wait", "degrade"):
                policy = StragglerPolicy(mode=mode, tau_max=tau)
                out = run_faulty_mean_estimation(
                    task, plan, arrays, staleness=policy, **kw
                )
                assert out["n_traces"] == 1, (
                    f"straggler cell retraced: {out['n_traces']}"
                )
                assert_comm_closed_form(out, plan, policy)
                err = float(np.median(out["mean_sq_error"][tail]))
                ratio = err / base_err
                # acceptance: tau_max <= 4, <= 25% stragglers => wait
                # within 10% of fault-free, degrade within 20%
                bar = 1.10 if mode == "wait" else 1.20
                assert ratio <= bar, (
                    f"{mode} tau={tau} rate={rate}: {ratio:.3f} > {bar}"
                )
                cells.append({
                    "tau_max": tau, "straggler_rate": rate, "policy": mode,
                    "tail_median_err": err,
                    "gap_ratio": ratio,
                    "comm": out["comm"],
                    "n_traces": out["n_traces"],
                })

    # one refresh lands UNDER live staleness: still zero retraces
    # (the refresher's own l_max padding is the base, so the swap is a
    # same-shape value change)
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=LAM))
    arrays_r = ref.schedule_arrays()
    plan_r = straggler_plan(4, 0.25)
    done = {"swapped": False}

    def hook(t):
        if not done["swapped"] and t >= 2 * seg - 1:
            done["swapped"] = True
            ref.refresh(task.Pi)
            return ref.schedule_arrays()
        return None

    refreshed = run_faulty_mean_estimation(
        task, plan_r, arrays_r,
        staleness=StragglerPolicy(mode="wait", tau_max=4),
        on_segment=hook, **kw
    )
    assert refreshed["n_traces"] == 1, refreshed["n_traces"]
    assert refreshed["swaps"] == [2 * seg - 1], refreshed["swaps"]
    assert_comm_closed_form(
        refreshed, plan_r, StragglerPolicy(mode="wait", tau_max=4)
    )
    refresh_err = float(np.median(refreshed["mean_sq_error"][tail]))
    assert refresh_err / base_err <= 1.10, refresh_err / base_err

    wall = time.perf_counter() - t0
    worst = max(cells, key=lambda c: c["gap_ratio"])
    results["straggler_sweep"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "lam": LAM, "batch": batch,
        "tau_maxes": list(tau_maxes),
        "straggler_rates": list(straggler_rates),
        "hard_straggler_rate": hard_rate,
        "baseline_tail_median_err": base_err,
        "baseline_comm": base["comm"],
        "bitwise_controls": bitwise_controls,
        "cells": cells,
        "refresh_under_staleness": {
            "swaps": refreshed["swaps"],
            "tail_median_err": refresh_err,
            "gap_ratio": refresh_err / base_err,
            "n_traces": refreshed["n_traces"],
            "comm": refreshed["comm"],
        },
        "acceptance": {"wait_bar": 1.10, "degrade_bar": 1.20,
                       "all_cells_pass": True},
        "wall_s": wall,
    }
    emit(
        f"faults_stragglers_n{n}", wall / max(len(cells), 1) * 1e6,
        f"{len(cells)}cells_base={base_err:.2e}"
        f"_worst={worst['gap_ratio']:.2f}x@{worst['policy']}"
        f"t{worst['tau_max']}r{worst['straggler_rate']}"
        f"_bitwise0=ok_retraces=0",
    )


def _bench_crash_recovery(results: dict, smoke: bool) -> None:
    """n=8 micro scenario: one crash + rejoin + one refresh under faults,
    killed and resumed mid-run."""
    n, K, steps, seg, batch, lr = 8, 4, 120, 20, 2, 0.05
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=LAM))
    arrays = ref.schedule_arrays()
    rng = np.random.default_rng(4)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )

    # one crash + rejoin window on node 3, plus stragglers and edge drops
    # riding along; the churn windows double as the plan's alive mask
    churn = NodeChurn(Pi0=task.Pi, events=((30, 3, 25),), seed=0)
    plan = FaultPlan.from_node_churn(
        churn, steps=steps, seed=5,
        straggler_rate=0.3, tau_max=2, edge_drop_rate=0.05,
    )

    # one warm refresh lands mid-outage: the refreshed schedule is
    # degraded by the SAME fault trace from its swap step on
    def make_hook():
        done = {"swapped": False}

        def hook(t):
            if not done["swapped"] and t >= 39:
                done["swapped"] = True
                ref.refresh(task.Pi)  # warm re-solve (Pi_hat = exact Pi here)
                return ref.schedule_arrays()
            return None

        return hook

    kw = dict(lr=lr, seed=2, zs=zs, segment_len=seg)
    t0 = time.perf_counter()
    full = run_faulty_mean_estimation(
        task, plan, arrays, on_segment=make_hook(), **kw
    )
    assert full["n_traces"] == 1, full["n_traces"]
    assert full["swaps"] == [39], full["swaps"]

    with tempfile.TemporaryDirectory(prefix="faults_recovery_") as ckpt_dir:
        head = run_faulty_mean_estimation(
            task, plan, arrays, on_segment=make_hook(),
            checkpoint_dir=ckpt_dir, stop_after_segments=3, **kw
        )
        assert head["stopped_at"] == 60, head["stopped_at"]
        assert head["swaps"] == [39]  # the refresh landed BEFORE the crash
        tail_run = run_faulty_mean_estimation(
            task, plan, arrays, checkpoint_dir=ckpt_dir, resume=True, **kw
        )
    assert tail_run["resumed_from"] == 60
    wall = time.perf_counter() - t0

    glued = np.concatenate([head["mean_sq_error"], tail_run["mean_sq_error"]])
    bitwise = bool(np.array_equal(glued, full["mean_sq_error"])) and bool(
        np.array_equal(tail_run["theta"], full["theta"])
    )
    assert bitwise, "checkpoint-resume diverged from the uninterrupted run"
    final_full = float(full["mean_sq_error"][-1])
    final_resumed = float(glued[-1])
    rel_gap = abs(final_resumed - final_full) / max(abs(final_full), 1e-12)
    # acceptance: within 5% of the uninterrupted run -- bitwise equality
    # lands it at exactly 0
    assert rel_gap <= 0.05, rel_gap

    results["crash_recovery"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "crash_window": [30, 55], "crashed_node": 3,
        "refresh_at": full["swaps"],
        "killed_at": head["stopped_at"],
        "resumed_from": tail_run["resumed_from"],
        "n_traces": {"full": full["n_traces"], "head": head["n_traces"],
                     "tail": tail_run["n_traces"]},
        "final_err_uninterrupted": final_full,
        "final_err_resumed": final_resumed,
        "relative_gap": rel_gap,
        "bitwise_resume": bitwise,
        "alive_frac": full["alive_frac"],
        "comm_full": full["comm"],
        "wall_s": wall,
    }
    emit(
        f"faults_recovery_n{n}", wall * 1e6,
        f"bitwise={bitwise}_gap={rel_gap:.1e}_retraces=0"
        f"_refresh@{full['swaps'][0]}_killed@{head['stopped_at']}",
    )


def main(smoke: bool = False) -> None:
    results: dict = {"smoke": smoke}
    _bench_fault_sweep(results, smoke)
    _bench_straggler_sweep(results, smoke)
    _bench_crash_recovery(results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_faults_json", 0.0, path)


if __name__ == "__main__":
    main()

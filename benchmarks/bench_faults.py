"""Fault-tolerance benchmarks: convergence under injected faults, and
crash recovery (ISSUE 6).

1. **Fault sweep** -- the Section 6.1 mean-estimation task under a
   crash-rate x straggler-delay x edge-drop grid, every cell on the SAME
   observation stream as the fault-free baseline (equal iteration
   count, so the gap measures the faults, not the data). Per cell:
   tail-median squared error, the convergence gap vs fault-free, mean
   alive fraction, and delivered-vs-dropped comm bytes from the honest
   meter. Every cell asserts ``n_traces == 1``: the degraded-W swap,
   the straggler ring-buffer update, and the post-crash
   renormalization all reach the compiled rollout as data (the
   jit-cache-miss detector of the acceptance criteria).

2. **Straggler sweep** (ISSUE 8) -- bounded-delay gossip under a
   tau_max x straggler-fraction x {wait, degrade} grid, same
   observation stream as the fault-free baseline. Acceptance bars: at
   tau_max <= 4 and <= 25% stragglers the wait policy's tail error
   stays within 10% of fault-free and degrade within 20%; every cell
   -- including a topology refresh landing UNDER staleness -- runs at
   zero retraces, and the delays=0 control arm is BITWISE the fresh
   run (losses AND bytes).

3. **Crash recovery** -- the micro scenario CI runs in --smoke: n=8, a
   scripted node crash + rejoin window (via ``NodeChurn`` ->
   ``FaultPlan.from_node_churn``), one warm topology refresh landing
   mid-run UNDER the faults, then the run is killed at a segment
   boundary and resumed from its checkpoint. Asserts (smoke included):
   retraces == 0 across the degraded swap + refresh, and
   checkpoint-resume is BITWISE equal to the uninterrupted faulty run
   -- which lands the "final loss within 5% of uninterrupted" bar at
   exactly 0% gap (recorded honestly in the JSON).

4. **Corruption sweep** (ISSUE 10) -- wire corruption (nan / sign_flip /
   scale / bitflip) from a scripted fraction of persistently lying
   nodes, with the receiver-side screen + quarantine ON vs OFF, against
   the same observation stream. Acceptance bars: screen-on tail loss
   over the HONEST nodes within 1.2x fault-free at 10% corrupting
   nodes for every mode; the screen-off arm recorded honestly as the
   divergence baseline; the corruption-off control arm BITWISE the
   plain transport; a NaN-sender confirmed within the screen's
   confirm streak; the quarantine-repaired W doubly stochastic to
   1e-12; metered quarantined bytes equal to the closed-form fates;
   zero false quarantines across every ``data/drift.py``
   heterogeneity scenario with no corruption injected; and retraces
   == 0 everywhere.

Writes experiments/bench/BENCH_faults.json.
"""

import json
import os
import tempfile
import time

import numpy as np

from .common import emit, result_dir
from repro.core.mixing import (
    StragglerPolicy,
    degrade_schedule,
    schedule_from_result,
    schedule_to_arrays,
)
from repro.core.stl_fw import learn_topology
from repro.data.drift import (
    AbruptLabelSwap,
    ConceptShift,
    FeatureDrift,
    GradualDirichlet,
    NodeChurn,
)
from repro.data.synthetic import mean_estimation_clusters
from repro.faults import (
    FaultPlan,
    QuarantineController,
    ScreenPolicy,
    false_quarantines,
    run_faulty_mean_estimation,
)
from repro.online import RefreshConfig, TopologyRefresher

LAM = 0.1


def _bench_fault_sweep(results: dict, smoke: bool) -> None:
    if smoke:
        n, K, steps, seg, batch = 8, 4, 120, 20, 2
        crash_rates = (0.0, 0.05)
        tau_maxes = (0, 2)
        edge_drops = (0.0, 0.1)
    else:
        n, K, steps, seg, batch = 32, 8, 600, 50, 2
        crash_rates = (0.0, 0.01, 0.05)
        tau_maxes = (0, 2, 4)
        edge_drops = (0.0, 0.05, 0.15)
    lr = 0.05
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    sched0 = schedule_from_result(res0)
    arrays = schedule_to_arrays(sched0, sched0.n_atoms + 2)
    rng = np.random.default_rng(1)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )
    tail = slice(-max(10, steps // 10), None)

    def run(plan: FaultPlan) -> dict:
        out = run_faulty_mean_estimation(
            task, plan, arrays, lr=lr, seed=2, zs=zs, segment_len=seg
        )
        assert out["n_traces"] == 1, (
            f"fault scenario retraced the rollout: n_traces={out['n_traces']}"
        )
        return out

    base_plan = FaultPlan(n_nodes=n, steps=steps, seed=0)
    t0 = time.perf_counter()
    base = run(base_plan)
    base_err = float(np.median(base["mean_sq_error"][tail]))
    cells = []
    for cr in crash_rates:
        for tau in tau_maxes:
            for ed in edge_drops:
                if cr == 0.0 and tau == 0 and ed == 0.0:
                    continue  # that IS the baseline
                plan = FaultPlan(
                    n_nodes=n, steps=steps, seed=3,
                    crash_rate=cr, mean_outage=6.0,
                    straggler_rate=0.3 if tau else 0.0, tau_max=tau,
                    edge_drop_rate=ed,
                )
                out = run(plan)
                err = float(np.median(out["mean_sq_error"][tail]))
                cells.append({
                    "crash_rate": cr, "tau_max": tau, "edge_drop_rate": ed,
                    "tail_median_err": err,
                    "convergence_gap": err - base_err,
                    "gap_ratio": err / base_err,
                    "alive_frac": out["alive_frac"],
                    "comm": out["comm"],
                    "n_traces": out["n_traces"],
                })
    wall = time.perf_counter() - t0
    worst = max(cells, key=lambda c: c["gap_ratio"])
    results["fault_sweep"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "lam": LAM, "batch": batch,
        "crash_rates": list(crash_rates), "tau_maxes": list(tau_maxes),
        "edge_drop_rates": list(edge_drops),
        "baseline_tail_median_err": base_err,
        "baseline_comm": base["comm"],
        "cells": cells,
        "wall_s": wall,
    }
    emit(
        f"faults_sweep_n{n}", wall / max(len(cells), 1) * 1e6,
        f"{len(cells)}cells_base={base_err:.2e}"
        f"_worst={worst['gap_ratio']:.2f}x@cr{worst['crash_rate']}"
        f"t{worst['tau_max']}e{worst['edge_drop_rate']}_retraces=0",
    )


def _bench_straggler_sweep(results: dict, smoke: bool) -> None:
    """Bounded-delay gossip: tau_max x straggler-rate x policy grid."""
    if smoke:
        n, K, steps, seg, batch = 8, 4, 120, 20, 2
        hard_rate = 0.02
    else:
        n, K, steps, seg, batch = 32, 8, 600, 50, 2
        hard_rate = 0.01  # larger fleets tolerate fewer per-node cuts
    lr = 0.02
    tau_maxes = (2, 4)
    straggler_rates = (0.1, 0.25)
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    sched0 = schedule_from_result(res0)
    arrays = schedule_to_arrays(sched0, sched0.n_atoms + 2)
    rng = np.random.default_rng(6)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )
    tail = slice(-max(10, steps // 3), None)
    kw = dict(lr=lr, seed=2, zs=zs, segment_len=seg)

    def straggler_plan(tau: int, rate: float) -> FaultPlan:
        """Stragglers at ``rate`` with delays <= tau (on-time for a
        deadline of tau), plus a sparse seeded set of HARD stragglers
        whose delay exceeds any deadline in the grid -- the node-steps
        where wait (clamp to tau) and degrade (cut for the step)
        actually disagree. Post-editing ``plan.delays`` follows the
        ``from_node_churn`` precedent of scripting part of a trace."""
        plan = FaultPlan(
            n_nodes=n, steps=steps, seed=8,
            straggler_rate=rate, tau_max=tau,
        )
        srng = np.random.default_rng([8, 99, tau, int(rate * 100)])
        late = srng.random((steps, n)) < hard_rate
        plan.delays[late] = tau + 2
        return plan

    t0 = time.perf_counter()
    plan0 = FaultPlan(n_nodes=n, steps=steps, seed=0)
    base = run_faulty_mean_estimation(task, plan0, arrays, **kw)
    assert base["n_traces"] == 1
    base_err = float(np.median(base["mean_sq_error"][tail]))

    # delays=0 control arm: the stale data plane with an all-zero delay
    # trace must be BITWISE the fresh run -- losses AND bytes
    bitwise_controls = {}
    for mode in ("wait", "degrade"):
        ctrl = run_faulty_mean_estimation(
            task, plan0, arrays,
            staleness=StragglerPolicy(mode=mode, tau_max=4), **kw
        )
        assert ctrl["n_traces"] == 1, ctrl["n_traces"]
        assert np.array_equal(
            ctrl["mean_sq_error"], base["mean_sq_error"]
        ), f"delays=0 {mode} arm diverged bitwise from the fresh run"
        assert ctrl["comm"]["total_bytes"] == base["comm"]["total_bytes"]
        assert ctrl["comm"]["deferred_bytes"] == 0
        assert ctrl["comm"]["dropped_bytes"] == 0
        bitwise_controls[mode] = {
            "bitwise_losses": True,
            "total_bytes": ctrl["comm"]["total_bytes"],
        }

    def assert_comm_closed_form(out, plan, policy) -> None:
        """The metered bytes must equal the closed form from the plan's
        transfer fates, aggregated segment-by-segment exactly as the
        meter ticks (volume conservation + deferred subset)."""
        comm = out["comm"]
        per_step = comm["per_step_bytes"]
        assert comm["total_bytes"] + comm["dropped_bytes"] == steps * per_step
        exp_total = exp_deferred = 0
        for t0 in range(0, steps, seg):
            k = min(seg, steps - t0)
            fates = [
                plan.transfer_fracs(
                    t, deadline=policy.tau_max, mode=policy.mode
                )
                for t in range(t0, t0 + k)
            ]
            on = float(np.mean([f[0] for f in fates]))
            df = float(np.mean([f[1] for f in fates]))
            delivered = int(k * per_step * (on + df))
            exp_total += delivered
            # deferred derives from the truncated delivered volume (the
            # PR 9 CommMeter fix: subset invariant by construction)
            exp_deferred += (
                int(delivered * (df / (on + df))) if on + df > 0 else 0
            )
        assert comm["total_bytes"] == exp_total, (
            comm["total_bytes"], exp_total
        )
        assert comm["deferred_bytes"] == exp_deferred, (
            comm["deferred_bytes"], exp_deferred
        )

    cells = []
    for tau in tau_maxes:
        for rate in straggler_rates:
            plan = straggler_plan(tau, rate)
            for mode in ("wait", "degrade"):
                policy = StragglerPolicy(mode=mode, tau_max=tau)
                out = run_faulty_mean_estimation(
                    task, plan, arrays, staleness=policy, **kw
                )
                assert out["n_traces"] == 1, (
                    f"straggler cell retraced: {out['n_traces']}"
                )
                assert_comm_closed_form(out, plan, policy)
                err = float(np.median(out["mean_sq_error"][tail]))
                ratio = err / base_err
                # acceptance: tau_max <= 4, <= 25% stragglers => wait
                # within 10% of fault-free, degrade within 20%
                bar = 1.10 if mode == "wait" else 1.20
                assert ratio <= bar, (
                    f"{mode} tau={tau} rate={rate}: {ratio:.3f} > {bar}"
                )
                cells.append({
                    "tau_max": tau, "straggler_rate": rate, "policy": mode,
                    "tail_median_err": err,
                    "gap_ratio": ratio,
                    "comm": out["comm"],
                    "n_traces": out["n_traces"],
                })

    # one refresh lands UNDER live staleness: still zero retraces
    # (the refresher's own l_max padding is the base, so the swap is a
    # same-shape value change)
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=LAM))
    arrays_r = ref.schedule_arrays()
    plan_r = straggler_plan(4, 0.25)
    done = {"swapped": False}

    def hook(t):
        if not done["swapped"] and t >= 2 * seg - 1:
            done["swapped"] = True
            ref.refresh(task.Pi)
            return ref.schedule_arrays()
        return None

    refreshed = run_faulty_mean_estimation(
        task, plan_r, arrays_r,
        staleness=StragglerPolicy(mode="wait", tau_max=4),
        on_segment=hook, **kw
    )
    assert refreshed["n_traces"] == 1, refreshed["n_traces"]
    assert refreshed["swaps"] == [2 * seg - 1], refreshed["swaps"]
    assert_comm_closed_form(
        refreshed, plan_r, StragglerPolicy(mode="wait", tau_max=4)
    )
    refresh_err = float(np.median(refreshed["mean_sq_error"][tail]))
    assert refresh_err / base_err <= 1.10, refresh_err / base_err

    wall = time.perf_counter() - t0
    worst = max(cells, key=lambda c: c["gap_ratio"])
    results["straggler_sweep"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "lam": LAM, "batch": batch,
        "tau_maxes": list(tau_maxes),
        "straggler_rates": list(straggler_rates),
        "hard_straggler_rate": hard_rate,
        "baseline_tail_median_err": base_err,
        "baseline_comm": base["comm"],
        "bitwise_controls": bitwise_controls,
        "cells": cells,
        "refresh_under_staleness": {
            "swaps": refreshed["swaps"],
            "tail_median_err": refresh_err,
            "gap_ratio": refresh_err / base_err,
            "n_traces": refreshed["n_traces"],
            "comm": refreshed["comm"],
        },
        "acceptance": {"wait_bar": 1.10, "degrade_bar": 1.20,
                       "all_cells_pass": True},
        "wall_s": wall,
    }
    emit(
        f"faults_stragglers_n{n}", wall / max(len(cells), 1) * 1e6,
        f"{len(cells)}cells_base={base_err:.2e}"
        f"_worst={worst['gap_ratio']:.2f}x@{worst['policy']}"
        f"t{worst['tau_max']}r{worst['straggler_rate']}"
        f"_bitwise0=ok_retraces=0",
    )


def _bench_crash_recovery(results: dict, smoke: bool) -> None:
    """n=8 micro scenario: one crash + rejoin + one refresh under faults,
    killed and resumed mid-run."""
    n, K, steps, seg, batch, lr = 8, 4, 120, 20, 2, 0.05
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=LAM))
    arrays = ref.schedule_arrays()
    rng = np.random.default_rng(4)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )

    # one crash + rejoin window on node 3, plus stragglers and edge drops
    # riding along; the churn windows double as the plan's alive mask
    churn = NodeChurn(Pi0=task.Pi, events=((30, 3, 25),), seed=0)
    plan = FaultPlan.from_node_churn(
        churn, steps=steps, seed=5,
        straggler_rate=0.3, tau_max=2, edge_drop_rate=0.05,
    )

    # one warm refresh lands mid-outage: the refreshed schedule is
    # degraded by the SAME fault trace from its swap step on
    def make_hook():
        done = {"swapped": False}

        def hook(t):
            if not done["swapped"] and t >= 39:
                done["swapped"] = True
                ref.refresh(task.Pi)  # warm re-solve (Pi_hat = exact Pi here)
                return ref.schedule_arrays()
            return None

        return hook

    kw = dict(lr=lr, seed=2, zs=zs, segment_len=seg)
    t0 = time.perf_counter()
    full = run_faulty_mean_estimation(
        task, plan, arrays, on_segment=make_hook(), **kw
    )
    assert full["n_traces"] == 1, full["n_traces"]
    assert full["swaps"] == [39], full["swaps"]

    with tempfile.TemporaryDirectory(prefix="faults_recovery_") as ckpt_dir:
        head = run_faulty_mean_estimation(
            task, plan, arrays, on_segment=make_hook(),
            checkpoint_dir=ckpt_dir, stop_after_segments=3, **kw
        )
        assert head["stopped_at"] == 60, head["stopped_at"]
        assert head["swaps"] == [39]  # the refresh landed BEFORE the crash
        tail_run = run_faulty_mean_estimation(
            task, plan, arrays, checkpoint_dir=ckpt_dir, resume=True, **kw
        )
    assert tail_run["resumed_from"] == 60
    wall = time.perf_counter() - t0

    glued = np.concatenate([head["mean_sq_error"], tail_run["mean_sq_error"]])
    bitwise = bool(np.array_equal(glued, full["mean_sq_error"])) and bool(
        np.array_equal(tail_run["theta"], full["theta"])
    )
    assert bitwise, "checkpoint-resume diverged from the uninterrupted run"
    final_full = float(full["mean_sq_error"][-1])
    final_resumed = float(glued[-1])
    rel_gap = abs(final_resumed - final_full) / max(abs(final_full), 1e-12)
    # acceptance: within 5% of the uninterrupted run -- bitwise equality
    # lands it at exactly 0
    assert rel_gap <= 0.05, rel_gap

    results["crash_recovery"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "crash_window": [30, 55], "crashed_node": 3,
        "refresh_at": full["swaps"],
        "killed_at": head["stopped_at"],
        "resumed_from": tail_run["resumed_from"],
        "n_traces": {"full": full["n_traces"], "head": head["n_traces"],
                     "tail": tail_run["n_traces"]},
        "final_err_uninterrupted": final_full,
        "final_err_resumed": final_resumed,
        "relative_gap": rel_gap,
        "bitwise_resume": bitwise,
        "alive_frac": full["alive_frac"],
        "comm_full": full["comm"],
        "wall_s": wall,
    }
    emit(
        f"faults_recovery_n{n}", wall * 1e6,
        f"bitwise={bitwise}_gap={rel_gap:.1e}_retraces=0"
        f"_refresh@{full['swaps'][0]}_killed@{head['stopped_at']}",
    )


# scripted corruption planes: what a persistent liar writes onto the
# wire ("scale:8" per the plan grammar; the bitflip toggles exponent
# bit 25, a silent-data-corruption stand-in)
_CORRUPT_MODES = {
    "nan": (np.float32(np.nan), np.int32(0)),
    "sign_flip": (np.float32(-1.0), np.int32(0)),
    "scale:8": (np.float32(8.0), np.int32(0)),
    "bitflip": (np.float32(1.0), np.int32(1) << np.int32(25)),
}


def _dense_w(arrays, f64_renorm: bool = True) -> np.ndarray:
    """Reconstruct dense W from (gammas, perms): row i receives from
    perms[l, i] with weight gammas[l]."""
    gam = np.asarray(arrays.gammas, np.float64)
    per = np.asarray(arrays.perms, np.int64)
    if f64_renorm:
        gam = gam / gam.sum()  # strip the f32 storage rounding
    n = per.shape[1]
    W = np.zeros((n, n))
    for l in range(per.shape[0]):
        W[np.arange(n), per[l]] += gam[l]
    return W


def _bench_corruption_sweep(results: dict, smoke: bool) -> None:
    """Wire corruption x screening: the ISSUE 10 acceptance grid."""
    if smoke:
        n, K, steps, seg, batch = 8, 4, 120, 20, 2
    else:
        n, K, steps, seg, batch = 16, 4, 300, 30, 2
    lr = 0.05
    rates = (0.1, 0.25)
    t_start = 5  # liars start lying here (after a couple of honest steps)
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    res0 = learn_topology(task.Pi, budget=8, lam=LAM)
    sched0 = schedule_from_result(res0)
    arrays = schedule_to_arrays(sched0, sched0.n_atoms + 2)
    rng = np.random.default_rng(12)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(
        np.float32
    )
    tail = slice(-max(10, steps // 10), None)
    kw = dict(lr=lr, seed=2, zs=zs, segment_len=seg)
    # cooldown > run length: a confirmed liar stays isolated, so the
    # quarantine mask is monotone and the metered quarantined bytes have
    # a closed form the bench replays below
    policy = ScreenPolicy(
        confirm_streak=2, cooldown_steps=2 * steps, probation_steps=8
    )

    def honest_tail(out, honest) -> float:
        per_node = out["sq_error_nodes"]  # (steps, n), screened path
        return float(np.median(np.mean(per_node[:, honest], axis=1)[tail]))

    t0_wall = time.perf_counter()
    # plain transport baseline (corruption-off, no controller): compiles
    # the PRE-corruption scan body -- the bitwise reference
    plan0 = FaultPlan(n_nodes=n, steps=steps, seed=0)
    base = run_faulty_mean_estimation(task, plan0, arrays, **kw)
    assert base["n_traces"] == 1
    assert base["sq_error_nodes"] is None  # unscreened body ran

    # screened-clean baseline: controller ON, zero corruption. The
    # screened transport with a clean wire must reproduce the plain
    # trajectory BITWISE (the corruption-off acceptance bar), quarantine
    # nobody, and its per-node trace is the fault-free reference the
    # 1.2x honest-tail bar measures against.
    q0 = QuarantineController(n, policy, lr=lr)
    clean = run_faulty_mean_estimation(
        task, plan0, arrays, quarantine=q0, **kw
    )
    assert clean["n_traces"] == 1
    corruption_off_bitwise = bool(
        np.array_equal(clean["mean_sq_error"], base["mean_sq_error"])
    )
    assert corruption_off_bitwise, (
        "screened transport with a clean wire diverged from the plain "
        "transport"
    )
    assert q0.n_quarantines == 0, q0.summary()
    assert clean["comm"]["quarantined_bytes"] == 0

    def liar_plan(liars, mult, xor) -> FaultPlan:
        """A clean plan post-edited into persistent liars (the
        ``from_node_churn`` precedent of scripting a derived trace)."""
        p = FaultPlan(n_nodes=n, steps=steps, seed=0)
        p.corrupt_mult[t_start:, liars] = mult
        p.corrupt_xor[t_start:, liars] = xor
        assert p.has_corruption
        return p

    def expected_quarantined_bytes(plan, events, comm) -> int:
        """Closed-form byte fates: replay the meter's segment ticks from
        the event log (mask from segment s's evidence is ACTIVE in
        segment s+1, cooldown > steps makes it monotone)."""
        per_step = comm["per_step_bytes"]
        q_ev = [(e["t"], e["node"]) for e in events
                if e["event"] == "quarantine"]
        total = 0
        for ts in range(0, steps, seg):
            k = min(seg, steps - ts)
            mask = np.zeros(n, dtype=bool)
            for (t_ev, i_ev) in q_ev:
                if t_ev < ts:
                    mask[i_ev] = True
            frac = float(np.mean(
                [plan.delivered_frac(t) for t in range(ts, ts + k)]
            ))
            qf = float(np.mean(
                [plan.quarantined_frac(t, mask) for t in range(ts, ts + k)]
            )) if mask.any() else 0.0
            delivered = int(k * per_step * frac)
            total += int(delivered * (qf / frac)) if frac > 0 else 0
        return total

    cells = []
    for rate in rates:
        h = max(1, round(rate * n))
        liars = list(range(h))
        honest = [i for i in range(n) if i >= h]
        # the fault-free reference for this rate is the ORACLE isolation
        # run: the liar slots simply offline from t_start (scripted
        # alive mask), clean wire, same screened transport. Removing a
        # node's data shifts the fleet optimum (Byzantine-robust
        # convention: the defense answers for the honest fleet vs the
        # best reachable honest-data solution, not vs an optimum that
        # still averages the liars' data in) -- so the 1.2x bar
        # measures the screen's overhead (detection latency + guard
        # substitution), not the optimum shift.
        oracle_plan = FaultPlan(n_nodes=n, steps=steps, seed=0)
        oracle_plan.alive[t_start:, liars] = False
        q_or = QuarantineController(n, policy, lr=lr)
        oracle = run_faulty_mean_estimation(
            task, oracle_plan, arrays, quarantine=q_or, **kw
        )
        assert oracle["n_traces"] == 1
        # absence is not evidence: the oracle's dead slots must not trip
        # the screen (they are self-loops -- never exposed)
        assert q_or.n_quarantines == 0, q_or.summary()
        base_honest = honest_tail(oracle, honest)
        for mode, (mult, xor) in _CORRUPT_MODES.items():
            # -- screen ON: quarantine controller drives the defense
            q = QuarantineController(n, policy, lr=lr)
            plan = liar_plan(liars, mult, xor)
            on = run_faulty_mean_estimation(
                task, plan, arrays, quarantine=q, **kw
            )
            assert on["n_traces"] == 1, on["n_traces"]
            err_on = honest_tail(on, honest)
            ratio = err_on / base_honest
            fq = false_quarantines(q.events, plan)
            assert fq == 0, (
                f"{mode}@{rate}: {fq} false quarantines: {q.summary()}"
            )
            # acceptance bar: at 10% corrupting nodes the honest fleet's
            # tail loss stays within 1.2x fault-free, every mode
            if rate <= 0.1:
                assert ratio <= 1.2, (
                    f"{mode}@{rate}: honest tail {ratio:.3f}x > 1.2x"
                )
            if mode == "nan":
                # a NaN-sender trips the hard non-finite screen on its
                # very first lie: confirmed within the streak, exactly
                first = {}
                for e in q.events:
                    if e["event"] == "quarantine":
                        first.setdefault(e["node"], e["t"])
                for i in liars:
                    assert i in first, f"NaN liar {i} never caught: {first}"
                    assert first[i] == t_start + policy.confirm_streak - 1, (
                        f"NaN liar {i} confirmed at {first[i]}, expected "
                        f"{t_start + policy.confirm_streak - 1}"
                    )
            # metered quarantine fates match the closed form, and stay a
            # subset of delivered volume
            exp_q = expected_quarantined_bytes(plan, q.events, on["comm"])
            assert on["comm"]["quarantined_bytes"] == exp_q, (
                on["comm"]["quarantined_bytes"], exp_q
            )
            assert on["comm"]["quarantined_bytes"] <= on["comm"]["total_bytes"]
            # the repaired schedule (liars pinned to self-loops) is
            # exactly doubly stochastic on f64-renormalized gammas
            if q.mask().any():
                deg = degrade_schedule(arrays, ~q.mask())
                W = _dense_w(deg)
                ds_err = max(
                    float(np.abs(W.sum(axis=0) - 1.0).max()),
                    float(np.abs(W.sum(axis=1) - 1.0).max()),
                )
                assert ds_err <= 1e-12, f"repaired W not DS: {ds_err:.2e}"
                for i in np.flatnonzero(q.mask()):
                    # isolated row/col: no off-diagonal mass at all, and
                    # the self-loop carries the full (renormalized) unit
                    assert float(np.abs(np.delete(W[i], i)).max()) == 0.0
                    assert float(np.abs(np.delete(W[:, i], i)).max()) == 0.0
                    assert abs(W[i, i] - 1.0) <= 1e-12
            else:
                ds_err = 0.0

            # -- screen OFF: same corruption, no controller -- the
            # honest divergence baseline (nan poisons the fleet; the
            # JSON records None where the tail is not finite)
            off = run_faulty_mean_estimation(
                task, liar_plan(liars, mult, xor), arrays, quarantine=None,
                **kw
            )
            assert off["n_traces"] == 1
            off_tail = honest_tail(off, honest)
            off_finite = bool(np.isfinite(off_tail))
            cells.append({
                "rate": rate, "mode": mode, "n_liars": h,
                "screen_on_honest_tail": err_on,
                "screen_on_ratio": ratio,
                "screen_off_honest_tail": off_tail if off_finite else None,
                "screen_off_finite": off_finite,
                "n_quarantines": q.n_quarantines,
                "quarantined_now": q.summary()["quarantined_now"],
                "false_quarantines": fq,
                "quarantined_bytes": on["comm"]["quarantined_bytes"],
                "repaired_w_ds_err": ds_err,
                "n_traces": on["n_traces"],
            })

    # -- false-quarantine drill: every data/drift.py heterogeneity
    # scenario, zero corruption. Observation means follow the scenario's
    # OWN Pi(t) (plus FeatureDrift's covariate offset), so the fleet is
    # heterogeneous AND drifting -- and the probe-derived screen must
    # still flag nobody, because its allowance is measured on the run.
    cmeans = np.linspace(-5.0, 5.0, K)
    drift_rng = np.random.default_rng(30)
    t_d = steps // 2

    def zs_from_scenario(scn) -> np.ndarray:
        out = np.empty((steps, n, batch), dtype=np.float32)
        for t in range(steps):
            mu = scn.Pi(t) @ cmeans
            if hasattr(scn, "feature_shift"):
                mu = mu + scn.feature_shift(t)[:, 0]
            out[t] = mu[:, None] + drift_rng.normal(size=(n, batch))
        return out

    churn = NodeChurn(Pi0=task.Pi, events=((t_d, 2, 10),), seed=0)
    scenarios = {
        "abrupt_label_swap": (
            AbruptLabelSwap(
                Pi0=task.Pi, t_drift=t_d,
                node_perm=drift_rng.permutation(n),
            ),
            FaultPlan(n_nodes=n, steps=steps, seed=0),
        ),
        "gradual_dirichlet": (
            GradualDirichlet(
                Pi0=task.Pi, t_start=steps // 3, t_end=2 * steps // 3, seed=1
            ),
            FaultPlan(n_nodes=n, steps=steps, seed=0),
        ),
        # churn rides with its matching crash trace: the screen must not
        # blame a node for going silent (dead nodes are self-loops --
        # not exposed, never voted on)
        "node_churn": (
            churn,
            FaultPlan.from_node_churn(churn, steps=steps),
        ),
        "feature_drift": (
            FeatureDrift(Pi0=task.Pi, t_drift=t_d, dim=4, seed=0),
            FaultPlan(n_nodes=n, steps=steps, seed=0),
        ),
        "concept_shift": (
            ConceptShift(Pi0=task.Pi, t_drift=t_d, dim=4, seed=0),
            FaultPlan(n_nodes=n, steps=steps, seed=0),
        ),
    }
    fp_drill = {}
    for name, (scn, plan) in scenarios.items():
        q = QuarantineController(n, policy, lr=lr)
        out = run_faulty_mean_estimation(
            task, plan, arrays, quarantine=q,
            lr=lr, seed=2, zs=zs_from_scenario(scn), segment_len=seg,
        )
        assert out["n_traces"] == 1
        # zero corruption injected => ANY quarantine would be false
        assert q.n_quarantines == 0, f"{name}: {q.summary()}"
        assert false_quarantines(q.events, plan) == 0
        fp_drill[name] = {
            "n_quarantines": 0, "false_quarantine_rate": 0.0,
            "n_traces": out["n_traces"],
        }

    wall = time.perf_counter() - t0_wall
    worst = max(cells, key=lambda c: c["screen_on_ratio"])
    results["corruption_sweep"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg, "lr": lr,
        "lam": LAM, "batch": batch, "rates": list(rates),
        "modes": list(_CORRUPT_MODES), "liar_start": t_start,
        "policy": {
            "slack": policy.slack, "abs_floor": policy.abs_floor,
            "confirm_streak": policy.confirm_streak,
            "cooldown_steps": policy.cooldown_steps,
            "probation_steps": policy.probation_steps,
        },
        "baseline_honest_tail": honest_tail(clean, list(range(n))),
        "corruption_off_bitwise": corruption_off_bitwise,
        "cells": cells,
        "false_quarantine_drill": fp_drill,
        "acceptance": {
            "honest_tail_bar": 1.2, "at_rate": 0.1,
            "all_cells_pass": True,
            "false_quarantine_rate": 0.0,
        },
        "wall_s": wall,
    }
    emit(
        f"faults_corruption_n{n}", wall / max(len(cells), 1) * 1e6,
        f"{len(cells)}cells_worst={worst['screen_on_ratio']:.2f}x"
        f"@{worst['mode']}r{worst['rate']}_fp=0_bitwise0=ok_retraces=0",
    )


def main(smoke: bool = False) -> None:
    results: dict = {"smoke": smoke}
    _bench_fault_sweep(results, smoke)
    _bench_straggler_sweep(results, smoke)
    _bench_crash_recovery(results, smoke)
    _bench_corruption_sweep(results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_faults.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_faults_json", 0.0, path)


if __name__ == "__main__":
    main()

"""Sparse Birkhoff mixing engine benchmarks (the first BENCH json).

Three comparisons, all on the n-node simulator hot path:

1. Transport throughput on an 8M-parameter stacked buffer (8M params
   TOTAL across the n nodes -- so per-node size and leaf count shrink as n
   grows; each row records its own n_leaves/params_per_node, compare rows
   at equal n only). Many small leaves = the deep-narrow regime the seed
   trainer actually mixes: the seed path
   (eager, leaf-by-leaf ``mix_dense``) vs the jitted dense pytree path vs
   the single-buffer Birkhoff schedule transport, at n in {16, 64} and
   L in {2, 8} atoms. Ops/sec = mixing steps per second.
2. Rollout compilation: scan-compiled ``run_mean_estimation`` vs the seed's
   per-step eager loop with a host sync every iteration (steps=500).
3. Incremental STL-FW vs the reference implementation at n=512, budget=64
   (trace-identical by construction; see test_stl_fw_incremental.py).

Writes experiments/bench/BENCH_mixing.json with every ratio so later PRs
have a perf trajectory to regress against. Wall-clock numbers on CI
containers are noisy (~2x run-to-run); the JSON stores medians.
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, result_dir
from repro.core import topology as T
from repro.core.mixing import (
    BirkhoffSchedule,
    _mix_schedule_flat,
    mix_dense,
    mix_schedule_stacked,
    ravel_stack,
)
from repro.core.dsgd import dsgd_init, dsgd_step_stacked
from repro.core.stl_fw import learn_topology
from repro.data.synthetic import mean_estimation_clusters
from repro.train.trainer import run_mean_estimation

TOTAL_PARAMS = 8_000_000
FW_N, FW_K, FW_BUDGET = 512, 4096, 64


def _median_time(fn, iters=5, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _many_leaf_stack(n: int, rng, total: int = TOTAL_PARAMS) -> dict:
    """~8M params TOTAL (across nodes) in transformer-ish w/b-sized leaves.

    Per-node size is 8M/n: rows of BENCH_mixing.json at different n are
    different workloads; only same-n comparisons are apples-to-apples.
    """
    leaves, tot, i = {}, 0, 0
    while tot < total:
        for s in (1024, 32 * 32, 2048, 64 * 48):
            leaves[f"p{i}"] = jnp.asarray(
                rng.normal(size=(n, s)).astype(np.float32)
            )
            tot += n * s
            i += 1
    return leaves


def _random_schedule(n: int, L: int, rng) -> BirkhoffSchedule:
    perms = [tuple(range(n))] + [
        tuple(int(x) for x in rng.permutation(n)) for _ in range(L - 1)
    ]
    coeffs = rng.random(L) + 0.2
    coeffs /= coeffs.sum()
    return BirkhoffSchedule(coeffs=tuple(float(c) for c in coeffs), perms=tuple(perms))


def bench_transports(results: dict, smoke: bool = False) -> None:
    rng = np.random.default_rng(0)
    for n in (8,) if smoke else (16, 64):
        tree = _many_leaf_stack(n, rng, total=200_000 if smoke else TOTAL_PARAMS)
        flat, spec = ravel_stack(tree)
        for L in (2,) if smoke else (2, 8):
            sched = _random_schedule(n, L, rng)
            Wj = jnp.asarray(sched.to_matrix(), jnp.float32)

            # seed hot path: eager, one dispatch per leaf
            t_dense_eager = _median_time(lambda: mix_dense(tree, Wj))
            # compiled dense pytree path
            dense_jit = jax.jit(lambda t: mix_dense(t, Wj))
            t_dense_jit = _median_time(lambda: dense_jit(tree))
            # schedule transport inside jit (per-leaf gathers, fused)
            sched_jit = jax.jit(lambda t: mix_schedule_stacked(t, sched))
            t_sched = _median_time(lambda: sched_jit(tree))
            # steady-state trainer path: buffer stays flat across steps
            flat_jit = jax.jit(lambda f: _mix_schedule_flat(f, sched))
            t_flat = _median_time(lambda: flat_jit(flat))

            key = f"n{n}_L{L}"
            results[key] = {
                "n": n,
                "L": L,
                "params_per_node": int(spec.total),
                "n_leaves": len(tree),
                "dense_eager_ops_per_s": 1.0 / t_dense_eager,
                "dense_jit_ops_per_s": 1.0 / t_dense_jit,
                "schedule_ops_per_s": 1.0 / t_sched,
                "schedule_flat_ops_per_s": 1.0 / t_flat,
                "speedup_vs_seed_dense": t_dense_eager / t_sched,
                "speedup_flat_vs_seed_dense": t_dense_eager / t_flat,
            }
            emit(
                f"mixing_dense_seed_{key}", t_dense_eager * 1e6,
                f"{1.0/t_dense_eager:.1f}ops/s",
            )
            emit(f"mixing_dense_jit_{key}", t_dense_jit * 1e6, f"{1.0/t_dense_jit:.1f}ops/s")
            emit(
                f"mixing_schedule_{key}", t_sched * 1e6,
                f"{t_dense_eager/t_sched:.2f}x_vs_seed",
            )
            emit(
                f"mixing_schedule_flat_{key}", t_flat * 1e6,
                f"{t_dense_eager/t_flat:.2f}x_vs_seed",
            )

    # Pallas gossip_schedule kernel: interpret mode on CPU is a Python-loop
    # stand-in -- record correctness delta + time at a small size only.
    n, L, P = (4, 2, 512) if smoke else (8, 3, 4096)
    rng2 = np.random.default_rng(1)
    theta = jnp.asarray(rng2.normal(size=(n, P)), jnp.float32)
    sched = _random_schedule(n, L, rng2)
    from repro.kernels.gossip_mix import gossip_schedule, gossip_schedule_ref

    coeffs, perms = sched.coeff_array(), sched.perm_array()
    t_kern = _median_time(lambda: gossip_schedule(theta, coeffs, perms), iters=3)
    err = float(
        jnp.max(
            jnp.abs(
                gossip_schedule(theta, coeffs, perms)
                - gossip_schedule_ref(theta, jnp.asarray(coeffs), jnp.asarray(perms))
            )
        )
    )
    results[f"kernel_interpret_{n}x{P}_L{L}"] = {"seconds": t_kern, "maxerr": err}
    emit(f"mixing_kernel_interpret_{n}x{P}", t_kern * 1e6, f"maxerr={err:.1e}")


def _seed_style_loop(task, W, steps, lr, seed):
    """The pre-scan trainer loop: eager step + host sync every iteration."""
    n = task.n_nodes
    rng = np.random.default_rng(seed)
    theta = jnp.zeros((n, 1))
    state = dsgd_init(theta)
    Wj = jnp.asarray(W, jnp.float32)
    theta_star = task.theta_star
    mse = []
    for _ in range(steps):
        z = jnp.asarray(task.sample(1, rng), jnp.float32)
        grads = 2.0 * (theta - z.mean(axis=1, keepdims=True))
        theta, state = dsgd_step_stacked(theta, grads, state, Wj, lr)
        err = np.asarray((theta[:, 0] - theta_star) ** 2)  # host sync
        mse.append(float(err.mean()))
    return np.array(mse)


def bench_rollout(results: dict, smoke: bool = False) -> None:
    n_nodes = 16 if smoke else 40
    task = mean_estimation_clusters(n_nodes=n_nodes, K=10, m=5.0)
    W = T.ring(n_nodes)
    steps = 50 if smoke else 500
    t_loop = _median_time(lambda: _seed_style_loop(task, W, steps, 0.2, 0), iters=3)
    t_scan = _median_time(
        lambda: run_mean_estimation(task, W, steps=steps, lr=0.2, seed=0, rollout="scan"),
        iters=3,
    )
    results[f"rollout_mean_estimation_{steps}"] = {
        "seed_loop_s": t_loop,
        "scan_s": t_scan,
        "speedup": t_loop / t_scan,
    }
    emit(f"rollout_seed_loop_{steps}", t_loop * 1e6, "eager+host-sync/step")
    emit(f"rollout_scan_{steps}", t_scan * 1e6, f"{t_loop/t_scan:.1f}x_vs_loop")


def bench_stl_fw(results: dict, smoke: bool = False) -> None:
    fw_n, fw_k, fw_budget = (48, 64, 8) if smoke else (FW_N, FW_K, FW_BUDGET)
    rng = np.random.default_rng(1)
    Pi = rng.dirichlet(np.ones(fw_k) * 0.1, size=fw_n)
    t0 = time.perf_counter()
    ref = learn_topology(Pi, budget=fw_budget, lam=0.1, method="reference")
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    inc = learn_topology(Pi, budget=fw_budget, lam=0.1, method="incremental")
    t_inc = time.perf_counter() - t0
    trace_diff = float(np.abs(ref.objective_trace - inc.objective_trace).max())
    results[f"stl_fw_n{fw_n}_K{fw_k}_b{fw_budget}"] = {
        "reference_s": t_ref,
        "incremental_s": t_inc,
        "speedup": t_ref / t_inc,
        "objective_trace_maxdiff": trace_diff,
    }
    emit(f"stl_fw_reference_n{fw_n}", t_ref * 1e6, f"budget={fw_budget}")
    emit(
        f"stl_fw_incremental_n{fw_n}", t_inc * 1e6,
        f"{t_ref/t_inc:.1f}x_tracediff={trace_diff:.1e}",
    )


def main(smoke: bool = False) -> None:
    results: dict = {}
    bench_transports(results, smoke)
    bench_rollout(results, smoke)
    bench_stl_fw(results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_mixing.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_mixing_json", 0.0, path)


if __name__ == "__main__":
    main()

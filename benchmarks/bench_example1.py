"""Example 1 (paper Sec. 4.1 / App. A): the alternating ring controls
neighborhood heterogeneity regardless of cluster separation m.

Validates: tau_bar^2 stays <= 4*sigma~^2 for every m while zeta_bar^2 = 4m^2
diverges; D-SGD on the ring converges at an m-independent rate.
"""

import time

import numpy as np

from .common import emit, save_rows
from repro.core import topology as T
from repro.core.heterogeneity import (
    local_heterogeneity,
    neighborhood_heterogeneity_mc,
)
from repro.data.synthetic import MeanEstimationTask
from repro.train.trainer import run_mean_estimation


def main(smoke: bool = False) -> None:
    n, sig2 = 20, 1.0
    mc_samples, steps = (100, 10) if smoke else (1000, 60)
    W = T.alternating_ring(n)
    rows = []
    t0 = time.perf_counter()
    for m in (0.0, 125.0) if smoke else (0.0, 1.0, 5.0, 25.0, 125.0):
        task = MeanEstimationTask(
            n_nodes=n, K=2, cluster_means=np.array([m, -m]), sigma_tilde2=sig2
        )
        G = task.expected_grads(0.0)
        zeta2 = local_heterogeneity(G)

        def sampler(rng, task=task):
            z = rng.normal(task.node_means, np.sqrt(sig2))
            return (-2.0 * z)[:, None]

        H = neighborhood_heterogeneity_mc(W, sampler, n_samples=mc_samples, seed=0)
        out = run_mean_estimation(task, W, steps=steps, lr=0.2, seed=0)
        rows.append([m, zeta2, H, 4 * sig2, out["mean_sq_error"][-1]])
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    save_rows("example1.csv", ["m", "zeta2", "H_measured", "tau2_bound", "final_mse"], rows)
    # derived: max measured H across m (must stay below the 4*sigma~^2 bound)
    max_h = max(r[2] for r in rows)
    emit("example1_ring_vs_m", us, f"maxH={max_h:.3f}<=bound4.0;zeta2(m=125)={rows[-1][1]:.0f}")


if __name__ == "__main__":
    main()

"""STL-FW LMO benchmarks: warm-started auction vs the exact references.

Sweeps n in {128, 512, 1024} x budget in {16, 64} on Dirichlet(0.1)
label-skew Pi and measures, per combination:

* end-to-end ``learn_topology`` wall clock for ``lmo="scipy"`` and
  ``lmo="auction"`` (both incremental method, identical trajectories);
* per-call LMO cost split into the cold first solve and the warm
  remainder (the auction carries dual prices across FW iterations;
  scipy re-solves cold every time);
* the dependency-free ``hungarian`` reference: measured end-to-end at
  the smallest n only (it is ~6 s *per LMO call* at n=512), measured
  per-call at n <= 512, and extrapolated end-to-end elsewhere as
  ``cold_lmo * budget + shared FW overhead`` (fields marked ``_est``).

Honest headline (recorded in the JSON): against the pure-python
Hungarian reference -- what a scipy-less deployment would otherwise run
-- the warm-started auction is 2-3 orders of magnitude faster end to
end. Against scipy's C Jonker-Volgenant solver the numpy auction does
NOT win at these sizes: the FW gradient update penalizes exactly the
previously-matched pairs (the ``lam W`` term), so every warm solve
still re-bids most rows, and a C inner loop beats a numpy one. That is
why ``lmo="auto"`` resolves to scipy when it is importable and auction
otherwise (see ROADMAP for the jitted-auction follow-up).

Writes experiments/bench/BENCH_stl_fw.json.
"""

import json
import os
import time

import numpy as np

from .common import emit, result_dir
from repro.core.assignment import hungarian
from repro.core.stl_fw import LMOSolver, learn_topology, resolve_lmo_backend

LAM = 0.1
# hungarian is O(n^3) python: ~0.6 s/solve at n=128, ~6 s at n=512.
HUNGARIAN_E2E_MAX_N = 128
HUNGARIAN_LMO_MAX_N = 512


class _RecordingLMO(LMOSolver):
    """LMOSolver that records per-call wall clock and auction counters."""

    def __init__(self, backend: str):
        super().__init__(backend)
        self.times: list[float] = []
        self.rebids: list[int] = []
        self.grads: list[np.ndarray] = []
        self.keep_grads = False

    def __call__(self, grad):
        if self.keep_grads and not self.grads:  # only the cold-start gradient
            self.grads.append(np.array(grad, copy=True))
        t0 = time.perf_counter()
        out = super().__call__(grad)
        self.times.append(time.perf_counter() - t0)
        if self.state is not None:
            self.rebids.append(int(self.state.n_rebid_rows))
        return out


def _bench_combo(n: int, budget: int, results: dict, smoke: bool) -> None:
    rng = np.random.default_rng(n + budget)
    K = n
    Pi = rng.dirichlet(np.ones(K) * 0.1, size=n)

    combo: dict = {"n": n, "budget": budget, "K": K, "lam": LAM}

    # --- end-to-end learn_topology, scipy vs auction -----------------------
    lmo_scipy = _RecordingLMO("scipy")
    lmo_scipy.keep_grads = n <= HUNGARIAN_LMO_MAX_N
    t0 = time.perf_counter()
    res_scipy = learn_topology(Pi, budget=budget, lam=LAM, lmo=lmo_scipy)
    t_scipy = time.perf_counter() - t0

    lmo_auction = _RecordingLMO("auction")
    t0 = time.perf_counter()
    res_auction = learn_topology(Pi, budget=budget, lam=LAM, lmo=lmo_auction)
    t_auction = time.perf_counter() - t0

    trace_maxdiff = float(
        np.abs(res_scipy.objective_trace - res_auction.objective_trace).max()
    )
    combo["e2e_s"] = {"scipy": t_scipy, "auction": t_auction}
    combo["trace_maxdiff_auction_vs_scipy"] = trace_maxdiff
    combo["lmo_cold_s"] = {
        "scipy": lmo_scipy.times[0],
        "auction": lmo_auction.times[0],
    }
    combo["lmo_warm_avg_s"] = {
        "scipy": float(np.mean(lmo_scipy.times[1:])) if budget > 1 else None,
        "auction": float(np.mean(lmo_auction.times[1:])) if budget > 1 else None,
    }
    combo["auction_rebid_rows_avg"] = (
        float(np.mean(lmo_auction.rebids[1:])) if budget > 1 else None
    )
    # FW overhead shared by every backend (gradient assembly, line search,
    # state updates): end-to-end minus the time spent inside the LMO.
    fw_overhead = t_scipy - float(np.sum(lmo_scipy.times))
    combo["fw_overhead_s"] = fw_overhead

    # --- the dependency-free hungarian reference ---------------------------
    if n <= HUNGARIAN_LMO_MAX_N and lmo_scipy.grads:
        t0 = time.perf_counter()
        hungarian(lmo_scipy.grads[0])
        t_h_cold = time.perf_counter() - t0
        combo["lmo_cold_s"]["hungarian"] = t_h_cold
        combo["e2e_hungarian_est_s"] = t_h_cold * budget + fw_overhead
        combo["speedup_e2e_auction_vs_hungarian_est"] = (
            combo["e2e_hungarian_est_s"] / t_auction
        )
    if n <= HUNGARIAN_E2E_MAX_N and (budget <= 16 or smoke):
        t0 = time.perf_counter()
        res_h = learn_topology(Pi, budget=budget, lam=LAM, lmo="hungarian")
        t_h = time.perf_counter() - t0
        combo["e2e_s"]["hungarian"] = t_h
        combo["trace_maxdiff_hungarian_vs_scipy"] = float(
            np.abs(res_scipy.objective_trace - res_h.objective_trace).max()
        )
        combo["speedup_e2e_auction_vs_hungarian"] = t_h / t_auction

    combo["speedup_e2e_auction_vs_scipy"] = t_scipy / t_auction

    key = f"n{n}_b{budget}"
    results[key] = combo
    emit(
        f"stl_fw_e2e_scipy_{key}", t_scipy * 1e6,
        f"cold_lmo={1e3 * combo['lmo_cold_s']['scipy']:.1f}ms",
    )
    emit(
        f"stl_fw_e2e_auction_{key}", t_auction * 1e6,
        f"{combo['speedup_e2e_auction_vs_scipy']:.2f}x_vs_scipy_"
        f"tracediff={trace_maxdiff:.1e}",
    )
    if "speedup_e2e_auction_vs_hungarian" in combo:
        emit(
            f"stl_fw_e2e_hungarian_{key}", combo["e2e_s"]["hungarian"] * 1e6,
            f"auction_{combo['speedup_e2e_auction_vs_hungarian']:.0f}x_faster",
        )
    elif "speedup_e2e_auction_vs_hungarian_est" in combo:
        emit(
            f"stl_fw_e2e_hungarian_est_{key}", combo["e2e_hungarian_est_s"] * 1e6,
            f"auction_{combo['speedup_e2e_auction_vs_hungarian_est']:.0f}x_faster_est",
        )


def main(smoke: bool = False) -> None:
    results: dict = {}
    sweep = [(32, 8)] if smoke else [
        (n, b) for n in (128, 512, 1024) for b in (16, 64)
    ]
    if resolve_lmo_backend("scipy") != "scipy":
        # Without scipy the "scipy" arm resolves to the pure-python
        # hungarian (~6 s per LMO call at n=512): the full sweep would
        # grind for hours and the reference labels would lie. Shrink to
        # the one combination where hungarian is practical.
        emit("bench_stl_fw_no_scipy", 0.0, "reference=hungarian;sweep=n128_b16")
        sweep = [(32, 8)] if smoke else [(128, 16)]
        results["reference_backend"] = "hungarian"
    for n, budget in sweep:
        _bench_combo(n, budget, results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_stl_fw.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_stl_fw_json", 0.0, path)


if __name__ == "__main__":
    main()

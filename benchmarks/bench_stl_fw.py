"""STL-FW LMO benchmarks: compiled auction vs numpy auction vs the exact
references.

Sweeps n in {128, 512, 1024} x budget in {16, 64} on Dirichlet(0.1)
label-skew Pi and measures, per combination and per backend
(scipy / auction / auction_jit):

* end-to-end ``learn_topology`` wall clock (identical trajectories --
  asserted in-bench: backend drift beyond 1e-9 on the objective trace
  raises, so the CI smoke tier catches it);
* per-call LMO cost split into the cold first solve -- which for
  ``auction_jit`` includes the one-time trace+compile of the
  ``lax.while_loop`` engine -- and the steady-state remainder, reported
  as the MEDIAN over calls 2..budget (the mean would let the compile
  call or one slow outlier pollute the steady number);
* the dependency-free ``hungarian`` reference: measured end-to-end at
  the smallest n only (it is ~6 s *per LMO call* at n=512), measured
  per-call at n <= 512, and extrapolated end-to-end elsewhere as
  ``cold_lmo * budget + shared FW overhead`` (fields marked ``_est``).

Honest headline (recorded in the JSON, this container = 2 vCPU):

* ``auction_jit`` beats the numpy ``auction`` ~1.8-3.1x steady-state at
  every n (n=512/b=64: 35 vs 91 ms/solve, 2.6x) -- real, but well short
  of the ~10x the dispatch-overhead arithmetic promised (and of this
  issue's >= 5x target): once compiled, each Gauss-Seidel bid is
  memory-bandwidth-bound (~6 O(n) passes), and the numpy solver's
  Jacobi rounds amortize its dispatch better than the per-bid 10us
  model assumed.
* scipy's C Jonker-Volgenant REMAINS the fastest steady-state LMO at
  every measured n (within ~1.7-1.9x of auction_jit at n >= 512, far
  ahead at small n). ``auto`` therefore still resolves to scipy when
  importable; ``auction_jit`` is the best scipy-less backend once its
  ~1-3 s one-time compile amortizes
  (see ``repro.core.stl_fw._jit_amortizes``).

``--smoke`` runs the sweep at (n=32, budget=8), exercises ALL four
backends including ``auction_jit`` (tracing-regression detector), and
asserts every backend reaches the same ``<P, G>`` LMO objective on a
fixed-seed gradient -- the backend-drift rot detector CI relies on.

Writes experiments/bench/BENCH_stl_fw.json.
"""

import json
import os
import time

import numpy as np

from .common import emit, result_dir
from repro.core.assignment import hungarian, solve_lmo
from repro.core.stl_fw import LMOSolver, learn_topology, resolve_lmo_backend

LAM = 0.1
# hungarian is O(n^3) python: ~0.6 s/solve at n=128, ~6 s at n=512.
HUNGARIAN_E2E_MAX_N = 128
HUNGARIAN_LMO_MAX_N = 512
# backends timed end-to-end in every combo (hungarian is special-cased)
BACKENDS = ("scipy", "auction", "auction_jit")


class _RecordingLMO(LMOSolver):
    """LMOSolver that records per-call wall clock and auction counters."""

    def __init__(self, backend: str):
        super().__init__(backend)
        self.times: list[float] = []
        self.rebids: list[int] = []
        self.grads: list[np.ndarray] = []
        self.keep_grads = False

    def __call__(self, grad):
        if self.keep_grads and not self.grads:  # only the cold-start gradient
            self.grads.append(np.array(grad, copy=True))
        t0 = time.perf_counter()
        out = super().__call__(grad)
        self.times.append(time.perf_counter() - t0)
        if self.state is not None:
            self.rebids.append(int(self.state.n_rebid_rows))
        return out


def _steady(times: list[float]):
    """Steady-state median, EXCLUDING the first call (compile/cold)."""
    return float(np.median(times[1:])) if len(times) > 1 else None


def _bench_combo(n: int, budget: int, results: dict, smoke: bool) -> None:
    rng = np.random.default_rng(n + budget)
    K = n
    Pi = rng.dirichlet(np.ones(K) * 0.1, size=n)

    combo: dict = {"n": n, "budget": budget, "K": K, "lam": LAM}
    combo["e2e_s"] = {}
    combo["lmo_cold_s"] = {}
    combo["lmo_steady_median_s"] = {}

    traces = {}
    lmos = {}
    for backend in BACKENDS:
        lmo = _RecordingLMO(backend)
        lmo.keep_grads = backend == "scipy" and n <= HUNGARIAN_LMO_MAX_N
        t0 = time.perf_counter()
        res = learn_topology(Pi, budget=budget, lam=LAM, lmo=lmo)
        combo["e2e_s"][backend] = time.perf_counter() - t0
        combo["lmo_cold_s"][backend] = lmo.times[0]
        combo["lmo_steady_median_s"][backend] = _steady(lmo.times)
        traces[backend] = res.objective_trace
        lmos[backend] = lmo

    combo["trace_maxdiff_auction_vs_scipy"] = float(
        np.abs(traces["scipy"] - traces["auction"]).max()
    )
    combo["trace_maxdiff_auction_jit_vs_scipy"] = float(
        np.abs(traces["scipy"] - traces["auction_jit"]).max()
    )
    # trajectory-equivalence assertion (not just a recorded number): a
    # backend whose FW trajectory drifts from the scipy reference fails
    # the bench -- and therefore CI's smoke tier -- loudly
    for backend in ("auction", "auction_jit"):
        drift = combo[f"trace_maxdiff_{backend}_vs_scipy"]
        assert drift <= 1e-9, (
            f"LMO trajectory drift: {backend} diverged from scipy by "
            f"{drift:.3e} at n={n}, budget={budget}"
        )
    combo["auction_rebid_rows_avg"] = (
        float(np.mean(lmos["auction"].rebids[1:])) if budget > 1 else None
    )
    # FW overhead shared by every backend (gradient assembly, line search,
    # state updates): end-to-end minus the time spent inside the LMO.
    fw_overhead = combo["e2e_s"]["scipy"] - float(np.sum(lmos["scipy"].times))
    combo["fw_overhead_s"] = fw_overhead

    # --- the dependency-free hungarian reference ---------------------------
    t_auction = combo["e2e_s"]["auction"]
    if n <= HUNGARIAN_LMO_MAX_N and lmos["scipy"].grads:
        t0 = time.perf_counter()
        hungarian(lmos["scipy"].grads[0])
        t_h_cold = time.perf_counter() - t0
        combo["lmo_cold_s"]["hungarian"] = t_h_cold
        combo["e2e_hungarian_est_s"] = t_h_cold * budget + fw_overhead
        combo["speedup_e2e_auction_vs_hungarian_est"] = (
            combo["e2e_hungarian_est_s"] / t_auction
        )
    if n <= HUNGARIAN_E2E_MAX_N and (budget <= 16 or smoke):
        t0 = time.perf_counter()
        res_h = learn_topology(Pi, budget=budget, lam=LAM, lmo="hungarian")
        t_h = time.perf_counter() - t0
        combo["e2e_s"]["hungarian"] = t_h
        combo["trace_maxdiff_hungarian_vs_scipy"] = float(
            np.abs(traces["scipy"] - res_h.objective_trace).max()
        )
        combo["speedup_e2e_auction_vs_hungarian"] = t_h / t_auction

    # --- headline ratios (steady state = the warm re-solve regime) --------
    sm = combo["lmo_steady_median_s"]
    if sm["auction_jit"] and sm["auction"]:
        combo["speedup_steady_auction_jit_vs_auction"] = (
            sm["auction"] / sm["auction_jit"]
        )
    if sm["auction_jit"] and sm["scipy"]:
        combo["speedup_steady_auction_jit_vs_scipy"] = (
            sm["scipy"] / sm["auction_jit"]
        )
    combo["speedup_e2e_auction_vs_scipy"] = combo["e2e_s"]["scipy"] / t_auction
    combo["speedup_e2e_auction_jit_vs_scipy"] = (
        combo["e2e_s"]["scipy"] / combo["e2e_s"]["auction_jit"]
    )
    combo["auto_resolves_to"] = resolve_lmo_backend("auto", n=n, budget=budget)

    key = f"n{n}_b{budget}"
    results[key] = combo
    for backend in BACKENDS:
        steady = sm[backend]
        emit(
            f"stl_fw_e2e_{backend}_{key}", combo["e2e_s"][backend] * 1e6,
            f"cold={1e3 * combo['lmo_cold_s'][backend]:.1f}ms_"
            f"steady={1e3 * steady:.1f}ms" if steady else "single_call",
        )
    if "speedup_steady_auction_jit_vs_auction" in combo:
        emit(
            f"stl_fw_jit_vs_numpy_auction_{key}",
            sm["auction_jit"] * 1e6,
            f"{combo['speedup_steady_auction_jit_vs_auction']:.2f}x_steady_"
            f"tracediff={combo['trace_maxdiff_auction_jit_vs_scipy']:.1e}",
        )
    if "speedup_e2e_auction_vs_hungarian" in combo:
        emit(
            f"stl_fw_e2e_hungarian_{key}", combo["e2e_s"]["hungarian"] * 1e6,
            f"auction_{combo['speedup_e2e_auction_vs_hungarian']:.0f}x_faster",
        )
    elif "speedup_e2e_auction_vs_hungarian_est" in combo:
        emit(
            f"stl_fw_e2e_hungarian_est_{key}", combo["e2e_hungarian_est_s"] * 1e6,
            f"auction_{combo['speedup_e2e_auction_vs_hungarian_est']:.0f}x_faster_est",
        )


def _assert_backend_agreement(results: dict) -> None:
    """Rot detector: every LMO backend must reach the same ``<P, G>``
    objective on a fixed-seed gradient. Catches silent backend drift
    (e.g. a quantization change that desyncs the compiled engine from
    the numpy solvers). Raises on mismatch so CI fails loudly."""
    rng = np.random.default_rng(1234)
    grad = rng.normal(size=(24, 24))
    objs = {}
    for backend in ("scipy", "hungarian", "auction", "auction_jit"):
        P, _ = solve_lmo(grad, backend=backend)
        objs[backend] = float((P * grad).sum())
    ref = objs["scipy"]
    scale = max(1.0, abs(ref))
    for backend, obj in objs.items():
        assert abs(obj - ref) <= 1e-9 * scale, (
            f"LMO backend drift: {backend} objective {obj!r} != scipy {ref!r}"
        )
    results["backend_agreement"] = {"objectives": objs, "max_rel_diff": max(
        abs(o - ref) / scale for o in objs.values()
    )}
    emit("stl_fw_backend_agreement", 0.0,
         f"4_backends_objdiff={results['backend_agreement']['max_rel_diff']:.1e}")


def main(smoke: bool = False) -> None:
    results: dict = {}
    _assert_backend_agreement(results)
    sweep = [(32, 8)] if smoke else [
        (n, b) for n in (128, 512, 1024) for b in (16, 64)
    ]
    if resolve_lmo_backend("scipy") != "scipy":
        # Without scipy the "scipy" arm resolves to the pure-python
        # hungarian (~6 s per LMO call at n=512): the full sweep would
        # grind for hours and the reference labels would lie. Shrink to
        # the one combination where hungarian is practical.
        emit("bench_stl_fw_no_scipy", 0.0, "reference=hungarian;sweep=n128_b16")
        sweep = [(32, 8)] if smoke else [(128, 16)]
        results["reference_backend"] = "hungarian"
    for n, budget in sweep:
        _bench_combo(n, budget, results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_stl_fw.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_stl_fw_json", 0.0, path)


if __name__ == "__main__":
    main()

"""Figure 2 (paper Sec. 6.2): label-skew classification, topology comparison.

Offline substitution (DESIGN.md): MNIST -> 10-class Gaussian-blob synthetic
set with shared P(X|Y) (pure label skew), linear classifier, McMahan shard
partition over n=100 nodes. Topologies: fully-connected (upper bound),
random d-regular, exponential graph, D-Cliques, STL-FW -- same budgets as
the paper (d_max = 2, 5, 10).
"""

import time

import numpy as np

from .common import emit, save_rows
from repro.core import topology as T
from repro.core.dcliques import d_cliques
from repro.core.stl_fw import learn_topology
from repro.data.partition import shard_partition
from repro.data.synthetic import gaussian_blobs
from repro.train.trainer import run_classification


def main(smoke: bool = False) -> None:
    t0 = time.perf_counter()
    n, n_samples, n_train, steps = (
        (20, 2400, 2000, 10) if smoke else (100, 12000, 10000, 150)
    )
    X, y = gaussian_blobs(n_samples=n_samples, num_classes=10, dim=48, sep=2.5, seed=0)
    X_train, y_train = X[:n_train], y[:n_train]
    X_test, y_test = X[n_train:], y[n_train:]
    idx, Pi = shard_partition(y_train, n, shards_per_node=2, seed=0)

    lr = 0.3
    topologies: dict[str, np.ndarray] = {
        "fully-connected": T.complete(n),
        "exponential(d14)": T.exponential_graph(n),
        "d-cliques": d_cliques(Pi, clique_size=10, seed=0),
    }
    for budget in (2, 5, 10):
        topologies[f"random(d{budget})"] = T.random_d_regular(n, budget, seed=0)
        topologies[f"stl-fw(d{budget})"] = learn_topology(Pi, budget=budget, lam=0.1).W

    rows = []
    accs = {}
    for name, W in topologies.items():
        log = run_classification(
            X_train, y_train, idx, W, model="linear", steps=steps,
            batch_size=64, lr=lr, eval_every=steps - 1,
            X_test=X_test, y_test=y_test, seed=0,
        )
        final = [r for r in log.history if "acc_mean" in r][-1]
        rows.append([name, final["acc_mean"], final["acc_min"], final["acc_max"],
                     final["consensus"]])
        accs[name] = final["acc_mean"]
        print(f"# fig2 {name:18s} acc={final['acc_mean']:.4f} "
              f"[{final['acc_min']:.4f},{final['acc_max']:.4f}]")
    save_rows("fig2.csv", ["topology", "acc_mean", "acc_min", "acc_max", "consensus"], rows)
    us = (time.perf_counter() - t0) * 1e6 / len(topologies)
    emit(
        "fig2_classification_topologies", us,
        f"stlfw_d10={accs['stl-fw(d10)']:.4f};dcliques={accs['d-cliques']:.4f};"
        f"random_d10={accs['random(d10)']:.4f};full={accs['fully-connected']:.4f}",
    )

    # non-convex counterpart (paper's CIFAR10 / GN-LeNet analogue): same
    # protocol with an MLP; validates the Theorem 1 non-convex regime's
    # qualitative topology ranking.
    t1 = time.perf_counter()
    mlp_rows = []
    mlp_accs = {}
    for name in ("fully-connected", "random(d5)", "stl-fw(d5)"):
        log = run_classification(
            X_train, y_train, idx, topologies[name], model="mlp", hidden=64,
            steps=steps, batch_size=64, lr=0.2, eval_every=steps - 1,
            X_test=X_test, y_test=y_test, seed=0,
        )
        final = [r for r in log.history if "acc_mean" in r][-1]
        mlp_rows.append([name, final["acc_mean"], final["acc_min"], final["acc_max"]])
        mlp_accs[name] = final["acc_mean"]
        print(f"# fig2-mlp {name:18s} acc={final['acc_mean']:.4f}")
    save_rows("fig2_mlp.csv", ["topology", "acc_mean", "acc_min", "acc_max"], mlp_rows)
    us2 = (time.perf_counter() - t1) * 1e6 / len(mlp_rows)
    emit(
        "fig2_nonconvex_mlp", us2,
        f"stlfw_d5={mlp_accs['stl-fw(d5)']:.4f};random_d5={mlp_accs['random(d5)']:.4f};"
        f"full={mlp_accs['fully-connected']:.4f}",
    )


if __name__ == "__main__":
    main()

"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) vs jnp oracle.

NOTE: interpret mode executes the kernel body in Python -- wall-clock here
measures the CPU stand-in, not TPU performance; correctness deltas and the
XLA-path timings are the meaningful numbers. TPU timing comes from the
roofline analysis (launch/roofline.py).
"""

import time

import jax.numpy as jnp
import numpy as np

from .common import emit, timeit
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.gossip_mix import gossip_mix, gossip_mix_ref


def main(smoke: bool = False) -> None:
    rng = np.random.default_rng(0)
    n_nodes, flat_p = (8, 1 << 15) if smoke else (16, 1 << 21)
    seq = 128 if smoke else 512

    # gossip mix: n nodes, flat params per node
    theta = jnp.asarray(rng.normal(size=(n_nodes, flat_p)), jnp.float32)
    W = np.abs(rng.normal(size=(n_nodes, n_nodes)))
    W = jnp.asarray(W / W.sum(1, keepdims=True), jnp.float32)
    ref_us = timeit(lambda: gossip_mix_ref(theta, W).block_until_ready())
    ker_us = timeit(lambda: gossip_mix(theta, W).block_until_ready())
    err = float(jnp.max(jnp.abs(gossip_mix(theta, W) - gossip_mix_ref(theta, W))))
    size_tag = f"{n_nodes}x{flat_p}"
    emit(f"gossip_mix_{size_tag}_ref_xla", ref_us, f"maxerr={err:.1e}")
    emit(f"gossip_mix_{size_tag}_pallas_interpret", ker_us, "interpret-mode")

    # flash attention: S=seq, H=8/4, D=128
    q = jnp.asarray(rng.normal(size=(1, seq, 8, 128)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, seq, 4, 128)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, seq, 4, 128)), jnp.float32)
    ref_us = timeit(lambda: flash_attention_ref(q, k, v).block_until_ready())
    ker_us = timeit(
        lambda: flash_attention(q, k, v).block_until_ready(), iters=1, warmup=1
    )
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v) - flash_attention_ref(q, k, v))))
    emit(f"flash_attention_{seq}_ref_xla", ref_us, f"maxerr={err:.1e}")
    emit(f"flash_attention_{seq}_pallas_interpret", ker_us, "interpret-mode")


if __name__ == "__main__":
    main()

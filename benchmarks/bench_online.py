"""Online topology adaptation benchmarks: the subsystem's three headline
claims, measured (and asserted) rather than asserted in prose.

1. **Warm refresh latency** -- at n=512/budget=64 (the ISSUE 4 acceptance
   point), a warm ``TopologyRefresher.refresh`` (previous Birkhoff atoms
   + persistent LMO duals + 1/4-budget cap + duality-gap stop) versus a
   cold ``learn_topology`` at full budget, under repeated abrupt
   node-permutation drifts. Steady-state MEDIANS over the drift rounds;
   the non-smoke run asserts the >= 3x acceptance bar and records the
   refreshed-vs-cold objective honestly (the warm solve's extra atom
   capacity usually makes it slightly BETTER, not worse).
   Measured on this 2-vCPU container: ~3.9x (cold ~3.2 s, warm
   ~0.84 s; the warm solve always hits its 16-iteration cap because a
   full node permutation relocates the optimum -- milder drifts stop
   earlier on the gap certificate).

2. **Post-drift convergence recovery** -- the abrupt label-swap scenario
   on the Section 6.1 mean-estimation task: frozen-W vs oracle-W
   (cold-solved on the true post-drift Pi, swapped exactly at the drift
   step) vs the full online pipeline (streaming Pi_hat -> drift detector
   -> warm refresh -> hot swap), all three on the SAME precomputed
   observation stream at equal iteration count. Recovery of the
   frozen->oracle error gap is reported in log space (strict: compares
   convergence floors) and linear space; the non-smoke run asserts
   log-recovery >= 0.8 (acceptance criterion a).

3. **Zero retraces** -- every online run asserts
   ``result["n_traces"] == 1``: the scanned rollout is compiled once
   and schedule hot-swaps reach it as data. This assertion runs in
   --smoke too, so CI catches any regression that turns a swap back
   into a retrace (acceptance criterion c).

Writes experiments/bench/BENCH_online.json.
"""

import json
import os
import time

import numpy as np

from .common import emit, result_dir
from repro.core.mixing import schedule_from_result, schedule_to_arrays
from repro.core.stl_fw import learn_topology
from repro.data.drift import AbruptLabelSwap, labels_stream
from repro.data.synthetic import mean_estimation_clusters
from repro.online import (
    OnlineTopologyController,
    RefreshConfig,
    StreamingPiEstimator,
    TopologyRefresher,
)
from repro.train.trainer import run_mean_estimation

LAM = 0.1


def _bench_refresh_speed(results: dict, smoke: bool) -> None:
    """Warm refresh vs cold solve under repeated abrupt drifts."""
    n, K, budget = (32, 8, 8) if smoke else (512, 64, 64)
    refresh_budget = max(4, budget // 4)
    rounds = 3 if smoke else 5
    rng = np.random.default_rng(0)
    Pi0 = rng.dirichlet(0.1 * np.ones(K), size=n)

    t0 = time.perf_counter()
    res0 = learn_topology(Pi0, budget=budget, lam=LAM)
    t_initial = time.perf_counter() - t0
    ref = TopologyRefresher(res0, RefreshConfig(budget=refresh_budget, lam=LAM))

    colds, warms, warm_iters, obj_pairs = [], [], [], []
    Pi_t = Pi0
    for _ in range(rounds):
        Pi_t = Pi_t[rng.permutation(n)]  # abrupt node-permutation drift
        t0 = time.perf_counter()
        cold = learn_topology(Pi_t, budget=budget, lam=LAM)
        colds.append(time.perf_counter() - t0)
        warm = ref.refresh(Pi_t)
        warms.append(ref.last_refresh_s)
        warm_iters.append(ref.last_iters)
        obj_pairs.append(
            (float(cold.objective_trace[-1]), float(warm.objective_trace[-1]))
        )

    cold_med, warm_med = float(np.median(colds)), float(np.median(warms))
    speedup = cold_med / warm_med
    results["refresh_speed"] = {
        "n": n, "K": K, "budget": budget, "refresh_budget": refresh_budget,
        "lam": LAM, "rounds": rounds,
        "initial_cold_s": t_initial,
        "gap_ref": ref.gap_ref,
        "cold_s": colds, "warm_s": warms,
        "cold_median_s": cold_med, "warm_median_s": warm_med,
        "speedup_warm_vs_cold": speedup,
        "warm_iters": warm_iters,
        "l_max": ref.l_max,
        "objective_cold_vs_warm": obj_pairs,
        # honesty note: warm objectives benefit from l_max > budget+1 atom
        # capacity; the comparison point is "topology you actually deploy"
        "warm_objective_worse_than_cold": max(
            w - c for c, w in obj_pairs
        ),
    }
    emit(
        f"online_refresh_n{n}_b{budget}", warm_med * 1e6,
        f"{speedup:.2f}x_vs_cold_{cold_med * 1e3:.0f}ms_iters={warm_iters}",
    )
    if not smoke:
        assert speedup >= 3.0, (
            f"acceptance (b) failed: warm refresh only {speedup:.2f}x faster "
            f"than cold at n={n}/budget={budget}"
        )


def _bench_recovery_and_retrace(results: dict, smoke: bool) -> None:
    """Abrupt label-swap: frozen vs oracle vs online-refreshed D-SGD."""
    if smoke:
        n, K, steps, seg, t_drift, budget = 12, 4, 120, 10, 40, 4
    else:
        n, K, steps, seg, t_drift, budget = 64, 8, 600, 20, 200, 8
    lam, lr, batch, beta = 0.5, 0.05, 4, 0.2
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    Pi0 = np.eye(K)[np.arange(n) % K].astype(float)
    # seeded random node permutation (the half-rotation default is a
    # symmetry of cyclic one-hot Pi -- see AbruptLabelSwap docstring)
    perm = np.random.default_rng(11).permutation(n)
    scenario = AbruptLabelSwap(Pi0, t_drift=t_drift, node_perm=perm)
    labels = labels_stream(scenario, steps, batch, seed=0)
    means = np.asarray(task.cluster_means)
    zs = means[labels] + np.sqrt(task.sigma_tilde2) * np.random.default_rng(
        1
    ).normal(size=labels.shape)

    res0 = learn_topology(Pi0, budget=budget, lam=lam)
    oracle_res = learn_topology(scenario.Pi(t_drift), budget=budget, lam=lam)
    ref = TopologyRefresher(res0, RefreshConfig(budget=budget, lam=lam))
    sa0 = schedule_to_arrays(schedule_from_result(res0), ref.l_max)
    sa_oracle = schedule_to_arrays(schedule_from_result(oracle_res), ref.l_max)

    def run(hook):
        return run_mean_estimation(
            task, None, steps=steps, lr=lr, batch=batch, seed=2,
            schedule=sa0, zs=zs, on_segment=hook, segment_len=seg,
        )

    out_frozen = run(None)

    # first segment boundary at/after the drift step (robust to seg
    # values that don't divide t_drift -- an exact-match hook would
    # silently never swap and the oracle arm would measure frozen-W)
    oracle_done = {"swapped": False}

    def oracle_hook(t):
        if not oracle_done["swapped"] and t >= t_drift - 1:
            oracle_done["swapped"] = True
            return sa_oracle
        return None

    out_oracle = run(oracle_hook)
    assert oracle_done["swapped"], "oracle arm never swapped -- check seg/t_drift"

    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(n, K, beta=beta, init=Pi0)
    )
    fed = {"t": 0}

    def online_hook(t):
        while fed["t"] <= t:
            ctl.observe(labels[fed["t"]])
            fed["t"] += 1
        return ctl.on_segment(t)

    out_online = run(online_hook)

    # acceptance (c): swaps reached the compiled rollout as data -- the
    # scan traced exactly once per run, drift or no drift. Asserted in
    # smoke too: this is the CI jit-cache-miss detector.
    for name, out in (("frozen", out_frozen), ("oracle", out_oracle),
                      ("online", out_online)):
        assert out["n_traces"] == 1, (
            f"hot-swap retraced the rollout in the {name} run: "
            f"n_traces={out['n_traces']}"
        )
    assert ref.n_refreshes >= 1, "drift never detected -- no swap exercised"
    assert out_online["swaps"], "refresh fired but no schedule swap landed"

    tail = slice(-max(10, steps // 12), None)
    e_frozen = float(np.median(out_frozen["mean_sq_error"][tail]))
    e_oracle = float(np.median(out_oracle["mean_sq_error"][tail]))
    e_online = float(np.median(out_online["mean_sq_error"][tail]))
    log_rec = (np.log(e_frozen) - np.log(e_online)) / (
        np.log(e_frozen) - np.log(e_oracle)
    )
    lin_rec = (e_frozen - e_online) / (e_frozen - e_oracle)
    results["recovery"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg,
        "t_drift": t_drift, "budget": budget, "lam": lam, "lr": lr,
        "batch": batch, "estimator_beta": beta,
        "err_frozen": e_frozen, "err_oracle": e_oracle, "err_online": e_online,
        "recovery_log": float(log_rec), "recovery_linear": float(lin_rec),
        "n_refreshes": ref.n_refreshes,
        "swap_steps": out_online["swaps"],
        "detector_events": ctl.events[-6:],
        "n_traces": {"frozen": out_frozen["n_traces"],
                     "oracle": out_oracle["n_traces"],
                     "online": out_online["n_traces"]},
    }
    emit(
        f"online_recovery_n{n}", 0.0,
        f"log={log_rec:.3f}_lin={lin_rec:.3f}_refreshes={ref.n_refreshes}"
        f"_retraces=0",
    )
    if not smoke:
        assert log_rec >= 0.8, (
            f"acceptance (a) failed: online refresh recovered only "
            f"{log_rec:.3f} of the frozen->oracle gap (log space)"
        )


def main(smoke: bool = False) -> None:
    results: dict = {"smoke": smoke}
    _bench_refresh_speed(results, smoke)
    _bench_recovery_and_retrace(results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_online.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_online_json", 0.0, path)


if __name__ == "__main__":
    main()

"""Online topology adaptation benchmarks: the subsystem's three headline
claims, measured (and asserted) rather than asserted in prose.

1. **Warm refresh latency** -- at n=512/budget=64 (the ISSUE 4 acceptance
   point), a warm ``TopologyRefresher.refresh`` (previous Birkhoff atoms
   + persistent LMO duals + 1/4-budget cap + duality-gap stop) versus a
   cold ``learn_topology`` at full budget, under repeated abrupt
   node-permutation drifts. Steady-state MEDIANS over the drift rounds;
   the non-smoke run asserts the >= 3x acceptance bar and records the
   refreshed-vs-cold objective honestly (the warm solve's extra atom
   capacity usually makes it slightly BETTER, not worse).
   Measured on this 2-vCPU container: ~3.9x (cold ~3.2 s, warm
   ~0.84 s; the warm solve always hits its 16-iteration cap because a
   full node permutation relocates the optimum -- milder drifts stop
   earlier on the gap certificate).

2. **Post-drift convergence recovery** -- the abrupt label-swap scenario
   on the Section 6.1 mean-estimation task: frozen-W vs oracle-W
   (cold-solved on the true post-drift Pi, swapped exactly at the drift
   step) vs the full online pipeline (streaming Pi_hat -> drift detector
   -> warm refresh -> hot swap), all three on the SAME precomputed
   observation stream at equal iteration count. Recovery of the
   frozen->oracle error gap is reported in log space (strict: compares
   convergence floors) and linear space; the non-smoke run asserts
   log-recovery >= 0.8 (acceptance criterion a).

3. **Zero retraces** -- every online run asserts
   ``result["n_traces"] == 1``: the scanned rollout is compiled once
   and schedule hot-swaps reach it as data. This assertion runs in
   --smoke too, so CI catches any regression that turns a swap back
   into a retrace (acceptance criterion c).

ISSUE 5 adds two more measured claims:

4. **Staged-pool sharded mixing** (subprocess, forced host devices) --
   the pre-staged ppermute atom pool vs the all-gather on the online
   MESH trainer: bytes/step from the comm counter (the pool must move
   <= (d_max+1)/n of the all-gather's bytes -- asserted), median
   segment wall time for both transports, zero retraces across >= 3
   consecutive in-pool gamma swaps (asserted, smoke included), and the
   pool-miss fallback costing exactly ONE counted recompile (asserted).
   Also runs the sharded-transport autotuner once on the forced-device
   mesh, memoizing the ``sh_`` bucket into the autotune table.

5. **Overlapped refresh** -- the background-thread refresh on the
   n=512/budget=64 simulator rollout: wall clock of frozen vs
   synchronous-refresh vs overlapped-refresh runs on identical data,
   hidden-latency fraction = (wall_sync - wall_async) / solve_total.
   Asserts (smoke included) that every in-run refresh was collected
   with ``blocked_s == 0`` (the hook never waits on the solver) and
   that segment-time jitter while a solve is in flight stays bounded
   (no rollout serialization behind the solve). The >= 50% hidden
   target is recorded honestly (``target_met``) rather than asserted:
   on a 2-vCPU container the solver and the rollout share cores, and
   the floor is explained in the JSON when missed.

ISSUE 7 adds the compressed-gossip claims:

6. **Bytes-vs-convergence frontier** -- the W-budget x wire-format grid
   under ``data/drift.py`` scenarios. Mean estimation (abrupt label
   swap, full online pipeline) sweeps budgets x {uncompressed,
   identity, bf16}: identity must be BITWISE equal to the uncompressed
   run (the trace-time routing rot detector), bf16 must move exactly
   half the bytes (CommMeter-verified) and, non-smoke, still recover
   >= 0.8 of the frozen->oracle gap. Label-skew classification (vector
   payloads, where top-k is meaningful) sweeps {uncompressed, bf16,
   topk:0.25, topk:0.1} with a mid-run schedule hot-swap, asserting
   zero retraces per wire and the metered bytes against each wire's
   closed-form ratio. The sharded-pool bench (4) additionally runs the
   compressed pool transport in-subprocess: identity bitwise vs the
   uncompressed pool across in-pool swaps, bf16 pool <= 0.55x the
   uncompressed pool's bytes/step, zero retraces in every compressed
   run -- all asserted in --smoke too.

Writes experiments/bench/BENCH_online.json.
"""

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from .common import emit, result_dir
from repro.core.mixing import schedule_from_result, schedule_to_arrays
from repro.core.stl_fw import learn_topology
from repro.core.compression import make_compressor
from repro.data.drift import AbruptLabelSwap, labels_stream, partition_from_pi
from repro.data.synthetic import gaussian_blobs, mean_estimation_clusters
from repro.online import (
    DriftDetector,
    OnlineTopologyController,
    RefreshConfig,
    StreamingPiEstimator,
    TopologyRefresher,
)
from repro.train.trainer import run_classification, run_mean_estimation

LAM = 0.1


def _bench_refresh_speed(results: dict, smoke: bool) -> None:
    """Warm refresh vs cold solve under repeated abrupt drifts."""
    n, K, budget = (32, 8, 8) if smoke else (512, 64, 64)
    refresh_budget = max(4, budget // 4)
    rounds = 3 if smoke else 5
    rng = np.random.default_rng(0)
    Pi0 = rng.dirichlet(0.1 * np.ones(K), size=n)

    t0 = time.perf_counter()
    res0 = learn_topology(Pi0, budget=budget, lam=LAM)
    t_initial = time.perf_counter() - t0
    ref = TopologyRefresher(res0, RefreshConfig(budget=refresh_budget, lam=LAM))

    colds, warms, warm_iters, obj_pairs = [], [], [], []
    Pi_t = Pi0
    for _ in range(rounds):
        Pi_t = Pi_t[rng.permutation(n)]  # abrupt node-permutation drift
        t0 = time.perf_counter()
        cold = learn_topology(Pi_t, budget=budget, lam=LAM)
        colds.append(time.perf_counter() - t0)
        warm = ref.refresh(Pi_t)
        warms.append(ref.last_refresh_s)
        warm_iters.append(ref.last_iters)
        obj_pairs.append(
            (float(cold.objective_trace[-1]), float(warm.objective_trace[-1]))
        )

    cold_med, warm_med = float(np.median(colds)), float(np.median(warms))
    speedup = cold_med / warm_med
    results["refresh_speed"] = {
        "n": n, "K": K, "budget": budget, "refresh_budget": refresh_budget,
        "lam": LAM, "rounds": rounds,
        "initial_cold_s": t_initial,
        "gap_ref": ref.gap_ref,
        "cold_s": colds, "warm_s": warms,
        "cold_median_s": cold_med, "warm_median_s": warm_med,
        "speedup_warm_vs_cold": speedup,
        "warm_iters": warm_iters,
        "l_max": ref.l_max,
        "objective_cold_vs_warm": obj_pairs,
        # honesty note: warm objectives benefit from l_max > budget+1 atom
        # capacity; the comparison point is "topology you actually deploy"
        "warm_objective_worse_than_cold": max(
            w - c for c, w in obj_pairs
        ),
    }
    emit(
        f"online_refresh_n{n}_b{budget}", warm_med * 1e6,
        f"{speedup:.2f}x_vs_cold_{cold_med * 1e3:.0f}ms_iters={warm_iters}",
    )
    if not smoke:
        assert speedup >= 3.0, (
            f"acceptance (b) failed: warm refresh only {speedup:.2f}x faster "
            f"than cold at n={n}/budget={budget}"
        )


def _bench_recovery_and_retrace(results: dict, smoke: bool) -> None:
    """Abrupt label-swap: frozen vs oracle vs online-refreshed D-SGD."""
    if smoke:
        n, K, steps, seg, t_drift, budget = 12, 4, 120, 10, 40, 4
    else:
        n, K, steps, seg, t_drift, budget = 64, 8, 600, 20, 200, 8
    lam, lr, batch, beta = 0.5, 0.05, 4, 0.2
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    Pi0 = np.eye(K)[np.arange(n) % K].astype(float)
    # seeded random node permutation (the half-rotation default is a
    # symmetry of cyclic one-hot Pi -- see AbruptLabelSwap docstring)
    perm = np.random.default_rng(11).permutation(n)
    scenario = AbruptLabelSwap(Pi0, t_drift=t_drift, node_perm=perm)
    labels = labels_stream(scenario, steps, batch, seed=0)
    means = np.asarray(task.cluster_means)
    zs = means[labels] + np.sqrt(task.sigma_tilde2) * np.random.default_rng(
        1
    ).normal(size=labels.shape)

    res0 = learn_topology(Pi0, budget=budget, lam=lam)
    oracle_res = learn_topology(scenario.Pi(t_drift), budget=budget, lam=lam)
    ref = TopologyRefresher(res0, RefreshConfig(budget=budget, lam=lam))
    sa0 = schedule_to_arrays(schedule_from_result(res0), ref.l_max)
    sa_oracle = schedule_to_arrays(schedule_from_result(oracle_res), ref.l_max)

    def run(hook):
        return run_mean_estimation(
            task, None, steps=steps, lr=lr, batch=batch, seed=2,
            schedule=sa0, zs=zs, on_segment=hook, segment_len=seg,
        )

    out_frozen = run(None)

    # first segment boundary at/after the drift step (robust to seg
    # values that don't divide t_drift -- an exact-match hook would
    # silently never swap and the oracle arm would measure frozen-W)
    oracle_done = {"swapped": False}

    def oracle_hook(t):
        if not oracle_done["swapped"] and t >= t_drift - 1:
            oracle_done["swapped"] = True
            return sa_oracle
        return None

    out_oracle = run(oracle_hook)
    assert oracle_done["swapped"], "oracle arm never swapped -- check seg/t_drift"

    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(n, K, beta=beta, init=Pi0)
    )
    fed = {"t": 0}

    def online_hook(t):
        while fed["t"] <= t:
            ctl.observe(labels[fed["t"]])
            fed["t"] += 1
        return ctl.on_segment(t)

    out_online = run(online_hook)

    # acceptance (c): swaps reached the compiled rollout as data -- the
    # scan traced exactly once per run, drift or no drift. Asserted in
    # smoke too: this is the CI jit-cache-miss detector.
    for name, out in (("frozen", out_frozen), ("oracle", out_oracle),
                      ("online", out_online)):
        assert out["n_traces"] == 1, (
            f"hot-swap retraced the rollout in the {name} run: "
            f"n_traces={out['n_traces']}"
        )
    assert ref.n_refreshes >= 1, "drift never detected -- no swap exercised"
    assert out_online["swaps"], "refresh fired but no schedule swap landed"

    tail = slice(-max(10, steps // 12), None)
    e_frozen = float(np.median(out_frozen["mean_sq_error"][tail]))
    e_oracle = float(np.median(out_oracle["mean_sq_error"][tail]))
    e_online = float(np.median(out_online["mean_sq_error"][tail]))
    log_rec = (np.log(e_frozen) - np.log(e_online)) / (
        np.log(e_frozen) - np.log(e_oracle)
    )
    lin_rec = (e_frozen - e_online) / (e_frozen - e_oracle)
    results["recovery"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg,
        "t_drift": t_drift, "budget": budget, "lam": lam, "lr": lr,
        "batch": batch, "estimator_beta": beta,
        "err_frozen": e_frozen, "err_oracle": e_oracle, "err_online": e_online,
        "recovery_log": float(log_rec), "recovery_linear": float(lin_rec),
        "n_refreshes": ref.n_refreshes,
        "swap_steps": out_online["swaps"],
        "detector_events": ctl.events[-6:],
        "n_traces": {"frozen": out_frozen["n_traces"],
                     "oracle": out_oracle["n_traces"],
                     "online": out_online["n_traces"]},
    }
    emit(
        f"online_recovery_n{n}", 0.0,
        f"log={log_rec:.3f}_lin={lin_rec:.3f}_refreshes={ref.n_refreshes}"
        f"_retraces=0",
    )
    if not smoke:
        assert log_rec >= 0.8, (
            f"acceptance (a) failed: online refresh recovered only "
            f"{log_rec:.3f} of the frozen->oracle gap (log space)"
        )


def _bench_frontier(results: dict, smoke: bool) -> None:
    """Bytes-vs-convergence frontier: W budget x wire format under drift.

    Two sweeps, one artifact. (a) Mean estimation under the abrupt
    label swap with the FULL online pipeline (estimator -> detector ->
    warm refresh -> hot swap) per arm: budgets x {none, identity,
    bf16}. The task's payload is scalar (P=1 per node), so top-k is
    degenerate there -- a k=1-of-1 wire would CHARGE 8 bytes against
    f32's 4, which the meter would report honestly but the frontier
    would learn nothing from. (b) Label-skew classification (linear
    model: P = d*C + C per node) where top-k earns its row: wires
    {none, bf16, topk:0.25:g0.25, topk:0.1:g0.25} with a mid-run hot
    swap to the post-drift topology (top-k rides CHOCO's damped
    consensus step -- see the gamma note at the wire loop). Every run
    asserts n_traces == 1 (smoke too).
    """
    if smoke:
        n, K, steps, seg, t_drift = 12, 4, 120, 10, 40
        budgets = (4,)
    else:
        n, K, steps, seg, t_drift = 32, 8, 400, 20, 120
        budgets = (4, 8)
    lam, lr, batch, beta = 0.5, 0.05, 4, 0.2
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    Pi0 = np.eye(K)[np.arange(n) % K].astype(float)
    perm = np.random.default_rng(11).permutation(n)
    scenario = AbruptLabelSwap(Pi0, t_drift=t_drift, node_perm=perm)
    labels = labels_stream(scenario, steps, batch, seed=0)
    means = np.asarray(task.cluster_means)
    zs = means[labels] + np.sqrt(task.sigma_tilde2) * np.random.default_rng(
        1
    ).normal(size=labels.shape)
    tail = slice(-max(10, steps // 12), None)

    points = []
    for budget in budgets:
        res0 = learn_topology(Pi0, budget=budget, lam=lam)
        oracle_res = learn_topology(scenario.Pi(t_drift), budget=budget, lam=lam)
        l_max = TopologyRefresher(
            res0, RefreshConfig(budget=budget, lam=lam)
        ).l_max
        sa0 = schedule_to_arrays(schedule_from_result(res0), l_max)
        sa_oracle = schedule_to_arrays(schedule_from_result(oracle_res), l_max)

        def run(hook, wire):
            return run_mean_estimation(
                task, None, steps=steps, lr=lr, batch=batch, seed=2,
                schedule=sa0, zs=zs, on_segment=hook, segment_len=seg,
                compression=wire,
            )

        out_frozen = run(None, None)
        swapped = {"done": False}

        def oracle_hook(t):
            if not swapped["done"] and t >= t_drift - 1:
                swapped["done"] = True
                return sa_oracle
            return None

        out_oracle = run(oracle_hook, None)
        e_frozen = float(np.median(out_frozen["mean_sq_error"][tail]))
        e_oracle = float(np.median(out_oracle["mean_sq_error"][tail]))

        base_bytes = None
        base_mse = None
        for wire in (None, "identity", "bf16"):
            # fresh pipeline state per arm: the refresher/estimator are
            # stateful, and each arm must solve from the same start
            ref = TopologyRefresher(res0, RefreshConfig(budget=budget, lam=lam))
            # the low-budget arms start from a W that fits Pi0 loosely,
            # so the permutation's relative proxy jump is smaller than
            # the 1.5x default trigger (1.47x at n=32/K=8/budget=4) --
            # the frontier measures bytes vs convergence, not detector
            # calibration, so pin a more sensitive trigger explicitly
            ctl = OnlineTopologyController(
                ref,
                estimator=StreamingPiEstimator(n, K, beta=beta, init=Pi0),
                detector=DriftDetector(threshold=1.3),
            )
            fed = {"t": 0}

            def online_hook(t):
                while fed["t"] <= t:
                    ctl.observe(labels[fed["t"]])
                    fed["t"] += 1
                return ctl.on_segment(t)

            out = run(online_hook, wire)
            assert out["n_traces"] == 1, (wire, out["n_traces"])
            assert out["swaps"], (wire, "no swap landed")
            e = float(np.median(out["mean_sq_error"][tail]))
            rec = (np.log(e_frozen) - np.log(e)) / (
                np.log(e_frozen) - np.log(e_oracle)
            )
            bps = out["comm"]["per_step_bytes"]
            if wire is None:
                base_bytes, base_mse = bps, out["mean_sq_error"]
            elif wire == "identity":
                # trace-time routing rot detector: the identity wire IS
                # the uncompressed transport, bit for bit
                assert bps == base_bytes
                assert np.array_equal(out["mean_sq_error"], base_mse), (
                    "identity wire diverged from the uncompressed run"
                )
            elif wire == "bf16":
                assert bps * 2 == base_bytes, (bps, base_bytes)
                if not smoke:
                    assert rec >= 0.8, (
                        f"bf16 frontier recovery {rec:.3f} < 0.8 at "
                        f"budget={budget}"
                    )
            points.append({
                "task": "mean_estimation", "budget": budget,
                "wire": wire or "none", "bytes_per_step": bps,
                "total_bytes": out["comm"]["total_bytes"],
                "err_tail": e, "err_frozen": e_frozen,
                "err_oracle": e_oracle, "recovery_log": float(rec),
                "n_refreshes": ref.n_refreshes, "swaps": out["swaps"],
            })

    # --- classification sweep: vector payloads make top-k meaningful
    if smoke:
        nc, C, d, steps_c, spn = 8, 4, 16, 60, 64
    else:
        nc, C, d, steps_c, spn = 16, 8, 32, 240, 256
    X, y = gaussian_blobs(
        n_samples=40 * spn, num_classes=C, dim=d, seed=3
    )
    Pi_pre = np.eye(C)[np.arange(nc) % C].astype(float)
    Pi_post = Pi_pre[np.random.default_rng(13).permutation(nc)]
    idx = partition_from_pi(y, Pi_post, samples_per_node=spn, seed=4)
    res_pre = learn_topology(Pi_pre, budget=4, lam=lam)
    res_post = learn_topology(Pi_post, budget=4, lam=lam)
    cap = max(
        schedule_from_result(res_pre).n_atoms,
        schedule_from_result(res_post).n_atoms,
    )
    sa_pre = schedule_to_arrays(schedule_from_result(res_pre), cap)
    sa_post = schedule_to_arrays(schedule_from_result(res_post), cap)
    p_total = d * C + C
    cls_points = []
    base_cls_bytes = None
    eval_every_c = max(10, steps_c // 6)
    # traces == distinct scan segment lengths (the t=0 eval point makes
    # a length-1 prefix segment) -- swaps and compression must add NONE
    from repro.train.trainer import _eval_segments

    expected_traces = len({l for l, _ in _eval_segments(steps_c, eval_every_c, True)})
    # top-k needs CHOCO's consensus step size: at gamma=1 the sparsifier's
    # error feedback through (W - I) has no contraction and the run
    # diverges (measured: loss_tail 7.9e6 at topk:0.25, 1.0e11 at
    # topk:0.1 on this sweep) -- gamma=0.25 converges at both fractions
    for wire in (None, "bf16", "topk:0.25:g0.25", "topk:0.1:g0.25"):
        swapped_c = {"done": False}

        def cls_hook(t):
            if not swapped_c["done"] and t >= steps_c // 3:
                swapped_c["done"] = True
                return sa_post
            return None

        logger = run_classification(
            X, y, idx, None, model="linear", steps=steps_c,
            batch_size=8, lr=0.2, eval_every=eval_every_c,
            seed=5, schedule=sa_pre, on_segment=cls_hook, compression=wire,
        )
        assert logger.aux["n_traces"] == expected_traces, (
            wire, logger.aux["n_traces"], expected_traces
        )
        assert logger.aux["swaps"], (wire, "no swap landed")
        bps = logger.aux["comm"]["per_step_bytes"]
        comp = make_compressor(wire)
        if wire is None:
            base_cls_bytes = bps
            expect_ratio = 1.0
        else:
            wire_elems, wire_item = comp.wire_layout(p_total)
            expect_ratio = (wire_elems * wire_item) / (p_total * 4)
            got_ratio = bps / base_cls_bytes
            assert abs(got_ratio - expect_ratio) < 1e-9, (
                wire, got_ratio, expect_ratio
            )
        loss_tail = float(np.median(logger.column("loss")[-20:]))
        if wire is None:
            base_cls_loss = loss_tail
        elif not smoke:
            # convergence bar: a compressed wire may trade bytes for
            # accuracy but not blow up -- stay within 1.5x of dense
            assert loss_tail <= 1.5 * base_cls_loss, (
                wire, loss_tail, base_cls_loss
            )
        cls_points.append({
            "task": "classification", "wire": wire or "none",
            "p_total": p_total, "bytes_per_step": bps,
            "bytes_ratio": bps / base_cls_bytes,
            "expected_ratio": expect_ratio,
            "loss_tail": loss_tail, "swaps": logger.aux["swaps"],
        })
        assert np.isfinite(loss_tail), wire

    results["frontier"] = {
        "mean_estimation": points,
        "classification": cls_points,
        "note": (
            "mean-estimation payloads are scalar (P=1), where a top-k "
            "value+index wire costs MORE than f32 -- the classification "
            "sweep owns the top-k rows; those ride gamma=0.25 (CHOCO "
            "consensus step size) because undamped top-k EF gossip "
            "diverges on this task"
        ),
    }
    best_bf = max(
        (p for p in points if p["wire"] == "bf16"),
        key=lambda p: p["recovery_log"],
    )
    emit(
        "online_frontier", 0.0,
        f"bf16_recovery={best_bf['recovery_log']:.3f}"
        f"_bytes=0.5x_topk_rows={len(cls_points) - 2}",
    )


_SHARDED_SCRIPT = """
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.compat import AxisType, make_compat_mesh, set_mesh
    from repro.configs import get_smoke_config
    from repro.core import learn_topology
    from repro.core.mixing import (BirkhoffSchedule, PermPool, PoolSwap,
                                   autotune_sharded_transport,
                                   schedule_from_result)
    from repro.online import RefreshConfig, TopologyRefresher
    from repro.train.lm_trainer import make_train_setup

    cfgd = json.loads(%r)
    n, K, steps, seg = cfgd["n"], cfgd["K"], cfgd["steps"], cfgd["seg"]

    rng = np.random.default_rng(0)
    Pi = rng.dirichlet(0.2 * np.ones(K), size=n)
    res0 = learn_topology(Pi, budget=cfgd["budget"], lam=0.1)
    ref = TopologyRefresher(res0, RefreshConfig(budget=2, lam=0.1))
    sched = ref.schedule
    pool = PermPool.from_schedule(sched, capacity=ref.l_max)
    g0, _ = pool.project(sched)
    W = sched.to_matrix()
    d_max = int(max((np.abs(W[i]) > 1e-9).sum() - (W[i, i] > 1e-9)
                    for i in range(n)))

    mesh = make_compat_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
    cfg = get_smoke_config("qwen3-0.6b")
    mk = lambda tr, pl, comp=None: make_train_setup(
        cfg, mesh, mode="dsgd", online_w=True, sharded_transport=tr,
        pool=pl, lr=1e-2, compression=comp)
    s_pool, s_ag = mk("pool", pool), mk("allgather", None)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), s_pool.param_specs,
                      is_leaf=lambda x: isinstance(x, P))
    out = {"n": n, "d_max": d_max, "pool_capacity": pool.capacity,
           "pool_comm_slots": pool.n_comm_slots,
           "pool_bytes_per_step": s_pool.comm_bytes_per_step,
           "allgather_bytes_per_step": s_ag.comm_bytes_per_step}

    with set_mesh(mesh):
        params = jax.jit(s_pool.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (steps, n, 1, 32), 0,
                                  cfg.vocab_size)
        batches = {"tokens": toks, "labels": toks}

        # (a) >= 3 consecutive in-pool gamma swaps: zero retraces
        g1 = np.roll(g0, 1).astype(np.float32); g1 /= max(g1.sum(), 1e-9)
        swaps = iter([PoolSwap(gammas=g1), PoolSwap(gammas=g0),
                      PoolSwap(gammas=g1)])
        r_pool = s_pool.run_segments(params, None, batches, g0, segment_len=seg,
                                     on_segment=lambda t: next(swaps, None))
        assert r_pool["n_traces"] == 1 and r_pool["recompiles"] == 0, r_pool
        assert len(r_pool["swaps"]) >= 3
        assert np.isfinite(r_pool["losses"]).all()

        # (b) pool miss: exactly one counted recompile
        new_perm = tuple(int(v) for v in np.roll(np.arange(n), n // 2 + 1))
        ns = BirkhoffSchedule(coeffs=(0.5, 0.5),
                              perms=(tuple(range(n)), new_perm))
        np2 = PermPool.from_schedule(ns, capacity=pool.capacity)
        ng, _ = np2.project(ns)
        miss = iter([PoolSwap(gammas=ng, pool=np2)])
        r_miss = s_pool.run_segments(r_pool["params"], None, batches, g0,
                                     segment_len=seg,
                                     on_segment=lambda t: next(miss, None))
        assert r_miss["recompiles"] == 1 and r_miss["n_traces"] == 2, r_miss

        # (c) wall clock: same batches, no swaps, both transports
        r_p = s_pool.run_segments(params, None, batches, g0, segment_len=seg)
        Wj = jnp.asarray(W, jnp.float32)
        r_a = s_ag.run_segments(params, None, batches, Wj, segment_len=seg)
        out["pool_segment_s"] = r_p["segment_s"]
        out["allgather_segment_s"] = r_a["segment_s"]
        out["pool_comm"] = r_p["comm"]
        out["allgather_comm"] = r_a["comm"]
        out["in_pool_swaps"] = len(r_pool["swaps"])
        out["miss_recompiles"] = r_miss["recompiles"]

        # (d) sharded autotune: measure once on this forced-device mesh
        p_total = out["allgather_bytes_per_step"] // ((n - 1) * 4)
        out["autotune_winner"] = autotune_sharded_transport(
            n, pool.n_comm_slots, p_total, measure=True, mesh=mesh)

        # (e) compressed pool transports: the EF wire on the staged
        # ppermutes. Identity is the trace-time-routing rot detector
        # (must be BITWISE the uncompressed pool, swaps included);
        # bf16/top-k assert zero retraces across in-pool swaps and the
        # metered bytes against each wire's closed-form ratio.
        from repro.core.compression import make_compressor
        s_id = mk("pool", pool, "identity")
        s_bf = mk("pool", pool, "bf16")
        s_tk = mk("pool", pool, "topk:0.25")
        out["pool_bf16_bytes_per_step"] = s_bf.comm_bytes_per_step
        out["pool_topk25_bytes_per_step"] = s_tk.comm_bytes_per_step
        assert s_id.comm_bytes_per_step == s_pool.comm_bytes_per_step
        assert s_bf.comm_bytes_per_step * 2 == s_pool.comm_bytes_per_step
        assert s_bf.comm_bytes_per_step <= 0.55 * s_pool.comm_bytes_per_step
        pp = s_pool.comm_bytes_per_step // (pool.n_comm_slots * 4)
        k_elems, k_item = make_compressor("topk:0.25").wire_layout(pp)
        assert s_tk.comm_bytes_per_step == pool.n_comm_slots * k_elems * k_item
        compressed = {}
        for wname, s_c in (("identity", s_id), ("bf16", s_bf),
                           ("topk:0.25", s_tk)):
            sw = iter([PoolSwap(gammas=g1), PoolSwap(gammas=g0),
                       PoolSwap(gammas=g1)])
            r_c = s_c.run_segments(params, s_c.init_opt_state(params),
                                   batches, g0, segment_len=seg,
                                   on_segment=lambda t: next(sw, None))
            assert r_c["n_traces"] == 1 and r_c["recompiles"] == 0, (wname, r_c)
            assert len(r_c["swaps"]) >= 3
            assert np.isfinite(r_c["losses"]).all(), wname
            compressed[wname] = {
                "bytes_per_step": s_c.comm_bytes_per_step,
                "comm": r_c["comm"],
                "losses_vs_uncompressed_max_abs": float(
                    np.abs(r_c["losses"] - r_pool["losses"]).max()),
            }
            if wname == "identity":
                assert np.array_equal(r_c["losses"], r_pool["losses"]), (
                    "identity wire diverged from the uncompressed pool")
        out["compressed_pool"] = compressed

    print("RESULT_JSON " + json.dumps(out))
"""


def _bench_sharded_pool(results: dict, smoke: bool) -> None:
    """Staged-pool vs all-gather on the online mesh trainer (subprocess:
    the main process must keep its single-device view)."""
    n = 8
    cfgd = {"n": n, "K": 4, "budget": 3,
            "steps": 8 if smoke else 24, "seg": 2 if smoke else 4}
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    # the sharded autotune entry lands next to the other bench artifacts
    # (the committed table on full runs, the smoke dir in CI)
    os.makedirs(result_dir(), exist_ok=True)
    env["REPRO_TRANSPORT_AUTOTUNE"] = os.path.join(
        result_dir(), "transport_autotune.json"
    )
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SHARDED_SCRIPT % json.dumps(cfgd))],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    assert proc.returncode == 0, f"sharded bench failed:\n{proc.stderr[-4000:]}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT_JSON ")]
    out = json.loads(line[0][len("RESULT_JSON "):])

    ratio = out["pool_bytes_per_step"] / out["allgather_bytes_per_step"]
    bound = (out["d_max"] + 1) / out["n"]
    out["bytes_ratio_pool_vs_allgather"] = ratio
    out["bytes_ratio_bound"] = bound
    # acceptance: the staged pool moves <= (d_max + eps)/n of the
    # all-gather's bytes/step, from the comm counters (eps = 1 atom)
    assert ratio <= bound, (ratio, bound)
    # steady-state medians, first segment (compile) excluded
    pool_med = float(np.median(out["pool_segment_s"][1:]))
    ag_med = float(np.median(out["allgather_segment_s"][1:]))
    out["pool_segment_median_s"] = pool_med
    out["allgather_segment_median_s"] = ag_med
    # acceptance (ISSUE 7): the bf16 pool moves <= 0.55x the
    # uncompressed pool's bytes/step, from the RUN meter (not just the
    # setup's static rate) -- asserted in smoke too
    bf_rate = out["compressed_pool"]["bf16"]["comm"]["per_step_bytes"]
    bf_ratio = bf_rate / out["pool_comm"]["per_step_bytes"]
    out["bytes_ratio_bf16_vs_pool"] = bf_ratio
    assert bf_ratio <= 0.55, bf_ratio
    results["sharded_pool"] = out
    emit(
        f"online_pool_mix_n{out['n']}", pool_med * 1e6,
        f"bytes_ratio={ratio:.3f}<=bound_{bound:.3f}_bf16={bf_ratio:.2f}x"
        f"_retraces=0_miss_recompiles={out['miss_recompiles']}"
        f"_vs_allgather_{ag_med * 1e6:.0f}us",
    )


def _bench_overlap(results: dict, smoke: bool) -> None:
    """Overlapped (background-thread) refresh vs inline refresh on the
    n=512/budget=64 rollout: how much solve latency the rollout hides.

    The three arms (frozen / sync / overlap) run the SAME precomputed
    observation stream -- this measures scheduling, not learning (the
    recovery bench above owns the quality claim). Drifts are scripted
    ``request_refresh`` calls on an estimator snapshotted from drifted
    labels, so all arms solve comparable problems deterministically.
    """
    if smoke:
        n, K, budget, rbudget = 32, 8, 8, 4
        steps, seg, batch = 600, 50, 4
        drift_segs = (3, 7)
    else:
        n, K, budget, rbudget = 512, 64, 64, 16
        steps, seg, batch = 40000, 1000, 1
        drift_segs = (8, 20, 32)
    rng = np.random.default_rng(0)
    Pi0 = rng.dirichlet(0.1 * np.ones(K), size=n)
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    zs = np.stack([task.sample(batch, rng) for _ in range(steps)]).astype(np.float32)

    t0 = time.perf_counter()
    res0 = learn_topology(Pi0, budget=budget, lam=LAM)
    t_initial = time.perf_counter() - t0
    # the initial arrays MUST use the refresher's l_max (zero-weight
    # atoms dropped + refresh-budget headroom): any other capacity would
    # make the first swap a shape change, i.e. a retrace
    sched0 = schedule_from_result(res0)
    sa0 = schedule_to_arrays(sched0, sched0.n_atoms + rbudget)

    # drifted Pi per scripted refresh + a label batch that imprints it on
    # a beta=1 estimator (empirical snapshot) at the drift boundary
    drift_pis = []
    Pi_t = Pi0
    for _ in drift_segs:
        Pi_t = Pi_t[rng.permutation(n)]
        drift_pis.append(Pi_t)
    label_rng = np.random.default_rng(7)
    drift_labels = [
        np.stack([label_rng.choice(K, size=256, p=Pi_d[i]) for i in range(n)])
        for Pi_d in drift_pis
    ]

    def run_arm(overlap: bool | None) -> dict:
        """overlap=None => frozen arm (no controller at all)."""
        arm: dict = {}
        hook = None
        ctl = None
        seg_times: list[tuple[float, bool]] = []
        if overlap is not None:
            ref = TopologyRefresher(res0, RefreshConfig(budget=rbudget, lam=LAM))
            ctl = OnlineTopologyController(
                ref, estimator=StreamingPiEstimator(n, K, beta=1.0, init=Pi0),
                overlap=overlap,
            )
            state = {"seg": 0, "drift": 0, "last": None}

            def hook(t):
                now = time.perf_counter()
                if state["last"] is not None:
                    seg_times.append((now - state["last"], ctl.refresh_pending))
                state["seg"] += 1
                if (state["drift"] < len(drift_segs)
                        and state["seg"] == drift_segs[state["drift"]]):
                    ctl.observe(drift_labels[state["drift"]])
                    state["drift"] += 1
                    ctl.request_refresh()
                ret = ctl.on_segment(t)
                state["last"] = time.perf_counter()
                return ret

        t0 = time.perf_counter()
        out = run_mean_estimation(
            task, None, steps=steps, lr=0.05, batch=batch, seed=2,
            schedule=sa0, zs=zs, on_segment=hook, segment_len=seg,
        )
        if ctl is not None:
            ctl.flush()
            ctl.close()
        arm["wall_s"] = time.perf_counter() - t0
        arm["n_traces"] = out["n_traces"]
        assert out["n_traces"] == 1, out["n_traces"]
        if ctl is not None:
            arm["refresh_log"] = ctl.refresh_log
            arm["solve_total_s"] = float(
                sum(r["solve_s"] for r in ctl.refresh_log)
            )
            arm["n_refreshes"] = ctl.refresher.n_refreshes
            idle = [s for s, pending in seg_times if not pending]
            busy = [s for s, pending in seg_times if pending]
            arm["segment_median_idle_s"] = float(np.median(idle)) if idle else None
            arm["segment_max_pending_s"] = float(max(busy)) if busy else None
        return arm

    frozen = run_arm(None)
    sync = run_arm(False)
    over = run_arm(True)

    solve_total = sync["solve_total_s"]
    hidden = (sync["wall_s"] - over["wall_s"]) / max(solve_total, 1e-9)
    hidden = float(np.clip(hidden, -1.0, 1.0))
    # the >= 0.5 target is a FULL-SIZE claim: at smoke sizes the solves
    # are ~ms, so the wall-clock difference is scheduling noise divided
    # by a tiny denominator -- record it, but only judge the target
    # where the measurement is meaningful (CI smoke still asserts the
    # non-blocking contract below, which is size-independent)
    target_met = None if smoke else hidden >= 0.5

    # the overlap contract, asserted in smoke too: every in-run refresh
    # was COLLECTED at a boundary, never waited for (blocked_s == 0 --
    # a final flush after the last segment is the only legal wait), and
    # no segment serialized behind a full solve (bounded jitter).
    in_run = [r for r in over["refresh_log"] if r["t_collect"] >= 0]
    assert in_run, "no overlapped refresh landed inside the run"
    for r in in_run:
        assert r["blocked_s"] == 0.0, r
    if over["segment_max_pending_s"] is not None:
        solve_med = float(np.median([r["solve_s"] for r in in_run]))
        jitter_bound = 5.0 * over["segment_median_idle_s"] + 0.8 * solve_med + 0.1
        assert over["segment_max_pending_s"] <= jitter_bound, (
            f"rollout serialized behind the solve: pending segment took "
            f"{over['segment_max_pending_s']:.3f}s > bound {jitter_bound:.3f}s"
        )

    results["overlap"] = {
        "n": n, "K": K, "budget": budget, "refresh_budget": rbudget,
        "steps": steps, "segment_len": seg, "drift_segments": list(drift_segs),
        "initial_cold_solve_s": t_initial,
        "wall_frozen_s": frozen["wall_s"],
        "wall_sync_s": sync["wall_s"],
        "wall_overlap_s": over["wall_s"],
        "solve_total_sync_s": solve_total,
        "solve_total_overlap_s": over["solve_total_s"],
        "hidden_latency_fraction": hidden,
        "target_met": target_met,
        "overlap_refresh_log": over["refresh_log"],
        "sync_refresh_log": sync["refresh_log"],
        "segment_median_idle_s": over["segment_median_idle_s"],
        "segment_max_pending_s": over["segment_max_pending_s"],
        # honesty note kept in the artifact, not only in prose: on a
        # 2-vCPU container the BLAS solve and the XLA rollout share
        # cores, so "hidden" latency is bounded by the spare-core time;
        # the >= 0.5 target assumes at least one core is free for the
        # solver while the rollout computes.
        "floor_note": (
            "hidden fraction is bounded by spare-core availability; "
            "solver (BLAS, GIL released) and rollout (XLA CPU) share "
            f"{os.cpu_count()} cores here"
        ),
    }
    emit(
        f"online_overlap_n{n}_b{budget}", over["wall_s"] * 1e6,
        f"hidden={hidden:.2f}_of_{solve_total * 1e3:.0f}ms"
        f"_sync_{sync['wall_s']:.2f}s_overlap_{over['wall_s']:.2f}s"
        f"_target_met={target_met}",
    )


def main(smoke: bool = False) -> None:
    results: dict = {"smoke": smoke}
    _bench_refresh_speed(results, smoke)
    _bench_recovery_and_retrace(results, smoke)
    _bench_frontier(results, smoke)
    _bench_sharded_pool(results, smoke)
    _bench_overlap(results, smoke)
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), "BENCH_online.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_online_json", 0.0, path)


if __name__ == "__main__":
    main()

"""Theorem 2: Frank-Wolfe suboptimality bound g(W^l) <= 16/(l+2)(lam + nuc).

Also App. D.3's lambda-insensitivity: final bias across lambda in
{1e-4, 0.1, 1e3}.
"""

import time

import numpy as np

from .common import emit, save_rows
from repro.core.stl_fw import fw_upper_bound, learn_topology
from repro.data.partition import shard_partition
from repro.data.synthetic import gaussian_blobs


def main(smoke: bool = False) -> None:
    t0 = time.perf_counter()
    n, n_samples = (30, 2000) if smoke else (100, 8000)
    X, y = gaussian_blobs(n_samples=n_samples, num_classes=10, dim=32, seed=1)
    _, Pi = shard_partition(y, n, shards_per_node=2, seed=1)

    lam = 0.1
    res = learn_topology(Pi, budget=20, lam=lam)
    rows = []
    worst_ratio = 0.0
    for l in range(1, 21):
        bound = fw_upper_bound(l, lam, Pi)
        g = res.objective_trace[l]
        worst_ratio = max(worst_ratio, g / bound)
        rows.append([l, g, bound, g / bound])
    save_rows("thm2.csv", ["l", "g", "bound", "ratio"], rows)
    us1 = (time.perf_counter() - t0) * 1e6
    emit("thm2_fw_bound", us1, f"max_g/bound={worst_ratio:.3f}(<=1)")

    # lambda sweep (App. D.3)
    t1 = time.perf_counter()
    lrows = []
    for lam_s in (1e-4, 0.1, 1e3):
        r = learn_topology(Pi, budget=10, lam=lam_s)
        lrows.append([lam_s, r.bias_trace[-1], r.variance_trace[-1]])
    save_rows("lambda_sweep.csv", ["lambda", "final_bias", "final_variance"], lrows)
    us2 = (time.perf_counter() - t1) * 1e6 / len(lrows)
    biases = [f"{r[1]:.4f}" for r in lrows]
    emit("lambda_sweep_bias", us2, "final_bias=" + "/".join(biases))


if __name__ == "__main__":
    main()

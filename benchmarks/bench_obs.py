"""Observability benchmarks: the telemetry stack's three load-bearing
claims, measured and asserted rather than asserted in prose.

1. **Probes are free in the values sense** -- the probes-on rollout
   (consensus distance, gradient deviation, tau_bar at Pi_hat riding
   the scan as extra per-step OUTPUTS) produces error/loss traces
   BITWISE equal to the probes-off run, across three schedule hot
   swaps, with every compile accounted for by a ``RetraceGuard``
   budget (``excess() == 0``, asserted in --smoke too: probes and
   swaps are value changes, never retraces).

2. **Probes are cheap in the wall-clock sense** -- per-segment wall
   time from the tracer's own ``sim.segment`` spans (the bench's
   timing harness IS the tracer), compile segments excluded,
   interleaved probes-on/off rounds with a min statistic (scheduler
   noise on a 1-vCPU container only ever adds time):

   * asserted <= 10% overhead for the default probe set (consensus +
     grad_dev) at the paper's n=512 mean-estimation scale (the CI
     bound, smoke too) -- the probes cost a fixed handful of fused
     kernels per step, well under the step's own wall there;
   * recorded honestly where the ratio is structurally worse: the
     tau_bar probe's O(l_max * n * K) Pi_hat mix rivals the whole
     scalar step at K=64, and on a vector-payload MLP the
     consensus/grad_dev passes are memory-bound against a
     matmul-bound step (20-40% of wall). The JSON carries those
     numbers with flags and the explanation instead of pretending
     one bound covers every payload regime.

3. **The report pipeline round-trips** -- the run's telemetry
   (metrics, comm fates, health series, span summaries, retrace
   table, swap events) aggregates into ``run_report.json`` +
   ``run_report.md``; the JSON re-loads through ``validate_report``,
   the live JSONL span sink re-parses via ``read_jsonl``, and the
   Perfetto export is a well-formed Chrome trace-event array. These
   are the artifacts CI uploads from --smoke.

Writes experiments/bench/BENCH_obs.json plus run_report.{json,md},
trace.jsonl, and trace_perfetto.json next to it.
"""

import json
import os

import numpy as np

from .common import emit, result_dir
from repro.core.mixing import BirkhoffSchedule, schedule_to_arrays
from repro.data.drift import partition_from_pi
from repro.data.synthetic import gaussian_blobs, mean_estimation_clusters
from repro.obs import (
    HealthProbes,
    RetraceGuard,
    RunReport,
    Tracer,
    load_report,
    read_jsonl,
)
from repro.train.trainer import _eval_segments, run_classification, run_mean_estimation


def _shift_schedule(n: int, coeffs=(1 / 3, 1 / 3, 1 / 3)):
    """Doubly stochastic ring mix: identity + both cyclic shifts."""
    ids = tuple(range(n))
    up = tuple(int(v) for v in np.roll(np.arange(n), 1))
    down = tuple(int(v) for v in np.roll(np.arange(n), -1))
    sched = BirkhoffSchedule(
        coeffs=tuple(float(c) for c in coeffs), perms=(ids, up, down)
    )
    return schedule_to_arrays(sched, sched.n_atoms)


class _CommShim:
    """Adapter: RunReport.add_comm wants ``.summary()``; the drivers
    return the already-summarized dict."""

    def __init__(self, summary: dict):
        self._summary = dict(summary)

    def summary(self) -> dict:
        return self._summary


def _seg_best(tracer: Tracer, k: int) -> float:
    """Best (min) ``sim.segment`` wall time over length-``k`` segments,
    first occurrence (the compile) excluded. Min, not median: the
    fastest repeat is the least noise-inflated estimate of the
    segment's true compute cost, which is what the overhead ratio
    compares (scheduler noise only ever adds time)."""
    durs = [
        r.duration_s
        for r in tracer.spans("sim.segment")
        if r.attrs.get("k") == k
    ]
    assert len(durs) >= 2, f"need >=2 length-{k} segments to exclude compile"
    return float(min(durs[1:]))


def _bench_bitwise_and_swaps(results, smoke, tracer, guard):
    """Probes-on vs probes-off mean estimation across 3 hot swaps:
    bitwise-equal errors, all compiles budgeted."""
    n, K, steps, seg = (16, 4, 160, 20) if smoke else (64, 8, 400, 50)
    task = mean_estimation_clusters(n_nodes=n, K=K)
    Pi = np.eye(K)[np.arange(n) % K].astype(float)
    sa_a = _shift_schedule(n)
    sa_b = _shift_schedule(n, coeffs=(0.5, 0.25, 0.25))

    def run(probes, pi_hat, tr):
        swaps = iter([sa_b, sa_a, sa_b])
        return run_mean_estimation(
            task, None, steps=steps, lr=0.1, batch=2, seed=0,
            schedule=sa_a, segment_len=seg,
            on_segment=lambda t: next(swaps, None),
            probes=probes, pi_hat=pi_hat, tracer=tr, retrace_guard=guard,
        )

    out_off = run(None, None, None)
    probes = HealthProbes(consensus=True, grad_dev=True, tau_bar=True,
                          B=1.0, sigma2=float(task.sigma_tilde2))
    out_on = run(probes, Pi, tracer)

    # the hot-swap invariant, now with probes in the scan outputs: both
    # arms trace once, swap thrice, and agree bit for bit
    for key in ("mean_sq_error", "max_sq_error", "min_sq_error"):
        assert np.array_equal(out_off[key], out_on[key]), (
            f"probes changed the {key} trajectory"
        )
    assert out_off["n_traces"] == 1 and out_on["n_traces"] == 1, (
        out_off["n_traces"], out_on["n_traces"],
    )
    assert out_off["swaps"] == out_on["swaps"] and len(out_on["swaps"]) == 3
    health = out_on["health"]
    assert tuple(health) == ("consensus", "grad_dev", "tau_bar")
    for name, series in health.items():
        assert series.shape == (steps,), (name, series.shape)
        assert np.all(np.isfinite(series)), name
    assert np.all(health["consensus"] >= 0.0)
    assert np.all(health["tau_bar"] >= 0.0)

    results["bitwise_swaps"] = {
        "n": n, "K": K, "steps": steps, "segment_len": seg,
        "swaps": out_on["swaps"],
        "n_traces": {"off": out_off["n_traces"], "on": out_on["n_traces"]},
        "bitwise_equal": True,
        "health_last": {k: float(v[-1]) for k, v in health.items()},
        "health_first": {k: float(v[0]) for k, v in health.items()},
    }
    emit(
        f"obs_bitwise_probes_n{n}", 0.0,
        f"bitwise=True_swaps={len(out_on['swaps'])}_retraces=1+1"
        f"_probes={'+'.join(health)}",
    )
    return out_on


def _bench_overhead_n512(results, smoke, guard) -> int:
    """The asserted <=10% bound, at the paper's n=512 mean-estimation
    scale with a realistic local batch.

    The default probe set (consensus + grad_dev) costs a FIXED ~10
    small fused kernels per step (~1.5us on this host), independent of
    how much work the step does -- so the ratio is about the step's
    own wall. At n=512/batch=64 the step is ~40us and the bound holds
    with margin; the assertion takes min over many interleaved
    segments (the first runs in a process pay one-time warm-up that an
    off-then-on ordering would book entirely against one arm, and
    scheduler noise only ever ADDS time) and allows itself extra
    rounds on a contended box before judging. The tau_bar probe's
    O(l_max*n*K) Pi_hat mix is ~2x the whole step at K=64 -- its
    overhead is recorded with its own flag, not asserted: tau_bar is a
    sampling diagnostic, not an always-on probe, at that payload/K
    ratio. Returns the number of runs (for the retrace ledger)."""
    n, K, batch = 512, 64, 64
    steps, seg = (1500, 250) if smoke else (3000, 500)
    rounds = 3 if smoke else 4
    task = mean_estimation_clusters(n_nodes=n, K=K)
    Pi = np.eye(K)[np.arange(n) % K].astype(float)
    sa = _shift_schedule(n)
    # one observation stream for every arm: re-sampling would vary the
    # data (not the math) between timing rounds
    zs = np.stack(
        [task.sample(batch, np.random.default_rng(0)) for _ in range(steps)]
    ).astype(np.float32)
    n_runs = 0

    def run(probes, pi_hat):
        nonlocal n_runs
        n_runs += 1
        tr = Tracer()
        out = run_mean_estimation(
            task, None, steps=steps, lr=0.1, batch=batch, seed=0, zs=zs,
            schedule=sa, segment_len=seg,
            probes=probes, pi_hat=pi_hat, tracer=tr, retrace_guard=guard,
        )
        assert out["n_traces"] == 1, out["n_traces"]
        return _seg_best(tr, seg)

    base = HealthProbes(consensus=True, grad_dev=True)
    tau = HealthProbes(consensus=True, grad_dev=True, tau_bar=True,
                       B=1.0, sigma2=float(task.sigma_tilde2))
    t_offs, t_bases, t_taus = [], [], []
    for _ in range(rounds):
        t_offs.append(run(None, None))
        t_bases.append(run(base, None))
        t_taus.append(run(tau, Pi))
    # a 1-vCPU container stalls in multi-second bursts; if the bound
    # looks blown, buy more samples before believing it
    extra = 0
    while (min(t_bases) - min(t_offs)) / min(t_offs) > 0.10 and extra < 2:
        extra += 1
        t_offs.append(run(None, None))
        t_bases.append(run(base, None))
    t_off, t_base, t_tau = min(t_offs), min(t_bases), min(t_taus)
    ovh_base = (t_base - t_off) / t_off
    ovh_tau = (t_tau - t_off) / t_off
    # acceptance: the default probe set within 10% of the probes-off
    # rollout wall at the paper's scale -- the CI smoke bound
    assert ovh_base <= 0.10, (
        f"probe overhead {ovh_base:.1%} > 10% of rollout wall at n={n} "
        f"(off {t_off * 1e3:.2f}ms, on {t_base * 1e3:.2f}ms per segment)"
    )
    results["overhead_n512"] = {
        "n": n, "K": K, "batch": batch, "steps": steps, "segment_len": seg,
        "rounds": rounds, "extra_rounds": extra,
        "segment_off_s": t_off,
        "segment_probes_s": t_base,
        "segment_probes_tau_s": t_tau,
        "overhead_frac": float(ovh_base),
        "overhead_frac_with_tau_bar": float(ovh_tau),
        "tau_bar_within_10pct": bool(ovh_tau <= 0.10),
        "note": (
            "default probes cost ~10 fixed kernels/step; tau_bar adds "
            "an O(l_max*n*K) Pi_hat mix that rivals the whole scalar "
            "step at K=64 -- sample it at segment boundaries instead "
            "of leaving it on when the payload is this small"
        ),
    }
    emit(
        f"obs_probe_overhead_n{n}", t_base * 1e6,
        f"overhead={ovh_base:+.3f}_bound=0.10_with_tau={ovh_tau:+.3f}",
    )
    return n_runs


def _bench_overhead_classification(results, smoke, guard):
    """Probe overhead on a vector-payload model, recorded honestly:
    consensus/grad_dev are memory-bound passes over the stacked params
    while the MLP step is matmul-bound, and this CPU does matmul FLOPs
    ~an order of magnitude faster than elementwise passes -- so the
    probes' share of wall here is 20-40%, NOT <=10%. The JSON carries
    the measured ratio and the explanation; the asserted bound lives
    on the n=512 arm above, where probe cost is payload-independent.
    steps = 1 + m*eval_every keeps every eval segment the same length,
    so exactly two shapes compile and the timed segments are uniform.
    The bitwise claim IS asserted here: probes must not change the
    loss trajectory."""
    n, C, d, spn = 8, 8, 64, 64
    eval_every = 40
    m = 3 if smoke else 6
    steps = 1 + m * eval_every
    X, y = gaussian_blobs(n_samples=40 * spn, num_classes=C, dim=d, seed=3)
    Pi = np.eye(C)[np.arange(n) % C].astype(float)
    idx = partition_from_pi(y, Pi, samples_per_node=spn, seed=4)
    sa = _shift_schedule(n)
    n_shapes = len({l for l, _ in _eval_segments(steps, eval_every, True)})

    def run(probes, pi_hat):
        tr = Tracer()
        logger = run_classification(
            X, y, idx, None, model="mlp", hidden=64, steps=steps,
            batch_size=32, lr=0.2, eval_every=eval_every, seed=5, schedule=sa,
            on_segment=lambda t: None,  # segment the rollout, swap nothing
            probes=probes, pi_hat=pi_hat, tracer=tr, retrace_guard=guard,
        )
        return logger, _seg_best(tr, eval_every)

    probes = HealthProbes(consensus=True, grad_dev=True, tau_bar=True,
                          B=1.0, sigma2=1.0)
    rounds = 2
    offs, ons = [], []
    for _ in range(rounds):
        log_off, t = run(None, None)
        offs.append(t)
        log_on, t = run(probes, Pi)
        ons.append(t)
    t_off, t_on = min(offs), min(ons)

    assert np.array_equal(
        np.asarray(log_off.column("loss"), float),
        np.asarray(log_on.column("loss"), float),
    ), "probes changed the classification loss trajectory"
    overhead = (t_on - t_off) / t_off
    results["overhead_classification"] = {
        "n": n, "C": C, "d": d, "model": "mlp", "hidden": 64,
        "steps": steps, "eval_every": eval_every,
        "segment_off_s": t_off, "segment_on_s": t_on,
        "overhead_frac": float(overhead),
        "within_10pct": bool(overhead <= 0.10),
        "rounds": rounds,
        "n_traces_per_run": n_shapes,
        "probes": list(probes.names()),
        "note": (
            "recorded, not asserted: full-probe-set passes over the "
            "param/grad stacks are memory-bound against a matmul-bound "
            "step -- the price of per-step deviation norms on vector "
            "payloads; thin the probe set or sample at boundaries if "
            "this matters for a given run"
        ),
    }
    emit(
        f"obs_overhead_cls_n{n}", t_on * 1e6,
        f"overhead={overhead:+.3f}_vs_off_{t_off * 1e6:.0f}us_recorded",
    )
    return log_on, 2 * rounds * n_shapes


def _bench_report(results, smoke, tracer, guard, out_me, logger_cls):
    """Aggregate the arms above into the run-report artifact pair and
    validate everything CI will rely on."""
    out_dir = result_dir()
    rep = RunReport(
        "bench_obs", smoke=smoke,
        tasks=["mean_estimation", "classification"],
    )
    rep.add_metrics(logger_cls)
    rep.add_comm(_CommShim(out_me["comm"]))
    rep.add_events("swap", [{"t": int(t)} for t in out_me["swaps"]])
    rep.add_health(out_me["health"])
    rep.add_spans(tracer)
    rep.add_retraces(guard)
    paths = rep.write(out_dir)
    # the validation CI runs on the artifact, run here first
    doc = load_report(paths["json"])
    assert doc["retraces"]["excess"] == 0, doc["retraces"]
    assert doc["health"], "report lost the health series"
    assert "sim.segment" in doc["spans"]["by_name"], doc["spans"]

    # trace artifacts: the live JSONL sink must re-parse, the ring
    # export must match it record-for-record (nothing dropped at these
    # sizes), and the Perfetto export must be a valid trace-event array
    tracer.close()
    sink_recs = read_jsonl(tracer.sink_path)
    ring_recs = tracer.spans()
    assert len(sink_recs) == len(ring_recs) and tracer.dropped == 0
    assert [r.name for r in sink_recs] == [r.name for r in ring_recs]
    pf_path = tracer.write_perfetto(os.path.join(out_dir, "trace_perfetto.json"))
    with open(pf_path) as f:
        events = json.load(f)
    assert isinstance(events, list) and events
    for ev in events:
        assert ev["ph"] in ("X", "i", "M"), ev
        assert "pid" in ev and "tid" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0 and "ts" in ev

    results["report"] = {
        "paths": paths,
        "trace_jsonl": tracer.sink_path,
        "trace_perfetto": pf_path,
        "n_spans": len(sink_recs),
        "n_perfetto_events": len(events),
        "retraces": guard.snapshot(),
    }
    emit(
        "obs_run_report", 0.0,
        f"spans={len(sink_recs)}_events={len(events)}"
        f"_excess_retraces={guard.excess()}_validated=True",
    )


def main(smoke: bool = False) -> None:
    results: dict = {"smoke": smoke}
    os.makedirs(result_dir(), exist_ok=True)
    sink = os.path.join(result_dir(), "trace.jsonl")
    if os.path.exists(sink):
        os.remove(sink)  # the sink appends; each bench run starts fresh
    guard = RetraceGuard()

    with Tracer(capacity=8192, sink_path=sink) as tracer:
        out_me = _bench_bitwise_and_swaps(results, smoke, tracer, guard)
        me_runs = _bench_overhead_n512(results, smoke, guard)
        logger_cls, cls_traces = _bench_overhead_classification(
            results, smoke, guard
        )

        # the compile ledger: every mean-estimation run (2 bitwise arms
        # + the interleaved overhead rounds) compiles its scan exactly
        # once, and each classification run compiles once per distinct
        # segment length. Anything beyond this budget is an unexplained
        # retrace -- the number CI keeps at 0.
        guard.expect("mean_estimation.roll", 2 + me_runs)
        guard.expect("classification.roll", cls_traces)
        assert guard.excess() == 0, guard.snapshot()

        _bench_report(results, smoke, tracer, guard, out_me, logger_cls)

    path = os.path.join(result_dir(), "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("bench_obs_json", 0.0, path)


if __name__ == "__main__":
    main()

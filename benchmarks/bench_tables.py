"""Tables 1-3 (paper App. D.2): statistics of the used topologies.

Per topology: in/out degree (mean +- std), classes in neighborhood, bias
(the label-skew neighborhood bias of Eq. 7) and 1-p.
"""

import time

import numpy as np

from .common import emit, save_rows
from repro.core import topology as T
from repro.core.dcliques import d_cliques
from repro.core.heterogeneity import classes_in_neighborhood, label_skew_bias
from repro.core.stl_fw import learn_topology
from repro.data.partition import shard_partition
from repro.data.synthetic import gaussian_blobs


def stats_row(name: str, W: np.ndarray, Pi: np.ndarray) -> list:
    ind = T.in_degrees(W)
    outd = T.out_degrees(W)
    cls = classes_in_neighborhood(W, Pi)
    bias = label_skew_bias(W, Pi)
    one_minus_p = 1.0 - T.mixing_parameter(W)
    return [
        name,
        f"{ind.mean():.2f}+-{ind.std():.2f}",
        f"{outd.mean():.2f}+-{outd.std():.2f}",
        f"{cls.mean():.2f}+-{cls.std():.2f}",
        f"{bias:.5f}",
        f"{one_minus_p:.3f}",
    ]


def main(smoke: bool = False) -> None:
    t0 = time.perf_counter()
    n, n_samples = (30, 2000) if smoke else (100, 10000)
    X, y = gaussian_blobs(n_samples=n_samples, num_classes=10, dim=32, seed=0)
    _, Pi = shard_partition(y, n, shards_per_node=2, seed=0)

    rows = []
    derived = []
    for budget in (2, 5, 10):
        Ws = learn_topology(Pi, budget=budget, lam=0.1).W
        Wr = T.random_d_regular(n, budget, seed=0)
        rows.append([f"d{budget}"] + stats_row(f"stl-fw(d{budget})", Ws, Pi)[1:])
        rows[-1][0] = f"stl-fw(d{budget})"
        rows.append(stats_row(f"random(d{budget})", Wr, Pi))
        if budget == 10:
            derived.append(
                f"bias_stlfw_d10={label_skew_bias(Ws, Pi):.5f}"
                f";bias_rnd_d10={label_skew_bias(Wr, Pi):.5f}"
            )
    rows.append(stats_row("d-cliques", d_cliques(Pi, clique_size=10, seed=0), Pi))
    rows.append(stats_row("exponential", T.exponential_graph(n), Pi))
    save_rows(
        "tables.csv",
        ["topology", "in_degree", "out_degree", "classes_in_nbhd", "bias", "1-p"],
        rows,
    )
    for r in rows:
        print("# table:", ",".join(str(x) for x in r))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    emit("tables_topology_stats", us, ";".join(derived))


if __name__ == "__main__":
    main()

"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")

_SMOKE = False


def set_smoke(value: bool) -> None:
    """Smoke runs save under experiments/bench/smoke/ so CI's tiny-size
    numbers never clobber the real benchmark artifacts."""
    global _SMOKE
    _SMOKE = bool(value)


def result_dir() -> str:
    return os.path.join(RESULT_DIR, "smoke") if _SMOKE else RESULT_DIR


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness CSV contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def save_rows(filename: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(result_dir(), exist_ok=True)
    path = os.path.join(result_dir(), filename)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(x) for x in row) + "\n")
    return path

"""Theorem 1 rate comparison: iteration complexity under the new
neighborhood-heterogeneity bound vs the classical Koloskova et al. rate.

For Example-1-like setups, the tau-based rate is m-independent while the
zeta-based rate diverges -- the paper's core theoretical claim, evaluated
numerically with the explicit constants of Appendix B.
"""

import time

import numpy as np

from .common import emit, save_rows
from repro.core import topology as T
from repro.core.heterogeneity import local_heterogeneity, tau_bar_label_skew
from repro.core.theory import (
    RateInputs,
    iterations_to_eps_convex,
    koloskova_iterations_convex,
)
from repro.data.synthetic import mean_estimation_clusters


def main(smoke: bool = False) -> None:
    t0 = time.perf_counter()
    n, K, eps = (30 if smoke else 100), 10, 0.05
    rows = []
    for m in (1.0, 5.0, 25.0):
        task = mean_estimation_clusters(n_nodes=n, K=K, m=m)
        from repro.core.stl_fw import learn_topology

        res = learn_topology(task.Pi, budget=9, lam=0.5)
        W = res.W
        p = T.mixing_parameter(W)
        tau2 = tau_bar_label_skew(W, task.Pi, B=task.B, sigma_max2=task.sigma_i2)
        zeta2 = local_heterogeneity(task.expected_grads(0.0))
        c = RateInputs(L=task.L, sigma_bar2=task.sigma_i2, tau_bar2=tau2,
                       p=p, n=n, r0=1.0)
        T_ours = iterations_to_eps_convex(c, eps)
        T_prior = koloskova_iterations_convex(
            task.L, task.sigma_i2, zeta2, p, n, 1.0, eps
        )
        rows.append([m, p, tau2, zeta2, T_ours, T_prior])
    save_rows("theory_rates.csv", ["m", "p", "tau2", "zeta2", "T_ours", "T_koloskova"], rows)
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    growth_ours = rows[-1][4] / rows[0][4]
    growth_prior = rows[-1][5] / rows[0][5]
    emit("thm1_rate_vs_m", us,
         f"T_growth_ours={growth_ours:.2f}x;T_growth_prior={growth_prior:.2f}x")


if __name__ == "__main__":
    main()

"""Quickstart: learn a sparse topology with STL-FW and train with D-SGD.

Reproduces the paper's core loop in ~30 lines of user code:

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import learn_topology, topology as T
from repro.data.synthetic import mean_estimation_clusters
from repro.train.trainer import run_mean_estimation


def main() -> None:
    # 100 agents, 10 latent data clusters, heterogeneity level m = 5
    task = mean_estimation_clusters(n_nodes=100, K=10, m=5.0)

    # STL-FW: learn a sparse mixing matrix from the class proportions Pi.
    # budget = 9 edges per node (the paper's elbow: K - 1).
    result = learn_topology(task.Pi, budget=9, lam=0.5)
    print(f"learned topology: d_max = {T.max_degree(result.W)}, "
          f"bias = {result.bias_trace[-1]:.2e}, "
          f"1-p = {1 - T.mixing_parameter(result.W):.3f}")

    # run D-SGD (Algorithm 1) on the learned topology vs a random baseline
    out_stl = run_mean_estimation(task, result.W, steps=60, lr=0.2)
    out_rnd = run_mean_estimation(task, T.random_d_regular(100, 9, seed=0),
                                  steps=60, lr=0.2)
    print(f"final error  STL-FW: {out_stl['mean_sq_error'][-1]:.5f}")
    print(f"final error  random: {out_rnd['mean_sq_error'][-1]:.5f}")
    print(f"worst node   STL-FW: {out_stl['max_sq_error'][-1]:.5f}")
    print(f"worst node   random: {out_rnd['max_sq_error'][-1]:.5f}")


if __name__ == "__main__":
    main()

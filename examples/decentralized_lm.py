"""End-to-end driver: decentralized LM pretraining with a learned topology.

Runs D-SGD over a (data x model) device mesh on a reduced transformer for a
few hundred steps with domain-skewed synthetic data -- the systems-scale
version of the paper's experiments. On the CPU container this uses 8 forced
host devices; the same code runs the full config on a TPU pod with --full.

    PYTHONPATH=src python examples/decentralized_lm.py --steps 200
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main  # the launcher is the public driver

if __name__ == "__main__":
    # default arguments: qwen3-0.6b smoke config, STL-FW topology
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "qwen3-0.6b", "--steps", "200",
                     "--topology", "stl-fw", "--budget", "2", "--lr", "5e-3"]
    main()

"""Online topology adaptation end-to-end: drift -> detect -> warm refresh.

The Section 6.1 mean-estimation task with an abrupt label swap halfway
through training. Three D-SGD runs on the SAME observation stream:

* frozen    -- the pre-drift STL-FW topology, never updated;
* oracle    -- a cold-solved topology on the true post-drift Pi, swapped
               in at exactly the drift step (what a clairvoyant would do);
* online    -- the repro.online pipeline: streaming Pi_hat from minibatch
               labels, drift detector on the Prop.-2 heterogeneity proxy,
               warm STL-FW refresh, zero-retrace schedule hot-swap.

    PYTHONPATH=src python examples/online_drift.py --nodes 32 --steps 300

Prints the detector's event log and the final error of each run. See
docs/online_adaptation.md for the walk-through.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import learn_topology
from repro.core.mixing import schedule_from_result, schedule_to_arrays
from repro.data.drift import AbruptLabelSwap, labels_stream
from repro.data.synthetic import mean_estimation_clusters
from repro.online import (
    OnlineTopologyController,
    RefreshConfig,
    StreamingPiEstimator,
    TopologyRefresher,
)
from repro.train.trainer import run_mean_estimation


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--segment", type=int, default=20)
    args = ap.parse_args()
    n, K, steps = args.nodes, args.classes, args.steps
    t_drift, lam, lr, batch = steps // 3, 0.5, 0.05, 4

    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=1.0)
    Pi0 = np.eye(K)[np.arange(n) % K].astype(float)
    scenario = AbruptLabelSwap(
        Pi0, t_drift=t_drift, node_perm=np.random.default_rng(11).permutation(n)
    )
    labels = labels_stream(scenario, steps, batch, seed=0)
    means = np.asarray(task.cluster_means)
    zs = means[labels] + np.random.default_rng(1).normal(size=labels.shape)

    print(f"learning the initial topology (n={n}, budget={args.budget})...")
    res0 = learn_topology(Pi0, budget=args.budget, lam=lam)
    oracle = learn_topology(scenario.Pi(t_drift), budget=args.budget, lam=lam)
    ref = TopologyRefresher(res0, RefreshConfig(budget=args.budget, lam=lam))
    sa0 = schedule_to_arrays(schedule_from_result(res0), ref.l_max)
    sa_oracle = schedule_to_arrays(schedule_from_result(oracle), ref.l_max)

    def run(hook):
        return run_mean_estimation(
            task, None, steps=steps, lr=lr, batch=batch, seed=2,
            schedule=sa0, zs=zs, on_segment=hook, segment_len=args.segment,
        )

    print(f"training 3x{steps} D-SGD steps (drift at t={t_drift})...")
    out_frozen = run(None)

    # swap at the first segment boundary at/after the drift -- robust to
    # --segment values that don't divide t_drift
    oracle_done = {"swapped": False}

    def oracle_hook(t):
        if not oracle_done["swapped"] and t >= t_drift - 1:
            oracle_done["swapped"] = True
            return sa_oracle
        return None

    out_oracle = run(oracle_hook)

    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(n, K, beta=0.2, init=Pi0)
    )
    fed = {"t": 0}

    def online_hook(t):
        while fed["t"] <= t:
            ctl.observe(labels[fed["t"]])
            fed["t"] += 1
        return ctl.on_segment(t)

    out_online = run(online_hook)

    print("\ndetector event log (one row per segment boundary):")
    for e in ctl.events:
        mark = " <-- REFRESH" if e["triggered"] else ""
        extra = (
            f" ({e['refresh_iters']} FW iters, {e['refresh_s'] * 1e3:.1f} ms)"
            if e["triggered"] else ""
        )
        print(f"  t={e['t']:4d}  proxy={e['proxy']:.4f}{mark}{extra}")

    tail = slice(-max(10, steps // 12), None)
    print(f"\nfinal mean squared error (median of last {-tail.start} steps):")
    for name, out in (("frozen", out_frozen), ("oracle", out_oracle),
                      ("online", out_online)):
        err = float(np.median(out["mean_sq_error"][tail]))
        print(f"  {name:8s} {err:.5f}   (rollout traces: {out['n_traces']})")
    n_lengths = len({min(args.segment, steps - t0)
                     for t0 in range(0, steps, args.segment)})
    print(
        f"\nonline pipeline: {ref.n_refreshes} warm refresh(es), schedule "
        f"swaps at steps {out_online['swaps']}; rollout traced "
        f"{out_online['n_traces']}x = once per distinct segment length "
        f"({n_lengths} here) -- the swaps themselves compiled nothing."
    )


if __name__ == "__main__":
    main()

"""Serving demo: batched prefill + decode across architecture families.

Greedy-generates from randomly initialized reduced models (weights are
untrained; the demo shows the engine API: batched requests, KV/window/
recurrent caches, long-context mode).

    PYTHONPATH=src python examples/serve_demo.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.serve.engine import generate


def main() -> None:
    for name, kwargs in [
        ("qwen3-0.6b", {}),
        ("gemma2-2b", {}),  # alternating local/global attention
        ("xlstm-350m", {}),  # recurrent state decode
        ("recurrentgemma-2b", {"long_context": True}),  # sub-quadratic mode
    ]:
        cfg = get_smoke_config(name)
        params = init_model(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab_size)
        t0 = time.time()
        out = generate(params, cfg, prompt, max_new_tokens=16, **kwargs)
        dt = time.time() - t0
        print(f"{name:20s} batch=4 prompt=12 -> +16 tokens in {dt:.2f}s "
              f"(first request: {out[0][:8].tolist()}...)")

    # VLM: image patches prepended
    cfg = get_smoke_config("llava-next-mistral-7b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    img = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.vision.num_patches, cfg.d_model)) * 0.1
    out = generate(params, cfg, prompt, max_new_tokens=8, image_embeds=img)
    print(f"{'llava (vlm)':20s} image+text decode ok: {out.shape}")

    # audio enc-dec
    cfg = get_smoke_config("whisper-small")
    params = init_model(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(3), (2, cfg.encoder.num_frames, cfg.d_model)) * 0.1
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 4), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, max_new_tokens=8, frames=frames)
    print(f"{'whisper (audio)':20s} enc-dec decode ok: {out.shape}")


if __name__ == "__main__":
    main()

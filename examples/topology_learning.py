"""Topology-learning deep dive: STL-FW vs every baseline the paper uses.

Builds the Section 6.2 style comparison on synthetic label-skew data:
fully-connected / random d-regular / exponential graph / D-Cliques / STL-FW,
prints the Tables 1-3 statistics and runs D-SGD classification on each.

    PYTHONPATH=src python examples/topology_learning.py --budget 5
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import learn_topology, topology as T
from repro.core.dcliques import d_cliques
from repro.core.heterogeneity import classes_in_neighborhood, label_skew_bias
from repro.core.mixing import preferred_transport, schedule_from_result
from repro.data.partition import shard_partition
from repro.data.synthetic import gaussian_blobs
from repro.train.trainer import run_classification


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=100)
    ap.add_argument("--budget", type=int, default=5)
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    n = args.nodes
    X, y = gaussian_blobs(n_samples=12000, num_classes=10, dim=48, sep=2.5, seed=0)
    idx, Pi = shard_partition(y[:10000], n, shards_per_node=2, seed=0)

    stl = learn_topology(Pi, budget=args.budget, lam=0.1)
    sched = schedule_from_result(stl)
    transport = preferred_transport(n, sched.n_communication_atoms)
    print(f"STL-FW: lmo backend = {stl.lmo_backend}, "
          f"{sched.n_atoms} Birkhoff atoms ({sched.n_communication_atoms} "
          f"communicating) -> preferred transport = {transport!r} "
          f"(schedule iff L <= n/4; see repro.core.mixing.preferred_transport)\n")

    topologies = {
        "fully-connected": T.complete(n),
        f"random(d{args.budget})": T.random_d_regular(n, args.budget, seed=0),
        "exponential": T.exponential_graph(n),
        "d-cliques": d_cliques(Pi, clique_size=10, seed=0),
        f"stl-fw(d{args.budget})": stl.W,
    }

    print(f"{'topology':18s} {'d_max':>5s} {'classes/nbhd':>12s} {'bias':>9s} {'1-p':>6s}")
    for name, W in topologies.items():
        cls = classes_in_neighborhood(W, Pi)
        print(f"{name:18s} {T.max_degree(W):5d} {cls.mean():12.2f} "
              f"{label_skew_bias(W, Pi):9.5f} {1 - T.mixing_parameter(W):6.3f}")

    print("\ntraining D-SGD (linear classifier) on each topology...")
    for name, W in topologies.items():
        log = run_classification(
            X[:10000], y[:10000], idx, W, steps=args.steps, batch_size=64,
            lr=0.3, eval_every=args.steps - 1,
            X_test=X[10000:], y_test=y[10000:],
        )
        final = [r for r in log.history if "acc_mean" in r][-1]
        print(f"{name:18s} acc = {final['acc_mean']:.4f} "
              f"[min {final['acc_min']:.4f} / max {final['acc_max']:.4f}]")


if __name__ == "__main__":
    main()

"""Transport equivalence: every mixing execution of the same W must agree.

Covers the tentpole surface of the sparse Birkhoff mixing engine:
  * mix_dense == mix_schedule_stacked (single-buffer, per-leaf, and Pallas
    gossip_schedule kernel paths) on random doubly-stochastic W and on
    learned STL-FW schedules;
  * mix_ppermute == mix_dense on real multi-device buffers (subprocess,
    forced host devices -- reuses the test_distributed harness);
  * ravel_stack/unravel_stack round-trip incl. pad-once edge cases
    (P not a multiple of 128, n = 1);
  * scan-compiled rollouts match the per-step loop bit-for-bit;
  * the preferred_transport cost model's shape.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.mixing import (
    BirkhoffSchedule,
    mix_dense,
    mix_schedule_stacked,
    mix_stacked,
    preferred_transport,
    ravel_stack,
    schedule_from_matrix,
    schedule_from_result,
    unravel_stack,
)
from repro.core.stl_fw import learn_topology
from repro.data.synthetic import mean_estimation_clusters, gaussian_blobs
from repro.data.partition import shard_partition
from repro.train.trainer import run_classification, run_mean_estimation

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _sinkhorn(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    M = rng.random((n, n)) + 0.05
    for _ in range(400):
        M /= M.sum(1, keepdims=True)
        M /= M.sum(0, keepdims=True)
    return M


def _random_tree(n: int, seed: int):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.normal(size=(n, 13, 7)), jnp.float32),
        "b1": jnp.asarray(rng.normal(size=(n, 7)), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(n, 7, 3)), jnp.float32),
    }


def _assert_trees_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


@pytest.mark.parametrize("n", [4, 9, 16])
def test_schedule_matches_dense_on_sinkhorn_W(n):
    W = _sinkhorn(n, seed=n)
    sched = schedule_from_matrix(W)
    Wj = jnp.asarray(sched.to_matrix(), jnp.float32)  # exact atoms' matrix
    tree = _random_tree(n, seed=n + 1)
    dense = mix_dense(tree, Wj)
    for kwargs in (
        {"single_buffer": True},
        {"single_buffer": False},
        {"use_kernel": True, "block_p": 128},
    ):
        _assert_trees_close(dense, mix_schedule_stacked(tree, sched, **kwargs))


@pytest.mark.parametrize("budget", [2, 6])
def test_schedule_matches_dense_on_learned_topology(budget):
    n, K = 12, 4
    rng = np.random.default_rng(budget)
    Pi = rng.dirichlet(np.ones(K) * 0.5, size=n)
    res = learn_topology(Pi, budget=budget, lam=0.2)
    sched = schedule_from_result(res)
    assert sched.n_communication_atoms <= budget  # Theorem 2 sparsity
    tree = _random_tree(n, seed=budget + 10)
    dense = mix_dense(tree, jnp.asarray(res.W, jnp.float32))
    _assert_trees_close(dense, mix_schedule_stacked(tree, sched))
    _assert_trees_close(dense, mix_stacked(tree, schedule=sched, transport="schedule"))


def test_mix_stacked_auto_picks_and_agrees():
    n = 16
    W = T.ring(n)
    sched = schedule_from_matrix(W)  # ring: 3 atoms << n -> schedule
    assert preferred_transport(n, sched.n_atoms) == "schedule"
    assert preferred_transport(n, n) == "dense"
    tree = _random_tree(n, seed=3)
    Wj = jnp.asarray(W, jnp.float32)
    _assert_trees_close(
        mix_dense(tree, Wj),
        mix_stacked(tree, W=Wj, schedule=sched, transport="auto"),
    )


def test_ppermute_matches_schedule_stacked_multidevice():
    """All three transports agree on real multi-device buffers."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_compat_mesh, shard_map
        from repro.core import topology as T
        from repro.core.mixing import (schedule_from_matrix, mix_ppermute,
                                       mix_dense, mix_schedule_stacked)

        n = 8
        mesh = make_compat_mesh((n,), ("data",))
        W = T.random_d_regular(n, 3, seed=4)
        sched = schedule_from_matrix(W)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(n, 24)), jnp.float32)

        def gossip(v):
            return shard_map(lambda p: mix_ppermute(p, sched, "data"),
                             mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data"), axis_names={"data"})(v)

        got = np.asarray(jax.jit(gossip)(x))
        Wj = jnp.asarray(sched.to_matrix(), jnp.float32)
        dense = np.asarray(mix_dense(x, Wj))
        stacked = np.asarray(mix_schedule_stacked(x, sched))
        assert np.allclose(got, dense, atol=1e-5), np.abs(got - dense).max()
        assert np.allclose(stacked, dense, atol=1e-5), np.abs(stacked - dense).max()
        print("TRANSPORTS_AGREE")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=480, env=env,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert "TRANSPORTS_AGREE" in proc.stdout


# ---------------------------------------------------------------------------
# single-buffer ravel/unravel + pad-once edge cases
# ---------------------------------------------------------------------------

def test_ravel_roundtrip_pads_once():
    tree = _random_tree(5, seed=0)
    flat, spec = ravel_stack(tree, pad_to=128)
    assert flat.shape[1] % 128 == 0
    assert spec.pad == spec.padded - spec.total
    _assert_trees_close(tree, unravel_stack(flat, spec), atol=0.0)


@pytest.mark.parametrize("n,sizes", [(1, (37,)), (3, (5, 130)), (2, (128, 1))])
def test_schedule_kernel_shape_edge_cases(n, sizes):
    """P not a multiple of 128 and n = 1 must both work through the kernel
    path (padding happens once, at flatten time)."""
    rng = np.random.default_rng(n)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=(n, s)), jnp.float32) for i, s in enumerate(sizes)}
    if n == 1:
        sched = BirkhoffSchedule(coeffs=(1.0,), perms=((0,),))
    else:
        sched = schedule_from_matrix(_sinkhorn(n, seed=n + 7))
    dense = mix_dense(tree, jnp.asarray(sched.to_matrix(), jnp.float32))
    kern = mix_schedule_stacked(tree, sched, use_kernel=True, block_p=128)
    _assert_trees_close(dense, kern)


def test_mixed_dtype_single_buffer():
    rng = np.random.default_rng(0)
    n = 4
    tree = {
        "a": jnp.asarray(rng.normal(size=(n, 40)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 24)), jnp.bfloat16),
    }
    sched = schedule_from_matrix(T.ring(n))
    out = mix_schedule_stacked(tree, sched)
    assert out["a"].dtype == jnp.float32 and out["b"].dtype == jnp.bfloat16
    dense = mix_dense(tree, jnp.asarray(sched.to_matrix(), jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out["a"]), np.asarray(dense["a"]), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(out["b"], np.float32), np.asarray(dense["b"], np.float32), atol=5e-2
    )


# ---------------------------------------------------------------------------
# scan rollout == python loop, bit for bit
# ---------------------------------------------------------------------------

def test_mean_estimation_scan_matches_loop_bitwise():
    task = mean_estimation_clusters(n_nodes=12, K=4, m=3.0)
    W = T.ring(12)
    a = run_mean_estimation(task, W, steps=40, lr=0.2, seed=3, rollout="scan")
    b = run_mean_estimation(task, W, steps=40, lr=0.2, seed=3, rollout="loop")
    assert np.array_equal(a["theta"], b["theta"])
    for k in ("mean_sq_error", "max_sq_error", "min_sq_error"):
        assert np.array_equal(a[k], b[k]), k


def test_mean_estimation_scan_matches_loop_with_schedule_transport():
    task = mean_estimation_clusters(n_nodes=10, K=5, m=2.0)
    res = learn_topology(task.Pi, budget=4, lam=0.5)
    sched = schedule_from_result(res)
    a = run_mean_estimation(task, None, steps=25, lr=0.2, seed=1,
                            schedule=sched, transport="schedule", rollout="scan")
    b = run_mean_estimation(task, None, steps=25, lr=0.2, seed=1,
                            schedule=sched, transport="schedule", rollout="loop")
    assert np.array_equal(a["theta"], b["theta"])
    assert np.array_equal(a["mean_sq_error"], b["mean_sq_error"])


def test_classification_scan_matches_loop_trace():
    X, y = gaussian_blobs(n_samples=800, num_classes=5, dim=12, seed=2)
    idx, Pi = shard_partition(y, 8, seed=0)
    kwargs = dict(steps=33, batch_size=8, lr=0.3, eval_every=10,
                  X_test=X[:100], y_test=y[:100], seed=5)
    la = run_classification(X, y, idx, T.ring(8), rollout="scan", **kwargs)
    lb = run_classification(X, y, idx, T.ring(8), rollout="loop", **kwargs)
    assert la.history == lb.history


# ---------------------------------------------------------------------------
# measured transport autotune table
# ---------------------------------------------------------------------------

def test_autotune_transport_fallback_and_memoize(tmp_path, monkeypatch):
    from repro.core import mixing as M

    path = str(tmp_path / "transport_autotune.json")
    monkeypatch.setenv("REPRO_TRANSPORT_AUTOTUNE", path)
    M._autotune_cache = None  # drop any table cached from other tests

    # miss without measure => closed-form fallback, nothing written
    assert M.autotune_transport(64, 4, 512) == M.preferred_transport(64, 4)
    assert M.autotune_transport(64, 60, 512) == M.preferred_transport(64, 60)
    assert not os.path.exists(path)

    # miss with measure => record written at the power-of-two bucket,
    # keyed by a hardware fingerprint so one machine's measurements
    # never decide transports on different hardware
    w = M.autotune_transport(60, 3, 500, measure=True)
    assert w in ("schedule", "dense")
    import json
    key = M._bucket_key(60, 3, 500)
    assert key.endswith("_n64_L4_P512") and key.startswith(M._hw_tag())
    table = json.load(open(path))
    assert key in table
    for k in ("schedule_us", "dense_us", "winner", "backend", "hw"):
        assert k in table[key]

    # same bucket now resolves from the table even when the closed form
    # would disagree (force disagreement via an absurd dense_speedup)
    forced = M.autotune_transport(64, 4, 512, dense_speedup=1e9)
    assert forced == w
    M._autotune_cache = None  # don't leak the tmp table to other tests


def test_mix_stacked_autotune_transport_matches_dense(tmp_path, monkeypatch):
    from repro.core import mixing as M

    monkeypatch.setenv("REPRO_TRANSPORT_AUTOTUNE", str(tmp_path / "t.json"))
    M._autotune_cache = None
    rng = np.random.default_rng(7)
    n = 12
    W = T.ring(n)
    sched = M.schedule_from_matrix(W)
    params = {"w": jnp.asarray(rng.normal(size=(n, 96)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(n, 7)), jnp.float32)}
    got = mix_stacked(params, W=jnp.asarray(W, jnp.float32), schedule=sched,
                      transport="autotune")
    want = mix_dense(params, jnp.asarray(W, jnp.float32))
    for k in params:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(want[k]), atol=1e-5
        )
    M._autotune_cache = None


# ---------------------------------------------------------------------------
# sharded hot-swap transports: PermPool + cost model + autotune schema
# ---------------------------------------------------------------------------

def test_perm_pool_staging_projection_and_restage():
    from repro.core.mixing import PermPool

    sched = schedule_from_matrix(T.ring(8))
    pool = PermPool.from_schedule(sched, capacity=6)
    assert pool.capacity == 6 and pool.n_nodes == 8
    # ring = 0.5 I + 0.25 shift + 0.25 shift^-1: 2 comm slots, identity
    # headroom pads the rest (free until staged)
    assert pool.n_comm_slots == sched.n_communication_atoms
    g, dropped = pool.project(sched)
    assert dropped == 0.0 and pool.contains(sched)
    np.testing.assert_allclose(pool.to_matrix(g), T.ring(8), atol=1e-12)

    # out-of-pool atom: its mass is dropped, the rest renormalized (the
    # executed W stays doubly stochastic)
    new_perm = tuple(int(v) for v in np.roll(np.arange(8), 3))
    drifted = BirkhoffSchedule(
        coeffs=(0.6,) + tuple(0.4 * c for c in sched.coeffs),
        perms=(new_perm,) + sched.perms,
    )
    g2, dropped2 = pool.project(drifted)
    assert abs(dropped2 - 0.6) < 1e-12 and not pool.contains(drifted)
    assert abs(g2.sum() - 1.0) < 1e-6
    W2 = pool.to_matrix(g2)
    np.testing.assert_allclose(W2.sum(axis=0), 1.0, atol=1e-6)
    np.testing.assert_allclose(W2.sum(axis=1), 1.0, atol=1e-6)

    # restage fits everything again
    restaged = PermPool.from_schedule(drifted, capacity=6)
    assert restaged.contains(drifted)
    # over-capacity schedules are truncated, largest coefficients kept
    many = BirkhoffSchedule(
        coeffs=tuple(np.full(8, 1 / 8)),
        perms=tuple(tuple(int(v) for v in np.roll(np.arange(8), k)) for k in range(8)),
    )
    small = PermPool.from_schedule(many, capacity=3)
    assert small.capacity == 3

    with pytest.raises(ValueError):
        PermPool(perms=((0, 0, 1),))  # not a permutation
    with pytest.raises(ValueError):
        pool.project(schedule_from_matrix(T.ring(4)))  # node-count mismatch


def test_perm_pool_arrays_for_matches_slots():
    from repro.core.mixing import PermPool, arrays_to_matrix

    sched = schedule_from_matrix(T.ring(8))
    pool = PermPool.from_schedule(sched, capacity=5)
    g, _ = pool.project(sched)
    arrays = pool.arrays_for(g)
    assert arrays.l_max == pool.capacity
    np.testing.assert_allclose(arrays_to_matrix(arrays), T.ring(8), atol=1e-6)
    with pytest.raises(ValueError):
        pool.arrays_for(np.ones(3, np.float32))  # wrong gamma shape


def test_preferred_sharded_transport_crossover():
    from repro.core.mixing import preferred_sharded_transport

    # bytes: pool moves K*P per node, all-gather (n-1)*P discounted by
    # the fused-collective advantage => pool iff K <= (n-1)/advantage
    assert preferred_sharded_transport(8, 3) == "pool"
    assert preferred_sharded_transport(8, 4) == "allgather"
    assert preferred_sharded_transport(512, 64) == "pool"
    assert preferred_sharded_transport(4, 3, allgather_speedup=1.0) == "pool"
    with pytest.raises(ValueError):
        preferred_sharded_transport(8, 3, allgather_speedup=0.0)


def test_autotune_sharded_transport_schema_and_fallback(tmp_path, monkeypatch):
    import json

    from repro.core import mixing as M

    path = str(tmp_path / "transport_autotune.json")
    monkeypatch.setenv("REPRO_TRANSPORT_AUTOTUNE", path)
    M._autotune_cache = None

    # lookup-only miss => closed form, nothing written, nothing timed
    assert M.autotune_sharded_transport(8, 3, 4096) == "pool"
    assert M.autotune_sharded_transport(8, 7, 4096) == "allgather"
    assert not os.path.exists(path)
    # measure without a mesh cannot time => still the closed form
    assert M.autotune_sharded_transport(8, 7, 4096, measure=True) == "allgather"

    # a measured entry (the "sh_" schema extension of the same table)
    # overrides the closed form at its bucket -- and ONLY there
    key = M._sharded_bucket_key(8, 3, 4096)
    assert key.startswith("sh_") and key.endswith("_n8_K4_P4096")
    with open(path, "w") as f:
        json.dump({key: {"winner": "allgather"}}, f)
    M._autotune_cache = None
    assert M.autotune_sharded_transport(8, 3, 4096) == "allgather"
    assert M.autotune_sharded_transport(8, 3, 1 << 20) == "pool"  # other bucket
    # stacked-transport lookups never see sharded keys (disjoint prefix)
    assert M.autotune_transport(8, 3, 4096) == M.preferred_transport(8, 3)
    M._autotune_cache = None


def test_mix_bytes_per_step_model():
    from repro.train.metrics import CommMeter, mix_bytes_per_step

    P_, n = 1000, 8
    ag = mix_bytes_per_step("allgather", n_nodes=n, p_total=P_)
    pool = mix_bytes_per_step("pool", n_nodes=n, p_total=P_, n_comm_atoms=2)
    assert ag == (n - 1) * P_ * 4 and pool == 2 * P_ * 4
    assert mix_bytes_per_step("dense", n_nodes=n, p_total=P_) == 0
    assert mix_bytes_per_step(
        "ppermute", n_nodes=n, p_total=P_, n_comm_atoms=3
    ) == 3 * P_ * 4
    with pytest.raises(ValueError):
        mix_bytes_per_step("pool", n_nodes=n, p_total=P_)  # needs n_comm_atoms
    with pytest.raises(ValueError):
        mix_bytes_per_step("warp", n_nodes=n, p_total=P_)

    meter = CommMeter(per_step_bytes=ag)
    meter.tick(10)
    meter.set_rate(pool, step=10)
    meter.tick(5)
    s = meter.summary()
    assert s["total_bytes"] == 10 * ag + 5 * pool
    assert s["steps"] == 15 and s["rate_changes"] == [
        {"step": 10, "per_step_bytes": pool}
    ]

"""Corruption-tolerant gossip: injection, screening, quarantine (ISSUE 10).

The invariants under test:

* ``corrupt_wire`` applies corruption at DELIVERY time only: honest
  senders are bitwise untouched, the corrupting sender's own state stays
  clean (self-loops move no bytes), dead nodes are forced honest.
* ``mix_schedule_arrays_screened`` with a clean wire is bitwise the
  unscreened stale transport; the in-graph guard substitutes the
  receiver's own payload for non-finite arrivals (and propagates the
  poison with ``guard=False`` -- the honest screen-off baseline).
* The host-side screen never flags an honest same-step edge, whatever
  the heterogeneity: the allowance is derived from the run's own
  consensus probe, which bounds honest deviations by the triangle
  inequality (zero false positives by construction, audited by
  ``false_quarantines`` against the plan's ground truth).
* ``QuarantineController`` walks trusted -> quarantined -> probation ->
  readmitted, doubling the cooldown on probation relapse, and chains
  the Pi-estimator absence masking + refresh requests.
* The quarantine repair is ONE ``degrade_schedule`` call: W stays
  exactly doubly stochastic with isolated rows pinned to e_i (the
  single-survivor / no-identity-slot edge cases of the repair helpers
  are the satellite regressions).
* ``FaultPlan.fingerprint()`` is unchanged for every plan that does not
  corrupt (pinned hashes from the pre-corruption release).
* The runner routes at trace time: corruption-off arms compile the
  prior scan body (bitwise), and quarantine/re-admission mask swaps
  keep ``n_traces == 1``.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import topology as T
from repro.core.compression import Compressor, ef_mix_schedule_arrays
from repro.core.mixing import (
    PermPool,
    ScheduleArrays,
    ScreenStats,
    WireCorruption,
    corrupt_wire,
    degrade_pool_gammas,
    degrade_schedule,
    mix_schedule_arrays,
    mix_schedule_arrays_stale,
    mix_schedule_arrays_screened,
    schedule_from_matrix,
    schedule_to_arrays,
    stale_buffer_init,
    stale_push,
)
from repro.data.synthetic import mean_estimation_clusters
from repro.faults import (
    FaultInjector,
    FaultPlan,
    QuarantineController,
    ScreenPolicy,
    false_quarantines,
    run_faulty_mean_estimation,
)
from repro.obs.report import RunReport, load_report, validate_report
from repro.online.streaming import StreamingPiEstimator, mask_absent
from repro.train.metrics import CommMeter
from repro.train.trainer import run_mean_estimation


def _arrays(n: int, l_max: int = 6) -> ScheduleArrays:
    sched = schedule_from_matrix(0.6 * T.ring(n) + 0.4 * np.eye(n))
    return schedule_to_arrays(sched, l_max)


def _dense(arrays: ScheduleArrays) -> np.ndarray:
    g = np.asarray(arrays.gammas, np.float64)
    g = g / g.sum()
    P = np.asarray(arrays.perms)
    n = P.shape[1]
    W = np.zeros((n, n))
    for l in range(len(g)):
        W[np.arange(n), P[l]] += g[l]
    return W


def _honest(n: int) -> WireCorruption:
    return WireCorruption(
        mult=jnp.ones(n, jnp.float32), xor=jnp.zeros(n, jnp.int32)
    )


# ------------------------------------------------------------ corrupt_wire


def test_corrupt_wire_modes_and_honest_bitwise():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 3)).astype(np.float32)
    mult = jnp.asarray([1.0, -1.0, 8.0, np.nan], jnp.float32)
    xor = jnp.zeros(4, jnp.int32)
    out = np.asarray(corrupt_wire(jnp.asarray(x), WireCorruption(mult, xor)))
    assert np.array_equal(out[0], x[0])  # honest row: BITWISE untouched
    np.testing.assert_array_equal(out[1], -x[1])
    np.testing.assert_allclose(out[2], 8.0 * x[2], rtol=1e-6)
    assert np.isnan(out[3]).all()


def test_corrupt_wire_bitflip_is_involutive_xor():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(3, 5)).astype(np.float32)
    bit = np.int32(1) << np.int32(25)
    c = WireCorruption(
        mult=jnp.ones(3, jnp.float32),
        xor=jnp.asarray([0, bit, 0], jnp.int32),
    )
    out = np.asarray(corrupt_wire(jnp.asarray(x), c))
    assert np.array_equal(out[0], x[0]) and np.array_equal(out[2], x[2])
    assert not np.array_equal(out[1], x[1])
    # XOR is an involution: corrupting the corrupted row restores it
    back = np.asarray(corrupt_wire(jnp.asarray(out), c))
    np.testing.assert_array_equal(back[1], x[1])


def test_corrupt_wire_rejects_non_f32():
    with pytest.raises(ValueError, match="f32"):
        corrupt_wire(jnp.zeros((2, 2), jnp.float16), _honest(2))


def test_plain_transport_honest_corruption_is_bitwise():
    """An all-honest WireCorruption selects the untouched wire -- the
    corrupt= path must be bitwise the corrupt=None path."""
    n = 6
    arrays = _arrays(n)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    base = mix_schedule_arrays(x, arrays)
    hon = mix_schedule_arrays(x, arrays, corrupt=_honest(n))
    assert np.array_equal(np.asarray(base), np.asarray(hon))
    # and through the EF-compressed wire (identity compressor routes to
    # the plain transport)
    ef = jnp.zeros_like(x)
    b2, _ = ef_mix_schedule_arrays(x, ef, arrays, Compressor("identity"))
    h2, _ = ef_mix_schedule_arrays(
        x, ef, arrays, Compressor("identity"), corrupt=_honest(n)
    )
    assert np.array_equal(np.asarray(b2), np.asarray(h2))


# ------------------------------------------------------- screened transport


def _screened_setup(n=6, p=4, seed=3):
    arrays = _arrays(n)
    rng = np.random.default_rng(seed)
    own = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    buf = stale_push(stale_buffer_init(own, 1), own)
    delays = jnp.zeros(n, jnp.int32)
    return arrays, own, buf, delays


def test_screened_clean_wire_bitwise_vs_stale():
    arrays, own, buf, delays = _screened_setup()
    base = mix_schedule_arrays_stale(buf, arrays, delays)
    mixed, stats = mix_schedule_arrays_screened(buf, arrays, delays, own)
    assert np.array_equal(np.asarray(base), np.asarray(mixed))
    assert np.asarray(stats.finite).all()
    np.testing.assert_allclose(
        np.asarray(stats.sq_own),
        np.sum(np.asarray(own) ** 2, axis=1),
        rtol=1e-6,
    )


def test_screened_stats_identify_the_sent_payload():
    """sq_recv / dot on a corrupted edge describe the CORRUPTED payload
    (what crossed the wire), keyed by sender through the perm table."""
    arrays, own, buf, delays = _screened_setup()
    n = own.shape[0]
    mult = np.ones(n, np.float32)
    mult[2] = -1.0  # node 2 sign-flips
    c = WireCorruption(jnp.asarray(mult), jnp.zeros(n, jnp.int32))
    _, stats = mix_schedule_arrays_screened(buf, arrays, delays, own, c)
    per = np.asarray(arrays.perms)
    gam = np.asarray(arrays.gammas)
    o = np.asarray(own)
    sq = np.asarray(stats.sq_recv)
    dt = np.asarray(stats.dot)
    for l in range(per.shape[0]):
        if gam[l] == 0.0:
            continue
        for i in range(n):
            j = per[l, i]
            if j == i:
                continue
            sent = -o[j] if j == 2 else o[j]
            np.testing.assert_allclose(sq[l, i], (sent**2).sum(), rtol=1e-5)
            np.testing.assert_allclose(
                dt[l, i], (sent * o[i]).sum(), rtol=1e-5, atol=1e-5
            )


def test_screened_guard_contains_nan_and_off_propagates():
    arrays, own, buf, delays = _screened_setup()
    n = own.shape[0]
    mult = np.ones(n, np.float32)
    mult[0] = np.nan
    c = WireCorruption(jnp.asarray(mult), jnp.zeros(n, jnp.int32))
    guarded, stats = mix_schedule_arrays_screened(
        buf, arrays, delays, own, c, guard=True
    )
    assert np.isfinite(np.asarray(guarded)).all()
    # the finite plane marks exactly the edges that carried node 0
    per = np.asarray(arrays.perms)
    gam = np.asarray(arrays.gammas)
    fin = np.asarray(stats.finite)
    for l in range(per.shape[0]):
        for i in range(n):
            expect_bad = gam[l] != 0 and per[l, i] == 0 and i != 0
            if gam[l] != 0:
                assert fin[l, i] == (not expect_bad)
    # node 0's own row never sees its own wire (self-loops are clean)
    unguarded, _ = mix_schedule_arrays_screened(
        buf, arrays, delays, own, c, guard=False
    )
    u = np.asarray(unguarded)
    receivers = set()
    for l in range(per.shape[0]):
        if gam[l] == 0:
            continue
        for i in range(n):
            if per[l, i] == 0 and i != 0:
                receivers.add(i)
    for i in range(n):
        if i in receivers:
            assert np.isnan(u[i]).all()
        else:
            assert np.isfinite(u[i]).all()


# ------------------------------------- satellite 1: degrade edge cases


def test_degrade_schedule_single_survivor_exact_identity():
    n = 6
    arrays = _arrays(n)
    for survivor in (0, 3, n - 1):
        alive = np.zeros(n, dtype=bool)
        alive[survivor] = True
        deg = degrade_schedule(arrays, alive)
        W = _dense(deg)
        np.testing.assert_array_equal(W, np.eye(n))
        assert np.array_equal(
            np.asarray(deg.gammas), np.asarray(arrays.gammas)
        )


def test_degrade_schedule_all_offline_exact_identity():
    n = 5
    arrays = _arrays(n)
    W = _dense(degrade_schedule(arrays, np.zeros(n, dtype=bool)))
    np.testing.assert_array_equal(W, np.eye(n))


def test_degrade_pool_gammas_single_survivor_identity_mass():
    sched = schedule_from_matrix(0.6 * T.ring(6) + 0.4 * np.eye(6))
    pool = PermPool.from_schedule(sched, capacity=sched.n_atoms + 2)
    g, _dropped = pool.project(sched)
    off = np.ones(6, dtype=bool)
    off[2] = False  # a single survivor
    g2 = degrade_pool_gammas(pool, g, off)
    # every non-identity slot zeroed; total mass exactly preserved
    ident = pool.identity
    for l, p in enumerate(pool.perms):
        if p != ident:
            assert g2[l] == 0.0
    np.testing.assert_allclose(
        float(np.asarray(g2, np.float64).sum()),
        float(np.asarray(g, np.float64).sum()),
        rtol=1e-6,
    )


def test_degrade_pool_gammas_no_identity_slot_noop_repair():
    """The satellite-1 regression: a pool WITHOUT an identity slot must
    repair fine when no mass needs moving (the offline node is already a
    fixed point of every slot) -- and raise only when mass must move."""
    # one swap atom (0<->1), nodes 2,3 fixed; no identity slot staged
    pool = PermPool(perms=(((1, 0, 2, 3)),))
    g = np.asarray([1.0], np.float32)
    off = np.array([False, False, True, False])
    out = degrade_pool_gammas(pool, g, off)  # pre-fix: raised ValueError
    np.testing.assert_array_equal(out, g)
    with pytest.raises(ValueError, match="identity slot"):
        degrade_pool_gammas(pool, g, np.array([True, False, False, False]))


# ------------------------------------- satellite 2: fingerprint back-compat


_PINNED_FINGERPRINTS = [
    (
        dict(n_nodes=8, steps=40, seed=0, crash_rate=0.05, mean_outage=6.0),
        "6b4eb458c910a293c2d68835cd690d8a74e09db43ed67ad3649501a57e4382cd",
    ),
    (
        dict(n_nodes=6, steps=25, seed=3, edge_drop_rate=0.1),
        "9ba9601a7e68e271b31595239e521c899339670178392c6e472ca09b35276eb8",
    ),
    (
        dict(n_nodes=8, steps=60, seed=7, crash_rate=0.03, mean_outage=5.0,
             straggler_rate=0.2, tau_max=3, edge_drop_rate=0.05,
             solve_failure_rate=0.1, solve_hang_rate=0.05),
        "919b405cd86e52d5eeecccba6f13b44d9f85e36e6e44c5a511fd991075def5af",
    ),
    (
        dict(n_nodes=4, steps=10, seed=42),
        "7877cb996d82253d34936f67b37484b3cb439122ef88a2cc857f6bdf79f9de8c",
    ),
]


@pytest.mark.parametrize("kwargs,expected", _PINNED_FINGERPRINTS)
def test_fingerprint_backcompat_pinned(kwargs, expected):
    """Corruption-free plans fingerprint exactly as the pre-corruption
    release did -- the corruption planes only hash when present."""
    plan = FaultPlan(**kwargs)
    assert not plan.has_corruption
    assert plan.fingerprint() == expected


def test_fingerprint_changes_only_with_corruption():
    base = FaultPlan(n_nodes=6, steps=30, seed=1).fingerprint()
    assert FaultPlan(n_nodes=6, steps=30, seed=1).fingerprint() == base
    hot = FaultPlan(
        n_nodes=6, steps=30, seed=1, corrupt_rate=0.3, mean_corruption=4.0
    )
    assert hot.has_corruption
    assert hot.fingerprint() != base
    # scripted (post-edited) corruption is covered too -- has_corruption
    # checks the derived planes, not the config
    scripted = FaultPlan(n_nodes=6, steps=30, seed=1)
    scripted.corrupt_mult[10:, 2] = np.float32(-1.0)
    assert scripted.has_corruption
    assert scripted.fingerprint() != base
    assert scripted.fingerprint() != hot.fingerprint()


# --------------------------------------------- plan corruption generation


def test_corruption_trace_deterministic_and_mode_held_per_window():
    kw = dict(n_nodes=8, steps=200, seed=9, corrupt_rate=0.05,
              mean_corruption=6.0)
    a, b = FaultPlan(**kw), FaultPlan(**kw)
    assert np.array_equal(a.corrupt_mult, b.corrupt_mult, equal_nan=True)
    assert np.array_equal(a.corrupt_xor, b.corrupt_xor)
    assert a.has_corruption  # 8 nodes x 200 steps at 5% start rate
    bad = (a.corrupt_mult != np.float32(1.0)) | (a.corrupt_xor != 0)
    for i in range(8):
        t = 0
        while t < 200:
            if not bad[t, i]:
                t += 1
                continue
            # a contiguous window carries ONE (mult, xor) signature
            t0 = t
            while t < 200 and bad[t, i]:
                t += 1
            win_m = a.corrupt_mult[t0:t, i]
            win_x = a.corrupt_xor[t0:t, i]
            assert np.all(win_x == win_x[0])
            if np.isnan(win_m[0]):
                assert np.isnan(win_m).all()
            else:
                assert np.all(win_m == win_m[0])


def test_corruption_dead_nodes_forced_honest():
    plan = FaultPlan(
        n_nodes=8, steps=300, seed=4, crash_rate=0.1, mean_outage=8.0,
        corrupt_rate=0.5, mean_corruption=20.0,
    )
    dead = ~plan.alive
    assert dead.any()  # the scenario actually exercises the rule
    assert np.all(plan.corrupt_mult[dead] == np.float32(1.0))
    assert np.all(plan.corrupt_xor[dead] == 0)


def test_corruption_validation():
    with pytest.raises(ValueError, match="corrupt_rate"):
        FaultPlan(n_nodes=4, steps=10, seed=0, corrupt_rate=1.5)
    with pytest.raises(ValueError, match="mean_corruption"):
        FaultPlan(n_nodes=4, steps=10, seed=0, corrupt_rate=0.1,
                  mean_corruption=0.5)
    with pytest.raises(ValueError, match="corrupt_modes"):
        FaultPlan(n_nodes=4, steps=10, seed=0, corrupt_rate=0.1,
                  corrupt_modes=())
    with pytest.raises(ValueError, match="mode"):
        FaultPlan(n_nodes=4, steps=10, seed=0, corrupt_rate=0.1,
                  corrupt_modes=("scale:x",))


def test_quarantined_frac_closed_form_and_subset():
    n = 8
    plan = FaultPlan(n_nodes=n, steps=20, seed=0)
    none = np.zeros(n, dtype=bool)
    assert plan.quarantined_frac(3, none) == 0.0
    for h in (1, 2, 5):
        mask = np.zeros(n, dtype=bool)
        mask[:h] = True
        expect = 1.0 - (n - h) * (n - h - 1) / (n * (n - 1))
        np.testing.assert_allclose(
            plan.quarantined_frac(3, mask), expect, rtol=1e-12
        )
    # under edge drops the quarantined share can never exceed delivered
    drop = FaultPlan(n_nodes=n, steps=20, seed=1, edge_drop_rate=0.3)
    mask = np.zeros(n, dtype=bool)
    mask[:2] = True
    for t in range(20):
        assert drop.quarantined_frac(t, mask) <= drop.delivered_frac(t)
    with pytest.raises(ValueError):
        plan.quarantined_frac(0, np.zeros(n - 1, dtype=bool))


def test_injector_set_quarantine_isolates_and_streams_corruption():
    n = 6
    arrays = _arrays(n)
    plan = FaultPlan(n_nodes=n, steps=10, seed=0)
    plan.corrupt_mult[4:, 1] = np.float32(np.nan)
    inj = FaultInjector(plan, arrays)
    mask = np.zeros(n, dtype=bool)
    mask[1] = True
    inj.set_quarantine(mask)
    gam, per, _ = inj.stream(0, 10)
    for t in range(10):
        W = _dense(ScheduleArrays(
            gammas=jnp.asarray(gam[t]), perms=jnp.asarray(per[t])
        ))
        assert abs(W[1, 1] - 1.0) <= 1e-12
        assert np.abs(np.delete(W[1], 1)).max() == 0.0
        assert np.abs(np.delete(W[:, 1], 1)).max() == 0.0
        # doubly stochastic on the trusted support too
        assert np.abs(W.sum(axis=0) - 1.0).max() <= 1e-12
        assert np.abs(W.sum(axis=1) - 1.0).max() <= 1e-12
    mult, xor = inj.corrupt_stream(2, 5)
    assert np.array_equal(
        mult, plan.corrupt_mult[2:7], equal_nan=True
    )
    assert np.array_equal(xor, plan.corrupt_xor[2:7])
    with pytest.raises(ValueError):
        inj.set_quarantine(np.zeros(n - 1, dtype=bool))
    with pytest.raises(ValueError):
        inj.corrupt_stream(8, 5)  # past the end of the plan


# ------------------------------------------------- screen policy + screens


def test_screen_policy_validation():
    with pytest.raises(ValueError, match="slack"):
        ScreenPolicy(slack=0.5)
    with pytest.raises(ValueError, match="confirm_streak"):
        ScreenPolicy(confirm_streak=0)
    with pytest.raises(ValueError, match="cooldown"):
        ScreenPolicy(cooldown_steps=0)
    with pytest.raises(ValueError, match="abs_floor"):
        ScreenPolicy(abs_floor=-1.0)
    with pytest.raises(ValueError, match="tau_term"):
        ScreenPolicy(tau_term=-0.1)


def test_dev_allow_honest_bound_and_staleness_term():
    p = ScreenPolicy(slack=1.0, abs_floor=0.0)
    # fresh: exactly the triangle-inequality bound 2 sqrt(C)
    np.testing.assert_allclose(
        p.dev_allow(4.0, 0.0, 0.0, lr=0.1), 4.0, rtol=1e-12
    )
    # staleness widens the allowance by the mean-drift term
    stale = p.dev_allow(4.0, 1.0, 9.0, lr=0.1, tau_max=2)
    np.testing.assert_allclose(stale, 4.0 + 0.1 * 4 * (3.0 + 1.0),
                               rtol=1e-12)
    # tau_term is an operator knob on top
    wide = ScreenPolicy(slack=1.0, abs_floor=0.0, tau_term=2.0)
    np.testing.assert_allclose(
        wide.dev_allow(4.0, 0.0, 0.0, lr=0.1, tau_bar=1.5), 7.0, rtol=1e-12
    )


def _ring_tables(k: int, n: int):
    """k steps of a single ring atom at gamma 0.5 (every node exposed)."""
    gam = np.full((k, 1), 0.5, np.float32)
    per = np.tile(np.roll(np.arange(n), -1)[None, None, :], (k, 1, 1))
    return gam, per


def _stats_from_payloads(pay: np.ndarray, per: np.ndarray) -> ScreenStats:
    """Host-built ScreenStats for payloads (k, n, p) under tables per."""
    k, n, _ = pay.shape
    sq_own = np.sum(pay**2, axis=2)
    l_max = per.shape[1]
    sq_recv = np.zeros((k, l_max, n), np.float32)
    dot = np.zeros((k, l_max, n), np.float32)
    finite = np.ones((k, l_max, n), bool)
    for t in range(k):
        for l in range(l_max):
            src = per[t, l]
            sq_recv[t, l] = np.sum(pay[t, src] ** 2, axis=1)
            dot[t, l] = np.sum(pay[t, src] * pay[t], axis=1)
            finite[t, l] = np.isfinite(pay[t, src]).all(axis=1)
    return ScreenStats(sq_own=sq_own, sq_recv=sq_recv, dot=dot,
                       finite=finite)


def _probes_from_payloads(pay: np.ndarray) -> dict:
    dev = pay - pay.mean(axis=1, keepdims=True)
    cons = np.max(np.sum(dev**2, axis=2), axis=1)
    return {
        "consensus_sq": cons,
        "gdev_sq": np.zeros_like(cons),
        "gbar_sq": np.zeros_like(cons),
    }


def test_screen_zero_false_positives_on_heterogeneous_honest_payloads():
    """Any honest payload set, however skewed, stays under the
    probe-derived allowance -- the triangle-inequality guarantee."""
    n, p = 8, 3
    for seed in range(10):
        rng = np.random.default_rng(seed)
        # wildly heterogeneous: per-node offsets up to 100x the noise
        pay = (rng.normal(size=(5, n, p))
               * rng.uniform(0.01, 10.0, size=(1, n, 1))
               + rng.uniform(-50, 50, size=(1, n, 1))).astype(np.float32)
        gam, per = _ring_tables(5, n)
        qc = QuarantineController(n, ScreenPolicy(), lr=0.1)
        qc.ingest(0, _stats_from_payloads(pay, per), gam, per,
                  _probes_from_payloads(pay))
        assert qc.n_quarantines == 0, (seed, qc.summary())
        assert not qc.quarantined.any()


def test_quarantine_lifecycle_confirm_cooldown_probation_readmit():
    n = 4
    policy = ScreenPolicy(confirm_streak=3, cooldown_steps=4,
                          probation_steps=2)
    qc = QuarantineController(n, policy, lr=0.1)
    rng = np.random.default_rng(0)

    def seg(t0, k, liar=None):
        pay = rng.normal(size=(k, n, 2)).astype(np.float32)
        gam, per = _ring_tables(k, n)
        stats = _stats_from_payloads(pay, per)
        if liar is not None:
            fin = np.asarray(stats.finite).copy()
            src = per[0, 0]
            fin[:, 0, src == liar] = False  # liar's edges go non-finite
            stats = stats._replace(finite=fin)
        return qc.ingest(t0, stats, gam, per, _probes_from_payloads(pay))

    # 2 flagged steps < confirm_streak=3: still trusted
    seg(0, 2, liar=1)
    assert not qc.quarantined.any() and qc._streak[1] == 2
    # a clean exposed step resets the streak (one glitch never confirms)
    seg(2, 1)
    assert qc._streak[1] == 0
    # 3 consecutive flags: quarantined at t = 3 + 2
    mask = seg(3, 3, liar=1)
    assert mask[1] and qc.n_quarantines == 1
    assert qc.events[-1] == {
        "t": 5, "node": 1, "event": "quarantine", "reason": "confirmed",
        "cooldown": 4,
    }
    # cooldown ticks per STEP; the 4th clean step (t=9) releases node 1
    # to probation and, being itself clean and exposed, burns the first
    # of the 2 probation steps
    seg(6, 4)
    assert not qc.quarantined[1] and qc._probation[1] == 1
    assert qc.events[-1] == {"t": 9, "node": 1, "event": "probation"}
    seg(10, 2)
    assert qc.n_readmissions == 1
    assert qc.events[-1]["event"] == "readmitted"
    assert qc._cooldown_len[1] == 4  # backoff reset on clean re-admission


def test_quarantine_probation_relapse_doubles_cooldown():
    n = 4
    policy = ScreenPolicy(confirm_streak=1, cooldown_steps=2,
                          probation_steps=3)
    qc = QuarantineController(n, policy, lr=0.1)
    rng = np.random.default_rng(1)

    def seg(t0, k, liar=None):
        pay = rng.normal(size=(k, n, 2)).astype(np.float32)
        gam, per = _ring_tables(k, n)
        stats = _stats_from_payloads(pay, per)
        if liar is not None:
            fin = np.asarray(stats.finite).copy()
            fin[:, 0, per[0, 0] == liar] = False
            stats = stats._replace(finite=fin)
        return qc.ingest(t0, stats, gam, per, _probes_from_payloads(pay))

    seg(0, 1, liar=2)  # confirm_streak=1: instant quarantine, cooldown 2
    assert qc.quarantined[2]
    seg(1, 2)  # cooldown burns; node 2 released to probation
    assert not qc.quarantined[2] and qc._probation[2] > 0
    seg(3, 1, liar=2)  # relapse ON probation: cooldown doubled
    assert qc.quarantined[2]
    assert qc.events[-1]["reason"] == "probation_flag"
    assert qc.events[-1]["cooldown"] == 4
    assert qc._cooldown_len[2] == 4


def test_quarantine_chains_inner_controller():
    """observe() masks quarantined rows; transitions request refreshes
    with a recorded reason (duck-typed inner)."""

    class Inner:
        def __init__(self):
            self.reasons, self.batches = [], []

        def observe(self, labels):
            self.batches.append(np.asarray(labels).copy())

        def request_refresh(self, reason=None):
            self.reasons.append(reason)

        def on_segment(self, t):
            return None

    n = 4
    inner = Inner()
    policy = ScreenPolicy(confirm_streak=1, cooldown_steps=1,
                          probation_steps=1)
    qc = QuarantineController(n, policy, lr=0.1, inner=inner)
    labels = np.arange(n * 3).reshape(n, 3) % 4
    qc.observe(labels)
    assert np.array_equal(inner.batches[-1], labels)  # nobody masked
    rng = np.random.default_rng(2)
    gam, per = _ring_tables(1, n)
    pay = rng.normal(size=(1, n, 2)).astype(np.float32)
    stats = _stats_from_payloads(pay, per)
    fin = np.asarray(stats.finite).copy()
    fin[:, 0, per[0, 0] == 3] = False
    qc.ingest(0, stats._replace(finite=fin), gam, per,
              _probes_from_payloads(pay))
    assert inner.reasons == ["quarantine"]
    qc.observe(labels)
    assert np.all(inner.batches[-1][3] == -1)  # quarantined row absent
    assert np.array_equal(inner.batches[-1][:3], labels[:3])
    # cooldown 1 -> probation, 1 clean exposed step -> readmitted
    pay = rng.normal(size=(2, n, 2)).astype(np.float32)
    gam, per = _ring_tables(2, n)
    qc.ingest(1, _stats_from_payloads(pay, per), gam, per,
              _probes_from_payloads(pay))
    assert inner.reasons == ["quarantine", "readmitted"]
    assert qc.on_segment(0) is None  # delegation is a no-op passthrough


def test_false_quarantines_audit():
    plan = FaultPlan(n_nodes=4, steps=50, seed=0)
    plan.corrupt_mult[10:20, 1] = np.float32(np.nan)
    events = [
        {"t": 12, "node": 1, "event": "quarantine"},  # true positive
        {"t": 22, "node": 1, "event": "quarantine"},  # lookback: still TP
        {"t": 12, "node": 2, "event": "quarantine"},  # node 2 was honest
        {"t": 30, "node": 1, "event": "probation"},   # not a quarantine
    ]
    assert false_quarantines(events, plan) == 1


# -------------------------- satellite 3: estimator re-admission plumbing


def test_mask_absent_shapes_and_passthrough():
    labels = np.arange(8).reshape(4, 2) % 3
    none = np.zeros(4, dtype=bool)
    assert mask_absent(labels, none) is labels  # no copy when untouched
    mask = np.array([False, True, False, False])
    out = mask_absent(labels, mask)
    assert np.all(out[1] == -1) and np.array_equal(out[[0, 2, 3]],
                                                   labels[[0, 2, 3]])
    assert np.array_equal(labels[1], np.array([2, 0]))  # input untouched
    with pytest.raises(ValueError):
        mask_absent(labels, np.zeros(3, dtype=bool))
    # 1-D labels promote to a column
    assert mask_absent(np.array([0, 1, 2]), np.zeros(3, bool)).shape == (3, 1)


def test_estimator_holds_quarantined_row_and_snaps_on_rejoin():
    rng = np.random.default_rng(5)
    n, K = 4, 3
    est = StreamingPiEstimator(n, K, beta=0.05, rejoin_beta=0.9)
    for _ in range(20):
        est.update(rng.integers(0, K, size=(n, 8)))
    held = est.Pi_hat[2].copy()
    mask = np.array([False, False, True, False])
    # quarantined: the masked row is held EXACTLY, absent_streak counts
    for j in range(6):
        est.update(mask_absent(rng.integers(0, K, size=(n, 8)), mask))
        assert np.array_equal(est.Pi_hat[2], held)
        assert est.absent_streak[2] == j + 1
    others = est.Pi_hat[[0, 1, 3]].copy()
    # re-admitted: rejoin_beta snaps the stale row toward the fresh
    # batch in ONE update; the honest rows keep their slow beta
    batch = rng.integers(0, K, size=(n, 8))
    est.update(batch)
    freq2 = np.bincount(batch[2], minlength=K) / batch.shape[1]
    np.testing.assert_allclose(
        est.Pi_hat[2], 0.1 * held + 0.9 * freq2, atol=1e-12
    )
    assert est.absent_streak[2] == 0
    for r, i in zip(others, (0, 1, 3)):
        freq = np.bincount(batch[i], minlength=K) / batch.shape[1]
        np.testing.assert_allclose(
            est.Pi_hat[i], 0.95 * r + 0.05 * freq, atol=1e-12
        )


# ---------------------------------------------------- meter + report


def test_comm_meter_quarantined_fate():
    m = CommMeter(per_step_bytes=1000)
    m.tick(4, delivered_frac=0.8, quarantined_frac=0.2)
    s = m.summary()
    assert s["total_bytes"] == 3200
    # derived from the truncated delivered volume: subset by construction
    assert s["quarantined_bytes"] == int(3200 * (0.2 / 0.8))
    m.tick(2, delivered_frac=1.0)  # default: no quarantine share
    assert m.summary()["quarantined_bytes"] == s["quarantined_bytes"]
    with pytest.raises(ValueError):
        m.tick(1, delivered_frac=0.5, quarantined_frac=0.6)
    with pytest.raises(ValueError):
        m.tick(1, delivered_frac=1.0, quarantined_frac=-0.1)


def test_report_quarantine_block_roundtrip(tmp_path):
    rep = RunReport("q")
    m = CommMeter(per_step_bytes=10)
    m.tick(10, delivered_frac=1.0, quarantined_frac=0.3)
    rep.add_comm(m)
    rep.add_quarantine({
        "n_quarantines": 2, "n_readmissions": 1, "quarantined_now": [3],
        "events": [
            {"t": 5, "node": 3, "event": "quarantine",
             "reason": "confirmed", "cooldown": 32},
            {"t": 40, "node": 3, "event": "probation"},
            {"t": 44, "node": 3, "event": "readmitted"},
        ],
    })
    doc = rep.to_dict()
    validate_report(doc)
    assert doc["quarantine"]["version"] == 1
    paths = rep.write(str(tmp_path), stem="report")
    loaded = load_report(paths["json"])
    assert loaded["quarantine"] == doc["quarantine"]
    assert loaded["comm"]["quarantined_bytes"] == 30
    md = rep.to_markdown()
    assert "quarantined" in md
    # the block stays optional: a PR 9-era report still validates
    old = RunReport("old")
    assert "quarantine" not in old.to_dict()
    validate_report(old.to_dict())
    # and a malformed block is rejected
    bad = dict(doc)
    bad["quarantine"] = dict(doc["quarantine"])
    bad["quarantine"]["events"] = [{"t": 1, "node": 0, "event": "exiled"}]
    with pytest.raises(ValueError):
        validate_report(bad)
    bad["quarantine"] = {"version": 1, "n_quarantines": -1,
                         "n_readmissions": 0, "quarantined_now": [],
                         "events": []}
    with pytest.raises(ValueError):
        validate_report(bad)


# ------------------------------------------------------ runner integration


@pytest.fixture(scope="module")
def corr_problem():
    n, K, steps = 6, 3, 60
    task = mean_estimation_clusters(n_nodes=n, K=K, m=3.0, sigma_tilde2=0.5)
    arrays = _arrays(n)
    rng = np.random.default_rng(8)
    zs = np.stack([task.sample(2, rng) for _ in range(steps)]).astype(
        np.float32
    )
    return task, arrays, zs, steps


def test_runner_corruption_off_routes_to_plain_scan(corr_problem):
    """Clean plan + no controller: the PRIOR scan body compiles and the
    trajectory is bitwise the fault-free driver's. Clean plan + a
    controller: the screened body runs, quarantines nobody, and the
    trajectory is STILL bitwise."""
    task, arrays, zs, steps = corr_problem
    plan = FaultPlan(n_nodes=task.n_nodes, steps=steps, seed=0)
    kw = dict(lr=0.05, seed=2, zs=zs, segment_len=15)
    base = run_faulty_mean_estimation(task, plan, arrays, **kw)
    assert base["n_traces"] == 1
    assert base["sq_error_nodes"] is None  # unscreened body: no per-node
    assert base["quarantine"] is None
    qc = QuarantineController(task.n_nodes, ScreenPolicy(), lr=0.05)
    screened = run_faulty_mean_estimation(
        task, plan, arrays, quarantine=qc, **kw
    )
    assert screened["n_traces"] == 1
    assert np.array_equal(
        screened["mean_sq_error"], base["mean_sq_error"]
    )
    assert qc.n_quarantines == 0
    assert screened["sq_error_nodes"].shape == (steps, task.n_nodes)
    assert screened["comm"]["quarantined_bytes"] == 0
    assert screened["quarantine"]["n_quarantines"] == 0


def test_runner_quarantines_nan_sender_single_trace(corr_problem):
    task, arrays, zs, steps = corr_problem
    n = task.n_nodes
    plan = FaultPlan(n_nodes=n, steps=steps, seed=0)
    plan.corrupt_mult[4:, 2] = np.float32(np.nan)
    policy = ScreenPolicy(confirm_streak=2, cooldown_steps=2 * steps)
    qc = QuarantineController(n, policy, lr=0.05)
    out = run_faulty_mean_estimation(
        task, plan, arrays, quarantine=qc, lr=0.05, seed=2, zs=zs,
        segment_len=15,
    )
    assert out["n_traces"] == 1  # quarantine mask swaps never retrace
    ev = [e for e in qc.events if e["event"] == "quarantine"]
    assert ev and ev[0]["node"] == 2
    assert ev[0]["t"] == 4 + policy.confirm_streak - 1
    assert false_quarantines(qc.events, plan) == 0
    assert qc.quarantined[2]
    comm = out["comm"]
    assert 0 < comm["quarantined_bytes"] <= comm["total_bytes"]
    # the mask lands on the segment AFTER confirmation (trace-immutable):
    # replaying the meter with the closed-form per-segment shares --
    # zero for segment 0, the h=1 pair count afterwards -- reproduces
    # the charged bytes exactly
    mask = np.zeros(n, dtype=bool)
    mask[2] = True
    replay = CommMeter(per_step_bytes=comm["per_step_bytes"])
    for ts in range(0, steps, 15):
        qf = float(np.mean([
            plan.quarantined_frac(t, mask) for t in range(ts, ts + 15)
        ])) if ts >= 15 else 0.0
        frac = float(np.mean([
            plan.delivered_frac(t) for t in range(ts, ts + 15)
        ]))
        replay.tick(15, delivered_frac=frac, quarantined_frac=qf)
    assert comm["quarantined_bytes"] == replay.summary()["quarantined_bytes"]
    # honest trajectory stays finite under the guard
    assert np.isfinite(out["mean_sq_error"]).all()


def test_runner_self_heals_after_corruption_window(corr_problem):
    """A liar that STOPS lying is re-admitted within the run and stays
    trusted afterwards."""
    task, arrays, zs, steps = corr_problem
    n = task.n_nodes
    plan = FaultPlan(n_nodes=n, steps=steps, seed=0)
    plan.corrupt_mult[5:12, 1] = np.float32(np.nan)
    policy = ScreenPolicy(confirm_streak=2, cooldown_steps=10,
                          probation_steps=4)
    qc = QuarantineController(n, policy, lr=0.05)
    out = run_faulty_mean_estimation(
        task, plan, arrays, quarantine=qc, lr=0.05, seed=2, zs=zs,
        segment_len=10,
    )
    assert out["n_traces"] == 1
    kinds = [e["event"] for e in qc.events if e["node"] == 1]
    assert kinds[:3] == ["quarantine", "probation", "readmitted"]
    assert qc.n_readmissions == 1
    assert not qc.quarantined.any()  # fully healed by the end
    assert false_quarantines(qc.events, plan) == 0
    summary = out["quarantine"]
    assert summary["n_readmissions"] == 1
    assert summary["quarantined_now"] == []


def test_runner_corrupting_plan_without_controller_is_screen_off(
    corr_problem,
):
    """plan.has_corruption alone routes to the screened body with the
    guard OFF: the NaN propagates (the honest divergence baseline) and
    nothing is quarantined or metered."""
    task, arrays, zs, steps = corr_problem
    plan = FaultPlan(n_nodes=task.n_nodes, steps=steps, seed=0)
    plan.corrupt_mult[4:, 2] = np.float32(np.nan)
    out = run_faulty_mean_estimation(
        task, plan, arrays, lr=0.05, seed=2, zs=zs, segment_len=15,
    )
    assert out["n_traces"] == 1
    assert out["quarantine"] is None
    assert out["comm"]["quarantined_bytes"] == 0
    assert np.isnan(out["mean_sq_error"][-1])  # poison spread unchecked
    assert out["sq_error_nodes"] is not None  # screened body ran

"""Compiled auction LMO (`repro.core.assignment_jit`) vs the references.

The jitted engine must match the numpy solvers' contract exactly: same
achieved objective on every input (assignments may differ under exact
ties), same error behavior on malformed input, same warm-start
semantics, and identical `learn_topology` trajectories on generic Pi --
the 1e-12-relative quantization grid plus the duality-gap certificate
make every backend solve the same discretized problem exactly.

Compilation note: the engine compiles once per (n, variant, validate)
via an lru_cache, so the tests deliberately reuse a small set of sizes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.assignment import (
    auction_assignment,
    hungarian,
    linear_assignment,
    solve_lmo,
)
from repro.core.assignment_jit import (
    AuctionJitState,
    auction_assignment_jit,
)
from repro.core.stl_fw import LMOSolver, learn_topology, resolve_lmo_backend


def _obj(cost, col):
    return float(cost[np.arange(len(col)), col].sum())


def _assert_perm(col, n):
    assert sorted(int(c) for c in col) == list(range(n))


VARIANTS = ("forward", "forward_reverse")


# ---------------------------------------------------------------------------
# degenerate shapes and values (same cases as the numpy solvers)
# ---------------------------------------------------------------------------

def test_n0_and_n1():
    col, state = auction_assignment_jit(np.empty((0, 0)))
    assert col.shape == (0,)
    col, state = auction_assignment_jit(np.array([[3.7]]))
    assert list(col) == [0]


@pytest.mark.parametrize("variant", VARIANTS)
def test_all_equal_costs(variant):
    """Fully tied problem: any permutation is optimal; must terminate."""
    for n in (2, 6):
        cost = np.full((n, n), 2.5)
        col, _ = auction_assignment_jit(cost, variant=variant)
        _assert_perm(col, n)
        assert _obj(cost, col) == pytest.approx(2.5 * n)


def test_nonsquare_raises():
    with pytest.raises(ValueError):
        auction_assignment_jit(np.zeros((3, 4)))
    with pytest.raises(ValueError):
        auction_assignment_jit(np.zeros(3))


def test_bad_args_raise():
    with pytest.raises(ValueError):
        auction_assignment_jit(np.eye(3), variant="sideways")
    with pytest.raises(ValueError):
        auction_assignment_jit(np.eye(3), scaling=0.5)


def test_forbidden_entries_feasible():
    cost = np.array([
        [np.inf, 1.0, 4.0],
        [2.0, np.inf, 6.0],
        [3.0, 8.0, np.inf],
    ])
    col, _ = auction_assignment_jit(cost)
    _assert_perm(col, 3)
    assert _obj(cost, col) == pytest.approx(1.0 + 3.0 + 6.0)


def test_forbidden_entries_infeasible():
    cost = np.array([
        [1.0, np.inf, np.inf],
        [1.0, np.inf, np.inf],
        [1.0, 1.0, 1.0],
    ])
    with pytest.raises(ValueError):
        auction_assignment_jit(cost)


def test_fully_forbidden_row_raises():
    cost = np.ones((3, 3))
    cost[1] = np.inf
    with pytest.raises(ValueError):
        auction_assignment_jit(cost)


def test_nan_and_neginf_rejected():
    for bad in (np.nan, -np.inf):
        cost = np.ones((3, 3))
        cost[1, 2] = bad
        with pytest.raises(ValueError):
            auction_assignment_jit(cost)


def test_forbidden_entries_do_not_coarsen_quantization():
    """The +inf sentinel is ~(n+1)x the finite costs; the in-core grid
    must be derived from the finite entries only (mirrors the numpy
    solver's scale_source handling)."""
    rng = np.random.default_rng(11)
    n = 48
    cost = rng.normal(size=(n, n))
    forbidden = rng.random((n, n)) < 0.02
    forbidden[np.arange(n), linear_assignment(cost)] = False  # stay feasible
    cost[forbidden] = np.inf
    col, _ = auction_assignment_jit(cost)
    ref = linear_assignment(cost)
    assert abs(_obj(cost, col) - _obj(cost, ref)) < 1e-9


# ---------------------------------------------------------------------------
# solver agreement (property test via the hypothesis shim)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([2, 3, 6, 16]), st.integers(0, 100_000))
def test_agrees_with_references_on_generic(n, seed):
    # gs_threshold=2 forces the bucketed Jacobi rounds (and, for the
    # forward_reverse variant, the reverse column-bid rounds) to carry
    # the bidding -- the CPU default (threshold=n) would drain
    # everything through Gauss-Seidel and leave those paths untested.
    rng = np.random.default_rng(seed)
    cost = rng.normal(size=(n, n)) * 10.0 ** rng.integers(-6, 6)
    ref = _obj(cost, linear_assignment(cost))
    scale = max(1.0, abs(ref))
    for variant in VARIANTS:
        for gs_threshold in (2, None):
            col, _ = auction_assignment_jit(
                cost, variant=variant, gs_threshold=gs_threshold
            )
            _assert_perm(col, n)
            assert abs(_obj(cost, col) - ref) <= 1e-9 * scale, (variant, gs_threshold)
    assert abs(_obj(cost, hungarian(cost)) - ref) <= 1e-9 * scale
    assert abs(_obj(cost, auction_assignment(cost)[0]) - ref) <= 1e-9 * scale


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([3, 6, 16]), st.integers(0, 10_000))
def test_agrees_on_tied_integer_costs(n, seed):
    """Small-integer costs produce many exact ties AND exercise the
    adaptive schedule's stagnation rescue (fixed-large-scaling auctions
    price-war on these)."""
    rng = np.random.default_rng(seed)
    cost = rng.integers(0, 3, size=(n, n)).astype(np.float64)
    ref = _obj(cost, linear_assignment(cost))
    col, _ = auction_assignment_jit(cost)
    assert _obj(cost, col) == pytest.approx(ref, abs=1e-9)


def test_near_duplicate_row_label_skew_instances():
    """The instance family the FW LMO actually sees: Gram matrices of
    label-skew Pi with near-duplicate rows (long eviction chains)."""
    rng = np.random.default_rng(5)
    n, K = 48, 8
    Pi = rng.dirichlet(np.ones(K) * 0.1, size=n)
    Pi[n // 2:] = np.maximum(Pi[: n // 2] + rng.normal(size=(n // 2, K)) * 1e-9, 1e-12)
    G = -(Pi @ Pi.T)
    ref = _obj(G, linear_assignment(G))
    # gs_threshold=16 keeps the Jacobi (and reverse) rounds in play on
    # the long eviction chains these instances produce
    for variant in VARIANTS:
        col, state = auction_assignment_jit(G, variant=variant, gs_threshold=16)
        _assert_perm(col, n)
        assert abs(_obj(G, col) - ref) <= 1e-9 * max(1.0, abs(ref)), variant
        assert state.n_rounds > 0


def test_validate_false_fast_path_matches():
    rng = np.random.default_rng(9)
    cost = rng.normal(size=(24, 24))
    col_v, _ = auction_assignment_jit(cost, validate=True)
    col_f, _ = auction_assignment_jit(cost, validate=False)
    assert _obj(cost, col_v) == pytest.approx(_obj(cost, col_f), abs=1e-12)


# ---------------------------------------------------------------------------
# warm start: state threading, deferred contraction, fast path
# ---------------------------------------------------------------------------

def test_warm_start_exact_after_perturbation():
    rng = np.random.default_rng(3)
    n = 48
    cost = rng.normal(size=(n, n))
    col, state = auction_assignment_jit(cost)
    for it in range(5):
        gamma = 1.0 / (it + 2)
        cost = (1.0 - gamma) * cost + gamma * rng.normal(size=(n, n))
        col, state = auction_assignment_jit(cost, state.scaled(1.0 - gamma))
        _assert_perm(col, n)
        ref = linear_assignment(cost)
        assert _obj(cost, col) == pytest.approx(_obj(cost, ref), abs=1e-9)


def test_warm_fast_path_identical_cost():
    """When the carried duals still certify optimality (duality gap below
    the grid at the warm check), the re-solve does zero bidding. The gap
    certificate only fires when the previous ladder ended gap-certified
    -- true for this instance (and for the numpy solver's equivalent
    test instance), not universally."""
    rng = np.random.default_rng(3)
    cost = rng.normal(size=(32, 32))
    col, state = auction_assignment_jit(cost)
    col2, state2 = auction_assignment_jit(cost, state)
    assert np.array_equal(col, col2)
    assert state2.n_rounds == 0 and state2.n_rebid_rows == 0


def test_warm_resolve_cheap_on_identical_cost():
    """Even without the certificate firing, re-solving an identical cost
    must only do a small cleanup, never a full reassignment."""
    rng = np.random.default_rng(4)
    n = 32
    cost = rng.normal(size=(n, n))
    col, state = auction_assignment_jit(cost)
    col2, state2 = auction_assignment_jit(cost, state)
    assert _obj(cost, col2) == pytest.approx(_obj(cost, col), abs=1e-12)
    assert state2.n_rounds < n * 4


def test_scaled_defers_contraction():
    st_ = AuctionJitState(
        prices=np.array([1.0, -2.0]), col_of_row=np.array([1, 0])
    )
    out = st_.scaled(0.5).scaled(0.5)
    np.testing.assert_allclose(np.asarray(out.prices), [1.0, -2.0])  # untouched
    assert out.pending_scale == pytest.approx(0.25)
    assert np.array_equal(out.col_of_row, st_.col_of_row)


def test_ignores_malformed_warm_state():
    rng = np.random.default_rng(5)
    cost = rng.normal(size=(10, 10))
    ref = linear_assignment(cost)
    bad_states = [
        AuctionJitState(prices=np.zeros(4), col_of_row=np.zeros(4, np.int64)),
        AuctionJitState(
            prices=np.zeros(10),
            col_of_row=np.array([0, 1, 2, 3, 4, 5, 6, 7, 8, 15]),
        ),
        AuctionJitState(prices=np.full(10, np.inf), col_of_row=np.arange(10)),
        # prices from a wildly differently-scaled problem: must fall back
        # to a cold solve instead of bidding the 1e6 spread down eps-wise
        AuctionJitState(prices=rng.normal(size=10) * 1e6, col_of_row=np.arange(10)),
        # non-finite pending contraction
        AuctionJitState(
            prices=np.zeros(10), col_of_row=np.arange(10), pending_scale=np.nan
        ),
    ]
    for bad in bad_states:
        col, _ = auction_assignment_jit(cost, bad)
        assert _obj(cost, col) == pytest.approx(_obj(cost, ref), abs=1e-12)


# ---------------------------------------------------------------------------
# learn_topology integration: trajectory equivalence + backend resolution
# ---------------------------------------------------------------------------

def test_resolve_backend_auction_jit():
    assert resolve_lmo_backend("auction_jit") == "auction_jit"
    assert resolve_lmo_backend("auto") in ("scipy", "auction", "auction_jit")
    # auto must pick a winner consistently for a known-big problem
    big = resolve_lmo_backend("auto", n=2048, budget=64)
    assert big in ("scipy", "auction_jit")
    with pytest.raises(ValueError):
        resolve_lmo_backend("jit")


def test_solve_lmo_auction_jit_backend():
    rng = np.random.default_rng(6)
    grad = rng.normal(size=(12, 12))
    ref_P, _ = solve_lmo(grad)
    P, col = solve_lmo(grad, backend="auction_jit")
    assert float((P * grad).sum()) == pytest.approx(
        float((ref_P * grad).sum()), abs=1e-12
    )


@pytest.mark.parametrize("method", ["incremental", "reference"])
def test_learn_topology_jit_matches_scipy_traces(method):
    """Generic random Pi: the optimum is unique at the quantization grid,
    so the compiled auction must reproduce the reference FW trajectory."""
    rng = np.random.default_rng(7)
    Pi = rng.dirichlet(np.ones(6) * 0.3, size=36)
    ref = learn_topology(Pi, budget=12, lam=0.2, method=method, lmo="scipy")
    jit = learn_topology(Pi, budget=12, lam=0.2, method=method, lmo="auction_jit")
    np.testing.assert_allclose(jit.objective_trace, ref.objective_trace, atol=1e-9)
    np.testing.assert_allclose(jit.gamma_trace, ref.gamma_trace, atol=1e-9)
    assert jit.lmo_backend == "auction_jit"


def test_learn_topology_warm_trajectory_matches_numpy_auction():
    """Warm-start-across-FW-steps equivalence: the compiled engine and
    the numpy auction carry dual prices through the same contraction
    schedule and must produce identical trajectories (both are exact on
    the shared grid; generic Pi keeps the optima unique)."""
    rng = np.random.default_rng(17)
    Pi = rng.dirichlet(np.ones(5) * 0.2, size=40)
    a = learn_topology(Pi, budget=16, lam=0.1, lmo="auction")
    b = learn_topology(Pi, budget=16, lam=0.1, lmo="auction_jit")
    np.testing.assert_allclose(b.objective_trace, a.objective_trace, atol=1e-9)
    np.testing.assert_allclose(b.gamma_trace, a.gamma_trace, atol=1e-9)
    # warm state actually threads: the solver ends with a live jit state
    solver = LMOSolver("auction_jit")
    res = learn_topology(Pi, budget=6, lam=0.1, lmo=solver)
    assert solver.state is not None and solver.state.col_of_row.shape == (40,)
    assert res.lmo_backend == "auction_jit"


def test_learn_topology_one_hot_all_backends():
    """Structured one-hot Pi (exactly tied LMO optima): the compiled
    backend must still eliminate bias by l = K - 1 and keep the
    objective monotone, like every other backend."""
    K, n = 5, 30
    Pi = np.zeros((n, K))
    Pi[np.arange(n), np.arange(n) % K] = 1.0
    res = learn_topology(Pi, budget=K - 1, lam=0.5, lmo="auction_jit")
    assert res.bias_trace[-1] < 1e-12
    assert np.all(np.diff(res.objective_trace) <= 1e-12)

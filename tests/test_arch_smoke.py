"""Per-architecture smoke tests (required deliverable f).

For each assigned architecture: instantiate the REDUCED same-family config
(<= 3 layers, d_model <= 512, <= 4 experts) and run one forward + one train
step on CPU, asserting output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (
    active_param_count,
    init_model,
    loss_fn,
    make_inputs,
    model_forward,
    param_count,
)

ARCHS = list(ARCH_IDS)


@pytest.fixture(scope="module")
def smoke_state():
    state = {}
    for name in ARCHS:
        cfg = get_smoke_config(name)
        params = init_model(jax.random.PRNGKey(0), cfg)
        state[name] = (cfg, params)
    return state


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_config_limits(name):
    cfg = get_smoke_config(name)
    assert cfg.num_layers <= 3
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name, smoke_state):
    cfg, params = smoke_state[name]
    B, S = 2, 32
    batch = make_inputs(cfg, batch_size=B, seq_len=S)
    logits, _, aux = model_forward(params, cfg, batch)
    S_total = batch["tokens"].shape[1]
    if cfg.arch_type == "vlm":
        S_total += cfg.vision.num_patches
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step_no_nans(name, smoke_state):
    cfg, params = smoke_state[name]
    batch = make_inputs(cfg, batch_size=2, seq_len=32)

    def loss(p):
        return loss_fn(p, cfg, batch)[0]

    l0, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    # SGD step
    new_params = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, grads)
    l1 = loss(new_params)
    assert bool(jnp.isfinite(l1))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(name)
    expected = {
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if name == "qwen3-moe-30b-a3b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (128, 8)
    if name == "deepseek-v2-236b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (160, 6)
        assert cfg.mla.kv_lora_rank == 512
        assert cfg.moe.num_shared_experts == 2
    if name == "gemma2-2b":
        assert cfg.layer_pattern == ("local_attn", "attn")
        assert cfg.final_logit_softcap == 30.0
    if name == "recurrentgemma-2b":
        assert cfg.layer_pattern == ("rglru", "rglru", "local_attn")


def test_moe_active_params_fraction():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    total = param_count(params)
    active = active_param_count(params, cfg)
    assert active < total  # top-2 of 4 experts -> routed params halved

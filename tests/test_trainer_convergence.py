"""System behaviour: D-SGD converges, topology ranking matches the paper."""

import numpy as np
import pytest

from repro.core import topology as T
from repro.core.stl_fw import learn_topology
from repro.data.partition import cluster_partition, shard_partition
from repro.data.synthetic import gaussian_blobs, mean_estimation_clusters
from repro.train.trainer import run_classification, run_mean_estimation


def test_mean_estimation_converges_on_complete_graph():
    task = mean_estimation_clusters(n_nodes=20, K=4, m=2.0)
    out = run_mean_estimation(task, T.complete(20), steps=80, lr=0.2, seed=0)
    assert out["mean_sq_error"][-1] < 0.05


def test_stl_fw_beats_random_under_heterogeneity():
    """Fig 1(b,c): same budget, STL-FW converges much closer to theta*."""
    task = mean_estimation_clusters(n_nodes=40, K=10, m=5.0)
    res = learn_topology(task.Pi, budget=9, lam=0.5)
    Wr = T.random_d_regular(40, 9, seed=0)
    out_stl = run_mean_estimation(task, res.W, steps=60, lr=0.2, seed=0)
    out_rnd = run_mean_estimation(task, Wr, steps=60, lr=0.2, seed=0)
    assert out_stl["mean_sq_error"][-1] < 0.5 * out_rnd["mean_sq_error"][-1]


def test_stl_fw_insensitive_to_heterogeneity_at_full_budget():
    """With d_max = K-1, STL-FW's error must not grow with m."""
    errs = []
    for m in (0.0, 10.0):
        task = mean_estimation_clusters(n_nodes=40, K=10, m=m)
        res = learn_topology(task.Pi, budget=9, lam=0.5)
        out = run_mean_estimation(task, res.W, steps=60, lr=0.2, seed=0)
        errs.append(out["mean_sq_error"][-1])
    assert errs[1] < 3.0 * max(errs[0], 1e-3)


def test_classification_accuracy_improves():
    X, y = gaussian_blobs(n_samples=3000, num_classes=10, dim=32, seed=1)
    idx, Pi = shard_partition(y, 20, seed=0)
    res = learn_topology(Pi, budget=5, lam=0.1)
    log = run_classification(
        X, y, idx, res.W, steps=80, batch_size=32, lr=0.5,
        eval_every=79, X_test=X[:500], y_test=y[:500],
    )
    final = [r for r in log.history if "acc_mean" in r][-1]
    assert final["acc_mean"] > 0.6
    # consensus should be finite and small-ish relative to param scale
    assert np.isfinite(final["consensus"])


def test_kernel_transport_equals_einsum_training():
    """D-SGD trained through the Pallas gossip kernel matches the einsum
    transport trajectory."""
    task = mean_estimation_clusters(n_nodes=8, K=4, m=2.0)
    W = T.ring(8)
    a = run_mean_estimation(task, W, steps=10, lr=0.2, seed=0, use_kernel=False)
    b = run_mean_estimation(task, W, steps=10, lr=0.2, seed=0, use_kernel=True)
    np.testing.assert_allclose(a["theta"], b["theta"], atol=1e-5)

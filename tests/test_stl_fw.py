import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core.assignment import (
    assignment_to_permutation,
    hungarian,
    linear_assignment,
)
from repro.core.stl_fw import (
    fw_upper_bound,
    learn_topology,
    line_search_gamma,
    stl_fw_gradient,
    stl_fw_objective,
)


def one_hot_pi(n, K):
    Pi = np.zeros((n, K))
    Pi[np.arange(n), np.arange(n) % K] = 1.0
    return Pi


def random_pi(n, K, seed):
    rng = np.random.default_rng(seed)
    Pi = rng.dirichlet(0.3 * np.ones(K), size=n)
    return Pi


# ---------------------------------------------------------------------------
# assignment / LMO
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(0, 10_000))
def test_hungarian_matches_scipy(n, seed):
    rng = np.random.default_rng(seed)
    cost = rng.normal(size=(n, n))
    ours = hungarian(cost)
    ref = linear_assignment(cost)  # scipy when available
    assert cost[np.arange(n), ours].sum() == pytest.approx(
        cost[np.arange(n), ref].sum(), abs=1e-9
    )


def test_assignment_to_permutation():
    perm = np.array([2, 0, 1])
    P = assignment_to_permutation(perm)
    assert P.sum() == 3 and np.all(P.sum(0) == 1) and np.all(P.sum(1) == 1)
    assert P[0, 2] == 1.0


# ---------------------------------------------------------------------------
# objective / gradient / line search
# ---------------------------------------------------------------------------

def test_gradient_matches_finite_differences():
    rng = np.random.default_rng(0)
    n, K, lam = 6, 3, 0.7
    Pi = random_pi(n, K, 1)
    W = T.ring(n)
    G = stl_fw_gradient(W, Pi, lam)
    eps = 1e-6
    for _ in range(10):
        i, j = rng.integers(0, n, 2)
        Wp = W.copy()
        Wp[i, j] += eps
        num = (stl_fw_objective(Wp, Pi, lam) - stl_fw_objective(W, Pi, lam)) / eps
        assert num == pytest.approx(G[i, j], rel=1e-3, abs=1e-5)


def test_line_search_is_minimizer():
    n, K, lam = 8, 4, 0.3
    Pi = random_pi(n, K, 2)
    W = np.eye(n)
    grad = stl_fw_gradient(W, Pi, lam)
    from repro.core.assignment import solve_lmo

    P, _ = solve_lmo(grad)
    g_star = line_search_gamma(W, P, Pi, lam)
    obj = lambda g: stl_fw_objective((1 - g) * W + g * P, Pi, lam)
    for g in np.linspace(0, 1, 21):
        assert obj(g_star) <= obj(float(g)) + 1e-12


# ---------------------------------------------------------------------------
# full algorithm (Theorem 2 properties)
# ---------------------------------------------------------------------------

def test_learn_topology_paper_setup():
    """Section 6.1: K=10 one-class nodes; elbow at l = K-1 = 9, zero bias."""
    Pi = one_hot_pi(100, 10)
    res = learn_topology(Pi, budget=9, lam=0.5)
    # monotone decrease
    assert np.all(np.diff(res.objective_trace) <= 1e-12)
    # bias eliminated at l = K - 1
    assert res.bias_trace[-1] < 1e-20
    # degree bound d_max <= l (Theorem 2)
    assert T.max_degree(res.W) <= 9
    assert T.is_doubly_stochastic(res.W)


@settings(max_examples=10, deadline=None)
@given(st.integers(6, 30), st.integers(2, 6), st.integers(0, 1000))
def test_fw_invariants_random_pi(n, K, seed):
    Pi = random_pi(n, K, seed)
    budget = min(5, n - 1)
    lam = 0.2
    res = learn_topology(Pi, budget=budget, lam=lam)
    assert T.is_doubly_stochastic(res.W)
    assert T.max_degree(res.W) <= budget
    # Theorem 2 bound at every iterate
    for l in range(1, budget + 1):
        assert res.objective_trace[l] <= fw_upper_bound(l, lam, Pi) + 1e-9
    # Birkhoff decomposition reconstructs W exactly
    assert np.allclose(res.rebuild_W(), res.W, atol=1e-9)
    assert res.coeffs.sum() == pytest.approx(1.0)


def test_complete_graph_is_global_optimum():
    Pi = random_pi(12, 4, 3)
    for lam in (0.1, 1.0):
        assert stl_fw_objective(T.complete(12), Pi, lam) == pytest.approx(0.0, abs=1e-12)

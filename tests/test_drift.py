"""Drift scenario generators + partition edge cases under drift resampling."""

import numpy as np
import pytest

from repro.data.drift import (
    AbruptLabelSwap,
    ConceptShift,
    FeatureDrift,
    GradualDirichlet,
    NodeChurn,
    features_stream,
    labels_stream,
    partition_from_pi,
)
from repro.data.partition import (
    cluster_partition,
    dirichlet_partition,
    proportions_from_labels,
    shard_partition,
)


def _dirichlet_pi(n, K, seed=0, alpha=0.5):
    return np.random.default_rng(seed).dirichlet(alpha * np.ones(K), size=n)


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

def test_abrupt_label_swap_switches_at_t_drift():
    Pi0 = _dirichlet_pi(10, 4)
    perm = np.random.default_rng(1).permutation(10)
    sc = AbruptLabelSwap(Pi0, t_drift=5, node_perm=perm)
    np.testing.assert_allclose(sc.Pi(4), Pi0)
    np.testing.assert_allclose(sc.Pi(5), Pi0[perm])
    with pytest.raises(ValueError):
        AbruptLabelSwap(Pi0, t_drift=5, node_perm=np.zeros(10, np.int64))


def test_sampled_labels_match_distribution():
    Pi0 = _dirichlet_pi(6, 5, seed=2)
    sc = AbruptLabelSwap(Pi0, t_drift=100)
    big = sc.sample_labels(0, 20000, np.random.default_rng(3))
    emp = np.stack([np.bincount(big[i], minlength=5) / 20000 for i in range(6)])
    assert np.abs(emp - Pi0).max() < 0.02


def test_labels_stream_reproducible_and_shaped():
    sc = AbruptLabelSwap(_dirichlet_pi(4, 3), t_drift=2)
    a = labels_stream(sc, 7, 5, seed=9)
    b = labels_stream(sc, 7, 5, seed=9)
    assert a.shape == (7, 4, 5) and a.dtype == np.int32
    np.testing.assert_array_equal(a, b)
    assert labels_stream(sc, 0, 5).shape == (0, 4, 5)


def test_gradual_dirichlet_interpolates_on_simplex():
    Pi0 = _dirichlet_pi(8, 4, seed=4)
    gd = GradualDirichlet(Pi0, t_start=10, t_end=20, seed=5)
    np.testing.assert_allclose(gd.Pi(10), Pi0)
    np.testing.assert_allclose(gd.Pi(20), gd.Pi1)
    for t in (12, 15, 18):
        Pi_t = gd.Pi(t)
        assert np.allclose(Pi_t.sum(axis=1), 1.0, atol=1e-12)
        assert Pi_t.min() >= 0.0
    mid = gd.Pi(15)
    np.testing.assert_allclose(mid, 0.5 * (Pi0 + gd.Pi1))
    with pytest.raises(ValueError):
        GradualDirichlet(Pi0, t_start=5, t_end=5)


def test_node_churn_replaces_rows_and_masks_offline_windows():
    Pi0 = _dirichlet_pi(6, 4, seed=6)
    ch = NodeChurn(Pi0, events=((3, 1, 2), (5, 4)), seed=7)
    np.testing.assert_allclose(ch.Pi(2), Pi0)
    assert not np.allclose(ch.Pi(3)[1], Pi0[1])       # replaced at t=3
    np.testing.assert_allclose(ch.Pi(3)[0], Pi0[0])   # others untouched
    assert not np.allclose(ch.Pi(5)[4], Pi0[4])
    rng = np.random.default_rng(0)
    lab3 = ch.sample_labels(3, 4, rng)
    assert np.all(lab3[1] == -1)                      # offline window [3, 5)
    lab5 = ch.sample_labels(5, 4, rng)
    assert np.all(lab5[1] >= 0)                       # back online
    assert np.array_equal(ch.offline_nodes(4), [1])
    assert ch.offline_nodes(5).size == 0
    with pytest.raises(ValueError):
        NodeChurn(Pi0, events=((1, 99),))


def test_partition_from_pi_matches_target_proportions():
    rng = np.random.default_rng(8)
    K = 4
    labels = rng.integers(0, K, size=4000)
    Pi = _dirichlet_pi(10, K, seed=9)
    parts = partition_from_pi(labels, Pi, samples_per_node=500, seed=10)
    emp = proportions_from_labels(labels, parts, K)
    assert np.abs(emp - Pi).max() < 0.08
    for idx in parts:
        assert len(idx) == 500


def test_partition_from_pi_handles_missing_class_pools():
    # class 2 has no samples at all: rows renormalize away from it
    labels = np.array([0, 0, 1, 1, 3, 3] * 20)
    Pi = np.array([[0.0, 0.0, 1.0, 0.0],     # entire row on the empty pool
                   [0.25, 0.25, 0.25, 0.25]])
    parts = partition_from_pi(labels, Pi, samples_per_node=40, seed=0)
    assert len(parts[0]) == 0                 # nothing to draw for node 0
    assert len(parts[1]) == 40
    assert not np.any(labels[parts[1]] == 2)


# ---------------------------------------------------------------------------
# partition regression: drift-resampling edge cases
# ---------------------------------------------------------------------------

def test_partitioners_keep_fixed_k_under_drift_resampling():
    """A temporarily-absent class must not shrink Pi's width."""
    rng = np.random.default_rng(11)
    labels_full = rng.integers(0, 5, size=300)
    labels_drifted = labels_full[labels_full != 4]  # class 4 vanished
    for fn in (shard_partition, dirichlet_partition, cluster_partition):
        _, Pi = fn(labels_drifted, 6, num_classes=5)
        assert Pi.shape == (6, 5)
        assert np.allclose(Pi.sum(axis=1), 1.0, atol=1e-12)
        # class-4 mass per row: 0 (observed data) or 1/K (an empty node's
        # uniform prior row) -- never anything data-driven
        for v in Pi[:, 4]:
            assert np.isclose(v, 0.0) or np.isclose(v, 0.2), Pi[:, 4]


def test_partitioners_single_class_and_empty_nodes():
    labels = np.zeros(10, np.int64)
    idx, Pi = dirichlet_partition(labels, 8, num_classes=1, seed=0)
    assert Pi.shape == (8, 1)
    np.testing.assert_allclose(Pi, 1.0)       # single class: all rows [1.0]
    # more nodes than samples: some nodes end up empty -> uniform rows
    idx, Pi = dirichlet_partition(labels, 8, num_classes=3, seed=0)
    empty = [i for i, ix in enumerate(idx) if len(ix) == 0]
    for i in empty:
        np.testing.assert_allclose(Pi[i], 1.0 / 3)
    covered = np.concatenate([ix for ix in idx if len(ix)])
    assert sorted(covered.tolist()) == list(range(10))  # no sample lost


def test_partitioners_reject_inconsistent_num_classes():
    labels = np.array([0, 1, 5])
    for fn in (shard_partition, dirichlet_partition, cluster_partition):
        with pytest.raises(ValueError):
            fn(labels, 2, num_classes=3)      # label 5 out of range
        with pytest.raises(ValueError):
            fn(np.array([], dtype=np.int64), 2)  # K not inferable
        idx, Pi = fn(np.array([], dtype=np.int64), 2, num_classes=4)
        assert Pi.shape == (2, 4)             # empty labels + explicit K is fine
        np.testing.assert_allclose(Pi, 0.25)


def test_proportions_from_labels_rejects_out_of_range():
    labels = np.array([0, 1, 2, 7])
    with pytest.raises(ValueError):
        proportions_from_labels(labels, [np.arange(4)], num_classes=3)
    Pi = proportions_from_labels(labels, [np.array([], np.int64)], num_classes=3)
    np.testing.assert_allclose(Pi, 1.0 / 3)


def test_shard_partition_more_shards_than_samples():
    labels = np.array([0, 1, 0, 1])
    idx, Pi = shard_partition(labels, 4, shards_per_node=2, num_classes=2)
    assert Pi.shape == (4, 2)
    covered = np.concatenate([ix for ix in idx if len(ix)])
    assert sorted(covered.tolist()) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# full-segment absence + immediate rejoin (ISSUE 6 hardening)
# ---------------------------------------------------------------------------

def test_node_churn_offline_windows():
    Pi0 = _dirichlet_pi(6, 3)
    churn = NodeChurn(Pi0=Pi0, events=((5, 2, 4), (8, 4, 3), (12, 1)), seed=0)
    wins = churn.offline_windows()
    assert (2, 5, 9) in wins and (4, 8, 11) in wins
    assert all(w[0] != 1 for w in wins)  # offline_steps=0 events omitted
    for node, t0, t1 in wins:
        for t in range(t0, t1):
            assert node in churn.offline_nodes(t)
        assert node not in churn.offline_nodes(t1)


def test_estimator_full_segment_absence_holds_row():
    """A node dark for a whole segment must keep its Pi row exactly --
    no decay toward stale data, no NaN -- and snap back on rejoin."""
    from repro.online.streaming import StreamingPiEstimator

    Pi0 = _dirichlet_pi(6, 3, seed=1)
    churn = NodeChurn(Pi0=Pi0, events=((0, 2, 50),), seed=0)
    est = StreamingPiEstimator(6, 3, beta=0.2, init=Pi0)
    row_before = est.Pi_hat[2].copy()
    rng = np.random.default_rng(0)
    for t in range(50):  # node 2 absent the ENTIRE stretch
        est.update(churn.sample_labels(t, 8, rng))
    assert np.array_equal(est.Pi_hat[2], row_before)   # held, not decayed
    assert np.isfinite(est.Pi_hat).all()
    assert est.absent_streak[2] == 50
    assert (est.absent_streak[[0, 1, 3, 4, 5]] == 0).all()
    # other rows kept estimating (rows sum to 1 throughout)
    np.testing.assert_allclose(est.Pi_hat.sum(axis=1), 1.0, atol=1e-9)


def test_estimator_immediate_rejoin_snaps_with_rejoin_beta():
    from repro.online.streaming import StreamingPiEstimator

    n, K = 4, 3
    init = np.full((n, K), 1.0 / K)
    slow = StreamingPiEstimator(n, K, beta=0.05, init=init)
    fast = StreamingPiEstimator(n, K, beta=0.05, init=init, rejoin_beta=0.8)
    absent = np.array([[0], [1], [2], [-1]])
    for est in (slow, fast):
        for _ in range(10):
            est.update(absent)
    # node 3 rejoins emitting pure class 2
    rejoin = np.array([[0], [1], [2], [2]])
    slow.update(rejoin)
    fast.update(rejoin)
    assert fast.Pi_hat[3, 2] > 0.8                  # snapped toward fresh data
    assert slow.Pi_hat[3, 2] < 0.4                  # legacy rate barely moved
    assert fast.absent_streak[3] == 0
    # steady-state behavior identical once the streak is cleared
    slow2 = StreamingPiEstimator(n, K, beta=0.05, init=init)
    fast2 = StreamingPiEstimator(n, K, beta=0.05, init=init, rejoin_beta=0.8)
    present = np.array([[0], [1], [2], [0]])
    for _ in range(5):
        slow2.update(present)
        fast2.update(present)
    assert np.array_equal(slow2.Pi_hat, fast2.Pi_hat)  # bitwise back-compat


def test_estimator_rejoin_beta_validation():
    from repro.online.streaming import StreamingPiEstimator

    with pytest.raises(ValueError):
        StreamingPiEstimator(4, 3, rejoin_beta=0.0)
    with pytest.raises(ValueError):
        StreamingPiEstimator(4, 3, rejoin_beta=1.5)


def test_fault_plan_from_churn_stream_consistency():
    """labels_stream's offline masking and the plan's alive windows agree
    step for step -- the estimator and the mixing layer see the SAME
    outage."""
    from repro.faults import FaultPlan

    Pi0 = _dirichlet_pi(6, 3, seed=2)
    churn = NodeChurn(Pi0=Pi0, events=((3, 1, 5), (10, 4, 4)), seed=0)
    plan = FaultPlan.from_node_churn(churn, steps=20)
    stream = labels_stream(churn, steps=20, batch=4, seed=1)
    for t in range(20):
        dark = set(np.flatnonzero((stream[t] < 0).all(axis=1)))
        assert dark == set(np.flatnonzero(~plan.alive[t]))


# ---------------------------------------------------------------------------
# feature-space drift: covariate shift + concept shift (ISSUE 8 satellite)
# ---------------------------------------------------------------------------

def test_feature_drift_shifts_means_not_labels():
    Pi0 = _dirichlet_pi(6, 4, seed=3)
    fd = FeatureDrift(Pi0, t_drift=10, dim=5, shift=3.0, seed=4)
    np.testing.assert_allclose(fd.Pi(0), fd.Pi(100))  # label marginals fixed
    assert np.allclose(fd.feature_shift(9), 0.0)
    post = fd.feature_shift(10)
    np.testing.assert_allclose(np.linalg.norm(post, axis=1), 3.0)
    rng = np.random.default_rng(0)
    X_pre, y_pre = fd.sample(0, 2000, rng)
    X_post, y_post = fd.sample(10, 2000, rng)
    assert X_pre.shape == (6, 2000, 5) and X_pre.dtype == np.float32
    # per-node mean moves by ~ the node's offset, labels stay on-Pi
    moved = X_post.mean(axis=1) - X_pre.mean(axis=1)
    assert np.abs(moved - post).max() < 0.5
    emp = np.stack([np.bincount(y_post[i], minlength=4) / 2000 for i in range(6)])
    assert np.abs(emp - Pi0).max() < 0.05
    with pytest.raises(ValueError):
        FeatureDrift(Pi0, t_drift=1, shift=-1.0)


def test_feature_drift_detector_fires_on_feature_stat_not_labels():
    """The label-space proxy is blind to covariate shift; a feature-mean
    statistic sees it. Recovery: re-centering on a post-drift window
    restores nearest-class-mean accuracy."""
    from repro.online.streaming import DriftDetector, StreamingPiEstimator

    # near-balanced rows so every (node, class) pool is populated for the
    # per-node mean re-estimation below; class_sep < shift so a stale
    # classifier actually breaks at the drift
    Pi0 = _dirichlet_pi(8, 4, seed=5, alpha=5.0)
    fd = FeatureDrift(Pi0, t_drift=30, dim=6, class_sep=1.5, shift=4.0,
                      noise=0.5, seed=6)
    X, y = features_stream(fd, steps=60, batch=64, seed=7)

    # label-space: Pi_hat never leaves Pi0's neighborhood
    est = StreamingPiEstimator(8, 4, beta=0.2, init=Pi0)
    # the Pi_hat statistic is also near-zero sampling noise pre-drift:
    # slack it above the noise floor so only a real marginal move fires
    label_det = DriftDetector(threshold=1.5, abs_slack=0.1, warmup=3)
    label_fired = []
    baseline_mean = X[:10].mean(axis=(0, 2))          # (n, dim) pre-drift
    # the pre-drift statistic is near-zero sampling noise, so the
    # relative trigger needs its absolute slack (the documented knob for
    # near-zero baselines); the post-drift jump is ~||shift|| * sqrt(n)
    feat_det = DriftDetector(threshold=1.5, abs_slack=1.0, warmup=3)
    feat_fired = []
    for t in range(60):
        Pi_hat = est.update(y[t])
        label_fired.append(label_det.update(np.abs(Pi_hat - Pi0).max()))
        stat = np.linalg.norm(X[t].mean(axis=1) - baseline_mean)
        feat_fired.append(feat_det.update(stat))
    assert not any(label_fired), "label detector must be blind to covariate shift"
    assert any(feat_fired[30:]), "feature statistic must fire post-drift"
    assert not any(feat_fired[:30])

    # recovery: each node re-estimates its class means on a post-drift
    # window (the shift is node-specific, so pooled means cannot recover)
    # and nearest-class-mean classification works again
    def ncm_acc(means, Xe, ye):
        pred = np.argmin(
            np.linalg.norm(Xe[..., None, :] - means, axis=-1), axis=-1
        )
        return float((pred == ye).mean())

    K, n = 4, 8
    acc_stale, acc_recov = [], []
    for i in range(n):
        means_pre = np.stack(
            [X[:20, i][y[:20, i] == k].mean(axis=0) for k in range(K)]
        )
        means_post = np.stack(
            [X[40:, i][y[40:, i] == k].mean(axis=0) for k in range(K)]
        )
        acc_stale.append(ncm_acc(means_pre, X[50, i], y[50, i]))
        acc_recov.append(ncm_acc(means_post, X[50, i], y[50, i]))
    acc_stale, acc_recov = np.mean(acc_stale), np.mean(acc_recov)
    assert acc_recov > 0.9, acc_recov
    assert acc_recov > acc_stale + 0.1, (acc_stale, acc_recov)


def test_concept_shift_permutes_labels_and_marginals():
    Pi0 = _dirichlet_pi(5, 4, seed=8)
    cs = ConceptShift(Pi0, t_drift=10, seed=9)
    perm = cs.class_perm
    assert not np.array_equal(perm, np.arange(4))
    np.testing.assert_allclose(cs.Pi(9), Pi0)
    # emitted-marginal identity: Pi(t)[:, perm[k]] == Pi0[:, k]
    np.testing.assert_allclose(cs.Pi(10)[:, perm], Pi0)
    rng = np.random.default_rng(0)
    X_pre, y_pre = cs.sample(0, 3000, rng)
    X_post, y_post = cs.sample(10, 3000, rng)
    emp = np.stack([np.bincount(y_post[i], minlength=4) / 3000 for i in range(5)])
    assert np.abs(emp - cs.Pi(10)).max() < 0.05
    with pytest.raises(ValueError):
        ConceptShift(Pi0, t_drift=1, class_perm=np.zeros(4, np.int64))
    with pytest.raises(ValueError):
        ConceptShift(np.ones((3, 1)), t_drift=1)  # K=1 has no non-identity perm


def test_concept_shift_detector_sees_it_and_estimator_recovers():
    """Unlike covariate shift, a class permutation moves the label
    marginals: the streaming-Pi detector fires, and after the drift the
    estimator converges to the permuted Pi."""
    from repro.online.streaming import DriftDetector, StreamingPiEstimator

    Pi0 = _dirichlet_pi(6, 4, seed=10)
    cs = ConceptShift(Pi0, t_drift=25, seed=11)
    stream = labels_stream(cs, steps=60, batch=64, seed=12)
    est = StreamingPiEstimator(6, 4, beta=0.2, init=Pi0)
    det = DriftDetector(threshold=1.5, abs_slack=0.1, warmup=3)
    fired = []
    for t in range(60):
        Pi_hat = est.update(stream[t])
        fired.append(det.update(np.abs(Pi_hat - Pi0).max()))
    assert not any(fired[:25])
    assert any(fired[25:]), "label detector must see a class permutation"
    # recovery: the estimator tracks the post-drift marginals
    assert np.abs(est.Pi_hat - cs.Pi(59)).max() < 0.1


def test_features_stream_reproducible_and_shaped():
    Pi0 = _dirichlet_pi(4, 3, seed=13)
    for sc in (FeatureDrift(Pi0, t_drift=3, dim=5, seed=1),
               ConceptShift(Pi0, t_drift=3, dim=5, seed=1)):
        Xa, ya = features_stream(sc, 6, 7, seed=2)
        Xb, yb = features_stream(sc, 6, 7, seed=2)
        assert Xa.shape == (6, 4, 7, 5) and Xa.dtype == np.float32
        assert ya.shape == (6, 4, 7) and ya.dtype == np.int32
        np.testing.assert_array_equal(Xa, Xb)
        np.testing.assert_array_equal(ya, yb)
        X0, y0 = features_stream(sc, 0, 7)
        assert X0.shape == (0, 4, 7, 5) and y0.shape == (0, 4, 7)

"""Beyond-paper extensions: time-varying topologies + compressed gossip."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.compression import bf16_compress, ef_gossip_step, topk_compress
from repro.core.dynamic import (
    AtomCycling,
    OnlineSchedule,
    PeriodicGossip,
    RandomMatching,
    composite_matrix,
)
from repro.core.stl_fw import learn_topology
from repro.data.synthetic import mean_estimation_clusters
from repro.train.trainer import run_mean_estimation


# ---------------------------------------------------------------------------
# time-varying topologies
# ---------------------------------------------------------------------------

def test_periodic_gossip_matrices():
    W = T.ring(8)
    sched = PeriodicGossip(W, period=3)
    assert np.allclose(sched.matrix(0), W)
    assert np.allclose(sched.matrix(1), np.eye(8))
    assert np.allclose(sched.matrix(3), W)


@pytest.mark.parametrize("n", [6, 7, 12])
def test_random_matching_doubly_stochastic(n):
    sched = RandomMatching(n, seed=0)
    for t in range(5):
        W = sched.matrix(t)
        assert T.is_doubly_stochastic(W)
        assert T.max_degree(W) <= 1  # pairwise exchange only
        assert not np.allclose(sched.matrix(0), sched.matrix(1)) or n <= 2


def test_atom_cycling_composite_mixes():
    task = mean_estimation_clusters(n_nodes=12, K=4, m=3.0)
    res = learn_topology(task.Pi, budget=4, lam=0.3)
    sched = AtomCycling(res)
    for t in range(4):
        W = sched.matrix(t)
        assert T.is_doubly_stochastic(W)
        assert T.max_degree(W) <= 1  # one permutation per step
    comp = composite_matrix(sched, 8)
    # the composite over a full cycle must actually mix (p > 0)
    assert T.mixing_parameter(comp) > 0.0


def test_online_schedule_composite_doubly_stochastic_across_refresh():
    """Satellite requirement: AtomCycling/PeriodicGossip composed with a
    refreshing W must keep the k-step composite doubly stochastic even
    when the window spans a refresh boundary."""
    rng = np.random.default_rng(0)
    n, K = 12, 4
    Pi0 = np.eye(K)[np.arange(n) % K].astype(float)
    r0 = learn_topology(Pi0, budget=4, lam=0.3)
    r1 = learn_topology(Pi0[rng.permutation(n)], budget=4, lam=0.3)

    for factory in (AtomCycling, lambda res: PeriodicGossip(res.W, period=3)):
        online = OnlineSchedule(factory, initial=r0)
        online.push(7, r1)          # refresh mid-window
        for t in (0, 6, 7, 8, 13):  # per-step matrices around the boundary
            assert T.is_doubly_stochastic(online.matrix(t))
        comp = composite_matrix(online, 14)  # spans the boundary at t=7
        assert T.is_doubly_stochastic(comp)
    # segment-local time: the refreshed PeriodicGossip gossips at its own t=0
    online = OnlineSchedule(lambda res: PeriodicGossip(res.W, period=3), initial=r0)
    online.push(7, r1)
    assert np.allclose(online.matrix(7), r1.W)
    assert np.allclose(online.matrix(8), np.eye(n))
    with pytest.raises(ValueError):
        online.push(5, r0)          # refreshes must move forward in time


def _run_dynamic(task, schedule, steps=80, lr=0.15):
    """D-SGD with a per-step matrix (reuses the stacked-step kernel)."""
    import jax.numpy as jnp

    from repro.core.dsgd import dsgd_init, dsgd_step_stacked

    n = task.n_nodes
    rng = np.random.default_rng(0)
    theta = jnp.zeros((n, 1))
    state = dsgd_init(theta)
    for t in range(steps):
        z = jnp.asarray(task.sample(1, rng), jnp.float32)
        grads = 2.0 * (theta - z)
        W = jnp.asarray(schedule.matrix(t), jnp.float32)
        theta, state = dsgd_step_stacked(theta, grads, state, W, lr)
    err = np.asarray((theta[:, 0] - task.theta_star) ** 2)
    return float(err.mean())


def test_dynamic_schedules_converge():
    task = mean_estimation_clusters(n_nodes=12, K=4, m=2.0)
    res = learn_topology(task.Pi, budget=4, lam=0.3)
    static_err = run_mean_estimation(task, res.W, steps=80, lr=0.15)["mean_sq_error"][-1]
    for sched in (
        PeriodicGossip(res.W, period=2),
        RandomMatching(12, seed=1),
        AtomCycling(res),
    ):
        err = _run_dynamic(task, sched)
        # cheaper communication converges, within an order of magnitude
        assert err < max(10.0 * static_err, 0.5), type(sched).__name__


# ---------------------------------------------------------------------------
# compressed gossip with error feedback
# ---------------------------------------------------------------------------

def test_identity_compressor_recovers_plain_mixing():
    rng = np.random.default_rng(0)
    n = 8
    theta = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
    ef = jnp.zeros_like(theta)
    W = jnp.asarray(T.ring(n), jnp.float32)
    mixed, new_ef = ef_gossip_step(theta, ef, W, lambda x: x)
    want = np.asarray(W) @ np.asarray(theta)
    np.testing.assert_allclose(np.asarray(mixed), want, atol=1e-5)
    assert float(jnp.abs(new_ef).max()) == 0.0


def test_bf16_compression_small_error():
    rng = np.random.default_rng(1)
    theta = jnp.asarray(rng.normal(size=(6, 64)), jnp.float32)
    ef = jnp.zeros_like(theta)
    W = jnp.asarray(T.ring(6), jnp.float32)
    mixed, _ = ef_gossip_step(theta, ef, W, bf16_compress)
    want = np.asarray(W) @ np.asarray(theta)
    assert np.abs(np.asarray(mixed) - want).max() < 0.05


def test_error_feedback_preserves_convergence_under_topk():
    """Top-10% sparsified gossip with EF still estimates the mean."""
    task = mean_estimation_clusters(n_nodes=10, K=2, m=2.0)
    W = jnp.asarray(T.alternating_ring(10), jnp.float32)
    comp = topk_compress(0.5)

    rng = np.random.default_rng(0)
    theta = jnp.zeros((10, 1))
    ef = jnp.zeros_like(theta)
    lr = 0.1
    for t in range(150):
        z = jnp.asarray(task.sample(2, rng).mean(axis=1, keepdims=True), jnp.float32)
        half = theta - lr * 2.0 * (theta - z)
        theta, ef = ef_gossip_step(half, ef, W, comp)
    err = float(np.mean((np.asarray(theta)[:, 0] - task.theta_star) ** 2))
    assert err < 0.3, err

"""Partition-rule unit tests (incl. the stage-axis regression that caused
DeepSeek's 16x replication -- EXPERIMENTS.md §Perf pair B bring-up)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.train.sharding import make_param_specs, sanitize_spec, tp_spec_for_path


class FakeMesh:
    shape = {"data": 16, "model": 16}


MESH = FakeMesh()


def test_sanitize_drops_nondivisible():
    spec = sanitize_spec(P("model", None), (60, 128), MESH)
    assert spec == P(None, None)  # 60 % 16 != 0
    spec = sanitize_spec(P("model", "data"), (64, 32), MESH)
    assert spec == P("model", "data")


def test_col_and_row_parallel_rules():
    assert tp_spec_for_path("['attn']['wq']", (1024, 2048)) == P(None, "model")
    assert tp_spec_for_path("['attn']['wo']", (2048, 1024)) == P("model", None)
    assert tp_spec_for_path("['mlp']['w_down']", (8192, 1024)) == P("model", None)
    assert tp_spec_for_path("['mlp']['w_gate']", (1024, 8192)) == P(None, "model")


def test_expert_rule_with_fsdp():
    spec = tp_spec_for_path("['mlp']['routed']['w_gate']", (160, 5120, 1536), fsdp_axis="data")
    assert spec == P("model", "data", None)


def test_vocab_rules():
    assert tp_spec_for_path("['embed']['table']", (151936, 1024)) == P("model", None)
    assert tp_spec_for_path("['embed']['unembed']", (1024, 151936)) == P(None, "model")


def test_stage_axis_prefix_regression():
    """Stage-stacked leaves must get a leading None for the group axis --
    without it the expert/TP axes shift onto the wrong dims (the DeepSeek
    16x replication bug)."""
    import jax.numpy as jnp

    params = {
        "stages": [{
            "attn": {"wq": jax.ShapeDtypeStruct((60, 5120, 16384), jnp.bfloat16)},
            "mlp": {"routed": {"w_gate": jax.ShapeDtypeStruct((60, 160, 5120, 1536), jnp.bfloat16)}},
        }],
        "embed": {"table": jax.ShapeDtypeStruct((102400, 5120), jnp.bfloat16)},
    }
    specs = make_param_specs(params, MESH, node_axis=None, fsdp_axis="data")
    wq = specs["stages"][0]["attn"]["wq"]
    routed = specs["stages"][0]["mlp"]["routed"]["w_gate"]
    table = specs["embed"]["table"]
    assert wq == P(None, "data", "model")  # group axis untouched
    assert routed == P(None, "model", "data", None)  # experts over model!
    assert table == P("model", "data")


def test_node_axis_prepended():
    import jax.numpy as jnp

    params = {"stages": [{"attn": {"wq": jax.ShapeDtypeStruct((16, 28, 1024, 2048), jnp.bfloat16)}}]}
    specs = make_param_specs(params, MESH, node_axis="data", fsdp_axis=None)
    assert specs["stages"][0]["attn"]["wq"] == P("data", None, None, "model")


def test_every_arch_has_no_unsharded_giant_leaf():
    """No parameter > 64 MB may stay fully replicated under TP specs."""
    from repro.configs import ARCH_IDS, get_config
    from repro.models import registry

    for name in ARCH_IDS:
        cfg = get_config(name)
        abstract = jax.eval_shape(
            lambda r: registry.init_model(r, cfg), jax.random.PRNGKey(0)
        )
        specs = make_param_specs(abstract, MESH, node_axis=None, fsdp_axis=None)
        leaves = jax.tree_util.tree_flatten_with_path(abstract)[0]
        spec_leaves = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )[0]
        for (path, leaf), (_, spec) in zip(leaves, spec_leaves):
            size = int(np.prod(leaf.shape)) * 2
            sharded = any(e is not None for e in spec)
            if size > 64 * 2**20:
                assert sharded, (name, jax.tree_util.keystr(path), leaf.shape)

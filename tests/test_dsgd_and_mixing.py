import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core.dsgd import dsgd_init, dsgd_step_stacked
from repro.core.mixing import mix_dense, schedule_from_matrix
from repro.core.stl_fw import learn_topology
from repro.core.mixing import schedule_from_result


def test_dsgd_step_matches_manual():
    n, d = 6, 5
    rng = np.random.default_rng(0)
    theta = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    grads = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    W = jnp.asarray(T.ring(n), jnp.float32)
    lr = 0.1
    state = dsgd_init(theta)
    new, _ = dsgd_step_stacked(theta, grads, state, W, lr)
    manual = np.asarray(W) @ (np.asarray(theta) - lr * np.asarray(grads))
    assert np.allclose(np.asarray(new), manual, atol=1e-6)


def test_mixing_preserves_average():
    """Doubly-stochastic mixing preserves the node average (Property 1)."""
    n = 8
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32)
    for W in (T.ring(n), T.random_d_regular(n, 3, seed=0), T.complete(n)):
        mixed = mix_dense(x, jnp.asarray(W, jnp.float32))
        assert np.allclose(
            np.asarray(mixed).mean(0), np.asarray(x).mean(0), atol=1e-5
        )


def test_consensus_contraction():
    """||Theta W^T - Theta_bar||_F^2 <= (1-p) ||Theta - Theta_bar||_F^2."""
    n = 10
    rng = np.random.default_rng(2)
    X = rng.normal(size=(n, 7))
    for W in (T.ring(n), T.random_d_regular(n, 3, seed=1)):
        p = T.mixing_parameter(W)
        before = np.linalg.norm(X - X.mean(0), "fro") ** 2
        after = np.linalg.norm(W @ X - X.mean(0), "fro") ** 2
        assert after <= (1 - p) * before + 1e-9


def test_kernel_mixing_matches_einsum():
    n, d = 8, 300
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    W = jnp.asarray(T.ring(n), jnp.float32)
    a = mix_dense({"w": x}, W, use_kernel=False)["w"]
    b = mix_dense({"w": x}, W, use_kernel=True)["w"]
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 16), st.integers(0, 100))
def test_birkhoff_decomposition_reconstructs(n, seed):
    rng = np.random.default_rng(seed)
    # random doubly-stochastic matrix by Sinkhorn
    M = rng.random((n, n)) + 0.05
    for _ in range(300):
        M /= M.sum(1, keepdims=True)
        M /= M.sum(0, keepdims=True)
    sched = schedule_from_matrix(M)
    assert np.allclose(sched.to_matrix(), M, atol=1e-3)


def test_schedule_from_stl_fw_result():
    Pi = np.zeros((10, 5))
    Pi[np.arange(10), np.arange(10) % 5] = 1.0
    res = learn_topology(Pi, budget=4, lam=0.2)
    sched = schedule_from_result(res)
    assert np.allclose(sched.to_matrix(), res.W, atol=1e-9)
    # communication atoms bounded by budget
    assert sched.n_communication_atoms <= 4

"""Incremental STL-FW == reference STL-FW.

The incremental path precomputes the Gram factors of Eq. (8) once and
maintains ``W Pi`` / ``W Pi Pi^T`` / ``||W||_F^2`` through the rank-one FW
update; every trace it emits must match the direct (seed) evaluation to
floating-point reassociation error. When the LMO hits an exactly degenerate
tie (two permutations with equal inner product, common on symmetric Pi) the
two paths may pick different-but-equally-optimal atoms, so W itself is
compared only through the objective it achieves.
"""

import numpy as np
import pytest

from repro.core.stl_fw import (
    fw_upper_bound,
    learn_topology,
    stl_fw_gradient,
    stl_fw_objective,
)


@pytest.mark.parametrize("n,K,budget", [(6, 3, 4), (16, 5, 8), (40, 10, 20)])
@pytest.mark.parametrize("dedup", [True, False])
def test_traces_match_reference(n, K, budget, dedup):
    rng = np.random.default_rng(n * K)
    Pi = rng.dirichlet(np.ones(K) * 0.3, size=n)
    ref = learn_topology(Pi, budget=budget, lam=0.3, dedup_atoms=dedup, method="reference")
    inc = learn_topology(Pi, budget=budget, lam=0.3, dedup_atoms=dedup, method="incremental")
    np.testing.assert_allclose(inc.objective_trace, ref.objective_trace, atol=1e-10)
    np.testing.assert_allclose(inc.gamma_trace, ref.gamma_trace, atol=1e-10)
    np.testing.assert_allclose(inc.bias_trace, ref.bias_trace, atol=1e-10)
    np.testing.assert_allclose(inc.variance_trace, ref.variance_trace, atol=1e-10)


def test_incremental_state_consistent_with_direct_evaluation():
    """The maintained quantities must equal direct recomputation on the
    returned W: objective, Birkhoff reconstruction, double stochasticity."""
    rng = np.random.default_rng(7)
    Pi = rng.dirichlet(np.ones(8) * 0.4, size=24)
    res = learn_topology(Pi, budget=12, lam=0.2, method="incremental")
    # final trace entry == objective evaluated from scratch on final W
    assert abs(res.objective_trace[-1] - stl_fw_objective(res.W, Pi, 0.2)) < 1e-10
    # W is exactly its Birkhoff reconstruction
    np.testing.assert_allclose(res.rebuild_W(), res.W, atol=1e-12)
    # doubly stochastic
    np.testing.assert_allclose(res.W.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(res.W.sum(1), 1.0, atol=1e-9)
    # Theorem 2 rate holds
    for l, g in enumerate(res.objective_trace):
        assert g <= fw_upper_bound(l, 0.2, Pi) + 1e-9


def test_monotone_descent_and_budget():
    rng = np.random.default_rng(3)
    Pi = rng.dirichlet(np.ones(6) * 0.5, size=30)
    res = learn_topology(Pi, budget=10, lam=0.1, method="incremental")
    assert np.all(np.diff(res.objective_trace) <= 1e-12)  # exact line search
    assert res.n_atoms <= 11  # identity + <= budget atoms (Theorem 2)


def test_incremental_gradient_identity():
    """Gram-form gradient == closed-form gradient (the LMO sees the same
    cost matrix up to fp noise)."""
    rng = np.random.default_rng(11)
    n, K, lam = 18, 6, 0.25
    Pi = rng.dirichlet(np.ones(K) * 0.3, size=n)
    res = learn_topology(Pi, budget=5, lam=lam, method="incremental")
    W = res.W
    G = Pi @ Pi.T
    b = Pi @ Pi.mean(axis=0)
    gram_form = (W @ G - b[None, :] + lam * W - lam / n) * (2.0 / n)
    np.testing.assert_allclose(gram_form, stl_fw_gradient(W, Pi, lam), atol=1e-12)

"""Per-kernel allclose suites against the pure-jnp oracles (interpret mode).

Shape/dtype sweeps as required: parametrized grids + hypothesis-driven
random shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.gossip_mix import gossip_mix, gossip_mix_ref


# ---------------------------------------------------------------------------
# gossip_mix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 8, 16, 32])
@pytest.mark.parametrize("P", [2048, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_grid(n, P, dtype):
    rng = np.random.default_rng(n * P)
    theta = jnp.asarray(rng.normal(size=(n, P)), dtype)
    W = np.abs(rng.normal(size=(n, n)))
    W = jnp.asarray(W / W.sum(1, keepdims=True), dtype)
    out = gossip_mix(theta, W)
    ref = gossip_mix_ref(theta, W)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert out.dtype == theta.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 12), st.integers(10, 5000), st.integers(0, 99))
def test_gossip_mix_hypothesis(n, P, seed):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
    W = np.abs(rng.normal(size=(n, n))) + 0.01
    W = jnp.asarray(W / W.sum(1, keepdims=True), jnp.float32)
    out = gossip_mix(theta, W)
    ref = gossip_mix_ref(theta, W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_gossip_mix_identity():
    theta = jnp.asarray(np.random.default_rng(0).normal(size=(4, 2048)), jnp.float32)
    out = gossip_mix(theta, jnp.eye(4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(theta), atol=1e-6)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

CASES = [
    # (B, S, H, Hkv, D, window, softcap)
    (1, 128, 2, 2, 64, None, 0.0),
    (2, 256, 4, 2, 64, None, 0.0),
    (1, 256, 4, 1, 128, None, 0.0),   # MQA
    (1, 256, 4, 4, 32, 64, 0.0),      # sliding window, padded head dim
    (1, 384, 2, 2, 128, None, 50.0),  # softcap (gemma2)
    (1, 128, 8, 4, 256, 128, 0.0),    # gemma-style 256 head dim + window
    (2, 512, 4, 2, 64, 100, 30.0),    # window + softcap + odd window
]


@pytest.mark.parametrize("B,S,H,Hkv,D,window,softcap", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(B, S, H, Hkv, D, window, softcap, dtype):
    rng = np.random.default_rng(hash((B, S, H, D)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, softcap=softcap)
    ref = flash_attention_ref(q, k, v, causal=True, window=window, softcap=softcap)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=8, deadline=None)
@given(
    st.integers(1, 2),
    st.sampled_from([128, 256]),
    st.sampled_from([(2, 1), (2, 2), (4, 2)]),
    st.sampled_from([32, 64, 128]),
    st.integers(0, 999),
)
def test_flash_attention_hypothesis(B, S, heads, D, seed):
    H, Hkv = heads
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3, rtol=2e-3)


def test_flash_attention_small_seq_fallback():
    # S < block_q routes to the reference path; result must still be exact
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 16, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 16, 2, 64)), jnp.float32)
    out = flash_attention(q, k, v)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

"""Tests for the observability layer (``repro.obs``).

Covers the three layers plus their driver integrations:

* ``Tracer`` -- span nesting/ordering, thread merging, the bounded
  ring, JSONL round-trips, and the Perfetto export schema;
* ``HealthProbes`` -- every probe checked against its host-side
  reference (``train.metrics.consensus_distance``,
  ``core.heterogeneity.local_heterogeneity`` / ``tau_bar_label_skew``,
  dense ``||W||_F``), plus the config/operand error contract;
* probes *in rollouts* -- the load-bearing claim: probe outputs are
  extra scan ys, so the probes-on trajectory is BITWISE the probes-off
  one and ``n_traces`` stays 1 across hot swaps (simulator drivers
  here; the forced-8-device mesh twin runs in a subprocess below);
* ``RetraceGuard`` -- wrap/jit counting exact compiles, budgets,
  excess;
* ``RunReport`` -- build -> write -> ``load_report`` round-trip and
  ``validate_report``'s failure modes;
* the PR's metric satellites -- ``CommMeter.tick``'s deferred-subset
  invariant under fractional fates and the ``MetricLogger`` hardening
  (explicit empty CSV cells, JSONL export, aligned columns,
  ``node_spread`` on empty input).
"""

import json
import os
import subprocess
import sys
import textwrap
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.heterogeneity import local_heterogeneity, tau_bar_label_skew
from repro.core.mixing import (
    BirkhoffSchedule,
    StragglerPolicy,
    arrays_to_matrix,
    schedule_from_matrix,
    schedule_to_arrays,
)
from repro.data.drift import partition_from_pi
from repro.data.synthetic import gaussian_blobs, mean_estimation_clusters
from repro.obs import (
    HealthProbes,
    RetraceGuard,
    RunReport,
    SpanRecord,
    Tracer,
    compute_probes,
    consensus_sq,
    grad_deviation_sq,
    load_report,
    mix_pi_arrays,
    read_jsonl,
    tau_bar_arrays,
    validate_report,
    w_frobenius_sq,
    w_minus_j_frobenius_sq,
)
from repro.train.metrics import CommMeter, MetricLogger, consensus_distance, node_spread
from repro.train.trainer import run_classification, run_mean_estimation

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _shift_schedule(n, coeffs=(0.5, 0.25, 0.25)):
    ids = np.arange(n)
    sched = BirkhoffSchedule(
        coeffs=tuple(coeffs),
        perms=(ids, np.roll(ids, 1), np.roll(ids, -1)),
    )
    return schedule_to_arrays(sched, sched.n_atoms)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_span_nesting_order_parent_depth():
    tr = Tracer()
    with tr.span("outer", k=3):
        with tr.span("inner"):
            pass
        with tr.span("inner"):
            pass
    recs = tr.spans()
    # ring orders by COMPLETION: children close before their parent
    assert [r.name for r in recs] == ["inner", "inner", "outer"]
    inner0, inner1, outer = recs
    assert outer.depth == 0 and outer.parent is None
    assert inner0.depth == 1 and inner0.parent == "outer"
    assert inner1.depth == 1 and inner1.parent == "outer"
    assert outer.attrs == {"k": 3}
    # children are contained in the parent on the shared clock
    assert outer.t0 <= inner0.t0 <= inner0.t1 <= inner1.t0 <= inner1.t1 <= outer.t1
    assert outer.duration_s >= 0.0
    assert {r.tid for r in recs} == {threading.get_ident()}
    assert tr.spans("inner") == recs[:2]
    assert tr.total_s("inner") == pytest.approx(
        inner0.duration_s + inner1.duration_s
    )
    s = tr.summary()
    assert s["recorded"] == 3 and s["dropped"] == 0
    assert s["by_name"]["inner"]["count"] == 2


def test_span_exception_still_completes_with_error_attr():
    tr = Tracer()
    with pytest.raises(RuntimeError, match="boom"):
        with tr.span("fails"):
            raise RuntimeError("boom")
    (rec,) = tr.spans()
    assert rec.name == "fails"
    assert "RuntimeError" in rec.attrs["error"]


def test_threads_share_one_timeline():
    tr = Tracer()

    def worker():
        with tr.span("solve"):
            pass

    with tr.span("rollout"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    solve = tr.spans("solve")[0]
    roll = tr.spans("rollout")[0]
    assert solve.tid != roll.tid
    # the worker's span is NOT a child of the main thread's (per-thread
    # stacks), but it lands inside the rollout on the shared clock
    assert solve.parent is None and solve.depth == 0
    assert roll.t0 <= solve.t0 and solve.t1 <= roll.t1


def test_instant_and_disabled_tracer():
    tr = Tracer()
    with tr.span("seg"):
        tr.instant("mark", t=7)
    mark = tr.spans("mark")[0]
    assert mark.t0 == mark.t1
    assert mark.parent == "seg" and mark.depth == 1
    assert mark.attrs == {"t": 7}

    off = Tracer(enabled=False)
    ran = []
    with off.span("seg"):
        ran.append(True)  # the body still runs
    off.instant("mark")
    assert ran == [True]
    assert off.spans() == [] and off.dropped == 0


def test_ring_capacity_eviction_counts_dropped():
    tr = Tracer(capacity=4)
    for i in range(7):
        with tr.span(f"s{i}"):
            pass
    assert tr.dropped == 3
    assert [r.name for r in tr.spans()] == ["s3", "s4", "s5", "s6"]
    assert tr.summary()["recorded"] == 4
    with pytest.raises(ValueError, match="capacity"):
        Tracer(capacity=0)


def test_jsonl_sink_roundtrip(tmp_path):
    sink = str(tmp_path / "trace.jsonl")
    with Tracer(capacity=2, sink_path=sink) as tr:
        for i in range(5):
            with tr.span("s", i=i):
                pass
    # the ring wrapped (capacity 2) but the sink holds everything
    assert tr.dropped == 3
    recs = read_jsonl(sink)
    assert len(recs) == 5
    assert [r.attrs["i"] for r in recs] == list(range(5))
    assert recs[-2:] == tr.spans()
    # dataclass dict round-trip is exact
    for r in recs:
        assert SpanRecord.from_dict(r.to_dict()) == r


def test_write_jsonl_export_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("a", x=np.float32(1.5)):  # numpy attr must serialize
        pass
    path = tr.write_jsonl(str(tmp_path / "export.jsonl"))
    recs = read_jsonl(path)
    assert len(recs) == 1 and recs[0].attrs["x"] == 1.5


def test_perfetto_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner", k=2):
            pass
        tr.instant("mark")
    def bg():
        with tr.span("bg"):
            pass

    th = threading.Thread(target=bg)
    th.start()
    th.join()
    path = tr.write_perfetto(str(tmp_path / "trace_perfetto.json"))
    with open(path) as f:
        events = json.load(f)
    phases = [e["ph"] for e in events]
    assert set(phases) <= {"M", "X", "i"}
    # one thread_name metadata event per tid
    tids = {r.tid for r in tr.spans()}
    metas = [e for e in events if e["ph"] == "M"]
    assert len(metas) == len(tids) == 2
    assert all(e["name"] == "thread_name" for e in metas)
    for e in events:
        assert e["pid"] == 1 and "tid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and "ts" in e
        if e["ph"] == "i":
            assert e["s"] == "t"
    names = {e["name"] for e in events if e["ph"] == "X"}
    assert {"outer", "inner", "bg"} <= names
    assert any(e["ph"] == "i" and e["name"] == "mark" for e in events)


# ---------------------------------------------------------------------------
# Probe math vs host-side references
# ---------------------------------------------------------------------------


def test_consensus_sq_matches_metrics_reference():
    rng = np.random.default_rng(0)
    stack = {
        "w": jnp.asarray(rng.normal(size=(6, 4, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32),
    }
    got = float(consensus_sq(stack))
    ref = float(consensus_distance(stack))
    assert got == ref  # same math, bit for bit
    # and against plain numpy
    want = sum(
        np.sum((np.asarray(v) - np.asarray(v).mean(0, keepdims=True)) ** 2)
        for v in stack.values()
    )
    assert got == pytest.approx(want, rel=1e-5)


def test_grad_deviation_sq_matches_local_heterogeneity():
    rng = np.random.default_rng(1)
    G = rng.normal(size=(8, 5)).astype(np.float32)
    got = float(grad_deviation_sq(jnp.asarray(G)))
    assert got == pytest.approx(local_heterogeneity(G), rel=1e-5)
    # pytree with the node axis leading on every leaf: same value
    split = {"a": jnp.asarray(G[:, :2]), "b": jnp.asarray(G[:, 2:])}
    assert float(grad_deviation_sq(split)) == pytest.approx(got, rel=1e-6)


def test_schedule_probes_match_dense_w():
    n, K = 8, 3
    sa = _shift_schedule(n, coeffs=(0.5, 0.3, 0.2))
    W = arrays_to_matrix(sa)
    rng = np.random.default_rng(2)
    Pi = rng.dirichlet(np.ones(K), size=n)

    got_mix = np.asarray(mix_pi_arrays(sa, jnp.asarray(Pi)))
    assert np.allclose(got_mix, W @ Pi, atol=1e-6)

    assert float(w_frobenius_sq(sa)) == pytest.approx(
        np.linalg.norm(W, "fro") ** 2, rel=1e-5
    )
    J = np.ones((n, n)) / n
    assert float(w_minus_j_frobenius_sq(sa)) == pytest.approx(
        np.linalg.norm(W - J, "fro") ** 2, rel=1e-5
    )
    # W == J: the clamp keeps the probe non-negative at float round-off
    complete = schedule_to_arrays(schedule_from_matrix(T.complete(4)), 6)
    assert 0.0 <= float(w_minus_j_frobenius_sq(complete)) <= 1e-5

    B, sigma2 = 1.7, 0.4
    got_tau = float(tau_bar_arrays(sa, jnp.asarray(Pi), B, sigma2))
    assert got_tau == pytest.approx(
        tau_bar_label_skew(W, Pi, B, sigma2), rel=1e-5
    )


def test_health_probes_config_and_operand_errors():
    assert HealthProbes().names() == ("consensus", "grad_dev")
    full = HealthProbes(consensus=True, grad_dev=True, tau_bar=True)
    assert full.names() == ("consensus", "grad_dev", "tau_bar")
    assert HealthProbes(consensus=False, grad_dev=False, tau_bar=True).names() == (
        "tau_bar",
    )
    with pytest.raises(ValueError, match="every probe disabled"):
        HealthProbes(consensus=False, grad_dev=False, tau_bar=False)
    with pytest.raises(ValueError, match="B must be"):
        HealthProbes(tau_bar=True, B=-1.0)
    with pytest.raises(ValueError, match="sigma2 must be"):
        HealthProbes(tau_bar=True, sigma2=-0.1)

    theta = jnp.ones((4, 2))
    with pytest.raises(ValueError, match="params_stack"):
        compute_probes(HealthProbes(grad_dev=False), grads_stack=theta)
    with pytest.raises(ValueError, match="grads_stack"):
        compute_probes(HealthProbes(consensus=False), params_stack=theta)
    with pytest.raises(ValueError, match="pi_hat"):
        compute_probes(
            HealthProbes(tau_bar=True), params_stack=theta, grads_stack=theta
        )
    out = compute_probes(HealthProbes(), params_stack=theta, grads_stack=theta)
    assert tuple(out) == ("consensus", "grad_dev")
    assert float(out["consensus"]) == 0.0  # identical rows


# ---------------------------------------------------------------------------
# Probes inside the simulator rollouts
# ---------------------------------------------------------------------------


def test_mean_estimation_probes_bitwise_and_tau_bar_value():
    n, K, steps = 8, 4, 30
    task = mean_estimation_clusters(n_nodes=n, K=K)
    Pi = np.eye(K)[np.arange(n) % K].astype(float)
    sa = _shift_schedule(n)
    kw = dict(steps=steps, lr=0.1, batch=2, seed=0, schedule=sa)

    out_off = run_mean_estimation(task, None, **kw)
    probes = HealthProbes(consensus=True, grad_dev=True, tau_bar=True,
                          B=1.3, sigma2=0.5)
    guard = RetraceGuard()
    out_on = run_mean_estimation(
        task, None, probes=probes, pi_hat=Pi, retrace_guard=guard, **kw
    )

    for key in ("mean_sq_error", "max_sq_error", "min_sq_error"):
        assert np.array_equal(out_off[key], out_on[key]), key
    assert out_on["n_traces"] == 1
    assert guard.count("mean_estimation.roll") == 1

    health = out_on["health"]
    assert tuple(health) == ("consensus", "grad_dev", "tau_bar")
    for series in health.values():
        assert series.shape == (steps,) and np.all(np.isfinite(series))
    # no swap and a fixed pi_hat: tau_bar is constant and equals the
    # host-side closed form on the densified schedule
    W = arrays_to_matrix(sa)
    want = tau_bar_label_skew(W, Pi, probes.B, probes.sigma2)
    assert np.allclose(health["tau_bar"], want, rtol=1e-5)


def test_mean_estimation_probe_arg_rejections():
    n = 8
    task = mean_estimation_clusters(n_nodes=n, K=4)
    sa = _shift_schedule(n)
    W = T.ring(n)
    Pi = np.eye(4)[np.arange(n) % 4].astype(float)
    probes = HealthProbes()
    tau_probes = HealthProbes(tau_bar=True)

    with pytest.raises(ValueError, match="retrace-free data plane"):
        run_mean_estimation(task, W, steps=4, probes=probes)  # static W
    with pytest.raises(ValueError, match="scan"):
        run_mean_estimation(
            task, None, steps=4, schedule=sa, rollout="loop", probes=probes
        )
    with pytest.raises(ValueError, match="pi_hat without probes"):
        run_mean_estimation(task, None, steps=4, schedule=sa, pi_hat=Pi)
    with pytest.raises(ValueError, match="needs pi_hat"):
        run_mean_estimation(task, None, steps=4, schedule=sa, probes=tau_probes)
    with pytest.raises(ValueError, match="tau_bar is off"):
        run_mean_estimation(
            task, None, steps=4, schedule=sa, probes=probes, pi_hat=Pi
        )
    with pytest.raises(ValueError, match="pi_hat must be"):
        run_mean_estimation(
            task, None, steps=4, schedule=sa, probes=tau_probes,
            pi_hat=Pi[: n - 1],
        )
    with pytest.raises(TypeError, match="HealthProbes"):
        run_mean_estimation(task, None, steps=4, schedule=sa, probes={"consensus": True})
    with pytest.raises(ValueError, match="bounded-delay"):
        run_mean_estimation(
            task, None, steps=4, schedule=sa, probes=probes,
            staleness=StragglerPolicy(tau_max=1),
        )


def test_classification_probes_bitwise_loss_and_aux_health():
    n, C, d, spn = 6, 3, 8, 16
    X, y = gaussian_blobs(n_samples=10 * spn, num_classes=C, dim=d, seed=7)
    Pi = np.eye(C)[np.arange(n) % C].astype(float)
    idx = partition_from_pi(y, Pi, samples_per_node=spn, seed=8)
    sa = _shift_schedule(n)
    kw = dict(model="linear", steps=12, batch_size=4, lr=0.2, eval_every=6,
              seed=9, schedule=sa)

    log_off = run_classification(X, y, idx, None, **kw)
    guard = RetraceGuard()
    log_on = run_classification(
        X, y, idx, None, probes=HealthProbes(), retrace_guard=guard, **kw
    )
    assert np.array_equal(log_off.column("loss"), log_on.column("loss"))
    assert log_on.aux["n_traces"] == log_off.aux["n_traces"]
    assert guard.count("classification.roll") == log_on.aux["n_traces"]
    health = log_on.aux["health"]
    assert tuple(health) == ("consensus", "grad_dev")
    for series in health.values():
        assert series.shape == (12,) and np.all(np.isfinite(series))
    assert np.all(health["consensus"] >= 0.0)


# ---------------------------------------------------------------------------
# RetraceGuard
# ---------------------------------------------------------------------------


def test_retrace_guard_counts_exact_jit_compiles():
    guard = RetraceGuard()
    fn = jax.jit(guard.wrap(lambda x: x * 2.0, "double"))
    a = jnp.ones((3,))
    for _ in range(4):
        fn(a)  # one shape -> one trace, cache hits after
    assert guard.count("double") == 1
    fn(jnp.ones((5,)))  # new shape -> exactly one more compile
    assert guard.count("double") == 2

    guard.expect("double", 2)
    assert guard.excess() == 0
    fn(jnp.ones((7,)))
    assert guard.excess() == 1
    guard.record("stream", k=3)  # undeclared: counts, never excess
    assert guard.total() == 6 and guard.excess() == 1
    snap = guard.snapshot()
    assert snap == {
        "counts": {"double": 3, "stream": 3},
        "expected": {"double": 2},
        "total": 6,
        "excess": 1,
    }


# ---------------------------------------------------------------------------
# RunReport
# ---------------------------------------------------------------------------


def _small_report():
    logger = MetricLogger()
    logger.log(0, loss=1.5)
    logger.log(1, loss=1.2, acc=0.4)
    logger.aux["n_traces"] = 1
    meter = CommMeter(per_step_bytes=10)
    meter.tick(4, delivered_frac=0.5, deferred_frac=0.25)
    tr = Tracer()
    with tr.span("sim.segment", k=4):
        pass
    guard = RetraceGuard()
    guard.record("roll")
    guard.expect("roll", 1)
    rep = (
        RunReport("unit", seed=0, n=np.int64(8))
        .add_metrics(logger)
        .add_comm(meter)
        .add_events("swap", [{"t": 3}])
        .add_health({"consensus": np.array([1.0, 0.5], np.float32)})
        .add_spans(tr)
        .add_retraces(guard)
    )
    return rep


def test_run_report_write_load_roundtrip(tmp_path):
    rep = _small_report()
    paths = rep.write(str(tmp_path))
    doc = load_report(paths["json"])  # load_report validates
    assert doc["schema"] == "repro.run_report/v1"
    assert doc["meta"] == {"seed": 0, "n": 8}  # numpy meta scrubbed to int
    assert doc["health"]["consensus"] == [1.0, 0.5]
    assert doc["comm"]["total_bytes"] == 20
    assert doc["comm"]["deferred_bytes"] == 10
    assert doc["retraces"]["excess"] == 0
    assert doc["spans"]["by_name"]["sim.segment"]["count"] == 1
    assert len(doc["metrics"]["history"]) == 2
    md = open(paths["md"]).read()
    for section in ("## Retraces", "## Communication", "## Health series",
                    "## Spans", "## Events", "## Metrics"):
        assert section in md
    # health is additive across calls (segments append)
    rep.add_health({"consensus": [0.25]})
    assert rep.to_dict()["health"]["consensus"] == [1.0, 0.5, 0.25]


def test_validate_report_failure_modes():
    good = _small_report().to_dict()
    validate_report(good)  # sanity

    def broken(mutate):
        doc = json.loads(json.dumps(good))
        mutate(doc)
        return doc

    cases = [
        ("schema mismatch", lambda d: d.update(schema="v0")),
        ("name", lambda d: d.update(name="")),
        ("meta", lambda d: d.update(meta=[])),
        ("history", lambda d: d["metrics"].update(history="nope")),
        ("events", lambda d: d["events"].update(swap="nope")),
        ("must be a list", lambda d: d["health"].update(consensus=1.0)),
        ("non-finite", lambda d: d["health"].update(consensus=[float("inf")])),
        ("non-neg int", lambda d: d["comm"].update(total_bytes=-1)),
        ("exceeds total", lambda d: d["comm"].update(deferred_bytes=10**9)),
        ("by_name", lambda d: d["spans"].update(by_name=[])),
        ("bad count", lambda d: d["spans"]["by_name"].update(
            {"sim.segment": {"count": 0, "total_s": 0.0}})),
        ("total inconsistent", lambda d: d["retraces"].update(total=99)),
        ("excess inconsistent", lambda d: d["retraces"].update(excess=5)),
    ]
    for pattern, mutate in cases:
        with pytest.raises(ValueError, match=pattern):
            validate_report(broken(mutate))
    with pytest.raises(ValueError, match="must be a dict"):
        validate_report([])


# ---------------------------------------------------------------------------
# Metric satellites: CommMeter rounding, MetricLogger hardening
# ---------------------------------------------------------------------------


def test_comm_meter_deferred_derived_from_delivered():
    # the regression: volume=10, delivered_frac=0.34, deferred_frac=0.33.
    # Two independent truncations gave delivered=int(3.4)=3 but
    # deferred=int(3.3)=3 -- "deferred == delivered" from pure round-off.
    # Deriving deferred from the truncated delivered keeps the subset
    # invariant strict: int(3 * 0.33/0.34) = 2 < 3.
    m = CommMeter(per_step_bytes=10)
    m.tick(1, delivered_frac=0.34, deferred_frac=0.33)
    assert m.total_bytes == 3
    assert m.deferred_bytes == 2
    assert m.dropped_bytes == 7

    # the invariant holds by construction under many fractional fates
    m = CommMeter(per_step_bytes=7)
    rng = np.random.default_rng(0)
    for _ in range(200):
        dlv = float(rng.uniform(0.0, 1.0))
        dfr = float(rng.uniform(0.0, dlv))
        before = (m.total_bytes, m.deferred_bytes)
        m.tick(int(rng.integers(1, 4)), delivered_frac=dlv, deferred_frac=dfr)
        assert m.deferred_bytes - before[1] <= m.total_bytes - before[0]
    assert m.deferred_bytes <= m.total_bytes
    assert m.total_bytes + m.dropped_bytes == m.steps * 7

    # edge cases: nothing delivered means nothing deferred; equal fracs
    # defer exactly the delivered volume
    m = CommMeter(per_step_bytes=5)
    m.tick(2, delivered_frac=0.0, deferred_frac=0.0)
    assert m.total_bytes == 0 and m.deferred_bytes == 0
    m.tick(2, delivered_frac=0.3, deferred_frac=0.3)
    assert m.deferred_bytes == m.total_bytes == 3

    with pytest.raises(ValueError, match="subset of delivered"):
        CommMeter(per_step_bytes=5).tick(1, delivered_frac=0.2, deferred_frac=0.4)
    with pytest.raises(ValueError, match="delivered_frac"):
        CommMeter(per_step_bytes=5).tick(1, delivered_frac=1.5)


def test_metric_logger_csv_and_jsonl_hardening(tmp_path):
    log = MetricLogger()
    log.log(0, loss=1.0)
    log.log(1, loss=float("nan"), acc=0.5)
    log.log(2, acc=0.75)

    csv_path = str(tmp_path / "m.csv")
    log.to_csv(csv_path)
    lines = open(csv_path).read().splitlines()
    assert lines[0] == "acc,loss,step"
    assert lines[1] == ",1.0,0"
    assert lines[2] == "0.5,,1"  # logged NaN -> explicit empty cell
    assert lines[3] == "0.75,,2"  # missing key -> explicit empty cell

    jsonl_path = str(tmp_path / "m.jsonl")
    log.to_jsonl(jsonl_path)
    rows = [json.loads(l) for l in open(jsonl_path)]
    assert rows[0] == {"step": 0, "loss": 1.0}
    assert rows[1] == {"step": 1, "loss": None, "acc": 0.5}  # NaN -> null
    assert rows[2] == {"step": 2, "acc": 0.75}

    # column(): skip-missing default vs aligned-with-nan
    acc = log.column("acc")
    assert np.array_equal(acc, [0.5, 0.75])
    aligned = log.column("acc", aligned=True)
    assert len(aligned) == 3 and np.isnan(aligned[0])
    assert np.array_equal(aligned[1:], [0.5, 0.75])

    with pytest.raises(ValueError, match="empty value array"):
        node_spread(np.zeros((0,)))


# ---------------------------------------------------------------------------
# Mesh trainer probes (forced 8 host devices, subprocess)
# ---------------------------------------------------------------------------


def _run_with_devices(code: str, n_devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_mesh_probes_bitwise_across_hot_swap():
    """The tentpole acceptance on the real mesh trainer: a probes-enabled
    run_segments rollout is BITWISE the probes-off run across a schedule
    hot swap, emits finite per-step health series, and every compile is
    accounted for by the RetraceGuard (excess == 0)."""
    out = _run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.mixing import schedule_from_matrix, schedule_to_arrays
        from repro.obs import HealthProbes, RetraceGuard
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((8, 1), ("data", "model"),
                                axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")

        # probe validation at setup time: tau_bar is a simulator probe,
        # and probes need the online dsgd step
        for kwargs in ({"mode": "dsgd", "online_w": True,
                        "probes": HealthProbes(tau_bar=True)},
                       {"mode": "fsdp", "probes": HealthProbes()},
                       {"mode": "dsgd", "online_w": False,
                        "probes": HealthProbes()}):
            try:
                make_train_setup(cfg, mesh, lr=1e-2, **kwargs)
            except ValueError:
                continue
            raise AssertionError(f"{kwargs} should be rejected")

        guard = RetraceGuard()
        s_off = make_train_setup(cfg, mesh, mode="dsgd", online_w=True, lr=1e-2)
        s_on = make_train_setup(cfg, mesh, mode="dsgd", online_w=True, lr=1e-2,
                                probes=HealthProbes())
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), s_off.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        mix0 = schedule_to_arrays(schedule_from_matrix(T.ring(8)), 4)
        mix1 = schedule_to_arrays(
            schedule_from_matrix(0.5 * T.ring(8) + 0.5 * np.eye(8)), 4)
        hook = lambda t: mix1 if t == 3 else None
        with set_mesh(mesh):
            params = jax.jit(s_off.init_params, out_shardings=sh)(
                jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 8, 2, 32), 0,
                                      cfg.vocab_size)
            batches = {"tokens": toks, "labels": toks}
            r_off = s_off.run_segments(params, None, batches, mix0,
                                       segment_len=2, on_segment=hook,
                                       retrace_guard=guard)
            r_on = s_on.run_segments(params, None, batches, mix0,
                                     segment_len=2, on_segment=hook,
                                     retrace_guard=guard)

        # probe outputs are extra step outputs: the loss trajectory is
        # bit-identical, and the swap landed in both arms
        assert np.array_equal(r_off["losses"], r_on["losses"]), (
            np.abs(r_off["losses"] - r_on["losses"]).max())
        assert r_off["swaps"] == r_on["swaps"] == [3]
        assert r_off["n_traces"] == 1 and r_on["n_traces"] == 1
        assert "health" not in r_off
        health = r_on["health"]
        assert tuple(health) == ("consensus", "grad_dev")
        for name, series in health.items():
            assert series.shape == (8,), (name, series.shape)
            assert np.all(np.isfinite(series)) and np.all(series >= 0), name

        # every compile accounted for: one multi-step trace per setup,
        # the hot swap adds none
        guard.expect("run_segments.multi_step", 2)
        assert guard.count("run_segments.multi_step") == 2, guard.snapshot()
        assert guard.excess() == 0, guard.snapshot()
        print("MESH_PROBES_OK")
    """)
    assert "MESH_PROBES_OK" in out

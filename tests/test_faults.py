"""Fault injection, degraded doubly-stochastic mixing, stale mixing, and
crash recovery (ISSUE 6).

The invariants under test:

* ``degrade_schedule`` repairs every atom to an EXACT permutation (cycle
  collapse), so the degraded W is doubly stochastic to 1e-12 under any
  alive mask / dropped-edge set, with the gamma vector bitwise untouched.
* stale mixing with all-zero delays is bitwise the fresh mixing path.
* ``FaultPlan`` traces are a pure function of the seed: identical across
  processes (subprocess fingerprint check) and random-access (resume
  reconstructs the same trace without replay).
* the faults runner reproduces the fault-free driver bitwise on a
  zero-fault plan, stays single-trace under live faults + a mid-run
  topology swap, and checkpoint-resumes bitwise.
"""

import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.mixing import (
    ScheduleArrays,
    StragglerPolicy,
    degrade_schedule,
    mix_schedule_arrays,
    mix_schedule_arrays_stale,
    schedule_from_matrix,
    schedule_to_arrays,
    stale_buffer_init,
    stale_push,
    stale_view,
)
from repro.core import topology as T
from repro.data.drift import NodeChurn
from repro.data.synthetic import mean_estimation_clusters
from repro.faults import FaultInjector, FaultPlan, run_faulty_mean_estimation
from repro.train.metrics import CommMeter, mix_bytes_per_step
from repro.train.trainer import run_mean_estimation

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _arrays(n: int, l_max: int = 8) -> ScheduleArrays:
    sched = schedule_from_matrix(
        0.6 * T.ring(n) + 0.4 * np.eye(n)
    )
    return schedule_to_arrays(sched, l_max)


def _dense(arrays: ScheduleArrays) -> np.ndarray:
    """Rebuild W with f64 gammas normalized to sum exactly 1, so double
    stochasticity is tested at the repair's precision, not the f32
    quantization the input gammas already carry."""
    g = np.asarray(arrays.gammas, np.float64)
    g = g / g.sum()
    P = np.asarray(arrays.perms)
    n = P.shape[1]
    W = np.zeros((n, n))
    for l in range(len(g)):
        W[np.arange(n), P[l]] += g[l]
    return W


# ---------------------------------------------------------------- degrade


@settings(max_examples=5)
@given(st.integers(0, 10_000), st.integers(4, 16))
def test_degrade_schedule_doubly_stochastic_sweep(seed, n):
    """Randomized alive masks + edge drops: repaired atoms stay exact
    permutations, W' doubly stochastic to 1e-12, gammas untouched."""
    rng = np.random.default_rng(seed)
    arrays = _arrays(n)
    alive = rng.random(n) > 0.3
    drop_mask = rng.random((n, n)) < 0.15
    np.fill_diagonal(drop_mask, False)
    dropped = tuple((int(i), int(j)) for i, j in np.argwhere(drop_mask))

    deg = degrade_schedule(arrays, alive, dropped)
    assert np.array_equal(np.asarray(deg.gammas), np.asarray(arrays.gammas))
    perms = np.asarray(deg.perms)
    ident = np.arange(n)
    for p in perms:
        assert np.array_equal(np.sort(p), ident)  # exact permutation
    W = _dense(deg)
    assert np.abs(W.sum(axis=1) - 1.0).max() < 1e-12
    assert np.abs(W.sum(axis=0) - 1.0).max() < 1e-12
    # dead nodes are isolated: row/col collapse to the self-loop
    for i in np.flatnonzero(~alive):
        e = np.zeros(n)
        e[i] = 1.0
        assert np.allclose(W[i], e, atol=1e-12)
        assert np.allclose(W[:, i], e, atol=1e-12)
    # no repaired atom routes a dropped transfer: perm[dst] = src means
    # src -> dst, forbidden when (src, dst) dropped or either end dead
    for p in perms:
        for dst in range(n):
            src = p[dst]
            if src != dst:
                assert alive[src] and alive[dst]
                assert not drop_mask[src, dst]


def test_degrade_schedule_healthy_is_identity():
    arrays = _arrays(8)
    deg = degrade_schedule(arrays, np.ones(8, bool), ())
    assert np.array_equal(np.asarray(deg.perms), np.asarray(arrays.perms))
    assert np.array_equal(np.asarray(deg.gammas), np.asarray(arrays.gammas))


def test_degrade_schedule_validates_edges():
    arrays = _arrays(4)
    with pytest.raises(ValueError):
        degrade_schedule(arrays, np.ones(4, bool), ((0, 7),))
    with pytest.raises(ValueError):
        degrade_schedule(arrays, np.ones(3, bool), ())


# ------------------------------------------------------------ stale mixing


def test_stale_mixing_zero_delay_is_fresh_bitwise():
    n, P_ = 8, 5
    rng = np.random.default_rng(0)
    arrays = _arrays(n)
    buf = stale_buffer_init(jnp.zeros((n, P_)), depth=3)
    delays0 = jnp.zeros((n,), jnp.int32)
    for _ in range(6):
        x = jnp.asarray(rng.normal(size=(n, P_)), jnp.float32)
        buf = stale_push(buf, x)
        fresh = mix_schedule_arrays(x, arrays, single_buffer=False)
        stale = mix_schedule_arrays_stale(buf, arrays, delays0)
        assert np.array_equal(np.asarray(fresh), np.asarray(stale))


def test_stale_view_reads_known_delays():
    n, P_ = 4, 2
    buf = stale_buffer_init(jnp.full((n, P_), -1.0), depth=3)
    for v in range(5):  # push values 0..4; ring keeps the last 3
        buf = stale_push(buf, jnp.full((n, P_), float(v)))
    delays = jnp.asarray([0, 1, 2, 0], jnp.int32)
    got = np.asarray(stale_view(buf, delays))
    assert np.array_equal(got[:, 0], [4.0, 3.0, 2.0, 4.0])


def test_stale_buffer_depth_one_is_always_fresh():
    buf = stale_buffer_init(jnp.zeros((3, 1)), depth=1)
    buf = stale_push(buf, jnp.ones((3, 1)))
    got = stale_view(buf, jnp.zeros((3,), jnp.int32))
    assert np.array_equal(np.asarray(got), np.ones((3, 1)))


# -------------------------------------------------------------- fault plan


def test_fault_plan_deterministic_across_processes():
    plan = FaultPlan(
        n_nodes=8, steps=50, seed=42, crash_rate=0.05, mean_outage=6.0,
        straggler_rate=0.25, tau_max=3, edge_drop_rate=0.1,
        solve_failure_rate=0.2, solve_hang_rate=0.1,
    )
    code = (
        "from repro.faults import FaultPlan\n"
        "p = FaultPlan(n_nodes=8, steps=50, seed=42, crash_rate=0.05,\n"
        "              mean_outage=6.0, straggler_rate=0.25, tau_max=3,\n"
        "              edge_drop_rate=0.1, solve_failure_rate=0.2,\n"
        "              solve_hang_rate=0.1)\n"
        "print(p.fingerprint())\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=120, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == plan.fingerprint()


def test_fault_plan_streams_are_random_access():
    plan = FaultPlan(n_nodes=6, steps=20, seed=7, edge_drop_rate=0.3)
    # reading t=13 before t=2 must not change either draw
    e13 = plan.dropped_edges(13)
    e2 = plan.dropped_edges(2)
    assert np.array_equal(plan.dropped_edges(13), e13)
    assert np.array_equal(plan.dropped_edges(2), e2)
    assert plan.solve_fault(3) == plan.solve_fault(3)


def test_fault_plan_never_kills_whole_fleet():
    plan = FaultPlan(
        n_nodes=4, steps=200, seed=0, crash_rate=0.9, mean_outage=100.0
    )
    assert plan.alive.any(axis=1).all()


def test_fault_plan_dead_nodes_have_zero_delay():
    plan = FaultPlan(
        n_nodes=8, steps=100, seed=1, crash_rate=0.2, mean_outage=5.0,
        straggler_rate=1.0, tau_max=4,
    )
    assert (plan.delays[~plan.alive] == 0).all()
    assert plan.delays.max() <= 4


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_fault_plan_delay_draws_bounded_by_ring(seed, tau):
    """Every drawn delay is reachable in a ``ring_depth``-deep ring:
    delays live in [0, tau_max] (no modular aliasing), offline nodes
    carry delay 0, and ``ring_depth == tau_max + 1``."""
    plan = FaultPlan(
        n_nodes=6, steps=60, seed=seed, crash_rate=0.1, mean_outage=4.0,
        straggler_rate=0.8, tau_max=tau,
    )
    assert plan.ring_depth == tau + 1
    assert plan.delays.dtype == np.int32
    assert plan.delays.min() >= 0
    assert plan.delays.max() <= tau
    assert (plan.delays[~plan.alive] == 0).all()


def test_fault_plan_zero_tau_means_no_staleness():
    plan = FaultPlan(
        n_nodes=4, steps=30, seed=3, straggler_rate=1.0, tau_max=0
    )
    assert plan.ring_depth == 1
    assert not plan.delays.any()


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_transfer_fracs_wait_is_backcompat_split(seed):
    """Under wait: fates sum to 1 and on_time + deferred equals the
    two-way ``delivered_frac`` (deferred bytes DO arrive)."""
    plan = FaultPlan(
        n_nodes=8, steps=25, seed=seed, crash_rate=0.08, mean_outage=5.0,
        straggler_rate=0.4, tau_max=3, edge_drop_rate=0.1,
    )
    for t in range(plan.steps):
        on, dfr, drp = plan.transfer_fracs(t, deadline=3, mode="wait")
        assert abs(on + dfr + drp - 1.0) < 1e-12
        assert abs((on + dfr) - plan.delivered_frac(t)) < 1e-12


def test_transfer_fracs_degrade_moves_deferred_to_dropped():
    """A degrade deadline below the plan's tau_max converts exactly the
    past-deadline deferred mass into dropped mass (closed form)."""
    plan = FaultPlan(
        n_nodes=8, steps=40, seed=5, straggler_rate=0.6, tau_max=4
    )
    n = plan.n_nodes
    total = n * (n - 1)
    saw_late = False
    for t in range(plan.steps):
        d = plan.delays[t]
        on_w, dfr_w, drp_w = plan.transfer_fracs(t, mode="wait")
        assert drp_w == 0.0  # no crashes/drops in this plan
        on_d, dfr_d, drp_d = plan.transfer_fracs(t, deadline=2, mode="degrade")
        # closed form on the on-time support
        on_time = d <= 2
        n_on = int(on_time.sum())
        assert abs((on_d + dfr_d) - n_on * (n_on - 1) / total) < 1e-12
        assert abs(dfr_d - int(((d > 0) & on_time).sum()) * (n_on - 1) / total) < 1e-12
        if (d > 2).any():
            saw_late = True
            assert drp_d > drp_w
        else:
            assert (on_d, dfr_d, drp_d) == (on_w, dfr_w, drp_w)
    assert saw_late  # the sweep actually exercised the deadline


def test_injector_stream_applies_wait_policy():
    """A policy-aware injector streams CLAMPED effective delays and
    leaves the schedule repaired only for crashes/drops (wait never
    repairs for staleness)."""
    plan = FaultPlan(n_nodes=8, steps=12, seed=4, straggler_rate=0.9, tau_max=4)
    arrays = _arrays(8)
    policy = StragglerPolicy(mode="wait", tau_max=2)
    inj = FaultInjector(plan, arrays, policy=policy)
    gammas, perms, delays = inj.stream(0, plan.steps)
    assert delays.max() <= 2  # clamped to the policy deadline, not the plan's
    expect = np.minimum(plan.delays, 2)
    assert np.array_equal(delays, expect)
    # everyone alive + wait => schedule untouched every step
    for t in range(plan.steps):
        assert np.array_equal(perms[t], np.asarray(arrays.perms))
        assert np.array_equal(gammas[t], np.asarray(arrays.gammas))


def test_injector_stream_applies_degrade_policy():
    """Under degrade, past-deadline nodes are self-looped in every atom
    of that step's repaired schedule and their effective delay is 0."""
    plan = FaultPlan(n_nodes=8, steps=20, seed=6, straggler_rate=0.7, tau_max=4)
    arrays = _arrays(8)
    policy = StragglerPolicy(mode="degrade", tau_max=1)
    inj = FaultInjector(plan, arrays, policy=policy)
    gammas, perms, delays = inj.stream(0, plan.steps)
    assert delays.max() <= 1
    saw_late = False
    for t in range(plan.steps):
        late = plan.delays[t] > 1
        assert (np.asarray(delays[t])[late] == 0).all()
        step_arrays = ScheduleArrays(gammas=gammas[t], perms=perms[t])
        W = _dense(step_arrays)
        assert np.abs(W.sum(axis=0) - 1.0).max() < 1e-12
        assert np.abs(W.sum(axis=1) - 1.0).max() < 1e-12
        for i in np.flatnonzero(late):
            saw_late = True
            assert (np.asarray(perms[t])[:, i] == i).all()  # isolated
    assert saw_late


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(n_nodes=4, steps=10, crash_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(n_nodes=4, steps=10, tau_max=-1)
    with pytest.raises(ValueError):
        FaultPlan(n_nodes=4, steps=10, solve_failure_rate=0.7, solve_hang_rate=0.7)


def test_from_node_churn_matches_offline_windows():
    Pi0 = np.full((6, 3), 1.0 / 3)
    churn = NodeChurn(Pi0=Pi0, events=((5, 2, 4), (8, 4, 3)), seed=0)
    plan = FaultPlan.from_node_churn(churn, steps=20, seed=9)
    assert plan.n_nodes == 6 and plan.steps == 20
    for node, t0, t1 in churn.offline_windows():
        assert not plan.alive[t0:min(t1, 20), node].any()
    # outside the windows everyone is up
    assert plan.alive[0].all() and plan.alive[15:].all()


# ------------------------------------------------------------------ runner


@pytest.fixture(scope="module")
def small_problem():
    n = 8
    task = mean_estimation_clusters(n_nodes=n, K=4)
    return task, _arrays(n)


def test_runner_zero_fault_bitwise_vs_fault_free_driver(small_problem):
    task, arrays = small_problem
    plan0 = FaultPlan(n_nodes=8, steps=30, seed=0)
    base = run_mean_estimation(
        task, None, steps=30, schedule=arrays, lr=0.1, seed=5, segment_len=10
    )
    faulty = run_faulty_mean_estimation(
        task, plan0, arrays, lr=0.1, seed=5, segment_len=10
    )
    for key in ("mean_sq_error", "max_sq_error", "min_sq_error"):
        assert np.array_equal(base[key], faulty[key]), key
    assert faulty["n_traces"] == 1


def test_runner_single_trace_under_faults_and_swap(small_problem):
    """Degraded-W swaps, straggler delays, a crash/rejoin, AND a mid-run
    topology refresh are all pure value changes: one compiled rollout."""
    task, arrays = small_problem
    plan = FaultPlan(
        n_nodes=8, steps=40, seed=3, crash_rate=0.05, mean_outage=5.0,
        straggler_rate=0.4, tau_max=2, edge_drop_rate=0.08,
    )
    swapped = schedule_to_arrays(
        schedule_from_matrix(0.5 * T.ring(8) + 0.5 * np.eye(8)),
        int(np.asarray(arrays.gammas).shape[0]),
    )
    hooks = iter([None, swapped])
    out = run_faulty_mean_estimation(
        task, plan, arrays, lr=0.1, seed=5, segment_len=10,
        on_segment=lambda t: next(hooks, None),
    )
    assert out["n_traces"] == 1, out["n_traces"]
    assert out["swaps"] == [19]
    assert np.isfinite(out["mean_sq_error"]).all()
    assert out["comm"]["dropped_bytes"] > 0  # degraded delivery was metered


def test_runner_checkpoint_resume_bitwise(tmp_path, small_problem):
    task, arrays = small_problem
    plan = FaultPlan(
        n_nodes=8, steps=30, seed=11, crash_rate=0.1, mean_outage=4.0,
        straggler_rate=0.3, tau_max=2, edge_drop_rate=0.1,
    )
    kw = dict(lr=0.1, seed=5, segment_len=10)
    full = run_faulty_mean_estimation(task, plan, arrays, **kw)
    d = str(tmp_path / "ckpt")
    head = run_faulty_mean_estimation(
        task, plan, arrays, checkpoint_dir=d, stop_after_segments=1, **kw
    )
    assert head["stopped_at"] == 10
    tail = run_faulty_mean_estimation(
        task, plan, arrays, checkpoint_dir=d, resume=True, **kw
    )
    assert tail["resumed_from"] == 10
    assert tail["n_traces"] == 1  # resume re-enters the same cached trace shape
    glued = np.concatenate([head["mean_sq_error"], tail["mean_sq_error"]])
    assert np.array_equal(glued, full["mean_sq_error"])
    assert np.array_equal(tail["theta"], full["theta"])


def test_runner_checkpoint_preserves_pre_crash_swap(tmp_path, small_problem):
    """A topology refresh BEFORE the crash must survive resume: the base
    schedule is part of the checkpoint."""
    task, arrays = small_problem
    plan = FaultPlan(n_nodes=8, steps=30, seed=2, edge_drop_rate=0.05)
    swapped = schedule_to_arrays(
        schedule_from_matrix(0.5 * T.ring(8) + 0.5 * np.eye(8)),
        int(np.asarray(arrays.gammas).shape[0]),
    )
    kw = dict(lr=0.1, seed=5, segment_len=10)
    hook = lambda t: swapped if t == 9 else None
    full = run_faulty_mean_estimation(task, plan, arrays, on_segment=hook, **kw)
    d = str(tmp_path / "ckpt")
    head = run_faulty_mean_estimation(
        task, plan, arrays, on_segment=hook,
        checkpoint_dir=d, stop_after_segments=2, **kw
    )
    assert head["swaps"] == [9]
    tail = run_faulty_mean_estimation(
        task, plan, arrays, checkpoint_dir=d, resume=True, **kw
    )
    glued = np.concatenate([head["mean_sq_error"], tail["mean_sq_error"]])
    assert np.array_equal(glued, full["mean_sq_error"])


def test_injector_rebind_rejects_shape_change(small_problem):
    task, arrays = small_problem
    plan = FaultPlan(n_nodes=8, steps=10, seed=0)
    inj = FaultInjector(plan, arrays)
    bad = ScheduleArrays(
        gammas=jnp.ones((3,), jnp.float32) / 3.0,
        perms=jnp.tile(jnp.arange(8, dtype=jnp.int32), (3, 1)),
    )
    with pytest.raises(ValueError):
        inj.rebind(bad)


# ----------------------------------------------------------- comm metering


def test_mix_bytes_per_step_alive_frac():
    full = mix_bytes_per_step("allgather", n_nodes=8, p_total=100)
    assert full == 7 * 100 * 4
    half = mix_bytes_per_step("allgather", n_nodes=8, p_total=100, alive_frac=0.5)
    assert half == 3 * 100 * 4  # (0.5*8 - 1) senders
    assert mix_bytes_per_step(
        "allgather", n_nodes=8, p_total=100, alive_frac=0.0
    ) == 0
    pool_full = mix_bytes_per_step("pool", n_nodes=8, p_total=10, n_comm_atoms=4)
    pool_half = mix_bytes_per_step(
        "pool", n_nodes=8, p_total=10, n_comm_atoms=4, alive_frac=0.5
    )
    assert pool_half == pool_full // 2
    with pytest.raises(ValueError):
        mix_bytes_per_step("allgather", n_nodes=8, p_total=100, alive_frac=1.5)


def test_comm_meter_degraded_accounting():
    m = CommMeter(per_step_bytes=100)
    m.tick(10)                       # fault-free: all delivered
    m.tick(10, delivered_frac=0.8)   # degraded: 20% lost
    assert m.steps == 20
    assert m.total_bytes == 1000 + 800
    assert m.dropped_bytes == 200
    m.retransmit(50)                 # a re-send arrives on top
    s = m.summary()
    assert s["total_bytes"] == 1850
    assert s["retransmit_bytes"] == 50
    assert s["dropped_bytes"] == 200
    with pytest.raises(ValueError):
        m.tick(1, delivered_frac=1.2)


def test_comm_meter_deferred_vs_dropped():
    """Deferred bytes are a SUBSET of delivered bytes (they arrive,
    late); dropped bytes never arrive. The two are accounted apart."""
    m = CommMeter(per_step_bytes=1000)
    m.tick(5, delivered_frac=0.9, deferred_frac=0.3)
    assert m.total_bytes == 4500
    assert m.dropped_bytes == 500
    assert m.deferred_bytes == 1500
    s = m.summary()
    assert s["deferred_bytes"] == 1500
    # deferred cannot exceed delivered
    with pytest.raises(ValueError):
        m.tick(1, delivered_frac=0.5, deferred_frac=0.6)
    with pytest.raises(ValueError):
        m.tick(1, deferred_frac=-0.1)

"""Online topology adaptation: streaming Pi, drift detection, warm refresh,
and the zero-retrace schedule hot-swap plumbing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.mixing import (
    BirkhoffSchedule,
    ScheduleArrays,
    arrays_to_matrix,
    mix_schedule_arrays,
    mix_schedule_stacked,
    mix_stacked,
    schedule_from_result,
    schedule_to_arrays,
    truncate_schedule,
)
from repro.core.stl_fw import learn_topology, stl_fw_objective
from repro.data.synthetic import mean_estimation_clusters
from repro.online import (
    DriftDetector,
    OnlineTopologyController,
    RefreshConfig,
    StreamingPiEstimator,
    TopologyRefresher,
)
from repro.train.trainer import run_mean_estimation


def _one_hot_pi(n, K):
    return np.eye(K)[np.arange(n) % K].astype(float)


def _labels_for(Pi_t, batch, rng):
    K = Pi_t.shape[1]
    return np.stack([rng.choice(K, size=batch, p=Pi_t[i]) for i in range(len(Pi_t))])


# ---------------------------------------------------------------------------
# streaming estimation
# ---------------------------------------------------------------------------

def test_streaming_pi_converges_on_stationary_data():
    """Pi_hat -> Pi on a stationary stream (EW estimator consistency)."""
    rng = np.random.default_rng(0)
    n, K = 12, 4
    Pi = rng.dirichlet(0.5 * np.ones(K), size=n)
    est = StreamingPiEstimator(n, K, beta=0.05)
    for _ in range(400):
        est.update(_labels_for(Pi, 32, rng))
    err = np.abs(est.Pi_hat - Pi).max()
    assert err < 0.05, err
    assert np.allclose(est.Pi_hat.sum(axis=1), 1.0, atol=1e-9)


def test_streaming_pi_tracks_abrupt_drift_geometrically():
    rng = np.random.default_rng(1)
    n, K = 8, 4
    Pi0 = _one_hot_pi(n, K)
    Pi1 = Pi0[::-1].copy()
    est = StreamingPiEstimator(n, K, beta=0.2, init=Pi0)
    for _ in range(50):
        est.update(_labels_for(Pi1, 16, rng))
    # effective window ~2/beta = 10; after 50 updates the old Pi is gone
    assert np.abs(est.Pi_hat - Pi1).max() < 0.05


def test_streaming_pi_masks_absent_nodes():
    n, K = 4, 3
    Pi0 = np.full((n, K), 1.0 / K)
    est = StreamingPiEstimator(n, K, beta=0.5, init=Pi0)
    labels = np.array([[0, 0], [-1, -1], [2, 2], [1, -1]])
    est.update(labels)
    assert np.allclose(est.Pi_hat[1], Pi0[1])          # fully absent: untouched
    assert est.Pi_hat[0, 0] > 0.6                      # observed rows move
    assert est.Pi_hat[3, 1] > 0.6                      # partial batch renormalized
    assert np.allclose(est.Pi_hat.sum(axis=1), 1.0)


def test_streaming_pi_validates_inputs():
    est = StreamingPiEstimator(4, 3)
    with pytest.raises(ValueError):
        est.update(np.zeros((5, 2), np.int64))     # wrong node count
    with pytest.raises(ValueError):
        est.update(np.full((4, 2), 7))             # label out of range
    with pytest.raises(ValueError):
        StreamingPiEstimator(4, 3, beta=0.0)
    with pytest.raises(ValueError):
        StreamingPiEstimator(4, 3, init=np.ones((4, 3)))  # rows don't sum to 1


def test_drift_detector_no_false_positives_on_stationary_stream():
    """FPR pinned at 0 for the default detector on a seeded stationary
    stream: the estimator's sampling noise must stay under the relative
    trigger for the whole run."""
    rng = np.random.default_rng(7)
    n, K = 16, 4
    Pi = _one_hot_pi(n, K)
    res = learn_topology(Pi, budget=8, lam=0.5)
    ctl = OnlineTopologyController(
        TopologyRefresher(res, RefreshConfig(budget=8, lam=0.5)), Pi0=Pi
    )
    for t in range(100):
        ctl.observe(_labels_for(Pi, 16, rng))
        assert ctl.on_segment(t) is None, (t, ctl.events[-1])
    assert ctl.detector.n_triggers == 0
    assert ctl.refresher.n_refreshes == 0


def test_drift_detector_fires_on_abrupt_swap():
    rng = np.random.default_rng(3)
    n, K = 16, 4
    Pi = _one_hot_pi(n, K)
    res = learn_topology(Pi, budget=8, lam=0.5)
    ctl = OnlineTopologyController(
        TopologyRefresher(res, RefreshConfig(budget=8, lam=0.5)), Pi0=Pi
    )
    for t in range(10):
        ctl.observe(_labels_for(Pi, 16, rng))
        ctl.on_segment(t)
    Pi2 = Pi[rng.permutation(n)]
    fired_at = None
    for t in range(10, 40):
        ctl.observe(_labels_for(Pi2, 16, rng))
        if ctl.on_segment(t) is not None:
            fired_at = t
            break
    assert fired_at is not None and fired_at <= 15  # detection within ~5 segments
    assert ctl.refresher.n_refreshes == 1


def test_detector_rebase_and_warmup():
    det = DriftDetector(threshold=1.5, warmup=2)
    assert det.update(1.0) is False        # seeds baseline
    assert det.update(100.0) is False      # still in warmup
    assert det.update(100.0) is True       # fires after warmup
    det.rebase()
    assert det.update(100.0) is False      # fresh baseline, no fire
    with pytest.raises(ValueError):
        DriftDetector(threshold=1.0)


# ---------------------------------------------------------------------------
# ScheduleArrays format
# ---------------------------------------------------------------------------

def _random_schedule(rng, n, n_atoms):
    coeffs = rng.dirichlet(np.ones(n_atoms))
    perms = [tuple(range(n))] + [tuple(rng.permutation(n)) for _ in range(n_atoms - 1)]
    return BirkhoffSchedule(
        coeffs=tuple(float(c) for c in coeffs), perms=tuple(perms)
    )


def test_schedule_to_arrays_roundtrip_and_padding():
    rng = np.random.default_rng(0)
    sched = _random_schedule(rng, 8, 3)
    sa = schedule_to_arrays(sched, l_max=6)
    assert sa.l_max == 6 and sa.n_nodes == 8
    assert np.allclose(arrays_to_matrix(sa), sched.to_matrix(), atol=1e-7)
    # padding atoms: identity perms, zero coefficients
    assert np.allclose(np.asarray(sa.gammas)[3:], 0.0)
    assert np.array_equal(np.asarray(sa.perms)[3:], np.tile(np.arange(8), (3, 1)))
    with pytest.raises(ValueError):
        schedule_to_arrays(sched, l_max=2)


def test_mix_schedule_arrays_matches_static_schedule():
    rng = np.random.default_rng(1)
    n = 8
    sched = _random_schedule(rng, n, 4)
    sa = schedule_to_arrays(sched, l_max=7)
    x = {
        "a": jnp.asarray(rng.normal(size=(n, 5, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
    }
    want = mix_schedule_stacked(x, sched)
    for got in (
        mix_schedule_arrays(x, sa),
        mix_schedule_arrays(x, sa, single_buffer=True),
        # use_kernel must be honored on the arrays path too (Pallas
        # gossip_schedule, interpret mode on CPU), not silently dropped
        mix_schedule_arrays(x, sa, use_kernel=True),
        mix_stacked(x, schedule=sa),
        mix_stacked(x, schedule=sa, use_kernel=True),
        jax.jit(lambda v, s: mix_schedule_arrays(v, s))(x, sa),
    ):
        for k in x:
            np.testing.assert_allclose(got[k], want[k], atol=1e-6)


def test_mix_schedule_arrays_validates_node_count():
    rng = np.random.default_rng(2)
    sa = schedule_to_arrays(_random_schedule(rng, 8, 2), l_max=4)
    with pytest.raises(ValueError):
        mix_schedule_arrays(jnp.zeros((5, 3)), sa)


def test_hot_swap_causes_zero_retraces():
    """Same (l_max, n) shapes => one compiled computation for any W."""
    rng = np.random.default_rng(3)
    n = 8
    sa1 = schedule_to_arrays(_random_schedule(rng, n, 3), l_max=5)
    sa2 = schedule_to_arrays(_random_schedule(rng, n, 5), l_max=5)
    count = [0]

    def f(x, sa):
        count[0] += 1
        return mix_schedule_arrays(x, sa)

    fj = jax.jit(f)
    x = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
    fj(x, sa1)
    out = fj(x, sa2)
    assert count[0] == 1
    want = mix_schedule_arrays(x, sa2)
    np.testing.assert_allclose(out, want, atol=1e-6)


def test_schedule_arrays_never_mix_with_stale_dense_w():
    """Regression: arrays + a (stale) static W must execute the ARRAYS,
    not auto-select the dense transport -- otherwise every online hot
    swap becomes a silent no-op that keeps mixing with yesterday's W."""
    rng = np.random.default_rng(5)
    n = 8
    sched = _random_schedule(rng, n, 6)       # l_max > n/4: dense-favored
    sa = schedule_to_arrays(sched, l_max=6)
    W_stale = jnp.asarray(np.eye(n), jnp.float32)  # a W the swap never updated
    x = {"a": jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)}
    got = mix_stacked(x, W=W_stale, schedule=sa, transport="auto")
    want = mix_schedule_stacked(x, sched)
    np.testing.assert_allclose(got["a"], want["a"], atol=1e-6)
    with pytest.raises(ValueError):
        mix_stacked(x, W=W_stale, schedule=sa, transport="dense")


def test_truncate_schedule_keeps_double_stochasticity():
    rng = np.random.default_rng(4)
    sched = _random_schedule(rng, 10, 7)
    t = truncate_schedule(sched, 3)
    assert t.n_atoms == 3
    W = t.to_matrix()
    assert np.allclose(W.sum(axis=0), 1.0, atol=1e-9)
    assert np.allclose(W.sum(axis=1), 1.0, atol=1e-9)
    # largest coefficients survive
    assert min(t.coeffs) * (1 - 1e-9) >= sorted(sched.coeffs, reverse=True)[3]
    # no-op when already small enough
    assert truncate_schedule(sched, 7) is sched


# ---------------------------------------------------------------------------
# warm refresh
# ---------------------------------------------------------------------------

def test_learn_topology_warm_init_continues_from_previous_w():
    rng = np.random.default_rng(5)
    n, K = 24, 6
    Pi = rng.dirichlet(0.3 * np.ones(K), size=n)
    r0 = learn_topology(Pi, budget=12, lam=0.1)
    Pi2 = Pi[rng.permutation(n)]
    warm = learn_topology(Pi2, budget=12, lam=0.1, init=r0)
    # starts exactly at the previous W's objective on the new Pi
    assert abs(warm.objective_trace[0] - stl_fw_objective(r0.W, Pi2, 0.1)) < 1e-10
    # the decomposition invariant survives the warm start
    np.testing.assert_allclose(warm.rebuild_W(), warm.W, atol=1e-9)
    assert np.all(np.diff(warm.objective_trace) <= 1e-12)
    # incremental and reference agree on the warm path too
    warm_ref = learn_topology(Pi2, budget=12, lam=0.1, init=r0, method="reference")
    np.testing.assert_allclose(
        warm.objective_trace, warm_ref.objective_trace, atol=1e-9
    )


def test_learn_topology_stop_gap_certifies_and_saves_iterations():
    rng = np.random.default_rng(6)
    n, K = 32, 8
    Pi = rng.dirichlet(0.3 * np.ones(K), size=n)
    r0 = learn_topology(Pi, budget=16, lam=0.1)
    Pi2 = Pi[rng.permutation(n)]
    cold = learn_topology(Pi2, budget=48, lam=0.1)
    target = float(cold.gap_trace[-1])
    warm = learn_topology(Pi2, budget=48, lam=0.1, init=r0, stop_gap=target)
    assert warm.gap_trace[-1] <= target * (1 + 1e-9)
    assert len(warm.gap_trace) < 48


def test_gap_trace_last_entry_certifies_returned_w():
    """Regression: a full-budget solve must record the FINAL iterate's
    gap (one extra LMO call), not stop at the pre-update gap of the
    penultimate iterate -- the online refresher's gap_ref target reads
    gap_trace[-1] and would otherwise chase a looser convergence level
    than the topology actually deployed."""
    rng = np.random.default_rng(12)
    Pi = rng.dirichlet(0.3 * np.ones(6), size=24)
    budget = 12
    for method in ("incremental", "reference"):
        res = learn_topology(Pi, budget=budget, lam=0.1, method=method)
        assert len(res.gap_trace) == budget + 1
        # the certificate is the gap AT the returned W: recompute it
        from repro.core.stl_fw import stl_fw_gradient
        from repro.core.assignment import linear_assignment

        grad = stl_fw_gradient(res.W, Pi, 0.1)
        col = linear_assignment(grad)
        want = float(np.sum(grad * res.W) - grad[np.arange(24), col].sum())
        assert abs(res.gap_trace[-1] - want) < 1e-9
    # early-stopped solves already end on the final iterate's gap (the
    # break happens pre-update), so there is no extra certificate entry:
    # one more gap than gammas, from the iteration that broke
    es = learn_topology(Pi, budget=64, lam=0.1, stop_tol=0.1)
    assert len(es.gap_trace) == len(es.gamma_trace) + 1
    assert len(es.gap_trace) < 64


def test_learn_topology_stop_tol_relative_to_initial_gap():
    rng = np.random.default_rng(7)
    Pi = rng.dirichlet(0.3 * np.ones(4), size=16)
    res = learn_topology(Pi, budget=64, lam=0.1, stop_tol=0.1)
    assert len(res.gap_trace) < 64
    assert res.gap_trace[-1] <= 0.1 * res.gap_trace[0] + 1e-15


def test_learn_topology_init_validation():
    Pi = _one_hot_pi(8, 4)
    with pytest.raises(ValueError):
        learn_topology(Pi, 2, init=([1.0], [np.array([0, 1, 2])]))  # wrong n
    with pytest.raises(ValueError):
        learn_topology(Pi, 2, init=([1.0], [np.zeros(8, np.int64)]))  # not a perm
    with pytest.raises(ValueError):
        learn_topology(Pi, 2, init=([], []))
    with pytest.raises(ValueError):
        learn_topology(Pi, 2, init=([-1.0], [np.arange(8)]))


def test_refresher_truncates_to_fixed_capacity_and_reuses_solver():
    rng = np.random.default_rng(8)
    n, K = 16, 4
    Pi = _one_hot_pi(n, K)
    r0 = learn_topology(Pi, budget=6, lam=0.5, lmo="auction")
    ref = TopologyRefresher(r0, RefreshConfig(budget=6, lam=0.5), lmo="auction")
    l_max = ref.l_max
    solver = ref.solver
    for _ in range(3):
        ref.refresh(Pi[rng.permutation(n)])
        sa = ref.schedule_arrays()
        assert sa.l_max == l_max and sa.n_nodes == n
        W = ref.W
        assert np.allclose(W.sum(axis=0), 1.0, atol=1e-9)
        assert np.allclose(W.sum(axis=1), 1.0, atol=1e-9)
    assert ref.solver is solver          # persistent LMO (warm dual prices)
    assert solver.state is not None      # auction state actually carried
    assert ref.n_refreshes == 3


def test_refresher_inherits_lam_and_guards_gap_target():
    """Regression: the default refresher must optimize the SAME Eq. (8)
    objective the initial solve used; an explicitly different lam makes
    the recorded gap incomparable and must discard the gap target."""
    Pi = _one_hot_pi(16, 4)
    r0 = learn_topology(Pi, budget=6, lam=0.5)
    assert r0.lam == 0.5
    ref = TopologyRefresher(r0, RefreshConfig(budget=6))   # lam unspecified
    assert ref.lam == 0.5
    assert ref.gap_ref is not None
    ref_mismatch = TopologyRefresher(r0, RefreshConfig(budget=6, lam=0.1))
    assert ref_mismatch.lam == 0.1
    assert ref_mismatch.gap_ref is None     # different objective: no target
    # a result with no recorded lam could have been solved at ANY lam:
    # its gap is incomparable no matter what the config says
    import dataclasses as _dc
    r_unknown = _dc.replace(r0, lam=None)
    assert TopologyRefresher(r_unknown, RefreshConfig(budget=6, lam=0.5)).gap_ref is None
    assert TopologyRefresher(r_unknown, RefreshConfig(budget=6)).gap_ref is None
    # l_max=0 is invalid capacity, not "use the default"
    with pytest.raises(ValueError):
        TopologyRefresher(r0, RefreshConfig(budget=6, l_max=0))


def test_controller_recovers_objective_after_abrupt_swap():
    rng = np.random.default_rng(9)
    n, K = 24, 6
    Pi = _one_hot_pi(n, K)
    res0 = learn_topology(Pi, budget=6, lam=0.5)
    ref = TopologyRefresher(res0, RefreshConfig(budget=6, lam=0.5))
    ctl = OnlineTopologyController(ref, Pi0=Pi)
    Pi2 = Pi[rng.permutation(n)]
    for t in range(60):
        ctl.observe(_labels_for(Pi2, 16, rng))
        ctl.on_segment(t)
    assert ref.n_refreshes >= 1
    g_frozen = stl_fw_objective(res0.W, Pi2, 0.5)
    g_refreshed = stl_fw_objective(ref.W, Pi2, 0.5)
    g_oracle = stl_fw_objective(learn_topology(Pi2, budget=6, lam=0.5).W, Pi2, 0.5)
    # refreshed topology closes most of the frozen->oracle objective gap
    assert g_refreshed <= g_oracle + 0.35 * (g_frozen - g_oracle)


# ---------------------------------------------------------------------------
# trainer hot-swap plumbing
# ---------------------------------------------------------------------------

def test_mean_estimation_arrays_match_static_schedule():
    task = mean_estimation_clusters(n_nodes=12, K=4)
    Pi = _one_hot_pi(12, 4)
    res = learn_topology(Pi, budget=4, lam=0.5)
    sched = schedule_from_result(res)
    sa = schedule_to_arrays(sched, l_max=8)
    out_static = run_mean_estimation(
        task, None, steps=30, schedule=sched, transport="schedule", seed=3
    )
    out_arrays = run_mean_estimation(task, None, steps=30, schedule=sa, seed=3)
    np.testing.assert_allclose(
        out_static["mean_sq_error"], out_arrays["mean_sq_error"], atol=1e-5
    )
    assert out_arrays["n_traces"] == 1
    # loop rollout traverses the same trajectory
    out_loop = run_mean_estimation(
        task, None, steps=30, schedule=sa, seed=3, rollout="loop"
    )
    np.testing.assert_allclose(
        out_arrays["mean_sq_error"], out_loop["mean_sq_error"], atol=1e-6
    )
    assert out_loop["n_traces"] == 1


def test_mean_estimation_hot_swap_zero_retraces():
    task = mean_estimation_clusters(n_nodes=12, K=4)
    Pi = _one_hot_pi(12, 4)
    sa1 = schedule_to_arrays(
        schedule_from_result(learn_topology(Pi, budget=4, lam=0.5)), l_max=8
    )
    sa2 = schedule_to_arrays(
        schedule_from_result(
            learn_topology(Pi[::-1].copy(), budget=4, lam=0.5)
        ),
        l_max=8,
    )
    seen = []

    def hook(t):
        seen.append(t)
        return sa2 if t == 14 else None

    out = run_mean_estimation(
        task, None, steps=30, schedule=sa1, seed=0,
        on_segment=hook, segment_len=5,
    )
    assert out["swaps"] == [14]
    # no hook call after the final segment: a refresh there would be
    # work whose schedule nothing ever executes
    assert seen == [4, 9, 14, 19, 24]
    assert out["n_traces"] == 1  # THE claim: swap compiled nothing
    with pytest.raises(ValueError):
        run_mean_estimation(
            task, None, steps=10,
            schedule=schedule_from_result(learn_topology(Pi, budget=2, lam=0.5)),
            on_segment=hook,
        )


def test_classification_online_swaps_without_eval_data():
    """Regression: on_segment must fire at eval_every boundaries even
    with no test set (segmenting is decoupled from evaluation), and the
    scan and loop rollouts must agree on the swap schedule."""
    from repro.data.partition import cluster_partition
    from repro.data.synthetic import gaussian_blobs
    from repro.train.trainer import run_classification

    X, y = gaussian_blobs(n_samples=400, num_classes=4, dim=8, seed=0)
    idx, Pi = cluster_partition(y, 8)
    sa1 = schedule_to_arrays(
        schedule_from_result(learn_topology(Pi, budget=4, lam=0.5)), l_max=8
    )
    sa2 = schedule_to_arrays(
        schedule_from_result(learn_topology(Pi[::-1].copy(), budget=4, lam=0.5)),
        l_max=8,
    )

    def make_hook(seen):
        def hook(t):
            seen.append(t)
            return sa2 if t == 10 else None
        return hook

    logs = {}
    for rollout in ("scan", "loop"):
        seen: list[int] = []
        logs[rollout] = run_classification(
            X, y, idx, None, steps=31, eval_every=10, schedule=sa1, seed=0,
            on_segment=make_hook(seen), rollout=rollout,  # note: no X_test
        )
        assert seen == [0, 10, 20], (rollout, seen)      # not just end-of-run
        assert logs[rollout].aux["swaps"] == [10], (rollout, logs[rollout].aux)
    l_scan = [r["loss"] for r in logs["scan"].history]
    l_loop = [r["loss"] for r in logs["loop"].history]
    np.testing.assert_allclose(l_scan, l_loop, atol=1e-6)
    # eval_every=0 stays legal when nothing needs boundaries (regression:
    # the loop rollout's swap condition must not divide by it)
    for rollout in ("scan", "loop"):
        run_classification(
            X, y, idx, None, steps=3, eval_every=0, schedule=sa1, seed=0,
            rollout=rollout,
        )


def test_mean_estimation_online_with_controller_end_to_end():
    """Full pipeline on the simulator: drift -> detect -> warm refresh ->
    hot swap, all inside one compiled rollout."""
    from repro.data.drift import AbruptLabelSwap, labels_stream

    n, K = 12, 4
    steps, seg = 120, 10
    task = mean_estimation_clusters(n_nodes=n, K=K, m=5.0, sigma_tilde2=0.25)
    Pi = _one_hot_pi(n, K)
    # seeded random node permutation: the default half-rotation is a
    # symmetry of this cyclic one-hot Pi (see AbruptLabelSwap docstring)
    scenario = AbruptLabelSwap(
        Pi, t_drift=40, node_perm=np.random.default_rng(11).permutation(n)
    )
    labels = labels_stream(scenario, steps, 8, seed=0)
    # observations follow the drifting cluster assignment
    means = np.asarray(task.cluster_means)
    rngz = np.random.default_rng(1)
    zs = np.stack([
        means[labels[t]] + 0.5 * rngz.normal(size=labels[t].shape)
        for t in range(steps)
    ])
    res0 = learn_topology(Pi, budget=4, lam=0.5)
    ref = TopologyRefresher(res0, RefreshConfig(budget=8, lam=0.5))
    ctl = OnlineTopologyController(ref, Pi0=Pi)
    l_max = ref.l_max
    fed = {"t": 0}

    def hook(t):
        while fed["t"] <= t:
            ctl.observe(labels[fed["t"]])
            fed["t"] += 1
        return ctl.on_segment(t)

    out = run_mean_estimation(
        task, None, steps=steps, schedule=ref.schedule_arrays(), seed=2,
        zs=zs, on_segment=hook, segment_len=seg,
    )
    assert out["n_traces"] == 1
    assert ref.n_refreshes >= 1
    assert len(out["swaps"]) == ref.n_refreshes
    assert all(s >= 40 for s in out["swaps"])  # no refresh before the drift
    assert ref.schedule_arrays().l_max == l_max


# ---------------------------------------------------------------------------
# pool-coordinate swaps + overlapped refresh (ISSUE 5)
# ---------------------------------------------------------------------------

def _small_problem(n=16, K=4, budget=4, seed=0):
    rng = np.random.default_rng(seed)
    Pi = rng.dirichlet(0.3 * np.ones(K), size=n)
    res = learn_topology(Pi, budget=budget, lam=0.1)
    return Pi, res


def test_controller_pool_mode_emits_pool_swaps():
    from repro.core.mixing import PermPool, PoolSwap

    Pi, res0 = _small_problem()
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    pool = PermPool.from_schedule(ref.schedule, capacity=ref.l_max)
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi),
        pool=pool, pool_miss_tol=0.05,
    )
    ctl.request_refresh()  # manual trigger bypasses the detector
    swap = ctl.on_segment(0)
    assert isinstance(swap, PoolSwap)
    # consistency either way the projection went: an in-pool swap's
    # gammas execute on the CURRENT pool, a restage carries the new one
    if swap.restaged:
        assert ctl.pool_misses == 1 and ctl.pool is swap.pool
        assert swap.pool.contains(ref.schedule)
    else:
        assert ctl.pool_misses == 0 and swap.dropped_mass <= 0.05
        assert swap.gammas.shape == (pool.capacity,)
    W = (ctl.pool).to_matrix(swap.gammas)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-5)


def test_controller_pool_miss_restages_with_stable_capacity():
    from repro.core.mixing import PermPool, PoolSwap

    Pi, res0 = _small_problem()
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    # a pool staged from a FOREIGN schedule: the refresh's atoms cannot
    # all be in it => guaranteed miss => restage at the same capacity
    foreign = BirkhoffSchedule(
        coeffs=(0.5, 0.5),
        perms=(tuple(np.roll(np.arange(16), 5)), tuple(np.roll(np.arange(16), 7))),
    )
    pool = PermPool.from_schedule(foreign, capacity=ref.l_max)
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi),
        pool=pool, pool_miss_tol=0.05,
    )
    ctl.request_refresh()
    swap = ctl.on_segment(0)
    assert isinstance(swap, PoolSwap) and swap.restaged
    assert ctl.pool_misses == 1
    assert swap.pool.capacity == pool.capacity  # gamma operand shape stable
    assert swap.gammas.shape == (pool.capacity,)
    assert swap.pool.contains(ctl.refresher.schedule)


def test_overlap_controller_never_blocks_and_lands_swap_later():
    import time as _time

    Pi, res0 = _small_problem()

    class SlowRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            _time.sleep(0.3)
            return super().refresh(Pi_hat)

    ref = SlowRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi), overlap=True
    )
    try:
        ctl.request_refresh()
        t0 = _time.perf_counter()
        assert ctl.on_segment(0) is None          # submit, don't solve inline
        assert _time.perf_counter() - t0 < 0.25, "on_segment blocked on the solve"
        assert ctl.refresh_pending
        assert ctl.on_segment(1) is None          # still pending: no block
        deadline = _time.monotonic() + 5.0
        swap = None
        while swap is None and _time.monotonic() < deadline:
            _time.sleep(0.05)
            swap = ctl.on_segment(2)
        assert swap is not None, "background solve never landed"
        assert not ctl.refresh_pending
        (rec,) = ctl.refresh_log
        assert rec["blocked_s"] == 0.0            # collected, never waited
        assert rec["pending_segments"] >= 1
        assert rec["overlap_wall_s"] >= 0.3
        # while pending the detector was suspended (events say so)
        assert any(e.get("pending") for e in ctl.events)
    finally:
        ctl.close()


def test_overlap_controller_flush_blocks_and_records_honestly():
    import time as _time

    Pi, res0 = _small_problem()

    class SlowRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            _time.sleep(0.25)
            return super().refresh(Pi_hat)

    ref = SlowRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi), overlap=True
    )
    try:
        assert ctl.flush() is None                # nothing in flight
        ctl.request_refresh()
        assert ctl.on_segment(0) is None
        swap = ctl.flush(7)
        assert swap is not None
        (rec,) = ctl.refresh_log
        assert rec["blocked_s"] > 0.0             # the wait is recorded
        assert rec["t_collect"] == 7
    finally:
        ctl.close()


def test_overlap_snapshot_isolates_worker_from_streaming_updates():
    """observe() keeps mutating Pi_hat while the solve runs; the worker
    must see the snapshot taken at submit time."""
    import time as _time

    Pi, res0 = _small_problem()
    seen = {}

    class RecordingRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            seen["Pi"] = np.array(Pi_hat)
            _time.sleep(0.2)
            return super().refresh(Pi_hat)

    ref = RecordingRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    est = StreamingPiEstimator(16, 4, beta=0.9, init=Pi)
    ctl = OnlineTopologyController(ref, estimator=est, overlap=True)
    try:
        ctl.request_refresh()
        snapshot_at_submit = np.array(est.Pi_hat)
        assert ctl.on_segment(0) is None
        # drown the estimator in class-0 labels while the solve runs
        ctl.observe(np.zeros((16, 32), np.int64))
        ctl.observe(np.zeros((16, 32), np.int64))
        ctl.flush()
        np.testing.assert_array_equal(seen["Pi"], snapshot_at_submit)
        assert np.abs(est.Pi_hat - snapshot_at_submit).max() > 0.1
    finally:
        ctl.close()


def test_online_simulator_results_carry_comm_accounting():
    task = mean_estimation_clusters(n_nodes=8, K=4, m=3.0, sigma_tilde2=0.5)
    Pi = _one_hot_pi(8, 4)
    res = learn_topology(Pi, budget=3, lam=0.5)
    sa = schedule_to_arrays(schedule_from_result(res), 6)
    out = run_mean_estimation(task, None, steps=20, schedule=sa, segment_len=5)
    comm = out["comm"]
    # the data-plane (hot-swappable) transport on a mesh is the
    # all-gather: (n-1) * P * 4 bytes per node per step, P=1 here
    assert comm["per_step_bytes"] == 7 * 1 * 4
    assert comm["steps"] == 20
    assert comm["total_bytes"] == 20 * 7 * 4


def test_restage_reports_capacity_truncation_residue():
    """A pool smaller than the refreshed atom set restages with the
    truncation residue reported in dropped_mass -- not a silent 0."""
    from repro.core.mixing import PermPool, PoolSwap

    Pi, res0 = _small_problem()
    ref = TopologyRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    assert ref.schedule.n_atoms > 2
    tiny = PermPool.from_schedule(
        BirkhoffSchedule(coeffs=(1.0,), perms=(tuple(np.roll(np.arange(16), 5)),)),
        capacity=2,
    )
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi),
        pool=tiny, pool_miss_tol=0.05,
    )
    ctl.request_refresh()
    swap = ctl.on_segment(0)
    assert isinstance(swap, PoolSwap) and swap.restaged
    assert swap.pool.capacity == 2
    assert swap.dropped_mass > 0.0            # the truncated atoms' mass
    assert abs(swap.gammas.sum() - 1.0) < 1e-6


# ---------------------------------------------------------------------------
# controller hardening under injected solve faults (ISSUE 6)
# ---------------------------------------------------------------------------

def test_inline_solve_failure_falls_back_to_last_good():
    Pi, res0 = _small_problem()

    class BrokenRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            raise RuntimeError("injected solve failure")

    ref = BrokenRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    W_before = ref.W.copy()
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi)
    )
    ctl.request_refresh()
    assert ctl.on_segment(0) is None          # no raise, no swap
    assert ctl.failed_refreshes == 1
    np.testing.assert_array_equal(ref.W, W_before)  # last-good kept
    (rec,) = ctl.refresh_log
    assert rec["error"].startswith("RuntimeError")
    assert rec["solve_s"] is None
    assert any(e.get("refresh_failed") for e in ctl.events)
    # the detector was re-armed: a later manual trigger still works
    ctl.request_refresh()
    assert ctl.on_segment(1) is None
    assert ctl.failed_refreshes == 2


def test_solve_retries_with_backoff_recover():
    Pi, res0 = _small_problem()
    calls = {"n": 0}

    class FlakyTwice(TopologyRefresher):
        def refresh(self, Pi_hat):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise RuntimeError(f"transient #{calls['n']}")
            return super().refresh(Pi_hat)

    ref = FlakyTwice(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi),
        solve_retries=3, retry_backoff_s=0.001,
    )
    ctl.request_refresh()
    swap = ctl.on_segment(0)
    assert swap is not None                   # third attempt succeeded
    assert calls["n"] == 3
    assert ctl.failed_refreshes == 0
    (rec,) = ctl.refresh_log
    assert rec["attempts"] == 3


def test_solve_retries_exhausted_count_one_failure():
    Pi, res0 = _small_problem()

    class AlwaysBroken(TopologyRefresher):
        def refresh(self, Pi_hat):
            raise RuntimeError("hard failure")

    ref = AlwaysBroken(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi),
        solve_retries=2, retry_backoff_s=0.001,
    )
    ctl.request_refresh()
    assert ctl.on_segment(0) is None
    assert ctl.failed_refreshes == 1
    assert ctl.refresh_log[-1]["attempts"] == 3   # 1 + 2 retries


def test_overlap_worker_failure_collects_as_fallback():
    import time as _time

    Pi, res0 = _small_problem()

    class BrokenRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            raise RuntimeError("worker died")

    ref = BrokenRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi), overlap=True
    )
    try:
        ctl.request_refresh()
        assert ctl.on_segment(0) is None      # submitted
        deadline = _time.monotonic() + 5.0
        while not ctl._pending[0].done() and _time.monotonic() < deadline:
            _time.sleep(0.01)
        assert ctl.on_segment(1) is None      # collect -> fallback, no raise
        assert not ctl.refresh_pending
        assert ctl.failed_refreshes == 1
        assert "worker died" in ctl.refresh_log[-1]["error"]
    finally:
        ctl.close()


def test_flush_reraises_worker_exception_with_metadata():
    from repro.online.refresh import RefreshError

    Pi, res0 = _small_problem()

    class BrokenRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            raise ValueError("bad Pi")

    ref = BrokenRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi), overlap=True
    )
    try:
        ctl.request_refresh()
        assert ctl.on_segment(3) is None
        with pytest.raises(RefreshError) as exc_info:
            ctl.flush(9)
        err = exc_info.value
        assert err.meta["t_submit"] == 3
        assert "bad Pi" in err.meta["error"]
        assert isinstance(err.__cause__, ValueError)
        # pending cleared: training can continue on the last-good W
        assert not ctl.refresh_pending
        assert ctl.failed_refreshes == 1
        assert ctl.flush() is None
    finally:
        ctl.close()


def test_flush_timeout_raises_and_preserves_pending():
    import threading
    import time as _time

    from repro.online.refresh import RefreshTimeoutError

    Pi, res0 = _small_problem()
    release = threading.Event()

    class HangingRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            release.wait(timeout=30.0)
            return super().refresh(Pi_hat)

    ref = HangingRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi), overlap=True
    )
    try:
        ctl.request_refresh()
        assert ctl.on_segment(0) is None
        with pytest.raises(RefreshTimeoutError) as exc_info:
            ctl.flush(1, timeout=0.1)
        assert exc_info.value.meta["t_submit"] == 0
        assert exc_info.value.meta["timeout_s"] == 0.1
        assert ctl.refresh_pending            # the solve is still in flight
        assert ctl.failed_refreshes == 0      # a timeout is not a failure
        release.set()                         # let it finish; now collectable
        swap = ctl.flush(2)
        assert swap is not None
    finally:
        release.set()
        ctl.close()


def test_solve_timeout_abandons_at_boundary_and_rearms():
    import threading
    import time as _time

    Pi, res0 = _small_problem()
    release = threading.Event()

    class HangingRefresher(TopologyRefresher):
        def refresh(self, Pi_hat):
            release.wait(timeout=30.0)
            return super().refresh(Pi_hat)

    ref = HangingRefresher(res0, RefreshConfig(budget=4, lam=0.1))
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi),
        overlap=True, solve_timeout_s=0.05,
    )
    try:
        ctl.request_refresh()
        t0 = _time.perf_counter()
        assert ctl.on_segment(0) is None      # submit
        _time.sleep(0.1)                      # let the timeout elapse
        assert ctl.on_segment(1) is None      # abandon, never block
        assert _time.perf_counter() - t0 < 5.0
        assert not ctl.refresh_pending
        assert ctl.failed_refreshes == 1
        assert "solve_timeout_s" in ctl.refresh_log[-1]["error"]
    finally:
        release.set()
        ctl.close()


def test_flaky_refresher_injects_per_plan():
    from repro.faults import FaultPlan, FlakyRefresher

    Pi, res0 = _small_problem()
    plan = FaultPlan(n_nodes=16, steps=10, seed=5, solve_failure_rate=1.0)
    ref = FlakyRefresher(TopologyRefresher(res0, RefreshConfig(budget=4, lam=0.1)), plan)
    ctl = OnlineTopologyController(
        ref, estimator=StreamingPiEstimator(16, 4, init=Pi)
    )
    ctl.request_refresh()
    assert ctl.on_segment(0) is None
    assert ctl.failed_refreshes == 1
    assert ref.n_injected_failures == 1
    assert "injected solve failure" in ctl.refresh_log[-1]["error"]
    # delegation: the wrapper exposes the inner refresher's surface
    assert ref.schedule_arrays().l_max == ref.l_max

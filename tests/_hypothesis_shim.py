"""Minimal deterministic stand-in for ``hypothesis`` (offline container).

The real ``hypothesis`` cannot be installed here, and six test modules
hard-import it. Rather than skipping those modules wholesale, this shim
implements the tiny surface they use -- ``@given`` / ``@settings`` and the
``integers`` / ``floats`` / ``sampled_from`` strategies -- as a fixed-seed
sweep: each ``@given`` test runs ``max_examples`` times (capped, see below)
with values drawn from a PRNG seeded by the test's qualified name, so runs
are reproducible and failures re-trigger identically.

Differences from real hypothesis (all acceptable for a CI fallback):
  * no shrinking, no example database, no ``@example``;
  * ``max_examples`` is capped at ``_MAX_EXAMPLES_CAP`` to bound suite time;
  * ``deadline`` and other settings are accepted and ignored.

``tests/conftest.py`` registers this module as ``hypothesis`` in
``sys.modules`` only when the real package is missing.
"""

from __future__ import annotations

import random
import types

_MAX_EXAMPLES_CAP = 5


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rnd: elements[rnd.randrange(len(elements))])


def given(*strategies: _Strategy):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n_examples = getattr(wrapper, "_shim_max_examples", _MAX_EXAMPLES_CAP)
            rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n_examples):
                drawn = [s.example_from(rnd) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:  # noqa: BLE001 - re-raise with the example
                    raise AssertionError(
                        f"falsifying example (hypothesis shim): "
                        f"{fn.__qualname__}({', '.join(map(repr, drawn))})"
                    ) from e
            return None

        # deliberately NOT functools.wraps: pytest must see the (*args,
        # **kwargs) signature, not the original one, or it would demand
        # fixtures for the strategy-supplied parameters.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        # inherit a cap set by @settings applied below @given (real
        # hypothesis accepts either decorator order)
        wrapper._shim_max_examples = getattr(
            fn, "_shim_max_examples", _MAX_EXAMPLES_CAP
        )
        return wrapper

    return decorate


def settings(max_examples: int = 10, deadline=None, **_kw):
    def decorate(fn):
        fn._shim_max_examples = min(int(max_examples), _MAX_EXAMPLES_CAP)
        return fn

    return decorate


def build_module() -> types.ModuleType:
    """Assemble ``hypothesis`` + ``hypothesis.strategies`` module objects."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__version__ = "0.0.0-shim"
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    hyp.strategies = st
    return hyp

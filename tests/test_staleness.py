"""Bounded-delay (straggler-tolerant) gossip property suite (ISSUE 8).

The invariants under test:

* ``StragglerPolicy`` semantics: *wait* clamps delays to the deadline
  and never repairs the schedule for staleness; *degrade* zeroes
  past-deadline delays and repairs the schedule on the on-time support
  (late nodes isolated, W exactly doubly stochastic); dead nodes always
  carry effective delay 0.
* degrade repair preserves the node MEAN (column sums stay 1) at both
  the cycle level (``degrade_schedule`` via the policy) and the pool
  level (``degrade_pool_gammas`` stays an exact convex combination).
* ``delays == 0`` reduces every stale transport BITWISE to its fresh
  counterpart -- the flat simulator path, the sharded all-gather path,
  and the staged-pool path (the latter two on a forced-8-device mesh).
* the stale ring (and the EF memory, under compression) ride ONE scan
  carry: a mid-run hot swap under staleness retraces nothing.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import topology as T
from repro.core.mixing import (
    PermPool,
    ScheduleArrays,
    StragglerPolicy,
    degrade_pool_gammas,
    schedule_from_matrix,
    schedule_to_arrays,
    straggler_pool_stream,
    straggler_stream,
)
from repro.data.synthetic import mean_estimation_clusters
from repro.faults import FaultPlan
from repro.train.trainer import run_mean_estimation

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


def _arrays(n: int, l_max: int = 8) -> ScheduleArrays:
    sched = schedule_from_matrix(0.6 * T.ring(n) + 0.4 * np.eye(n))
    return schedule_to_arrays(sched, l_max)


def _dense(arrays: ScheduleArrays) -> np.ndarray:
    g = np.asarray(arrays.gammas, np.float64)
    g = g / g.sum()
    P = np.asarray(arrays.perms)
    n = P.shape[1]
    W = np.zeros((n, n))
    for l in range(len(g)):
        W[np.arange(n), P[l]] += g[l]
    return W


# ----------------------------------------------------------------- policy


def test_policy_wait_clamps_and_never_repairs():
    arrays = _arrays(8)
    pol = StragglerPolicy(mode="wait", tau_max=2)
    assert pol.ring_depth == 3
    delays = np.array([0, 1, 2, 3, 7, 0, 1, 5])
    sa, eff = pol.apply(arrays, delays)
    assert eff.dtype == np.int32
    assert np.array_equal(eff, [0, 1, 2, 2, 2, 0, 1, 2])  # clamped
    # wait never repairs for staleness: schedule untouched
    assert np.array_equal(np.asarray(sa.perms), np.asarray(arrays.perms))
    assert np.array_equal(np.asarray(sa.gammas), np.asarray(arrays.gammas))


def test_policy_degrade_cuts_late_nodes():
    n = 8
    arrays = _arrays(n)
    pol = StragglerPolicy(mode="degrade", tau_max=2)
    delays = np.array([0, 1, 2, 3, 7, 0, 1, 5])
    sa, eff = pol.apply(arrays, delays)
    late = delays > 2
    assert np.array_equal(eff, np.where(late, 0, delays))
    perms = np.asarray(sa.perms)
    for i in np.flatnonzero(late):
        assert (perms[:, i] == i).all()  # late node isolated in every atom
    W = _dense(sa)
    assert np.abs(W.sum(axis=0) - 1.0).max() < 1e-12
    assert np.abs(W.sum(axis=1) - 1.0).max() < 1e-12


def test_policy_dead_nodes_get_zero_delay():
    arrays = _arrays(4)
    pol = StragglerPolicy(mode="wait", tau_max=3)
    alive = np.array([True, False, True, False])
    _, eff = pol.apply(arrays, np.array([2, 2, 0, 3]), alive_mask=alive)
    assert np.array_equal(eff, [2, 0, 0, 0])  # the alive mask governs them


def test_policy_validation():
    with pytest.raises(ValueError):
        StragglerPolicy(mode="barrier")
    with pytest.raises(ValueError):
        StragglerPolicy(tau_max=-1)
    pol = StragglerPolicy()
    arrays = _arrays(4)
    with pytest.raises(ValueError):
        pol.apply(arrays, np.array([0, -1, 0, 0]))
    with pytest.raises(ValueError):
        pol.apply(arrays, np.zeros(5, np.int32))
    hash(pol)  # frozen/hashable: usable as a jit static or dict key


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 12))
def test_degrade_repair_preserves_node_mean(seed, n):
    """Column sums of the repaired W stay exactly 1, so degrade never
    biases the consensus mean: mean(W' x) == mean(x)."""
    rng = np.random.default_rng(seed)
    arrays = _arrays(n)
    pol = StragglerPolicy(mode="degrade", tau_max=1)
    delays = rng.integers(0, 5, size=n)
    sa, _ = pol.apply(arrays, delays)
    W = _dense(sa)
    x = rng.normal(size=(n, 3))
    assert np.abs((W @ x).mean(axis=0) - x.mean(axis=0)).max() < 1e-12


# ------------------------------------------------------------ pool repair


def _pool_and_gammas(n: int = 8, capacity: int = 8):
    sched = schedule_from_matrix(0.6 * T.ring(n) + 0.4 * np.eye(n))
    pool = PermPool.from_schedule(sched, capacity=capacity)
    gammas, residual = pool.project(sched)
    assert residual < 1e-6
    return pool, gammas


def _pool_dense(pool: PermPool, gammas) -> np.ndarray:
    g = np.asarray(gammas, np.float64)
    n = pool.n_nodes
    W = np.zeros((n, n))
    for l, p in enumerate(pool.perms):
        W[np.arange(n), list(p)] += g[l]
    return W


def test_degrade_pool_gammas_mean_preserving():
    pool, gammas = _pool_and_gammas()
    off = np.zeros(8, bool)
    off[[2, 5]] = True
    g2 = degrade_pool_gammas(pool, gammas, off)
    assert abs(g2.sum() - np.asarray(gammas).sum()) < 1e-6  # mass conserved
    W = _pool_dense(pool, g2)
    assert np.abs(W.sum(axis=0) - 1.0).max() < 1e-6
    assert np.abs(W.sum(axis=1) - 1.0).max() < 1e-6
    # offline nodes are fixed points: row/col collapse to the self-loop
    for i in (2, 5):
        e = np.zeros(8)
        e[i] = 1.0
        assert np.allclose(W[i], e, atol=1e-6)
        assert np.allclose(W[:, i], e, atol=1e-6)


# ---------------------------------------------------------------- streams


def test_straggler_stream_zero_delays_is_identity():
    arrays = _arrays(8)
    pol = StragglerPolicy(mode="degrade", tau_max=2)
    g, p, eff = straggler_stream(pol, arrays, np.zeros((5, 8), np.int32))
    assert g.shape == (5, 8) and p.shape == (5, 8, 8) and eff.shape == (5, 8)
    assert not np.asarray(eff).any()
    for t in range(5):
        assert np.array_equal(np.asarray(g[t]), np.asarray(arrays.gammas))
        assert np.array_equal(np.asarray(p[t]), np.asarray(arrays.perms))


def test_straggler_pool_stream_wait_and_degrade():
    pool, gammas = _pool_and_gammas()
    delays = np.zeros((4, 8), np.int64)
    delays[1, 3] = 5  # past any deadline below
    delays[2, 0] = 1  # within deadline
    wait = StragglerPolicy(mode="wait", tau_max=2)
    g_w, e_w = straggler_pool_stream(wait, gammas, pool, delays)
    assert g_w.shape == (4, pool.capacity) and e_w.shape == (4, 8)
    # wait: base gammas every step, delays clamped
    for t in range(4):
        assert np.array_equal(np.asarray(g_w[t]), np.asarray(gammas, np.float32))
    assert int(e_w[1, 3]) == 2 and int(e_w[2, 0]) == 1
    deg = StragglerPolicy(mode="degrade", tau_max=2)
    g_d, e_d = straggler_pool_stream(deg, gammas, pool, delays)
    assert int(e_d[1, 3]) == 0  # late node self-loops with fresh params
    # step 1's repaired gammas isolate node 3; steps 0/3 keep the base
    W1 = _pool_dense(pool, np.asarray(g_d[1], np.float64))
    e3 = np.zeros(8)
    e3[3] = 1.0
    assert np.allclose(W1[3], e3, atol=1e-6)
    assert np.array_equal(np.asarray(g_d[0]), np.asarray(gammas, np.float32))
    with pytest.raises(ValueError):
        straggler_pool_stream(deg, gammas, pool, np.zeros((4, 7), np.int64))
    with pytest.raises(ValueError):
        straggler_pool_stream(deg, gammas, pool, -np.ones((4, 8), np.int64))


# --------------------------------------------- simulator: delays=0 bitwise


@pytest.fixture(scope="module")
def me_problem():
    n = 8
    task = mean_estimation_clusters(n_nodes=n, K=4)
    return task, _arrays(n)


@pytest.mark.parametrize("mode", ["wait", "degrade"])
def test_mean_estimation_zero_delays_bitwise_fresh(me_problem, mode):
    task, arrays = me_problem
    kw = dict(steps=24, schedule=arrays, lr=0.1, seed=7, segment_len=8)
    base = run_mean_estimation(task, None, **kw)
    stale = run_mean_estimation(
        task, None, staleness=StragglerPolicy(mode=mode, tau_max=3), **kw
    )
    for key in ("mean_sq_error", "max_sq_error", "min_sq_error"):
        assert np.array_equal(base[key], stale[key]), key
    assert np.array_equal(base["theta"], stale["theta"])
    assert stale["n_traces"] == 1
    assert stale["comm"]["deferred_bytes"] == 0
    assert stale["comm"]["dropped_bytes"] == 0


def test_mean_estimation_zero_delays_bitwise_with_ef(me_problem):
    """Staleness composed with EF compression: zero delays + identity
    routing still leave the bf16 EF trajectory bitwise unchanged."""
    task, arrays = me_problem
    kw = dict(steps=24, schedule=arrays, lr=0.1, seed=7, segment_len=8,
              compression="bf16")
    base = run_mean_estimation(task, None, **kw)
    stale = run_mean_estimation(
        task, None, staleness=StragglerPolicy(mode="wait", tau_max=2), **kw
    )
    for key in ("mean_sq_error", "max_sq_error", "min_sq_error"):
        assert np.array_equal(base[key], stale[key]), key
    assert stale["n_traces"] == 1


def test_mean_estimation_stale_hot_swap_single_trace(me_problem):
    """Live delays + EF memory + a mid-run topology swap, one trace:
    the stale ring and the EF memory share one scan carry and the swap
    is a pure value change."""
    task, arrays = me_problem
    plan = FaultPlan(n_nodes=8, steps=30, seed=2, straggler_rate=0.5, tau_max=3)
    swapped = schedule_to_arrays(
        schedule_from_matrix(0.5 * T.ring(8) + 0.5 * np.eye(8)),
        int(np.asarray(arrays.gammas).shape[0]),
    )
    hooks = iter([None, swapped])
    out = run_mean_estimation(
        task, None, steps=30, schedule=arrays, lr=0.1, seed=7,
        segment_len=10, compression="bf16",
        staleness=StragglerPolicy(mode="wait", tau_max=3),
        delays=plan.delays, on_segment=lambda t: next(hooks, None),
    )
    assert out["n_traces"] == 1, out["n_traces"]
    assert out["swaps"] == [19]
    assert np.isfinite(out["mean_sq_error"]).all()
    assert out["comm"]["deferred_bytes"] > 0   # stragglers were metered late
    assert out["comm"]["dropped_bytes"] == 0   # wait drops nothing
    deg = run_mean_estimation(
        task, None, steps=30, schedule=arrays, lr=0.1, seed=7,
        segment_len=10, staleness=StragglerPolicy(mode="degrade", tau_max=1),
        delays=plan.delays,
    )
    assert deg["n_traces"] == 1
    assert deg["comm"]["dropped_bytes"] > 0    # degrade converts late to lost
    assert np.isfinite(deg["mean_sq_error"]).all()


def test_mean_estimation_staleness_validation(me_problem):
    task, arrays = me_problem
    with pytest.raises(ValueError, match="delays without staleness"):
        run_mean_estimation(
            task, None, steps=4, schedule=arrays,
            delays=np.zeros((4, 8), np.int32),
        )
    with pytest.raises(ValueError, match="ScheduleArrays"):
        run_mean_estimation(
            task, np.full((8, 8), 1 / 8), steps=4,
            staleness=StragglerPolicy(),
        )
    with pytest.raises(ValueError, match="delays must be"):
        run_mean_estimation(
            task, None, steps=4, schedule=arrays,
            staleness=StragglerPolicy(), delays=np.zeros((3, 8), np.int32),
        )


# -------------------------------------------- sharded transports (8 dev)


def test_sharded_stale_transports_zero_delay_bitwise():
    """On a forced-8-device mesh, both sharded stale transports reduce
    bitwise to their fresh twins at delays=0, and nonzero delays match
    the flat single-host stale reference row-for-row."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.core import topology as T
        from repro.core.mixing import (
            PermPool, mix_arrays_sharded, mix_arrays_sharded_stale,
            mix_ppermute_pool, mix_ppermute_pool_stale,
            mix_schedule_arrays_stale, schedule_from_matrix,
            schedule_to_arrays, shard_stale_init, stale_buffer_init,
            stale_push,
        )

        n, Pdim, depth, steps = 8, 16, 3, 4
        mesh = make_compat_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
        sched = schedule_from_matrix(0.6 * T.ring(n) + 0.4 * np.eye(n))
        arrays = schedule_to_arrays(sched, 8)
        pool = PermPool.from_schedule(sched, capacity=8)
        gammas, _ = pool.project(sched)
        gammas = jnp.asarray(gammas, jnp.float32)
        rng = np.random.default_rng(0)
        xs = jnp.asarray(rng.normal(size=(steps, n, Pdim)), jnp.float32)
        delays = jnp.asarray([0, 1, 2, 0, 1, 2, 0, 1], jnp.int32)
        zeros = jnp.zeros((n,), jnp.int32)

        def rollout(xs_loc, d):
            # xs_loc (steps, 1, Pdim) per shard; d (n,) replicated
            st_ag = shard_stale_init(xs_loc[0] * 0.0, depth)
            st_pool = shard_stale_init(xs_loc[0] * 0.0, depth)
            f_ag, s_ag, f_pl, s_pl = [], [], [], []
            for t in range(steps):
                x = xs_loc[t]
                f_ag.append(mix_arrays_sharded(x, arrays, "data"))
                m, st_ag = mix_arrays_sharded_stale(x, st_ag, arrays, zeros, "data")
                s_ag.append(m)
                f_pl.append(mix_ppermute_pool(x, gammas, pool, "data"))
                m, st_pool = mix_ppermute_pool_stale(
                    x, st_pool, gammas, pool, zeros, "data"
                )
                s_pl.append(m)
            # one more push, read at NONZERO source-indexed delays
            late_ag, st_ag = mix_arrays_sharded_stale(
                xs_loc[-1], st_ag, arrays, d, "data"
            )
            late_pl, st_pool = mix_ppermute_pool_stale(
                xs_loc[-1], st_pool, gammas, pool, d, "data"
            )
            return (jnp.stack(f_ag), jnp.stack(s_ag), jnp.stack(f_pl),
                    jnp.stack(s_pl), late_ag, late_pl)

        with set_mesh(mesh):
            run = jax.jit(shard_map(
                rollout, mesh=mesh,
                in_specs=(P(None, "data"), P()),
                out_specs=tuple(P(None, "data") for _ in range(4))
                          + (P("data"), P("data")),
                axis_names={"data"},
            ))
            f_ag, s_ag, f_pl, s_pl, late_ag, late_pl = run(xs, delays)

        # delays == 0: bitwise the fresh transports, every step
        assert np.array_equal(np.asarray(f_ag), np.asarray(s_ag))
        assert np.array_equal(np.asarray(f_pl), np.asarray(s_pl))
        print("ZERO_DELAY_BITWISE_OK")

        # nonzero delays: match the flat single-host stale reference
        buf = stale_buffer_init(jnp.zeros((n, Pdim)), depth)
        for t in range(steps):
            buf = stale_push(buf, xs[t])
        buf = stale_push(buf, xs[-1])  # the rollout's extra push
        want = mix_schedule_arrays_stale(buf, arrays, delays)
        assert np.allclose(np.asarray(late_ag), np.asarray(want), atol=1e-6), \\
            np.abs(np.asarray(late_ag) - np.asarray(want)).max()
        # and the two sharded transports agree on the same delayed W x
        assert np.allclose(np.asarray(late_ag), np.asarray(late_pl), atol=1e-5)
        print("NONZERO_DELAY_REFERENCE_OK")
    """)
    assert "ZERO_DELAY_BITWISE_OK" in out
    assert "NONZERO_DELAY_REFERENCE_OK" in out


def test_lm_stale_ring_and_ef_share_one_carry():
    """End-to-end LM trainer on a forced-8-device mesh: staleness + EF
    compression + a mid-rollout hot swap run in ONE compiled trace, and
    the delays=0 arm is bitwise the fresh run (losses AND bytes)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.mixing import (
            StragglerPolicy, schedule_from_matrix, schedule_to_arrays,
        )
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((8, 1), ("data", "model"),
                                axis_types=(AxisType.Auto,) * 2)
        cfg = get_smoke_config("qwen3-0.6b")
        sched = schedule_from_matrix(0.6 * T.ring(8) + 0.4 * np.eye(8))
        arrays = schedule_to_arrays(sched, 8)
        swapped = schedule_to_arrays(
            schedule_from_matrix(0.5 * T.ring(8) + 0.5 * np.eye(8)), 8
        )
        steps, seg = 8, 4
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (steps, 8, 2, 32), 0, cfg.vocab_size
        )
        batches = {"tokens": toks, "labels": toks}

        def build(**kw):
            s = make_train_setup(cfg, mesh, mode="dsgd", lr=1e-2,
                                 online_w=True, sharded_transport="allgather",
                                 **kw)
            sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                              s.param_specs,
                              is_leaf=lambda x: isinstance(x, P))
            with set_mesh(mesh):
                p = jax.jit(s.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
                o = s.init_opt_state(p)
            return s, p, o

        pol = StragglerPolicy(mode="wait", tau_max=2)

        # fresh vs staleness-at-zero-delays: bitwise
        s0, p0, o0 = build(compression="bf16")
        with set_mesh(mesh):
            base = s0.run_segments(p0, o0, batches, arrays, segment_len=seg)
        s1, p1, o1 = build(compression="bf16", staleness=pol)
        with set_mesh(mesh):
            zero = s1.run_segments(p1, o1, batches, arrays, segment_len=seg)
        assert np.array_equal(base["losses"], zero["losses"])
        assert base["comm"]["total_bytes"] == zero["comm"]["total_bytes"]
        assert zero["comm"]["deferred_bytes"] == 0
        print("LM_ZERO_BITWISE_OK", zero["n_traces"])

        # live delays + EF + mid-rollout hot swap: one trace
        rng = np.random.default_rng(3)
        delays = (rng.random((steps, 8)) < 0.4) * rng.integers(
            1, 3, size=(steps, 8)
        )
        s2, p2, o2 = build(compression="bf16", staleness=pol)
        hooks = iter([swapped])
        with set_mesh(mesh):
            live = s2.run_segments(
                p2, o2, batches, arrays, segment_len=seg,
                delays=delays.astype(np.int32),
                on_segment=lambda t: next(hooks, None),
            )
        assert live["n_traces"] == 1, live["n_traces"]
        assert live["swaps"] == [3]
        assert np.isfinite(live["losses"]).all()
        assert live["comm"]["deferred_bytes"] > 0
        print("LM_STALE_EF_SWAP_OK")
    """, timeout=600)
    assert "LM_ZERO_BITWISE_OK" in out and "LM_STALE_EF_SWAP_OK" in out


def test_lm_staleness_validation():
    out = run_with_devices("""
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_compat_mesh
        from repro.configs import get_smoke_config
        from repro.core.mixing import StragglerPolicy
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((8, 1), ("data", "model"),
                                axis_types=(AxisType.Auto,) * 2)
        cfg = get_smoke_config("qwen3-0.6b")
        pol = StragglerPolicy(mode="wait", tau_max=2)
        for kw, exc in (
            (dict(mode="fsdp", staleness=pol), ValueError),
            (dict(mode="dsgd", online_w=True, gossip_every=2, staleness=pol),
             ValueError),
            (dict(mode="dsgd", staleness=pol), ValueError),  # needs online_w
            (dict(mode="dsgd", online_w=True, staleness="wait"), TypeError),
        ):
            try:
                make_train_setup(cfg, mesh, lr=1e-2, **kw)
            except exc:
                pass
            else:
                raise AssertionError(f"{kw} did not raise {exc}")
        print("LM_VALIDATION_OK")
    """)
    assert "LM_VALIDATION_OK" in out

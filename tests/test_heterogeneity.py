import numpy as np
import pytest

from repro.core import topology as T
from repro.core.heterogeneity import (
    classes_in_neighborhood,
    label_skew_bias,
    local_heterogeneity,
    neighborhood_bias,
    neighborhood_heterogeneity_mc,
    prop3_bounds,
    tau_bar_label_skew,
    tau_from_prop1,
    variance_term,
)
from repro.data.synthetic import mean_estimation_clusters


def test_example1_exact_values():
    """Paper Example 1 / Appendix A: alternating ring on two clusters."""
    n, m, sig2 = 20, 7.0, 1.0
    W = T.alternating_ring(n)
    mu = np.array([m if i % 2 == 0 else -m for i in range(n)])
    G = (2.0 * (0.0 - mu))[:, None]  # expected grads at theta=0

    # neighborhood bias is exactly 0 (each neighborhood averages to 0)
    assert neighborhood_bias(W, G) == pytest.approx(0.0, abs=1e-12)
    # zeta_bar^2 = 4 m^2 (grows with heterogeneity)
    assert local_heterogeneity(G) == pytest.approx(4 * m**2)

    # H(theta) <= 4 sigma~^2 = tau_bar^2, independent of m (Appendix A)
    def sampler(rng):
        z = rng.normal(mu, np.sqrt(sig2))
        return (2.0 * (0.0 - z))[:, None]

    H = neighborhood_heterogeneity_mc(W, sampler, n_samples=2000, seed=0)
    assert H <= 4 * sig2 + 0.2
    # exact value: 4 sigma~^2 * (1/n)||W - 11^T/n||_F^2
    exact = 4 * sig2 * np.linalg.norm(W - np.ones((n, n)) / n, "fro") ** 2 / n
    assert H == pytest.approx(exact, rel=0.1)


def test_prop1_dominates_mc():
    """tau^2 = (1-p)(zeta^2 + sigma^2) upper bounds measured H(theta)."""
    n, m, sig2 = 12, 3.0, 0.5
    W = T.random_d_regular(n, 3, seed=0)
    task = mean_estimation_clusters(n_nodes=n, K=4, m=m, sigma_tilde2=sig2)
    mu = task.node_means

    def sampler(rng):
        z = rng.normal(mu, np.sqrt(sig2))
        return (2.0 * (1.0 - z))[:, None]  # theta = 1

    H = neighborhood_heterogeneity_mc(W, sampler, n_samples=3000, seed=1)
    G = task.expected_grads(1.0)
    zeta2 = local_heterogeneity(G)
    p = T.mixing_parameter(W)
    bound = tau_from_prop1(p, zeta2, task.sigma_i2)
    assert H <= bound + 1e-6


def test_prop2_closed_form_dominates_mc():
    """Proposition 2's label-skew tau_bar^2 upper bounds measured H."""
    task = mean_estimation_clusters(n_nodes=20, K=5, m=4.0, sigma_tilde2=1.0)
    W = T.random_d_regular(20, 4, seed=3)
    theta = 0.5

    def sampler(rng):
        z = rng.normal(task.node_means, 1.0)
        return (2.0 * (theta - z))[:, None]

    H = neighborhood_heterogeneity_mc(W, sampler, n_samples=3000, seed=2)
    tau2 = tau_bar_label_skew(W, task.Pi, B=task.B, sigma_max2=task.sigma_i2)
    assert H <= tau2 + 1e-6


def test_variance_term_complete_graph_zero():
    assert variance_term(T.complete(10), 5.0) == pytest.approx(0.0, abs=1e-12)


def test_prop3_sandwich():
    for W in (T.ring(10), T.random_d_regular(12, 3, seed=1), T.complete(8)):
        lo, val, hi = prop3_bounds(W)
        assert lo - 1e-9 <= val <= hi + 1e-9


def test_classes_in_neighborhood():
    n, K = 20, 10
    Pi = np.zeros((n, K))
    Pi[np.arange(n), np.arange(n) % K] = 1.0
    W = T.alternating_ring(n)
    counts = classes_in_neighborhood(W, Pi)
    # ring over alternating 10-class layout: self + 2 neighbors = 3 classes
    assert np.all(counts == 3)


def test_label_skew_bias_zero_for_iid():
    n, K = 16, 4
    Pi = np.full((n, K), 1.0 / K)
    for W in (T.ring(n), T.random_d_regular(n, 3, seed=0)):
        assert label_skew_bias(W, Pi) == pytest.approx(0.0, abs=1e-15)

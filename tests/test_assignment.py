"""Edge cases + solver-agreement properties for repro.core.assignment.

The three LMO backends (scipy/JV, numpy hungarian, warm-started auction)
must agree on the achieved objective ``sum_i cost[i, col[i]]`` on every
input -- assignments themselves may differ under exact ties. The auction
additionally guarantees exact optimality of the 1e-12-quantized matrix
via its duality-gap certificate, and its warm-start path must reproduce
cold results bit-for-bit in objective terms.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.assignment import (
    AuctionState,
    auction_assignment,
    hungarian,
    linear_assignment,
    solve_lmo,
)
from repro.core.stl_fw import learn_topology, resolve_lmo_backend


def _obj(cost, col):
    return float(cost[np.arange(len(col)), col].sum())


def _assert_perm(col, n):
    assert sorted(int(c) for c in col) == list(range(n))


ALL_SOLVERS = {
    "scipy": linear_assignment,
    "hungarian": hungarian,
    "auction": lambda c: auction_assignment(c)[0],
}


# ---------------------------------------------------------------------------
# degenerate shapes and values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_n1(name):
    col = ALL_SOLVERS[name](np.array([[3.7]]))
    assert list(col) == [0]


@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_all_equal_costs(name):
    """Fully tied problem: any permutation is optimal; must terminate."""
    for n in (1, 2, 7):
        cost = np.full((n, n), 2.5)
        col = ALL_SOLVERS[name](cost)
        _assert_perm(col, n)
        assert _obj(cost, col) == pytest.approx(2.5 * n)


@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_duplicate_optima(name):
    """Two identical rows -> two optimal assignments with equal value."""
    cost = np.array([
        [1.0, 5.0, 9.0],
        [1.0, 5.0, 9.0],
        [9.0, 9.0, 0.0],
    ])
    col = ALL_SOLVERS[name](cost)
    _assert_perm(col, 3)
    assert _obj(cost, col) == pytest.approx(6.0)  # 1 + 5 + 0, either tie


@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_nonsquare_raises(name):
    with pytest.raises(ValueError):
        ALL_SOLVERS[name](np.zeros((3, 4)))
    with pytest.raises(ValueError):
        ALL_SOLVERS[name](np.zeros(3))


@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_forbidden_entries_feasible(name):
    """+inf marks forbidden pairs; the optimum routes around them."""
    cost = np.array([
        [np.inf, 1.0, 4.0],
        [2.0, np.inf, 6.0],
        [3.0, 8.0, np.inf],
    ])
    col = ALL_SOLVERS[name](cost)
    _assert_perm(col, 3)
    assert np.isfinite(_obj(cost, col))
    assert _obj(cost, col) == pytest.approx(1.0 + 3.0 + 6.0)


@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_forbidden_entries_infeasible(name):
    # rows 0 and 1 both admit only column 0: no feasible assignment, but
    # neither a full row nor a full column is forbidden.
    cost = np.array([
        [1.0, np.inf, np.inf],
        [1.0, np.inf, np.inf],
        [1.0, 1.0, 1.0],
    ])
    with pytest.raises(ValueError):
        ALL_SOLVERS[name](cost)


def test_forbidden_entries_do_not_coarsen_quantization():
    """The +inf sentinel is ~(n+1)x the finite costs; the quantization
    grid must be derived from the finite entries only, or sub-1e-9
    differences between assignments get merged and the auction returns a
    measurably suboptimal matching."""
    rng = np.random.default_rng(11)
    n = 200
    cost = rng.normal(size=(n, n))
    forbidden = rng.random((n, n)) < 0.02
    forbidden[np.arange(n), linear_assignment(cost)] = False  # stay feasible
    cost[forbidden] = np.inf
    col, _ = auction_assignment(cost)
    ref = linear_assignment(cost)
    assert abs(_obj(cost, col) - _obj(cost, ref)) < 1e-9


@pytest.mark.parametrize("name", list(ALL_SOLVERS))
def test_nan_and_neginf_rejected(name):
    for bad in (np.nan, -np.inf):
        cost = np.ones((3, 3))
        cost[1, 2] = bad
        with pytest.raises(ValueError):
            ALL_SOLVERS[name](cost)


# ---------------------------------------------------------------------------
# solver agreement (property test via the hypothesis shim)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 24), st.integers(0, 100_000))
def test_solvers_agree_on_objective(n, seed):
    rng = np.random.default_rng(seed)
    cost = rng.normal(size=(n, n)) * 10.0 ** rng.integers(-6, 6)
    objs = {name: _obj(cost, fn(cost)) for name, fn in ALL_SOLVERS.items()}
    ref = objs["scipy"]
    scale = max(1.0, abs(ref))
    for name, o in objs.items():
        assert abs(o - ref) <= 1e-9 * scale, (name, objs)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 16), st.integers(0, 10_000))
def test_solvers_agree_on_tied_integer_costs(n, seed):
    """Small-integer costs produce many exact ties."""
    rng = np.random.default_rng(seed)
    cost = rng.integers(0, 3, size=(n, n)).astype(np.float64)
    objs = {name: _obj(cost, fn(cost)) for name, fn in ALL_SOLVERS.items()}
    assert len({round(o, 9) for o in objs.values()}) == 1, objs


# ---------------------------------------------------------------------------
# auction specifics: warm start, state threading, exactness
# ---------------------------------------------------------------------------

def test_auction_warm_start_exact_after_perturbation():
    rng = np.random.default_rng(3)
    n = 60
    cost = rng.normal(size=(n, n))
    col, state = auction_assignment(cost)
    for it in range(5):
        gamma = 1.0 / (it + 2)
        cost = (1.0 - gamma) * cost + gamma * rng.normal(size=(n, n))
        col, state = auction_assignment(cost, state.scaled(1.0 - gamma))
        _assert_perm(col, n)
        ref = linear_assignment(cost)
        assert _obj(cost, col) == pytest.approx(_obj(cost, ref), abs=1e-9)


def test_auction_warm_fast_path_identical_cost():
    """Unchanged cost: the carried certificate returns with zero bidding."""
    rng = np.random.default_rng(4)
    cost = rng.normal(size=(32, 32))
    col, state = auction_assignment(cost)
    col2, state2 = auction_assignment(cost, state)
    assert np.array_equal(col, col2)
    assert state2.n_phases == 0 and state2.n_rounds == 0
    assert state2.n_rebid_rows == 0


def test_auction_state_scaled():
    st_ = AuctionState(prices=np.array([1.0, -2.0]), col_of_row=np.array([1, 0]))
    out = st_.scaled(0.5)
    np.testing.assert_allclose(out.prices, [0.5, -1.0])
    assert np.array_equal(out.col_of_row, st_.col_of_row)


def test_auction_ignores_malformed_warm_state():
    rng = np.random.default_rng(5)
    cost = rng.normal(size=(10, 10))
    ref = linear_assignment(cost)
    bad_states = [
        # wrong shape
        AuctionState(prices=np.zeros(4), col_of_row=np.zeros(4, np.int64)),
        # out-of-range column index (not a permutation)
        AuctionState(
            prices=np.zeros(10),
            col_of_row=np.array([0, 1, 2, 3, 4, 5, 6, 7, 8, 15]),
        ),
        # non-finite prices
        AuctionState(prices=np.full(10, np.inf), col_of_row=np.arange(10)),
        # prices from a wildly differently-scaled problem: must fall back
        # to a cold solve instead of bidding the 1e6 spread down eps-wise
        AuctionState(prices=rng.normal(size=10) * 1e6, col_of_row=np.arange(10)),
    ]
    for bad in bad_states:
        col, _ = auction_assignment(cost, bad)
        assert _obj(cost, col) == pytest.approx(_obj(cost, ref), abs=1e-12)


def test_solve_lmo_backends():
    rng = np.random.default_rng(6)
    grad = rng.normal(size=(12, 12))
    ref_P, ref_col = solve_lmo(grad)
    for backend in ("scipy", "hungarian", "auction"):
        P, col = solve_lmo(grad, backend=backend)
        assert float((P * grad).sum()) == pytest.approx(
            float((ref_P * grad).sum()), abs=1e-12
        )
    with pytest.raises(ValueError):
        solve_lmo(grad, backend="simplex")


# ---------------------------------------------------------------------------
# learn_topology integration: backend selection + trajectory equivalence
# ---------------------------------------------------------------------------

def test_resolve_lmo_backend():
    assert resolve_lmo_backend("auto") in ("scipy", "auction")
    assert resolve_lmo_backend("hungarian") == "hungarian"
    with pytest.raises(ValueError):
        resolve_lmo_backend("jv")


@pytest.mark.parametrize("method", ["incremental", "reference"])
def test_learn_topology_auction_matches_scipy_traces(method):
    """The warm-started auction LMO reproduces the reference FW trajectory
    (generic random Pi: the optimum is unique at the quantization grid)."""
    rng = np.random.default_rng(7)
    Pi = rng.dirichlet(np.ones(6) * 0.3, size=36)
    ref = learn_topology(Pi, budget=12, lam=0.2, method=method, lmo="scipy")
    auc = learn_topology(Pi, budget=12, lam=0.2, method=method, lmo="auction")
    np.testing.assert_allclose(
        auc.objective_trace, ref.objective_trace, atol=1e-9
    )
    np.testing.assert_allclose(auc.gamma_trace, ref.gamma_trace, atol=1e-9)
    assert auc.lmo_backend == "auction" and ref.lmo_backend == "scipy"


def test_learn_topology_one_hot_all_backends():
    """Structured one-hot Pi (exactly tied LMO optima): every backend must
    still eliminate bias by l = K - 1 and respect the degree bound."""
    K, n = 5, 30
    Pi = np.zeros((n, K))
    Pi[np.arange(n), np.arange(n) % K] = 1.0
    for backend in ("scipy", "hungarian", "auction"):
        res = learn_topology(Pi, budget=K - 1, lam=0.5, lmo=backend)
        assert res.bias_trace[-1] < 1e-12, backend
        assert np.all(np.diff(res.objective_trace) <= 1e-12), backend

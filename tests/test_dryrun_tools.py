"""Unit tests for the dry-run / roofline tooling (HLO parsing, flops model).

These import ``parse_collectives`` via a fresh module object so the
XLA_FLAGS side effect of repro.launch.dryrun never touches this process.
"""

import importlib.util
import os
import sys
import types

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _load_parse_collectives():
    """Extract parse_collectives without importing the dryrun module
    (which sets XLA_FLAGS at import)."""
    path = os.path.join(SRC, "repro", "launch", "dryrun.py")
    text = open(path).read()
    # cut everything after the function we need, drop the os.environ line
    ns: dict = {}
    import re as _re

    exec("import re", ns)
    start = text.index("_COLLECTIVE_RE")
    end = text.index("def scan_trip_count")
    exec(text[start:end], ns)
    return ns["parse_collectives"]


parse_collectives = _load_parse_collectives()


HLO = """
HloModule test

%body.1 (arg: (f32[16,128], s32[])) -> (f32[16,128], s32[]) {
  %ar1 = bf16[16,512]{1,0} all-reduce(%x), replica_groups={}
  %cp = f32[4,64]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  ROOT %t = tuple()
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %w = while(...), condition=%cond.1, body=%body.1
  %ag = f32[32,256]{1,0} all-gather(%p0), dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(%p0), dimensions={0}
  ROOT %r = f32[8,8] add(%p0, %p0)
}
"""


def test_parse_collectives_loop_weighting():
    out = parse_collectives(HLO, scan_trip=10)
    # in-body ops weighted x10
    assert out["all-reduce"] == 16 * 512 * 2 * 10
    assert out["collective-permute"] == 4 * 64 * 4 * 10
    # entry ops counted once
    assert out["all-gather"] == 32 * 256 * 4
    assert out["all-to-all"] == 8 * 128 * 2
    assert out["total_bytes"] == sum(
        v for k, v in out.items() if k != "total_bytes"
    )


def test_parse_collectives_no_collectives():
    out = parse_collectives("ENTRY %m () -> f32[1] { ROOT %c = f32[1] constant(0) }", 5)
    assert out["total_bytes"] == 0


def test_analytic_flops_scaling():
    from repro.launch.roofline import analytic_flops, param_counts

    # train flops scale ~linearly in tokens; decode ~linearly in batch
    f_train = analytic_flops("qwen3-0.6b", "train_4k")
    f_prefill = analytic_flops("qwen3-0.6b", "prefill_32k")
    f_decode = analytic_flops("qwen3-0.6b", "decode_32k")
    assert f_train > f_prefill > f_decode > 0
    total, active = param_counts("qwen3-0.6b")
    assert total == active  # dense
    t_moe, a_moe = param_counts("qwen3-moe-30b-a3b")
    assert a_moe < t_moe / 3  # 8 of 128 experts active
    # scale sanity: 30B-class total
    assert 25e9 < t_moe < 36e9


def test_roofline_row_structure():
    from repro.launch.roofline import roofline_row

    rec = {
        "status": "ok", "arch": "qwen3-0.6b", "shape": "train_4k",
        "mesh": "16x16", "mode": "dsgd", "scan_trip": 28,
        "memory": {"temp_bytes": 2**30, "argument_bytes": 2**28, "output_bytes": 0},
        "cost": {"flops_per_device_hlo": 1e12, "bytes_accessed_hlo": 1e11},
        "collectives": {"total_bytes": 5e9},
    }
    row = roofline_row(rec)
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["compute_s"] > 0 and row["collective_s"] == 5e9 / 50e9
    assert "advice" in row and len(row["advice"]) > 10

import os
import sys

# src-layout import path (tests run as `PYTHONPATH=src pytest tests/`, but be
# robust when invoked without it).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set --xla_force_host_platform_device_count here -- smoke tests
# and benchmarks must see exactly 1 device. Multi-device behaviour is tested
# in subprocesses (see test_distributed.py).

# The offline container has no `hypothesis`; register the deterministic shim
# so the property-test modules collect and run instead of erroring.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim

    _mod = _hypothesis_shim.build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

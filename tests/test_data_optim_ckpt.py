"""Substrate tests: partitioners, token pipeline, optimizers, checkpoints."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.partition import (
    cluster_partition,
    dirichlet_partition,
    shard_partition,
)
from repro.data.synthetic import gaussian_blobs
from repro.data.tokens import DomainSkewCorpus, TokenBatcher
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.train.checkpoints import CheckpointManager, restore_checkpoint, save_checkpoint


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

def test_shard_partition_properties():
    _, y = gaussian_blobs(n_samples=5000, num_classes=10, seed=0)
    idx, Pi = shard_partition(y, 100, shards_per_node=2, seed=0)
    assert len(idx) == 100
    covered = np.concatenate(idx)
    assert len(covered) == len(y)
    assert len(np.unique(covered)) == len(y)  # exact partition
    assert np.allclose(Pi.sum(1), 1.0)
    # McMahan scheme: most nodes see ~2 classes (up to 4 at boundaries)
    classes_per_node = (Pi > 0).sum(1)
    assert np.median(classes_per_node) <= 3
    assert classes_per_node.max() <= 4


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 40), st.floats(0.05, 5.0), st.integers(0, 99))
def test_dirichlet_partition_valid(n_nodes, alpha, seed):
    _, y = gaussian_blobs(n_samples=2000, num_classes=5, seed=1)
    idx, Pi = dirichlet_partition(y, n_nodes, alpha=alpha, seed=seed)
    covered = np.concatenate([i for i in idx if len(i)])
    assert len(np.unique(covered)) == len(covered)
    assert np.allclose(Pi.sum(1), 1.0)


def test_cluster_partition_one_class_per_node():
    _, y = gaussian_blobs(n_samples=3000, num_classes=10, seed=2)
    idx, Pi = cluster_partition(y, 30, seed=0)
    assert np.all((Pi > 0).sum(1) == 1)


# ---------------------------------------------------------------------------
# token pipeline
# ---------------------------------------------------------------------------

def test_token_batcher_deterministic_and_skewed():
    corpus = DomainSkewCorpus(vocab_size=512, n_domains=4, seed=0)
    Pi = np.eye(4)[[0, 1, 2, 3]].astype(float)
    Pi = 0.9 * Pi + 0.1 / 4
    Pi /= Pi.sum(1, keepdims=True)
    b = TokenBatcher(corpus, Pi, per_node_batch=2, seq_len=64, seed=7)
    x1, y1 = b.next_batch(0)
    x2, y2 = b.next_batch(0)
    np.testing.assert_array_equal(x1, x2)  # counter-seeded: reproducible
    assert x1.shape == (4, 2, 64)
    np.testing.assert_array_equal(x1[:, :, 1:], y1[:, :, :-1])  # shifted labels
    x3, _ = b.next_batch(1)
    assert not np.array_equal(x1, x3)  # different step -> different data
    # domain skew: node token histograms must differ
    h0 = np.bincount(x1[0].ravel(), minlength=512)
    h1 = np.bincount(x1[1].ravel(), minlength=512)
    assert np.abs(h0 - h1).sum() > 0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _rosenbrock_ish(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum(params["b"] ** 2)


@pytest.mark.parametrize("opt", [
    sgd(0.1), sgd(0.05, momentum=0.9), sgd(0.05, momentum=0.9, nesterov=True),
    adamw(0.1), adamw(0.1, weight_decay=0.001),
])
def test_optimizers_decrease_quadratic(opt):
    params = {"w": jnp.ones((4,)), "b": jnp.ones((2,)) * 2.0}
    state = opt.init(params)
    loss0 = _rosenbrock_ish(params)
    for _ in range(60):
        grads = jax.grad(_rosenbrock_ish)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert _rosenbrock_ish(params) < 0.05 * loss0


def test_clip_by_global_norm():
    grads = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(300.0), rel=1e-5)
    from repro.optim import global_norm

    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layers": [{"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}],
        "step_count": jnp.asarray(7, jnp.int32),
        "bf16": jnp.ones((4,), jnp.bfloat16),
    }
    save_checkpoint(str(tmp_path), 5, tree, metadata={"note": "test"})
    restored, meta = restore_checkpoint(str(tmp_path), 5, tree)
    assert meta["note"] == "test"
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_manager_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    tree = {"w": jnp.zeros((2,))}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004"]
    latest = mgr.restore_latest(tree)
    assert latest is not None and latest[0] == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3, 3))})

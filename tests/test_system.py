"""End-to-end behaviour tests for the paper's system.

Full pipeline: heterogeneous data -> Pi -> STL-FW topology -> Birkhoff
schedule -> D-SGD training -> evaluation, plus the theory cross-checks that
tie the measured behaviour back to Theorem 1.
"""

import numpy as np
import pytest

from repro.core import learn_topology, schedule_from_result, topology as T
from repro.core.heterogeneity import label_skew_bias, tau_bar_label_skew
from repro.core.theory import RateInputs, error_bound_convex
from repro.data.partition import shard_partition
from repro.data.synthetic import gaussian_blobs, mean_estimation_clusters
from repro.train.trainer import run_classification, run_mean_estimation


def test_full_pipeline_classification():
    """Data -> partition -> Pi -> STL-FW -> D-SGD -> accuracy."""
    n = 30
    X, y = gaussian_blobs(n_samples=4000, num_classes=10, dim=32, sep=3.0, seed=0)
    idx, Pi = shard_partition(y, n, shards_per_node=2, seed=0)
    res = learn_topology(Pi, budget=9, lam=0.1)
    assert T.max_degree(res.W) <= 9

    # the learned topology's neighborhoods must cover classes better than a
    # random graph of the same budget
    Wr = T.random_d_regular(n, 9, seed=0)
    assert label_skew_bias(res.W, Pi) < label_skew_bias(Wr, Pi)

    log = run_classification(
        X, y, idx, res.W, steps=100, batch_size=32, lr=0.5,
        eval_every=99, X_test=X[:600], y_test=y[:600],
    )
    final = [r for r in log.history if "acc_mean" in r][-1]
    assert final["acc_mean"] > 0.7


def test_theory_error_bound_dominates_measurement():
    """Lemma 4's anytime bound must upper-bound the measured D-SGD error
    (mean estimation task where all constants are exact)."""
    n, K, m = 20, 4, 2.0
    task = mean_estimation_clusters(n_nodes=n, K=K, m=m)
    res = learn_topology(task.Pi, budget=6, lam=0.5)
    W = res.W
    p = T.mixing_parameter(W)
    tau2 = tau_bar_label_skew(W, task.Pi, B=task.B, sigma_max2=task.sigma_i2)

    steps = 50
    out = run_mean_estimation(task, W, steps=steps, lr=0.05, seed=0)
    # measured average suboptimality f(theta_bar) - f*:
    # for F = (theta - z)^2, f(t) - f* = (t - theta*)^2
    measured = float(np.mean(out["mean_sq_error"]))

    c = RateInputs(
        L=task.L, sigma_bar2=task.sigma_i2, tau_bar2=tau2, p=p, n=n,
        r0=task.theta_star**2 + float(np.mean(task.node_means**2)),
    )
    bound = error_bound_convex(c, steps)
    assert measured <= bound + 1e-6


def test_birkhoff_schedule_roundtrip_system():
    """Learned topology -> schedule -> matrix roundtrip, and the schedule's
    communication cost (atoms) stays within the budget."""
    task = mean_estimation_clusters(n_nodes=16, K=4, m=3.0)
    res = learn_topology(task.Pi, budget=4, lam=0.3)
    sched = schedule_from_result(res)
    assert np.allclose(sched.to_matrix(), res.W, atol=1e-9)
    assert sched.n_communication_atoms <= 4
    # running D-SGD with the schedule-reconstructed matrix is identical
    out_a = run_mean_estimation(task, res.W, steps=15, lr=0.2, seed=0)
    out_b = run_mean_estimation(task, sched.to_matrix(), steps=15, lr=0.2, seed=0)
    np.testing.assert_allclose(out_a["theta"], out_b["theta"], atol=1e-6)

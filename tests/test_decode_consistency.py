"""Decode path == full forward, for every architecture family.

Prefill S-1 tokens through the cache, decode the final token, and compare
its logits against the full-sequence forward. Exercises full KV caches,
window ring buffers, MLA latent caches and all recurrent states.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import init_model, transformer
from repro.models import whisper as wmod

S = 24
TOL = 2e-3


@pytest.mark.parametrize("name", list(ARCH_IDS))
def test_decode_matches_full_forward(name):
    cfg = get_smoke_config(name)
    params = init_model(jax.random.PRNGKey(1), cfg)
    B = 2
    if cfg.arch_type == "audio":
        frames = (
            jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder.num_frames, cfg.d_model)) * 0.1
        )
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
        full_logits, _, _ = wmod.whisper_forward(params, cfg, frames, toks)
        enc = wmod.encode(params, cfg, frames)
        cache = wmod.init_whisper_cache(cfg, B, S + 8, enc)
        pos = jnp.broadcast_to(jnp.arange(S - 1)[None], (B, S - 1))
        _, cache, _ = wmod.whisper_forward(
            params, cfg, None, toks[:, : S - 1], cache=cache, positions=pos
        )
        dec_logits, _, _ = wmod.whisper_forward(
            params, cfg, None, toks[:, S - 1 : S], cache=cache,
            positions=jnp.full((B, 1), S - 1),
        )
        err = float(jnp.max(jnp.abs(dec_logits[:, 0] - full_logits[:, -1])))
    else:
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
        img = None
        if cfg.arch_type == "vlm":
            img = (
                jax.random.normal(
                    jax.random.PRNGKey(4), (B, cfg.vision.num_patches, cfg.d_model)
                ) * 0.1
            )
        full_logits, _, _ = transformer.forward(params, cfg, toks, image_embeds=img)
        total = S + (cfg.vision.num_patches if img is not None else 0)
        cache = transformer.init_cache(cfg, B, total + 8)
        pos = jnp.broadcast_to(jnp.arange(total - 1)[None], (B, total - 1))
        _, cache, _ = transformer.forward(
            params, cfg, toks[:, : S - 1], image_embeds=img, cache=cache, positions=pos
        )
        dec_logits, _, _ = transformer.forward(
            params, cfg, toks[:, S - 1 : S], cache=cache,
            positions=jnp.full((B, 1), total - 1),
        )
        err = float(jnp.max(jnp.abs(dec_logits[:, 0] - full_logits[:, -1])))
    assert err < TOL, f"{name}: decode/full mismatch {err}"


def test_long_context_window_decode():
    """Sub-quadratic decode: window ring caches must match the window-masked
    full forward once the context exceeds the window."""
    import dataclasses

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-0.6b"), long_context_window=8
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = transformer.forward(
        params, cfg, toks, window_override=cfg.long_context_window
    )
    cache = transformer.init_cache(cfg, B, S + 4, long_context=True)
    pos = jnp.broadcast_to(jnp.arange(S - 1)[None], (B, S - 1))
    _, cache, _ = transformer.forward(
        params, cfg, toks[:, : S - 1], cache=cache, positions=pos,
        window_override=cfg.long_context_window,
    )
    dec_logits, _, _ = transformer.forward(
        params, cfg, toks[:, S - 1 :], cache=cache,
        positions=jnp.full((B, 1), S - 1),
        window_override=cfg.long_context_window,
    )
    err = float(jnp.max(jnp.abs(dec_logits[:, 0] - full_logits[:, -1])))
    assert err < TOL

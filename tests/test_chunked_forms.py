"""Memory-bounded (chunked) compute forms == dense reference forms."""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as att
import repro.models.xlstm as xl
from repro.models.common import MLAConfig, ModelConfig


def _cfg(**kw):
    base = dict(
        name="t", arch_type="dense", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("window", [None, 300])
def test_sdpa_chunked_matches_dense(window):
    rng = np.random.default_rng(0)
    cfg = _cfg()
    B, S, H, Hkv, D = 2, 1024, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    dense = att._sdpa(q, k, v, att._causal_mask(S, S, window), cfg)
    chunked = att._sdpa_chunked(q, k, v, cfg, window, chunk_q=256)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), atol=2e-5)


def test_sdpa_chunked_softcap():
    rng = np.random.default_rng(1)
    cfg = _cfg(attn_logit_softcap=30.0)
    B, S = 1, 1024
    q = jnp.asarray(rng.normal(size=(B, S, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, 16)), jnp.float32)
    dense = att._sdpa(q, k, v, att._causal_mask(S, S, None), cfg)
    chunked = att._sdpa_chunked(q, k, v, cfg, None, chunk_q=128)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), atol=2e-5)


def test_mlstm_chunkwise_matches_parallel():
    rng = np.random.default_rng(2)
    B, H, S, Dh = 2, 3, 512, 16
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, Dh)), jnp.float32) for _ in range(3))
    it = jnp.asarray(rng.normal(size=(B, H, S)), jnp.float32)
    ft = jnp.asarray(rng.normal(size=(B, H, S)) + 2.0, jnp.float32)
    par = xl._mlstm_parallel(q, k, v, it, ft)
    chw = xl._mlstm_chunkwise(q, k, v, it, ft, chunk=64)
    np.testing.assert_allclose(np.asarray(par), np.asarray(chw), atol=5e-3)


def test_mla_chunked_matches_dense():
    rng = np.random.default_rng(3)
    mla = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    cfg = _cfg(mla=mla, num_heads=4, num_kv_heads=4)
    import jax

    from repro.models.attention import init_mla_attention, mla_attention
    import repro.models.attention as A

    params = init_mla_attention(jax.random.PRNGKey(0), cfg)
    B, S = 1, 1024
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3, jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    # dense path (below threshold)
    thresh = A._CHUNK_THRESHOLD
    A._CHUNK_THRESHOLD = 10**9
    dense, _ = mla_attention(params, cfg, x, positions=positions)
    A._CHUNK_THRESHOLD = 0
    try:
        chunked, _ = mla_attention(params, cfg, x, positions=positions)
    finally:
        A._CHUNK_THRESHOLD = thresh
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), atol=2e-4)


def test_fused_unembed_xent_matches_direct():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.models.transformer import forward, fused_unembed_xent, softmax_xent
    from repro.models.layers import unembed

    cfg = get_smoke_config("qwen3-0.6b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 1024  # multiple of the xent chunk
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    hidden, _, _ = forward(params, cfg, toks, return_hidden=True)
    fused = fused_unembed_xent(params, cfg, hidden, labels)
    direct = softmax_xent(unembed(params["embed"], hidden, cfg), labels)
    assert float(jnp.abs(fused - direct)) < 1e-4

"""rglru_scan kernel vs oracle: shape/dtype sweeps + model consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref


def _ab(rng, B, S, D, dtype):
    a = jnp.asarray(rng.uniform(0.6, 0.999, (B, S, D)), dtype)
    b = jnp.asarray(rng.normal(size=(B, S, D)) * 0.2, dtype)
    return a, b


@pytest.mark.parametrize("B,S,D", [(1, 256, 128), (2, 512, 512), (1, 1000, 300), (3, 300, 700)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_grid(B, S, D, dtype):
    rng = np.random.default_rng(B * S + D)
    a, b = _ab(rng, B, S, D, dtype)
    out = rglru_scan(a, b, block_s=256, block_d=512)
    ref = rglru_scan_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(20, 600), st.integers(16, 256), st.integers(0, 99))
def test_rglru_scan_hypothesis(B, S, D, seed):
    rng = np.random.default_rng(seed)
    a, b = _ab(rng, B, S, D, jnp.float32)
    out = rglru_scan(a, b, block_s=128, block_d=128)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_rglru_scan_matches_model_block_recurrence():
    """The kernel computes the same recurrence the RG-LRU block uses."""
    from repro.models.common import ModelConfig
    from repro.models.rglru import init_rglru_block, rglru_block

    cfg = ModelConfig(
        name="t", arch_type="hybrid", num_layers=1, d_model=128, num_heads=4,
        num_kv_heads=1, d_ff=128, vocab_size=64, rnn_width=128,
        layer_pattern=("rglru",),
    )
    params = init_rglru_block(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # reconstruct (a, b) exactly as the block does, then compare scans
    x = jnp.asarray(rng.normal(size=(2, 64, 128)) * 0.3, jnp.float32)
    from repro.models.layers import rms_norm
    from repro.models.rglru import _causal_conv1d, _C

    xn = rms_norm(params["norm"], x, cfg.norm_eps)
    rnn_in, _ = _causal_conv1d(xn @ params["w_rnn_in"], params["conv_w"], None)
    r = jax.nn.sigmoid((rnn_in @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((rnn_in @ params["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * rnn_in.astype(jnp.float32))
    h_kernel = rglru_scan(a, b, block_s=32, block_d=128)
    h_ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_ref), atol=1e-5)


def test_rglru_scan_stability_long_sequence():
    """a < 1 everywhere: state must stay bounded over long scans."""
    rng = np.random.default_rng(5)
    a = jnp.full((1, 2048, 128), 0.99, jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, 2048, 128)) * 0.01, jnp.float32)
    out = rglru_scan(a, b)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.abs(out).max()) < 10.0

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core.dcliques import d_cliques


ALL_TOPOLOGIES = [
    ("complete", lambda: T.complete(12)),
    ("ring", lambda: T.ring(12)),
    ("alternating_ring", lambda: T.alternating_ring(12)),
    ("random_3_regular", lambda: T.random_d_regular(12, 3, seed=0)),
    ("random_9_regular", lambda: T.random_d_regular(100, 9, seed=1)),
    ("exponential", lambda: T.exponential_graph(100)),
    ("exponential_directed", lambda: T.exponential_graph(16, undirected=False)),
    ("star", lambda: T.star(9)),
    ("torus", lambda: T.torus(3, 4)),
    ("disconnected", lambda: T.disconnected(7)),
]


@pytest.mark.parametrize("name,builder", ALL_TOPOLOGIES)
def test_doubly_stochastic(name, builder):
    W = builder()
    assert T.is_doubly_stochastic(W), name


def test_mixing_parameter_extremes():
    assert T.mixing_parameter(T.complete(8)) == pytest.approx(1.0)
    assert T.mixing_parameter(T.disconnected(8)) == pytest.approx(0.0)
    p_ring = T.mixing_parameter(T.ring(8))
    assert 0.0 < p_ring < 1.0


def test_exponential_graph_degree_n100():
    # Ying et al. undirected construction at n=100 -> d_max = 14 (paper Sec 6)
    W = T.exponential_graph(100)
    assert T.max_degree(W) == 14


def test_degrees():
    W = T.random_d_regular(20, 5, seed=2)
    assert np.all(T.in_degrees(W) == 5)
    assert np.all(T.out_degrees(W) == 5)
    assert T.max_degree(W) == 5


def test_self_loop_lazy():
    W = T.ring(10)
    L = T.self_loop_lazy(W, 0.5)
    assert T.is_doubly_stochastic(L)
    assert T.mixing_parameter(L) <= T.mixing_parameter(W) + 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 24), st.integers(0, 10_000))
def test_metropolis_hastings_random_graphs(n, seed):
    rng = np.random.default_rng(seed)
    A = rng.random((n, n)) < 0.4
    A = A | A.T
    np.fill_diagonal(A, False)
    W = T.metropolis_hastings(A)
    assert T.is_doubly_stochastic(W)
    assert np.allclose(W, W.T)


def test_dcliques_doubly_stochastic_and_low_bias():
    n, K = 40, 10
    Pi = np.zeros((n, K))
    Pi[np.arange(n), np.arange(n) % K] = 1.0
    W = d_cliques(Pi, clique_size=K, seed=0)
    assert T.is_doubly_stochastic(W)
    from repro.core.heterogeneity import label_skew_bias

    # cliques cover all classes -> bias far below a random regular graph
    Wr = T.random_d_regular(n, K - 1, seed=0)
    assert label_skew_bias(W, Pi) < label_skew_bias(Wr, Pi)

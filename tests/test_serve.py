"""Serving engine: greedy generation across architecture families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.serve.engine import decode_step, generate, prefill


@pytest.mark.parametrize("name", ["qwen3-0.6b", "gemma2-2b", "xlstm-350m",
                                  "recurrentgemma-2b", "deepseek-v2-236b"])
def test_generate_shapes(name):
    cfg = get_smoke_config(name)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_generate_vlm_with_image():
    cfg = get_smoke_config("llava-next-mistral-7b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab_size)
    img = jax.random.normal(jax.random.PRNGKey(2), (1, cfg.vision.num_patches, cfg.d_model)) * 0.1
    out = generate(params, cfg, prompt, max_new_tokens=4, image_embeds=img)
    assert out.shape == (1, 4)


def test_generate_audio():
    cfg = get_smoke_config("whisper-small")
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.encoder.num_frames, cfg.d_model)) * 0.1
    out = generate(params, cfg, prompt, max_new_tokens=4, frames=frames)
    assert out.shape == (2, 4)


def test_greedy_generation_matches_stepwise_full_forward():
    """The cached decode trajectory equals argmax over repeated full
    forwards (the gold reference for cache correctness)."""
    from repro.models import transformer

    cfg = get_smoke_config("gemma2-2b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, cfg.vocab_size)
    n_new = 5
    out_engine = generate(params, cfg, prompt, max_new_tokens=n_new)

    toks = prompt
    ref = []
    for _ in range(n_new):
        logits, _, _ = transformer.forward(params, cfg, toks)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        ref.append(nxt)
        toks = jnp.concatenate([toks, nxt], axis=1)
    ref = jnp.concatenate(ref, axis=1)
    np.testing.assert_array_equal(np.asarray(out_engine), np.asarray(ref))


def test_long_context_generation_runs():
    cfg = get_smoke_config("recurrentgemma-2b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, max_new_tokens=4, long_context=True)
    assert out.shape == (1, 4)

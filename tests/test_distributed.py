"""Multi-device distribution tests (subprocess: forced host devices).

These run in subprocesses because the main pytest process must keep seeing
exactly 1 device (jax locks device count at first init).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_ppermute_gossip_equals_dense_mixing():
    """The sharded Birkhoff-ppermute transport must equal the dense W-matmul
    transport (same mixing matrix) on real multi-device buffers."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.core import topology as T
        from repro.core.mixing import schedule_from_matrix, mix_ppermute, mix_dense

        mesh = make_compat_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        W = T.ring(8)
        sched = schedule_from_matrix(W)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), jnp.float32)

        def gossip(v):
            def inner(p):
                return mix_ppermute(p, sched, "data")
            return shard_map(inner, mesh=mesh, in_specs=(P("data"),),
                                 out_specs=P("data"), axis_names={"data"})(v)

        with set_mesh(mesh):
            got = np.asarray(jax.jit(gossip)(x))
        want = np.asarray(mix_dense(x, jnp.asarray(W, jnp.float32)))
        assert np.allclose(got, want, atol=1e-5), np.abs(got - want).max()
        print("PPERMUTE_OK")
    """)
    assert "PPERMUTE_OK" in out


def test_sharded_dsgd_step_runs_and_learns():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.configs import get_smoke_config
        from repro.core import learn_topology, schedule_from_result
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        Pi = np.eye(2)[np.arange(4) % 2].astype(float)
        sched = schedule_from_result(learn_topology(Pi, budget=2, lam=0.5))
        setup = make_train_setup(cfg, mesh, mode="dsgd", schedule=sched, lr=2e-2)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), setup.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        with set_mesh(mesh):
            params = jax.jit(setup.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            batch = {k: jnp.zeros((4, 2, 32), jnp.int32) for k in ("tokens", "labels")}
            step = jax.jit(setup.train_step)
            losses = []
            for _ in range(6):
                params, _, loss = step(params, None, batch)
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print("DSGD_SHARDED_OK", losses[0], losses[-1])
    """)
    assert "DSGD_SHARDED_OK" in out


def test_gossip_every_k_amortization():
    """gossip_every=k: consensus collapses exactly on gossip steps and
    drifts on local-only steps (time-varying W^(t), EXPERIMENTS.md §Perf A)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.mixing import schedule_from_matrix
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        sched = schedule_from_matrix(T.complete(4))
        setup = make_train_setup(cfg, mesh, mode="dsgd", schedule=sched,
                                 lr=1e-2, gossip_every=3)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), setup.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        with set_mesh(mesh):
            params = jax.jit(setup.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 32), 0, cfg.vocab_size)
            batch = {"tokens": toks, "labels": toks}
            opt = {"step": jnp.zeros((), jnp.int32), "m": None}
            step = jax.jit(setup.train_step)
            cons = []
            for t in range(4):
                params, opt, loss = step(params, opt, batch)
                leaf = jax.tree_util.tree_leaves(params)[1]
                mean = jnp.mean(leaf, 0, keepdims=True)
                cons.append(float(jnp.sum(((leaf - mean).astype(jnp.float32))**2)))
        assert cons[0] < 1e-9 and cons[3] < 1e-9, cons  # gossip steps
        assert cons[1] > 1e-9 and cons[2] > 1e-9, cons  # local-only steps
        print("GOSSIP_EVERY_OK")
    """)
    assert "GOSSIP_EVERY_OK" in out


def test_multi_step_scan_bitwise_equals_loop():
    """The scanned multi-step train fn (lax.scan over k inner steps, mix in
    the carry, gossip_every + grad-accum inside) must be bitwise-equivalent
    to stepping the same jitted train_step from Python."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.mixing import schedule_from_matrix
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        sched = schedule_from_matrix(T.ring(4))
        setup = make_train_setup(cfg, mesh, mode="dsgd", schedule=sched,
                                 lr=1e-2, momentum=0.9, gossip_every=2,
                                 grad_accum=2)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), setup.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        k = 4
        with set_mesh(mesh):
            params = jax.jit(setup.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (k, 4, 4, 32), 0, cfg.vocab_size)
            batches = {"tokens": toks, "labels": toks}
            zeros_m = jax.tree.map(jnp.zeros_like, params)
            opt = {"step": jnp.zeros((), jnp.int32), "m": zeros_m}

            scan_fn = jax.jit(setup.multi_step_fn("scan"))
            p_scan, opt_scan, loss_scan = scan_fn(params, opt, batches)

            loop_fn = setup.multi_step_fn("loop")
            p_loop, opt_loop, loss_loop = loop_fn(params, opt, batches)

        for a, b in zip(jax.tree.leaves(p_scan), jax.tree.leaves(p_loop)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), "params diverged"
        for a, b in zip(jax.tree.leaves(opt_scan), jax.tree.leaves(opt_loop)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), "opt state diverged"
        assert np.array_equal(np.asarray(loss_scan), np.asarray(loss_loop)), "losses"
        assert int(opt_scan["step"]) == k
        print("MULTI_STEP_BITWISE_OK", [float(x) for x in np.asarray(loss_scan)])
    """)
    assert "MULTI_STEP_BITWISE_OK" in out


def test_fsdp_step_matches_loss_of_dsgd_complete():
    """fsdp (C-PSGD) and dsgd-with-complete-graph start from the same init
    and identical data => identical first-step loss."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.configs import get_smoke_config
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("gemma-2b")
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (8, 32), 0, cfg.vocab_size))
        with set_mesh(mesh):
            s_f = make_train_setup(cfg, mesh, mode="fsdp", lr=1e-2)
            p_f = jax.jit(s_f.init_params)(jax.random.PRNGKey(0))
            bf = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
            _, _, loss_f = jax.jit(s_f.train_step)(p_f, None, bf)

            s_d = make_train_setup(cfg, mesh, mode="dsgd", schedule=None, lr=1e-2)
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), s_d.param_specs,
                              is_leaf=lambda x: isinstance(x, P))
            # init unsharded then device_put: out_shardings= would partition
            # the threefry calls, which changes the drawn values on JAX
            # installs where jax_threefry_partitionable defaults to False --
            # and this test needs bit-identical init across both modes.
            p_d = jax.device_put(jax.jit(s_d.init_params)(jax.random.PRNGKey(0)), sh)
            bd = {"tokens": jnp.asarray(toks.reshape(4, 2, 32)),
                  "labels": jnp.asarray(toks.reshape(4, 2, 32))}
            _, _, loss_d = jax.jit(s_d.train_step)(p_d, None, bd)
        assert abs(float(loss_f) - float(loss_d)) < 1e-2, (float(loss_f), float(loss_d))
        print("MODES_CONSISTENT", float(loss_f), float(loss_d))
    """)
    assert "MODES_CONSISTENT" in out


def test_online_w_matches_static_schedule_and_swaps_without_retrace():
    """The online-adaptation step (W as data, all-gather mixing) must equal
    the static ppermute-schedule step on the same W, and a W hot-swap
    through the scanned multi-step must compile nothing new."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh
        from repro.configs import get_smoke_config
        from repro.core import learn_topology, schedule_from_result
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        Pi = np.eye(2)[np.arange(4) % 2].astype(float)
        sched = schedule_from_result(learn_topology(Pi, budget=2, lam=0.5))
        W = jnp.asarray(sched.to_matrix(), jnp.float32)

        s_static = make_train_setup(cfg, mesh, mode="dsgd", schedule=sched, lr=2e-2)
        s_online = make_train_setup(cfg, mesh, mode="dsgd", online_w=True, lr=2e-2)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), s_static.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        with set_mesh(mesh):
            params = jax.jit(s_static.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            toks = np.random.default_rng(0).integers(0, 50, size=(4, 2, 32))
            batch = {k: jnp.asarray(toks, jnp.int32) for k in ("tokens", "labels")}
            p1, _, l1 = jax.jit(s_static.train_step)(params, None, batch)
            p2, _, l2 = jax.jit(s_online.train_step)(params, None, batch, W)
            d = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
            assert d < 2e-5, d
            assert abs(float(l1) - float(l2)) < 1e-5

            n_traces = [0]
            ms = s_online.multi_step_fn("scan")
            def counted(p, m, b, w):
                n_traces[0] += 1
                return ms(p, m, b, w)
            msj = jax.jit(counted)
            batches = {k: jnp.stack([batch[k]] * 3) for k in batch}
            p, _, _ = msj(params, None, batches, W)
            W2 = jnp.full((4, 4), 0.25, jnp.float32)   # hot swap: uniform W
            p, _, losses2 = msj(p, None, batches, W2)
            assert n_traces[0] == 1, n_traces          # swap retraced nothing
            assert np.isfinite(np.asarray(losses2)).all()
        print("ONLINE_W_OK", d)
    """)
    assert "ONLINE_W_OK" in out


def test_staged_pool_bitwise_equals_allgather_and_swaps_without_retrace():
    """The staged-ppermute pool transport must equal the all-gather
    ScheduleArrays transport BITWISE on the same schedule (slot-for-slot
    identical accumulation), and >= 3 consecutive in-pool gamma swaps
    through run_segments must compile nothing; a forced pool miss must
    cost exactly one counted recompile."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.mixing import (BirkhoffSchedule, PermPool, PoolSwap,
                                       schedule_from_matrix, mix_ppermute_pool,
                                       mix_arrays_sharded)
        from repro.train.lm_trainer import make_train_setup

        mesh1 = make_compat_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        sched = schedule_from_matrix(T.ring(8))
        pool = PermPool.from_schedule(sched, capacity=6)
        g, dropped = pool.project(sched)
        assert dropped == 0.0
        arrays = pool.arrays_for(g)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 37)), jnp.float32)
        gj = jnp.asarray(g)

        def run(fn):
            return jax.jit(shard_map(fn, mesh=mesh1, in_specs=(P("data"),),
                                     out_specs=P("data"), axis_names={"data"},
                                     check_vma=False))(x)

        got_pool = np.asarray(run(lambda v: mix_ppermute_pool(v, gj, pool, "data")))
        got_ag = np.asarray(run(lambda v: mix_arrays_sharded(v, arrays, "data")))
        assert np.array_equal(got_pool, got_ag), np.abs(got_pool - got_ag).max()
        want = T.ring(8) @ np.asarray(x)
        assert np.allclose(got_pool, want, atol=1e-5)

        mesh = make_compat_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        setup = make_train_setup(cfg, mesh, mode="dsgd", online_w=True,
                                 sharded_transport="pool", pool=pool, lr=1e-2)
        assert setup.sharded_transport == "pool"
        assert setup.comm_bytes_per_step > 0
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), setup.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        with set_mesh(mesh):
            params = jax.jit(setup.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (10, 8, 2, 32), 0,
                                      cfg.vocab_size)
            batches = {"tokens": toks, "labels": toks}
            g2 = np.roll(g, 1).astype(np.float32); g2 /= g2.sum()
            swaps = iter([PoolSwap(gammas=g2), PoolSwap(gammas=g),
                          PoolSwap(gammas=g2)])
            out = setup.run_segments(params, None, batches, g, segment_len=2,
                                     on_segment=lambda t: next(swaps, None))
            assert out["n_traces"] == 1, out["n_traces"]   # 3 in-pool swaps: 0 retraces
            assert out["recompiles"] == 0
            assert len(out["swaps"]) == 3
            assert np.isfinite(out["losses"]).all()

            # the all-gather transport must accept the SAME pool-coordinate
            # updates (gammas execute as their ScheduleArrays twin) and
            # produce bitwise-identical losses -- the autotune can then pick
            # either transport under one controller
            setup_ag = make_train_setup(cfg, mesh, mode="dsgd", online_w=True,
                                        sharded_transport="allgather",
                                        pool=pool, lr=1e-2)
            swaps_ag = iter([PoolSwap(gammas=g2), PoolSwap(gammas=g),
                             PoolSwap(gammas=g2)])
            out_ag = setup_ag.run_segments(params, None, batches, g,
                                           segment_len=2,
                                           on_segment=lambda t: next(swaps_ag, None))
            assert np.array_equal(out["losses"], out_ag["losses"]), "transports diverged"

            # out-of-pool atom => restage => exactly ONE counted recompile
            new_perm = tuple(int(v) for v in np.roll(np.arange(8), 3))
            ns = BirkhoffSchedule(coeffs=(0.5, 0.5),
                                  perms=(tuple(range(8)), new_perm))
            new_pool = PermPool.from_schedule(ns, capacity=6)
            ng, _ = new_pool.project(ns)
            miss = iter([PoolSwap(gammas=ng, pool=new_pool)])
            out2 = setup.run_segments(out["params"], None, batches, g,
                                      segment_len=5,
                                      on_segment=lambda t: next(miss, None))
            assert out2["recompiles"] == 1, out2
            assert out2["n_traces"] == 2, out2
            assert out2["setup"].pool is new_pool  # continue from the LIVE setup
            assert np.isfinite(out2["losses"]).all()

            # same restage on the all-gather transport: pure data, NO recompile
            miss_ag = iter([PoolSwap(gammas=ng, pool=new_pool)])
            out3 = setup_ag.run_segments(out_ag["params"], None, batches, g,
                                         segment_len=5,
                                         on_segment=lambda t: next(miss_ag, None))
            assert out3["recompiles"] == 0 and out3["n_traces"] == 1, out3
            assert np.array_equal(out2["losses"], out3["losses"]), "restage diverged"
        print("POOL_TRANSPORT_OK", out["comm"]["per_step_bytes"])
    """)
    assert "POOL_TRANSPORT_OK" in out


def test_mix_dense_sharded_serialized_peak_memory():
    """The serialized all-gather contraction must never hold the gathered
    (n, P_total) stack live: compiled per-device temp memory stays within
    ~one gathered leaf (the PR-4 peak-memory fix, checked on the compiled
    HLO's buffer assignment)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_compat_mesh, shard_map
        from repro.core.mixing import mix_dense_sharded

        n, n_leaves = 8, 6
        mesh = make_compat_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
        leaves = {f"w{i}": jnp.zeros((n, 64, 257), jnp.float32)
                  for i in range(n_leaves)}
        W = jnp.eye(n, dtype=jnp.float32)

        def f(p, w):
            return shard_map(
                lambda q: mix_dense_sharded(q, w, "data"),
                mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
                axis_names={"data"}, check_vma=False)(p)

        stats = jax.jit(f).lower(leaves, W).compile().memory_analysis()
        one_gathered_leaf = n * 64 * 257 * 4      # bytes, f32
        full_stack = n_leaves * one_gathered_leaf
        temp = stats.temp_size_in_bytes
        # one live gather (+ slack for the contraction buffer), NOT the stack
        assert temp <= 2 * one_gathered_leaf, (temp, one_gathered_leaf)
        assert temp < full_stack // 2, (temp, full_stack)
        print("PEAK_MEMORY_OK", temp, one_gathered_leaf, full_stack)
    """)
    assert "PEAK_MEMORY_OK" in out


def test_node_churn_end_to_end_online_mesh_trainer():
    """NodeChurn drift (node replacement + offline windows) driven through
    the ONLINE MESH TRAINER: streamed labels -> drift detector -> warm
    refresh -> pool-coordinate hot swap at a run_segments boundary, with
    zero retraces unless the refresh restages (counted)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh
        from repro.configs import get_smoke_config
        from repro.core import learn_topology
        from repro.core.mixing import PermPool, schedule_from_result
        from repro.data.drift import NodeChurn, labels_stream
        from repro.online import (DriftDetector, OnlineTopologyController,
                                  RefreshConfig, StreamingPiEstimator,
                                  TopologyRefresher)
        from repro.train.lm_trainer import make_train_setup

        n, K, steps, seg = 8, 4, 24, 4
        Pi0 = np.eye(K)[np.arange(n) % K].astype(float)
        churn = NodeChurn(Pi0, events=((6, 1, 4), (6, 4), (6, 6)), alpha=0.3,
                          seed=3)
        labels = labels_stream(churn, steps, batch=16, seed=0)

        res0 = learn_topology(Pi0, budget=3, lam=0.5)
        ref = TopologyRefresher(res0, RefreshConfig(budget=3, lam=0.5))
        pool = PermPool.from_schedule(ref.schedule, capacity=ref.l_max)
        ctl = OnlineTopologyController(
            ref, estimator=StreamingPiEstimator(n, K, beta=0.5, init=Pi0),
            detector=DriftDetector(threshold=1.05, warmup=1),
            pool=pool, pool_miss_tol=0.25)

        mesh = make_compat_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        setup = make_train_setup(cfg, mesh, mode="dsgd", online_w=True,
                                 sharded_transport="pool", pool=pool, lr=1e-2)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), setup.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        fed = {"t": 0}
        def hook(t):
            while fed["t"] <= t:
                ctl.observe(labels[fed["t"]])
                fed["t"] += 1
            return ctl.on_segment(t)

        g0, _ = pool.project(ref.schedule)
        with set_mesh(mesh):
            params = jax.jit(setup.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (steps, 8, 2, 32),
                                      0, cfg.vocab_size)
            out = setup.run_segments(params, None,
                                     {"tokens": toks, "labels": toks}, g0,
                                     segment_len=seg, on_segment=hook)
        assert ref.n_refreshes >= 1, "churn never detected"
        assert out["swaps"], "refresh fired but no swap landed"
        # every trace is accounted: 1 initial + 1 per counted restage
        assert out["n_traces"] == 1 + out["recompiles"], out
        assert np.isfinite(out["losses"]).all()
        assert out["comm"]["total_bytes"] > 0
        print("NODE_CHURN_MESH_OK", len(out["swaps"]), out["recompiles"],
              ctl.pool_misses)
    """)
    assert "NODE_CHURN_MESH_OK" in out


def test_online_w_rejects_invalid_configs():
    from repro.configs import get_smoke_config  # noqa: F401  (import-path smoke)
    code = """
        import numpy as np, pytest
        from repro.compat import AxisType, make_compat_mesh
        from repro.configs import get_smoke_config
        from repro.core import learn_topology, schedule_from_result
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        Pi = np.eye(2)[np.arange(4) % 2].astype(float)
        sched = schedule_from_result(learn_topology(Pi, budget=2, lam=0.5))
        for kwargs in ({"mode": "fsdp", "online_w": True},
                       {"mode": "dsgd", "online_w": True, "schedule": sched}):
            try:
                make_train_setup(cfg, mesh, lr=1e-2, **kwargs)
            except ValueError:
                continue
            raise AssertionError(f"{kwargs} should have been rejected")
        setup = make_train_setup(cfg, mesh, mode="dsgd", online_w=True, lr=1e-2)
        ms = setup.multi_step_fn("scan")
        try:
            ms(None, None, {"tokens": np.zeros((1, 4, 2, 32))})  # missing mix_w
        except TypeError:
            pass
        else:
            raise AssertionError("missing mix_w should raise")
        print("ONLINE_W_VALIDATION_OK")
    """
    out = run_with_devices(code)
    assert "ONLINE_W_VALIDATION_OK" in out


def test_compressed_sharded_transports_agree_and_validate():
    """ISSUE 7: the EF-compressed pool and all-gather transports must be
    bitwise twins on the same schedule and wire (like their uncompressed
    counterparts); the identity wire must route to the PLAIN transports
    bitwise; and make_train_setup must reject the combos that have no
    compressed wire (fsdp all-reduce, dsgd_pod einsum, offline runs)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_compat_mesh, set_mesh, shard_map
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.compression import (Compressor, make_compressor,
                                            mix_arrays_sharded_ef,
                                            mix_dense_sharded_ef,
                                            mix_ppermute_pool_ef)
        from repro.core.mixing import (PermPool, mix_arrays_sharded,
                                       mix_ppermute_pool, schedule_from_matrix)
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        sched = schedule_from_matrix(T.ring(8))
        pool = PermPool.from_schedule(sched, capacity=6)
        g, dropped = pool.project(sched)
        assert dropped == 0.0
        arrays = pool.arrays_for(g)
        gj = jnp.asarray(g)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 37)), jnp.float32)
        e = jnp.asarray(rng.normal(size=(8, 37), scale=0.2), jnp.float32)

        def run(fn):
            return jax.jit(shard_map(fn, mesh=mesh, in_specs=(P("data"), P("data")),
                                     out_specs=(P("data"), P("data")),
                                     axis_names={"data"}, check_vma=False))(x, e)

        for wire in ("bf16", "topk:0.25"):
            comp = make_compressor(wire)
            mp, ep = run(lambda v, m: mix_ppermute_pool_ef(v, m, gj, pool,
                                                           "data", comp))
            ma, ea = run(lambda v, m: mix_arrays_sharded_ef(v, m, arrays,
                                                            "data", comp))
            assert np.array_equal(np.asarray(mp), np.asarray(ma)), wire
            assert np.array_equal(np.asarray(ep), np.asarray(ea)), wire
            # dense reference on the reconstructed W: same EF bitwise,
            # mixed equal up to accumulation order
            Wj = jnp.asarray(sched.to_matrix(), jnp.float32)
            md, ed = run(lambda v, m: mix_dense_sharded_ef(v, m, Wj,
                                                           "data", comp))
            assert np.array_equal(np.asarray(ep), np.asarray(ed)), wire
            assert np.allclose(np.asarray(mp), np.asarray(md), atol=1e-5), wire

        ident = Compressor("identity")
        mi, ei = run(lambda v, m: mix_ppermute_pool_ef(v, m, gj, pool,
                                                       "data", ident))
        plain = jax.jit(shard_map(
            lambda v: mix_ppermute_pool(v, gj, pool, "data"), mesh=mesh,
            in_specs=(P("data"),), out_specs=P("data"), axis_names={"data"},
            check_vma=False))(x)
        assert np.array_equal(np.asarray(mi), np.asarray(plain))
        assert np.array_equal(np.asarray(ei), np.asarray(e))  # ef untouched

        mesh2 = make_compat_mesh((8, 1), ("data", "model"),
                                 axis_types=(AxisType.Auto,) * 2)
        cfg = get_smoke_config("qwen3-0.6b")
        for kwargs in ({"mode": "fsdp"},
                       {"mode": "dsgd_pod"},
                       {"mode": "dsgd", "online_w": False}):
            try:
                make_train_setup(cfg, mesh2, lr=1e-2, compression="bf16",
                                 **kwargs)
            except ValueError:
                continue
            raise AssertionError(f"{kwargs} + compression should be rejected")
        s = make_train_setup(cfg, mesh2, mode="dsgd", online_w=True, lr=1e-2,
                             sharded_transport="pool", pool=pool,
                             compression="topk:0.25")
        assert s.compression.label == "topk:0.25"
        assert s.comm_bytes_per_step < make_train_setup(
            cfg, mesh2, mode="dsgd", online_w=True, lr=1e-2,
            sharded_transport="pool", pool=pool).comm_bytes_per_step
        print("COMPRESSED_SHARDED_OK")
    """)
    assert "COMPRESSED_SHARDED_OK" in out


def test_run_segments_checkpoint_resume_bitwise():
    """Crash recovery for the mesh trainer: stop after 2 segments (the
    scripted crash), resume from the checkpoint, and land bitwise on the
    uninterrupted run -- including a pre-crash hot swap, which rides the
    checkpoint as the saved mixing operand."""
    out = run_with_devices("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import AxisType, make_compat_mesh, set_mesh
        from repro.configs import get_smoke_config
        from repro.core import topology as T
        from repro.core.mixing import schedule_from_matrix, schedule_to_arrays
        from repro.train.lm_trainer import make_train_setup

        mesh = make_compat_mesh((8, 1), ("data", "model"),
                                axis_types=(AxisType.Auto,)*2)
        cfg = get_smoke_config("qwen3-0.6b")
        setup = make_train_setup(cfg, mesh, mode="dsgd", online_w=True, lr=1e-2)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), setup.param_specs,
                          is_leaf=lambda x: isinstance(x, P))
        mix0 = schedule_to_arrays(schedule_from_matrix(T.ring(8)), 4)
        mix1 = schedule_to_arrays(
            schedule_from_matrix(0.5 * T.ring(8) + 0.5 * np.eye(8)), 4)
        hook = lambda t: mix1 if t == 3 else None   # swap BEFORE the crash
        with set_mesh(mesh):
            params = jax.jit(setup.init_params, out_shardings=sh)(jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 8, 2, 32), 0,
                                      cfg.vocab_size)
            batches = {"tokens": toks, "labels": toks}
            full = setup.run_segments(params, None, batches, mix0,
                                      segment_len=2, on_segment=hook)
            assert full["stopped_at"] is None and full["resumed_from"] is None
            with tempfile.TemporaryDirectory() as d:
                head = setup.run_segments(params, None, batches, mix0,
                                          segment_len=2, on_segment=hook,
                                          checkpoint_dir=d,
                                          stop_after_segments=2)
                assert head["stopped_at"] == 4, head["stopped_at"]
                assert head["swaps"] == [3]
                tail = setup.run_segments(params, None, batches, mix0,
                                          segment_len=2, checkpoint_dir=d,
                                          resume=True)
                assert tail["resumed_from"] == 4, tail["resumed_from"]
                assert tail["n_traces"] == 1      # resume retraces nothing new
        glued = np.concatenate([head["losses"], tail["losses"]])
        assert np.array_equal(glued, full["losses"]), "resume diverged"
        for a, b in zip(jax.tree.leaves(tail["params"]),
                        jax.tree.leaves(full["params"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("CKPT_RESUME_OK")
    """)
    assert "CKPT_RESUME_OK" in out

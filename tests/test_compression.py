"""ISSUE 7: compressed-gossip wire formats + EF operators, property-tested.

Four layers of contract:

* top-k regression -- the exactly-k / tie / NaN / inf / truncation fixes
  (the old ``>= threshold`` rule kept more than k on ties and kept
  EVERYTHING when the k-th magnitude was 0.0);
* CHOCO properties on random Birkhoff topologies (via the hypothesis
  shim): identity wire bitwise-equals uncompressed mixing, per-step
  node-mean preservation, the EF telescoping identity, and
  schedule-transport == dense-reference agreement;
* byte accounting -- ``mix_bytes_per_step`` / ``CommMeter`` under
  compressed wire layouts (bf16 exactly halves, top-k charges values
  AND indices, delivered/retransmit composition, allreduce rejection);
* the online simulator drivers -- compressed runs hot-swap with zero
  retraces and the identity wire reproduces the uncompressed run
  bitwise end-to-end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import topology as T
from repro.core.compression import (
    Compressor,
    ef_gossip_step,
    ef_init,
    ef_mix_schedule_arrays,
    make_compressor,
    topk_compress,
    topk_keep_count,
    topk_mask,
)
from repro.core.dsgd import dsgd_init, dsgd_step_stacked
from repro.core.mixing import (
    ScheduleArrays,
    mix_schedule_arrays,
    schedule_from_matrix,
    schedule_to_arrays,
)
from repro.train.metrics import CommMeter, mix_bytes_per_step
from repro.train.trainer import run_mean_estimation


def _random_arrays(rng: np.random.Generator, n: int, L: int) -> ScheduleArrays:
    """Random Birkhoff schedule as data: identity + L-1 random atoms."""
    perms = np.stack(
        [np.arange(n)] + [rng.permutation(n) for _ in range(L - 1)]
    )
    gammas = rng.dirichlet(np.ones(L))
    return ScheduleArrays(
        gammas=jnp.asarray(gammas, jnp.float32),
        perms=jnp.asarray(perms, jnp.int32),
    )


def _dense_of(arrays: ScheduleArrays) -> np.ndarray:
    """W[i, j] = sum_l gamma_l [perms[l, i] == j] (receive convention)."""
    g = np.asarray(arrays.gammas, np.float64)
    p = np.asarray(arrays.perms)
    L, n = p.shape
    W = np.zeros((n, n))
    for l in range(L):
        W[np.arange(n), p[l]] += g[l]
    return W


# ---------------------------------------------------------------------------
# top-k regression: exactly-k, ties, truncation, NaN/inf, determinism
# ---------------------------------------------------------------------------

def test_topk_keep_count_truncation():
    assert topk_keep_count(10, 0.25) == 2      # int(2.5) truncates
    assert topk_keep_count(7, 0.5) == 3
    assert topk_keep_count(10, 0.01) == 1      # floor at one entry
    assert topk_keep_count(10, 1.0) == 10
    assert topk_keep_count(3, 0.99) == 2       # clamped below size
    with pytest.raises(ValueError):
        topk_keep_count(0, 0.5)


def test_topk_exactly_k_on_ties():
    """All-equal magnitudes: the >=-threshold rule kept ALL of them;
    the stable-argsort rule keeps exactly k, lowest indices first."""
    x = jnp.ones(10)
    mask = np.asarray(topk_mask(x, 0.3))
    assert mask.sum() == 3
    assert mask[:3].all() and not mask[3:].any()


def test_topk_many_zeros_leaf():
    """All-zero payload: a 0.0 threshold passed everything; the mask
    rule still keeps exactly k (of zeros -- the wire stays honest)."""
    x = jnp.zeros(8)
    mask = np.asarray(topk_mask(x, 0.5))
    assert mask.sum() == 4
    out = topk_compress(0.5)(x)
    assert np.array_equal(np.asarray(out), np.zeros(8))


def test_topk_nan_never_selected():
    x = jnp.asarray([5.0, np.nan, 3.0, 1.0, 0.5, 0.1])
    mask = np.asarray(topk_mask(x, 0.5))
    assert mask.sum() == 3
    assert not mask[1]
    out = np.asarray(topk_compress(0.5)(x))
    assert np.isfinite(out).all()


def test_topk_inf_sorts_first():
    x = jnp.asarray([1.0, 2.0, -np.inf, 3.0, 4.0, 5.0])
    mask = np.asarray(topk_mask(x, 1 / 6))
    assert mask.sum() == 1 and mask[2]


def test_topk_frac_one_is_identity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 7)), jnp.float32)
    out = topk_compress(1.0)(x)
    assert np.array_equal(np.asarray(out), np.asarray(x))


def test_topk_deterministic_and_jit_consistent():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-3, 4, size=31), jnp.float32)  # many ties
    eager = np.asarray(topk_mask(x, 0.4))
    again = np.asarray(topk_mask(x, 0.4))
    jitted = np.asarray(jax.jit(lambda v: topk_mask(v, 0.4))(x))
    assert np.array_equal(eager, again)
    assert np.array_equal(eager, jitted)
    assert eager.sum() == topk_keep_count(31, 0.4)


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=1, max_value=64),
    st.floats(min_value=0.01, max_value=1.0),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_topk_mask_count_property(size, frac, seed):
    """Exactly ``topk_keep_count`` survivors for ANY payload -- ties,
    zeros, repeated values included (values drawn from a tiny set to
    force heavy magnitude collisions)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.choice([-1.0, 0.0, 0.5, 1.0], size=size), jnp.float32)
    mask = np.asarray(topk_mask(x, frac))
    assert int(mask.sum()) == topk_keep_count(size, frac)


# ---------------------------------------------------------------------------
# CHOCO properties on random Birkhoff topologies
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=3, max_value=12),
    st.integers(min_value=2, max_value=5),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_identity_wire_bitwise_equals_uncompressed(n, L, seed):
    """The identity Compressor routes to the PLAIN transport at trace
    time, so equality is bitwise, not approximate -- in both the dense
    reference and the data-plane schedule operator."""
    rng = np.random.default_rng(seed)
    arrays = _random_arrays(rng, n, L)
    W = jnp.asarray(_dense_of(arrays), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
    ef = ef_init(theta)

    mixed, new_ef = ef_gossip_step(theta, ef, W, Compressor("identity"))
    want = jnp.tensordot(W, theta, axes=([1], [0]))
    assert np.array_equal(np.asarray(mixed), np.asarray(want))
    assert np.array_equal(np.asarray(new_ef), np.asarray(ef))

    mixed_a, ef_a = ef_mix_schedule_arrays(
        theta, ef, arrays, Compressor("identity")
    )
    want_a = mix_schedule_arrays(theta, arrays)
    assert np.array_equal(np.asarray(mixed_a), np.asarray(want_a))
    assert np.array_equal(np.asarray(ef_a), np.asarray(ef))


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=3, max_value=12),
    st.sampled_from(["bf16", "topk:0.25", "topk:0.6", "topk:0.25:g0.25"]),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ef_step_preserves_node_mean(n, wire, seed):
    """1^T W = 1^T kills the ``W c - c`` term: compressed mixing moves
    mass between nodes but never creates or destroys it."""
    rng = np.random.default_rng(seed)
    arrays = _random_arrays(rng, n, 3)
    W = jnp.asarray(_dense_of(arrays), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(n, 6)), jnp.float32)
    ef = jnp.asarray(rng.normal(size=(n, 6), scale=0.1), jnp.float32)
    mixed, _ = ef_gossip_step(theta, ef, W, make_compressor(wire))
    np.testing.assert_allclose(
        np.asarray(mixed).mean(axis=0),
        np.asarray(theta).mean(axis=0),
        atol=1e-5,
    )


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=3, max_value=10),
    st.sampled_from(["bf16", "topk:0.25", "topk:0.5"]),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ef_telescoping_identity(n, wire, seed):
    """theta_{t+1} - theta_t = (W - I)(theta_t + e_t - e_{t+1}):
    the compressed view c equals the EF-memory difference, so whatever
    the wire withholds stays in ``e`` and re-enters a later step --
    nothing is silently lost."""
    rng = np.random.default_rng(seed)
    arrays = _random_arrays(rng, n, 3)
    W64 = _dense_of(arrays)
    W = jnp.asarray(W64, jnp.float32)
    comp = make_compressor(wire)
    theta = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
    e = jnp.zeros_like(theta)
    for _ in range(3):
        theta_new, e_new = ef_gossip_step(theta, e, W, comp)
        c = np.asarray(theta, np.float64) + np.asarray(e, np.float64) \
            - np.asarray(e_new, np.float64)
        want = np.asarray(theta, np.float64) + (W64 - np.eye(n)) @ c
        np.testing.assert_allclose(np.asarray(theta_new), want, atol=1e-4)
        theta, e = theta_new, e_new


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=3, max_value=12),
    st.integers(min_value=2, max_value=5),
    st.sampled_from(["bf16", "topk:0.25", "topk:0.5", "bf16:g0.5"]),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_compressed_schedule_matches_dense_reference(n, L, wire, seed):
    """``ef_mix_schedule_arrays`` on a random Birkhoff schedule agrees
    with ``ef_gossip_step`` on the reconstructed dense W: same EF memory
    BITWISE (identical per-node compression ops) and same mixed output
    up to f32 accumulation order."""
    rng = np.random.default_rng(seed)
    arrays = _random_arrays(rng, n, L)
    W = jnp.asarray(_dense_of(arrays), jnp.float32)
    comp = make_compressor(wire)
    theta = jnp.asarray(rng.normal(size=(n, 7)), jnp.float32)
    ef = jnp.asarray(rng.normal(size=(n, 7), scale=0.2), jnp.float32)
    mixed_a, ef_a = ef_mix_schedule_arrays(theta, ef, arrays, comp)
    mixed_d, ef_d = ef_gossip_step(theta, ef, W, comp)
    assert np.array_equal(np.asarray(ef_a), np.asarray(ef_d))
    np.testing.assert_allclose(
        np.asarray(mixed_a), np.asarray(mixed_d), atol=1e-5
    )


def test_ef_memory_absorbs_dropped_mass():
    """What top-k withholds is exactly the EF memory (to_send - c)."""
    rng = np.random.default_rng(3)
    arrays = _random_arrays(rng, 6, 3)
    W = jnp.asarray(_dense_of(arrays), jnp.float32)
    comp = make_compressor("topk:0.25")
    theta = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    ef = jnp.zeros_like(theta)
    _, new_ef = ef_gossip_step(theta, ef, W, comp)
    # per node: kept entries have zero memory, dropped entries keep the
    # full withheld value
    k = topk_keep_count(8, 0.25)
    nz = np.count_nonzero(np.asarray(new_ef), axis=1)
    assert (nz <= 8 - k).all()
    np.testing.assert_allclose(
        np.asarray(new_ef) + np.asarray(jax.vmap(comp)(theta)),
        np.asarray(theta),
        atol=1e-6,
    )


def test_dsgd_step_stacked_ef_triple_and_rejections():
    rng = np.random.default_rng(0)
    arrays = _random_arrays(rng, 6, 3)
    theta = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
    grads = jnp.zeros_like(theta)
    state = dsgd_init(theta)
    ef = ef_init(theta)
    comp = make_compressor("bf16")
    out = dsgd_step_stacked(
        theta, grads, state, None, 0.1, schedule=arrays, ef=ef,
        compression=comp,
    )
    assert len(out) == 3
    mixed, new_state, new_ef = out
    assert int(new_state.step) == 1
    assert np.asarray(new_ef).shape == np.asarray(ef).shape
    # static (closure-format) schedules carry no EF memory
    static_sched = schedule_from_matrix(T.ring(6))
    with pytest.raises(ValueError, match="ScheduleArrays"):
        dsgd_step_stacked(
            theta, grads, state, None, 0.1, schedule=static_sched, ef=ef,
            compression=comp,
        )
    with pytest.raises(ValueError, match="ef"):
        dsgd_step_stacked(
            theta, grads, state, None, 0.1, schedule=arrays,
            compression=comp,
        )


# ---------------------------------------------------------------------------
# byte accounting: wire layouts through mix_bytes_per_step / CommMeter
# ---------------------------------------------------------------------------

def test_bf16_halves_bytes_exactly():
    for transport, kw in (
        ("allgather", {}),
        ("pool", {"n_comm_atoms": 3}),
        ("ppermute", {"n_comm_atoms": 5}),
    ):
        for alive in (1.0, 0.7, 0.5):
            plain = mix_bytes_per_step(
                transport, n_nodes=8, p_total=1000, alive_frac=alive, **kw
            )
            bf = mix_bytes_per_step(
                transport, n_nodes=8, p_total=1000, alive_frac=alive,
                compression="bf16", **kw
            )
            assert bf * 2 == plain, (transport, alive, bf, plain)


def test_topk_charges_values_and_indices():
    # k = 250 of P = 1000, each entry 4B value + 4B int32 index
    got = mix_bytes_per_step(
        "allgather", n_nodes=8, p_total=1000, compression="topk:0.25"
    )
    assert got == 7 * 250 * 8
    got_pool = mix_bytes_per_step(
        "pool", n_nodes=8, p_total=1000, n_comm_atoms=3,
        compression="topk:0.25",
    )
    assert got_pool == 3 * 250 * 8
    # a sparsifier that only charged values would claim half this
    assert got == 2 * mix_bytes_per_step(
        "allgather", n_nodes=8, p_total=250
    )


def test_identity_compression_is_byte_neutral():
    for transport, kw in (("allgather", {}), ("pool", {"n_comm_atoms": 3}),
                          ("allreduce", {})):
        plain = mix_bytes_per_step(transport, n_nodes=8, p_total=999, **kw)
        ident = mix_bytes_per_step(
            transport, n_nodes=8, p_total=999, compression="identity", **kw
        )
        assert ident == plain, transport


def test_allreduce_rejects_compressed_wire():
    with pytest.raises(ValueError, match="allreduce"):
        mix_bytes_per_step(
            "allreduce", n_nodes=8, p_total=100, compression="bf16"
        )


def test_comm_meter_compressed_delivery_composition():
    """delivered_frac and retransmit compose without double-counting
    on a compressed rate: delivered + dropped == modeled volume, and
    retransmissions add on top of (never into) the modeled bytes."""
    rate = mix_bytes_per_step(
        "pool", n_nodes=8, p_total=1000, n_comm_atoms=3, compression="bf16"
    )
    meter = CommMeter(per_step_bytes=rate)
    meter.tick(10, delivered_frac=0.6)
    modeled = 10 * rate
    assert meter.total_bytes == int(modeled * 0.6)
    assert meter.dropped_bytes == modeled - int(modeled * 0.6)
    meter.retransmit(123)
    assert meter.retransmit_bytes == 123
    assert meter.total_bytes == int(modeled * 0.6) + 123
    assert meter.total_bytes + meter.dropped_bytes == modeled + 123
    summary = meter.summary()
    assert summary["per_step_bytes"] == rate
    assert summary["steps"] == 10


def test_make_compressor_parsing_and_validation():
    assert make_compressor(None) is None
    for spec in ("none", "identity"):
        c = make_compressor(spec)
        assert c.is_identity and c.label == "identity"
    c = make_compressor("bf16")
    assert c.kind == "bf16" and not c.is_identity
    assert make_compressor("topk").frac == 0.25
    tk = make_compressor("topk:0.1")
    assert tk.kind == "topk" and tk.frac == 0.1
    # labels round-trip through the parser
    for spec in ("identity", "bf16", "topk:0.25", "topk:0.1"):
        assert make_compressor(make_compressor(spec).label).label == \
            make_compressor(spec).label
    # a Compressor passes through untouched
    assert make_compressor(tk) is tk
    with pytest.raises(ValueError):
        make_compressor("zstd")
    with pytest.raises(TypeError):
        make_compressor(lambda x: x)   # bare callables have no byte model
    with pytest.raises(ValueError):
        Compressor("gzip")
    with pytest.raises(ValueError):
        Compressor("topk", 0.0)
    with pytest.raises(ValueError):
        Compressor("topk", 1.5)


def test_gamma_spec_parsing_and_validation():
    """CHOCO consensus step size: ``:g<gamma>`` suffix on any wire."""
    c = make_compressor("topk:0.1:g0.25")
    assert (c.kind, c.frac, c.gamma) == ("topk", 0.1, 0.25)
    assert make_compressor("bf16:g0.5").gamma == 0.5
    assert make_compressor("topk:g0.5") == Compressor("topk", 0.25, 0.5)
    assert make_compressor("identity:g0.7").gamma == 0.7
    # labels round-trip, gamma=1 stays suffix-free
    for spec in ("topk:0.1:g0.25", "bf16:g0.5", "bf16", "topk:0.25"):
        c = make_compressor(spec)
        assert make_compressor(c.label) == c
    assert make_compressor("bf16").label == "bf16"
    # only the UNDAMPED identity is the plain transport bitwise
    assert make_compressor("identity").routes_to_plain
    assert not make_compressor("identity:g0.5").routes_to_plain
    assert not make_compressor("bf16").routes_to_plain
    with pytest.raises(ValueError):
        make_compressor("bf16:0.5")   # frac only means something on topk
    with pytest.raises(ValueError):
        make_compressor("topk:0.1:0.2")   # second frac token
    with pytest.raises(ValueError):
        Compressor("bf16", gamma=0.0)
    with pytest.raises(ValueError):
        Compressor("bf16", gamma=1.5)
    # gamma never changes the wire: same byte model at any step size
    assert Compressor("topk", 0.25, 0.5).wire_layout(1000) == \
        Compressor("topk", 0.25).wire_layout(1000)


def test_damped_identity_is_damped_exact_gossip():
    """identity at gamma<1 must NOT route to plain mixing: it is
    ``(1-g) theta + g W theta`` with zero EF memory, on both the dense
    reference and the schedule transport."""
    rng = np.random.default_rng(7)
    n, d, g = 6, 5, 0.5
    arrays = _random_arrays(rng, n, 3)
    W = jnp.asarray(_dense_of(arrays), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    e = jnp.zeros_like(theta)
    comp = Compressor("identity", gamma=g)
    want = (1 - g) * np.asarray(theta) + g * np.asarray(
        jnp.tensordot(W, theta, axes=([1], [0]))
    )
    mixed, new_e = ef_gossip_step(theta, e, W, comp)
    np.testing.assert_allclose(np.asarray(mixed), want, atol=1e-5)
    assert not np.asarray(new_e).any()
    mixed_a, new_e_a = ef_mix_schedule_arrays(theta, e, arrays, comp)
    np.testing.assert_allclose(np.asarray(mixed_a), want, atol=1e-5)
    assert not np.asarray(new_e_a).any()


def test_gamma_damps_topk_ef_steady_state_error():
    """Regression for the frontier divergence: aggressive top-k EF
    gossip at gamma=1 feeds its compression error back through (W - I)
    undamped. On a ring with heterogeneous local pulls, the damped wire
    must settle measurably closer to consensus (deterministic seed; at
    full scale undamped top-k diverges outright -- see bench_online's
    frontier gamma note)."""
    rng = np.random.default_rng(0)
    n, d = 8, 32
    targets = jnp.asarray(rng.normal(size=(n, d), scale=5.0), jnp.float32)
    W = jnp.asarray(np.asarray(T.ring(n)), jnp.float32)
    devs = {}
    for spec in ("topk:0.1", "topk:0.1:g0.25"):
        comp = make_compressor(spec)
        theta = jnp.zeros((n, d))
        e = jnp.zeros((n, d))
        for _ in range(150):
            theta = theta - 0.4 * (theta - targets)
            theta, e = ef_gossip_step(theta, e, W, comp)
        devs[spec] = float(jnp.abs(theta - jnp.mean(targets, 0)).max())
    assert np.isfinite(devs["topk:0.1:g0.25"])
    assert devs["topk:0.1:g0.25"] < 0.8 * devs["topk:0.1"], devs


def test_wire_ratio_closed_form():
    assert Compressor("bf16").wire_ratio(1000) == 0.5
    assert Compressor("identity").wire_ratio(1000) == 1.0
    tk = Compressor("topk", 0.25)
    assert tk.wire_ratio(1000) == (250 * 8) / (1000 * 4)
    # scalar payload: the value+index wire COSTS more than f32 -- the
    # meter reports that honestly instead of pretending compression
    assert Compressor("topk", 0.25).wire_ratio(1) == 2.0


# ---------------------------------------------------------------------------
# online simulator drivers: zero retraces + identity end-to-end bitwise
# ---------------------------------------------------------------------------

def _mean_estimation_run(wire, on_segment=None):
    from repro.data.synthetic import mean_estimation_clusters

    task = mean_estimation_clusters(n_nodes=8, K=2, m=3.0, sigma_tilde2=0.2)
    sa = schedule_to_arrays(schedule_from_matrix(T.ring(8)), 4)
    return run_mean_estimation(
        task, None, steps=40, lr=0.1, batch=2, seed=5, schedule=sa,
        segment_len=10, on_segment=on_segment, compression=wire,
    )


def test_online_compressed_swap_zero_retraces_and_bytes():
    sa_alt = schedule_to_arrays(
        schedule_from_matrix(T.alternating_ring(8)), 4
    )
    hooks = {"fired": 0}

    def hook(t):
        hooks["fired"] += 1
        return sa_alt if hooks["fired"] == 1 else None

    out_plain = _mean_estimation_run(None, hook)
    hooks["fired"] = 0
    out_id = _mean_estimation_run("identity", hook)
    hooks["fired"] = 0
    out_bf = _mean_estimation_run("bf16", hook)
    hooks["fired"] = 0
    out_tk = _mean_estimation_run("topk:0.5", hook)

    for name, out in (("plain", out_plain), ("identity", out_id),
                      ("bf16", out_bf), ("topk", out_tk)):
        assert out["n_traces"] == 1, (name, out["n_traces"])
        assert out["swaps"], name
        assert np.isfinite(out["mean_sq_error"]).all(), name
    # identity wire: END-TO-END bitwise, through the hot swap
    assert np.array_equal(out_id["mean_sq_error"], out_plain["mean_sq_error"])
    assert out_id["comm"]["per_step_bytes"] == out_plain["comm"]["per_step_bytes"]
    assert out_id["compression"] == "identity"
    assert out_plain["compression"] is None
    # bf16: exactly half the wire, still converging
    assert out_bf["comm"]["per_step_bytes"] * 2 == \
        out_plain["comm"]["per_step_bytes"]
    assert not np.array_equal(out_bf["mean_sq_error"],
                              out_plain["mean_sq_error"])
    # scalar payload: top-k value+index costs 2x f32 -- metered honestly
    assert out_tk["comm"]["per_step_bytes"] == \
        2 * out_plain["comm"]["per_step_bytes"]


def test_online_compressed_loop_rollout_matches_scan():
    from repro.data.synthetic import mean_estimation_clusters

    task = mean_estimation_clusters(n_nodes=6, K=2, m=2.0, sigma_tilde2=0.2)
    sa = schedule_to_arrays(schedule_from_matrix(T.ring(6)), 3)
    outs = {}
    for rollout in ("scan", "loop"):
        outs[rollout] = run_mean_estimation(
            task, None, steps=20, lr=0.1, batch=2, seed=7, schedule=sa,
            segment_len=5, compression="bf16", rollout=rollout,
        )
    assert np.array_equal(
        outs["scan"]["mean_sq_error"], outs["loop"]["mean_sq_error"]
    )


def test_run_mean_estimation_rejects_compression_off_data_plane():
    from repro.data.synthetic import mean_estimation_clusters

    task = mean_estimation_clusters(n_nodes=6, K=2, m=2.0)
    with pytest.raises(ValueError, match="ScheduleArrays"):
        run_mean_estimation(task, T.ring(6), steps=5, compression="bf16")
    static_sched = schedule_from_matrix(T.ring(6))
    with pytest.raises(ValueError, match="ScheduleArrays"):
        run_mean_estimation(
            task, None, steps=5, schedule=static_sched, compression="bf16"
        )

"""Mean-estimation D-SGD under injected faults, with crash recovery.

The faulty twin of ``repro.train.trainer.run_mean_estimation``'s online
driver, same step math op-for-op:

    grads = 2 (theta - z_bar)                    # quadratic task
    half  = theta - lr * grads                   # local half-step
    push half into the staleness ring buffer
    theta = sum_l gammas_t[l] * stale[perms_t[l]]  # degraded + delayed mix

The per-step fault data -- degraded ``(gammas, perms)`` tables and the
delay vector -- ride the ``lax.scan`` as xs with fixed shapes, so every
fault event (a crash's degraded-W swap, a straggler's buffer delay, the
post-rejoin renormalization back to the full schedule) is a pure value
change into ONE compiled rollout (``n_traces == 1``, asserted in tests
and the CI smoke bench). A zero-fault plan reproduces the fault-free
driver's trajectory bitwise (delays 0 read back the value just pushed;
``degrade_schedule`` with everyone alive is the identity).

Crash recovery: at segment boundaries the carry (theta, ring buffer,
and the CURRENT base schedule -- so a pre-crash topology refresh
survives) checkpoints via ``repro.train.checkpoints``; ``resume=True``
restores the latest checkpoint and continues bitwise, because every
fault draw is random-access from the plan's seed (no replay needed).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import (
    ScheduleArrays,
    StragglerPolicy,
    WireCorruption,
    mix_schedule_arrays_screened,
    mix_schedule_arrays_stale,
    stale_buffer_init,
    stale_push,
)
from repro.obs.trace import Tracer
from repro.train.checkpoints import latest_step, restore_checkpoint, save_checkpoint
from repro.train.metrics import CommMeter, mix_bytes_per_step

_NULL_TRACER = Tracer(enabled=False)

from .plan import FaultInjector, FaultPlan

__all__ = ["run_faulty_mean_estimation"]


def run_faulty_mean_estimation(
    task,
    plan: FaultPlan,
    schedule: ScheduleArrays,
    *,
    lr: float = 0.1,
    batch: int = 1,
    seed: int = 0,
    segment_len: int | None = None,
    on_segment: Callable | None = None,
    zs: np.ndarray | None = None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    resume: bool = False,
    stop_after_segments: int | None = None,
    staleness: StragglerPolicy | None = None,
    quarantine=None,
    tracer: "Tracer | None" = None,
    retrace_guard=None,
) -> dict:
    """D-SGD mean estimation under a seeded fault plan.

    Args:
      task: a ``MeanEstimationTask`` (supplies ``theta_star`` and the
        observation sampler; ``zs`` overrides the presampled stream).
      plan: the fault trace; ``plan.steps`` is the run length.
      schedule: fault-free base topology as fixed-shape
        ``ScheduleArrays`` (refreshes swap it via ``on_segment``).
      segment_len: boundary spacing for the hook/checkpoints (defaults
        to one full-run segment).
      on_segment: ``hook(t) -> ScheduleArrays | None`` called after
        every segment except the last; a non-None return rebases the
        injector on the new topology (same shape). Same contract as the
        fault-free drivers, so an ``OnlineTopologyController`` plugs in
        unchanged.
      checkpoint_dir / checkpoint_every: save the carry every
        ``checkpoint_every``-th segment boundary (plus at an early
        stop). ``resume=True`` restores the newest checkpoint and
        continues bitwise; returned traces then cover only the resumed
        tail (``resumed_from`` records the restart step).
      stop_after_segments: execute at most this many segments in this
        process then return (the scripted "crash" of recovery drills);
        ``stopped_at`` records where.
      staleness: a ``StragglerPolicy`` resolving the plan's raw delays
        against a deadline. ``"wait"`` consumes every late payload at
        its (clamped) staleness; ``"degrade"`` treats past-deadline
        stragglers as offline for the step (one combined schedule
        repair with the crash/drop faults). The ring depth becomes the
        POLICY's ``ring_depth`` and the meter splits delivered bytes
        into on-time vs deferred (``comm["deferred_bytes"]``). ``None``
        keeps the PR 6 behavior: raw delays, ring sized by the plan.
      quarantine: a :class:`repro.faults.quarantine.QuarantineController`
        -- enables the screened transport (non-finite guard in-graph,
        norm/deviation screens host-side), folds the controller's mask
        into the injector's schedule repair at every segment boundary,
        and meters ``quarantined_bytes``. Routing is decided at TRACE
        time: with ``quarantine=None`` and a corruption-free plan the
        original unscreened scan body runs, so corruption-off arms are
        bitwise-identical to prior releases. A corrupting plan with
        ``quarantine=None`` runs the screened transport with the guard
        OFF -- the honest screen-off divergence baseline.
      tracer: a ``repro.obs.Tracer`` -- records ``sim.segment`` spans
        per rollout segment and ``faults.stream`` spans for the
        host-side fault resolution (via the injector).
      retrace_guard: a ``repro.obs.RetraceGuard`` -- rollout compiles
        are counted under ``"faults.roll"``.

    Returns a dict with the fault-free driver's keys
    (``mean/max/min_sq_error``, ``theta``, ``n_traces``, ``swaps``,
    ``comm``) plus ``resumed_from``, ``stopped_at``, and
    ``alive_frac`` (the plan's mean alive fraction over the run).
    """
    steps = plan.steps
    n = task.n_nodes
    if plan.n_nodes != n:
        raise ValueError(f"plan is for {plan.n_nodes} nodes, task for {n}")
    if checkpoint_every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, got {checkpoint_every}")
    seg = int(segment_len) if segment_len is not None else max(steps, 1)
    if seg < 1:
        raise ValueError(f"segment_len must be >= 1, got {segment_len}")

    rng = np.random.default_rng(seed)
    theta = jnp.zeros((n, 1))
    theta_star = jnp.asarray(task.theta_star, jnp.float32)
    if zs is None:
        # identical call sequence to run_mean_estimation: a zero-fault
        # plan at the same seed traverses the same observations
        zs_host = [task.sample(batch, rng) for _ in range(steps)]
        zs = np.stack(zs_host) if zs_host else np.zeros((0, n, batch))
    zs = jnp.asarray(zs, jnp.float32)
    if zs.ndim != 3 or zs.shape[0] != steps or zs.shape[1] != n:
        raise ValueError(f"zs must be ({steps}, {n}, batch), got {zs.shape}")

    depth = staleness.ring_depth if staleness is not None else plan.ring_depth
    buffer = stale_buffer_init(theta, depth)
    tracer = _NULL_TRACER if tracer is None else tracer
    injector = FaultInjector(
        plan, schedule, policy=staleness,
        tracer=tracer if tracer.enabled else None,
    )
    lr = float(lr)

    n_traces = 0
    # trace-time routing: the screened body only exists when the plan
    # corrupts or a quarantine controller screens -- a corruption-off
    # run compiles the EXACT prior scan, so its trajectory is bitwise
    screened = plan.has_corruption or quarantine is not None
    guard = quarantine is not None

    def roll_impl(carry, xs):
        nonlocal n_traces
        n_traces += 1
        if retrace_guard is not None:
            retrace_guard.record("faults.roll")

        def step(c, x):
            th, buf = c
            z, g_t, p_t, d_t = x
            grads = 2.0 * (th - z.mean(axis=1, keepdims=True))
            half = th - lr * grads
            buf = stale_push(buf, half)
            th = mix_schedule_arrays_stale(
                buf, ScheduleArrays(gammas=g_t, perms=p_t), d_t
            )
            err = jnp.square(th[:, 0] - theta_star)
            return (th, buf), (jnp.mean(err), jnp.max(err), jnp.min(err))

        return jax.lax.scan(step, carry, xs)

    def roll_screened_impl(carry, xs):
        nonlocal n_traces
        n_traces += 1
        if retrace_guard is not None:
            retrace_guard.record("faults.roll")

        def step(c, x):
            th, buf = c
            z, g_t, p_t, d_t, m_t, x_t = x
            grads = 2.0 * (th - z.mean(axis=1, keepdims=True))
            half = th - lr * grads
            buf = stale_push(buf, half)
            th, stats = mix_schedule_arrays_screened(
                buf,
                ScheduleArrays(gammas=g_t, perms=p_t),
                d_t,
                half,
                corrupt=WireCorruption(mult=m_t, xor=x_t),
                guard=guard,
            )
            err = jnp.square(th[:, 0] - theta_star)
            # live probes the host-side screen derives its honest-
            # deviation allowance from (max over nodes, not mean: the
            # zero-false-positive bound is a triangle inequality
            # against the worst honest node)
            hbar = jnp.mean(half, axis=0, keepdims=True)
            cons = jnp.max(jnp.sum(jnp.square(half - hbar), axis=1))
            gbar = jnp.mean(grads, axis=0, keepdims=True)
            gdev = jnp.max(jnp.sum(jnp.square(grads - gbar), axis=1))
            gbar_sq = jnp.sum(jnp.square(gbar))
            return (th, buf), (
                jnp.mean(err), jnp.max(err), jnp.min(err), err,
                stats, cons, gdev, gbar_sq,
            )

        return jax.lax.scan(step, carry, xs)

    roll = jax.jit(roll_screened_impl if screened else roll_impl)

    t0 = 0
    resumed_from = None
    if checkpoint_dir is not None and resume:
        last = latest_step(checkpoint_dir)
        if last is not None:
            like = {
                "theta": theta,
                "buf": buffer.buf,
                "head": buffer.head,
                "gammas": injector.base.gammas,
                "perms": injector.base.perms,
            }
            tree, _meta = restore_checkpoint(checkpoint_dir, last, like)
            theta = jnp.asarray(tree["theta"])
            buffer = type(buffer)(
                buf=jnp.asarray(tree["buf"]), head=jnp.asarray(tree["head"])
            )
            injector.rebind(ScheduleArrays(
                gammas=jnp.asarray(tree["gammas"]),
                perms=jnp.asarray(tree["perms"]),
            ))
            t0 = int(last)
            resumed_from = t0

    def save(t: int) -> None:
        save_checkpoint(
            checkpoint_dir,
            t,
            {
                "theta": theta,
                "buf": buffer.buf,
                "head": buffer.head,
                "gammas": injector.base.gammas,
                "perms": injector.base.perms,
            },
            metadata={"t": int(t), "seed": int(seed)},
        )

    meter = CommMeter(per_step_bytes=mix_bytes_per_step(
        "allgather", n_nodes=n, p_total=1,
    ))
    mse_l, mx_l, mn_l = [], [], []
    nodes_l: list[np.ndarray] = []
    swaps: list[int] = []
    stopped_at = None
    seg_idx = 0
    carry = (theta, buffer)
    while t0 < steps:
        k = min(seg, steps - t0)
        gammas_k, perms_k, delays_k = injector.stream(t0, k)
        # the mask ACTIVE during this segment (transitions from ingest
        # below only land on the next one) -- also the honest basis for
        # this segment's quarantined-byte fate
        qmask = injector.quarantined.copy()
        with tracer.span("sim.segment", t0=t0, k=k):
            if screened:
                mult_k, xor_k = injector.corrupt_stream(t0, k)
                carry, (e_mean, e_max, e_min, e_nodes, stats, cons, gdev,
                        gbars) = roll(
                    carry,
                    (zs[t0 : t0 + k], jnp.asarray(gammas_k),
                     jnp.asarray(perms_k), jnp.asarray(delays_k),
                     jnp.asarray(mult_k), jnp.asarray(xor_k)),
                )
            else:
                carry, (e_mean, e_max, e_min) = roll(
                    carry,
                    (zs[t0 : t0 + k], jnp.asarray(gammas_k),
                     jnp.asarray(perms_k), jnp.asarray(delays_k)),
                )
            jax.block_until_ready(e_mean)
        mse_l.append(np.asarray(e_mean))
        mx_l.append(np.asarray(e_max))
        mn_l.append(np.asarray(e_min))
        if screened:
            nodes_l.append(np.asarray(e_nodes))
        if staleness is not None:
            fates = [
                plan.transfer_fracs(
                    t, deadline=staleness.tau_max, mode=staleness.mode
                )
                for t in range(t0, t0 + k)
            ]
            on_time = float(np.mean([f[0] for f in fates]))
            deferred = float(np.mean([f[1] for f in fates]))
            q_frac = float(np.mean([
                plan.quarantined_frac(
                    t, qmask, deadline=staleness.tau_max, mode=staleness.mode
                )
                for t in range(t0, t0 + k)
            ])) if qmask.any() else 0.0
            meter.tick(
                k, delivered_frac=on_time + deferred, deferred_frac=deferred,
                quarantined_frac=q_frac,
            )
        else:
            frac = float(
                np.mean([plan.delivered_frac(t) for t in range(t0, t0 + k)])
            )
            q_frac = float(np.mean([
                plan.quarantined_frac(t, qmask) for t in range(t0, t0 + k)
            ])) if qmask.any() else 0.0
            meter.tick(k, delivered_frac=frac, quarantined_frac=q_frac)
        if quarantine is not None:
            new_mask = quarantine.ingest(
                t0, stats, gammas_k, perms_k,
                {"consensus_sq": np.asarray(cons),
                 "gdev_sq": np.asarray(gdev),
                 "gbar_sq": np.asarray(gbars)},
            )
            injector.set_quarantine(new_mask)
        t0 += k
        seg_idx += 1
        theta, buffer = carry
        if on_segment is not None and t0 < steps:
            update = on_segment(t0 - 1)
            if update is not None:
                injector.rebind(update)
                swaps.append(t0 - 1)
        if checkpoint_dir is not None and (
            seg_idx % checkpoint_every == 0 or t0 >= steps
        ):
            save(t0)
        if stop_after_segments is not None and seg_idx >= stop_after_segments and t0 < steps:
            if checkpoint_dir is not None and seg_idx % checkpoint_every != 0:
                save(t0)  # the crash drill must leave a resumable state
            stopped_at = t0
            break

    empty = np.zeros((0,))
    return {
        "mean_sq_error": np.concatenate(mse_l) if mse_l else empty,
        "max_sq_error": np.concatenate(mx_l) if mx_l else empty,
        "min_sq_error": np.concatenate(mn_l) if mn_l else empty,
        "theta": np.asarray(theta),
        "n_traces": n_traces,
        "swaps": swaps,
        "comm": meter.summary(),
        "resumed_from": resumed_from,
        "stopped_at": stopped_at,
        "alive_frac": plan.alive_frac(),
        "quarantine": None if quarantine is None else quarantine.summary(),
        # per-node (steps, n) error trace, screened path only: the bench
        # separates honest-node tail loss from the quarantined nodes'
        # solo-SGD error (the Byzantine-robust convention -- a liar's
        # own loss is not the defense's responsibility)
        "sq_error_nodes": np.concatenate(nodes_l) if nodes_l else None,
    }

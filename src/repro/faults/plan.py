"""Seeded fault plans: reproducible crash / drop / straggler / solver traces.

Reproducibility contract: every draw comes from
``np.random.default_rng([seed, stream, ...])`` seed sequences, so

* two processes constructing ``FaultPlan(seed=s, ...)`` with the same
  config produce byte-identical traces (asserted by a subprocess test),
  and
* a checkpoint resume reconstructs the exact trace WITHOUT replaying
  the run: the Markov alive/delay processes are precomputed arrays, and
  per-step edge drops are random-access (stream keyed by ``t``), so
  step 500's drops can be drawn without drawing steps 0..499.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading

import numpy as np

from repro.core.mixing import ScheduleArrays, degrade_schedule

__all__ = ["FaultPlan", "FaultInjector", "FlakyRefresher"]

# rng stream tags (part of the on-disk/reproducibility contract: changing
# one silently changes every seeded trace)
_STREAM_ALIVE = 1
_STREAM_DELAYS = 2
_STREAM_EDGES = 3
_STREAM_SOLVES = 4
_STREAM_CORRUPT = 5

# bitflip corruption draws one exponent bit in [24, 28): flipping it
# rescales the payload by a large-but-FINITE power of two (a low
# mantissa flip would be indistinguishable from honest noise, bit 30
# overflows straight to inf -- which the nan mode already covers)
_BITFLIP_LO, _BITFLIP_HI = 24, 28


def _parse_corrupt_mode(mode: str) -> tuple[float | None, bool]:
    """``mode`` -> ``(mult, is_bitflip)``.

    ``mult`` is the multiplicative plane value (``nan`` / ``-1`` /
    ``k``); ``None`` with ``is_bitflip=True`` means the XOR plane draws
    an exponent bit instead.
    """
    if mode == "nan":
        return float("nan"), False
    if mode == "sign_flip":
        return -1.0, False
    if mode == "bitflip":
        return None, True
    if mode.startswith("scale:"):
        try:
            k = float(mode[len("scale:"):])
        except ValueError:
            raise ValueError(
                f"unknown corruption mode {mode!r}: the scale factor in "
                "'scale:<k>' must be a number"
            ) from None
        if not np.isfinite(k):
            raise ValueError(f"scale factor must be finite, got {mode!r}")
        return k, False
    raise ValueError(
        f"unknown corruption mode {mode!r}: expected 'nan', 'sign_flip', "
        "'bitflip', or 'scale:<k>'"
    )


@dataclasses.dataclass
class FaultPlan:
    """A reproducible fault trace for an ``steps``-step, ``n_nodes`` run.

    Args:
      n_nodes / steps: trace dimensions.
      seed: the single seed every stream derives from.
      crash_rate: per-node per-step probability that an alive node
        crashes (start of an offline window).
      mean_outage: expected outage length in steps; a crashed node
        rejoins each step with probability ``1 / mean_outage``
        (geometric outages -- the memoryless twin of
        ``data.drift.NodeChurn``'s fixed windows).
      straggler_rate: per-node per-step probability that a node's
        parameters arrive stale this step.
      tau_max: bounded-delay cap; a straggling node's delay is uniform
        in ``[1, tau_max]`` (0 = no staleness model).
      edge_drop_rate: per-directed-edge per-step message-drop
        probability.
      solve_failure_rate / solve_hang_rate: per-refresh probabilities
        that the k-th topology solve raises / hangs (consumed by
        :class:`FlakyRefresher`).
      corrupt_rate: per-node per-step probability that an honest node
        turns CORRUPT (starts lying on the wire -- start of a
        corruption window).
      mean_corruption: expected corruption-window length in steps; a
        corrupt node recovers each step with probability
        ``1 / mean_corruption`` (geometric windows, like outages --
        finite windows are what make self-healing re-admission a
        testable event rather than a hypothetical).
      corrupt_modes: the palette a corruption window draws its mode
        from (uniformly, once per window): ``"nan"``, ``"sign_flip"``,
        ``"scale:<k>"``, ``"bitflip"``.

    Derived (precomputed, deterministic):
      alive: (steps, n) bool -- the crash/rejoin Markov trace.
      delays: (steps, n) int32 in [0, tau_max] -- the straggler trace
        (crashed nodes carry delay 0; their transfers are cut by the
        alive mask, not by staleness).
      corrupt_mult / corrupt_xor: (steps, n) f32 / int32 -- the wire
        corruption trace in the two planes
        :class:`repro.core.mixing.WireCorruption` consumes (1.0 / 0 =
        honest; dead nodes are forced honest -- they send nothing).
    """

    n_nodes: int
    steps: int
    seed: int = 0
    crash_rate: float = 0.0
    mean_outage: float = 10.0
    straggler_rate: float = 0.0
    tau_max: int = 0
    edge_drop_rate: float = 0.0
    solve_failure_rate: float = 0.0
    solve_hang_rate: float = 0.0
    corrupt_rate: float = 0.0
    mean_corruption: float = 8.0
    corrupt_modes: tuple = ("nan", "sign_flip", "scale:8", "bitflip")
    alive: np.ndarray = dataclasses.field(init=False, repr=False)
    delays: np.ndarray = dataclasses.field(init=False, repr=False)
    corrupt_mult: np.ndarray = dataclasses.field(init=False, repr=False)
    corrupt_xor: np.ndarray = dataclasses.field(init=False, repr=False)

    def __post_init__(self) -> None:
        if self.n_nodes < 1 or self.steps < 0:
            raise ValueError(f"bad n_nodes={self.n_nodes} / steps={self.steps}")
        for name in ("crash_rate", "straggler_rate", "edge_drop_rate",
                     "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.mean_outage < 1.0:
            raise ValueError(f"mean_outage must be >= 1, got {self.mean_outage}")
        if self.mean_corruption < 1.0:
            raise ValueError(
                f"mean_corruption must be >= 1, got {self.mean_corruption}"
            )
        if self.tau_max < 0:
            raise ValueError(f"tau_max must be >= 0, got {self.tau_max}")
        if self.solve_failure_rate + self.solve_hang_rate > 1.0:
            raise ValueError("solve_failure_rate + solve_hang_rate must be <= 1")
        self.corrupt_modes = tuple(self.corrupt_modes)
        if not self.corrupt_modes:
            raise ValueError("corrupt_modes must not be empty")
        for mode in self.corrupt_modes:
            _parse_corrupt_mode(mode)  # validates
        self.alive = self._gen_alive()
        self.delays = self._gen_delays()
        self.corrupt_mult, self.corrupt_xor = self._gen_corruption()

    # -- trace generation ---------------------------------------------------

    def _gen_alive(self) -> np.ndarray:
        n, T = self.n_nodes, self.steps
        alive = np.ones((T, n), dtype=bool)
        if self.crash_rate == 0.0 or T == 0:
            return alive
        rng = np.random.default_rng([self.seed, _STREAM_ALIVE])
        rejoin_p = 1.0 / self.mean_outage
        state = np.ones(n, dtype=bool)
        for t in range(T):
            u = rng.random(n)
            crash = state & (u < self.crash_rate)
            rejoin = ~state & (u < rejoin_p)
            state = (state & ~crash) | rejoin
            if not state.any():
                # never let the whole fleet die: W would degrade to I and
                # the run silently stops mixing forever; resurrect one
                # node deterministically (lowest index)
                state[0] = True
            alive[t] = state
        return alive

    def _gen_delays(self) -> np.ndarray:
        n, T = self.n_nodes, self.steps
        delays = np.zeros((T, n), dtype=np.int32)
        if self.straggler_rate == 0.0 or self.tau_max == 0 or T == 0:
            return delays
        rng = np.random.default_rng([self.seed, _STREAM_DELAYS])
        lagging = rng.random((T, n)) < self.straggler_rate
        draw = rng.integers(1, self.tau_max + 1, size=(T, n), dtype=np.int32)
        # defensive clamp to the ring's reach: a delay past tau_max would
        # alias modulo the (tau_max + 1)-deep ring and silently read a
        # NEWER state than asked for (the draw above already respects the
        # bound; the clamp pins the invariant against future draw changes)
        delays[lagging] = np.minimum(draw[lagging], self.tau_max)
        # offline nodes carry delay 0: the alive mask governs them (their
        # transfers are cut by schedule repair), not staleness
        delays[~self.alive] = 0
        return delays

    def _gen_corruption(self) -> tuple[np.ndarray, np.ndarray]:
        n, T = self.n_nodes, self.steps
        mult = np.ones((T, n), dtype=np.float32)
        xor = np.zeros((T, n), dtype=np.int32)
        if self.corrupt_rate == 0.0 or T == 0:
            return mult, xor
        rng = np.random.default_rng([self.seed, _STREAM_CORRUPT])
        recover_p = 1.0 / self.mean_corruption
        # per-node window state: honest (mult 1 / xor 0) or one drawn
        # mode held for the whole window -- a corrupted node lies the
        # same WAY until it recovers, so streak-based confirmation sees
        # a consistent signature
        cur_mult = np.ones(n, dtype=np.float32)
        cur_xor = np.zeros(n, dtype=np.int32)
        corrupt = np.zeros(n, dtype=bool)
        for t in range(T):
            u = rng.random(n)
            start = ~corrupt & (u < self.corrupt_rate)
            stop = corrupt & (u < recover_p)
            for i in np.flatnonzero(start):
                mode = self.corrupt_modes[
                    int(rng.integers(len(self.corrupt_modes)))
                ]
                m, is_bitflip = _parse_corrupt_mode(mode)
                if is_bitflip:
                    cur_mult[i] = 1.0
                    cur_xor[i] = np.int32(1) << np.int32(
                        rng.integers(_BITFLIP_LO, _BITFLIP_HI)
                    )
                else:
                    cur_mult[i] = np.float32(m)
                    cur_xor[i] = 0
            corrupt = (corrupt | start) & ~stop
            cur_mult[~corrupt] = 1.0
            cur_xor[~corrupt] = 0
            # dead nodes send nothing: force their wire planes honest so
            # the corruption trace never claims bytes that never moved
            row_ok = corrupt & self.alive[t]
            mult[t] = np.where(row_ok, cur_mult, np.float32(1.0))
            xor[t] = np.where(row_ok, cur_xor, 0)
        return mult, xor

    @property
    def has_corruption(self) -> bool:
        """True iff any (node, step) actually lies on the wire.

        Checked on the DERIVED arrays, not the config: a scripted plan
        (arrays edited in place, like :meth:`from_node_churn` does for
        ``alive``) still reports -- and fingerprints -- its corruption.
        """
        return bool(
            (self.corrupt_mult != np.float32(1.0)).any()
            or (self.corrupt_xor != 0).any()
        )

    @property
    def ring_depth(self) -> int:
        """Ring-buffer depth that makes every drawn delay reachable:
        ``tau_max + 1`` slots hold delays 0..tau_max without aliasing."""
        return self.tau_max + 1

    def dropped_edges(self, t: int) -> np.ndarray:
        """(m, 2) int64 array of (src, dst) drops at step ``t``.

        Random-access: stream keyed by ``[seed, tag, t]``, so a resumed
        run re-draws exactly this step's drops without replaying the
        prefix.
        """
        if not 0 <= t < self.steps:
            raise ValueError(f"t={t} outside [0, {self.steps})")
        if self.edge_drop_rate == 0.0:
            return np.zeros((0, 2), dtype=np.int64)
        rng = np.random.default_rng([self.seed, _STREAM_EDGES, t])
        mask = rng.random((self.n_nodes, self.n_nodes)) < self.edge_drop_rate
        np.fill_diagonal(mask, False)
        return np.argwhere(mask).astype(np.int64)

    def solve_fault(self, k: int) -> str:
        """Fate of the k-th topology refresh solve: 'ok'|'raise'|'hang'."""
        if k < 0:
            raise ValueError(f"k must be >= 0, got {k}")
        if self.solve_failure_rate == 0.0 and self.solve_hang_rate == 0.0:
            return "ok"
        u = np.random.default_rng([self.seed, _STREAM_SOLVES, k]).random()
        if u < self.solve_failure_rate:
            return "raise"
        if u < self.solve_failure_rate + self.solve_hang_rate:
            return "hang"
        return "ok"

    # -- derived views ------------------------------------------------------

    def alive_frac(self, t0: int = 0, k: int | None = None) -> float:
        """Mean alive fraction over steps [t0, t0 + k)."""
        k = self.steps - t0 if k is None else k
        window = self.alive[t0 : t0 + k]
        return float(window.mean()) if window.size else 1.0

    def delivered_frac(self, t: int) -> float:
        """Fraction of the fault-free per-step transfer volume delivered.

        The all-gather model moves n(n-1) directed transfers per step; a
        transfer survives iff both endpoints are alive and the edge was
        not dropped. This is the honest ``delivered_frac`` for
        :meth:`repro.train.metrics.CommMeter.tick` under faults.
        """
        n = self.n_nodes
        if n < 2:
            return 1.0
        a = self.alive[t]
        ok = np.outer(a, a)
        np.fill_diagonal(ok, False)
        edges = self.dropped_edges(t)
        if edges.size:
            ok[edges[:, 0], edges[:, 1]] = False
        return float(ok.sum()) / (n * (n - 1))

    def transfer_fracs(
        self, t: int, deadline: int | None = None, mode: str = "wait"
    ) -> tuple[float, float, float]:
        """Three-way fate split of step ``t``'s n(n-1) directed transfers:
        ``(on_time, deferred, dropped)``, summing to 1.

        * *dropped*: an endpoint is dead or the edge was dropped -- the
          bytes never arrive. Under ``mode="degrade"`` with a
          ``deadline``, a source later than the deadline joins this
          bucket (the repaired schedule self-loops it for the step).
        * *deferred*: the source is a straggler (``delays[t, src] > 0``)
          but the transfer is otherwise alive -- the bytes DO arrive,
          past their freshness deadline (the wait policy consumes them
          stale).
        * *on_time*: everything else.

        ``on_time + deferred == delivered_frac(t)`` under ``wait`` (the
        back-compatible two-way split); ``degrade`` moves the
        past-deadline deferred mass into dropped. This is the honest
        pair for :meth:`repro.train.metrics.CommMeter.tick`'s
        ``(delivered_frac, deferred_frac)``.
        """
        if mode not in ("wait", "degrade"):
            raise ValueError(f"mode must be 'wait' or 'degrade', got {mode!r}")
        n = self.n_nodes
        if n < 2:
            return 1.0, 0.0, 0.0
        a = np.asarray(self.alive[t], bool).copy()
        d = np.asarray(self.delays[t])
        if mode == "degrade" and deadline is not None:
            a &= ~(d > deadline)
        ok = np.outer(a, a)
        np.fill_diagonal(ok, False)
        edges = self.dropped_edges(t)
        if edges.size:
            ok[edges[:, 0], edges[:, 1]] = False
        total = n * (n - 1)
        delivered = int(ok.sum())
        late_src = (d > 0) & a
        deferred = int(ok[late_src, :].sum())
        on_time = delivered - deferred
        return on_time / total, deferred / total, (total - delivered) / total

    def quarantined_frac(
        self,
        t: int,
        quarantined: np.ndarray,
        deadline: int | None = None,
        mode: str = "wait",
    ) -> float:
        """Fraction of step ``t``'s n(n-1) directed transfers that were
        DELIVERED but touch a quarantined endpoint.

        Quarantine isolation is bidirectional (the repaired W pins the
        node to ``e_i`` symmetrically), so a transfer is quarantined iff
        it would otherwise deliver AND either endpoint is quarantined.
        Always a subset of ``delivered`` = ``on_time + deferred`` from
        :meth:`transfer_fracs` -- the meter's ``quarantined_bytes``
        honesty invariant.
        """
        if mode not in ("wait", "degrade"):
            raise ValueError(f"mode must be 'wait' or 'degrade', got {mode!r}")
        n = self.n_nodes
        q = np.asarray(quarantined, bool)
        if q.shape != (n,):
            raise ValueError(f"quarantined must be ({n},), got {q.shape}")
        if n < 2 or not q.any():
            return 0.0
        a = np.asarray(self.alive[t], bool).copy()
        d = np.asarray(self.delays[t])
        if mode == "degrade" and deadline is not None:
            a &= ~(d > deadline)
        ok = np.outer(a, a)
        np.fill_diagonal(ok, False)
        edges = self.dropped_edges(t)
        if edges.size:
            ok[edges[:, 0], edges[:, 1]] = False
        touched = q[:, None] | q[None, :]
        return float((ok & touched).sum()) / (n * (n - 1))

    def fingerprint(self) -> str:
        """sha256 over the full derived trace (the cross-process
        determinism witness: two processes with the same config must
        agree on every byte)."""
        h = hashlib.sha256()
        h.update(repr((self.n_nodes, self.steps, self.seed, self.crash_rate,
                       self.mean_outage, self.straggler_rate, self.tau_max,
                       self.edge_drop_rate, self.solve_failure_rate,
                       self.solve_hang_rate)).encode())
        h.update(self.alive.tobytes())
        h.update(self.delays.tobytes())
        for t in range(self.steps):
            h.update(self.dropped_edges(t).tobytes())
        for k in range(self.steps):
            h.update(self.solve_fault(k).encode())
        # corruption joins the hash ONLY when the derived trace actually
        # lies somewhere: plans that don't use it keep their pre-existing
        # fingerprints byte-for-byte (pinned by a regression test)
        if self.has_corruption:
            h.update(repr((self.corrupt_rate, self.mean_corruption,
                           self.corrupt_modes)).encode())
            h.update(self.corrupt_mult.tobytes())
            h.update(self.corrupt_xor.tobytes())
        return h.hexdigest()

    @classmethod
    def from_node_churn(cls, churn, steps: int, **kwargs) -> "FaultPlan":
        """Generalize a :class:`repro.data.drift.NodeChurn` scenario: the
        plan's alive trace mirrors the churn's offline windows exactly
        (on top of any additional stochastic faults in ``kwargs``)."""
        plan = cls(n_nodes=churn.n_nodes, steps=steps, **kwargs)
        for node, t_start, t_end in churn.offline_windows():
            plan.alive[max(t_start, 0) : min(t_end, steps), node] = False
        for t in range(steps):
            if not plan.alive[t].any():
                plan.alive[t, 0] = True
        plan.delays[~plan.alive] = 0
        return plan


class FaultInjector:
    """Binds a :class:`FaultPlan` to a live data-plane schedule.

    Produces the per-step degraded ``ScheduleArrays`` and delay vectors
    a compiled rollout consumes as scan data. ``rebind`` swaps the
    fault-free base schedule after an online topology refresh -- the
    degradation then applies to the NEW topology from the next step on.

    ``policy`` (a :class:`repro.core.mixing.StragglerPolicy`) resolves
    the plan's raw delay trace against a deadline: each step's alive
    mask, edge drops AND past-deadline stragglers fold into one
    schedule repair, and the streamed delay vectors become the policy's
    effective (clamped / zeroed) delays. ``policy=None`` keeps the
    PR 6 behavior: repair on crashes/drops only, raw delays passed
    through.

    ``set_quarantine`` folds a host-decided quarantine mask into the
    SAME single repair call (``alive_eff = alive & ~quarantined``): a
    quarantined node is isolated to ``e_i`` symmetrically, so W stays
    exactly doubly stochastic on the trusted support with zero extra
    repair passes -- and zero retraces, since the swap is pure values.
    """

    def __init__(self, plan: FaultPlan, base: ScheduleArrays, policy=None,
                 tracer=None):
        if base.n_nodes != plan.n_nodes:
            raise ValueError(
                f"schedule is for {base.n_nodes} nodes, plan for {plan.n_nodes}"
            )
        self.plan = plan
        self.base = base
        self.policy = policy
        # a repro.obs.Tracer (duck-typed; this module stays importable
        # without obs loaded) -- stream() records "faults.stream" spans
        self.tracer = tracer
        self.quarantined = np.zeros(plan.n_nodes, dtype=bool)

    def set_quarantine(self, mask: np.ndarray) -> None:
        """Replace the quarantine mask (applies from the next streamed
        step on -- the controller calls this at segment boundaries)."""
        m = np.asarray(mask, bool)
        if m.shape != (self.plan.n_nodes,):
            raise ValueError(
                f"mask must be ({self.plan.n_nodes},), got {m.shape}"
            )
        self.quarantined = m.copy()

    def _alive_eff(self, t: int) -> np.ndarray:
        if not self.quarantined.any():
            return self.plan.alive[t]
        return self.plan.alive[t] & ~self.quarantined

    def rebind(self, base: ScheduleArrays) -> None:
        if base.n_nodes != self.plan.n_nodes or base.l_max != self.base.l_max:
            raise ValueError(
                "rebind must preserve the schedule shape "
                f"({self.base.l_max}, {self.base.n_nodes}); got "
                f"({base.l_max}, {base.n_nodes})"
            )
        self.base = base

    def arrays_at(self, t: int) -> ScheduleArrays:
        """Degraded schedule for step ``t`` (host-side value change)."""
        return degrade_schedule(
            self.base, self._alive_eff(t), self.plan.dropped_edges(t)
        )

    def delays_at(self, t: int) -> np.ndarray:
        return self.plan.delays[t]

    def stream(self, t0: int, k: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host-side per-step fault data for steps [t0, t0 + k), stacked
        for a ``lax.scan``: ``(gammas (k, l_max), perms (k, l_max, n),
        delays (k, n))``. Fixed shapes whatever the faults -- the whole
        zero-retrace argument."""
        if self.tracer is not None:
            with self.tracer.span("faults.stream", t0=int(t0), k=int(k)):
                return self._stream(t0, k)
        return self._stream(t0, k)

    def _stream(self, t0: int, k: int):
        gammas = np.empty((k, self.base.l_max), np.float32)
        perms = np.empty((k, self.base.l_max, self.base.n_nodes), np.int32)
        delays = np.empty((k, self.base.n_nodes), np.int32)
        for j in range(k):
            t = t0 + j
            if self.policy is None:
                arrays_t = self.arrays_at(t)
                delays[j] = self.plan.delays[t]
            else:
                arrays_t, delays[j] = self.policy.apply(
                    self.base,
                    self.plan.delays[t],
                    alive_mask=self._alive_eff(t),
                    dropped_edges=self.plan.dropped_edges(t),
                )
            gammas[j] = np.asarray(arrays_t.gammas)
            perms[j] = np.asarray(arrays_t.perms)
        return gammas, perms, delays

    def corrupt_stream(self, t0: int, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Wire-corruption planes for steps [t0, t0 + k), stacked for a
        ``lax.scan``: ``(mult (k, n) f32, xor (k, n) int32)``. Slices of
        the precomputed trace -- same fixed-shape/zero-retrace contract
        as :meth:`stream`."""
        if not 0 <= t0 <= t0 + k <= self.plan.steps:
            raise ValueError(
                f"window [{t0}, {t0 + k}) outside [0, {self.plan.steps})"
            )
        return (
            np.ascontiguousarray(self.plan.corrupt_mult[t0 : t0 + k]),
            np.ascontiguousarray(self.plan.corrupt_xor[t0 : t0 + k]),
        )


class FlakyRefresher:
    """Wrap a ``TopologyRefresher`` so its solves fail per the plan.

    The k-th ``refresh`` call consults ``plan.solve_fault(k)``:
    ``"raise"`` raises RuntimeError, ``"hang"`` blocks on ``hang_event``
    (or sleeps ``hang_s``) before proceeding, ``"ok"`` delegates.
    Everything else (``schedule``, ``W``, ``schedule_arrays``,
    ``last_refresh_s``, ...) proxies to the wrapped refresher, so the
    controller cannot tell the difference -- which is the point: the
    hardening must work against the real interface.

    Pass a ``threading.Event`` as ``hang_event`` in tests and SET it in
    the test's finally block: executor worker threads are non-daemon,
    so an un-released hang would block interpreter exit.
    """

    def __init__(
        self,
        inner,
        plan: FaultPlan,
        hang_event: "threading.Event | None" = None,
        hang_s: float = 60.0,
    ):
        self._inner = inner
        self._plan = plan
        self._hang_event = hang_event
        self._hang_s = float(hang_s)
        self.n_solves = 0
        self.n_injected_failures = 0
        self.n_injected_hangs = 0

    def refresh(self, Pi_hat):
        k = self.n_solves
        self.n_solves += 1
        fate = self._plan.solve_fault(k)
        if fate == "raise":
            self.n_injected_failures += 1
            raise RuntimeError(f"injected solve failure (refresh #{k})")
        if fate == "hang":
            self.n_injected_hangs += 1
            if self._hang_event is not None:
                self._hang_event.wait()
            else:
                import time

                time.sleep(self._hang_s)
        return self._inner.refresh(Pi_hat)

    def __getattr__(self, name):
        return getattr(self._inner, name)

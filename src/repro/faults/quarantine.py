"""Receiver-side corruption screening, quarantine, and re-admission.

The fault layer's answer to nodes that LIE (wire corruption) rather
than disappear. Three pieces, split across the trace boundary so the
compiled rollout never retraces:

* In-graph (``repro.core.mixing.mix_schedule_arrays_screened``): the
  hard non-finite guard plus cheap per-edge reductions
  (:class:`~repro.core.mixing.ScreenStats`) riding the scan as outputs.
* Host-side (:class:`ScreenPolicy`): norm and deviation screens
  thresholded from the run's OWN live heterogeneity probes. This is the
  paper-aware part -- under label skew a legitimately heterogeneous
  neighbor is statistically indistinguishable from a corrupted one to a
  fixed-threshold distance screen, so the allowance must be derived
  from the measured consensus spread and gradient deviation, not from a
  constant.
* :class:`QuarantineController`: streak-confirmed quarantine, cooldown,
  probation re-admission, and the plumbing into the rest of the stack
  (``FaultInjector.set_quarantine`` for the doubly-stochastic repair,
  ``StreamingPiEstimator`` absence masking, an inner
  ``OnlineTopologyController`` chained through ``on_segment``).

Zero false quarantines, by construction
---------------------------------------
Honest same-step payloads obey the triangle inequality against the
fleet mean: with ``C = max_i ||p_i - p_bar||^2`` (the consensus probe),

    ||p_j - p_i|| <= ||p_j - p_bar|| + ||p_bar - p_i|| <= 2 sqrt(C).

Both screens test statistics bounded by ``||p_j - p_i||`` (the norm
screen by the reverse triangle inequality), so any allowance
``dev_allow >= 2 sqrt(C)`` can never flag an honest same-step edge --
whatever the label skew, because C is measured on the actual run.
``slack >= 1`` times the bound plus an absolute floor keeps the
guarantee with margin; under bounded delay ``tau_max > 0`` the payload
may be ``tau`` steps old, and the bound gains a window-max over the
trailing ``tau_max + 1`` probes plus a mean-drift term
``lr (tau_max + 2) (sqrt(max ||g_bar||^2) + sqrt(max_i ||g_i -
g_bar||^2))`` covering how far the fleet mean can travel while the
payload was in flight. The false-quarantine rate across every
``data/drift.py`` scenario is pinned at 0 in tests and the CI smoke.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.mixing import ScreenStats
from repro.online.streaming import mask_absent

__all__ = ["ScreenPolicy", "QuarantineController", "false_quarantines"]


@dataclasses.dataclass(frozen=True)
class ScreenPolicy:
    """Threshold and lifecycle policy for the corruption screen.

    Attributes:
      slack: multiplier on the probe-derived honest-deviation bound
        (>= 1 preserves the zero-false-positive guarantee; the margin
        absorbs f32-vs-f64 probe rounding).
      abs_floor: absolute allowance floor -- keeps near-consensus fleets
        (bound ~ 0) from flagging honest f32 rounding noise.
      confirm_streak: consecutive flagged steps required before a node
        is quarantined (a single-step glitch -- one bad batch, one
        transient -- never quarantines).
      cooldown_steps: steps a quarantined node stays isolated before it
        is offered probation.
      probation_steps: steps a re-admitted node must screen clean
        before it is fully trusted; any flag during probation
        re-quarantines with the cooldown DOUBLED (exponential backoff
        for chronic liars).
      tau_term: optional additive allowance per unit of the controller's
        live ``tau_bar`` proxy (0 disables). ``tau_bar`` rises exactly
        when the topology tolerates more neighborhood heterogeneity, so
        an operator can trade screen sharpness for fewer probation
        round-trips on very skewed fleets.
    """

    slack: float = 1.25
    abs_floor: float = 1e-4
    confirm_streak: int = 2
    cooldown_steps: int = 32
    probation_steps: int = 16
    tau_term: float = 0.0

    def __post_init__(self) -> None:
        if self.slack < 1.0:
            raise ValueError(
                f"slack must be >= 1 (the zero-false-positive bound), "
                f"got {self.slack}"
            )
        if self.abs_floor < 0.0:
            raise ValueError(f"abs_floor must be >= 0, got {self.abs_floor}")
        if self.confirm_streak < 1:
            raise ValueError(
                f"confirm_streak must be >= 1, got {self.confirm_streak}"
            )
        if self.cooldown_steps < 1 or self.probation_steps < 0:
            raise ValueError(
                f"bad cooldown_steps={self.cooldown_steps} / "
                f"probation_steps={self.probation_steps}"
            )
        if self.tau_term < 0.0:
            raise ValueError(f"tau_term must be >= 0, got {self.tau_term}")

    def dev_allow(
        self,
        consensus_sq: float,
        gdev_sq: float,
        gbar_sq: float,
        *,
        lr: float,
        tau_max: int = 0,
        tau_bar: float = 0.0,
    ) -> float:
        """Honest-deviation allowance from (window-max) probe values.

        ``consensus_sq`` is ``max_i ||p_i - p_bar||^2`` over the
        staleness window, ``gdev_sq`` / ``gbar_sq`` the matching
        gradient-deviation and mean-gradient maxima (only consulted
        when ``tau_max > 0``).
        """
        bound = 2.0 * float(np.sqrt(max(consensus_sq, 0.0)))
        if tau_max > 0:
            drift = float(np.sqrt(max(gbar_sq, 0.0))) + float(
                np.sqrt(max(gdev_sq, 0.0))
            )
            bound += lr * (tau_max + 2) * drift
        return self.abs_floor + self.slack * bound + self.tau_term * tau_bar


def _edge_flags(
    stats: ScreenStats,
    gammas: np.ndarray,
    perms: np.ndarray,
    allow: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-step per-sender (flagged, exposed) bool arrays, both (k, n).

    A sender is *exposed* at a step if at least one active non-self
    edge carries its payload (gamma > 0); it is *flagged* if any such
    edge fails the non-finite, norm, or deviation screen. Receivers
    never vote on their own self-loop (no wire payload there).
    """
    sq_own = np.asarray(stats.sq_own, np.float64)  # (k, n)
    sq_recv = np.asarray(stats.sq_recv, np.float64)  # (k, l, n)
    dot = np.asarray(stats.dot, np.float64)
    finite = np.asarray(stats.finite, bool)
    gam = np.asarray(gammas, np.float64)  # (k, l)
    per = np.asarray(perms, np.int64)  # (k, l, n)
    k, l_max, n = per.shape
    recv_idx = np.arange(n)[None, None, :]
    active = (gam[:, :, None] > 0.0) & (per != recv_idx)  # non-self, live slot
    dev_sq = sq_own[:, None, :] + sq_recv - 2.0 * dot  # ||p_j - p_i||^2
    norm_gap = np.abs(np.sqrt(sq_recv) - np.sqrt(sq_own)[:, None, :])
    a = allow.reshape(k, 1, 1)
    bad = ~finite | (norm_gap > a) | (dev_sq > a * a)
    # edge (t, l, i) blames SENDER per[t, l, i]: scatter-or by sender
    flagged = np.zeros((k, n), dtype=bool)
    exposed = np.zeros((k, n), dtype=bool)
    t_idx = np.broadcast_to(np.arange(k)[:, None, None], per.shape)
    np.logical_or.at(exposed, (t_idx[active], per[active]), True)
    hit = active & bad
    np.logical_or.at(flagged, (t_idx[hit], per[hit]), True)
    return flagged, exposed


class QuarantineController:
    """Streak-confirmed quarantine with probation re-admission.

    The host-side half of the corruption defense. A fault runner calls
    :meth:`ingest` once per segment with the scan's stacked
    :class:`~repro.core.mixing.ScreenStats`, the per-step mixing tables
    it actually used, and the per-step probe scalars; the controller
    updates its per-node lifecycle state machine

        trusted --confirm_streak flags--> quarantined
        quarantined --cooldown--> probation
        probation --clean window--> trusted
        probation --any flag--> quarantined (cooldown doubled)

    and exposes the resulting mask via :meth:`mask` / ``quarantined``.
    All transitions land at segment boundaries -- the scan that already
    ran is immutable -- as pure value changes (the caller folds the
    mask into ``FaultInjector.set_quarantine``), so the rollout never
    retraces.

    ``inner`` (optional) is an ``OnlineTopologyController``:
    :meth:`observe` masks quarantined nodes' label rows to -1 (absent)
    before forwarding, so the streaming Pi estimate holds their rows
    exactly while isolated and ``rejoin_beta`` snaps them on
    re-admission; :meth:`on_segment` delegates, so the stack composes
    as one hook.
    """

    def __init__(
        self,
        n_nodes: int,
        policy: ScreenPolicy | None = None,
        *,
        lr: float,
        tau_max: int = 0,
        inner=None,
    ):
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if tau_max < 0:
            raise ValueError(f"tau_max must be >= 0, got {tau_max}")
        self.n_nodes = int(n_nodes)
        self.policy = policy or ScreenPolicy()
        self.lr = float(lr)
        self.tau_max = int(tau_max)
        self.inner = inner
        self.quarantined = np.zeros(self.n_nodes, dtype=bool)
        self.events: list[dict] = []
        self.n_quarantines = 0
        self.n_readmissions = 0
        self._streak = np.zeros(self.n_nodes, dtype=np.int64)
        self._cooldown = np.zeros(self.n_nodes, dtype=np.int64)
        self._probation = np.zeros(self.n_nodes, dtype=np.int64)
        # per-node cooldown length, doubled on each probation failure
        self._cooldown_len = np.full(
            self.n_nodes, self.policy.cooldown_steps, dtype=np.int64
        )
        # trailing probe window for staleness-aware thresholds
        self._probe_win: deque = deque(maxlen=self.tau_max + 1)

    def mask(self) -> np.ndarray:
        """Current quarantine mask (copy) -- True = isolated."""
        return self.quarantined.copy()

    @property
    def trusted(self) -> np.ndarray:
        return ~self.quarantined

    # -- probe plumbing -----------------------------------------------------

    def _allowances(self, probes: dict, k: int, tau_bar: float) -> np.ndarray:
        cons = np.asarray(probes["consensus_sq"], np.float64).reshape(-1)
        gdev = np.asarray(probes["gdev_sq"], np.float64).reshape(-1)
        gbar = np.asarray(probes["gbar_sq"], np.float64).reshape(-1)
        if not (cons.shape == gdev.shape == gbar.shape == (k,)):
            raise ValueError(
                f"probes must be ({k},) each, got {cons.shape}/{gdev.shape}/"
                f"{gbar.shape}"
            )
        allow = np.empty(k)
        for j in range(k):
            self._probe_win.append((cons[j], gdev[j], gbar[j]))
            win = np.asarray(self._probe_win)
            allow[j] = self.policy.dev_allow(
                float(win[:, 0].max()),
                float(win[:, 1].max()),
                float(win[:, 2].max()),
                lr=self.lr,
                tau_max=self.tau_max,
                tau_bar=tau_bar,
            )
        return allow

    # -- lifecycle ----------------------------------------------------------

    def ingest(
        self,
        t0: int,
        stats: ScreenStats,
        gammas: np.ndarray,
        perms: np.ndarray,
        probes: dict,
        tau_bar: float = 0.0,
    ) -> np.ndarray:
        """Fold one segment's screen evidence in; returns the new mask.

        Args:
          t0: global step index of the segment's first step.
          stats: scan-stacked screen stats (leading axis k).
          gammas / perms: the (k, l_max) / (k, l_max, n) mixing tables
            the segment actually ran with (quarantined nodes appear as
            self-loops there, so they gather no votes and cast none).
          probes: dict with per-step (k,) arrays ``consensus_sq``
            (max_i ||p_i - p_bar||^2), ``gdev_sq``
            (max_i ||g_i - g_bar||^2), and ``gbar_sq`` (||g_bar||^2).
          tau_bar: optional live heterogeneity proxy for the policy's
            ``tau_term``.
        """
        k = int(np.asarray(gammas).shape[0])
        allow = self._allowances(probes, k, float(tau_bar))
        flagged, exposed = _edge_flags(stats, gammas, perms, allow)
        p = self.policy
        for j in range(k):
            t = t0 + j
            fl, ex = flagged[j], exposed[j]
            # ticking clocks: isolation and probation age per STEP, not
            # per segment, so lifecycle lengths are segment-size-free
            cooling = self.quarantined & (self._cooldown > 0)
            self._cooldown[cooling] -= 1
            release = self.quarantined & (self._cooldown == 0)
            for i in np.flatnonzero(release):
                self.quarantined[i] = False
                self._probation[i] = p.probation_steps
                self._streak[i] = 0
                self.events.append({
                    "t": int(t), "node": int(i), "event": "probation",
                })
            on_probation = self._probation > 0
            # probation failure: ANY flag re-quarantines, backoff doubled
            relapse = on_probation & fl
            for i in np.flatnonzero(relapse):
                self._cooldown_len[i] *= 2
                self._quarantine(int(t), int(i), reason="probation_flag")
            # probation success: a clean exposed step burns one
            # probation step; survival of the whole window restores
            # full trust (and resets the backoff)
            clean = on_probation & ex & ~fl & ~relapse
            self._probation[clean] -= 1
            for i in np.flatnonzero(clean & (self._probation == 0)):
                self._cooldown_len[i] = p.cooldown_steps
                self.n_readmissions += 1
                self.events.append({
                    "t": int(t), "node": int(i), "event": "readmitted",
                })
                # fleet composition is whole again: ask the topology
                # stack to re-solve with the returning node's (snapped)
                # Pi row instead of waiting for the drift detector
                if self.inner is not None and hasattr(
                    self.inner, "request_refresh"
                ):
                    self.inner.request_refresh(reason="readmitted")
            # trusted nodes: streak-confirmed quarantine
            watch = ~self.quarantined & ~(self._probation > 0)
            self._streak[watch & fl] += 1
            self._streak[watch & ex & ~fl] = 0
            for i in np.flatnonzero(
                watch & (self._streak >= p.confirm_streak)
            ):
                self._quarantine(int(t), int(i), reason="confirmed")
        return self.mask()

    def _quarantine(self, t: int, i: int, reason: str) -> None:
        self.quarantined[i] = True
        self._cooldown[i] = self._cooldown_len[i]
        self._probation[i] = 0
        self._streak[i] = 0
        self.n_quarantines += 1
        self.events.append({
            "t": int(t), "node": int(i), "event": "quarantine",
            "reason": reason, "cooldown": int(self._cooldown_len[i]),
        })
        if self.inner is not None and hasattr(self.inner, "request_refresh"):
            self.inner.request_refresh(reason="quarantine")

    # -- inner-controller chaining ------------------------------------------

    def observe(self, labels: np.ndarray) -> None:
        """Forward one step's labels with quarantined rows masked absent.

        A quarantined node's data is untrusted, so its Pi row must not
        keep updating; marking the whole row < 0 makes the
        ``StreamingPiEstimator`` hold it (and count ``absent_streak``),
        and ``rejoin_beta`` snaps it on the first post-release batch.
        """
        if self.inner is None:
            return
        self.inner.observe(mask_absent(labels, self.quarantined))

    def on_segment(self, t: int):
        """Delegate to the inner topology controller (or no-op)."""
        if self.inner is None:
            return None
        return self.inner.on_segment(t)

    def summary(self) -> dict:
        return {
            "n_quarantines": int(self.n_quarantines),
            "n_readmissions": int(self.n_readmissions),
            "quarantined_now": [int(i) for i in np.flatnonzero(self.quarantined)],
            "events": list(self.events),
        }


def false_quarantines(events: list[dict], plan) -> int:
    """Count quarantine events whose node was honest at confirm time.

    Ground-truth audit against a :class:`~repro.faults.plan.FaultPlan`:
    a quarantine at step ``t`` of node ``i`` is FALSE iff the plan's
    corruption trace shows ``i`` honest over the trailing confirm
    window ``[t - steps_back, t]`` (a node can recover between lying
    and being confirmed -- blaming the screen for reacting to real lies
    that just ended would be unfair, so the window looks back).
    """
    bad = (plan.corrupt_mult != np.float32(1.0)) | (plan.corrupt_xor != 0)
    count = 0
    for ev in events:
        if ev.get("event") != "quarantine":
            continue
        t, i = int(ev["t"]), int(ev["node"])
        lo = max(t - 2 * max(plan.tau_max, 1) - 8, 0)
        hi = min(t + 1, plan.steps)
        if not bad[lo:hi, i].any():
            count += 1
    return count

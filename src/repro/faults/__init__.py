"""Fault injection + graceful degradation for decentralized training.

The deployment story ("millions of users") breaks three assumptions the
fault-free stack makes: every node is up every step, every gossip edge
delivers, and the overlapped refresh solve always returns. This package
makes each failure a first-class, seeded, reproducible scenario:

* :class:`FaultPlan` / :class:`FaultInjector` -- deterministic fault
  traces (crash/rejoin windows, per-edge message drops, bounded-delay
  stragglers, overlap-worker failures) from a single seed, identical
  across processes and across checkpoint resumes.
* :class:`FlakyRefresher` -- wraps a ``TopologyRefresher`` so its
  solves raise or hang per the plan (the controller-hardening drill).
* :class:`ScreenPolicy` / :class:`QuarantineController` -- the defense
  against nodes that LIE rather than disappear: receiver-side screens
  thresholded from the run's own heterogeneity probes, streak-confirmed
  quarantine with a doubly-stochastic repair, and probation-based
  self-healing re-admission.
* :func:`run_faulty_mean_estimation` -- the mean-estimation simulator
  under faults: degraded doubly-stochastic mixing
  (:func:`repro.core.mixing.degrade_schedule`), stale-theta mixing via
  the staleness ring buffer, wire corruption + screening, and
  crash-recovery via ``repro.train.checkpoints`` -- all zero-retrace.

Layering: ``faults`` imports core + data + train (for checkpoints) +
online (for the estimator-absence plumbing); nothing imports ``faults``
back -- the production modules only grow fault-*tolerant* paths, never
fault-*aware* ones.
"""

from .plan import FaultInjector, FaultPlan, FlakyRefresher
from .quarantine import QuarantineController, ScreenPolicy, false_quarantines
from .runner import run_faulty_mean_estimation

__all__ = [
    "FaultPlan",
    "FaultInjector",
    "FlakyRefresher",
    "ScreenPolicy",
    "QuarantineController",
    "false_quarantines",
    "run_faulty_mean_estimation",
]

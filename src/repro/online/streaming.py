"""Streaming heterogeneity estimation: Pi_hat from minibatch labels.

The paper learns W once, from the exact label-proportion matrix Pi,
before training starts (Section 5). Online topology adaptation needs the
same quantity *during* training, from the only signal a node actually
observes: the labels of its minibatches. Two pieces live here:

* ``StreamingPiEstimator`` -- an exponentially-weighted estimator of Pi.
  Each update folds one step's per-node batch label proportions into
  ``Pi_hat_i <- (1 - beta) Pi_hat_i + beta p_batch_i``, so every row
  stays on the probability simplex by construction and the estimate is
  unbiased under stationarity (``E[p_batch_i] = Pi_i``). ``beta`` sets
  the memory/variance trade-off: the effective window is ``~2/beta``
  batches, and under an abrupt drift the estimate converges to the new
  Pi geometrically at rate ``(1 - beta)`` per step.
* ``DriftDetector`` -- a relative trigger on a scalar heterogeneity
  proxy (the refresh controller feeds it ``tau_bar_label_skew`` of the
  *current* W evaluated at Pi_hat -- Proposition 2's closed form, i.e.
  exactly the criterion the paper optimizes). The detector keeps an
  exponentially-weighted baseline of the proxy; a drift fires when the
  observed value exceeds ``threshold x baseline + abs_slack``. The
  threshold is configurable; the false-positive rate on stationary
  streams is pinned by tests under a fixed seed
  (tests/test_online.py).

Everything here is host-side numpy: label streams are exogenous to the
compiled training step, so estimation adds zero work to the hot path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StreamingPiEstimator", "DriftDetector", "mask_absent"]


def mask_absent(labels: np.ndarray, absent: np.ndarray) -> np.ndarray:
    """Mark whole node rows of a label batch absent (all entries -> -1).

    The one blessed way to hide a node from the streaming estimator for
    a step -- churn drivers and the quarantine controller both use it,
    so "absent" means exactly one thing: the row is held (no decay),
    ``absent_streak`` counts, and ``rejoin_beta`` snaps on return.
    Returns a copy when any row is masked; the original array otherwise.
    """
    labels = np.asarray(labels)
    if labels.ndim == 1:
        labels = labels[:, None]
    absent = np.asarray(absent, bool)
    if absent.shape != (labels.shape[0],):
        raise ValueError(
            f"absent mask must be ({labels.shape[0]},), got {absent.shape}"
        )
    if not absent.any():
        return labels
    out = labels.copy()
    out[absent] = -1
    return out


class StreamingPiEstimator:
    """Exponentially-weighted streaming estimate of the (n, K) Pi matrix.

    Args:
      n_nodes: number of nodes (rows of Pi).
      num_classes: number of classes K (fixed across drift -- pass the
        task's class count, not the max label seen so far, or the
        estimate changes shape mid-run).
      beta: EW step size in (0, 1]; effective window ~2/beta batches.
      init: optional (n, K) initial estimate (e.g. the Pi the initial
        topology was learned from). Defaults to the uniform matrix.
      rejoin_beta: optional boosted step size in (0, 1] applied to a
        node's FIRST update after one or more fully-absent steps. A
        node dark for a whole outage window holds a stale row (held,
        not decayed -- see below); on rejoin the stale row is exactly
        the thing to forget fast, so ``rejoin_beta`` (typically >>
        ``beta``, e.g. 0.5) snaps it toward the fresh batch instead of
        blending at the slow stationary rate. ``None`` (default) keeps
        the single-rate behavior bitwise.

    Labels < 0 are treated as "absent" (node churn: a node that is
    offline this step contributes no observations and its row keeps its
    previous value, decaying toward nothing new rather than toward
    garbage). ``absent_streak[i]`` counts consecutive fully-absent
    updates for node ``i`` (reset on the first present batch).
    """

    def __init__(
        self,
        n_nodes: int,
        num_classes: int,
        beta: float = 0.1,
        init: np.ndarray | None = None,
        rejoin_beta: float | None = None,
    ):
        if not 0.0 < beta <= 1.0:
            raise ValueError(f"beta must be in (0, 1], got {beta}")
        if rejoin_beta is not None and not 0.0 < rejoin_beta <= 1.0:
            raise ValueError(f"rejoin_beta must be in (0, 1], got {rejoin_beta}")
        if n_nodes < 1 or num_classes < 1:
            raise ValueError("need n_nodes >= 1 and num_classes >= 1")
        self.n_nodes = int(n_nodes)
        self.num_classes = int(num_classes)
        self.beta = float(beta)
        if init is None:
            pi = np.full((n_nodes, num_classes), 1.0 / num_classes)
        else:
            pi = np.asarray(init, dtype=np.float64).copy()
            if pi.shape != (n_nodes, num_classes):
                raise ValueError(
                    f"init must be ({n_nodes}, {num_classes}), got {pi.shape}"
                )
            if not np.allclose(pi.sum(axis=1), 1.0, atol=1e-6):
                raise ValueError("rows of init must sum to 1")
        self._pi = pi
        self.rejoin_beta = None if rejoin_beta is None else float(rejoin_beta)
        self._absent_streak = np.zeros(self.n_nodes, dtype=np.int64)
        self.n_updates = 0

    @property
    def Pi_hat(self) -> np.ndarray:
        """Current estimate (copy; rows sum to 1)."""
        return self._pi.copy()

    @property
    def absent_streak(self) -> np.ndarray:
        """Consecutive fully-absent updates per node (copy)."""
        return self._absent_streak.copy()

    def update(self, labels: np.ndarray) -> np.ndarray:
        """Fold one step's labels in; returns the updated Pi_hat (copy).

        Args:
          labels: (n_nodes, batch) integer labels in [0, K); entries < 0
            mark absent observations (that node's row is left untouched
            when its whole batch is absent, and renormalized over the
            present entries otherwise).
        """
        labels = np.asarray(labels)
        if labels.ndim == 1:
            labels = labels[:, None]
        if labels.shape[0] != self.n_nodes:
            raise ValueError(
                f"labels must be ({self.n_nodes}, batch), got {labels.shape}"
            )
        if labels.size and labels.max() >= self.num_classes:
            raise ValueError(
                f"label {int(labels.max())} out of range for K={self.num_classes}"
            )
        counts = np.zeros((self.n_nodes, self.num_classes))
        present = labels >= 0
        node_idx = np.broadcast_to(
            np.arange(self.n_nodes)[:, None], labels.shape
        )[present]
        np.add.at(counts, (node_idx, labels[present]), 1.0)
        totals = counts.sum(axis=1)
        active = totals > 0
        if np.any(active):
            p_batch = counts[active] / totals[active, None]
            if self.rejoin_beta is not None and np.any(
                self._absent_streak[active] > 0
            ):
                # a rejoining node's row is stale by absent_streak
                # steps: snap it toward the fresh batch at rejoin_beta
                beta = np.where(
                    self._absent_streak[active] > 0, self.rejoin_beta, self.beta
                )[:, None]
            else:
                beta = self.beta  # scalar fast path, bitwise-stable
            self._pi[active] = (1.0 - beta) * self._pi[active] + beta * p_batch
        self._absent_streak[active] = 0
        self._absent_streak[~active] += 1
        self.n_updates += 1
        return self.Pi_hat


@dataclasses.dataclass
class DriftDetector:
    """Relative trigger on a scalar heterogeneity proxy.

    The controller evaluates ``proxy_t`` (by default Proposition 2's
    ``tau_bar_label_skew`` of the current W at Pi_hat) once per segment
    and calls :meth:`update`. The detector maintains an EW baseline of
    the proxy; a drift fires when

        proxy_t > threshold * baseline + abs_slack

    after ``warmup`` updates have seeded the baseline. ``rebase()``
    resets the baseline after a refresh (the proxy legitimately drops
    once W is re-learned -- carrying the stale baseline over would make
    the *next* trigger threshold nonsense).

    Attributes:
      threshold: relative trigger factor (> 1; 1.5 means "fire when the
        neighborhood-heterogeneity proxy worsens by 50%").
      abs_slack: additive slack so near-zero baselines (a topology that
        nails Pi exactly) don't turn fp noise into triggers.
      baseline_beta: EW rate of the baseline tracker.
      warmup: updates required before triggering is allowed (both after
        construction and after each ``rebase``).
    """

    threshold: float = 1.5
    abs_slack: float = 1e-8
    baseline_beta: float = 0.2
    warmup: int = 3

    def __post_init__(self) -> None:
        if self.threshold <= 1.0:
            raise ValueError("threshold must be > 1 (relative trigger)")
        self._baseline: float | None = None
        self._seen = 0
        self.n_triggers = 0

    @property
    def baseline(self) -> float | None:
        return self._baseline

    def update(self, value: float) -> bool:
        """Fold one proxy observation in; True iff a drift fired."""
        value = float(value)
        self._seen += 1
        if self._baseline is None:
            self._baseline = value
            return False
        if self._seen > self.warmup and value > (
            self.threshold * self._baseline + self.abs_slack
        ):
            self.n_triggers += 1
            return True
        b = self.baseline_beta
        self._baseline = (1.0 - b) * self._baseline + b * value
        return False

    def rebase(self, value: float | None = None) -> None:
        """Reset the baseline after a topology refresh."""
        self._baseline = None if value is None else float(value)
        self._seen = 0

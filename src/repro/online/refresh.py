"""Mid-training topology refresh: warm STL-FW re-solves + hot-swap plumbing.

The pieces the streaming estimator feeds:

* ``TopologyRefresher`` -- re-runs :func:`repro.core.stl_fw.learn_topology`
  *warm*: Frank-Wolfe restarts from the previous W's Birkhoff atoms
  (``init=``), a single persistent ``LMOSolver`` carries the auction
  backends' dual prices across refreshes, and the solve early-stops at
  the duality-gap level the initial cold solve certified (``stop_gap``).
  A refresh therefore costs a few FW steps, not a cold ``budget``-length
  solve (measured in benchmarks/bench_online.py, BENCH_online.json).
  After each solve the atom set is truncated back to a fixed capacity
  ``l_max`` (largest coefficients kept, renormalized -- still doubly
  stochastic), so the data-plane schedule the trainers consume never
  changes shape.
* ``OnlineTopologyController`` -- the object a training loop talks to.
  It owns the estimator, the drift detector, and the refresher;
  ``observe(labels)`` streams minibatch labels in, and ``on_segment(t)``
  (the hook the drivers in ``repro.train.trainer`` call at segment
  boundaries) evaluates the heterogeneity proxy, consults the detector,
  and -- on a trigger -- refreshes W and returns the new fixed-shape
  :class:`~repro.core.mixing.ScheduleArrays` for a zero-retrace swap.

Layering: this module imports core + data only. The trainers never
import it -- they accept any object with the ``on_segment`` protocol --
so ``repro.train`` stays independent of ``repro.online``.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time

import numpy as np

from repro.core.heterogeneity import tau_bar_label_skew
from repro.core.mixing import (
    BirkhoffSchedule,
    PermPool,
    PoolSwap,
    ScheduleArrays,
    schedule_from_result,
    schedule_to_arrays,
    truncate_schedule,
)
from repro.core.stl_fw import LMOSolver, STLFWResult, learn_topology

from .streaming import DriftDetector, StreamingPiEstimator

__all__ = ["RefreshConfig", "TopologyRefresher", "OnlineTopologyController"]


@dataclasses.dataclass
class RefreshConfig:
    """Policy knobs for warm mid-training refreshes.

    Attributes:
      budget: max FW iterations per refresh (the cap that guarantees a
        refresh is cheap even when the drift is total; the gap stop
        usually fires earlier).
      lam: Eq. (8) bias/variance trade-off. ``None`` (default) inherits
        the initial solve's recorded ``lam`` -- the only choice under
        which the gap target compares like with like. Setting it
        explicitly to a different value is allowed but then the
        refresher discards ``gap_ref`` (gaps of different objectives
        are incomparable) and falls back to the relative ``stop_tol``.
      gap_slack: the refresh stops once its FW gap reaches
        ``gap_slack x`` the initial cold solve's final gap (1.0 =
        "certifiably as converged as the cold solve").
      stop_tol: fallback relative gap stop when the warm start has no
        recorded reference gap.
      l_max: fixed atom capacity of the emitted data-plane schedule
        (which is also the per-step gather/communication degree of the
        data-plane transport). ``None`` defaults to the initial
        result's atom count plus one refresh ``budget`` of headroom:
        a single refresh then fits without truncating its new atoms,
        and across repeated refreshes the contraction-decayed old atoms
        are the ones dropped. A tight ``l_max`` (= initial atom count)
        keeps communication minimal at a measurable topology-quality
        cost -- the trade-off is the operator's.
      method: ``learn_topology`` method ("incremental" | "reference").
    """

    budget: int = 16
    lam: float | None = None
    gap_slack: float = 1.0
    stop_tol: float | None = 0.05
    l_max: int | None = None
    method: str = "incremental"


class TopologyRefresher:
    """Warm re-learner with persistent LMO state and fixed atom capacity.

    Args:
      initial: the cold-solved topology training started with (its atoms
        seed the first warm refresh; its final FW gap is the quality
        target every refresh stops at).
      config: refresh policy.
      lmo: LMO backend name, or a pre-built persistent ``LMOSolver``.
        The same solver instance is reused across every refresh, so the
        auction backends' dual prices (device-resident for
        ``auction_jit``) warm-start each solve; ``"auto"`` resolves with
        ``budget=None`` -- the open-ended online rule.
    """

    def __init__(
        self,
        initial: STLFWResult,
        config: RefreshConfig | None = None,
        lmo: "str | LMOSolver" = "auto",
    ):
        self.config = config or RefreshConfig()
        self.solver = lmo if isinstance(lmo, LMOSolver) else LMOSolver(lmo)
        self.solver.resolve(n=initial.W.shape[0], budget=None)
        sched = schedule_from_result(initial)
        # `is None`, not truthiness: an explicit l_max=0 must hit
        # truncate_schedule's validation, not silently become the default
        if self.config.l_max is not None:
            self.l_max = int(self.config.l_max)
        else:
            self.l_max = sched.n_atoms + self.config.budget
        sched = truncate_schedule(sched, self.l_max)
        self._atoms = (list(sched.coeffs), [np.asarray(p) for p in sched.perms])
        self.result = initial
        if self.config.lam is not None:
            self.lam = float(self.config.lam)
        elif initial.lam is not None:
            self.lam = float(initial.lam)
        else:
            self.lam = 0.1  # the paper's default; pre-lam-field results only
        gap_ref = None
        # the gap target is only meaningful against the SAME objective:
        # require a recorded lam that matches (a result without one --
        # hand-built or pre-lam-field -- could have been solved at any
        # lam, so its gap is incomparable and we fall back to stop_tol)
        same_objective = initial.lam is not None and float(initial.lam) == self.lam
        if same_objective and initial.gap_trace is not None and len(initial.gap_trace):
            gap_ref = float(initial.gap_trace[-1])
        self.gap_ref = gap_ref
        self.n_refreshes = 0
        self.last_refresh_s: float | None = None
        self.last_iters: int | None = None

    @property
    def schedule(self) -> BirkhoffSchedule:
        """Current (truncated) static schedule."""
        return BirkhoffSchedule(
            coeffs=tuple(float(c) for c in self._atoms[0]),
            perms=tuple(tuple(int(x) for x in p) for p in self._atoms[1]),
        )

    @property
    def W(self) -> np.ndarray:
        """Current dense W (rebuilt from the truncated atoms)."""
        return self.schedule.to_matrix()

    def schedule_arrays(self) -> ScheduleArrays:
        """Current schedule in the fixed-shape data-plane format."""
        return schedule_to_arrays(self.schedule, self.l_max)

    def refresh(self, Pi_hat: np.ndarray) -> STLFWResult:
        """Warm re-solve against the streamed Pi estimate.

        Returns the (un-truncated) STLFWResult; the refresher's own
        schedule/arrays views reflect the ``l_max``-truncated atoms.
        """
        cfg = self.config
        stop_gap = None if self.gap_ref is None else self.gap_ref * cfg.gap_slack
        stop_tol = cfg.stop_tol if stop_gap is None else None
        t0 = time.perf_counter()
        res = learn_topology(
            Pi_hat,
            cfg.budget,
            lam=self.lam,
            method=cfg.method,
            lmo=self.solver,
            init=self._atoms,
            stop_tol=stop_tol,
            stop_gap=stop_gap,
        )
        self.last_refresh_s = time.perf_counter() - t0
        self.last_iters = len(res.gamma_trace)
        sched = truncate_schedule(schedule_from_result(res), self.l_max)
        self._atoms = (list(sched.coeffs), [np.asarray(p) for p in sched.perms])
        self.result = res
        self.n_refreshes += 1
        return res


class OnlineTopologyController:
    """Streaming estimation -> drift detection -> warm refresh, as one hook.

    The training drivers call ``on_segment(t)`` at segment boundaries
    (duck-typed -- ``repro.train`` never imports this module). Between
    those calls the label stream is fed in with ``observe`` (labels are
    exogenous to the compiled training step, so this happens host-side
    at zero hot-path cost).

    Args:
      refresher: warm re-learner holding the current topology.
      estimator: streaming Pi estimator (defaults: seeded from the
        refresher's n plus ``num_classes``, uniform init).
      detector: drift detector on the heterogeneity proxy.
      num_classes: K, required when ``estimator`` is not given.
      Pi0: the Pi the initial topology was learned from; seeds the
        default estimator so the proxy does not ramp from the uniform
        init to its stationary value (a ramp the detector would read as
        drift). Ignored when ``estimator`` is given.
      proxy_B / proxy_sigma2: the ``B`` and ``sigma_max^2`` constants of
        Proposition 2's ``tau_bar_label_skew`` proxy. The *relative*
        detector only cares about B up to scale; sigma adds the
        variance term, which does not depend on Pi_hat -- keep it 0 to
        track the drift-sensitive bias part alone.
      pool: a staged :class:`~repro.core.mixing.PermPool` puts the
        controller in POOL COORDINATES: ``on_segment`` returns
        :class:`~repro.core.mixing.PoolSwap` updates instead of
        ``ScheduleArrays``. A refresh whose atoms project onto the pool
        with at most ``pool_miss_tol`` dropped coefficient mass is
        emitted as an in-pool gamma swap (zero retraces for the pool-
        transport trainer); beyond the tolerance the controller
        restages a new pool from the refreshed schedule (counted in
        ``pool_misses``; the trainer pays one recompile). The
        pool-aware truncation this implements trades a bounded amount
        of mixing mass (``dropped_mass``) for staying inside the
        compiled communication plan.
      pool_miss_tol: max coefficient mass the in-pool projection may
        drop before a restage is declared.
      overlap: run each refresh solve in a background worker thread
        instead of inline. The numpy/scipy LMO releases the GIL in
        BLAS, so the solve overlaps the compiled rollout: the
        triggering ``on_segment`` SUBMITS and returns ``None`` (the
        rollout launches its next segment immediately); the first
        boundary after the solve finishes collects the result and
        hands the swap back -- a double-buffered handoff in which the
        hook never blocks on the solver (only an explicit
        :meth:`flush` waits). Detector updates are suspended while a
        solve is in flight (the post-collect ``rebase`` re-anchors the
        baseline), and per-refresh timing lands in ``refresh_log``.
    """

    def __init__(
        self,
        refresher: TopologyRefresher,
        estimator: StreamingPiEstimator | None = None,
        detector: DriftDetector | None = None,
        *,
        num_classes: int | None = None,
        Pi0: np.ndarray | None = None,
        proxy_B: float = 1.0,
        proxy_sigma2: float = 0.0,
        pool: PermPool | None = None,
        pool_miss_tol: float = 0.05,
        overlap: bool = False,
    ):
        self.refresher = refresher
        n = refresher.W.shape[0]
        if estimator is None:
            if num_classes is None and Pi0 is None:
                raise ValueError("pass num_classes, Pi0, or a pre-built estimator")
            if num_classes is None:
                num_classes = int(np.asarray(Pi0).shape[1])
            estimator = StreamingPiEstimator(n, num_classes, init=Pi0)
        if estimator.n_nodes != n:
            raise ValueError(
                f"estimator is for {estimator.n_nodes} nodes, topology has {n}"
            )
        if pool is not None and pool.n_nodes != n:
            raise ValueError(f"pool is for {pool.n_nodes} nodes, topology has {n}")
        self.estimator = estimator
        self.detector = detector or DriftDetector()
        self.proxy_B = float(proxy_B)
        self.proxy_sigma2 = float(proxy_sigma2)
        self.pool = pool
        self.pool_miss_tol = float(pool_miss_tol)
        self.pool_misses = 0
        self.overlap = bool(overlap)
        self.events: list[dict] = []
        self.refresh_log: list[dict] = []
        self._W = refresher.W
        self._executor: concurrent.futures.ThreadPoolExecutor | None = None
        self._pending: tuple[concurrent.futures.Future, dict] | None = None
        self._manual_request = False

    def observe(self, labels: np.ndarray) -> None:
        """Stream one step's (n, batch) minibatch labels in."""
        self.estimator.update(labels)

    def proxy(self) -> float:
        """Current neighborhood-heterogeneity proxy (Prop. 2 at Pi_hat)."""
        return tau_bar_label_skew(
            self._W, self.estimator.Pi_hat, self.proxy_B, self.proxy_sigma2
        )

    def request_refresh(self) -> None:
        """Force a refresh at the next ``on_segment`` (scripted drills /
        external schedulers), bypassing the detector."""
        self._manual_request = True

    @property
    def refresh_pending(self) -> bool:
        return self._pending is not None

    def on_segment(self, t: int):
        """Segment-boundary hook.

        Returns ``None`` (no update -- including "solve still running"
        in overlap mode), a :class:`ScheduleArrays` (no pool), or a
        :class:`PoolSwap` (pool coordinates).
        """
        if self._pending is not None:
            fut, meta = self._pending
            if not fut.done():
                meta["pending_segments"] += 1
                self.events.append({"t": int(t), "pending": True})
                return None
            return self._collect(t, blocked_s=0.0)
        value = self.proxy()
        triggered = self.detector.update(value) or self._manual_request
        self._manual_request = False
        event = {"t": int(t), "proxy": float(value), "triggered": bool(triggered)}
        if not triggered:
            self.events.append(event)
            return None
        # the worker must see a frozen Pi: observe() keeps mutating the
        # estimator while the solve runs (double-buffered handoff)
        snapshot = np.array(self.estimator.Pi_hat)
        if self.overlap:
            fut = self._ensure_executor().submit(self._solve, snapshot)
            self._pending = (
                fut,
                {"t_submit": int(t), "pending_segments": 0,
                 "wall0": time.perf_counter()},
            )
            event["submitted"] = True
            self.events.append(event)
            return None
        self._solve(snapshot)
        self.events.append(event)
        swap = self._finish_refresh(t)
        self.refresh_log.append({
            "t_submit": int(t), "t_collect": int(t),
            "solve_s": self.refresher.last_refresh_s,
            "pending_segments": 0, "overlap_wall_s": 0.0, "blocked_s": 0.0,
            "restaged": isinstance(swap, PoolSwap) and swap.restaged,
        })
        return swap

    def flush(self, t: int | None = None):
        """Block on an in-flight solve and return its swap (or None).

        The one place the controller is allowed to wait: call it after
        the rollout's final segment so a late solve still lands (the
        blocked time is recorded honestly in ``refresh_log``).
        """
        if self._pending is None:
            return None
        fut, _ = self._pending
        t0 = time.perf_counter()
        fut.result()
        blocked = time.perf_counter() - t0
        return self._collect(-1 if t is None else t, blocked_s=blocked)

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    # -- internals ---------------------------------------------------------

    def _ensure_executor(self) -> concurrent.futures.ThreadPoolExecutor:
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="topo-refresh"
            )
        return self._executor

    def _solve(self, Pi_snapshot: np.ndarray) -> None:
        # runs on the worker thread in overlap mode: refresher state is
        # only read back on the main thread after fut.done()
        self.refresher.refresh(Pi_snapshot)

    def _collect(self, t: int, blocked_s: float):
        fut, meta = self._pending
        self._pending = None
        fut.result()  # propagate worker exceptions
        swap = self._finish_refresh(t)
        self.refresh_log.append({
            "t_submit": meta["t_submit"], "t_collect": int(t),
            "solve_s": self.refresher.last_refresh_s,
            "pending_segments": meta["pending_segments"],
            "overlap_wall_s": time.perf_counter() - meta["wall0"],
            "blocked_s": float(blocked_s),
            "restaged": None,  # patched below once the swap is built
        })
        self.refresh_log[-1]["restaged"] = (
            isinstance(swap, PoolSwap) and swap.restaged
        )
        self.events.append({
            "t": int(t), "collected": True,
            "refresh_s": self.refresher.last_refresh_s,
            "refresh_iters": self.refresher.last_iters,
        })
        return swap

    def _finish_refresh(self, t: int):
        self._W = self.refresher.W
        self.detector.rebase(self.proxy())
        if self.events and self.events[-1].get("triggered"):
            self.events[-1]["refresh_s"] = self.refresher.last_refresh_s
            self.events[-1]["refresh_iters"] = self.refresher.last_iters
        return self._emit()

    def _emit(self):
        """Current topology as the trainer-facing update object."""
        if self.pool is None:
            return self.refresher.schedule_arrays()
        sched = self.refresher.schedule
        gammas, dropped = self.pool.project(sched)
        if dropped <= self.pool_miss_tol and gammas.sum() > 0.0:
            return PoolSwap(gammas=gammas, pool=None, dropped_mass=dropped)
        # pool miss: restage the refreshed atoms (capacity-truncated),
        # keeping the old capacity so the trainer's gamma operand shape
        # -- and hence everything EXCEPT the one recompile -- is stable.
        # Projecting the UN-truncated schedule reports any capacity-
        # truncation residue honestly in dropped_mass (0 iff every
        # refreshed atom fit).
        self.pool_misses += 1
        new_pool = PermPool.from_schedule(sched, capacity=self.pool.capacity)
        self.pool = new_pool
        new_gammas, dropped = new_pool.project(sched)
        return PoolSwap(gammas=new_gammas, pool=new_pool, dropped_mass=dropped)

    def schedule_arrays(self) -> ScheduleArrays:
        """Current schedule in the trainers' data-plane format."""
        return self.refresher.schedule_arrays()

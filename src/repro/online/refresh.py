"""Mid-training topology refresh: warm STL-FW re-solves + hot-swap plumbing.

The pieces the streaming estimator feeds:

* ``TopologyRefresher`` -- re-runs :func:`repro.core.stl_fw.learn_topology`
  *warm*: Frank-Wolfe restarts from the previous W's Birkhoff atoms
  (``init=``), a single persistent ``LMOSolver`` carries the auction
  backends' dual prices across refreshes, and the solve early-stops at
  the duality-gap level the initial cold solve certified (``stop_gap``).
  A refresh therefore costs a few FW steps, not a cold ``budget``-length
  solve (measured in benchmarks/bench_online.py, BENCH_online.json).
  After each solve the atom set is truncated back to a fixed capacity
  ``l_max`` (largest coefficients kept, renormalized -- still doubly
  stochastic), so the data-plane schedule the trainers consume never
  changes shape.
* ``OnlineTopologyController`` -- the object a training loop talks to.
  It owns the estimator, the drift detector, and the refresher;
  ``observe(labels)`` streams minibatch labels in, and ``on_segment(t)``
  (the hook the drivers in ``repro.train.trainer`` call at segment
  boundaries) evaluates the heterogeneity proxy, consults the detector,
  and -- on a trigger -- refreshes W and returns the new fixed-shape
  :class:`~repro.core.mixing.ScheduleArrays` for a zero-retrace swap.

Layering: this module imports core + data only. The trainers never
import it -- they accept any object with the ``on_segment`` protocol --
so ``repro.train`` stays independent of ``repro.online``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.heterogeneity import tau_bar_label_skew
from repro.core.mixing import (
    BirkhoffSchedule,
    ScheduleArrays,
    schedule_from_result,
    schedule_to_arrays,
    truncate_schedule,
)
from repro.core.stl_fw import LMOSolver, STLFWResult, learn_topology

from .streaming import DriftDetector, StreamingPiEstimator

__all__ = ["RefreshConfig", "TopologyRefresher", "OnlineTopologyController"]


@dataclasses.dataclass
class RefreshConfig:
    """Policy knobs for warm mid-training refreshes.

    Attributes:
      budget: max FW iterations per refresh (the cap that guarantees a
        refresh is cheap even when the drift is total; the gap stop
        usually fires earlier).
      lam: Eq. (8) bias/variance trade-off. ``None`` (default) inherits
        the initial solve's recorded ``lam`` -- the only choice under
        which the gap target compares like with like. Setting it
        explicitly to a different value is allowed but then the
        refresher discards ``gap_ref`` (gaps of different objectives
        are incomparable) and falls back to the relative ``stop_tol``.
      gap_slack: the refresh stops once its FW gap reaches
        ``gap_slack x`` the initial cold solve's final gap (1.0 =
        "certifiably as converged as the cold solve").
      stop_tol: fallback relative gap stop when the warm start has no
        recorded reference gap.
      l_max: fixed atom capacity of the emitted data-plane schedule
        (which is also the per-step gather/communication degree of the
        data-plane transport). ``None`` defaults to the initial
        result's atom count plus one refresh ``budget`` of headroom:
        a single refresh then fits without truncating its new atoms,
        and across repeated refreshes the contraction-decayed old atoms
        are the ones dropped. A tight ``l_max`` (= initial atom count)
        keeps communication minimal at a measurable topology-quality
        cost -- the trade-off is the operator's.
      method: ``learn_topology`` method ("incremental" | "reference").
    """

    budget: int = 16
    lam: float | None = None
    gap_slack: float = 1.0
    stop_tol: float | None = 0.05
    l_max: int | None = None
    method: str = "incremental"


class TopologyRefresher:
    """Warm re-learner with persistent LMO state and fixed atom capacity.

    Args:
      initial: the cold-solved topology training started with (its atoms
        seed the first warm refresh; its final FW gap is the quality
        target every refresh stops at).
      config: refresh policy.
      lmo: LMO backend name, or a pre-built persistent ``LMOSolver``.
        The same solver instance is reused across every refresh, so the
        auction backends' dual prices (device-resident for
        ``auction_jit``) warm-start each solve; ``"auto"`` resolves with
        ``budget=None`` -- the open-ended online rule.
    """

    def __init__(
        self,
        initial: STLFWResult,
        config: RefreshConfig | None = None,
        lmo: "str | LMOSolver" = "auto",
    ):
        self.config = config or RefreshConfig()
        self.solver = lmo if isinstance(lmo, LMOSolver) else LMOSolver(lmo)
        self.solver.resolve(n=initial.W.shape[0], budget=None)
        sched = schedule_from_result(initial)
        # `is None`, not truthiness: an explicit l_max=0 must hit
        # truncate_schedule's validation, not silently become the default
        if self.config.l_max is not None:
            self.l_max = int(self.config.l_max)
        else:
            self.l_max = sched.n_atoms + self.config.budget
        sched = truncate_schedule(sched, self.l_max)
        self._atoms = (list(sched.coeffs), [np.asarray(p) for p in sched.perms])
        self.result = initial
        if self.config.lam is not None:
            self.lam = float(self.config.lam)
        elif initial.lam is not None:
            self.lam = float(initial.lam)
        else:
            self.lam = 0.1  # the paper's default; pre-lam-field results only
        gap_ref = None
        # the gap target is only meaningful against the SAME objective:
        # require a recorded lam that matches (a result without one --
        # hand-built or pre-lam-field -- could have been solved at any
        # lam, so its gap is incomparable and we fall back to stop_tol)
        same_objective = initial.lam is not None and float(initial.lam) == self.lam
        if same_objective and initial.gap_trace is not None and len(initial.gap_trace):
            gap_ref = float(initial.gap_trace[-1])
        self.gap_ref = gap_ref
        self.n_refreshes = 0
        self.last_refresh_s: float | None = None
        self.last_iters: int | None = None

    @property
    def schedule(self) -> BirkhoffSchedule:
        """Current (truncated) static schedule."""
        return BirkhoffSchedule(
            coeffs=tuple(float(c) for c in self._atoms[0]),
            perms=tuple(tuple(int(x) for x in p) for p in self._atoms[1]),
        )

    @property
    def W(self) -> np.ndarray:
        """Current dense W (rebuilt from the truncated atoms)."""
        return self.schedule.to_matrix()

    def schedule_arrays(self) -> ScheduleArrays:
        """Current schedule in the fixed-shape data-plane format."""
        return schedule_to_arrays(self.schedule, self.l_max)

    def refresh(self, Pi_hat: np.ndarray) -> STLFWResult:
        """Warm re-solve against the streamed Pi estimate.

        Returns the (un-truncated) STLFWResult; the refresher's own
        schedule/arrays views reflect the ``l_max``-truncated atoms.
        """
        cfg = self.config
        stop_gap = None if self.gap_ref is None else self.gap_ref * cfg.gap_slack
        stop_tol = cfg.stop_tol if stop_gap is None else None
        t0 = time.perf_counter()
        res = learn_topology(
            Pi_hat,
            cfg.budget,
            lam=self.lam,
            method=cfg.method,
            lmo=self.solver,
            init=self._atoms,
            stop_tol=stop_tol,
            stop_gap=stop_gap,
        )
        self.last_refresh_s = time.perf_counter() - t0
        self.last_iters = len(res.gamma_trace)
        sched = truncate_schedule(schedule_from_result(res), self.l_max)
        self._atoms = (list(sched.coeffs), [np.asarray(p) for p in sched.perms])
        self.result = res
        self.n_refreshes += 1
        return res


class OnlineTopologyController:
    """Streaming estimation -> drift detection -> warm refresh, as one hook.

    The training drivers call ``on_segment(t)`` at segment boundaries
    (duck-typed -- ``repro.train`` never imports this module). Between
    those calls the label stream is fed in with ``observe`` (labels are
    exogenous to the compiled training step, so this happens host-side
    at zero hot-path cost).

    Args:
      refresher: warm re-learner holding the current topology.
      estimator: streaming Pi estimator (defaults: seeded from the
        refresher's n plus ``num_classes``, uniform init).
      detector: drift detector on the heterogeneity proxy.
      num_classes: K, required when ``estimator`` is not given.
      Pi0: the Pi the initial topology was learned from; seeds the
        default estimator so the proxy does not ramp from the uniform
        init to its stationary value (a ramp the detector would read as
        drift). Ignored when ``estimator`` is given.
      proxy_B / proxy_sigma2: the ``B`` and ``sigma_max^2`` constants of
        Proposition 2's ``tau_bar_label_skew`` proxy. The *relative*
        detector only cares about B up to scale; sigma adds the
        variance term, which does not depend on Pi_hat -- keep it 0 to
        track the drift-sensitive bias part alone.
    """

    def __init__(
        self,
        refresher: TopologyRefresher,
        estimator: StreamingPiEstimator | None = None,
        detector: DriftDetector | None = None,
        *,
        num_classes: int | None = None,
        Pi0: np.ndarray | None = None,
        proxy_B: float = 1.0,
        proxy_sigma2: float = 0.0,
    ):
        self.refresher = refresher
        n = refresher.W.shape[0]
        if estimator is None:
            if num_classes is None and Pi0 is None:
                raise ValueError("pass num_classes, Pi0, or a pre-built estimator")
            if num_classes is None:
                num_classes = int(np.asarray(Pi0).shape[1])
            estimator = StreamingPiEstimator(n, num_classes, init=Pi0)
        if estimator.n_nodes != n:
            raise ValueError(
                f"estimator is for {estimator.n_nodes} nodes, topology has {n}"
            )
        self.estimator = estimator
        self.detector = detector or DriftDetector()
        self.proxy_B = float(proxy_B)
        self.proxy_sigma2 = float(proxy_sigma2)
        self.events: list[dict] = []
        self._W = refresher.W

    def observe(self, labels: np.ndarray) -> None:
        """Stream one step's (n, batch) minibatch labels in."""
        self.estimator.update(labels)

    def proxy(self) -> float:
        """Current neighborhood-heterogeneity proxy (Prop. 2 at Pi_hat)."""
        return tau_bar_label_skew(
            self._W, self.estimator.Pi_hat, self.proxy_B, self.proxy_sigma2
        )

    def on_segment(self, t: int) -> ScheduleArrays | None:
        """Segment-boundary hook: returns new arrays iff a refresh fired."""
        value = self.proxy()
        triggered = self.detector.update(value)
        event = {"t": int(t), "proxy": float(value), "triggered": bool(triggered)}
        if triggered:
            self.refresher.refresh(self.estimator.Pi_hat)
            self._W = self.refresher.W
            event["refresh_s"] = self.refresher.last_refresh_s
            event["refresh_iters"] = self.refresher.last_iters
            self.detector.rebase(self.proxy())
        self.events.append(event)
        return self.refresher.schedule_arrays() if triggered else None

    def schedule_arrays(self) -> ScheduleArrays:
        """Current schedule in the trainers' data-plane format."""
        return self.refresher.schedule_arrays()

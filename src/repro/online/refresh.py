"""Mid-training topology refresh: warm STL-FW re-solves + hot-swap plumbing.

The pieces the streaming estimator feeds:

* ``TopologyRefresher`` -- re-runs :func:`repro.core.stl_fw.learn_topology`
  *warm*: Frank-Wolfe restarts from the previous W's Birkhoff atoms
  (``init=``), a single persistent ``LMOSolver`` carries the auction
  backends' dual prices across refreshes, and the solve early-stops at
  the duality-gap level the initial cold solve certified (``stop_gap``).
  A refresh therefore costs a few FW steps, not a cold ``budget``-length
  solve (measured in benchmarks/bench_online.py, BENCH_online.json).
  After each solve the atom set is truncated back to a fixed capacity
  ``l_max`` (largest coefficients kept, renormalized -- still doubly
  stochastic), so the data-plane schedule the trainers consume never
  changes shape.
* ``OnlineTopologyController`` -- the object a training loop talks to.
  It owns the estimator, the drift detector, and the refresher;
  ``observe(labels)`` streams minibatch labels in, and ``on_segment(t)``
  (the hook the drivers in ``repro.train.trainer`` call at segment
  boundaries) evaluates the heterogeneity proxy, consults the detector,
  and -- on a trigger -- refreshes W and returns the new fixed-shape
  :class:`~repro.core.mixing.ScheduleArrays` for a zero-retrace swap.

Layering: this module imports core + data only. The trainers never
import it -- they accept any object with the ``on_segment`` protocol --
so ``repro.train`` stays independent of ``repro.online``.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time

import numpy as np

from repro.core.heterogeneity import tau_bar_label_skew
from repro.core.mixing import (
    BirkhoffSchedule,
    PermPool,
    PoolSwap,
    ScheduleArrays,
    schedule_from_result,
    schedule_to_arrays,
    truncate_schedule,
)
from repro.core.stl_fw import LMOSolver, STLFWResult, learn_topology
from repro.obs.trace import Tracer

from .streaming import DriftDetector, StreamingPiEstimator

# instrumented paths take an always-on tracer; callers opt in with a
# real one (the Tracer is thread-safe, so overlap-mode worker solves
# record spans on their own tid against the shared clock origin)
_NULL_TRACER = Tracer(enabled=False)

__all__ = [
    "RefreshConfig",
    "RefreshError",
    "RefreshTimeoutError",
    "TopologyRefresher",
    "OnlineTopologyController",
]


class RefreshError(RuntimeError):
    """A refresh solve failed (after any configured retries).

    ``meta`` carries the refresh metadata at failure time: ``t_submit``,
    ``pending_segments``, ``overlap_wall_s``, ``attempts``, and the
    original exception's ``repr`` under ``error`` -- so a trainer that
    catches this knows exactly which refresh died and how long it ran.
    """

    def __init__(self, message: str, meta: dict | None = None):
        super().__init__(message)
        self.meta = dict(meta or {})


class RefreshTimeoutError(RefreshError):
    """``flush(timeout=)`` expired with the solve still running.

    The solve is NOT cancelled -- it stays pending, and a later
    ``on_segment``/``flush`` can still collect it. ``meta`` records how
    long the solve has been in flight."""


@dataclasses.dataclass
class RefreshConfig:
    """Policy knobs for warm mid-training refreshes.

    Attributes:
      budget: max FW iterations per refresh (the cap that guarantees a
        refresh is cheap even when the drift is total; the gap stop
        usually fires earlier).
      lam: Eq. (8) bias/variance trade-off. ``None`` (default) inherits
        the initial solve's recorded ``lam`` -- the only choice under
        which the gap target compares like with like. Setting it
        explicitly to a different value is allowed but then the
        refresher discards ``gap_ref`` (gaps of different objectives
        are incomparable) and falls back to the relative ``stop_tol``.
      gap_slack: the refresh stops once its FW gap reaches
        ``gap_slack x`` the initial cold solve's final gap (1.0 =
        "certifiably as converged as the cold solve").
      stop_tol: fallback relative gap stop when the warm start has no
        recorded reference gap.
      l_max: fixed atom capacity of the emitted data-plane schedule
        (which is also the per-step gather/communication degree of the
        data-plane transport). ``None`` defaults to the initial
        result's atom count plus one refresh ``budget`` of headroom:
        a single refresh then fits without truncating its new atoms,
        and across repeated refreshes the contraction-decayed old atoms
        are the ones dropped. A tight ``l_max`` (= initial atom count)
        keeps communication minimal at a measurable topology-quality
        cost -- the trade-off is the operator's.
      method: ``learn_topology`` method ("incremental" | "reference").
    """

    budget: int = 16
    lam: float | None = None
    gap_slack: float = 1.0
    stop_tol: float | None = 0.05
    l_max: int | None = None
    method: str = "incremental"


class TopologyRefresher:
    """Warm re-learner with persistent LMO state and fixed atom capacity.

    Args:
      initial: the cold-solved topology training started with (its atoms
        seed the first warm refresh; its final FW gap is the quality
        target every refresh stops at).
      config: refresh policy.
      lmo: LMO backend name, or a pre-built persistent ``LMOSolver``.
        The same solver instance is reused across every refresh, so the
        auction backends' dual prices (device-resident for
        ``auction_jit``) warm-start each solve; ``"auto"`` resolves with
        ``budget=None`` -- the open-ended online rule.
    """

    def __init__(
        self,
        initial: STLFWResult,
        config: RefreshConfig | None = None,
        lmo: "str | LMOSolver" = "auto",
        tracer: "Tracer | None" = None,
    ):
        self.config = config or RefreshConfig()
        self.tracer = tracer
        self.solver = lmo if isinstance(lmo, LMOSolver) else LMOSolver(lmo)
        self.solver.resolve(n=initial.W.shape[0], budget=None)
        sched = schedule_from_result(initial)
        # `is None`, not truthiness: an explicit l_max=0 must hit
        # truncate_schedule's validation, not silently become the default
        if self.config.l_max is not None:
            self.l_max = int(self.config.l_max)
        else:
            self.l_max = sched.n_atoms + self.config.budget
        sched = truncate_schedule(sched, self.l_max)
        self._atoms = (list(sched.coeffs), [np.asarray(p) for p in sched.perms])
        self.result = initial
        if self.config.lam is not None:
            self.lam = float(self.config.lam)
        elif initial.lam is not None:
            self.lam = float(initial.lam)
        else:
            self.lam = 0.1  # the paper's default; pre-lam-field results only
        gap_ref = None
        # the gap target is only meaningful against the SAME objective:
        # require a recorded lam that matches (a result without one --
        # hand-built or pre-lam-field -- could have been solved at any
        # lam, so its gap is incomparable and we fall back to stop_tol)
        same_objective = initial.lam is not None and float(initial.lam) == self.lam
        if same_objective and initial.gap_trace is not None and len(initial.gap_trace):
            gap_ref = float(initial.gap_trace[-1])
        self.gap_ref = gap_ref
        self.n_refreshes = 0
        self.last_refresh_s: float | None = None
        self.last_iters: int | None = None

    @property
    def schedule(self) -> BirkhoffSchedule:
        """Current (truncated) static schedule."""
        return BirkhoffSchedule(
            coeffs=tuple(float(c) for c in self._atoms[0]),
            perms=tuple(tuple(int(x) for x in p) for p in self._atoms[1]),
        )

    @property
    def W(self) -> np.ndarray:
        """Current dense W (rebuilt from the truncated atoms)."""
        return self.schedule.to_matrix()

    def schedule_arrays(self) -> ScheduleArrays:
        """Current schedule in the fixed-shape data-plane format."""
        return schedule_to_arrays(self.schedule, self.l_max)

    def refresh(self, Pi_hat: np.ndarray) -> STLFWResult:
        """Warm re-solve against the streamed Pi estimate.

        Returns the (un-truncated) STLFWResult; the refresher's own
        schedule/arrays views reflect the ``l_max``-truncated atoms.
        """
        cfg = self.config
        stop_gap = None if self.gap_ref is None else self.gap_ref * cfg.gap_slack
        stop_tol = cfg.stop_tol if stop_gap is None else None
        tr = self.tracer if self.tracer is not None else _NULL_TRACER
        t0 = time.perf_counter()
        with tr.span("refresh.solve", n_refresh=self.n_refreshes):
            res = learn_topology(
                Pi_hat,
                cfg.budget,
                lam=self.lam,
                method=cfg.method,
                lmo=self.solver,
                init=self._atoms,
                stop_tol=stop_tol,
                stop_gap=stop_gap,
            )
        self.last_refresh_s = time.perf_counter() - t0
        self.last_iters = len(res.gamma_trace)
        sched = truncate_schedule(schedule_from_result(res), self.l_max)
        self._atoms = (list(sched.coeffs), [np.asarray(p) for p in sched.perms])
        self.result = res
        self.n_refreshes += 1
        return res


class OnlineTopologyController:
    """Streaming estimation -> drift detection -> warm refresh, as one hook.

    The training drivers call ``on_segment(t)`` at segment boundaries
    (duck-typed -- ``repro.train`` never imports this module). Between
    those calls the label stream is fed in with ``observe`` (labels are
    exogenous to the compiled training step, so this happens host-side
    at zero hot-path cost).

    Args:
      refresher: warm re-learner holding the current topology.
      estimator: streaming Pi estimator (defaults: seeded from the
        refresher's n plus ``num_classes``, uniform init).
      detector: drift detector on the heterogeneity proxy.
      num_classes: K, required when ``estimator`` is not given.
      Pi0: the Pi the initial topology was learned from; seeds the
        default estimator so the proxy does not ramp from the uniform
        init to its stationary value (a ramp the detector would read as
        drift). Ignored when ``estimator`` is given.
      proxy_B / proxy_sigma2: the ``B`` and ``sigma_max^2`` constants of
        Proposition 2's ``tau_bar_label_skew`` proxy. The *relative*
        detector only cares about B up to scale; sigma adds the
        variance term, which does not depend on Pi_hat -- keep it 0 to
        track the drift-sensitive bias part alone.
      pool: a staged :class:`~repro.core.mixing.PermPool` puts the
        controller in POOL COORDINATES: ``on_segment`` returns
        :class:`~repro.core.mixing.PoolSwap` updates instead of
        ``ScheduleArrays``. A refresh whose atoms project onto the pool
        with at most ``pool_miss_tol`` dropped coefficient mass is
        emitted as an in-pool gamma swap (zero retraces for the pool-
        transport trainer); beyond the tolerance the controller
        restages a new pool from the refreshed schedule (counted in
        ``pool_misses``; the trainer pays one recompile). The
        pool-aware truncation this implements trades a bounded amount
        of mixing mass (``dropped_mass``) for staying inside the
        compiled communication plan.
      pool_miss_tol: max coefficient mass the in-pool projection may
        drop before a restage is declared.
      overlap: run each refresh solve in a background worker thread
        instead of inline. The numpy/scipy LMO releases the GIL in
        BLAS, so the solve overlaps the compiled rollout: the
        triggering ``on_segment`` SUBMITS and returns ``None`` (the
        rollout launches its next segment immediately); the first
        boundary after the solve finishes collects the result and
        hands the swap back -- a double-buffered handoff in which the
        hook never blocks on the solver (only an explicit
        :meth:`flush` waits). Detector updates are suspended while a
        solve is in flight (the post-collect ``rebase`` re-anchors the
        baseline), and per-refresh timing lands in ``refresh_log``.
      solve_retries: re-run a raising solve up to this many extra times
        (exponential backoff starting at ``retry_backoff_s``) before
        declaring the refresh failed. Retries happen inside the worker
        in overlap mode, so the rollout never sees them.
      retry_backoff_s: initial backoff; doubles per retry.
      solve_timeout_s: in overlap mode, a solve still running this many
        seconds after submit is ABANDONED at the next ``on_segment``:
        the controller falls back to the last-good schedule, counts a
        ``failed_refreshes``, and re-arms the detector. The wedged
        worker thread is detached (``shutdown(wait=False)``) and a
        fresh executor is created lazily -- the thread itself cannot be
        killed, so a truly hung native solve still holds its memory
        until process exit (and, being non-daemon, interpreter exit
        joins it; scripted hang drills must release their hang event).

    A failed or abandoned refresh NEVER raises out of ``on_segment``:
    the rollout keeps mixing with the last-good schedule, the failure
    is recorded (``failed_refreshes``, a ``refresh_log`` entry with an
    ``error`` field, an ``events`` entry), and the detector is
    re-armed so a later segment can trigger again. Only :meth:`flush`
    -- the explicit wait -- re-raises, as :class:`RefreshError` /
    :class:`RefreshTimeoutError` with the metadata attached.
    """

    def __init__(
        self,
        refresher: TopologyRefresher,
        estimator: StreamingPiEstimator | None = None,
        detector: DriftDetector | None = None,
        *,
        num_classes: int | None = None,
        Pi0: np.ndarray | None = None,
        proxy_B: float = 1.0,
        proxy_sigma2: float = 0.0,
        pool: PermPool | None = None,
        pool_miss_tol: float = 0.05,
        overlap: bool = False,
        solve_retries: int = 0,
        retry_backoff_s: float = 0.05,
        solve_timeout_s: float | None = None,
        tracer: "Tracer | None" = None,
    ):
        self.refresher = refresher
        self.tracer = tracer if tracer is not None else _NULL_TRACER
        if tracer is not None:
            # propagate to the (possibly wrapped -- e.g. FlakyRefresher)
            # refresher so its solves record "refresh.solve" spans; walk
            # the _inner proxy chain to the object that actually solves
            target = refresher
            while hasattr(target, "_inner"):
                target = target._inner
            if getattr(target, "tracer", None) is None:
                target.tracer = tracer
        n = refresher.W.shape[0]
        if estimator is None:
            if num_classes is None and Pi0 is None:
                raise ValueError("pass num_classes, Pi0, or a pre-built estimator")
            if num_classes is None:
                num_classes = int(np.asarray(Pi0).shape[1])
            estimator = StreamingPiEstimator(n, num_classes, init=Pi0)
        if estimator.n_nodes != n:
            raise ValueError(
                f"estimator is for {estimator.n_nodes} nodes, topology has {n}"
            )
        if pool is not None and pool.n_nodes != n:
            raise ValueError(f"pool is for {pool.n_nodes} nodes, topology has {n}")
        self.estimator = estimator
        self.detector = detector or DriftDetector()
        self.proxy_B = float(proxy_B)
        self.proxy_sigma2 = float(proxy_sigma2)
        self.pool = pool
        self.pool_miss_tol = float(pool_miss_tol)
        self.pool_misses = 0
        self.overlap = bool(overlap)
        if solve_retries < 0:
            raise ValueError(f"solve_retries must be >= 0, got {solve_retries}")
        self.solve_retries = int(solve_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.solve_timeout_s = (
            None if solve_timeout_s is None else float(solve_timeout_s)
        )
        self.failed_refreshes = 0
        self.events: list[dict] = []
        self.refresh_log: list[dict] = []
        self._W = refresher.W
        self._executor: concurrent.futures.ThreadPoolExecutor | None = None
        self._pending: tuple[concurrent.futures.Future, dict] | None = None
        self._manual_request = False
        self._manual_reason: str | None = None
        self._last_attempts = 0

    def observe(self, labels: np.ndarray) -> None:
        """Stream one step's (n, batch) minibatch labels in."""
        self.estimator.update(labels)

    def proxy(self) -> float:
        """Current neighborhood-heterogeneity proxy (Prop. 2 at Pi_hat)."""
        return tau_bar_label_skew(
            self._W, self.estimator.Pi_hat, self.proxy_B, self.proxy_sigma2
        )

    def request_refresh(self, reason: str | None = None) -> None:
        """Force a refresh at the next ``on_segment`` (scripted drills /
        external schedulers, quarantine membership changes), bypassing
        the detector. ``reason`` is recorded on the trigger event, so
        the event log says WHY a refresh happened off-detector."""
        self._manual_request = True
        if reason is not None:
            self._manual_reason = str(reason)

    @property
    def refresh_pending(self) -> bool:
        return self._pending is not None

    def on_segment(self, t: int):
        """Segment-boundary hook.

        Returns ``None`` (no update -- including "solve still running"
        in overlap mode), a :class:`ScheduleArrays` (no pool), or a
        :class:`PoolSwap` (pool coordinates).
        """
        if self._pending is not None:
            fut, meta = self._pending
            if not fut.done():
                wall = time.perf_counter() - meta["wall0"]
                if (
                    self.solve_timeout_s is not None
                    and wall > self.solve_timeout_s
                ):
                    self._abandon(t, wall)
                    return None
                meta["pending_segments"] += 1
                self.events.append({"t": int(t), "pending": True})
                return None
            return self._collect(t, blocked_s=0.0)
        value = self.proxy()
        manual = self._manual_request
        triggered = self.detector.update(value) or manual
        self._manual_request = False
        reason, self._manual_reason = self._manual_reason, None
        event = {"t": int(t), "proxy": float(value), "triggered": bool(triggered)}
        if manual and reason is not None:
            event["reason"] = reason
        if not triggered:
            self.events.append(event)
            return None
        # the worker must see a frozen Pi: observe() keeps mutating the
        # estimator while the solve runs (double-buffered handoff)
        snapshot = np.array(self.estimator.Pi_hat)
        if self.overlap:
            self.tracer.instant("refresh.submit", t=int(t), proxy=float(value))
            fut = self._ensure_executor().submit(self._solve, snapshot)
            self._pending = (
                fut,
                {"t_submit": int(t), "pending_segments": 0,
                 "wall0": time.perf_counter()},
            )
            event["submitted"] = True
            self.events.append(event)
            return None
        wall0 = time.perf_counter()
        try:
            self._solve(snapshot)
        except Exception as exc:  # fall back to the last-good schedule
            self.events.append(event)
            self._record_failure(
                t,
                {"t_submit": int(t), "pending_segments": 0, "wall0": wall0},
                exc,
            )
            return None
        self.events.append(event)
        swap = self._finish_refresh(t)
        self.refresh_log.append({
            "t_submit": int(t), "t_collect": int(t),
            "solve_s": self.refresher.last_refresh_s,
            "pending_segments": 0, "overlap_wall_s": 0.0, "blocked_s": 0.0,
            "attempts": self._last_attempts,
            "restaged": isinstance(swap, PoolSwap) and swap.restaged,
        })
        self.tracer.instant(
            "refresh.collect", t=int(t), t_submit=int(t),
            solve_s=self.refresher.last_refresh_s,
        )
        return swap

    def flush(self, t: int | None = None, timeout: float | None = None):
        """Block on an in-flight solve and return its swap (or None).

        The one place the controller is allowed to wait: call it after
        the rollout's final segment so a late solve still lands (the
        blocked time is recorded honestly in ``refresh_log``).

        Unlike ``on_segment`` -- which never raises -- ``flush`` is the
        honest surface: a worker exception (after in-worker retries)
        re-raises here as :class:`RefreshError` with the refresh
        metadata on ``.meta`` (the failure is also logged and the
        pending slot cleared, so training COULD continue on the
        last-good schedule after catching it). With ``timeout=``, a
        solve still running when it expires raises
        :class:`RefreshTimeoutError`; the solve is left pending, so a
        later boundary or a second ``flush`` can still collect it.
        """
        if self._pending is None:
            return None
        fut, meta = self._pending
        t0 = time.perf_counter()
        try:
            fut.result(timeout=timeout)
        except concurrent.futures.TimeoutError:
            wall = time.perf_counter() - meta["wall0"]
            raise RefreshTimeoutError(
                f"refresh submitted at t={meta['t_submit']} still running "
                f"after {wall:.3f}s (flush timeout={timeout})",
                meta={
                    "t_submit": meta["t_submit"],
                    "pending_segments": meta["pending_segments"],
                    "overlap_wall_s": wall,
                    "timeout_s": timeout,
                },
            ) from None
        except Exception as exc:
            self._pending = None
            failure = self._record_failure(
                -1 if t is None else t, meta, exc, blocked_s=time.perf_counter() - t0
            )
            raise RefreshError(
                f"refresh submitted at t={meta['t_submit']} failed: {exc!r}",
                meta=failure,
            ) from exc
        blocked = time.perf_counter() - t0
        return self._collect(-1 if t is None else t, blocked_s=blocked)

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    # -- internals ---------------------------------------------------------

    def _ensure_executor(self) -> concurrent.futures.ThreadPoolExecutor:
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="topo-refresh"
            )
        return self._executor

    def _solve(self, Pi_snapshot: np.ndarray) -> None:
        # runs on the worker thread in overlap mode: refresher state is
        # only read back on the main thread after fut.done()
        attempt = 0
        while True:
            try:
                self.refresher.refresh(Pi_snapshot)
                self._last_attempts = attempt + 1
                return
            except Exception:
                attempt += 1
                if attempt > self.solve_retries:
                    self._last_attempts = attempt
                    raise
                # exponential backoff; in overlap mode this sleeps the
                # worker thread, never the rollout
                time.sleep(self.retry_backoff_s * (2.0 ** (attempt - 1)))

    def _record_failure(
        self, t: int, meta: dict, exc: BaseException, blocked_s: float = 0.0
    ) -> dict:
        """Log a dead refresh and re-arm the detector; returns the entry."""
        self.failed_refreshes += 1
        entry = {
            "t_submit": meta["t_submit"], "t_collect": int(t),
            "solve_s": None,
            "pending_segments": meta["pending_segments"],
            "overlap_wall_s": time.perf_counter() - meta["wall0"],
            "blocked_s": float(blocked_s),
            "attempts": self._last_attempts,
            "restaged": False,
            "error": repr(exc),
        }
        self.refresh_log.append(entry)
        self.events.append({
            "t": int(t), "refresh_failed": True, "error": repr(exc),
        })
        # keep mixing with the last-good schedule; re-anchor the
        # detector at the current proxy so drift can trigger again
        self.detector.rebase(self.proxy())
        return entry

    def _abandon(self, t: int, wall_s: float) -> None:
        """Give up on a timed-out solve: fall back to last-good W.

        The worker thread cannot be killed; it is detached via
        ``shutdown(wait=False)`` and a fresh executor is created on the
        next submit. If the old solve eventually finishes it mutates
        the refresher -- harmless for correctness (the refresher only
        ever holds SOME valid doubly stochastic topology, and the next
        emitted swap re-reads it) but the reason ``solve_timeout_s``
        should comfortably exceed a healthy solve time.
        """
        fut, meta = self._pending
        self._pending = None
        self.tracer.instant(
            "refresh.abandon", t=int(t), t_submit=meta["t_submit"],
            wall_s=float(wall_s),
        )
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        self._record_failure(
            t, meta,
            TimeoutError(
                f"refresh solve exceeded solve_timeout_s="
                f"{self.solve_timeout_s} ({wall_s:.3f}s elapsed)"
            ),
        )

    def _collect(self, t: int, blocked_s: float):
        fut, meta = self._pending
        self._pending = None
        try:
            fut.result()
        except Exception as exc:  # fall back to the last-good schedule
            self._record_failure(t, meta, exc, blocked_s=blocked_s)
            return None
        swap = self._finish_refresh(t)
        self.refresh_log.append({
            "t_submit": meta["t_submit"], "t_collect": int(t),
            "solve_s": self.refresher.last_refresh_s,
            "pending_segments": meta["pending_segments"],
            "overlap_wall_s": time.perf_counter() - meta["wall0"],
            "blocked_s": float(blocked_s),
            "attempts": self._last_attempts,
            "restaged": None,  # patched below once the swap is built
        })
        self.refresh_log[-1]["restaged"] = (
            isinstance(swap, PoolSwap) and swap.restaged
        )
        self.tracer.instant(
            "refresh.collect", t=int(t), t_submit=meta["t_submit"],
            solve_s=self.refresher.last_refresh_s,
        )
        self.events.append({
            "t": int(t), "collected": True,
            "refresh_s": self.refresher.last_refresh_s,
            "refresh_iters": self.refresher.last_iters,
        })
        return swap

    def _finish_refresh(self, t: int):
        self._W = self.refresher.W
        self.detector.rebase(self.proxy())
        if self.events and self.events[-1].get("triggered"):
            self.events[-1]["refresh_s"] = self.refresher.last_refresh_s
            self.events[-1]["refresh_iters"] = self.refresher.last_iters
        return self._emit()

    def _emit(self):
        """Current topology as the trainer-facing update object."""
        if self.pool is None:
            return self.refresher.schedule_arrays()
        sched = self.refresher.schedule
        gammas, dropped = self.pool.project(sched)
        if dropped <= self.pool_miss_tol and gammas.sum() > 0.0:
            return PoolSwap(gammas=gammas, pool=None, dropped_mass=dropped)
        # pool miss: restage the refreshed atoms (capacity-truncated),
        # keeping the old capacity so the trainer's gamma operand shape
        # -- and hence everything EXCEPT the one recompile -- is stable.
        # Projecting the UN-truncated schedule reports any capacity-
        # truncation residue honestly in dropped_mass (0 iff every
        # refreshed atom fit).
        self.pool_misses += 1
        new_pool = PermPool.from_schedule(sched, capacity=self.pool.capacity)
        self.pool = new_pool
        new_gammas, dropped = new_pool.project(sched)
        return PoolSwap(gammas=new_gammas, pool=new_pool, dropped_mass=dropped)

    def schedule_arrays(self) -> ScheduleArrays:
        """Current schedule in the trainers' data-plane format."""
        return self.refresher.schedule_arrays()

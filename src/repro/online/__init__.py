"""Online topology adaptation: streaming Pi estimation + mid-training
STL-FW refresh with zero-retrace schedule hot-swap.

The paper (Section 5) learns a topology once, before training, from a
fixed label-proportion matrix Pi. This subsystem relearns it *during*
training when Pi drifts:

1. ``streaming``  -- exponentially-weighted Pi_hat from minibatch labels
   plus a drift detector on the neighborhood-heterogeneity proxy
   (Proposition 2's ``tau_bar`` evaluated at Pi_hat).
2. ``refresh``    -- a controller that re-runs ``learn_topology`` warm
   (previous Birkhoff atoms + persistent LMO dual prices + duality-gap
   early stop), truncates back to a fixed atom capacity, and emits the
   result as fixed-shape ``ScheduleArrays`` -- or, with ``pool=``, as
   pool-coordinate ``PoolSwap`` gamma updates for the staged-ppermute
   mesh transport (out-of-pool refreshes restage: one counted
   recompile). ``overlap=True`` runs each solve in a background
   worker (the LMO releases the GIL in BLAS) with a double-buffered
   handoff, so the rollout never waits on the solver.
3. The trainers (``repro.train.trainer`` drivers, ``lm_trainer``'s
   ``online_w`` mode + ``TrainSetup.run_segments``) consume those
   updates as *data*, so a mid-run W swap never retraces a compiled
   rollout.

Drift workloads to drive it live in ``repro.data.drift``; the headline
claims (warm-refresh speedup, zero retraces, post-drift convergence
recovery) are measured in ``benchmarks/bench_online.py``. See
docs/online_adaptation.md for the tutorial.
"""

from . import refresh, streaming
from .refresh import OnlineTopologyController, RefreshConfig, TopologyRefresher
from .streaming import DriftDetector, StreamingPiEstimator

__all__ = [
    "refresh",
    "streaming",
    "OnlineTopologyController",
    "RefreshConfig",
    "TopologyRefresher",
    "DriftDetector",
    "StreamingPiEstimator",
]

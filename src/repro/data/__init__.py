"""Data substrate: heterogeneous partitioners + synthetic datasets/pipelines."""

from . import drift, partition, synthetic, tokens
from .drift import (
    AbruptLabelSwap,
    ConceptShift,
    FeatureDrift,
    GradualDirichlet,
    NodeChurn,
    features_stream,
    labels_stream,
    partition_from_pi,
)
from .partition import (
    cluster_partition,
    dirichlet_partition,
    proportions_from_labels,
    shard_partition,
)
from .synthetic import MeanEstimationTask, gaussian_blobs, mean_estimation_clusters
from .tokens import DomainSkewCorpus, TokenBatcher

__all__ = [
    "drift",
    "partition",
    "synthetic",
    "tokens",
    "AbruptLabelSwap",
    "ConceptShift",
    "FeatureDrift",
    "GradualDirichlet",
    "NodeChurn",
    "features_stream",
    "labels_stream",
    "partition_from_pi",
    "cluster_partition",
    "dirichlet_partition",
    "proportions_from_labels",
    "shard_partition",
    "MeanEstimationTask",
    "gaussian_blobs",
    "mean_estimation_clusters",
    "DomainSkewCorpus",
    "TokenBatcher",
]

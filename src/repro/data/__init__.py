"""Data substrate: heterogeneous partitioners + synthetic datasets/pipelines."""

from . import partition, synthetic, tokens
from .partition import (
    cluster_partition,
    dirichlet_partition,
    proportions_from_labels,
    shard_partition,
)
from .synthetic import MeanEstimationTask, gaussian_blobs, mean_estimation_clusters
from .tokens import DomainSkewCorpus, TokenBatcher

__all__ = [
    "partition",
    "synthetic",
    "tokens",
    "cluster_partition",
    "dirichlet_partition",
    "proportions_from_labels",
    "shard_partition",
    "MeanEstimationTask",
    "gaussian_blobs",
    "mean_estimation_clusters",
    "DomainSkewCorpus",
    "TokenBatcher",
]

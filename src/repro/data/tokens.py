"""Deterministic synthetic LM token pipeline with per-node domain skew.

For large-model D-SGD training we emulate data heterogeneity as *domain skew*
over a synthetic corpus: the corpus has K domains, each with its own n-gram
token distribution; node i draws documents from its own domain mixture
``Pi[i]``. The per-node domain mixtures play exactly the role of the label
proportions in Proposition 2 (heterogeneity is a mixture over K conditional
distributions), so STL-FW consumes ``Pi`` unchanged.

Batches are generated on host from a counter-based seeded RNG: batch ``t`` of
node ``i`` is a pure function of ``(seed, i, t)`` -- no state to checkpoint,
reproducible across restarts/reshards, and shardable (each data-axis host
generates only its own rows).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DomainSkewCorpus", "TokenBatcher"]


@dataclasses.dataclass
class DomainSkewCorpus:
    """K domains, each a Markov-ish unigram distribution over the vocab.

    Domain k's token distribution is a Zipf re-ranked by a domain-specific
    permutation, so domains overlap but are statistically distinct.
    """

    vocab_size: int
    n_domains: int = 10
    zipf_a: float = 1.2
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        base = ranks ** (-self.zipf_a)
        base /= base.sum()
        self._probs = np.empty((self.n_domains, self.vocab_size))
        for k in range(self.n_domains):
            perm = rng.permutation(self.vocab_size)
            self._probs[k] = base[perm]

    def domain_probs(self, k: int) -> np.ndarray:
        return self._probs[k]

    def sample_tokens(
        self, domain: int, shape: tuple[int, ...], rng: np.random.Generator
    ) -> np.ndarray:
        # Gumbel-max sampling keeps memory bounded for large vocabs.
        flat = int(np.prod(shape))
        # chunk to avoid (flat, vocab) blowups
        out = np.empty(flat, dtype=np.int32)
        logp = np.log(self._probs[domain])
        chunk = max(1, min(flat, 1 << 14))
        for s in range(0, flat, chunk):
            e = min(flat, s + chunk)
            g = rng.gumbel(size=(e - s, self.vocab_size))
            out[s:e] = np.argmax(logp[None, :] + g, axis=1)
        return out.reshape(shape)


class TokenBatcher:
    """Counter-seeded per-node LM batches under a domain mixture ``Pi``.

    ``next_batch(step)`` returns ``(tokens, labels)`` of shape
    ``(n_nodes, per_node_batch, seq_len)`` -- labels are next-token shifted.
    """

    def __init__(
        self,
        corpus: DomainSkewCorpus,
        Pi: np.ndarray,
        per_node_batch: int,
        seq_len: int,
        seed: int = 0,
    ) -> None:
        self.corpus = corpus
        self.Pi = np.asarray(Pi, dtype=np.float64)
        self.n_nodes = self.Pi.shape[0]
        self.per_node_batch = per_node_batch
        self.seq_len = seq_len
        self.seed = seed
        if self.Pi.shape[1] != corpus.n_domains:
            raise ValueError("Pi columns must match corpus domains")

    def node_batch(self, node: int, step: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(node, step))
        )
        domains = rng.choice(
            self.corpus.n_domains, size=self.per_node_batch, p=self.Pi[node]
        )
        toks = np.empty((self.per_node_batch, self.seq_len + 1), dtype=np.int32)
        for b, dom in enumerate(domains):
            toks[b] = self.corpus.sample_tokens(int(dom), (self.seq_len + 1,), rng)
        return toks[:, :-1], toks[:, 1:]

    def next_batch(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        xs, ys = [], []
        for i in range(self.n_nodes):
            x, y = self.node_batch(i, step)
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.stack(ys)

"""Synthetic drift scenarios: reproducible workloads for online adaptation.

Each scenario is a time-indexed label-distribution process: ``Pi(t)``
returns the true (n, K) per-node class proportions at step ``t`` and
``sample_labels(t, batch, rng)`` draws the (n, batch) minibatch labels a
node would observe -- the exact signal ``repro.online.streaming``
consumes. Three drift shapes cover the deployment stories the online
subsystem exists for:

* ``AbruptLabelSwap``       -- at ``t_drift`` the nodes' distributions are
  permuted (the classic "two shards trade places" shift). The optimal
  topology changes discontinuously; this is the headline benchmark
  scenario (BENCH_online.json).
* ``GradualDirichlet``      -- row-wise linear interpolation from ``Pi0``
  to ``Pi1`` over ``[t_start, t_end]`` (rows stay on the simplex, so
  every intermediate matrix is a valid Pi). Models slow data-collection
  shift; exercises the detector's baseline tracking.
* ``NodeChurn``             -- point events where a node's distribution is
  replaced by a fresh Dirichlet draw (a "new participant" taking over
  the slot) and optional offline windows during which the node emits no
  observations (labels = -1, which the streaming estimator masks).

Two feature-space drift shapes complete the taxonomy (both carry a
Gaussian class-conditional feature model, so they emit (features,
labels) pairs via ``sample``):

* ``FeatureDrift``          -- covariate shift: at ``t_drift`` every node's
  feature distribution gains a seeded node-specific mean offset while
  the label marginals never move (``Pi(t) = Pi0`` for all t). The
  label-space detector is provably blind to it; monitoring must watch a
  feature statistic.
* ``ConceptShift``          -- ``P(y | x)`` changes: at ``t_drift`` the
  labels are re-mapped by a seeded class permutation while the feature
  process is untouched. The label marginals permute with it, so the
  streaming-Pi detector CAN see this one.

``labels_stream`` materializes any scenario into a (steps, n, batch)
array for presampled rollouts (``features_stream`` is the
feature-bearing twin), and ``partition_from_pi`` resamples a dataset
partition matching a target Pi -- the bridge from a drifted
distribution back to ``run_classification``'s per-node index lists.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "AbruptLabelSwap",
    "GradualDirichlet",
    "NodeChurn",
    "FeatureDrift",
    "ConceptShift",
    "labels_stream",
    "features_stream",
    "partition_from_pi",
]


def _check_pi(Pi: np.ndarray, name: str = "Pi") -> np.ndarray:
    Pi = np.asarray(Pi, dtype=np.float64)
    if Pi.ndim != 2:
        raise ValueError(f"{name} must be (n, K)")
    if not np.allclose(Pi.sum(axis=1), 1.0, atol=1e-6):
        raise ValueError(f"rows of {name} must sum to 1")
    return Pi


def _sample_rows(Pi_t: np.ndarray, batch: int, rng: np.random.Generator) -> np.ndarray:
    """Vectorized per-row categorical sampling: (n, K) -> (n, batch) int32.

    Inverse-CDF against one uniform draw per (node, sample) -- one
    ``searchsorted`` per node row, no python-level class loops.
    """
    n, K = Pi_t.shape
    cdf = np.cumsum(Pi_t, axis=1)
    cdf[:, -1] = 1.0  # guard fp undershoot so u < cdf[-1] always
    u = rng.random((n, batch))
    out = np.empty((n, batch), np.int32)
    for i in range(n):
        out[i] = np.searchsorted(cdf[i], u[i], side="right")
    return np.minimum(out, K - 1).astype(np.int32)


@dataclasses.dataclass
class AbruptLabelSwap:
    """``Pi(t) = Pi0`` for ``t < t_drift``, else ``Pi0[node_perm]``.

    ``node_perm=None`` defaults to the half-rotation (node ``i`` takes
    node ``(i + n//2) % n``'s distribution), which changes every node's
    distribution. Caveat: on *structured* Pi the rotation can be a
    symmetry of the topology-learning problem -- e.g. cyclic one-hot
    rows (``class(i) = i mod K``) rotate onto an equally-well-mixed
    assignment, so a W learned pre-drift is exactly as good post-drift
    and the heterogeneity criterion (correctly) never fires. Pass an
    explicit random permutation to guarantee a criterion-visible drift.
    """

    Pi0: np.ndarray
    t_drift: int
    node_perm: np.ndarray | None = None

    def __post_init__(self) -> None:
        self.Pi0 = _check_pi(self.Pi0, "Pi0")
        n = self.Pi0.shape[0]
        if self.node_perm is None:
            self.node_perm = (np.arange(n) + n // 2) % n
        self.node_perm = np.asarray(self.node_perm)
        if not np.array_equal(np.sort(self.node_perm), np.arange(n)):
            raise ValueError("node_perm must be a permutation of the nodes")

    @property
    def n_nodes(self) -> int:
        return self.Pi0.shape[0]

    @property
    def num_classes(self) -> int:
        return self.Pi0.shape[1]

    def Pi(self, t: int) -> np.ndarray:
        return self.Pi0 if t < self.t_drift else self.Pi0[self.node_perm]

    def sample_labels(self, t: int, batch: int, rng: np.random.Generator) -> np.ndarray:
        return _sample_rows(self.Pi(t), batch, rng)


@dataclasses.dataclass
class GradualDirichlet:
    """Row-wise linear interpolation ``Pi0 -> Pi1`` over ``[t_start, t_end]``.

    ``Pi1=None`` draws it as Dirichlet(alpha) label skew (a fresh
    independent skew pattern), seeded for reproducibility.
    """

    Pi0: np.ndarray
    t_start: int
    t_end: int
    Pi1: np.ndarray | None = None
    alpha: float = 0.5
    seed: int = 0

    def __post_init__(self) -> None:
        self.Pi0 = _check_pi(self.Pi0, "Pi0")
        if self.t_end <= self.t_start:
            raise ValueError("need t_end > t_start")
        if self.Pi1 is None:
            rng = np.random.default_rng(self.seed)
            self.Pi1 = rng.dirichlet(
                self.alpha * np.ones(self.Pi0.shape[1]), size=self.Pi0.shape[0]
            )
        self.Pi1 = _check_pi(self.Pi1, "Pi1")
        if self.Pi1.shape != self.Pi0.shape:
            raise ValueError("Pi1 must match Pi0's shape")

    @property
    def n_nodes(self) -> int:
        return self.Pi0.shape[0]

    @property
    def num_classes(self) -> int:
        return self.Pi0.shape[1]

    def Pi(self, t: int) -> np.ndarray:
        if t <= self.t_start:
            return self.Pi0
        if t >= self.t_end:
            return self.Pi1
        w = (t - self.t_start) / (self.t_end - self.t_start)
        return (1.0 - w) * self.Pi0 + w * self.Pi1

    def sample_labels(self, t: int, batch: int, rng: np.random.Generator) -> np.ndarray:
        return _sample_rows(self.Pi(t), batch, rng)


@dataclasses.dataclass(frozen=True)
class _ChurnEvent:
    t: int
    node: int
    offline_until: int  # labels masked (-1) for t in [t, offline_until)


@dataclasses.dataclass
class NodeChurn:
    """Node-replacement drift: at each event a node leaves and a new one
    (fresh Dirichlet(alpha) label distribution) joins its slot.

    Args:
      Pi0: initial proportions.
      events: ``(t, node)`` or ``(t, node, offline_steps)`` tuples. The
        node's distribution changes to a fresh draw at step ``t``; with
        ``offline_steps > 0`` the slot first goes dark (labels -1) for
        that many steps before the new node starts emitting.
      alpha: Dirichlet concentration of the replacement distributions.
      seed: draw seed (one independent draw per event).
    """

    Pi0: np.ndarray
    events: tuple
    alpha: float = 0.5
    seed: int = 0

    def __post_init__(self) -> None:
        self.Pi0 = _check_pi(self.Pi0, "Pi0")
        n, K = self.Pi0.shape
        rng = np.random.default_rng(self.seed)
        parsed = []
        for ev in self.events:
            if len(ev) == 2:
                t, node, offline = int(ev[0]), int(ev[1]), 0
            else:
                t, node, offline = int(ev[0]), int(ev[1]), int(ev[2])
            if not 0 <= node < n:
                raise ValueError(f"event node {node} out of range")
            parsed.append(
                (_ChurnEvent(t=t, node=node, offline_until=t + offline),
                 rng.dirichlet(self.alpha * np.ones(K)))
            )
        self._events = sorted(parsed, key=lambda pair: pair[0].t)

    @property
    def n_nodes(self) -> int:
        return self.Pi0.shape[0]

    @property
    def num_classes(self) -> int:
        return self.Pi0.shape[1]

    def Pi(self, t: int) -> np.ndarray:
        Pi_t = self.Pi0.copy()
        for ev, row in self._events:
            if ev.t <= t:
                Pi_t[ev.node] = row
        return Pi_t

    def offline_nodes(self, t: int) -> np.ndarray:
        """Indices of nodes emitting no observations at step t."""
        off = [ev.node for ev, _ in self._events if ev.t <= t < ev.offline_until]
        return np.asarray(sorted(set(off)), dtype=np.int64)

    def offline_windows(self) -> tuple:
        """All dark windows as ``(node, t_start, t_end)`` tuples,
        labels masked for ``t_start <= t < t_end`` (empty windows from
        ``offline_steps == 0`` events are omitted). This is the bridge
        into ``repro.faults.FaultPlan.from_node_churn``: a churn
        scenario's outages double as crash windows for the mixing
        layer."""
        return tuple(
            (ev.node, ev.t, ev.offline_until)
            for ev, _ in self._events
            if ev.offline_until > ev.t
        )

    def sample_labels(self, t: int, batch: int, rng: np.random.Generator) -> np.ndarray:
        labels = _sample_rows(self.Pi(t), batch, rng)
        off = self.offline_nodes(t)
        if off.size:
            labels[off] = -1
        return labels


@dataclasses.dataclass
class FeatureDrift:
    """Covariate shift: node-specific Gaussian feature-mean offsets
    switch on at ``t_drift``; the label process never moves.

    Features are drawn from a shared class-conditional Gaussian model
    (seeded class means at pairwise distance ~``class_sep``, isotropic
    ``noise``); from ``t_drift`` on, node ``i``'s features are all
    shifted by a seeded unit direction scaled to ``shift``. Because
    ``Pi(t) = Pi0`` for every t, a detector watching label proportions
    (``StreamingPiEstimator`` + heterogeneity proxy) sees NOTHING --
    the scenario exists to exercise feature-statistic monitoring
    (e.g. feed ``DriftDetector`` the per-step deviation of the batch
    feature mean from a pre-drift baseline) and mean-re-estimation
    recovery.
    """

    Pi0: np.ndarray
    t_drift: int
    dim: int = 8
    class_sep: float = 4.0
    shift: float = 3.0
    noise: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        self.Pi0 = _check_pi(self.Pi0, "Pi0")
        if self.dim < 1:
            raise ValueError(f"dim must be >= 1, got {self.dim}")
        if self.shift < 0 or self.noise < 0:
            raise ValueError("shift and noise must be non-negative")
        n, K = self.Pi0.shape
        rng = np.random.default_rng(self.seed)
        self._class_means = self.class_sep * rng.normal(size=(K, self.dim))
        direc = rng.normal(size=(n, self.dim))
        direc /= np.linalg.norm(direc, axis=1, keepdims=True)
        self._node_shift = self.shift * direc

    @property
    def n_nodes(self) -> int:
        return self.Pi0.shape[0]

    @property
    def num_classes(self) -> int:
        return self.Pi0.shape[1]

    def Pi(self, t: int) -> np.ndarray:
        return self.Pi0  # label marginals are drift-invariant by design

    def feature_shift(self, t: int) -> np.ndarray:
        """The (n, dim) mean offset in effect at step t (the oracle the
        detector smoke test checks its statistic against)."""
        if t < self.t_drift:
            return np.zeros_like(self._node_shift)
        return self._node_shift

    def sample_labels(self, t: int, batch: int, rng: np.random.Generator) -> np.ndarray:
        return _sample_rows(self.Pi0, batch, rng)

    def sample(
        self, t: int, batch: int, rng: np.random.Generator
    ) -> tuple[np.ndarray, np.ndarray]:
        """One step's observations: ``(X (n, batch, dim) f32, y (n, batch))``."""
        y = self.sample_labels(t, batch, rng)
        X = self._class_means[y] + self.noise * rng.normal(
            size=(self.n_nodes, batch, self.dim)
        )
        X = X + self.feature_shift(t)[:, None, :]
        return X.astype(np.float32), y


@dataclasses.dataclass
class ConceptShift:
    """``P(y | x)`` drift: from ``t_drift`` on, labels are re-mapped by a
    seeded class permutation while the feature process is untouched.

    The latent class (which drives the features through the same
    Gaussian model as :class:`FeatureDrift`) is always drawn from
    ``Pi0``; the EMITTED label is ``class_perm[latent]`` once the drift
    hits. The label marginals permute accordingly --
    ``Pi(t)[:, class_perm[k]] = Pi0[:, k]`` -- so the streaming-Pi
    detector CAN see this drift (unlike pure covariate shift), and a
    model trained pre-drift misclassifies exactly the moved classes
    until it adapts.

    ``class_perm=None`` draws a seeded derangement-ish permutation
    (re-drawn until it is not the identity; requires ``K >= 2``).
    """

    Pi0: np.ndarray
    t_drift: int
    class_perm: np.ndarray | None = None
    dim: int = 8
    class_sep: float = 4.0
    noise: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        self.Pi0 = _check_pi(self.Pi0, "Pi0")
        n, K = self.Pi0.shape
        rng = np.random.default_rng(self.seed)
        if self.class_perm is None:
            if K < 2:
                raise ValueError("a default class_perm needs K >= 2")
            perm = np.arange(K)
            while np.array_equal(perm, np.arange(K)):
                perm = rng.permutation(K)
            self.class_perm = perm
        self.class_perm = np.asarray(self.class_perm)
        if not np.array_equal(np.sort(self.class_perm), np.arange(K)):
            raise ValueError("class_perm must be a permutation of the classes")
        self._class_means = self.class_sep * rng.normal(size=(K, self.dim))

    @property
    def n_nodes(self) -> int:
        return self.Pi0.shape[0]

    @property
    def num_classes(self) -> int:
        return self.Pi0.shape[1]

    def Pi(self, t: int) -> np.ndarray:
        if t < self.t_drift:
            return self.Pi0
        # emitted label c had latent class argsort(perm)[c]
        return self.Pi0[:, np.argsort(self.class_perm)]

    def sample_labels(self, t: int, batch: int, rng: np.random.Generator) -> np.ndarray:
        latent = _sample_rows(self.Pi0, batch, rng)
        if t < self.t_drift:
            return latent
        return self.class_perm[latent].astype(np.int32)

    def sample(
        self, t: int, batch: int, rng: np.random.Generator
    ) -> tuple[np.ndarray, np.ndarray]:
        """One step's observations: features keyed by the LATENT class,
        labels by the (possibly permuted) emitted class."""
        latent = _sample_rows(self.Pi0, batch, rng)
        X = self._class_means[latent] + self.noise * rng.normal(
            size=(self.n_nodes, batch, self.dim)
        )
        y = (
            latent
            if t < self.t_drift
            else self.class_perm[latent].astype(np.int32)
        )
        return X.astype(np.float32), y


def labels_stream(
    scenario, steps: int, batch: int, seed: int = 0
) -> np.ndarray:
    """Materialize a scenario's label stream: (steps, n, batch) int32.

    One rng drives the whole stream, so the same (scenario, steps,
    batch, seed) is bit-reproducible -- the property every drift
    benchmark and test here relies on.
    """
    rng = np.random.default_rng(seed)
    return np.stack(
        [scenario.sample_labels(t, batch, rng) for t in range(steps)]
    ) if steps else np.zeros((0, scenario.n_nodes, batch), np.int32)


def features_stream(
    scenario, steps: int, batch: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Feature-bearing twin of :func:`labels_stream` for scenarios with a
    ``sample(t, batch, rng)`` method (:class:`FeatureDrift`,
    :class:`ConceptShift`): returns ``(X (steps, n, batch, dim) f32,
    y (steps, n, batch) int32)``, one rng for the whole stream so the
    same arguments are bit-reproducible.
    """
    rng = np.random.default_rng(seed)
    if not steps:
        return (
            np.zeros((0, scenario.n_nodes, batch, scenario.dim), np.float32),
            np.zeros((0, scenario.n_nodes, batch), np.int32),
        )
    pairs = [scenario.sample(t, batch, rng) for t in range(steps)]
    return (
        np.stack([X for X, _ in pairs]),
        np.stack([y for _, y in pairs]),
    )


def partition_from_pi(
    labels: np.ndarray,
    Pi: np.ndarray,
    samples_per_node: int,
    seed: int = 0,
) -> list[np.ndarray]:
    """Resample a per-node index partition matching a target Pi.

    Draws ``samples_per_node`` indices per node (with replacement, from
    the per-class index pools of ``labels``) so node ``i``'s empirical
    class counts follow ``Pi[i]``. Classes with zero pool mass are
    renormalized away from that node's row; a node whose entire row
    lands on empty pools gets an empty index list (the trainers' padded
    stacking and ``proportions_from_labels`` both handle that). This is
    the bridge from a drifted Pi(t) back to ``run_classification``'s
    data format.
    """
    labels = np.asarray(labels)
    Pi = _check_pi(Pi)
    n, K = Pi.shape
    rng = np.random.default_rng(seed)
    pools = [np.nonzero(labels == k)[0] for k in range(K)]
    have = np.asarray([len(p) > 0 for p in pools])
    indices_per_node: list[np.ndarray] = []
    for i in range(n):
        row = np.where(have, Pi[i], 0.0)
        total = row.sum()
        if total <= 0.0:
            indices_per_node.append(np.array([], dtype=np.int64))
            continue
        counts = rng.multinomial(samples_per_node, row / total)
        idx = [rng.choice(pools[k], size=c) for k, c in enumerate(counts) if c > 0]
        indices_per_node.append(np.sort(np.concatenate(idx)) if idx else np.array([], dtype=np.int64))
    return indices_per_node

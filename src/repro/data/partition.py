"""Heterogeneous data partitioners for decentralized learning.

Implements the label-skew partitioning schemes the paper uses:

* ``shard_partition`` -- the McMahan et al. (2017) scheme used in Section 6.2:
  sort by label, split into ``2n`` equal shards, deal 2 shards per node. Most
  nodes see 2 classes; label-boundary shards can carry up to 4.
* ``dirichlet_partition`` -- Dirichlet(alpha) label-skew (common FL benchmark,
  provided for the "beyond label skew" extension suggested in the paper's
  conclusion).
* ``cluster_partition`` -- one class per node group (the Section 6.1 synthetic
  setup: n nodes, K clusters, n/K nodes per cluster).

All partitioners return ``(indices_per_node, Pi)`` where ``Pi[i, k]`` is the
empirical class proportion of node i -- exactly the matrix STL-FW consumes.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "shard_partition",
    "dirichlet_partition",
    "cluster_partition",
    "proportions_from_labels",
]


def proportions_from_labels(
    labels: np.ndarray, indices_per_node: list[np.ndarray], num_classes: int
) -> np.ndarray:
    """Empirical per-node class proportions Pi from a partition.

    Empty nodes (churn, extreme skew) get the uniform row -- the
    agnostic prior, which also keeps every row on the simplex so
    ``learn_topology``'s input contract holds under drift resampling.
    """
    labels = np.asarray(labels)
    n = len(indices_per_node)
    Pi = np.zeros((n, num_classes))
    for i, idx in enumerate(indices_per_node):
        if len(idx) == 0:
            Pi[i] = 1.0 / num_classes
            continue
        node_labels = labels[idx]
        if node_labels.min() < 0 or node_labels.max() >= num_classes:
            # out-of-range labels would silently widen bincount and
            # break the (n, K) shape contract downstream
            raise ValueError(
                f"node {i} has labels outside [0, {num_classes}); pass the "
                "task's true num_classes"
            )
        counts = np.bincount(node_labels, minlength=num_classes)
        Pi[i] = counts / counts.sum()
    return Pi


def _resolve_num_classes(labels: np.ndarray, num_classes: int | None) -> int:
    """K for a partitioner: explicit wins; else inferred from the labels.

    Under drift resampling a class can be temporarily absent from the
    observed labels -- inferring K from ``labels.max()`` then silently
    *shrinks Pi's width* between resamples, which breaks every consumer
    that compares or warm-starts across time (the streaming estimator,
    the refresh controller). Callers that resample over time must pass
    the task's true ``num_classes``.
    """
    if num_classes is not None:
        if num_classes < 1:
            raise ValueError(f"num_classes must be >= 1, got {num_classes}")
        if labels.size and labels.max() >= num_classes:
            raise ValueError(
                f"labels contain class {int(labels.max())} >= num_classes={num_classes}"
            )
        return int(num_classes)
    if labels.size == 0:
        raise ValueError("cannot infer num_classes from empty labels; pass it")
    return int(labels.max()) + 1


def shard_partition(
    labels: np.ndarray,
    n_nodes: int,
    shards_per_node: int = 2,
    seed: int = 0,
    num_classes: int | None = None,
) -> tuple[list[np.ndarray], np.ndarray]:
    """McMahan-style shard partition (sort by label, deal shards).

    Args:
      labels: (N,) integer labels.
      n_nodes: number of agents.
      shards_per_node: shards dealt to each node (2 in the paper).
      seed: shard-dealing rng seed.
      num_classes: fixed K for the returned Pi; pass it when resampling
        under drift (see ``_resolve_num_classes``), else inferred.
    """
    labels = np.asarray(labels)
    num_classes = _resolve_num_classes(labels, num_classes)
    order = np.argsort(labels, kind="stable")
    n_shards = n_nodes * shards_per_node
    shards = np.array_split(order, n_shards)
    rng = np.random.default_rng(seed)
    shard_ids = rng.permutation(n_shards)
    indices_per_node = []
    for i in range(n_nodes):
        mine = shard_ids[i * shards_per_node : (i + 1) * shards_per_node]
        idx = np.concatenate([shards[s] for s in mine])
        indices_per_node.append(np.sort(idx))
    Pi = proportions_from_labels(labels, indices_per_node, num_classes)
    return indices_per_node, Pi


def dirichlet_partition(
    labels: np.ndarray,
    n_nodes: int,
    alpha: float = 0.5,
    seed: int = 0,
    num_classes: int | None = None,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Dirichlet(alpha) label-skew partition (lower alpha = more skew).

    Robust to the drift-resampling edge cases: a class absent from
    ``labels`` contributes empty chunks (pass ``num_classes`` so Pi
    keeps its width), and nodes that end up with zero samples get the
    uniform Pi row from ``proportions_from_labels``.
    """
    labels = np.asarray(labels)
    num_classes = _resolve_num_classes(labels, num_classes)
    rng = np.random.default_rng(seed)
    idx_by_class = [np.nonzero(labels == k)[0] for k in range(num_classes)]
    node_lists: list[list[np.ndarray]] = [[] for _ in range(n_nodes)]
    for k in range(num_classes):
        idx = rng.permutation(idx_by_class[k])
        props = rng.dirichlet(alpha * np.ones(n_nodes))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, chunk in enumerate(np.split(idx, cuts)):
            node_lists[i].append(chunk)
    indices_per_node = [
        np.sort(np.concatenate(chunks)) if chunks else np.array([], dtype=np.int64)
        for chunks in node_lists
    ]
    Pi = proportions_from_labels(labels, indices_per_node, num_classes)
    return indices_per_node, Pi


def cluster_partition(
    labels: np.ndarray, n_nodes: int, seed: int = 0, num_classes: int | None = None
) -> tuple[list[np.ndarray], np.ndarray]:
    """One class per node (Section 6.1): node i gets class ``i % K`` data."""
    labels = np.asarray(labels)
    num_classes = _resolve_num_classes(labels, num_classes)
    rng = np.random.default_rng(seed)
    idx_by_class = [rng.permutation(np.nonzero(labels == k)[0]) for k in range(num_classes)]
    counters = [0] * num_classes
    nodes_of_class = [np.nonzero(np.arange(n_nodes) % num_classes == k)[0] for k in range(num_classes)]
    indices_per_node: list[np.ndarray] = [None] * n_nodes  # type: ignore
    for k in range(num_classes):
        chunks = np.array_split(idx_by_class[k], max(len(nodes_of_class[k]), 1))
        for node, chunk in zip(nodes_of_class[k], chunks):
            indices_per_node[node] = np.sort(chunk)
    for i in range(n_nodes):
        if indices_per_node[i] is None:
            indices_per_node[i] = np.array([], dtype=np.int64)
    Pi = proportions_from_labels(labels, indices_per_node, num_classes)
    return indices_per_node, Pi

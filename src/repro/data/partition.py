"""Heterogeneous data partitioners for decentralized learning.

Implements the label-skew partitioning schemes the paper uses:

* ``shard_partition`` -- the McMahan et al. (2017) scheme used in Section 6.2:
  sort by label, split into ``2n`` equal shards, deal 2 shards per node. Most
  nodes see 2 classes; label-boundary shards can carry up to 4.
* ``dirichlet_partition`` -- Dirichlet(alpha) label-skew (common FL benchmark,
  provided for the "beyond label skew" extension suggested in the paper's
  conclusion).
* ``cluster_partition`` -- one class per node group (the Section 6.1 synthetic
  setup: n nodes, K clusters, n/K nodes per cluster).

All partitioners return ``(indices_per_node, Pi)`` where ``Pi[i, k]`` is the
empirical class proportion of node i -- exactly the matrix STL-FW consumes.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "shard_partition",
    "dirichlet_partition",
    "cluster_partition",
    "proportions_from_labels",
]


def proportions_from_labels(
    labels: np.ndarray, indices_per_node: list[np.ndarray], num_classes: int
) -> np.ndarray:
    """Empirical per-node class proportions Pi from a partition."""
    n = len(indices_per_node)
    Pi = np.zeros((n, num_classes))
    for i, idx in enumerate(indices_per_node):
        if len(idx) == 0:
            Pi[i] = 1.0 / num_classes
            continue
        counts = np.bincount(labels[idx], minlength=num_classes)
        Pi[i] = counts / counts.sum()
    return Pi


def shard_partition(
    labels: np.ndarray,
    n_nodes: int,
    shards_per_node: int = 2,
    seed: int = 0,
) -> tuple[list[np.ndarray], np.ndarray]:
    """McMahan-style shard partition (sort by label, deal shards).

    Args:
      labels: (N,) integer labels.
      n_nodes: number of agents.
      shards_per_node: shards dealt to each node (2 in the paper).
      seed: shard-dealing rng seed.
    """
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    order = np.argsort(labels, kind="stable")
    n_shards = n_nodes * shards_per_node
    shards = np.array_split(order, n_shards)
    rng = np.random.default_rng(seed)
    shard_ids = rng.permutation(n_shards)
    indices_per_node = []
    for i in range(n_nodes):
        mine = shard_ids[i * shards_per_node : (i + 1) * shards_per_node]
        idx = np.concatenate([shards[s] for s in mine])
        indices_per_node.append(np.sort(idx))
    Pi = proportions_from_labels(labels, indices_per_node, num_classes)
    return indices_per_node, Pi


def dirichlet_partition(
    labels: np.ndarray,
    n_nodes: int,
    alpha: float = 0.5,
    seed: int = 0,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Dirichlet(alpha) label-skew partition (lower alpha = more skew)."""
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    idx_by_class = [np.nonzero(labels == k)[0] for k in range(num_classes)]
    node_lists: list[list[np.ndarray]] = [[] for _ in range(n_nodes)]
    for k in range(num_classes):
        idx = rng.permutation(idx_by_class[k])
        props = rng.dirichlet(alpha * np.ones(n_nodes))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, chunk in enumerate(np.split(idx, cuts)):
            node_lists[i].append(chunk)
    indices_per_node = [
        np.sort(np.concatenate(chunks)) if chunks else np.array([], dtype=np.int64)
        for chunks in node_lists
    ]
    Pi = proportions_from_labels(labels, indices_per_node, num_classes)
    return indices_per_node, Pi


def cluster_partition(
    labels: np.ndarray, n_nodes: int, seed: int = 0
) -> tuple[list[np.ndarray], np.ndarray]:
    """One class per node (Section 6.1): node i gets class ``i % K`` data."""
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    idx_by_class = [rng.permutation(np.nonzero(labels == k)[0]) for k in range(num_classes)]
    counters = [0] * num_classes
    nodes_of_class = [np.nonzero(np.arange(n_nodes) % num_classes == k)[0] for k in range(num_classes)]
    indices_per_node: list[np.ndarray] = [None] * n_nodes  # type: ignore
    for k in range(num_classes):
        chunks = np.array_split(idx_by_class[k], max(len(nodes_of_class[k]), 1))
        for node, chunk in zip(nodes_of_class[k], chunks):
            indices_per_node[node] = np.sort(chunk)
    for i in range(n_nodes):
        if indices_per_node[i] is None:
            indices_per_node[i] = np.array([], dtype=np.int64)
    Pi = proportions_from_labels(labels, indices_per_node, num_classes)
    return indices_per_node, Pi

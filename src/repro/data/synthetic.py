"""Synthetic datasets reproducing the paper's experimental setups.

The container is offline (no MNIST/CIFAR download), so the Section 6.2
experiments run on statistically analogous synthetic classification tasks;
the substitution is recorded in DESIGN.md / EXPERIMENTS.md.

* ``mean_estimation_clusters`` -- Section 6.1: K Gaussian clusters with means
  evenly spread over [-m, m], variance sigma~^2 = 1; the "pointwise loss" is
  ``F(theta, z) = (theta - z)^2`` so all constants of the theory are known in
  closed form (B = 4 m_spread^2-ish; see ``mean_estimation_constants``).
* ``gaussian_blobs`` -- an MNIST-like stand-in: K classes, class-conditional
  Gaussians in q dims with fixed class means (shared across nodes =>
  P(X|Y) fixed, only P_i(Y) varies: pure label skew, matching Section 5.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "MeanEstimationTask",
    "mean_estimation_clusters",
    "gaussian_blobs",
]


@dataclasses.dataclass
class MeanEstimationTask:
    """Section 6.1 task. Node i observes Z ~ N(mu_{c(i)}, sigma~^2), c(i) = i % K.

    Loss ``F(theta, Z) = (theta - Z)^2`` (d = 1). Closed-form constants:

    * grad F(theta, z) = 2 (theta - z);  grad f_i(theta) = 2 (theta - mu_i)
    * global optimum theta* = mean(mu), f* analytic
    * L = 2, sigma_i^2 = 4 sigma~^2 for all i
    * zeta_bar^2 = 4 Var(mu) ; B (Prop. 2, class level) = max_k 4 (mu_k - mu_bar)^2-ish
    """

    n_nodes: int
    K: int
    cluster_means: np.ndarray  # (K,)
    sigma_tilde2: float

    @property
    def node_means(self) -> np.ndarray:
        return self.cluster_means[np.arange(self.n_nodes) % self.K]

    @property
    def theta_star(self) -> float:
        return float(self.node_means.mean())

    @property
    def L(self) -> float:
        return 2.0

    @property
    def sigma_i2(self) -> float:
        """E||grad F - grad f_i||^2 = 4 sigma~^2 (exact)."""
        return 4.0 * self.sigma_tilde2

    @property
    def zeta_bar2(self) -> float:
        mu = self.node_means
        return float(4.0 * np.mean((mu - mu.mean()) ** 2))

    @property
    def B(self) -> float:
        """Class-level heterogeneity constant of Proposition 2.

        ||E[gF|Y=k] - mean_k' E[gF|Y=k']||^2 = 4 (mu_k - mu_bar)^2 <= B.
        """
        mu = self.cluster_means
        return float(4.0 * np.max((mu - mu.mean()) ** 2))

    @property
    def Pi(self) -> np.ndarray:
        """One-hot class proportions: node i holds only class i % K."""
        Pi = np.zeros((self.n_nodes, self.K))
        Pi[np.arange(self.n_nodes), np.arange(self.n_nodes) % self.K] = 1.0
        return Pi

    def sample(self, batch: int, rng: np.random.Generator) -> np.ndarray:
        """(n_nodes, batch) draws, one row per node."""
        return rng.normal(
            self.node_means[:, None], np.sqrt(self.sigma_tilde2), size=(self.n_nodes, batch)
        )

    def grad(self, theta: np.ndarray, z: np.ndarray) -> np.ndarray:
        """Stochastic gradient 2(theta - mean_batch(z)) per node."""
        return 2.0 * (theta - z.mean(axis=-1))

    def expected_grads(self, theta: float) -> np.ndarray:
        """(n, 1) expected local gradients at a common scalar theta."""
        return (2.0 * (theta - self.node_means))[:, None]


def mean_estimation_clusters(
    n_nodes: int = 100, K: int = 10, m: float = 5.0, sigma_tilde2: float = 1.0
) -> MeanEstimationTask:
    """Section 6.1 generalization of Example 1: K cluster means evenly spread
    over [-m, m] (m controls heterogeneity)."""
    means = np.linspace(-m, m, K) if K > 1 else np.zeros(1)
    return MeanEstimationTask(n_nodes=n_nodes, K=K, cluster_means=means, sigma_tilde2=sigma_tilde2)


def gaussian_blobs(
    n_samples: int = 20000,
    num_classes: int = 10,
    dim: int = 64,
    sep: float = 3.0,
    noise: float = 1.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """MNIST-like synthetic classification set: shared P(X|Y), K classes.

    Returns (X, y): features (N, dim) float32, labels (N,) int32. Class means
    are random unit directions scaled by ``sep`` (fixed by seed so every node
    shares P(X|Y), and heterogeneity is purely label skew).
    """
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(num_classes, dim))
    means = sep * means / np.linalg.norm(means, axis=1, keepdims=True)
    y = rng.integers(0, num_classes, size=n_samples)
    X = means[y] + noise * rng.normal(size=(n_samples, dim))
    return X.astype(np.float32), y.astype(np.int32)

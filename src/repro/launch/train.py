"""End-to-end distributed training driver.

Trains any assigned architecture with D-SGD over a device mesh:

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-0.6b --steps 50 --topology stl-fw --budget 3

On this CPU container it runs a reduced (smoke) config on a small forced
host-device mesh; on a real TPU slice the same flags with ``--full`` and the
production mesh run the full configuration. The learned STL-FW topology is
built from the data pipeline's per-node domain histograms -- exactly the
paper's pre-processing step -- and executed as a Birkhoff ppermute schedule.
"""

import os

if "XLA_FLAGS" not in os.environ:
    # host-device mesh for CPU runs; harmless on real TPU launches where the
    # flag is managed by the launcher
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import learn_topology, schedule_from_result, topology as topo
from repro.core.mixing import schedule_from_matrix
from repro.data.tokens import DomainSkewCorpus, TokenBatcher
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.checkpoints import CheckpointManager
from repro.train.lm_trainer import make_train_setup
from repro.train.metrics import MetricLogger
from repro.compat import set_mesh


def build_topology(kind: str, Pi: np.ndarray, budget: int, lam: float):
    n = Pi.shape[0]
    if kind == "complete":
        return None  # pmean
    if kind == "ring":
        return schedule_from_matrix(topo.ring(n))
    if kind == "random":
        return schedule_from_matrix(topo.random_d_regular(n, min(budget, n - 1), seed=0))
    if kind == "stl-fw":
        return schedule_from_result(learn_topology(Pi, budget=budget, lam=lam))
    raise ValueError(kind)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--per-node-batch", type=int, default=2)
    ap.add_argument("--topology", default="stl-fw",
                    choices=["stl-fw", "random", "ring", "complete"])
    ap.add_argument("--budget", type=int, default=2, help="STL-FW d_max")
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--full", action="store_true",
                    help="full config on the production mesh (TPU)")
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.full:
        mesh = make_production_mesh()
        cfg = get_config(args.arch)
    else:
        mesh = make_host_mesh(args.data, args.model)
        cfg = get_smoke_config(args.arch)
    n_nodes = mesh.shape["data"]

    # Heterogeneous data: one skewed domain mixture per node.
    n_domains = max(4, n_nodes // 2)
    corpus = DomainSkewCorpus(vocab_size=cfg.vocab_size, n_domains=n_domains, seed=0)
    Pi = np.full((n_nodes, n_domains), 0.1 / (n_domains - 1))
    Pi[np.arange(n_nodes), np.arange(n_nodes) % n_domains] = 0.9
    Pi /= Pi.sum(1, keepdims=True)
    batcher = TokenBatcher(corpus, Pi, args.per_node_batch, args.seq_len, seed=1)

    schedule = build_topology(args.topology, Pi, args.budget, args.lam)
    if schedule is not None:
        print(f"topology '{args.topology}': {schedule.n_communication_atoms} "
              f"communication atoms (d_max bound)")

    setup = make_train_setup(cfg, mesh, mode="dsgd", schedule=schedule, lr=args.lr)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), setup.param_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    logger = MetricLogger()
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with set_mesh(mesh):
        params = jax.jit(setup.init_params, out_shardings=shardings)(
            jax.random.PRNGKey(0)
        )
        step_fn = jax.jit(setup.train_step)
        t0 = time.time()
        for t in range(args.steps):
            toks, labels = batcher.next_batch(t)
            batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
            if cfg.arch_type == "vlm":
                b, per, s = toks.shape
                batch["image_embeds"] = jnp.zeros(
                    (b, per, cfg.vision.num_patches, cfg.d_model), jnp.dtype(cfg.dtype)
                )
            if cfg.arch_type == "audio":
                b, per, s = toks.shape
                batch["frames"] = jnp.zeros(
                    (b, per, cfg.encoder.num_frames, cfg.d_model), jnp.dtype(cfg.dtype)
                )
                batch["tokens"] = batch["tokens"][..., :448]
                batch["labels"] = batch["labels"][..., :448]
            params, _, loss = step_fn(params, None, batch)
            logger.log(t, loss=float(loss))
            if t % 5 == 0 or t == args.steps - 1:
                print(f"step {t:4d}  loss {float(loss):.4f}  "
                      f"({(time.time()-t0)/(t+1):.2f}s/step)")
        if ckpt is not None:
            ckpt.save(args.steps, jax.device_get(params))
            print(f"checkpoint written to {args.ckpt_dir}")
    losses = logger.column("loss")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with NO real device allocation (ShapeDtypeStruct
inputs only).

The two lines above MUST stay the first statements of this module: jax locks
the device count at first backend initialization, and the dry-run needs 512
placeholder host devices to build the 2x16x16 production mesh. Tests and
benchmarks import other modules and keep seeing 1 device.

Per combo this produces:
  * compiled.memory_analysis()  -- per-device argument/temp/output bytes
  * compiled.cost_analysis()    -- HLO FLOPs / bytes accessed (NOTE: XLA
    counts while-loop bodies ONCE; repro.launch.roofline corrects for the
    layer-scan trip counts)
  * collective statistics parsed from the post-SPMD HLO text (per type,
    loop-aware)
written to experiments/dryrun/<arch>__<shape>__<mesh>.json.

Shape kinds: train_4k lowers train_step; prefill_32k lowers the prefill
path; decode_32k / long_500k lower serve_step (ONE token against a
seq_len-sized cache; long_500k uses the sub-quadratic window/recurrent
state). Whisper skips decode shapes (enc-dec, max target length 448 --
DESIGN.md).
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.serve.engine import make_serve_setup, prefill as engine_prefill
from repro.train.lm_trainer import make_train_setup
from repro.compat import set_mesh

SKIPS: dict[tuple[str, str], str] = {
    ("whisper-small", "decode_32k"): "enc-dec ASR: decoder max target len 448",
    ("whisper-small", "long_500k"): "enc-dec ASR: decoder max target len 448",
}

# archs that need sliding-window *variants* for long_500k (pure full-attn
# families) -- permitted by the brief, recorded in DESIGN.md.
_COLLECTIVE_RE = re.compile(
    r"(\bf\d+|bf16|u\d+|s\d+|pred)\[([0-9,]*)\][^=]*\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "u32": 4, "s32": 4,
                "u8": 1, "s8": 1, "pred": 1, "u64": 8, "s64": 8, "f8": 1}


def train_mode_for(arch: str, multi_pod: bool) -> str:
    if multi_pod:
        return "dsgd_pod"
    if arch == "deepseek-v2-236b":
        return "fsdp"  # 16 replicas do not fit a pod (DESIGN.md)
    return "dsgd"


def parse_collectives(hlo_text: str, scan_trip: int) -> dict:
    """Sum collective result bytes from post-SPMD HLO, weighting ops that
    live inside while-loop bodies by ``scan_trip`` (the layer-scan length --
    XLA prints loop bodies once)."""
    body_names = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    totals = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
              "all-to-all": 0, "collective-permute": 0}
    current_mult = 1
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers: "%name (args...) -> ... {" or "ENTRY %name ...{".
        # args may contain nested parens (tuple params), so match only the
        # leading name token.
        if stripped.endswith("{") and (stripped.startswith("%") or stripped.startswith("ENTRY")):
            tok = stripped.split()[1] if stripped.startswith("ENTRY") else stripped.split()[0]
            name = tok.lstrip("%").split("(")[0]
            current_mult = scan_trip if name in body_names else 1
        m = _COLLECTIVE_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            nelems = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        nelems *= int(d)
            totals[kind] += nelems * _DTYPE_BYTES.get(dtype, 4) * current_mult
    totals["total_bytes"] = sum(totals.values())
    return totals


def scan_trip_count(cfg) -> int:
    return max(cfg.num_layers // len(cfg.layer_pattern), 1)


def _param_shardings(specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


# §Perf: microbatching policy -- archs whose activation footprint exceeds
# HBM at the full per-step batch accumulate gradients over microbatches.
GRAD_ACCUM = {"deepseek-v2-236b": 8, "qwen3-moe-30b-a3b": 2}


def build_train_lowering(arch: str, shape: dict, mesh, multi_pod: bool):
    cfg = get_config(arch)
    mode = train_mode_for(arch, multi_pod)
    setup = make_train_setup(cfg, mesh, mode=mode, schedule=None, lr=1e-3,
                             grad_accum=GRAD_ACCUM.get(arch, 1))
    gb, S = shape["global_batch"], shape["seq_len"]
    if mode == "dsgd":
        n = setup.n_nodes
        lead = (n, gb // n)
    elif mode == "dsgd_pod":
        n = setup.n_nodes
        lead = (n, gb // n)
    else:
        lead = (gb,)

    def batch_abs():
        ex = registry.make_inputs(cfg, batch_size=1, seq_len=S, abstract=True)
        out = {}
        for k, v in ex.items():
            out[k] = jax.ShapeDtypeStruct(lead + v.shape[1:], v.dtype)
        return out

    batch = batch_abs()
    bspec = {}
    for k, v in batch.items():
        spec = setup.batch_spec(v.ndim)
        bspec[k] = NamedSharding(mesh, spec)
    params_abs = setup.abstract_params()
    shardings = _param_shardings(setup.param_specs, mesh)
    jitted = jax.jit(
        setup.train_step,
        in_shardings=(shardings, None, bspec),
        donate_argnums=(0,),  # params updated in place
    )
    lowered = jitted.lower(params_abs, None, batch)
    return cfg, lowered, {"mode": mode}


def build_decode_lowering(arch: str, shape: dict, mesh, multi_pod: bool, long: bool):
    cfg = get_config(arch)
    B, S = shape["global_batch"], shape["seq_len"]
    setup = make_serve_setup(cfg, mesh, batch=B, seq_len=S, long_context=long)
    params_abs = jax.eval_shape(
        lambda r: registry.init_model(r, cfg), jax.random.PRNGKey(0)
    )
    pshard = _param_shardings(setup.param_specs, mesh)
    cshard = _param_shardings(setup.cache_specs, mesh)
    dp = ("pod", "data") if multi_pod else ("data",)
    dp_axis = tuple(dp) if len(dp) > 1 else dp[0]
    tok_spec = NamedSharding(mesh, P(dp_axis if B > 1 else None, None))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    position = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    jitted = jax.jit(
        setup.serve_step,
        in_shardings=(pshard, tok_spec, tok_spec, cshard),
        donate_argnums=(3,),  # in-place cache update: no double-buffer temp
    )
    lowered = jitted.lower(params_abs, token, position, setup.abstract_cache)
    return cfg, lowered, {"mode": "serve_decode" + ("_long" if long else "")}


def build_prefill_lowering(arch: str, shape: dict, mesh, multi_pod: bool):
    cfg = get_config(arch)
    B, S = shape["global_batch"], shape["seq_len"]
    from repro.train.sharding import make_param_specs

    params_abs = jax.eval_shape(
        lambda r: registry.init_model(r, cfg), jax.random.PRNGKey(0)
    )
    pspecs = make_param_specs(params_abs, mesh, node_axis=None, fsdp_axis=None)
    pshard = _param_shardings(pspecs, mesh)
    dp = ("pod", "data") if multi_pod else ("data",)
    dp_axis = tuple(dp) if len(dp) > 1 else dp[0]

    inputs = registry.make_inputs(cfg, batch_size=B, seq_len=S, abstract=True)
    in_shardings = {}
    for k, v in inputs.items():
        in_shardings[k] = NamedSharding(mesh, P(dp_axis, *([None] * (v.ndim - 1))))

    def prefill_step(params, batch):
        if cfg.arch_type == "audio":
            return engine_prefill(
                params, cfg, batch["tokens"], max_len=batch["tokens"].shape[1] + 8,
                frames=batch["frames"],
            )
        img = batch.get("image_embeds")
        return engine_prefill(
            params, cfg, batch["tokens"],
            max_len=S + 8, image_embeds=img,
        )

    inputs.pop("labels", None)
    in_shardings.pop("labels", None)
    jitted = jax.jit(prefill_step, in_shardings=(pshard, in_shardings))
    lowered = jitted.lower(params_abs, inputs)
    return cfg, lowered, {"mode": "serve_prefill"}


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    key = f"{arch}__{shape_name}__{mesh_name}"
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
        _write(out_dir, key, rec)
        print(f"SKIP {key}: {rec['reason']}")
        return rec
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with set_mesh(mesh):
            if shape["kind"] == "train":
                cfg, lowered, meta = build_train_lowering(arch, shape, mesh, multi_pod)
            elif shape["kind"] == "prefill":
                cfg, lowered, meta = build_prefill_lowering(arch, shape, mesh, multi_pod)
            else:
                long = shape["kind"] == "decode_long"
                cfg, lowered, meta = build_decode_lowering(arch, shape, mesh, multi_pod, long)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            trip = scan_trip_count(cfg)
            coll = parse_collectives(hlo, trip)
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", **meta,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
            },
            "cost": {
                "flops_per_device_hlo": ca.get("flops", 0.0),
                "bytes_accessed_hlo": ca.get("bytes accessed", 0.0),
            },
            "collectives": coll,
            "scan_trip": trip,
            "hlo_bytes": len(hlo),
        }
        print(
            f"OK   {key}: compile {t_compile:.0f}s | "
            f"temp {ma.temp_size_in_bytes/2**30:.2f} GiB/dev | "
            f"coll {coll['total_bytes']/2**20:.1f} MiB/dev"
        )
    except Exception as e:  # noqa: BLE001 - record failures, don't crash the sweep
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"FAIL {key}: {rec['error'][:200]}")
    _write(out_dir, key, rec)
    return rec


def _write(out_dir: str, key: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, key + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def _run_subprocess(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    """Run one combo in an isolated process (XLA CHECK failures abort the
    whole process; isolation keeps the sweep alive) and read back its JSON."""
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out_dir]
    if multi_pod:
        cmd.append("--multi-pod")
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=3600)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    key = f"{arch}__{shape}__{mesh_name}"
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "error" or "traceback" in rec:
            return rec
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "status": "error",
           "error": f"process died (rc={proc.returncode})",
           "stderr_tail": proc.stderr[-1500:]}
    _write(out_dir, key, rec)
    print(f"FAIL {key}: process died rc={proc.returncode}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="isolate each combo in its own process")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_fail = n_skip = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                if args.subprocess:
                    rec = _run_subprocess(arch, shape, multi_pod, args.out)
                else:
                    rec = run_one(arch, shape, multi_pod, args.out)
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"\ndry-run summary: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) this derives the three roofline terms for TPU v5e:

    compute    = FLOPs_step / (chips * 197e12)
    memory     = bytes_step / (chips * 819e9)
    collective = collective_bytes_per_device / 50e9

Sources and caveats (documented in EXPERIMENTS.md):
  * FLOPs: XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so with
    the layer-scan the raw number under-counts by ~num_groups. The primary
    compute numerator is therefore the ANALYTIC model-FLOPs estimate
    (6*N_active*T for training [+ attention S^2 term], 2*N_active*B for
    decode); the raw HLO value is reported alongside, and the ratio
    MODEL_FLOPS / (HLO_FLOPs * scan_trip) is the remat/loop sanity check.
  * bytes: analytic traffic model (params + activation streams + cache);
    raw HLO bytes-accessed reported alongside.
  * collective bytes: parsed from the post-SPMD HLO by the dry-run with
    loop-body x trip weighting; shapes in the partitioned module are
    per-device, so the value divides by the link bandwidth directly.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline \
        --dryrun experiments/dryrun --out experiments/roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import V5E

# populated lazily: abstract param counts are cheap but not free
_COUNTS_CACHE: dict[str, tuple[int, int]] = {}


def param_counts(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts from abstract shapes."""
    if arch in _COUNTS_CACHE:
        return _COUNTS_CACHE[arch]
    import jax

    from repro.models import active_param_count, param_count, registry

    cfg = get_config(arch)
    abstract = jax.eval_shape(
        lambda r: registry.init_model(r, cfg), jax.random.PRNGKey(0)
    )
    total = param_count(abstract)
    active = active_param_count(abstract, cfg)
    _COUNTS_CACHE[arch] = (total, active)
    return total, active


def analytic_flops(arch: str, shape_name: str) -> float:
    """Whole-step model FLOPs (all devices), standard accounting."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    B, S = shape["global_batch"], shape["seq_len"]
    total, active = param_counts(arch)
    H, Dh, L = cfg.num_heads, cfg.resolved_head_dim, cfg.num_layers
    n_attn = sum(
        1 for i in range(L) if cfg.kind(i) in ("attn", "local_attn")
    )
    def attn_ctx(kind: str) -> float:
        # average causal context length per query
        if kind == "local_attn":
            return 0.5 * min(S, cfg.sliding_window)
        return 0.5 * S

    attn_ctx_sum = sum(
        attn_ctx(cfg.kind(i)) for i in range(L) if cfg.kind(i) in ("attn", "local_attn")
    )
    if shape["kind"] == "train":
        T = B * S
        # 6*N*T (fwd 2 + bwd 4) + attention 12*T*ctx*H*Dh per layer
        return 6.0 * active * T + 12.0 * T * H * Dh * attn_ctx_sum
    if shape["kind"] == "prefill":
        T = B * S
        return 2.0 * active * T + 4.0 * T * H * Dh * attn_ctx_sum
    # decode: one token per request
    ctx = S if shape["kind"] == "decode" else min(S, cfg.long_context_window)
    return 2.0 * active * B + 4.0 * n_attn * B * ctx * H * Dh


def analytic_bytes_per_device(arch: str, shape_name: str, rec: dict, chips: int) -> float:
    """Per-device HBM traffic estimate for one step."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    B, S = shape["global_batch"], shape["seq_len"]
    total, active = param_counts(arch)
    dt = 2  # bf16
    if shape["kind"] == "train":
        n_nodes = 16 if rec.get("mode") == "dsgd" else rec.get("n_nodes", 1)
        reps = n_nodes if rec.get("mode", "").startswith("dsgd") else 1
        params_dev = total * dt * reps / chips
        # fwd read + bwd read + grad write + update r/w (~5x with remat ~6x)
        param_traffic = 6.0 * params_dev
        b_loc = B / (chips / 16)  # batch per model-group
        act_traffic = 20.0 * cfg.num_layers * (B * S * cfg.d_model * dt) / chips * 3
        loss_traffic = 4.0 * B * S * cfg.vocab_size * dt / chips
        return param_traffic + act_traffic + loss_traffic
    if shape["kind"] == "prefill":
        params_dev = total * dt / chips
        act = 12.0 * cfg.num_layers * B * S * cfg.d_model * dt / chips
        return 2.0 * params_dev + act
    # decode: whole params + whole cache read per token
    params_dev = total * dt / chips
    if shape["kind"] == "decode":
        cache = rec.get("memory", {}).get("argument_bytes", 0) - params_dev
        cache = max(cache, 0.0)
    else:
        cache = 0.0
    return 2.0 * params_dev + cache


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops = analytic_flops(arch, shape_name)
    t_compute = flops / (chips * V5E["peak_flops_bf16"])
    bytes_dev = analytic_bytes_per_device(arch, shape_name, rec, chips)
    t_memory = bytes_dev / V5E["hbm_bw"]
    coll_dev = rec["collectives"]["total_bytes"]
    t_coll = coll_dev / V5E["ici_bw"]
    total, active = param_counts(arch)
    hlo_flops_dev = rec["cost"]["flops_per_device_hlo"]
    trip = rec.get("scan_trip", 1)
    # loop-corrected per-device HLO flops -> whole-step estimate
    hlo_flops_corr = hlo_flops_dev * max(trip, 1) * chips
    ratio = flops / hlo_flops_corr if hlo_flops_corr else float("nan")
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    advice = {
        "compute": "raise arithmetic efficiency (MXU-aligned tiles, fused kernels) or shrink redundant compute (remat policy)",
        "memory": "cut HBM traffic: larger fusion blocks, bf16 end-to-end, chunked loss/attention streaming",
        "collective": "cut collective volume: sparser gossip schedule (smaller d_max), overlap permutes with compute, shard params to reduce all-gathers",
    }[dominant]
    return {
        "arch": arch, "shape": shape_name, "mesh": rec["mesh"],
        "mode": rec.get("mode", ""),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": flops,
        "hlo_flops_corrected": hlo_flops_corr,
        "flops_ratio": ratio,
        "params_total": total, "params_active": active,
        "coll_bytes_dev": coll_dev,
        "temp_gib_dev": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib_dev": rec["memory"]["argument_bytes"] / 2**30,
        "advice": advice,
    }


def fmt_s(x: float) -> str:
    if x >= 1e-1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dryrun, "*.json"))):
        rec = json.load(open(f))
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)

    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    lines = [
        "# Roofline table (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | mesh | mode | compute | memory | collective | dominant | MODEL_FLOPS | MF/HLO | mem GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['flops_ratio']:.2f} "
            f"| {r['args_gib_dev'] + r['temp_gib_dev']:.1f} |"
        )
    lines.append("")
    lines.append("## Bottleneck advice (one line per combo)")
    for r in rows:
        lines.append(
            f"- **{r['arch']} x {r['shape']} ({r['mesh']})**: {r['dominant']}-bound "
            f"-> {r['advice']}"
        )
    out_text = "\n".join(lines)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(out_text + "\n")
    with open(args.out.replace(".md", ".json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(out_text)


if __name__ == "__main__":
    main()

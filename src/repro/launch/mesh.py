"""Production device meshes (TPU v5e).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.

Single-pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
cross-pod D-SGD gossip (dsgd_pod mode) or plain cross-pod data parallelism.
"""

from __future__ import annotations

import jax

from repro.compat import AxisType, make_compat_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "V5E"]


# TPU v5e hardware constants used by the roofline analysis.
V5E = {
    "peak_flops_bf16": 197e12,  # FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link (per direction, approx.)
    "hbm_bytes": 16 * 2**30,
    "chips_per_pod": 256,
}


def make_production_mesh(*, multi_pod: bool = False):
    """The deployment mesh: 16x16 single pod or 2x16x16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh for tests on forced host devices."""
    return make_compat_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )

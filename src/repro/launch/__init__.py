"""Launchers: production meshes, multi-pod dry-run, training driver, roofline.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import; import it only in a
dedicated process (``python -m repro.launch.dryrun``).
"""

from .mesh import V5E, make_host_mesh, make_production_mesh

__all__ = ["V5E", "make_host_mesh", "make_production_mesh"]

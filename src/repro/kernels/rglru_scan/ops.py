"""Jitted public wrapper for the RG-LRU scan kernel (padding + dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import rglru_scan_ref
from .rglru_scan import DEFAULT_BLOCK_D, DEFAULT_BLOCK_S, rglru_scan_pallas

__all__ = ["rglru_scan"]


@functools.partial(jax.jit, static_argnames=("block_s", "block_d", "interpret", "use_ref"))
def rglru_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis 1 of (B, S, D).

    Pads S and D to the kernel tiles and strips the padding. Padded time
    steps use a = 1, b = 0 (identity recurrence -> no effect on real steps:
    the pad sits at the END of the sequence); padded feature lanes are junk
    and sliced off.
    """
    if use_ref:
        return rglru_scan_ref(a, b)
    B, S, D = a.shape
    if S < block_s:  # tiny sequences: the tiled kernel is pure overhead
        return rglru_scan_ref(a, b)
    pad_s = (-S) % block_s
    pad_d = (-D) % block_d
    if pad_s or pad_d:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_d)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_d)))
    out = rglru_scan_pallas(a, b, block_s=block_s, block_d=block_d, interpret=interpret)
    return out[:, :S, :D]

"""Pure-jnp oracle for the RG-LRU linear scan: h_t = a_t * h_{t-1} + b_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """Elementwise linear recurrence over axis 1.

    a, b: (B, S, D) coefficients; h0: optional (B, D) initial state.
    Returns h: (B, S, D) with h_t = a_t * h_{t-1} + b_t, h_{-1} = h0 or 0.
    """
    if a.shape != b.shape or a.ndim != 3:
        raise ValueError(f"bad shapes a={a.shape} b={b.shape}")
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(prev, cur):
        a1, b1 = prev
        a2, b2 = cur
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h

from . import ops, ref
from .ops import rglru_scan
from .ref import rglru_scan_ref
from .rglru_scan import rglru_scan_pallas

__all__ = ["ops", "ref", "rglru_scan", "rglru_scan_ref", "rglru_scan_pallas"]

"""Pallas TPU kernel for the RG-LRU linear scan  h_t = a_t * h_{t-1} + b_t.

Tiling: grid = (B, D / BLOCK_D, S / BLOCK_S) with the time axis innermost
("arbitrary" semantics) so a per-(batch, feature-block) carry persists in
VMEM scratch across time blocks. Within a block the recurrence runs as a
vectorized associative scan over the (BLOCK_S, BLOCK_D) tile -- O(log S)
depth on the VPU -- and the carried state folds in as

    h_block = A_cum * h_carry + B_cum

where (A_cum, B_cum) is the blockwise prefix composition.

VMEM per grid step (BLOCK_S = 256, BLOCK_D = 512, f32):
  a tile + b tile + out tile = 3 * 256*512*4 = 1.5 MiB, carry 2 KiB --
  comfortably double-bufferable in v5e's ~16 MiB VMEM. BLOCK_D is a
  multiple of 128 (lane width).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_S = 256
DEFAULT_BLOCK_D = 512


def _rglru_kernel(a_ref, b_ref, out_ref, h_scratch):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[0].astype(jnp.float32)  # (BS, BD)
    b = b_ref[0].astype(jnp.float32)

    def combine(prev, cur):
        a1, b1 = prev
        a2, b2 = cur
        return a1 * a2, a2 * b1 + b2

    A_cum, B_cum = jax.lax.associative_scan(combine, (a, b), axis=0)
    h = A_cum * h_scratch[...] + B_cum  # fold the carried state
    out_ref[0] = h.astype(out_ref.dtype)
    h_scratch[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("block_s", "block_d", "interpret"))
def rglru_scan_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
) -> jax.Array:
    """a, b: (B, S, D); S % block_s == 0, D % block_d == 0."""
    B, S, D = a.shape
    if S % block_s or D % block_d:
        raise ValueError(f"S={S}, D={D} must tile by ({block_s}, {block_d})")
    grid = (B, D // block_d, S // block_s)

    def idx(bi, di, si):
        return (bi, si, di)

    return pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), idx),
            pl.BlockSpec((1, block_s, block_d), idx),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d), idx),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)

"""Pallas TPU kernels for the system's compute hot spots.

Each kernel ships three files:
  <name>.py -- pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jitted public wrapper (padding, dispatch, fallbacks)
  ref.py    -- pure-jnp oracle used by the allclose test suites

Kernels are validated on CPU in interpret=True mode; block shapes are chosen
for TPU v5e (BQ/BKV multiples of 128 for the MXU, working sets << 16 MiB VMEM).
"""

from . import flash_attention, gossip_mix, rglru_scan

__all__ = ["flash_attention", "gossip_mix", "rglru_scan"]

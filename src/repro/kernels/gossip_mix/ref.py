"""Pure-jnp oracles for the gossip mixing kernels.

``gossip_mix_ref``: dense ``out = W @ theta``.
``gossip_schedule_ref``: Birkhoff form ``out = sum_l coeffs[l] theta[perms[l]]``.

``theta``: (n, P) stacked per-node flat parameters; ``W``: (n, n) mixing
matrix. ``out[i] = sum_j W[i, j] theta[j]`` -- the D-SGD averaging step
(Algorithm 1, line 4) over all nodes at once.
"""

from __future__ import annotations

import jax.numpy as jnp


def gossip_mix_ref(theta: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    if theta.ndim != 2 or W.ndim != 2 or W.shape[0] != W.shape[1]:
        raise ValueError(f"bad shapes theta={theta.shape} W={W.shape}")
    if W.shape[1] != theta.shape[0]:
        raise ValueError("W columns must match theta rows")
    return jnp.einsum(
        "ij,jp->ip", W.astype(jnp.float32), theta.astype(jnp.float32)
    ).astype(theta.dtype)


def gossip_schedule_ref(
    theta: jnp.ndarray, coeffs: jnp.ndarray, perms: jnp.ndarray
) -> jnp.ndarray:
    if theta.ndim != 2 or perms.ndim != 2 or perms.shape[1] != theta.shape[0]:
        raise ValueError(f"bad shapes theta={theta.shape} perms={perms.shape}")
    acc = jnp.zeros(theta.shape, jnp.float32)
    x = theta.astype(jnp.float32)
    for l in range(perms.shape[0]):
        acc = acc + coeffs[l].astype(jnp.float32) * x[perms[l]]
    return acc.astype(theta.dtype)

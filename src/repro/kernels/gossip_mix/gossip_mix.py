"""Pallas TPU kernel for the D-SGD gossip mixing step ``out = W @ theta``.

The mixing matrix ``W`` (n x n, n = node count, small) lives entirely in
VMEM; the parameter matrix ``theta`` (n, P) is tiled along the parameter
axis so each grid step streams one (n, BLOCK_P) tile HBM -> VMEM, performs a
tiny MXU matmul against W, and writes the mixed tile back.

VMEM budget per grid step (BLOCK_P = 2048, n <= 64, f32):
  theta tile  n * BLOCK_P * 4  <= 512 KiB
  out tile    n * BLOCK_P * 4  <= 512 KiB
  W           n * n * 4        <=  16 KiB          -- well under ~16 MiB VMEM.

The parameter axis is padded to a multiple of BLOCK_P by the ops.py wrapper
(lane dimension stays a multiple of 128 for the MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 2048


def _gossip_kernel(w_ref, theta_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)
    x = theta_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.dot(
        w, x, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def gossip_mix_pallas(
    theta: jax.Array,
    W: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = True,
) -> jax.Array:
    """``out = W @ theta`` with theta (n, P), P a multiple of ``block_p``."""
    n, P = theta.shape
    if P % block_p != 0:
        raise ValueError(f"P={P} must be a multiple of block_p={block_p}")
    grid = (P // block_p,)
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda p: (0, 0)),  # W: whole matrix, reused
            pl.BlockSpec((n, block_p), lambda p: (0, p)),
        ],
        out_specs=pl.BlockSpec((n, block_p), lambda p: (0, p)),
        out_shape=jax.ShapeDtypeStruct((n, P), theta.dtype),
        interpret=interpret,
    )(W, theta)

"""Jitted public wrapper for the gossip mixing kernel (padding + dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gossip_mix import DEFAULT_BLOCK_P, gossip_mix_pallas
from .ref import gossip_mix_ref

__all__ = ["gossip_mix"]


@functools.partial(jax.jit, static_argnames=("block_p", "interpret", "use_ref"))
def gossip_mix(
    theta: jax.Array,
    W: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Mixing step ``out[i] = sum_j W[i, j] theta[j]`` for (n, P) theta.

    Pads the parameter axis to a multiple of ``block_p`` (the kernel's VMEM
    tile width), dispatches to the Pallas kernel, and strips the padding.
    ``use_ref=True`` routes to the pure-jnp oracle (for A/B testing).
    """
    if use_ref:
        return gossip_mix_ref(theta, W)
    n, P = theta.shape
    # Small parameter axes are cheaper as one einsum than one padded tile.
    if P < block_p:
        return gossip_mix_ref(theta, W)
    pad = (-P) % block_p
    if pad:
        theta_p = jnp.pad(theta, ((0, 0), (0, pad)))
    else:
        theta_p = theta
    out = gossip_mix_pallas(theta_p, W.astype(theta.dtype), block_p=block_p, interpret=interpret)
    return out[:, :P]

"""Jitted public wrappers for the gossip mixing kernels.

Handles backend auto-detection (Pallas interpret mode on every non-TPU
backend), padding
of the parameter axis to the kernel tile width, and the dense-vs-schedule
dispatch: the dense matmul kernel is the right tool at ``L ~ n`` (an
unstructured W has up to n atoms), the schedule kernel at ``L << n``
(learned sparse topologies). ``gossip_apply`` picks automatically via the
``repro.core.mixing.preferred_transport`` cost model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gossip_mix import DEFAULT_BLOCK_P, gossip_mix_pallas
from .gossip_schedule import gossip_schedule_pallas
from .ref import gossip_mix_ref, gossip_schedule_ref

__all__ = ["default_interpret", "gossip_mix", "gossip_schedule", "gossip_apply"]


def default_interpret() -> bool:
    """Interpret mode everywhere except real TPU.

    These kernels use TPU-specific pallas features (PrefetchScalarGridSpec,
    VMEM scratch) that only lower on the TPU backend, so GPU installs also
    fall back to the interpreter rather than a failing Triton lowering.
    """
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else interpret


@functools.partial(
    jax.jit, static_argnames=("block_p", "interpret", "use_ref")
)
def _gossip_mix_impl(theta, W, block_p, interpret, use_ref):
    if use_ref:
        return gossip_mix_ref(theta, W)
    n, P = theta.shape
    # Small parameter axes are cheaper as one einsum than one padded tile.
    if P < block_p:
        return gossip_mix_ref(theta, W)
    pad = (-P) % block_p
    if pad:
        theta_p = jnp.pad(theta, ((0, 0), (0, pad)))
    else:
        theta_p = theta
    out = gossip_mix_pallas(theta_p, W.astype(theta.dtype), block_p=block_p, interpret=interpret)
    return out[:, :P]


def gossip_mix(
    theta: jax.Array,
    W: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
    use_ref: bool = False,
) -> jax.Array:
    """Dense mixing ``out[i] = sum_j W[i, j] theta[j]`` for (n, P) theta.

    Pads the parameter axis to a multiple of ``block_p`` (the kernel's VMEM
    tile width), dispatches to the Pallas kernel, and strips the padding.
    ``interpret=None`` auto-selects interpret mode on non-TPU backends
    (see ``default_interpret``: the kernels only lower on TPU).
    ``use_ref=True`` routes to the pure-jnp oracle (for A/B testing).
    """
    return _gossip_mix_impl(theta, W, block_p, _resolve_interpret(interpret), use_ref)


@functools.partial(
    jax.jit, static_argnames=("block_p", "interpret", "use_ref", "pre_padded")
)
def _gossip_schedule_impl(theta, coeffs, perms, block_p, interpret, use_ref, pre_padded):
    if use_ref:
        return gossip_schedule_ref(theta, coeffs, perms)
    n, P = theta.shape
    if pre_padded:
        if P % block_p != 0:
            raise ValueError(
                f"pre_padded theta has P={P}, not a multiple of block_p={block_p}"
            )
        return gossip_schedule_pallas(
            theta, coeffs, perms, block_p=block_p, interpret=interpret
        )
    if P < block_p:
        return gossip_schedule_ref(theta, coeffs, perms)
    pad = (-P) % block_p
    theta_p = jnp.pad(theta, ((0, 0), (0, pad))) if pad else theta
    out = gossip_schedule_pallas(
        theta_p, coeffs, perms, block_p=block_p, interpret=interpret
    )
    return out[:, :P]


def gossip_schedule(
    theta: jax.Array,
    coeffs,
    perms,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
    use_ref: bool = False,
    pre_padded: bool = False,
) -> jax.Array:
    """Birkhoff mixing ``out = sum_l coeffs[l] theta[perms[l]]`` for (n, P) theta.

    ``pre_padded=True`` asserts the caller already padded P to a multiple of
    ``block_p`` (the single-buffer path pads once at flatten time via
    ``ravel_stack``) and skips the per-call pad/strip entirely.
    ``interpret=None`` auto-selects interpret mode on non-TPU backends
    (see ``default_interpret``: the kernels only lower on TPU).
    """
    coeffs = jnp.asarray(coeffs, jnp.float32)
    perms = jnp.asarray(perms, jnp.int32)
    return _gossip_schedule_impl(
        theta, coeffs, perms, block_p, _resolve_interpret(interpret), use_ref, pre_padded
    )


def gossip_apply(
    theta: jax.Array,
    W: jax.Array | None = None,
    schedule=None,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool | None = None,
) -> jax.Array:
    """Cost-model dispatch between the dense and schedule kernels.

    ``schedule`` is a ``repro.core.mixing.BirkhoffSchedule``. With both W and
    schedule available the ``preferred_transport`` model picks; with only one
    available that one runs.
    """
    from repro.core.mixing import preferred_transport

    if schedule is None and W is None:
        raise ValueError("gossip_apply needs W or schedule")
    if schedule is not None:
        n = theta.shape[0]
        # Unlike the XLA _mix_schedule_flat path, the Pallas kernel gathers
        # EVERY atom including identities, so all atoms count as cost here.
        choice = (
            "schedule"
            if W is None
            else preferred_transport(n, schedule.n_atoms)
        )
        if choice == "schedule":
            return gossip_schedule(
                theta,
                schedule.coeff_array(),
                schedule.perm_array(),
                block_p=block_p,
                interpret=interpret,
            )
    return gossip_mix(theta, W, block_p=block_p, interpret=interpret)

from . import ops, ref
from .gossip_mix import gossip_mix_pallas
from .gossip_schedule import gossip_schedule_pallas
from .ops import default_interpret, gossip_apply, gossip_mix, gossip_schedule
from .ref import gossip_mix_ref, gossip_schedule_ref

__all__ = [
    "ops",
    "ref",
    "default_interpret",
    "gossip_apply",
    "gossip_mix",
    "gossip_mix_pallas",
    "gossip_mix_ref",
    "gossip_schedule",
    "gossip_schedule_pallas",
    "gossip_schedule_ref",
]

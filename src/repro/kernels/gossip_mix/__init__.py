from . import ops, ref
from .gossip_mix import gossip_mix_pallas
from .ops import gossip_mix
from .ref import gossip_mix_ref

__all__ = ["ops", "ref", "gossip_mix", "gossip_mix_pallas", "gossip_mix_ref"]

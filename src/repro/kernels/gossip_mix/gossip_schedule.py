"""Pallas TPU kernel for Birkhoff-schedule gossip mixing.

Computes ``out = sum_l coeffs[l] * theta[perms[l]]`` -- the D-SGD averaging
step executed in its sparse Birkhoff decomposition (L gather-AXPYs,
``O(L n P)``) instead of the dense ``W @ theta`` matmul (``O(n^2 P)``).
After ``l`` Frank-Wolfe iterations of STL-FW the learned ``W`` has at most
``l + 1`` atoms (Theorem 2), so for a budget-constrained topology this is
the natural *compute* format, not just the ppermute transport format.

Layout: the parameter axis is tiled in (n, BLOCK_P) blocks streamed
HBM -> VMEM; the (L, n) permutation table and (L,) coefficients ride the
scalar-prefetch path (SMEM) so the gather indices are available before the
tile body runs. Accumulation is f32 in a VMEM scratch tile regardless of
``theta.dtype``.

VMEM budget per grid step (BLOCK_P = 2048, n <= 64, f32):
  theta tile  n * BLOCK_P * 4  <= 512 KiB
  acc tile    n * BLOCK_P * 4  <= 512 KiB
  out tile    n * BLOCK_P * 4  <= 512 KiB        -- well under ~16 MiB VMEM.

The wrapper in ops.py pads P to a multiple of BLOCK_P (or receives a
pre-padded single-buffer from ``repro.core.mixing.ravel_stack``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_P = 2048


def _gossip_schedule_kernel(perm_ref, coeff_ref, theta_ref, out_ref, acc_ref):
    """One (n, BLOCK_P) tile: acc[i] = sum_l coeff[l] * theta[perm[l, i]]."""
    L, n = perm_ref.shape
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def atom_body(l, _):
        gamma = coeff_ref[l].astype(jnp.float32)

        def row_body(i, _):
            src = perm_ref[l, i]
            row = theta_ref[pl.ds(src, 1), :].astype(jnp.float32)
            acc_ref[pl.ds(i, 1), :] += gamma * row
            return 0

        return jax.lax.fori_loop(0, n, row_body, 0)

    jax.lax.fori_loop(0, L, atom_body, 0)
    out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def gossip_schedule_pallas(
    theta: jax.Array,
    coeffs: jax.Array,
    perms: jax.Array,
    *,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = True,
) -> jax.Array:
    """``out = sum_l coeffs[l] theta[perms[l]]``, theta (n, P), P % block_p == 0.

    Args:
      theta: (n, P) stacked flat parameters.
      coeffs: (L,) float32 convex-combination coefficients.
      perms: (L, n) int32; ``perms[l, i] = j`` means node i receives node j's
        parameters in atom l.
    """
    n, P = theta.shape
    L = perms.shape[0]
    if perms.shape != (L, n):
        raise ValueError(f"perms must be (L, n), got {perms.shape} for n={n}")
    if coeffs.shape != (L,):
        raise ValueError(f"coeffs must be ({L},), got {coeffs.shape}")
    if P % block_p != 0:
        raise ValueError(f"P={P} must be a multiple of block_p={block_p}")
    grid = (P // block_p,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # perms + coeffs live in SMEM, prefetched
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_p), lambda p, *prefetch: (0, p)),
        ],
        out_specs=pl.BlockSpec((n, block_p), lambda p, *prefetch: (0, p)),
        scratch_shapes=[pltpu.VMEM((n, block_p), jnp.float32)],
    )
    return pl.pallas_call(
        _gossip_schedule_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, P), theta.dtype),
        interpret=interpret,
    )(perms.astype(jnp.int32), coeffs.astype(jnp.float32), theta)

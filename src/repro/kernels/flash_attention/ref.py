"""Pure-jnp oracle for flash attention (causal / sliding-window, GQA)."""

from __future__ import annotations

import jax.numpy as jnp

_NEG_INF = -2.0e9


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Reference attention. q: (B, S, H, D); k/v: (B, S, Hkv, D).

    Hkv must divide H (GQA). Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    groups = H // Hkv
    qg = q.reshape(B, S, Hkv, groups, D).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * (D**-0.5)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)

"""Jitted public wrapper for flash attention (padding + dispatch).

Pads the head dim to an MXU-aligned multiple of 128 and the sequence to a
multiple of the q/kv block sizes (padded kv positions are masked out by the
causal mask since they sit in the "future"), then calls the Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import (
    DEFAULT_BLOCK_KV,
    DEFAULT_BLOCK_Q,
    flash_attention_pallas,
)
from .ref import flash_attention_ref

__all__ = ["flash_attention"]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv", "interpret", "use_ref"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
    use_ref: bool = False,
) -> jax.Array:
    """Flash attention with GQA. q: (B,S,H,D); k/v: (B,S,Hkv,D)."""
    if use_ref:
        return flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    B, S, H, D = q.shape
    if S < block_q:  # tiny sequences: kernel tiling is pure overhead
        return flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)

    pad_d = (-D) % 128
    pad_s = (-S) % max(block_q, block_kv)
    # NOTE: scale must use the TRUE head dim, not the padded one; the kernel
    # applies D_padded**-0.5, so pre-scale q to compensate.
    if pad_d:
        Dp = D + pad_d
        q = q * ((Dp / D) ** 0.5)  # undo the kernel's padded scaling
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))

    out = flash_attention_pallas(
        q, k, v,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return out[:, :S, :, :D]

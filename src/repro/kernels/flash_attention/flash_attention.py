"""Pallas TPU flash attention (forward): causal / sliding-window, GQA.

Online-softmax tiling (Dao et al., adapted to TPU):

* grid = (B * H, num_q_blocks, num_kv_blocks); the kv axis is the innermost
  ("arbitrary") dimension so the running (m, l, acc) state carries across kv
  steps in VMEM scratch.
* Per grid step the kernel holds one (BQ, D) q tile, one (BKV, D) k tile and
  one (BKV, D) v tile in VMEM; BQ = BKV = 128 and D <= 256 keeps the working
  set < 1 MiB -- far below the ~16 MiB v5e VMEM, leaving room for double
  buffering of the streamed k/v tiles.
* MXU alignment: BQ/BKV are multiples of 128; D is padded to a multiple of
  128 by the ops.py wrapper.
* Causal / window block skipping happens at trace time: out-of-range kv
  blocks are masked entirely (their contribution is exp(-inf) = 0); fully
  in-range blocks skip the mask computation.

GQA is expressed through the k/v BlockSpec index maps: q head ``h`` reads kv
head ``h // (H // Hkv)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
_NEG_INF = -2.0e9


def _fa_kernel(
    q_ref, k_ref, v_ref, out_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, causal: bool, window: int | None, softcap: float,
    block_q: int, block_kv: int, num_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Trace-time reasoning is impossible (qi/ki are dynamic), so compute a
    # cheap runtime block-relevance predicate instead.
    relevant = jnp.asarray(True)
    if causal:
        relevant = relevant & (k_start <= q_start + block_q - 1)
    if window is not None:
        relevant = relevant & (k_start + block_kv - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BKV, D)
        v = v_ref[0].astype(jnp.float32)  # (BKV, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (BQ, BKV)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scratch[...]  # (BQ, 1)
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (BQ, BKV)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        out_ref[0] = (acc_scratch[...] / l_safe).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_kv", "interpret"
    ),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, Hkv, D); S % block == 0, D MXU-aligned."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    groups = H // Hkv
    if S % block_q or S % block_kv:
        raise ValueError(f"S={S} must be divisible by block sizes")
    nq = S // block_q
    nkv = S // block_kv

    # layout: fold heads into the batch grid axis; keep (S, D) per block
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * Hkv + h // groups, ki, 0)

    kernel = functools.partial(
        _fa_kernel,
        scale=D**-0.5,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_kv, D), kv_map),
            pl.BlockSpec((1, block_kv, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)

"""Minimal functional optimizers (SGD / momentum / AdamW) on pytrees."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "Optimizer",
    "OptState",
    "sgd",
    "adamw",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
]


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree | None  # first moment / momentum
    nu: PyTree | None  # second moment (adam only)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def _zeros_like_tree(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    momentum: float = 0.0,
    nesterov: bool = False,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Plain / heavy-ball / Nesterov SGD with optional decoupled weight decay."""

    def lr_at(step):
        return learning_rate(step) if callable(learning_rate) else learning_rate

    def init(params: PyTree) -> OptState:
        mu = _zeros_like_tree(params) if momentum > 0.0 else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(grads: PyTree, state: OptState, params: PyTree):
        lr = lr_at(state.step)
        if weight_decay > 0.0:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params
            )
        if momentum > 0.0:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state.mu, grads
            )
            if nesterov:
                upd = jax.tree_util.tree_map(
                    lambda m, g: -(lr) * (momentum * m + g), mu, grads
                )
            else:
                upd = jax.tree_util.tree_map(lambda m: -(lr) * m, mu)
            return upd, OptState(step=state.step + 1, mu=mu, nu=None)
        upd = jax.tree_util.tree_map(lambda g: -(lr) * g, grads)
        return upd, OptState(step=state.step + 1, mu=None, nu=None)

    return Optimizer(init=init, update=update)


def adamw(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """AdamW with bias correction and decoupled weight decay."""

    def lr_at(step):
        return learning_rate(step) if callable(learning_rate) else learning_rate

    def init(params: PyTree) -> OptState:
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=_zeros_like_tree(params),
            nu=_zeros_like_tree(params),
        )

    def update(grads: PyTree, state: OptState, params: PyTree):
        step = state.step + 1
        lr = lr_at(state.step)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads
        )
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            upd = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay > 0.0:
                upd = upd + weight_decay * p
            return -(lr) * upd

        upd = jax.tree_util.tree_map(u, mu, nu, params)
        return upd, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm

"""Optimizers and LR schedules, written from scratch (optax is unavailable).

API mirrors the optax convention: an optimizer is an ``(init, update)`` pair
where ``update(grads, state, params) -> (updates, state)`` and updates are
*added* to params by ``apply_updates``.
"""

from .optimizers import (
    OptState,
    Optimizer,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    sgd,
)
from .schedule import constant, cosine_decay, linear_warmup_cosine, warmup_constant

__all__ = [
    "OptState",
    "Optimizer",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "sgd",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
    "warmup_constant",
]

"""Learning-rate schedules (callables from step -> lr)."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["constant", "warmup_constant", "cosine_decay", "linear_warmup_cosine"]

Schedule = Callable[[jax.Array], jax.Array]


def constant(value: float) -> Schedule:
    def fn(step):
        return jnp.asarray(value, jnp.float32)

    return fn


def warmup_constant(value: float, warmup_steps: int) -> Schedule:
    def fn(step):
        frac = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        return jnp.asarray(value, jnp.float32) * frac

    return fn


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0) -> Schedule:
    def fn(step):
        frac = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init_value * ((1 - alpha) * cos + alpha)

    return fn


def linear_warmup_cosine(
    peak: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
) -> Schedule:
    def fn(step):
        warm = peak * (step + 1) / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decayed = peak * ((1 - final_frac) * cos + final_frac)
        return jnp.where(step < warmup_steps, warm, decayed).astype(jnp.float32)

    return fn

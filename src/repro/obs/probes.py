"""In-rollout health probes: the paper's quantities as scan DATA.

The theory names exactly which quantities predict convergence, and all
of them can be computed *inside* a compiled rollout as pure value
computations -- no retrace, no host round-trip, a health sample at
EVERY step instead of at eval boundaries only:

* ``consensus``  -- consensus distance ``||Theta - Theta_bar||_F^2``,
  the quantity Lemma 3 controls (and Koloskova et al. show governs
  D-SGD under changing topologies). Computed on the post-mix stacked
  parameters.
* ``grad_dev``   -- per-node gradient deviation
  ``(1/n) sum_i ||g_i - g_bar||^2``, the streaming proxy for
  Assumption 4's H(theta) that the gradient-subspace drift detector
  consumes (``zeta_bar^2`` at the current iterate, cf.
  ``core.heterogeneity.local_heterogeneity``).
* ``tau_bar``    -- Proposition 2's closed-form ``tau_bar^2`` evaluated
  at the LIVE label-histogram estimate Pi_hat and the schedule
  currently in the carry:
  ``K B / n ||W Pi_hat - 1 pibar^T||_F^2 + sigma^2/n ||W - J||_F^2``.
  Both terms come straight off :class:`ScheduleArrays` without ever
  densifying W (see :func:`tau_bar_arrays`), so a topology hot-swap
  or a drifting Pi_hat changes the probe's VALUE, never its trace.

:class:`HealthProbes` is a frozen config selecting which probes a
rollout emits; ``names()`` fixes the output ordering the drivers and
the report pipeline agree on. All probe functions are jnp-traceable
and f32-accumulated; correctness against the host-side reference
implementations in ``core.heterogeneity`` is asserted in
``tests/test_obs.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.mixing import ScheduleArrays

PyTree = Any

__all__ = [
    "HealthProbes",
    "consensus_sq",
    "grad_deviation_sq",
    "mix_pi_arrays",
    "w_frobenius_sq",
    "w_minus_j_frobenius_sq",
    "tau_bar_arrays",
]


@dataclasses.dataclass(frozen=True)
class HealthProbes:
    """Which health quantities a compiled rollout emits per step.

    Frozen and hashable so it can key jit caches / closures safely.
    ``tau_bar`` needs the run to carry a ``ScheduleArrays`` (the
    simulators' online and stale paths; the mesh trainer rejects it --
    its pool transport never materializes W's coefficients in the
    carry) plus a Pi_hat operand and the Prop. 2 constants ``B`` /
    ``sigma2``.
    """

    consensus: bool = True
    grad_dev: bool = True
    tau_bar: bool = False
    B: float = 1.0
    sigma2: float = 0.0

    def __post_init__(self):
        if self.tau_bar and self.B < 0.0:
            raise ValueError(f"B must be >= 0, got {self.B}")
        if self.tau_bar and self.sigma2 < 0.0:
            raise ValueError(f"sigma2 must be >= 0, got {self.sigma2}")
        if not (self.consensus or self.grad_dev or self.tau_bar):
            raise ValueError(
                "HealthProbes with every probe disabled -- pass probes=None "
                "instead of an empty config"
            )

    def names(self) -> tuple[str, ...]:
        """Probe output ordering (the contract between rollout and report)."""
        out = []
        if self.consensus:
            out.append("consensus")
        if self.grad_dev:
            out.append("grad_dev")
        if self.tau_bar:
            out.append("tau_bar")
        return tuple(out)


def consensus_sq(params_stack: PyTree) -> jax.Array:
    """``||Theta - Theta_bar||_F^2`` over node-stacked parameters.

    Same math as ``repro.train.metrics.consensus_distance`` (asserted
    equal in tests); defined here too so ``repro.obs`` stays importable
    below ``repro.train`` in the layering (train imports obs, not the
    reverse).
    """
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(params_stack):
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        total = total + jnp.sum(jnp.square((leaf - mean).astype(jnp.float32)))
    return total


def grad_deviation_sq(grads_stack: PyTree) -> jax.Array:
    """``(1/n) sum_i ||g_i - g_bar||^2`` over node-stacked gradients.

    The in-rollout twin of ``core.heterogeneity.local_heterogeneity``
    (which takes a host-side (n, d) matrix): same quantity, computed on
    a pytree whose leaves carry the node axis first, f32-accumulated.
    """
    leaves = jax.tree_util.tree_leaves(grads_stack)
    n = leaves[0].shape[0]
    total = jnp.zeros((), jnp.float32)
    for leaf in leaves:
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        total = total + jnp.sum(jnp.square((leaf - mean).astype(jnp.float32)))
    return total / n


def mix_pi_arrays(arrays: ScheduleArrays, pi: jax.Array) -> jax.Array:
    """``W @ Pi`` straight from the Birkhoff atoms: ``(n, K)``.

    ``(W Pi)[i, k] = sum_l gamma_l Pi[perms[l, i], k]`` -- L row
    gathers instead of densifying the (n, n) matrix, the same idiom as
    the ``_mix_arrays_flat`` transport. O(L n K).
    """
    pi = pi.astype(jnp.float32)

    def body(acc, atom):
        gamma, perm = atom
        return acc + gamma * jnp.take(pi, perm, axis=0), None

    init = jnp.zeros_like(pi)
    out, _ = jax.lax.scan(
        body, init, (arrays.gammas.astype(jnp.float32), arrays.perms)
    )
    return out


def w_frobenius_sq(arrays: ScheduleArrays) -> jax.Array:
    """``||W||_F^2`` from the atoms: ``g^T E g`` with
    ``E[l, m] = #{i : perms[l, i] == perms[m, i]}``.

    Two atoms' contributions to entry (i, j) collide exactly where
    their permutations agree, so the Frobenius norm is a quadratic
    form in the coefficients over the (l_max, l_max) agreement-count
    matrix. O(L^2 n) -- no (n, n) densification.
    """
    eq = jnp.sum(
        (arrays.perms[:, None, :] == arrays.perms[None, :, :]), axis=-1
    ).astype(jnp.float32)
    g = arrays.gammas.astype(jnp.float32)
    return g @ eq @ g


def w_minus_j_frobenius_sq(arrays: ScheduleArrays) -> jax.Array:
    """``||W - 11^T/n||_F^2 = ||W||_F^2 - 1`` for doubly stochastic W.

    ``<W, J> = (1/n) sum_ij W_ij = 1`` (rows sum to 1) and
    ``||J||_F^2 = 1``, so the cross terms collapse; clamp at 0 against
    float round-off when W is exactly J.
    """
    return jnp.maximum(w_frobenius_sq(arrays) - 1.0, 0.0)


def tau_bar_arrays(
    arrays: ScheduleArrays,
    pi_hat: jax.Array,
    B: float,
    sigma2: float,
) -> jax.Array:
    """Proposition 2's ``tau_bar^2`` at (schedule-in-carry, Pi_hat).

    ``K B / n * sum_{k,i} ((W Pi)_ik - pibar_k)^2
    + sigma^2 / n * ||W - 11^T/n||_F^2``

    -- the traceable twin of ``core.heterogeneity.tau_bar_label_skew``
    (host-side, dense W), evaluated on the data-plane schedule and a
    live label-histogram estimate. Both inputs are values: a refresh
    hot-swap or an updated Pi_hat moves the probe without a retrace.
    """
    pi_hat = pi_hat.astype(jnp.float32)
    n, K = pi_hat.shape
    resid = mix_pi_arrays(arrays, pi_hat) - jnp.mean(
        pi_hat, axis=0, keepdims=True
    )
    bias = jnp.sum(jnp.square(resid)) / n
    return K * B * bias + sigma2 / n * w_minus_j_frobenius_sq(arrays)


def compute_probes(
    probes: HealthProbes,
    *,
    params_stack: PyTree = None,
    grads_stack: PyTree = None,
    arrays: ScheduleArrays | None = None,
    pi_hat: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """Evaluate the enabled probes; returns ``{name: scalar}`` in
    ``probes.names()`` order (dicts preserve insertion order).

    Pure value computation -- safe inside scan bodies. Missing operands
    for an enabled probe raise at trace time (a config error, not a
    runtime one).
    """
    out: dict[str, jax.Array] = {}
    for name in probes.names():
        if name == "consensus":
            if params_stack is None:
                raise ValueError("consensus probe needs params_stack")
            out[name] = consensus_sq(params_stack)
        elif name == "grad_dev":
            if grads_stack is None:
                raise ValueError("grad_dev probe needs grads_stack")
            out[name] = grad_deviation_sq(grads_stack)
        elif name == "tau_bar":
            if arrays is None or pi_hat is None:
                raise ValueError(
                    "tau_bar probe needs the in-carry ScheduleArrays and a "
                    "pi_hat operand"
                )
            out[name] = tau_bar_arrays(arrays, pi_hat, probes.B, probes.sigma2)
    return out


__all__.append("compute_probes")

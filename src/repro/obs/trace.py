"""Span tracing: where did a segment's wall time go?

A :class:`Tracer` records *spans* -- named wall-clock intervals with
nesting -- from any thread (the overlapped refresh solve runs on a
worker; its spans land in the same trace with their own thread id).
Three sinks, all cheap enough to leave on in production runs:

* a bounded in-memory ring (``capacity`` completed spans; overflow
  drops the OLDEST spans and counts them in ``dropped``, so a long run
  can keep a tracer attached without unbounded memory),
* an optional append-only JSONL file (``sink_path``): every completed
  span is written immediately, so the on-disk trace is complete even
  when the ring has wrapped, and survives a crash mid-run,
* a Chrome/Perfetto trace-event export (:meth:`to_perfetto` /
  :meth:`write_perfetto`): load the JSON in ``chrome://tracing`` or
  https://ui.perfetto.dev and see the rollout, the overlapped solve,
  the restage, and the checkpoint on one timeline.

Clocks are monotonic (``time.perf_counter``): span durations are
immune to wall-clock adjustments, and all spans of one tracer share a
single origin so they compose into one timeline. ``wall_unix`` on each
record anchors that timeline to the epoch once, at tracer creation.

Usage::

    tracer = Tracer(sink_path="trace.jsonl")
    with tracer.span("segment.rollout", t0=0, k=64):
        ...
        with tracer.span("segment.checkpoint"):
            ...
    tracer.instant("refresh.submit", t=63)
    tracer.write_perfetto("trace_perfetto.json")

Spans nest per-thread: the ``depth`` and ``parent`` fields record the
enclosing span at *entry* time, and the ring orders records by
*completion* (the parent closes after its children -- the Perfetto
"X" events reconstruct the nesting from timestamps, which is why the
exporter never needs the parent pointers).
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from contextlib import contextmanager
from collections import deque

__all__ = ["SpanRecord", "Tracer", "read_jsonl"]


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span (or instant event, where ``t1 == t0``).

    ``t0``/``t1`` are seconds on the tracer's monotonic clock (shared
    origin across threads); ``wall_unix`` is the epoch time of that
    origin, so ``wall_unix + t0`` is an absolute timestamp.
    """

    name: str
    t0: float
    t1: float
    tid: int
    depth: int
    parent: str | None
    attrs: dict
    wall_unix: float

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "tid": self.tid,
            "depth": self.depth,
            "parent": self.parent,
            "attrs": self.attrs,
            "wall_unix": self.wall_unix,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SpanRecord":
        return cls(
            name=str(d["name"]),
            t0=float(d["t0"]),
            t1=float(d["t1"]),
            tid=int(d["tid"]),
            depth=int(d["depth"]),
            parent=d.get("parent"),
            attrs=dict(d.get("attrs") or {}),
            wall_unix=float(d.get("wall_unix", 0.0)),
        )


def _json_default(x):
    # attrs may carry numpy scalars / 0-d arrays from instrumented code;
    # coerce instead of crashing the sink mid-run
    try:
        return x.item()
    except AttributeError:
        return repr(x)


def read_jsonl(path: str) -> list[SpanRecord]:
    """Load a JSONL span sink back into records (the round-trip half)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(SpanRecord.from_dict(json.loads(line)))
    return out


class Tracer:
    """Thread-safe span recorder with a bounded ring and optional sinks.

    Args:
      capacity: max completed spans held in memory. Overflow evicts the
        oldest records (counted in :attr:`dropped`); the JSONL sink, if
        configured, still holds everything.
      sink_path: append-mode JSONL file; one completed span per line,
        flushed per span (crash-honest).
      enabled: ``Tracer(enabled=False)`` is a no-op recorder -- every
        ``span()`` still runs its body, nothing is stored. Lets
        instrumented code take an always-on ``tracer`` argument with a
        disabled default instead of ``if tracer is not None`` forests.
    """

    def __init__(
        self,
        capacity: int = 4096,
        sink_path: str | None = None,
        enabled: bool = True,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.dropped = 0
        self._ring: deque[SpanRecord] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        # one shared origin: all threads' spans land on one timeline
        self._origin = time.perf_counter()
        self._wall_unix = time.time()
        self._sink = None
        self.sink_path = sink_path
        if sink_path is not None and self.enabled:
            os.makedirs(os.path.dirname(os.path.abspath(sink_path)), exist_ok=True)
            self._sink = open(sink_path, "a")

    # -- recording ----------------------------------------------------------

    def _stack(self) -> list[str]:
        if not hasattr(self._local, "stack"):
            self._local.stack = []
        return self._local.stack

    def _now(self) -> float:
        return time.perf_counter() - self._origin

    def _commit(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(rec)
            if self._sink is not None:
                self._sink.write(
                    json.dumps(rec.to_dict(), default=_json_default) + "\n"
                )
                self._sink.flush()

    @contextmanager
    def span(self, name: str, **attrs):
        """Record ``name`` around the with-body. Exceptions propagate;
        the span still completes (with ``attrs["error"]`` set)."""
        if not self.enabled:
            yield self
            return
        stack = self._stack()
        parent = stack[-1] if stack else None
        depth = len(stack)
        stack.append(name)
        t0 = self._now()
        try:
            yield self
        except BaseException as exc:
            attrs = dict(attrs)
            attrs["error"] = repr(exc)
            raise
        finally:
            stack.pop()
            self._commit(SpanRecord(
                name=name, t0=t0, t1=self._now(),
                tid=threading.get_ident(), depth=depth, parent=parent,
                attrs=dict(attrs), wall_unix=self._wall_unix,
            ))

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration event (submit/abandon markers)."""
        if not self.enabled:
            return
        stack = self._stack()
        t = self._now()
        self._commit(SpanRecord(
            name=name, t0=t, t1=t,
            tid=threading.get_ident(), depth=len(stack),
            parent=stack[-1] if stack else None,
            attrs=dict(attrs), wall_unix=self._wall_unix,
        ))

    # -- views / export -----------------------------------------------------

    def spans(self, name: str | None = None) -> list[SpanRecord]:
        """Ring contents in completion order (oldest first); optionally
        filtered by exact name."""
        with self._lock:
            recs = list(self._ring)
        if name is not None:
            recs = [r for r in recs if r.name == name]
        return recs

    def total_s(self, name: str) -> float:
        """Summed duration of all in-ring spans named ``name``."""
        return sum(r.duration_s for r in self.spans(name))

    def summary(self) -> dict:
        """Per-name count/total seconds (the run report's span table)."""
        table: dict[str, dict] = {}
        for r in self.spans():
            row = table.setdefault(r.name, {"count": 0, "total_s": 0.0})
            row["count"] += 1
            row["total_s"] += r.duration_s
        return {
            "capacity": self.capacity,
            "dropped": self.dropped,
            "recorded": len(self.spans()),
            "by_name": table,
        }

    def to_perfetto(self) -> list[dict]:
        """Chrome trace-event list (``ph: "X"`` complete events, us).

        Instants become ``ph: "i"`` thread-scoped events. One metadata
        event per thread names it by its first span. Load the dumped
        JSON array in chrome://tracing or ui.perfetto.dev.
        """
        events: list[dict] = []
        named_tids: set[int] = set()
        for r in self.spans():
            if r.tid not in named_tids:
                named_tids.add(r.tid)
                events.append({
                    "ph": "M", "pid": 1, "tid": r.tid,
                    "name": "thread_name",
                    "args": {"name": f"thread-{r.tid % 100000}"},
                })
            base = {
                "name": r.name, "pid": 1, "tid": r.tid,
                "ts": r.t0 * 1e6, "cat": "repro",
                "args": dict(r.attrs),
            }
            if r.t1 == r.t0:
                events.append({**base, "ph": "i", "s": "t"})
            else:
                events.append({**base, "ph": "X", "dur": r.duration_s * 1e6})
        return events

    def write_perfetto(self, path: str) -> str:
        events = self.to_perfetto()
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(events, f, default=_json_default)
        return path

    def write_jsonl(self, path: str) -> str:
        """Dump the ring to a JSONL file (distinct from the live sink:
        this is a one-shot export of what is currently in memory)."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            for r in self.spans():
                f.write(json.dumps(r.to_dict(), default=_json_default) + "\n")
        return path

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Unified run telemetry: span tracing, in-rollout health probes, and
run reports.

Three layers (see ``docs/observability.md``):

* :mod:`repro.obs.trace`  -- :class:`Tracer`: nestable wall-clock spans
  on monotonic clocks, a bounded in-memory ring + JSONL sink, and a
  Chrome/Perfetto trace-event exporter. Threaded through the segment
  drivers, the online refresh controller, the fault injector, and the
  benchmark harness.
* :mod:`repro.obs.probes` -- :class:`HealthProbes`: the paper's
  convergence-predicting quantities (consensus distance, Assumption-4
  gradient deviation, Prop. 2 tau_bar at the live Pi_hat) computed
  INSIDE compiled rollouts as pure value computations -- zero retraces,
  a sample every step.
* :mod:`repro.obs.report` -- :class:`RunReport` (one versioned
  JSON/markdown document aggregating metrics, byte fates, events,
  health series, spans, and compiles) and :class:`RetraceGuard` (the
  first-class jit cache-miss counter behind the repo-wide
  "retraces == 0" invariant).
"""

from .probes import (
    HealthProbes,
    compute_probes,
    consensus_sq,
    grad_deviation_sq,
    mix_pi_arrays,
    tau_bar_arrays,
    w_frobenius_sq,
    w_minus_j_frobenius_sq,
)
from .report import (
    REPORT_SCHEMA,
    RetraceGuard,
    RunReport,
    load_report,
    validate_report,
)
from .trace import SpanRecord, Tracer, read_jsonl

__all__ = [
    "Tracer",
    "SpanRecord",
    "read_jsonl",
    "HealthProbes",
    "compute_probes",
    "consensus_sq",
    "grad_deviation_sq",
    "mix_pi_arrays",
    "tau_bar_arrays",
    "w_frobenius_sq",
    "w_minus_j_frobenius_sq",
    "RunReport",
    "RetraceGuard",
    "REPORT_SCHEMA",
    "validate_report",
    "load_report",
]

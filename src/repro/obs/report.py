"""Run reports and the retrace guard.

Two pieces the rest of the repo reports through:

* :class:`RetraceGuard` -- a first-class jit cache-miss counter. Every
  driver and bench in this repo re-implements the same bookkeeping (a
  ``nonlocal n_traces`` bumped inside a jitted wrapper's Python body)
  to assert the load-bearing invariant: schedule hot-swaps, staleness,
  compression, and health probes are all VALUE changes, so a compiled
  rollout traces exactly once. The guard centralizes that: ``wrap`` a
  function before jitting (or hand it an already-scanned body),
  declare how many compiles you *expect* per name, and ``excess()``
  is the number of unexplained retraces -- the quantity that must be
  zero in CI.

* :class:`RunReport` -- one registry that aggregates what a run
  produced: the ``MetricLogger`` history, ``CommMeter`` byte fates,
  refresh / fault / staleness events, health-probe series, tracer
  span summaries, and the retrace-guard table, into a versioned JSON
  document (``repro.run_report/v1``) plus a human-readable markdown
  rendering. ``benchmarks/run.py --smoke`` emits one and CI validates
  it with :func:`validate_report`.
"""

from __future__ import annotations

import json
import os
from typing import Any, Callable

import numpy as np

__all__ = [
    "RetraceGuard",
    "RunReport",
    "REPORT_SCHEMA",
    "validate_report",
]

REPORT_SCHEMA = "repro.run_report/v1"


class RetraceGuard:
    """Counts XLA compiles per named function and audits them.

    ``wrap(fn, name)`` returns a function whose *Python body* bumps the
    counter and calls ``fn`` -- jit the wrapper (not ``fn``) and every
    cache miss executes the body once, so ``counts[name]`` is exactly
    the number of traces. This generalizes the ``nonlocal n_traces``
    idiom scattered through the drivers; ``record(name)`` serves code
    that already has a counting site and just wants the ledger.

    ``expect(name, n)`` declares the compile budget (usually 1 per
    distinct rollout shape); ``excess()`` sums traces beyond budget --
    the number that must be 0 for the hot-swap invariant to hold.
    Names never expected (pure ``record`` streams) budget at their
    first-seen count only if declared; undeclared names count fully
    toward ``total()`` but not ``excess()`` -- budget what you audit.
    """

    def __init__(self):
        self.counts: dict[str, int] = {}
        self.expected: dict[str, int] = {}

    def record(self, name: str, k: int = 1) -> None:
        self.counts[name] = self.counts.get(name, 0) + int(k)

    def wrap(self, fn: Callable, name: str) -> Callable:
        """Return ``fn`` with a trace-counting Python body; jit the result."""

        def counted(*args, **kwargs):
            self.record(name)
            return fn(*args, **kwargs)

        counted.__name__ = getattr(fn, "__name__", name)
        return counted

    def expect(self, name: str, n: int = 1) -> None:
        """Declare that ``name`` is budgeted ``n`` compiles."""
        self.expected[name] = int(n)

    def count(self, name: str) -> int:
        return self.counts.get(name, 0)

    def total(self) -> int:
        return sum(self.counts.values())

    def excess(self) -> int:
        """Traces beyond budget across all *declared* names (>= 0 each)."""
        return sum(
            max(self.counts.get(name, 0) - n, 0)
            for name, n in self.expected.items()
        )

    def snapshot(self) -> dict:
        return {
            "counts": dict(self.counts),
            "expected": dict(self.expected),
            "total": self.total(),
            "excess": self.excess(),
        }


def _scrub(x: Any) -> Any:
    """Make a nested structure json.dump-safe (numpy/jax scalars, arrays)."""
    if isinstance(x, dict):
        return {str(k): _scrub(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_scrub(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if isinstance(x, np.ndarray):
        return _scrub(x.tolist())
    item = getattr(x, "item", None)
    if callable(item):
        try:
            return _scrub(item())
        except (TypeError, ValueError):
            pass
    return repr(x)


class RunReport:
    """Aggregates one run's telemetry into a versioned JSON/markdown doc.

    Feed it whatever the run produced -- every section is optional --
    then ``write(dir)`` for the artifact pair (``run_report.json`` +
    ``run_report.md``). The JSON always carries ``schema`` and
    ``meta``; :func:`validate_report` checks the structural contract
    CI relies on.
    """

    def __init__(self, name: str, **meta):
        self.name = str(name)
        self.meta = _scrub(dict(meta))
        self._metrics: list[dict] = []
        self._metrics_aux: dict = {}
        self._comm: dict | None = None
        self._events: dict[str, list] = {}
        self._health: dict[str, list] = {}
        self._spans: dict | None = None
        self._retraces: dict | None = None
        self._quarantine: dict | None = None

    # -- ingestion (each accepts the repo's native object OR plain data) ----

    def add_metrics(self, logger) -> "RunReport":
        """A ``MetricLogger`` (or any object with .history/.aux)."""
        self._metrics = _scrub(list(logger.history))
        self._metrics_aux = _scrub(dict(logger.aux))
        return self

    def add_comm(self, meter) -> "RunReport":
        """A ``CommMeter`` (or any object with .summary() -> dict)."""
        self._comm = _scrub(meter.summary())
        return self

    def add_events(self, kind: str, events) -> "RunReport":
        """Append refresh/fault/staleness event dicts under ``kind``."""
        self._events.setdefault(str(kind), []).extend(_scrub(list(events)))
        return self

    def add_health(self, series: dict) -> "RunReport":
        """Per-probe value series, e.g. ``{"consensus": [...], ...}``."""
        for k, v in series.items():
            self._health.setdefault(str(k), []).extend(
                _scrub(np.asarray(v).reshape(-1).tolist())
            )
        return self

    def add_spans(self, tracer) -> "RunReport":
        """A ``Tracer`` -- stores its per-name summary, not raw spans
        (the raw trace ships as its own JSONL artifact)."""
        self._spans = _scrub(tracer.summary())
        return self

    def add_retraces(self, guard: RetraceGuard) -> "RunReport":
        self._retraces = guard.snapshot()
        return self

    def add_quarantine(self, summary: dict) -> "RunReport":
        """A ``QuarantineController.summary()`` dict (or plain data).

        Stored as its own versioned block: the section is OPTIONAL in
        the ``repro.run_report/v1`` document (absent = the run had no
        corruption defense -- every pre-existing report stays valid),
        and when present it carries its own ``version`` tag so the
        block can evolve without bumping the whole report schema.
        """
        s = _scrub(dict(summary))
        self._quarantine = {
            "version": 1,
            "n_quarantines": int(s.get("n_quarantines", 0)),
            "n_readmissions": int(s.get("n_readmissions", 0)),
            "quarantined_now": list(s.get("quarantined_now", [])),
            "events": list(s.get("events", [])),
        }
        return self

    # -- emission -----------------------------------------------------------

    def to_dict(self) -> dict:
        doc = {
            "schema": REPORT_SCHEMA,
            "name": self.name,
            "meta": self.meta,
            "metrics": {"history": self._metrics, "aux": self._metrics_aux},
            "comm": self._comm,
            "events": self._events,
            "health": self._health,
            "spans": self._spans,
            "retraces": self._retraces,
        }
        # optional block: only emitted when a defense actually ran, so
        # documents round-trip byte-compatibly with pre-quarantine readers
        if self._quarantine is not None:
            doc["quarantine"] = self._quarantine
        return doc

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    def to_markdown(self) -> str:
        d = self.to_dict()
        lines = [f"# Run report: {self.name}", ""]
        if self.meta:
            lines.append("## Meta")
            for k, v in sorted(self.meta.items()):
                lines.append(f"- **{k}**: {v}")
            lines.append("")
        if self._retraces is not None:
            r = self._retraces
            lines += [
                "## Retraces",
                f"- total compiles: {r['total']}  |  "
                f"excess beyond budget: **{r['excess']}**",
            ]
            for name in sorted(r["counts"]):
                exp = r["expected"].get(name)
                budget = f" (expected {exp})" if exp is not None else ""
                lines.append(f"- `{name}`: {r['counts'][name]}{budget}")
            lines.append("")
        if self._comm is not None:
            c = self._comm
            lines += [
                "## Communication",
                "| fate | bytes |",
                "|---|---|",
                f"| delivered | {c.get('total_bytes', 0)} |",
                f"| dropped | {c.get('dropped_bytes', 0)} |",
                f"| deferred (late, subset of delivered) | "
                f"{c.get('deferred_bytes', 0)} |",
                f"| quarantined (isolated, subset of delivered) | "
                f"{c.get('quarantined_bytes', 0)} |",
                f"| retransmitted | {c.get('retransmit_bytes', 0)} |",
                "",
                f"{c.get('steps', 0)} steps at {c.get('per_step_bytes', 0)} "
                f"bytes/node/step.",
                "",
            ]
        if self._health:
            lines += ["## Health series", "| probe | points | last | max |",
                      "|---|---|---|---|"]
            for k in sorted(self._health):
                v = self._health[k]
                last = f"{v[-1]:.6g}" if v else "-"
                vmax = f"{max(v):.6g}" if v else "-"
                lines.append(f"| {k} | {len(v)} | {last} | {vmax} |")
            lines.append("")
        if self._spans is not None:
            lines += [
                "## Spans",
                f"{self._spans.get('recorded', 0)} recorded, "
                f"{self._spans.get('dropped', 0)} dropped from the ring.",
                "| span | count | total s |",
                "|---|---|---|",
            ]
            by = self._spans.get("by_name", {})
            for k in sorted(by):
                lines.append(
                    f"| `{k}` | {by[k]['count']} | {by[k]['total_s']:.4f} |"
                )
            lines.append("")
        if self._quarantine is not None:
            q = self._quarantine
            lines += [
                "## Quarantine",
                f"- quarantines: {q['n_quarantines']}  |  re-admissions: "
                f"{q['n_readmissions']}  |  isolated at end: "
                f"{q['quarantined_now'] or 'none'}",
                f"- {len(q['events'])} lifecycle events",
                "",
            ]
        if self._events:
            lines.append("## Events")
            for kind in sorted(self._events):
                lines.append(f"- **{kind}**: {len(self._events[kind])} events")
            lines.append("")
        if self._metrics:
            lines += [
                "## Metrics",
                f"{len(self._metrics)} logged rows; aux keys: "
                f"{sorted(self._metrics_aux) or 'none'}.",
                "",
            ]
        return "\n".join(lines)

    def write(self, out_dir: str, stem: str = "run_report") -> dict[str, str]:
        """Write ``<stem>.json`` + ``<stem>.md`` into ``out_dir``."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {
            "json": os.path.join(out_dir, f"{stem}.json"),
            "md": os.path.join(out_dir, f"{stem}.md"),
        }
        with open(paths["json"], "w") as f:
            f.write(self.to_json() + "\n")
        with open(paths["md"], "w") as f:
            f.write(self.to_markdown() + "\n")
        return paths


def validate_report(doc: dict) -> None:
    """Structural validation of a run-report dict; raises ValueError.

    The contract CI enforces on the smoke artifact: schema tag, name,
    all sections present with the right container types, health series
    all-finite floats, and the retrace table internally consistent.
    """
    if not isinstance(doc, dict):
        raise ValueError(f"report must be a dict, got {type(doc).__name__}")
    if doc.get("schema") != REPORT_SCHEMA:
        raise ValueError(
            f"schema mismatch: expected {REPORT_SCHEMA!r}, "
            f"got {doc.get('schema')!r}"
        )
    if not isinstance(doc.get("name"), str) or not doc["name"]:
        raise ValueError("report.name must be a non-empty string")
    for key, typ in [
        ("meta", dict), ("metrics", dict), ("events", dict), ("health", dict),
    ]:
        if not isinstance(doc.get(key), typ):
            raise ValueError(f"report.{key} must be a {typ.__name__}")
    m = doc["metrics"]
    if not isinstance(m.get("history"), list) or not isinstance(
        m.get("aux"), dict
    ):
        raise ValueError("report.metrics needs 'history' list and 'aux' dict")
    for kind, events in doc["events"].items():
        if not isinstance(events, list):
            raise ValueError(f"report.events[{kind!r}] must be a list")
    for probe, series in doc["health"].items():
        if not isinstance(series, list):
            raise ValueError(f"report.health[{probe!r}] must be a list")
        arr = np.asarray(series, dtype=np.float64)
        if arr.size and not np.all(np.isfinite(arr)):
            raise ValueError(f"report.health[{probe!r}] has non-finite values")
    comm = doc.get("comm")
    if comm is not None:
        for k in ("total_bytes", "dropped_bytes", "deferred_bytes", "steps"):
            if not isinstance(comm.get(k), int) or comm[k] < 0:
                raise ValueError(f"report.comm[{k!r}] must be a non-neg int")
        if comm["deferred_bytes"] > comm["total_bytes"]:
            raise ValueError(
                "report.comm: deferred_bytes exceeds total_bytes (deferred "
                "is a subset of delivered)"
            )
        # optional fate -- absent in pre-quarantine reports
        qb = comm.get("quarantined_bytes")
        if qb is not None:
            if not isinstance(qb, int) or qb < 0:
                raise ValueError(
                    "report.comm['quarantined_bytes'] must be a non-neg int"
                )
            if qb > comm["total_bytes"]:
                raise ValueError(
                    "report.comm: quarantined_bytes exceeds total_bytes "
                    "(quarantined is a subset of delivered)"
                )
    spans = doc.get("spans")
    if spans is not None:
        if not isinstance(spans.get("by_name"), dict):
            raise ValueError("report.spans.by_name must be a dict")
        for name, row in spans["by_name"].items():
            if not (isinstance(row.get("count"), int) and row["count"] >= 1):
                raise ValueError(f"report.spans.by_name[{name!r}] bad count")
            if not (
                isinstance(row.get("total_s"), (int, float))
                and row["total_s"] >= 0.0
            ):
                raise ValueError(f"report.spans.by_name[{name!r}] bad total_s")
    rt = doc.get("retraces")
    if rt is not None:
        for k in ("counts", "expected"):
            if not isinstance(rt.get(k), dict):
                raise ValueError(f"report.retraces[{k!r}] must be a dict")
        if rt.get("total") != sum(rt["counts"].values()):
            raise ValueError("report.retraces.total inconsistent with counts")
        excess = sum(
            max(rt["counts"].get(name, 0) - n, 0)
            for name, n in rt["expected"].items()
        )
        if rt.get("excess") != excess:
            raise ValueError("report.retraces.excess inconsistent")
    # OPTIONAL versioned block: absent in every pre-quarantine report
    # (PR 9 documents validate unchanged); when present, checked fully
    q = doc.get("quarantine")
    if q is not None:
        if not isinstance(q, dict):
            raise ValueError("report.quarantine must be a dict")
        if not isinstance(q.get("version"), int) or q["version"] < 1:
            raise ValueError("report.quarantine.version must be an int >= 1")
        for k in ("n_quarantines", "n_readmissions"):
            if not isinstance(q.get(k), int) or q[k] < 0:
                raise ValueError(f"report.quarantine[{k!r}] must be a non-neg int")
        if not isinstance(q.get("events"), list):
            raise ValueError("report.quarantine.events must be a list")
        for ev in q["events"]:
            if not isinstance(ev, dict) or "t" not in ev or "node" not in ev:
                raise ValueError(
                    "report.quarantine.events entries need 't' and 'node'"
                )
            if ev.get("event") not in ("quarantine", "probation", "readmitted"):
                raise ValueError(
                    f"report.quarantine.events: unknown event {ev.get('event')!r}"
                )


def load_report(path: str) -> dict:
    """Read + validate a run-report JSON file."""
    with open(path) as f:
        doc = json.load(f)
    validate_report(doc)
    return doc

"""Checkpointing: msgpack-serialized pytrees with a manifest.

Layout of a checkpoint directory::

    <dir>/
      manifest.json       # step, tree structure, shapes/dtypes, metadata
      arrays.msgpack      # flat list of raw array buffers

In ``dsgd`` mode the trainer checkpoints the stacked per-node parameters, so
a single checkpoint holds every node's replica (restorable onto a different
node count only through explicit re-mixing, which we deliberately do not do
silently).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import jax
import msgpack
import numpy as np

PyTree = Any

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.msgpack"


def _tree_paths(tree: PyTree) -> list[str]:
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(tree)[0]) if jax.tree_util.tree_leaves(tree) else ((), None)
    return [jax.tree_util.keystr(p) for p in paths]


def save_checkpoint(directory: str, step: int, tree: PyTree, metadata: dict | None = None) -> str:
    """Write ``tree`` under ``directory/step_<step>``; returns the path."""
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(kp) for kp, _ in leaves_with_paths]
    leaves = [np.asarray(leaf) for _, leaf in leaves_with_paths]
    manifest = {
        "step": step,
        "keys": keys,
        "shapes": [list(x.shape) for x in leaves],
        "dtypes": [str(x.dtype) for x in leaves],
        "treedef": str(treedef),
        "metadata": metadata or {},
    }
    packed = msgpack.packb([x.tobytes() for x in leaves], use_bin_type=True)
    with open(os.path.join(path, _ARRAYS), "wb") as f:
        f.write(packed)
    with open(os.path.join(path, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    return path


def restore_checkpoint(directory: str, step: int, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with open(os.path.join(path, _ARRAYS), "rb") as f:
        raw = msgpack.unpackb(f.read(), raw=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(raw) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(raw)} leaves, template has {len(leaves_like)}"
        )
    leaves = []
    for buf, shape, dtype, tmpl in zip(raw, manifest["shapes"], manifest["dtypes"], leaves_like):
        arr = np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape)
        t_shape = tuple(np.shape(tmpl))
        if t_shape != tuple(shape):
            raise ValueError(f"shape mismatch: checkpoint {shape} vs template {t_shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_")
    ]
    return max(steps) if steps else None


@dataclasses.dataclass
class CheckpointManager:
    """Keeps the most recent ``max_to_keep`` checkpoints."""

    directory: str
    max_to_keep: int = 3

    def save(self, step: int, tree: PyTree, metadata: dict | None = None) -> str:
        path = save_checkpoint(self.directory, step, tree, metadata)
        self._gc()
        return path

    def restore_latest(self, like: PyTree) -> tuple[int, PyTree, dict] | None:
        step = latest_step(self.directory)
        if step is None:
            return None
        tree, meta = restore_checkpoint(self.directory, step, like)
        return step, tree, meta

    def _gc(self) -> None:
        steps = sorted(
            int(name.split("_")[1])
            for name in os.listdir(self.directory)
            if name.startswith("step_")
        )
        for s in steps[: -self.max_to_keep]:
            p = os.path.join(self.directory, f"step_{s:08d}")
            for fn in os.listdir(p):
                os.remove(os.path.join(p, fn))
            os.rmdir(p)

"""Training: n-node D-SGD simulator + mesh-sharded LM trainer + utilities."""

from . import checkpoints, lm_trainer, metrics, sharding, trainer
from .checkpoints import CheckpointManager, restore_checkpoint, save_checkpoint
from .lm_trainer import TrainSetup, make_train_setup
from .metrics import MetricLogger, consensus_distance, node_spread
from .trainer import run_classification, run_mean_estimation

__all__ = [
    "checkpoints",
    "lm_trainer",
    "metrics",
    "sharding",
    "trainer",
    "CheckpointManager",
    "restore_checkpoint",
    "save_checkpoint",
    "TrainSetup",
    "make_train_setup",
    "MetricLogger",
    "consensus_distance",
    "node_spread",
    "run_classification",
    "run_mean_estimation",
]

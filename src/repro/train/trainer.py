"""n-node D-SGD simulator (the paper's experimental rig).

Simulates Algorithm 1 exactly on a single device: per-node parameters are
stacked on a leading node axis, local gradients are computed with
``vmap(grad)``, and the mixing step runs through any stacked transport
(dense ``Theta W^T``, the sparse Birkhoff gather schedule, or the Pallas
gossip kernels). This reproduces the paper's n=100 experiments bit-for-bit
up to RNG.

Rollout compilation: by default each driver compiles the whole multi-step
rollout between eval points with ``jax.lax.scan`` (``rollout="scan"``), so
there is no per-step dispatch and no ``float(loss)`` host round-trip inside
the hot loop -- error/loss traces are accumulated on device and fetched once
per segment. ``rollout="loop"`` keeps the step-by-step Python loop (same
jitted step function, bit-identical trajectories) for debugging and A/B
benchmarking.

Two ready-made drivers:
* ``run_mean_estimation`` -- Section 6.1 / Example 1 quadratic task, with
  closed-form error tracking against theta*.
* ``run_classification``  -- Section 6.2-style label-skew classification
  (linear model or MLP) on a partitioned synthetic dataset.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import ef_init, ef_stale_mix_flat, make_compressor
from repro.core.dsgd import DSGDState, dsgd_init, dsgd_step_stacked
from repro.core.mixing import (
    BirkhoffSchedule,
    ScheduleArrays,
    StragglerPolicy,
    mix_schedule_arrays_stale,
    ravel_stack,
    stale_buffer_init,
    stale_push,
    straggler_stream,
    unravel_stack,
)
from repro.data.synthetic import MeanEstimationTask
from repro.obs.probes import HealthProbes, compute_probes
from repro.obs.trace import Tracer
from .metrics import (
    CommMeter,
    MetricLogger,
    consensus_distance,
    mix_bytes_per_step,
    staleness_transfer_fracs,
)

# instrumented code paths take an always-on tracer (span() bodies still
# run); callers opt in by passing a real one
_NULL_TRACER = Tracer(enabled=False)


def _online_comm_meter(
    n_nodes: int, params_per_node: int, compression=None
) -> CommMeter:
    """Modeled comm meter for a data-plane (hot-swappable) schedule.

    The simulator runs on one host, so these are the bytes the SAME
    run would move on a device mesh: the ``ScheduleArrays`` transport
    there is the all-gather (``mix_arrays_sharded``) -- ``(n-1) P``
    received per node per step -- until a ``PermPool`` trainer brings
    it down to the staged slot count (``lm_trainer.run_segments``
    meters that case from its own transport). ``compression`` swaps in
    the compressed wire layout (``(n-1) x wire_bytes(P)``).
    """
    return CommMeter(per_step_bytes=mix_bytes_per_step(
        "allgather", n_nodes=n_nodes, p_total=params_per_node,
        compression=compression,
    ))

PyTree = Any

__all__ = [
    "run_mean_estimation",
    "init_linear_classifier",
    "init_mlp_classifier",
    "classifier_loss",
    "classifier_accuracy",
    "run_classification",
]


def _check_staleness_args(staleness, delays, steps, n, online, rollout):
    """Validate + normalize the (staleness, delays) pair shared by both
    simulator drivers. Returns the (steps, n) int32 raw-delay trace, or
    None when no policy is given."""
    if staleness is None:
        if delays is not None:
            raise ValueError(
                "delays without staleness: pass a StragglerPolicy to say "
                "how the delay trace should be consumed (wait vs degrade)"
            )
        return None
    if not isinstance(staleness, StragglerPolicy):
        raise TypeError(
            f"staleness must be a StragglerPolicy, got {type(staleness).__name__}"
        )
    if not online:
        raise ValueError(
            "staleness rides the retrace-free data plane: pass the "
            "schedule as ScheduleArrays (a static schedule cannot carry "
            "the ring buffer / per-step delay data)"
        )
    if rollout != "scan":
        raise ValueError(
            "staleness needs rollout='scan': the per-step schedule and "
            "delay vectors travel as scan xs"
        )
    if delays is None:
        delays = np.zeros((steps, n), np.int32)
    delays = np.asarray(delays)
    if delays.shape != (steps, n):
        raise ValueError(
            f"delays must be (steps={steps}, n={n}), got {delays.shape}"
        )
    if delays.size and delays.min() < 0:
        raise ValueError("delays must be non-negative")
    return delays.astype(np.int32)


def _check_probe_args(probes, pi_hat, n, online, rollout, staleness):
    """Validate the (probes, pi_hat) pair shared by both simulator
    drivers; returns pi_hat as a device f32 array (or None)."""
    if probes is None:
        if pi_hat is not None:
            raise ValueError(
                "pi_hat without probes: pass HealthProbes(tau_bar=True) to "
                "say what the estimate is for"
            )
        return None
    if not isinstance(probes, HealthProbes):
        raise TypeError(
            f"probes must be a HealthProbes, got {type(probes).__name__}"
        )
    if not online:
        raise ValueError(
            "health probes ride the retrace-free data plane: pass the "
            "schedule as ScheduleArrays (probe values are per-step scan "
            "outputs of the compiled rollout)"
        )
    if rollout != "scan":
        raise ValueError(
            "health probes need rollout='scan': per-step probe values come "
            "back as scan outputs, not per-dispatch host reads"
        )
    if staleness is not None:
        raise ValueError(
            "health probes under bounded-delay gossip are not supported "
            "yet: run probes on the fresh online path, or sample at eval "
            "boundaries under staleness"
        )
    if probes.tau_bar:
        if pi_hat is None:
            raise ValueError(
                "HealthProbes(tau_bar=True) needs pi_hat: the live (n, K) "
                "label-histogram estimate the Prop. 2 proxy is evaluated at"
            )
        pi_hat = jnp.asarray(pi_hat, jnp.float32)
        if pi_hat.ndim != 2 or pi_hat.shape[0] != n:
            raise ValueError(
                f"pi_hat must be (n={n}, K), got {tuple(pi_hat.shape)}"
            )
        return pi_hat
    if pi_hat is not None:
        raise ValueError("pi_hat given but probes.tau_bar is off")
    return None


def _live_pi_hat(on_segment, current):
    """Snapshot the hook's live Pi estimate (an OnlineTopologyController
    exposes ``.estimator.Pi_hat``), so the tau_bar probe tracks the
    estimate as a per-segment VALUE change; hooks without an estimator
    keep the caller-provided pi_hat."""
    est = getattr(on_segment, "estimator", None)
    live = getattr(est, "Pi_hat", None) if est is not None else None
    return current if live is None else jnp.asarray(live, jnp.float32)


def _staleness_meter_fracs(delays, staleness) -> tuple[float, float]:
    """Mean (delivered_frac, deferred_frac) over a (k, n) delay window --
    the :meth:`CommMeter.tick` pair, from the closed-form model."""
    fates = [
        staleness_transfer_fracs(row, staleness.tau_max, staleness.mode)
        for row in np.asarray(delays)
    ]
    on_time = float(np.mean([f[0] for f in fates])) if fates else 1.0
    deferred = float(np.mean([f[1] for f in fates])) if fates else 0.0
    return on_time + deferred, deferred


# ---------------------------------------------------------------------------
# Section 6.1: decentralized mean estimation
# ---------------------------------------------------------------------------

def run_mean_estimation(
    task: MeanEstimationTask,
    W: np.ndarray | None,
    steps: int = 50,
    lr: float = 0.1,
    batch: int = 1,
    seed: int = 0,
    use_kernel: bool = False,
    schedule: BirkhoffSchedule | ScheduleArrays | None = None,
    transport: str = "auto",
    rollout: str = "scan",
    zs: np.ndarray | None = None,
    on_segment=None,
    segment_len: int | None = None,
    compression=None,
    staleness: StragglerPolicy | None = None,
    delays: np.ndarray | None = None,
    probes: HealthProbes | None = None,
    pi_hat: np.ndarray | None = None,
    tracer: Tracer | None = None,
    retrace_guard=None,
) -> dict:
    """D-SGD on ``F_i(theta, z) = (theta - z)^2``; returns error traces.

    Returns dict with 'mean_sq_error' (n^-1 ||theta - theta*||^2 per step),
    'max_sq_error', 'min_sq_error' (the paper's dashed lines), and the final
    per-node parameters.

    ``rollout="scan"`` compiles all ``steps`` iterations into one
    ``lax.scan`` (noise is presampled host-side with the same RNG call
    sequence as the loop, so both rollouts traverse identical data);
    ``rollout="loop"`` dispatches the same jitted step per iteration.

    Online topology adaptation: pass ``schedule`` as a fixed-shape
    ``ScheduleArrays`` and the mixing matrix becomes *data* -- the
    rollout is compiled once and a mid-run schedule swap never
    retraces it (the returned dict carries ``"n_traces"`` to prove it).
    ``on_segment(t) -> ScheduleArrays | None`` is called after each
    ``segment_len``-step segment (e.g. an
    ``repro.online.OnlineTopologyController``); a non-None return hot-
    swaps the schedule for the following segments. ``zs`` overrides the
    presampled observations with an explicit (steps, n, batch) stream
    (how the drift scenarios of ``repro.data.drift`` are injected --
    the observation noise is exogenous to training, so a drifting task
    is just a different precomputed stream).

    ``compression`` (a ``repro.core.compression.Compressor`` or a spec
    string like ``"bf16"`` / ``"topk:0.25"``) mixes through the
    EF-compressed data-plane transport instead: the error-feedback
    memory rides the rollout carry (fixed shape -- hot swaps still
    retrace nothing) and the returned ``comm`` meters the compressed
    wire. Requires the online ``ScheduleArrays`` schedule; the identity
    wire routes to the uncompressed transport bitwise.

    ``staleness`` (a ``repro.core.mixing.StragglerPolicy``) turns on
    bounded-delay gossip: ``delays`` is the raw (steps, n) per-source
    delay trace (e.g. ``FaultPlan.delays``; defaults to all-zero), the
    policy resolves it per step into a repaired schedule + effective
    delays, and the half-steps mix through the staleness ring buffer
    riding the scan carry. Composes with ``compression`` (EF memory and
    stale ring share one carry) and with ``on_segment`` hot swaps (the
    refreshed base is re-resolved from the next segment on). All-zero
    delays reproduce the fresh run BITWISE. Requires the online
    ``ScheduleArrays`` schedule and ``rollout="scan"``.

    ``probes`` (a ``repro.obs.HealthProbes``) threads the paper's health
    quantities -- consensus distance, gradient deviation, and (with
    ``pi_hat``, the (n, K) live label-histogram estimate) Prop. 2's
    ``tau_bar`` at the in-carry schedule -- into the compiled rollout's
    per-step outputs as pure value computations: the returned dict gains
    ``"health"`` (one (steps,) series per probe) and ``n_traces`` stays
    1 across hot swaps. When ``on_segment`` is an
    ``OnlineTopologyController``, ``pi_hat`` re-snapshots its live
    estimator at every boundary. ``tracer`` (a ``repro.obs.Tracer``)
    records a ``sim.segment`` span per rollout segment;
    ``retrace_guard`` (a ``repro.obs.RetraceGuard``) counts rollout
    compiles under ``"mean_estimation.roll"``.
    """
    if rollout not in ("scan", "loop"):
        raise ValueError(f"unknown rollout {rollout!r}")
    compressor = make_compressor(compression)
    n = task.n_nodes
    rng = np.random.default_rng(seed)
    theta = jnp.zeros((n, 1))
    state = dsgd_init(theta)
    Wj = jnp.asarray(W, jnp.float32) if W is not None else None
    theta_star = jnp.asarray(task.theta_star, jnp.float32)
    if zs is None:
        # Presample the noise exactly as the per-step loop would draw it.
        zs_host = [task.sample(batch, rng) for _ in range(steps)]
        zs = jnp.asarray(
            np.stack(zs_host) if zs_host else np.zeros((0, n, batch)), jnp.float32
        )  # (steps, n, batch)
    else:
        zs = jnp.asarray(zs, jnp.float32)
        if zs.ndim != 3 or zs.shape[0] != steps or zs.shape[1] != n:
            raise ValueError(
                f"zs must be (steps={steps}, n={n}, batch), got {zs.shape}"
            )

    online = isinstance(schedule, ScheduleArrays)
    if on_segment is not None and not online:
        raise ValueError(
            "on_segment hot-swapping needs the schedule as ScheduleArrays "
            "(a static BirkhoffSchedule is baked into the trace)"
        )
    if compressor is not None and not online:
        raise ValueError(
            "compression rides the retrace-free data plane: pass the "
            "schedule as ScheduleArrays (static schedules have no EF carry)"
        )
    delays_arr = _check_staleness_args(
        staleness, delays, steps, n, online, rollout
    )
    pi_hat = _check_probe_args(probes, pi_hat, n, online, rollout, staleness)
    if staleness is not None:
        return _run_mean_estimation_stale(
            theta, zs, schedule,
            steps=steps, segment_len=segment_len, on_segment=on_segment,
            lr=lr, theta_star=theta_star, staleness=staleness,
            delays=delays_arr, compressor=compressor,
        )

    def make_step(sched, ph=None):
        def step(carry, z):
            if compressor is not None:
                theta, st, e = carry
            else:
                theta, st = carry
            grads = 2.0 * (theta - z.mean(axis=1, keepdims=True))
            if compressor is not None:
                theta, st, e = dsgd_step_stacked(
                    theta, grads, st, Wj, lr,
                    use_kernel=use_kernel, schedule=sched, transport=transport,
                    ef=e, compression=compressor,
                )
                new_carry = (theta, st, e)
            else:
                theta, st = dsgd_step_stacked(
                    theta, grads, st, Wj, lr,
                    use_kernel=use_kernel, schedule=sched, transport=transport,
                )
                new_carry = (theta, st)
            err = jnp.square(theta[:, 0] - theta_star)
            outs = (jnp.mean(err), jnp.max(err), jnp.min(err))
            if probes is not None:
                # pure value computations on the post-mix params / this
                # step's grads -- extra scan outputs, zero retraces
                pv = compute_probes(
                    probes, params_stack=theta, grads_stack=grads,
                    arrays=sched, pi_hat=ph,
                )
                outs = outs + tuple(pv.values())
            return new_carry, outs
        return step

    if online:
        return _run_mean_estimation_online(
            theta, state, zs, make_step, schedule,
            steps=steps, segment_len=segment_len, on_segment=on_segment,
            rollout=rollout, compressor=compressor,
            probes=probes, pi_hat=pi_hat, tracer=tracer,
            retrace_guard=retrace_guard,
        )

    step = make_step(schedule)

    if rollout == "scan":
        @jax.jit
        def roll(theta, st, zs):
            return jax.lax.scan(step, (theta, st), zs)

        (theta, state), (mse, mx, mn) = roll(theta, state, zs)
        mse, mx, mn = np.asarray(mse), np.asarray(mx), np.asarray(mn)
    else:
        step_j = jax.jit(step)
        carry = (theta, state)
        mse_l, mx_l, mn_l = [], [], []
        for t in range(steps):
            carry, (e_mean, e_max, e_min) = step_j(carry, zs[t])
            mse_l.append(e_mean)
            mx_l.append(e_max)
            mn_l.append(e_min)
        theta, state = carry
        mse = np.asarray(jnp.stack(mse_l)) if mse_l else np.zeros((0,))
        mx = np.asarray(jnp.stack(mx_l)) if mx_l else np.zeros((0,))
        mn = np.asarray(jnp.stack(mn_l)) if mn_l else np.zeros((0,))
    return {
        "mean_sq_error": mse,
        "max_sq_error": mx,
        "min_sq_error": mn,
        "theta": np.asarray(theta),
    }


def _run_mean_estimation_online(
    theta,
    state,
    zs,
    make_step,
    sched0: ScheduleArrays,
    *,
    steps: int,
    segment_len: int | None,
    on_segment,
    rollout: str,
    compressor=None,
    probes=None,
    pi_hat=None,
    tracer=None,
    retrace_guard=None,
) -> dict:
    """Mean-estimation driver with the schedule threaded as data.

    The ``ScheduleArrays`` rides in the rollout carry, so every segment
    -- before or after a hot swap -- executes the SAME compiled
    computation. ``n_traces`` in the returned dict counts actual traces
    of the rollout: 1 per distinct segment length (exactly 1 when
    ``segment_len`` divides ``steps``), regardless of how many times
    the schedule was swapped. Under ``compressor`` the EF memory joins
    the carry (fixed shape, like the schedule itself), so the count
    stays 1 in compressed runs too. ``pi_hat`` (tau_bar probe only)
    enters the jitted rollout as an ordinary operand -- per-segment
    estimator updates are value changes.
    """
    tracer = _NULL_TRACER if tracer is None else tracer
    n_traces = 0
    if rollout == "scan":
        def roll_impl(carry, zs_seg, ph):
            nonlocal n_traces
            n_traces += 1
            if retrace_guard is not None:
                retrace_guard.record("mean_estimation.roll")
            inner, sa = carry[:-1], carry[-1]
            inner, traces = jax.lax.scan(make_step(sa, ph), inner, zs_seg)
            return inner + (sa,), traces
        roll = jax.jit(roll_impl)
    else:
        def step_impl(carry, z, ph):
            nonlocal n_traces
            n_traces += 1
            if retrace_guard is not None:
                retrace_guard.record("mean_estimation.roll")
            inner, sa = carry[:-1], carry[-1]
            inner, out = make_step(sa, ph)(inner, z)
            return inner + (sa,), out
        step_j = jax.jit(step_impl)

        def roll(carry, zs_seg, ph):
            outs = []
            for t in range(zs_seg.shape[0]):
                carry, out = step_j(carry, zs_seg[t], ph)
                outs.append(out)
            stacked = [
                jnp.stack([o[i] for o in outs]) for i in range(len(outs[0]))
            ]
            return carry, tuple(stacked)

    # NB: `is None`, not truthiness -- segment_len=0 must hit the
    # validation below, not silently become one full-run segment
    seg = int(segment_len) if segment_len is not None else max(steps, 1)
    if seg < 1:
        raise ValueError(f"segment_len must be >= 1, got {segment_len}")
    if compressor is not None:
        carry = (theta, state, ef_init(theta), sched0)
    else:
        carry = (theta, state, sched0)
    mse_l, mx_l, mn_l = [], [], []
    probe_names = probes.names() if probes is not None else ()
    health_l: dict[str, list] = {nm: [] for nm in probe_names}
    swaps: list[int] = []
    meter = _online_comm_meter(
        theta.shape[0], int(np.prod(theta.shape[1:])), compression=compressor
    )
    ph = pi_hat  # None is a valid (empty-pytree) jit operand when tau_bar off
    t0 = 0
    while t0 < steps:
        length = min(seg, steps - t0)
        with tracer.span("sim.segment", t0=t0, k=length):
            carry, traces = roll(carry, zs[t0 : t0 + length], ph)
            traces = jax.block_until_ready(traces)
        e_mean, e_max, e_min = traces[:3]
        mse_l.append(np.asarray(e_mean))
        mx_l.append(np.asarray(e_max))
        mn_l.append(np.asarray(e_min))
        for nm, series in zip(probe_names, traces[3:]):
            health_l[nm].append(np.asarray(series))
        meter.tick(length)
        t0 += length
        if on_segment is not None and t0 < steps:
            # no hook after the final segment: a refresh triggered there
            # would burn a warm solve whose schedule nothing executes
            new_sa = on_segment(t0 - 1)
            if new_sa is not None:
                carry = carry[:-1] + (new_sa,)
                swaps.append(t0 - 1)
            if ph is not None:
                # tau_bar tracks the hook's live estimator as a VALUE
                ph = _live_pi_hat(on_segment, ph)
    theta = carry[0]
    empty = np.zeros((0,))
    out = {
        "mean_sq_error": np.concatenate(mse_l) if mse_l else empty,
        "max_sq_error": np.concatenate(mx_l) if mx_l else empty,
        "min_sq_error": np.concatenate(mn_l) if mn_l else empty,
        "theta": np.asarray(theta),
        "n_traces": n_traces,
        "swaps": swaps,
        "comm": meter.summary(),
        "compression": compressor.label if compressor is not None else None,
    }
    if probes is not None:
        out["health"] = {
            nm: (np.concatenate(v) if v else empty) for nm, v in health_l.items()
        }
    return out


def _run_mean_estimation_stale(
    theta,
    zs,
    sched0: ScheduleArrays,
    *,
    steps: int,
    segment_len: int | None,
    on_segment,
    lr: float,
    theta_star,
    staleness: StragglerPolicy,
    delays: np.ndarray,
    compressor=None,
) -> dict:
    """Mean-estimation driver under bounded-delay gossip.

    Same step math as the fresh online driver op-for-op (grads, local
    half-step) with the mixing routed through the staleness ring: the
    per-step policy-resolved ``(gammas, perms, eff_delays)`` ride the
    scan as xs (fixed shapes whatever the delays -- zero retraces), the
    ring buffer (and the EF memory, under ``compressor``) rides the
    carry. A hot swap rebases the HOST-side schedule the policy
    resolves from; the compiled rollout never notices. All-zero delays
    read back the value just pushed, so the trajectory is bitwise the
    fresh driver's.
    """
    n = theta.shape[0]
    lr = float(lr)
    buffer = stale_buffer_init(theta, staleness.ring_depth)
    n_traces = 0

    def roll_impl(carry, xs):
        nonlocal n_traces
        n_traces += 1

        def step(c, x):
            z, g_t, p_t, d_t = x
            sa = ScheduleArrays(gammas=g_t, perms=p_t)
            grads_of = lambda th: 2.0 * (th - z.mean(axis=1, keepdims=True))
            if compressor is not None:
                th, e, buf = c
                half = th - lr * grads_of(th)
                th, e, buf = ef_stale_mix_flat(half, e, buf, sa, d_t, compressor)
                new_c = (th, e, buf)
            else:
                th, buf = c
                half = th - lr * grads_of(th)
                buf = stale_push(buf, half)
                th = mix_schedule_arrays_stale(buf, sa, d_t)
                new_c = (th, buf)
            err = jnp.square(th[:, 0] - theta_star)
            return new_c, (jnp.mean(err), jnp.max(err), jnp.min(err))

        return jax.lax.scan(step, carry, xs)

    roll = jax.jit(roll_impl)
    seg = int(segment_len) if segment_len is not None else max(steps, 1)
    if seg < 1:
        raise ValueError(f"segment_len must be >= 1, got {segment_len}")
    if compressor is not None:
        carry = (theta, ef_init(theta), buffer)
    else:
        carry = (theta, buffer)
    base = sched0
    meter = _online_comm_meter(n, 1, compression=compressor)
    mse_l, mx_l, mn_l = [], [], []
    swaps: list[int] = []
    t0 = 0
    while t0 < steps:
        k = min(seg, steps - t0)
        g_k, p_k, d_k = straggler_stream(staleness, base, delays[t0 : t0 + k])
        carry, (e_mean, e_max, e_min) = roll(carry, (zs[t0 : t0 + k], g_k, p_k, d_k))
        mse_l.append(np.asarray(e_mean))
        mx_l.append(np.asarray(e_max))
        mn_l.append(np.asarray(e_min))
        delivered, deferred = _staleness_meter_fracs(
            delays[t0 : t0 + k], staleness
        )
        meter.tick(k, delivered_frac=delivered, deferred_frac=deferred)
        t0 += k
        if on_segment is not None and t0 < steps:
            new_sa = on_segment(t0 - 1)
            if new_sa is not None:
                base = new_sa
                swaps.append(t0 - 1)
    empty = np.zeros((0,))
    return {
        "mean_sq_error": np.concatenate(mse_l) if mse_l else empty,
        "max_sq_error": np.concatenate(mx_l) if mx_l else empty,
        "min_sq_error": np.concatenate(mn_l) if mn_l else empty,
        "theta": np.asarray(carry[0]),
        "n_traces": n_traces,
        "swaps": swaps,
        "comm": meter.summary(),
        "compression": compressor.label if compressor is not None else None,
        "staleness": {"mode": staleness.mode, "tau_max": staleness.tau_max},
    }


# ---------------------------------------------------------------------------
# Section 6.2: label-skew classification
# ---------------------------------------------------------------------------

def init_linear_classifier(rng: jax.Array, dim: int, num_classes: int) -> PyTree:
    k1, _ = jax.random.split(rng)
    return {
        "w": jax.random.normal(k1, (dim, num_classes)) * 0.01,
        "b": jnp.zeros((num_classes,)),
    }


def init_mlp_classifier(
    rng: jax.Array, dim: int, num_classes: int, hidden: int = 64
) -> PyTree:
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * (2.0 / dim) ** 0.5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, num_classes)) * (2.0 / hidden) ** 0.5,
        "b2": jnp.zeros((num_classes,)),
    }


def _classifier_logits(params: PyTree, x: jax.Array) -> jax.Array:
    if "w1" in params:
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    return x @ params["w"] + params["b"]


def classifier_loss(params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
    logits = _classifier_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def classifier_accuracy(params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean(jnp.argmax(_classifier_logits(params, x), -1) == y)


@dataclasses.dataclass
class _NodeData:
    """Per-node dataset views, padded to a common length for stacking."""

    x: jax.Array  # (n, max_len, dim)
    y: jax.Array  # (n, max_len)
    lengths: jax.Array  # (n,)


def _stack_node_data(X, y, indices_per_node) -> _NodeData:
    n = len(indices_per_node)
    max_len = max(len(idx) for idx in indices_per_node)
    dim = X.shape[1]
    xs = np.zeros((n, max_len, dim), np.float32)
    ys = np.zeros((n, max_len), np.int32)
    lens = np.zeros((n,), np.int32)
    for i, idx in enumerate(indices_per_node):
        L = len(idx)
        xs[i, :L] = X[idx]
        ys[i, :L] = y[idx]
        lens[i] = L
        if L > 0 and L < max_len:  # cyclic pad so sampling stays uniform
            reps = idx[np.arange(max_len - L) % L]
            xs[i, L:] = X[reps]
            ys[i, L:] = y[reps]
            lens[i] = max_len
    return _NodeData(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(lens))


def _eval_segments(steps: int, eval_every: int, do_eval: bool) -> list[tuple[int, bool]]:
    """Split [0, steps) into scan segments ending at eval points.

    Returns (segment_length, evaluate_after) pairs covering all steps in
    order, where ``evaluate_after`` marks the loop's eval condition
    ``t % eval_every == 0 or t == steps - 1`` on the segment's last step.
    """
    if steps <= 0:
        return []
    if not do_eval:
        # no eval points: one full-length scan, no per-segment host sync
        return [(steps, False)]
    segments: list[tuple[int, bool]] = []
    start = 0
    while start < steps:
        end = start
        while end < steps - 1 and not (end % eval_every == 0 or end == steps - 1):
            end += 1
        segments.append((end - start + 1, True))
        start = end + 1
    return segments


def run_classification(
    X: np.ndarray,
    y: np.ndarray,
    indices_per_node: list[np.ndarray],
    W: np.ndarray | None,
    *,
    model: str = "linear",
    hidden: int = 64,
    steps: int = 300,
    batch_size: int = 32,
    lr: float = 0.1,
    eval_every: int = 20,
    X_test: np.ndarray | None = None,
    y_test: np.ndarray | None = None,
    seed: int = 0,
    use_kernel: bool = False,
    schedule: BirkhoffSchedule | ScheduleArrays | None = None,
    transport: str = "auto",
    rollout: str = "scan",
    on_segment=None,
    compression=None,
    staleness: StragglerPolicy | None = None,
    delays: np.ndarray | None = None,
    probes: HealthProbes | None = None,
    pi_hat: np.ndarray | None = None,
    tracer: Tracer | None = None,
    retrace_guard=None,
) -> MetricLogger:
    """D-SGD classification with per-node local data (Algorithm 1).

    Logs train loss (node mean) every step and test accuracy min/mean/max
    across nodes at eval points. ``rollout="scan"`` compiles the steps
    between consecutive eval points into single ``lax.scan`` rollouts (the
    per-step losses come back as one array per segment -- no host sync in
    the hot loop); ``rollout="loop"`` runs the same jitted step per
    iteration and produces a bit-identical trace.

    Online topology adaptation: with ``schedule`` as a fixed-shape
    ``ScheduleArrays`` the mixing schedule travels in the rollout carry
    as data, and ``on_segment(t) -> ScheduleArrays | None`` (called
    after each scan segment / at eval boundaries) can hot-swap it with
    zero retraces. The returned logger's ``aux`` dict records
    ``n_traces`` (compiled-rollout traces: one per distinct segment
    length -- swaps add none) and ``swaps`` (steps where a swap
    landed). ``compression`` composes with the online path exactly as
    in :func:`run_mean_estimation`: EF memory in the carry, compressed
    wire in ``aux["comm"]``, zero extra traces.

    ``staleness`` / ``delays`` turn on bounded-delay gossip exactly as
    in :func:`run_mean_estimation`: the half-step pytree is raveled
    into one (n, P) buffer, pushed into the staleness ring riding the
    scan carry, and mixed under the policy-resolved per-step schedule
    + effective delays (scan xs). Composes with ``compression`` (EF
    memory and stale ring in ONE carry) and ``on_segment`` hot swaps;
    all-zero delays are bitwise the fresh run. Scan rollout + online
    ``ScheduleArrays`` required.

    ``probes`` / ``pi_hat`` / ``tracer`` / ``retrace_guard`` work as in
    :func:`run_mean_estimation`: per-step health series land in
    ``logger.aux["health"]``, segments get ``sim.segment`` spans, and
    rollout compiles are counted under ``"classification.roll"``.
    Requires the online scan rollout; probe outputs are extra scan ys,
    so the loss trajectory is BITWISE the probes-off run's.
    """
    if rollout not in ("scan", "loop"):
        raise ValueError(f"unknown rollout {rollout!r}")
    online = isinstance(schedule, ScheduleArrays)
    if on_segment is not None and not online:
        raise ValueError(
            "on_segment hot-swapping needs the schedule as ScheduleArrays "
            "(a static BirkhoffSchedule is baked into the trace)"
        )
    compressor = make_compressor(compression)
    if compressor is not None and not online:
        raise ValueError(
            "compression rides the retrace-free data plane: pass the "
            "schedule as ScheduleArrays (static schedules have no EF carry)"
        )
    n = len(indices_per_node)
    delays_arr = _check_staleness_args(
        staleness, delays, steps, n, online, rollout
    )
    pi_hat = _check_probe_args(probes, pi_hat, n, online, rollout, staleness)
    tracer = _NULL_TRACER if tracer is None else tracer
    num_classes = int(y.max()) + 1
    dim = X.shape[1]
    data = _stack_node_data(X, y, indices_per_node)
    rng = jax.random.PRNGKey(seed)
    init_fn = (
        (lambda r: init_linear_classifier(r, dim, num_classes))
        if model == "linear"
        else (lambda r: init_mlp_classifier(r, dim, num_classes, hidden))
    )
    params0 = init_fn(rng)
    # same init on every node (theta_i^0 = theta^0, as in Algorithm 1)
    params = jax.tree_util.tree_map(lambda p: jnp.stack([p] * n), params0)
    state = dsgd_init(params)
    Wj = jnp.asarray(W, jnp.float32) if W is not None else None

    grad_fn = jax.grad(classifier_loss)

    def node_grads(p, x_node, y_node, length, k):
        idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(length, 1))
        xb = x_node[idx]
        yb = y_node[idx]
        loss = classifier_loss(p, xb, yb)
        return grad_fn(p, xb, yb), loss

    def step(carry, _, ph=None):
        if online and compressor is not None:
            params, state, key, e, sa = carry
            sched_t = sa
        elif online:
            params, state, key, sa = carry
            sched_t = sa
        else:
            params, state, key = carry
            sched_t = schedule
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        grads, losses = jax.vmap(node_grads)(params, data.x, data.y, data.lengths, keys)
        if compressor is not None:
            new_params, new_state, new_e = dsgd_step_stacked(
                params, grads, state, Wj, lr,
                use_kernel=use_kernel, schedule=sched_t, transport=transport,
                ef=e, compression=compressor,
            )
            out_carry = (new_params, new_state, key, new_e, sa)
        else:
            new_params, new_state = dsgd_step_stacked(
                params, grads, state, Wj, lr,
                use_kernel=use_kernel, schedule=sched_t, transport=transport,
            )
            out_carry = (
                (new_params, new_state, key, sa)
                if online
                else (new_params, new_state, key)
            )
        if probes is None:
            return out_carry, losses.mean()
        # extra scan ys only -- the loss trajectory is bitwise unchanged
        pv = compute_probes(
            probes, params_stack=new_params, grads_stack=grads,
            arrays=sched_t, pi_hat=ph,
        )
        return out_carry, (losses.mean(),) + tuple(pv.values())

    @jax.jit
    def eval_fn(params, X_t, y_t):
        return jax.vmap(lambda p: classifier_accuracy(p, X_t, y_t))(params)

    logger = MetricLogger()
    key = jax.random.PRNGKey(seed + 1)
    do_eval = X_test is not None
    X_t = jnp.asarray(X_test) if do_eval else None
    y_t = jnp.asarray(y_test) if do_eval else None

    def log_segment(t0: int, losses: np.ndarray, params, evaluate: bool) -> None:
        for j, loss in enumerate(losses):
            t = t0 + j
            last = j == len(losses) - 1
            if last and evaluate and (t % eval_every == 0 or t == steps - 1):
                accs = np.asarray(eval_fn(params, X_t, y_t))
                logger.log(
                    t,
                    loss=float(loss),
                    acc_mean=float(accs.mean()),
                    acc_min=float(accs.min()),
                    acc_max=float(accs.max()),
                    consensus=float(consensus_distance(params)),
                )
            else:
                logger.log(t, loss=float(loss))

    n_traces = 0
    swaps: list[int] = []
    probe_names = probes.names() if probes is not None else ()
    health_l: dict[str, list] = {nm: [] for nm in probe_names}
    ph = pi_hat  # None is a valid (empty-pytree) jit operand when tau_bar off

    def maybe_swap(t: int, carry):
        """Hot-swap the carried schedule if the hook hands back a new one."""
        if on_segment is None:
            return carry
        new_sa = on_segment(t)
        if new_sa is None:
            return carry
        swaps.append(t)
        return (*carry[:-1], new_sa)

    # on_segment needs segment boundaries even when there is no eval
    # data: segmenting is decoupled from evaluation (the eval calls
    # themselves stay gated on do_eval), so a hook-driven run without
    # X_test still swaps at eval_every boundaries -- identically in
    # both rollouts -- instead of silently degrading to one
    # end-of-run call.
    segmented = do_eval or on_segment is not None

    if staleness is not None:
        # bounded-delay branch: the half-step pytree ravels into one
        # (n, P) buffer so the ring holds ONE array; schedule + delays
        # arrive as scan xs (policy-resolved host-side per segment)
        flat0, ravel_spec = ravel_stack(params)
        buffer = stale_buffer_init(flat0, staleness.ring_depth)

        def stale_step(carry, x):
            if compressor is not None:
                params, state, key, e, buf = carry
            else:
                params, state, key, buf = carry
            g_t, p_t, d_t = x
            sa_t = ScheduleArrays(gammas=g_t, perms=p_t)
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            grads, losses = jax.vmap(node_grads)(
                params, data.x, data.y, data.lengths, keys
            )
            half = jax.tree_util.tree_map(
                lambda p, g: p - lr * g, params, grads
            )
            flat, _ = ravel_stack(half)
            if compressor is not None:
                mixed, e, buf = ef_stale_mix_flat(
                    flat, e, buf, sa_t, d_t, compressor
                )
                rest = (e, buf)
            else:
                buf = stale_push(buf, flat)
                mixed = mix_schedule_arrays_stale(buf, sa_t, d_t)
                rest = (buf,)
            new_params = unravel_stack(mixed, ravel_spec)
            new_state = DSGDState(step=state.step + 1, momentum=None)
            return (new_params, new_state, key) + rest, losses.mean()

        def roll_stale_impl(carry, xs):
            nonlocal n_traces
            n_traces += 1
            if retrace_guard is not None:
                retrace_guard.record("classification.roll")
            return jax.lax.scan(stale_step, carry, xs)

        roll_stale = jax.jit(roll_stale_impl)
        if compressor is not None:
            carry = (params, state, key, jnp.zeros_like(flat0), buffer)
        else:
            carry = (params, state, key, buffer)
        base_sa = schedule
        t0 = 0
        for seg_len, evaluate in _eval_segments(steps, eval_every, segmented):
            xs = straggler_stream(
                staleness, base_sa, delays_arr[t0 : t0 + seg_len]
            )
            with tracer.span("sim.segment", t0=t0, k=seg_len):
                carry, losses = roll_stale(carry, xs)
                losses = jax.block_until_ready(losses)
            log_segment(t0, np.asarray(losses), carry[0], evaluate and do_eval)
            t0 += seg_len
            if t0 < steps and on_segment is not None:
                new_sa = on_segment(t0 - 1)
                if new_sa is not None:
                    base_sa = new_sa  # re-resolved from the next segment on
                    swaps.append(t0 - 1)
    elif rollout == "scan":
        @functools.partial(jax.jit, static_argnames=("length",))
        def roll(carry, length: int, ph=None):
            nonlocal n_traces
            n_traces += 1
            if retrace_guard is not None:
                retrace_guard.record("classification.roll")
            return jax.lax.scan(
                lambda c, x: step(c, x, ph), carry, None, length=length
            )

        if online and compressor is not None:
            carry = (params, state, key, ef_init(params), schedule)
        elif online:
            carry = (params, state, key, schedule)
        else:
            carry = (params, state, key)
        t0 = 0
        for seg_len, evaluate in _eval_segments(steps, eval_every, segmented):
            with tracer.span("sim.segment", t0=t0, k=seg_len):
                carry, traces = roll(carry, seg_len, ph)
                traces = jax.block_until_ready(traces)
            if probes is not None:
                losses = traces[0]
                for nm, series in zip(probe_names, traces[1:]):
                    health_l[nm].append(np.asarray(series))
            else:
                losses = traces
            log_segment(t0, np.asarray(losses), carry[0], evaluate and do_eval)
            t0 += seg_len
            if t0 < steps:  # no hook after the final segment (see above)
                carry = maybe_swap(t0 - 1, carry)
                if ph is not None:
                    # tau_bar tracks the hook's live estimator as a VALUE
                    ph = _live_pi_hat(on_segment, ph)
    else:
        def step_impl(carry, x):
            nonlocal n_traces
            n_traces += 1
            if retrace_guard is not None:
                retrace_guard.record("classification.roll")
            return step(carry, x)

        step_j = jax.jit(step_impl)
        if online and compressor is not None:
            carry = (params, state, key, ef_init(params), schedule)
        elif online:
            carry = (params, state, key, schedule)
        else:
            carry = (params, state, key)
        for t in range(steps):
            carry, loss = step_j(carry, None)
            log_segment(t, np.asarray(loss)[None], carry[0], do_eval)
            # same boundaries the scan segments end on, minus the final
            # step; the hook guard also keeps eval_every=0 runs (legal
            # when neither eval nor a hook needs boundaries) modulo-free
            if on_segment is not None and t % eval_every == 0 and t < steps - 1:
                carry = maybe_swap(t, carry)
    logger.aux["n_traces"] = n_traces
    logger.aux["swaps"] = swaps
    if probes is not None:
        empty = np.zeros((0,))
        logger.aux["health"] = {
            nm: (np.concatenate(v) if v else empty)
            for nm, v in health_l.items()
        }
    if online:
        meter = _online_comm_meter(
            n,
            sum(int(np.prod(np.asarray(p.shape))) for p in
                jax.tree_util.tree_leaves(params0)),
            compression=compressor,
        )
        if staleness is not None:
            delivered, deferred = _staleness_meter_fracs(delays_arr, staleness)
            meter.tick(steps, delivered_frac=delivered, deferred_frac=deferred)
            logger.aux["staleness"] = {
                "mode": staleness.mode, "tau_max": staleness.tau_max,
            }
        else:
            meter.tick(steps)
        logger.aux["comm"] = meter.summary()
        logger.aux["compression"] = (
            compressor.label if compressor is not None else None
        )
    return logger

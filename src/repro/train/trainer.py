"""n-node D-SGD simulator (the paper's experimental rig).

Simulates Algorithm 1 exactly on a single device: per-node parameters are
stacked on a leading node axis, local gradients are computed with
``vmap(grad)``, and the mixing step is the dense ``Theta W^T`` product
(optionally through the Pallas gossip kernel). This reproduces the paper's
n=100 experiments bit-for-bit up to RNG.

Two ready-made drivers:
* ``run_mean_estimation`` -- Section 6.1 / Example 1 quadratic task, with
  closed-form error tracking against theta*.
* ``run_classification``  -- Section 6.2-style label-skew classification
  (linear model or MLP) on a partitioned synthetic dataset.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsgd import dsgd_init, dsgd_step_stacked
from repro.data.synthetic import MeanEstimationTask
from .metrics import MetricLogger, consensus_distance

PyTree = Any

__all__ = [
    "run_mean_estimation",
    "init_linear_classifier",
    "init_mlp_classifier",
    "classifier_loss",
    "classifier_accuracy",
    "run_classification",
]


# ---------------------------------------------------------------------------
# Section 6.1: decentralized mean estimation
# ---------------------------------------------------------------------------

def run_mean_estimation(
    task: MeanEstimationTask,
    W: np.ndarray,
    steps: int = 50,
    lr: float = 0.1,
    batch: int = 1,
    seed: int = 0,
    use_kernel: bool = False,
) -> dict:
    """D-SGD on ``F_i(theta, z) = (theta - z)^2``; returns error traces.

    Returns dict with 'mean_sq_error' (n^-1 ||theta - theta*||^2 per step),
    'max_sq_error', 'min_sq_error' (the paper's dashed lines), and the final
    per-node parameters.
    """
    n = task.n_nodes
    rng = np.random.default_rng(seed)
    theta = jnp.zeros((n, 1))
    state = dsgd_init(theta)
    Wj = jnp.asarray(W, jnp.float32)
    theta_star = task.theta_star

    mse, mx, mn = [], [], []
    for _ in range(steps):
        z = jnp.asarray(task.sample(batch, rng), jnp.float32)  # (n, batch)
        grads = 2.0 * (theta - z.mean(axis=1, keepdims=True))
        theta, state = dsgd_step_stacked(theta, grads, state, Wj, lr, use_kernel=use_kernel)
        err = np.asarray((theta[:, 0] - theta_star) ** 2)
        mse.append(float(err.mean()))
        mx.append(float(err.max()))
        mn.append(float(err.min()))
    return {
        "mean_sq_error": np.array(mse),
        "max_sq_error": np.array(mx),
        "min_sq_error": np.array(mn),
        "theta": np.asarray(theta),
    }


# ---------------------------------------------------------------------------
# Section 6.2: label-skew classification
# ---------------------------------------------------------------------------

def init_linear_classifier(rng: jax.Array, dim: int, num_classes: int) -> PyTree:
    k1, _ = jax.random.split(rng)
    return {
        "w": jax.random.normal(k1, (dim, num_classes)) * 0.01,
        "b": jnp.zeros((num_classes,)),
    }


def init_mlp_classifier(
    rng: jax.Array, dim: int, num_classes: int, hidden: int = 64
) -> PyTree:
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * (2.0 / dim) ** 0.5,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, num_classes)) * (2.0 / hidden) ** 0.5,
        "b2": jnp.zeros((num_classes,)),
    }


def _classifier_logits(params: PyTree, x: jax.Array) -> jax.Array:
    if "w1" in params:
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    return x @ params["w"] + params["b"]


def classifier_loss(params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
    logits = _classifier_logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def classifier_accuracy(params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean(jnp.argmax(_classifier_logits(params, x), -1) == y)


@dataclasses.dataclass
class _NodeData:
    """Per-node dataset views, padded to a common length for stacking."""

    x: jax.Array  # (n, max_len, dim)
    y: jax.Array  # (n, max_len)
    lengths: jax.Array  # (n,)


def _stack_node_data(X, y, indices_per_node) -> _NodeData:
    n = len(indices_per_node)
    max_len = max(len(idx) for idx in indices_per_node)
    dim = X.shape[1]
    xs = np.zeros((n, max_len, dim), np.float32)
    ys = np.zeros((n, max_len), np.int32)
    lens = np.zeros((n,), np.int32)
    for i, idx in enumerate(indices_per_node):
        L = len(idx)
        xs[i, :L] = X[idx]
        ys[i, :L] = y[idx]
        lens[i] = L
        if L > 0 and L < max_len:  # cyclic pad so sampling stays uniform
            reps = idx[np.arange(max_len - L) % L]
            xs[i, L:] = X[reps]
            ys[i, L:] = y[reps]
            lens[i] = max_len
    return _NodeData(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(lens))


def run_classification(
    X: np.ndarray,
    y: np.ndarray,
    indices_per_node: list[np.ndarray],
    W: np.ndarray,
    *,
    model: str = "linear",
    hidden: int = 64,
    steps: int = 300,
    batch_size: int = 32,
    lr: float = 0.1,
    eval_every: int = 20,
    X_test: np.ndarray | None = None,
    y_test: np.ndarray | None = None,
    seed: int = 0,
    use_kernel: bool = False,
) -> MetricLogger:
    """D-SGD classification with per-node local data (Algorithm 1).

    Logs train loss (node mean) and test accuracy min/mean/max across nodes.
    """
    n = len(indices_per_node)
    num_classes = int(y.max()) + 1
    dim = X.shape[1]
    data = _stack_node_data(X, y, indices_per_node)
    rng = jax.random.PRNGKey(seed)
    init_fn = (
        (lambda r: init_linear_classifier(r, dim, num_classes))
        if model == "linear"
        else (lambda r: init_mlp_classifier(r, dim, num_classes, hidden))
    )
    params0 = init_fn(rng)
    # same init on every node (theta_i^0 = theta^0, as in Algorithm 1)
    params = jax.tree_util.tree_map(lambda p: jnp.stack([p] * n), params0)
    state = dsgd_init(params)
    Wj = jnp.asarray(W, jnp.float32)

    grad_fn = jax.grad(classifier_loss)

    @jax.jit
    def step_fn(params, state, key):
        keys = jax.random.split(key, n)

        def node_grads(p, x_node, y_node, length, k):
            idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(length, 1))
            xb = x_node[idx]
            yb = y_node[idx]
            loss = classifier_loss(p, xb, yb)
            return grad_fn(p, xb, yb), loss

        grads, losses = jax.vmap(node_grads)(params, data.x, data.y, data.lengths, keys)
        new_params, new_state = dsgd_step_stacked(
            params, grads, state, Wj, lr, use_kernel=use_kernel
        )
        return new_params, new_state, losses.mean()

    @jax.jit
    def eval_fn(params, X_t, y_t):
        return jax.vmap(lambda p: classifier_accuracy(p, X_t, y_t))(params)

    logger = MetricLogger()
    key = jax.random.PRNGKey(seed + 1)
    for t in range(steps):
        key, sub = jax.random.split(key)
        params, state, loss = step_fn(params, state, sub)
        if (t % eval_every == 0 or t == steps - 1) and X_test is not None:
            accs = np.asarray(eval_fn(params, jnp.asarray(X_test), jnp.asarray(y_test)))
            logger.log(
                t,
                loss=float(loss),
                acc_mean=float(accs.mean()),
                acc_min=float(accs.min()),
                acc_max=float(accs.max()),
                consensus=float(consensus_distance(params)),
            )
        else:
            logger.log(t, loss=float(loss))
    return logger

"""Mesh-sharded large-model trainer: D-SGD over the data axis + tensor
parallelism over the model axis.

Three distribution modes (DESIGN.md Section 3.2):

* ``dsgd``     -- each index of the ``data`` mesh axis is one D-SGD node
                  holding its own model replica (params get a leading node
                  axis sharded over ``data``; each replica is TP-sharded over
                  ``model``). The mixing step executes the learned topology's
                  Birkhoff decomposition as a ``ppermute`` schedule
                  (d_max collective-permutes instead of an all-reduce).
* ``fsdp``     -- C-PSGD baseline / fallback: one global model, params
                  sharded over (data x model), gradients all-reduced by
                  GSPMD. Equivalent to D-SGD with W = 11^T/n.
* ``dsgd_pod`` -- multi-pod: pods are the D-SGD nodes (params stacked over
                  ``pod``); within a pod, classic data parallelism; across
                  pods, the sparse gossip schedule rides the slow DCN links.

``make_train_setup`` returns everything the launcher / dry-run needs:
the jitted-able step function, in/out shardings, and abstract input specs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.compression import (
    Compressor,
    ef_init,
    make_compressor,
    mix_arrays_sharded_ef,
    mix_arrays_sharded_stale_ef,
    mix_dense_sharded_ef,
    mix_ppermute_pool_ef,
    mix_ppermute_pool_stale_ef,
)
from repro.core.mixing import (
    BirkhoffSchedule,
    PermPool,
    PoolSwap,
    ScheduleArrays,
    ShardStaleState,
    StragglerPolicy,
    autotune_sharded_transport,
    mix_arrays_sharded,
    mix_arrays_sharded_stale,
    mix_dense_sharded,
    mix_ppermute,
    mix_ppermute_pool,
    mix_ppermute_pool_stale,
    straggler_pool_stream,
    straggler_stream,
)
from repro.models import registry
from repro.models.common import ModelConfig
from repro.obs.probes import HealthProbes
from repro.obs.trace import Tracer
from .checkpoints import latest_step, restore_checkpoint, save_checkpoint
from .metrics import CommMeter, mix_bytes_per_step, staleness_transfer_fracs
from .sharding import make_param_specs

# instrumented paths take an always-on tracer; callers opt in with a real one
_NULL_TRACER = Tracer(enabled=False)

PyTree = Any

__all__ = ["TrainSetup", "make_train_setup", "gossip_fn"]


@dataclasses.dataclass
class TrainSetup:
    """Everything needed to jit / lower a distributed train step.

    With ``online_w=True`` the step function takes the mixing matrix as
    a trailing *data* argument -- ``train_step(params, opt_state, batch,
    mix_w)`` -- so an online topology refresh swaps W by passing a
    different (n, n) array, never by rebuilding/retracing the step.
    """

    train_step: Callable  # (params, opt_state, batch[, mix_w]) -> (params, opt_state, loss)
    init_params: Callable  # (rng) -> params (abstract-safe via jax.eval_shape)
    param_specs: PyTree
    batch_spec: PyTree
    mode: str
    n_nodes: int
    online_w: bool = False
    # hot-swappable sharded mixing (online_w dsgd mode only):
    #   "allgather" -- mix_dense_sharded / mix_arrays_sharded (O(nP) bytes,
    #                  any W swaps with zero retraces)
    #   "pool"      -- mix_ppermute_pool over `pool` (O(K P) bytes; in-pool
    #                  gamma swaps are value changes, restages recompile)
    sharded_transport: str | None = None
    pool: PermPool | None = None
    # modeled bytes RECEIVED per node per mixing step (see
    # train.metrics.mix_bytes_per_step); None when nothing communicates
    comm_bytes_per_step: int | None = None
    # resolved wire format (repro.core.compression.Compressor) when the
    # online transports run EF-compressed gossip; None = uncompressed
    compression: "Compressor | None" = None
    # bounded-delay gossip policy (repro.core.mixing.StragglerPolicy).
    # When set, the step takes per-step delays as a second trailing data
    # argument -- train_step(params, opt_state, batch, mix_w, delays) --
    # and the sender-side stale ring travels in the opt-state dict under
    # "stale" (build it with init_opt_state). None = fresh gossip.
    staleness: "StragglerPolicy | None" = None
    # in-rollout health probes (repro.obs.HealthProbes; consensus /
    # grad_dev only -- tau_bar is a simulator probe). When set, the
    # step's loss output becomes the dict {"loss": ..., <probe>: ...}
    # of replicated scalars, computed INSIDE the shard_map as pure
    # collectives -- probe values per step, zero extra traces, and the
    # loss trajectory bitwise the probes-off run's.
    probes: "HealthProbes | None" = None

    def abstract_params(self) -> PyTree:
        return jax.eval_shape(self.init_params, jax.random.PRNGKey(0))

    def init_opt_state(self, params: PyTree):
        """Initial opt/comm state for ``train_step``, matching this
        setup's carried-state convention: ``None`` when nothing is
        carried, a bare momentum tree for plain momentum, a dict with
        ``"step"`` (gossip_every), ``"m"`` (momentum), and/or ``"ef"``
        (the per-node error-feedback memory of compressed mixing --
        required whenever ``compression`` is set)."""
        if self._init_opt_state is None:
            raise ValueError(
                "init_opt_state needs a setup built by make_train_setup"
            )
        return self._init_opt_state(params)

    def multi_step_fn(self, rollout: str = "scan") -> Callable:
        """Multi-step train fn: ``(params, opt_state, batches) -> (params,
        opt_state, losses)`` where every ``batches`` leaf carries a leading
        time axis ``(k, ...)`` of per-step batches.

        ``rollout="scan"`` compiles all ``k`` inner steps into one
        ``jax.lax.scan`` whose carry holds the (mixed) parameters and the
        opt/step state -- so ``gossip_every`` off-steps, the grad-accum
        microbatch scan, and the Birkhoff ppermute mixing all execute
        with no per-step Python dispatch and no host sync inside the
        segment (the per-step losses come back as one ``(k,)`` array).
        ``rollout="loop"`` dispatches the same jitted ``train_step`` per
        iteration from Python -- same trace per step, bit-identical
        trajectories (verified in tests/test_distributed.py) -- kept for
        debugging and A/B benchmarking, exactly like the simulator
        drivers in ``train/trainer.py``.

        Jit the scan variant (``jax.jit(setup.multi_step_fn())``) and
        feed it segments of ``k`` steps between eval points.

        With ``online_w=True`` both variants take the mixing matrix as a
        trailing argument -- ``multi_step(params, opt_state, batches,
        mix_w)`` -- and thread it through the scan as an ordinary traced
        operand: calling the same jitted multi-step with a refreshed W
        is a value change, not a shape change, so the hot swap compiles
        nothing (asserted in tests/test_distributed.py).

        With ``staleness`` set the signature grows per-STEP operands --
        ``multi_step(params, opt_state, batches, mix_stack, delays)``
        where ``mix_stack`` stacks the per-step mixing operand over a
        leading ``(k, ...)`` time axis (a ``ScheduleArrays`` of stacked
        gammas/perms, or ``(k, capacity)`` pool gammas) and ``delays``
        is ``(k, n)`` int32 -- both scanned as xs, so a straggler burst
        or a per-step degrade repair is pure data into the one trace.
        ``TrainSetup.run_segments`` builds these stacks from the policy
        and a raw delay trace; see ``straggler_stream`` /
        ``straggler_pool_stream``.
        """
        if rollout == "scan":
            def multi_step(params, momentum_state, batches, *mix_w):
                self._check_online_args(mix_w)
                stale = self.online_w and self.staleness is not None
                # fresh mixing operands are loop-invariant (closed over);
                # stale operands are per-step and scan as xs
                xs = (batches,) + mix_w if stale else batches

                def body(carry, x):
                    p, m = carry
                    step_args = x if stale else (x,) + mix_w
                    p, m, loss = self.train_step(p, m, *step_args)
                    return (p, m), loss

                (params, momentum_state), losses = jax.lax.scan(
                    body, (params, momentum_state), xs
                )
                return params, momentum_state, losses

            return multi_step
        if rollout == "loop":
            def multi_step(params, momentum_state, batches, *mix_w):
                self._check_online_args(mix_w)
                if self._jitted_step is None:
                    self._jitted_step = jax.jit(self.train_step)
                k = jax.tree_util.tree_leaves(batches)[0].shape[0]
                stale = self.online_w and self.staleness is not None
                losses = []
                for t in range(k):
                    batch_t = jax.tree_util.tree_map(lambda x: x[t], batches)
                    # per-step slices of the stacked stale operands; the
                    # fresh path passes mix_w through whole
                    extra = (
                        tuple(
                            jax.tree_util.tree_map(lambda x: x[t], w)
                            for w in mix_w
                        )
                        if stale
                        else mix_w
                    )
                    params, momentum_state, loss = self._jitted_step(
                        params, momentum_state, batch_t, *extra
                    )
                    losses.append(loss)
                # tree-stack, not jnp.stack: with probes the per-step
                # output is the {"loss", <probe>...} dict
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *losses
                )
                return params, momentum_state, stacked

            return multi_step
        raise ValueError(f"unknown rollout {rollout!r}")

    def _check_online_args(self, mix_w: tuple) -> None:
        if self.online_w and self.staleness is not None:
            if len(mix_w) != 2:
                raise TypeError(
                    "staleness setup: call multi_step(params, opt_state, "
                    "batches, mix_stack, delays)"
                )
            return
        if self.online_w and len(mix_w) != 1:
            raise TypeError(
                "online_w setup: call multi_step(params, opt_state, batches, mix_w)"
            )
        if not self.online_w and mix_w:
            raise TypeError(
                "this setup was built without online_w; no mix_w argument expected"
            )

    def run_segments(
        self,
        params,
        opt_state,
        batches,
        mix,
        *,
        segment_len: int,
        on_segment: Callable | None = None,
        rollout: str = "scan",
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 1,
        resume: bool = False,
        stop_after_segments: int | None = None,
        delays=None,
        quarantine=None,
        tracer: "Tracer | None" = None,
        retrace_guard=None,
    ) -> dict:
        """Segmented online rollout with hot-swap handoff at boundaries.

        Runs the jitted multi-step over ``segment_len``-step slices of
        ``batches`` (leaves ``(steps, ...)``), calling ``on_segment(t)``
        after every segment except the last (same contract as the
        simulator drivers in ``repro.train.trainer``). The hook may
        return:

        * ``None``            -- keep mixing with the current operand;
        * a ``ScheduleArrays`` or an ``(n, n)`` array -- swapped in as
          the next segments' ``mix_w`` (pure value change on the
          allgather transport: zero retraces);
        * a :class:`~repro.core.mixing.PoolSwap` -- pool-coordinate
          update: an in-pool swap replaces the gamma vector (zero
          retraces); a restage on the pool transport rebuilds the setup
          around the new pool and recompiles ONCE (counted in
          ``recompiles`` -- the logged pool-miss fallback), while on
          the all-gather transport (which executes pool gammas as their
          ``ScheduleArrays`` twin) even a restage is a pure value
          change.

        An overlapped refresh controller fits this hook unchanged: it
        returns ``None`` while its background solve runs and hands the
        finished swap back at a later boundary, so the rollout never
        waits on the solve.

        Crash recovery: with ``checkpoint_dir`` set, the carry
        (``params``, ``opt_state``, and the CURRENT mixing operand --
        so a pre-crash hot swap survives) is saved via
        ``repro.train.checkpoints`` every ``checkpoint_every``-th
        segment boundary, AFTER the hook (plus at the end and at an
        early stop). ``resume=True`` restores the newest checkpoint
        and continues; because the same jitted multi-step replays the
        same batch slices from the same restored values, the resumed
        trajectory is bitwise the uninterrupted one (asserted in
        tests). ``stop_after_segments`` ends the run early after that
        many executed segments -- the scripted "crash" of recovery
        drills -- recording ``stopped_at``. The checkpointed operand
        covers the value-swap paths (W / ScheduleArrays / in-pool
        gammas); a mid-run pool RESTAGE rebuilds the setup, which a
        checkpoint cannot capture -- resume from the returned ``setup``
        in that case.

        Bounded-delay gossip: on a ``staleness`` setup, ``delays`` is
        the raw ``(steps, n)`` non-negative delay trace (default all
        zeros -- bitwise the fresh run). Each segment resolves its slice
        against the policy host-side (``straggler_stream`` /
        ``straggler_pool_stream``) into per-step stacked operands, so
        wait-clamping, per-step degrade repairs, AND a hook's hot swap
        all stay value changes into the one compiled multi-step. The
        hook still trades in BASE operands (ScheduleArrays / pool
        gammas; dense W has no per-sender ring semantics and is
        rejected), and the checkpoint stores the base operand -- a
        resumed run re-resolves the same delays from ``t0``, bitwise.
        The meter splits delivered bytes into on-time vs deferred per
        the closed form (``comm["deferred_bytes"]``).

        Quarantine accounting: ``quarantine`` (duck-typed -- any object
        with ``mask() -> (n,) bool`` and ``summary() -> dict``, e.g. a
        :class:`repro.faults.quarantine.QuarantineController` whose
        screens run elsewhere) makes the meter charge the
        ``quarantined_bytes`` fate per segment from the all-gather
        closed form ``1 - (n-h)(n-h-1) / (n(n-1))`` for ``h`` isolated
        nodes (scaled into the delivered volume under staleness -- the
        model treats delay fates as independent of quarantine status),
        and the controller's lifecycle summary lands in the result
        under ``"quarantine"``. Typically the same controller also
        chains the topology hook: pass ``on_segment=qc.on_segment``.

        Telemetry: ``tracer`` (a ``repro.obs.Tracer``) records
        ``segment.rollout`` / ``segment.restage`` / ``segment.checkpoint``
        spans; ``retrace_guard`` (a ``repro.obs.RetraceGuard``) counts
        multi-step compiles under ``"run_segments.multi_step"``. On a
        ``probes`` setup the per-step health series come back under
        ``"health"`` (one ``(steps,)`` array per probe) while
        ``"losses"`` stays the plain loss trajectory.

        Returns ``{"params", "opt_state", "losses", "n_traces",
        "swaps", "recompiles", "segment_s", "comm", "setup", "mix",
        "resumed_from", "stopped_at"}``
        -- ``n_traces`` counts multi-step traces (1 when
        ``segment_len`` divides ``steps`` and no restage happened; a
        pool-transport restage adds exactly one), ``segment_s``
        per-segment wall seconds (the overlap benches' jitter probe),
        ``comm`` the :class:`~repro.train.metrics.CommMeter` summary of
        modeled mixing bytes. ``setup`` and ``mix`` are the LIVE setup
        (rebuilt if a restage happened -- continue chunked training
        from these, not from ``self``, or post-restage gammas would
        execute on the stale pool's staged permutations) and the final
        mixing operand.
        """
        if not self.online_w:
            raise ValueError("run_segments needs an online_w=True setup")
        if segment_len < 1:
            raise ValueError(f"segment_len must be >= 1, got {segment_len}")
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}"
            )
        steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
        setup = self
        tracer = _NULL_TRACER if tracer is None else tracer
        n_traces = 0
        if self.staleness is None:
            if delays is not None:
                raise ValueError(
                    "delays given but this setup has no staleness policy: "
                    "build with make_train_setup(staleness=StragglerPolicy(...))"
                )
        else:
            delays = (
                np.zeros((steps, setup.n_nodes), np.int64)
                if delays is None
                else np.asarray(delays, np.int64)
            )
            if delays.shape != (steps, setup.n_nodes):
                raise ValueError(
                    f"delays must be ({steps}, {setup.n_nodes}), "
                    f"got {delays.shape}"
                )
            if delays.size and delays.min() < 0:
                raise ValueError("delays must be non-negative")

        def jit_counted(ms):
            def counted(p, m, b, *w):
                nonlocal n_traces
                n_traces += 1
                if retrace_guard is not None:
                    retrace_guard.record("run_segments.multi_step")
                return ms(p, m, b, *w)

            return jax.jit(counted)

        msj = jit_counted(setup.multi_step_fn(rollout))
        pool = setup.pool
        mix = _as_mix_operand(mix, setup, pool)

        def stale_stream(base, d_seg):
            # resolve this segment's delay slice against the policy into
            # per-step stacked scan operands (host-side control plane)
            pol = setup.staleness
            if isinstance(base, ScheduleArrays):
                g, p, eff = straggler_stream(pol, base, d_seg)
                return ScheduleArrays(gammas=g, perms=p), eff
            arr = np.asarray(base)
            if arr.ndim == 1:
                g, eff = straggler_pool_stream(pol, base, pool, d_seg)
                return g, eff
            raise ValueError(
                "staleness needs a ScheduleArrays or pool-gamma mixing "
                "operand: a dense (n, n) W has no per-sender payload to "
                "delay (decompose it with schedule_from_matrix)"
            )

        meter = CommMeter(per_step_bytes=setup.comm_bytes_per_step or 0)
        losses, swaps, segment_s = [], [], []
        probe_names = (
            setup.probes.names() if setup.probes is not None else ()
        )
        health_l: dict[str, list] = {nm: [] for nm in probe_names}
        recompiles = 0
        t0 = 0
        resumed_from = None
        stopped_at = None
        if checkpoint_dir is not None and resume:
            last = latest_step(checkpoint_dir)
            if last is not None:
                like = {"params": params, "opt": opt_state, "mix": mix}
                tree, _meta = restore_checkpoint(checkpoint_dir, last, like)
                params, opt_state, mix = tree["params"], tree["opt"], tree["mix"]
                t0 = int(last)
                resumed_from = t0

        def save(t: int) -> None:
            with tracer.span("segment.checkpoint", t=int(t)):
                save_checkpoint(
                    checkpoint_dir,
                    t,
                    {"params": params, "opt": opt_state, "mix": mix},
                    metadata={"t": int(t)},
                )

        seg_idx = 0
        while t0 < steps:
            k = min(segment_len, steps - t0)
            seg = jax.tree_util.tree_map(lambda x: x[t0 : t0 + k], batches)
            tic = time.perf_counter()
            with tracer.span("segment.rollout", t0=t0, k=k):
                if setup.staleness is not None:
                    d_seg = delays[t0 : t0 + k]
                    w_stack, eff = stale_stream(mix, d_seg)
                    params, opt_state, loss = msj(
                        params, opt_state, seg, w_stack, eff
                    )
                else:
                    params, opt_state, loss = msj(params, opt_state, seg, mix)
                # segment wall time is the overlap probe (loss may be the
                # probes dict -- block on the whole tree)
                loss = jax.block_until_ready(loss)
            segment_s.append(time.perf_counter() - tic)
            if quarantine is not None:
                h = int(np.asarray(quarantine.mask(), bool).sum())
                n = setup.n_nodes
                q_share = (
                    1.0 - (n - h) * (n - h - 1) / (n * (n - 1))
                    if n > 1 and h > 0 else 0.0
                )
            else:
                q_share = 0.0
            if setup.staleness is not None:
                fates = [
                    staleness_transfer_fracs(
                        d_seg[j], setup.staleness.tau_max, setup.staleness.mode
                    )
                    for j in range(k)
                ]
                on_time = float(np.mean([f[0] for f in fates]))
                deferred = float(np.mean([f[1] for f in fates]))
                delivered = on_time + deferred
                meter.tick(
                    k, delivered_frac=delivered, deferred_frac=deferred,
                    quarantined_frac=delivered * q_share,
                )
            else:
                meter.tick(k, quarantined_frac=q_share)
            if probe_names:
                losses.append(np.asarray(loss["loss"]))
                for nm in probe_names:
                    health_l[nm].append(np.asarray(loss[nm]))
            else:
                losses.append(np.asarray(loss))
            t0 += k
            seg_idx += 1
            # no hook after the final segment (nothing executes it)
            if on_segment is not None and t0 < steps:
                update = on_segment(t0 - 1)
                if update is not None:
                    swaps.append(t0 - 1)
                    if isinstance(update, PoolSwap) and update.restaged:
                        pool = update.pool
                        if setup.sharded_transport == "pool":
                            # pool miss: the new atoms are not compiled in
                            # -- rebuild the step around the restaged pool
                            # (the ONE counted recompile)
                            with tracer.span("segment.restage", t=t0 - 1):
                                setup = setup._rebuild(pool)
                                msj = jit_counted(setup.multi_step_fn(rollout))
                            recompiles += 1
                            meter.set_rate(
                                setup.comm_bytes_per_step or 0, step=t0
                            )
                        # on the all-gather transport the restaged atoms
                        # execute as ScheduleArrays data: no rebuild, no
                        # recompile
                    mix = _as_mix_operand(update, setup, pool)
            if checkpoint_dir is not None and (
                seg_idx % checkpoint_every == 0 or t0 >= steps
            ):
                save(t0)
            if (
                stop_after_segments is not None
                and seg_idx >= stop_after_segments
                and t0 < steps
            ):
                if checkpoint_dir is not None and seg_idx % checkpoint_every != 0:
                    save(t0)  # the crash drill must leave a resumable state
                stopped_at = t0
                break
        out = {
            "params": params,
            "opt_state": opt_state,
            "losses": np.concatenate(losses) if losses else np.zeros((0,)),
            "n_traces": n_traces,
            "swaps": swaps,
            "recompiles": recompiles,
            "segment_s": segment_s,
            "comm": meter.summary(),
            "setup": setup,
            "mix": mix,
            "resumed_from": resumed_from,
            "stopped_at": stopped_at,
        }
        if quarantine is not None:
            out["quarantine"] = quarantine.summary()
        if probe_names:
            empty = np.zeros((0,))
            out["health"] = {
                nm: (np.concatenate(v) if v else empty)
                for nm, v in health_l.items()
            }
        return out

    # rebuilds this setup around a restaged PermPool (set by
    # make_train_setup; a manually constructed TrainSetup cannot restage)
    _rebuild: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # builds the initial opt/comm state (set by make_train_setup, which
    # knows the momentum/gossip_every/compression carry convention)
    _init_opt_state: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    # cached jax.jit of train_step for the "loop" rollout (recompiling it
    # per multi_step call would defeat the A/B comparison)
    _jitted_step: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False
    )


def _as_mix_operand(update, setup: "TrainSetup", pool: PermPool | None):
    """Normalize a hook return / initial mix into the step's operand.

    ``pool`` is the CURRENTLY staged pool (tracked by ``run_segments``
    across restages). Pool-coordinate gammas are accepted on either
    transport: the pool transport consumes them directly; the
    all-gather transport (e.g. ``sharded_transport="auto"`` resolving
    against the pool) executes them as ``pool.arrays_for(gammas)`` --
    the bitwise-equal ScheduleArrays twin -- so the same controller
    drives both without caring which transport won the autotune.
    """
    if isinstance(update, PoolSwap):
        update = update.gammas
    if isinstance(update, ScheduleArrays):
        return update
    arr = np.asarray(update, np.float32)
    if setup.sharded_transport == "pool":
        if arr.shape != (setup.pool.capacity,):
            raise ValueError(
                f"pool transport expects ({setup.pool.capacity},) gammas, "
                f"got {arr.shape}"
            )
        return jnp.asarray(arr)
    if pool is not None and arr.ndim == 1:
        if arr.shape != (pool.capacity,):
            raise ValueError(
                f"pool-coordinate gammas must be ({pool.capacity},), "
                f"got {arr.shape}"
            )
        return pool.arrays_for(arr)
    return jnp.asarray(arr)


def gossip_fn(
    mesh: Mesh, schedule: BirkhoffSchedule | None, axis: str, param_specs: PyTree
) -> Callable[[PyTree], PyTree]:
    """Mixing transport over ``axis``: Birkhoff ppermute schedule, or pmean
    when ``schedule`` is None (complete graph / C-PSGD)."""

    node_specs = jax.tree_util.tree_map(
        lambda s: P(axis), param_specs, is_leaf=lambda x: isinstance(x, P)
    )

    def mix(params: PyTree) -> PyTree:
        def inner(p):
            if schedule is None:
                # f32 reduction: numerics + XLA-CPU bf16 all-reduce workaround
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.pmean(x.astype(jnp.float32), axis).astype(x.dtype),
                    p,
                )
            return mix_ppermute(p, schedule, axis)

        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(node_specs,),
            out_specs=node_specs,
            axis_names={axis},
            check_vma=False,
        )(params)

    return mix


def _sgd_update(params, grads, momentum_state, lr, momentum):
    if momentum > 0.0:
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, momentum_state, grads
        )
        new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
        return new_p, new_m
    new_p = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_p, momentum_state


def make_train_setup(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    mode: str = "dsgd",
    schedule: BirkhoffSchedule | None = None,
    lr: float = 1e-3,
    momentum: float = 0.0,
    impl: str = "xla",
    grad_accum: int = 1,
    gossip_every: int = 1,
    online_w: bool = False,
    sharded_transport: str = "auto",
    pool: PermPool | None = None,
    compression: "Compressor | str | None" = None,
    staleness: "StragglerPolicy | None" = None,
    probes: "HealthProbes | None" = None,
) -> TrainSetup:
    """Build the distributed train step for (cfg, mesh, mode).

    ``schedule=None`` in dsgd/dsgd_pod modes means complete-graph mixing.
    ``online_w=True`` builds the *online-adaptation* step: the mixing
    operand is a trailing data argument (``train_step(params,
    opt_state, batch, mix_w)``) instead of a baked-in schedule, so a
    mid-training topology refresh swaps it with zero retraces. In dsgd
    mode the per-node mixing transport is then picked by
    ``sharded_transport``:

    * ``"allgather"`` -- ``mix_w`` is an (n, n) W (``mix_dense_sharded``)
      or a ``ScheduleArrays`` (``mix_arrays_sharded``): any topology
      swaps as data, at O(n P) bytes per node per step.
    * ``"pool"``      -- requires ``pool``; ``mix_w`` is the
      ``(pool.capacity,)`` gamma vector and mixing runs as
      ``mix_ppermute_pool``: O(pool.n_comm_slots x P) bytes -- the
      learned topology's sparse-communication payoff -- and in-pool
      swaps are pure value changes. Out-of-pool refreshes restage via
      ``TrainSetup.run_segments`` (one counted recompile).
    * ``"auto"``      -- the measured sharded autotune table when a
      bucket exists, else the ``preferred_sharded_transport`` closed
      form (``repro.core.mixing``); resolves to ``"allgather"`` when no
      pool is given. The resolved choice is recorded on
      ``TrainSetup.sharded_transport``.

    Incompatible with a static ``schedule`` and with fsdp mode (whose
    all-reduce has no W); ``pool`` requires online_w dsgd mode (the
    dsgd_pod online path mixes by GSPMD einsum, W as data).
    ``grad_accum > 1`` splits the per-step batch into microbatches and
    accumulates gradients in a scan -- same math, ~grad_accum x smaller
    live-activation footprint (the big lever for DeepSeek-V2 -- §Perf).
    ``gossip_every = k > 1`` mixes only every k-th step (time-varying
    W^(t) with W = I on off-steps -- covered by the paper's changing-
    topology analysis): amortizes gossip bytes by 1/k. The step function
    then takes a step counter through the momentum_state slot convention
    (see train_step signature below: ``step`` is carried in opt state).

    ``compression`` (a ``repro.core.compression.Compressor`` or a spec
    string -- ``"identity"``, ``"bf16"``, ``"topk:<frac>"``) turns the
    online mixing into CHOCO-style EF-compressed gossip: every
    transport's payload passes through the wire format, the per-node
    error-feedback memory travels in the opt-state dict under ``"ef"``
    (build it with ``TrainSetup.init_opt_state`` -- it rides the scan
    carry, so hot swaps stay zero-retrace), and
    ``TrainSetup.comm_bytes_per_step`` meters the compressed wire
    (bf16: exactly half; top-k: k value+index pairs). Only the
    retrace-free dsgd online transports compose: fsdp (all-reduce, no
    per-edge payload -- e.g. ``compression="topk:0.1"`` with
    ``mode="fsdp"`` is meaningless), dsgd_pod (GSPMD einsum, no EF
    carry), and offline (static-schedule) setups are rejected
    explicitly. The identity wire routes to the uncompressed transports
    at trace time, so it is bitwise the ``compression=None`` run -- the
    A/B control arm.

    ``staleness`` (a ``repro.core.mixing.StragglerPolicy``) turns the
    online mixing into bounded-delay gossip: every node keeps a
    sender-side ring of its last ``tau_max + 1`` wire payloads in the
    opt-state dict under ``"stale"`` (build it with
    ``TrainSetup.init_opt_state`` -- it rides the scan carry next to
    the EF memory, so hot swaps stay zero-retrace), and the step takes
    a per-step ``(n,)`` delay vector as a second trailing data argument
    after ``mix_w``. A straggler's payload is then consumed
    ``delays[i]`` pushes old; ``delays == 0`` reads back the value just
    pushed, reproducing the fresh transports bitwise. Only the
    per-sender-payload transports compose (ScheduleArrays on allgather,
    gammas on pool -- a dense (n, n) ``mix_w`` is rejected at mix
    time); fsdp/dsgd_pod (no per-node ring) and ``gossip_every > 1``
    (off-steps would desynchronize ring pushes from consumption) are
    rejected explicitly. Composes with ``compression``: the ring then
    stores the compressed wire payload and the EF memory stays local
    and fresh (see ``repro.core.compression``).

    ``probes`` (a ``repro.obs.HealthProbes``; ``consensus`` and
    ``grad_dev`` only) threads the paper's health quantities through
    the shard_map as collective value computations (``pmean`` /
    ``psum`` over the node axis -- same numbers as the stacked-host
    probes, asserted in tests): the step's loss output becomes the
    ``{"loss", <probe>...}`` dict of replicated scalars, per-step
    series land in ``run_segments``' ``"health"``, and the loss
    trajectory is BITWISE the probes-off run's. ``tau_bar`` is
    rejected here -- the pool transport never materializes W's
    coefficients in the carry; use the simulator drivers. Requires the
    online_w dsgd step (fsdp has one global model, so consensus is
    identically zero; dsgd_pod mixes by GSPMD einsum outside the
    manual node axis).
    """
    compressor = make_compressor(compression)
    if probes is not None:
        if not isinstance(probes, HealthProbes):
            raise TypeError(
                f"probes must be a HealthProbes, got {type(probes).__name__}"
            )
        if probes.tau_bar:
            raise ValueError(
                "the tau_bar probe needs the in-carry ScheduleArrays of the "
                "simulator drivers (run_mean_estimation / run_classification); "
                "the mesh transports never carry W's coefficients"
            )
        if mode != "dsgd":
            raise ValueError(
                f"health probes are incompatible with mode={mode!r}: they "
                "are collectives over the manual dsgd node axis (fsdp has "
                "one global model -- consensus is identically 0; dsgd_pod "
                "mixes by GSPMD einsum)"
            )
        if not online_w:
            raise ValueError(
                "health probes ride the online (retrace-free) step: build "
                "with online_w=True"
            )
    if staleness is not None:
        if not isinstance(staleness, StragglerPolicy):
            raise TypeError(
                f"staleness must be a StragglerPolicy, got {type(staleness)}"
            )
        if mode != "dsgd":
            raise ValueError(
                f"staleness is incompatible with mode={mode!r}: the "
                "bounded-delay ring is per-NODE sender state, which only "
                "the dsgd shard_map transports carry (fsdp all-reduces "
                "in-network; dsgd_pod mixes by GSPMD einsum)"
            )
        if not online_w:
            raise ValueError(
                "staleness rides the online (retrace-free) transports: "
                "build with online_w=True"
            )
        if gossip_every > 1:
            raise ValueError(
                f"staleness is incompatible with gossip_every={gossip_every}: "
                "off-steps would push no ring slot while delays keep "
                "counting pushes, silently re-basing every delay -- run "
                "bounded-delay gossip with gossip_every=1"
            )
    if compressor is not None:
        if mode == "fsdp":
            raise ValueError(
                f"compression={compressor.label!r} is incompatible with "
                "mode='fsdp': the C-PSGD baseline mixes by in-network "
                "all-reduce, so there is no per-edge gossip payload for a "
                "wire format to compress"
            )
        if mode == "dsgd_pod":
            raise ValueError(
                f"compression={compressor.label!r} is incompatible with "
                "mode='dsgd_pod': cross-pod mixing is a GSPMD einsum with "
                "no EF memory carry; use mode='dsgd'"
            )
        if not online_w:
            raise ValueError(
                "compression rides the online (retrace-free) transports: "
                "build with online_w=True"
            )
    if online_w and mode == "fsdp":
        raise ValueError("online_w needs a node axis (dsgd/dsgd_pod); fsdp has no W")
    if online_w and schedule is not None:
        raise ValueError(
            "online_w and a static schedule are mutually exclusive -- pass the "
            "initial W as the mix_w argument of the step instead"
        )
    if sharded_transport not in ("auto", "allgather", "pool"):
        raise ValueError(f"unknown sharded_transport {sharded_transport!r}")
    if pool is not None and not (online_w and mode == "dsgd"):
        raise ValueError("a PermPool requires online_w=True and mode='dsgd'")
    if sharded_transport == "pool" and pool is None:
        raise ValueError("sharded_transport='pool' requires a PermPool")
    axes = mesh.axis_names
    if mode == "dsgd":
        node_axis = "data"
        n_nodes = mesh.shape["data"]
        fsdp_axis = None
    elif mode == "dsgd_pod":
        if "pod" not in axes:
            raise ValueError("dsgd_pod requires a 'pod' mesh axis")
        node_axis = "pod"
        n_nodes = mesh.shape["pod"]
        fsdp_axis = "data"
    elif mode == "fsdp":
        node_axis = None
        n_nodes = 1
        fsdp_axis = "data"
    else:
        raise ValueError(f"unknown mode {mode}")

    if schedule is not None and node_axis is not None and schedule.n_nodes != n_nodes:
        raise ValueError(
            f"schedule has {schedule.n_nodes} nodes, mesh axis '{node_axis}' "
            f"provides {n_nodes}"
        )

    def init_single(rng):
        return registry.init_model(rng, cfg)

    if node_axis is not None:
        def init_params(rng):
            p = init_single(rng)
            # Algorithm 1: theta_i^(0) = theta^(0) -- same init on all nodes.
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape), p
            )
    else:
        init_params = init_single

    if pool is not None and pool.n_nodes != n_nodes:
        raise ValueError(
            f"pool is staged for {pool.n_nodes} nodes, mesh axis "
            f"'{node_axis}' provides {n_nodes}"
        )

    params_proto = jax.eval_shape(init_params, jax.random.PRNGKey(0))
    param_specs = make_param_specs(
        params_proto, mesh, node_axis=node_axis, fsdp_axis=fsdp_axis
    )

    # per-NODE parameter count (leaves carry the leading node axis in
    # node modes) -- the P of the bytes/step accounting and the sharded
    # autotune bucket. TP over `model` divides the per-DEVICE share, not
    # the per-node collective volume modeled here.
    p_total = sum(
        int(np.prod(leaf.shape[1:] if node_axis is not None else leaf.shape,
                    dtype=np.int64))
        for leaf in jax.tree_util.tree_leaves(params_proto)
    )

    # Resolve the hot-swappable sharded transport (satellite of ISSUE 5:
    # consult the measured table / closed form instead of hardcoding the
    # all-gather). Lookup-only: unmeasured hardware falls back to the
    # conservative preferred_sharded_transport crossover.
    resolved_transport: str | None = None
    comm_bytes: int | None = None
    if mode == "dsgd":
        if online_w:
            if sharded_transport == "auto":
                resolved_transport = (
                    "allgather"
                    if pool is None
                    else autotune_sharded_transport(
                        n_nodes, pool.n_comm_slots, p_total
                    )
                )
            else:
                resolved_transport = sharded_transport
            comm_bytes = mix_bytes_per_step(
                "pool" if resolved_transport == "pool" else "allgather",
                n_nodes=n_nodes,
                p_total=p_total,
                n_comm_atoms=pool.n_comm_slots if resolved_transport == "pool" else None,
                compression=compressor,
            )
        elif schedule is not None:
            comm_bytes = mix_bytes_per_step(
                "ppermute", n_nodes=n_nodes, p_total=p_total,
                n_comm_atoms=schedule.n_communication_atoms,
            )
        else:
            comm_bytes = mix_bytes_per_step(
                "allreduce", n_nodes=n_nodes, p_total=p_total
            )

    # batch sharding:
    #   dsgd:      leaves (n_nodes, per_node, ...) -> P(data, None, ...)
    #   dsgd_pod:  leaves (n_pod, per_pod, ...)    -> P(pod, data, ...)
    #   fsdp:      leaves (batch, ...)             -> P((pod?, data), ...)
    if mode == "dsgd":
        batch_prefix = ("data", None)
    elif mode == "dsgd_pod":
        batch_prefix = ("pod", "data")
    else:
        # true-FSDP batch sharding: batch over data AND model (weights are
        # gathered per layer-group; grads reduce-scatter back)
        dp = ("pod", "data", "model") if "pod" in axes else ("data", "model")
        batch_prefix = (tuple(dp),)

    def batch_spec_for(leaf_ndim: int) -> P:
        pad = [None] * (leaf_ndim - len(batch_prefix))
        return P(*batch_prefix, *pad)

    loss_of = lambda p, b: registry.loss_fn(p, cfg, b, impl=impl)[0]
    grad_of_single = jax.value_and_grad(loss_of)

    if grad_accum > 1:
        def grad_of(p, b):
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
                b,
            )

            def body(acc, mb):
                loss_acc, g_acc = acc
                loss, g = grad_of_single(p, mb)
                g_new = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_new), None

            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p
            )
            (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), micro)
            g_mean = jax.tree_util.tree_map(
                lambda g, x: (g / grad_accum).astype(x.dtype), g_sum, p
            )
            return loss_sum / grad_accum, g_mean
    else:
        grad_of = grad_of_single

    def _step_impl(params, momentum_state, batch, mix_w=None, delays=None):
        if node_axis is None:
            loss, grads = grad_of(params, batch)
            new_params, new_m = _sgd_update(params, grads, momentum_state, lr, momentum)
            return new_params, new_m, loss

        if mode == "dsgd_pod":
            # Cross-pod gossip as a dense mixing einsum over the (tiny) pod
            # axis: GSPMD lowers the contraction over the pod-sharded axis
            # to cross-pod collectives. (A partial-manual shard_map over
            # `pod` with auto data/model axes crashes this XLA version's
            # SPMD partitioner -- see EXPERIMENTS.md.)
            import numpy as _np

            losses, grads = jax.vmap(grad_of)(params, batch)
            half, new_m = _sgd_update(params, grads, momentum_state, lr, momentum)
            if online_w:
                if isinstance(mix_w, ScheduleArrays) or getattr(mix_w, "ndim", 2) != 2:
                    raise TypeError(
                        "dsgd_pod online mixing is a GSPMD einsum over the pod "
                        "axis: pass mix_w as a dense (n, n) W (pool gammas / "
                        "ScheduleArrays are dsgd-mode operands)"
                    )
                W_pod = mix_w.astype(jnp.float32)
            else:
                W_pod = (
                    jnp.asarray(schedule.to_matrix(), jnp.float32)
                    if schedule is not None
                    else jnp.full((n_nodes, n_nodes), 1.0 / n_nodes, jnp.float32)
                )
            mixed = jax.tree_util.tree_map(
                lambda x: jnp.einsum(
                    "pq,q...->p...", W_pod, x.astype(jnp.float32)
                ).astype(x.dtype),
                half,
            )
            return mixed, new_m, losses.mean()

        # The node axis is *manual* (shard_map over `node_axis`): each shard
        # owns exactly one node's replica, so node-local activations can
        # never silently replicate across nodes. TP over `model` (and, in
        # dsgd_pod mode, data-parallel grads over `data`) stays automatic
        # inside the shard.
        squeeze = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        unsqueeze = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)

        def per_node(p, m, b, *w_args):
            p1, b1 = squeeze(p), squeeze(b)
            step = m.get("step") if isinstance(m, dict) else None
            m_tree = m.get("m") if isinstance(m, dict) else m
            m1 = squeeze(m_tree) if momentum > 0.0 else None
            ef_tree = m.get("ef") if isinstance(m, dict) else None
            if compressor is not None and ef_tree is None:
                raise ValueError(
                    "compressed mixing carries its error-feedback memory in "
                    "the opt state: pass momentum_state including an 'ef' "
                    "entry (build it with TrainSetup.init_opt_state)"
                )
            e1 = squeeze(ef_tree) if ef_tree is not None else None
            stale_tree = m.get("stale") if isinstance(m, dict) else None
            if staleness is not None and stale_tree is None:
                raise ValueError(
                    "bounded-delay mixing carries its sender-side ring in "
                    "the opt state: pass momentum_state including a 'stale' "
                    "entry (build it with TrainSetup.init_opt_state)"
                )
            st1 = (
                ShardStaleState(
                    rings=squeeze(stale_tree["buf"]), head=stale_tree["head"]
                )
                if stale_tree is not None
                else None
            )
            # In dsgd_pod mode the within-pod `data` axis stays automatic:
            # GSPMD data-parallelizes the loss/grad over it (the batch input
            # sharding carries P(pod, data, ...)).
            loss, grads = grad_of(p1, b1)
            half, new_m = _sgd_update(p1, grads, m1, lr, momentum)

            def do_mix(h):
                if online_w:
                    w = w_args[0]
                    if resolved_transport == "pool":
                        return mix_ppermute_pool(h, w, pool, node_axis)
                    if isinstance(w, ScheduleArrays):
                        return mix_arrays_sharded(h, w, node_axis)
                    return mix_dense_sharded(h, w, node_axis)
                if schedule is None:
                    return jax.tree_util.tree_map(
                        lambda x: jax.lax.pmean(x.astype(jnp.float32), node_axis).astype(x.dtype),
                        h,
                    )
                return mix_ppermute(h, schedule, node_axis)

            def do_mix_ef(he):
                # EF-compressed online transports: same dispatch as
                # do_mix, with the wire format static and the EF memory
                # threaded as data (the hot-swap story is unchanged)
                h, e = he
                w = w_args[0]
                if resolved_transport == "pool":
                    return mix_ppermute_pool_ef(
                        h, e, w, pool, node_axis, compressor
                    )
                if isinstance(w, ScheduleArrays):
                    return mix_arrays_sharded_ef(h, e, w, node_axis, compressor)
                return mix_dense_sharded_ef(h, e, W=w, axis_name=node_axis,
                                            compressor=compressor)

            if gossip_every > 1 and step is None:
                raise ValueError(
                    "gossip_every > 1 needs a step counter: pass "
                    "momentum_state={'step': jnp.zeros((), jnp.int32), 'm': ...}"
                )
            new_e1 = None
            new_st1 = None
            if staleness is not None:
                # bounded-delay dispatch: same transport fork as do_mix,
                # with the sender-side ring and this step's delay vector
                # threaded as data (gossip_every > 1 was rejected at
                # build time, so every step both pushes and mixes)
                w, d = w_args
                stale_dense_msg = (
                    "staleness needs a per-sender payload to delay: pass "
                    "mix_w as ScheduleArrays (allgather) or pool gammas, "
                    "not a dense (n, n) W"
                )
                if compressor is not None:
                    if resolved_transport == "pool":
                        mixed, new_e1, new_st1 = mix_ppermute_pool_stale_ef(
                            half, e1, st1, w, pool, d, node_axis, compressor
                        )
                    elif isinstance(w, ScheduleArrays):
                        mixed, new_e1, new_st1 = mix_arrays_sharded_stale_ef(
                            half, e1, st1, w, d, node_axis, compressor
                        )
                    else:
                        raise TypeError(stale_dense_msg)
                else:
                    if resolved_transport == "pool":
                        mixed, new_st1 = mix_ppermute_pool_stale(
                            half, st1, w, pool, d, node_axis
                        )
                    elif isinstance(w, ScheduleArrays):
                        mixed, new_st1 = mix_arrays_sharded_stale(
                            half, st1, w, d, node_axis
                        )
                    else:
                        raise TypeError(stale_dense_msg)
            elif compressor is not None:
                if gossip_every > 1:
                    mixed, new_e1 = jax.lax.cond(
                        jnp.mod(step, gossip_every) == 0,
                        do_mix_ef,
                        lambda he: he,
                        (half, e1),
                    )
                else:
                    mixed, new_e1 = do_mix_ef((half, e1))
            elif gossip_every > 1:
                mixed = jax.lax.cond(
                    jnp.mod(step, gossip_every) == 0, do_mix, lambda h: h, half
                )
            else:
                mixed = do_mix(half)
            loss_mean = jax.lax.pmean(loss, node_axis)
            if probes is not None:
                # collective twins of the stacked-host probes: psum over
                # nodes of this shard's squared distance to the pmean.
                # Pure value computations on this step's mixed params /
                # grads -- extra replicated outputs, zero extra traces.
                def spread_sq(tree):
                    tot = jnp.zeros((), jnp.float32)
                    for x in jax.tree_util.tree_leaves(tree):
                        xf = x.astype(jnp.float32)
                        mu = jax.lax.pmean(xf, node_axis)
                        tot = tot + jax.lax.psum(
                            jnp.sum(jnp.square(xf - mu)), node_axis
                        )
                    return tot

                loss_out = {"loss": loss_mean}
                if probes.consensus:
                    loss_out["consensus"] = spread_sq(mixed)
                if probes.grad_dev:
                    loss_out["grad_dev"] = spread_sq(grads) / n_nodes
            else:
                loss_out = loss_mean
            new_m_tree = unsqueeze(new_m) if momentum > 0.0 else m_tree
            if isinstance(m, dict):
                new_m_out = {}
                if "step" in m:
                    new_m_out["step"] = step + 1
                if "m" in m:
                    new_m_out["m"] = new_m_tree
                if "ef" in m:
                    new_m_out["ef"] = (
                        unsqueeze(new_e1) if new_e1 is not None else ef_tree
                    )
                if "stale" in m:
                    new_m_out["stale"] = (
                        {"buf": unsqueeze(new_st1.rings), "head": new_st1.head}
                        if new_st1 is not None
                        else stale_tree
                    )
            else:
                new_m_out = new_m_tree
            return unsqueeze(mixed), new_m_out, loss_out

        node_specs = jax.tree_util.tree_map(
            lambda s: P(node_axis), param_specs, is_leaf=lambda x: isinstance(x, P)
        )
        m_inner = node_specs if momentum > 0.0 else None
        if isinstance(momentum_state, dict):
            key_spec = {
                "step": P(),
                "m": m_inner,
                "ef": node_specs,
                # ring leaves carry (n, depth, *shape): node-sharded like
                # params; the head counter is a replicated scalar
                "stale": {"buf": node_specs, "head": P()},
            }
            mom_specs = {k: key_spec[k] for k in momentum_state}
        else:
            mom_specs = m_inner
        bspec = jax.tree_util.tree_map(lambda _: P(node_axis), batch)
        in_specs = (node_specs, mom_specs, bspec)
        args = (params, momentum_state, batch)
        if online_w:
            # mixing operand replicated to every node shard; tree-mapped
            # so ScheduleArrays (a 2-leaf pytree) and flat gammas/W all fit
            w_specs = jax.tree_util.tree_map(lambda _: P(), mix_w)
            in_specs = in_specs + (w_specs,)
            args = args + (mix_w,)
            if staleness is not None:
                # the (n,) delay vector is replicated; each node picks
                # its own entry by axis_index inside the transport
                in_specs = in_specs + (P(),)
                args = args + (delays,)
        loss_specs = (
            {"loss": P(), **{nm: P() for nm in probes.names()}}
            if probes is not None
            else P()
        )
        return shard_map(
            per_node,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(node_specs, mom_specs, loss_specs),
            axis_names={node_axis},
            check_vma=False,
        )(*args)

    if online_w and staleness is not None:
        def train_step(params, momentum_state, batch, mix_w, delays):
            return _step_impl(params, momentum_state, batch, mix_w, delays)
    elif online_w:
        def train_step(params, momentum_state, batch, mix_w):
            return _step_impl(params, momentum_state, batch, mix_w)
    else:
        def train_step(params, momentum_state, batch):
            return _step_impl(params, momentum_state, batch)

    def rebuild(new_pool: PermPool) -> TrainSetup:
        # pool-miss fallback: same setup, new staged atoms (the one
        # counted recompile of TrainSetup.run_segments)
        return make_train_setup(
            cfg, mesh, mode=mode, schedule=schedule, lr=lr, momentum=momentum,
            impl=impl, grad_accum=grad_accum, gossip_every=gossip_every,
            online_w=online_w, sharded_transport="pool", pool=new_pool,
            compression=compressor, staleness=staleness, probes=probes,
        )

    def init_opt_state(params: PyTree):
        # the momentum_state the step expects for this configuration:
        # a dict of the present slots ({'step','m','ef'} keys), a bare
        # momentum tree when only momentum is on, None when stateless
        out: dict = {}
        if gossip_every > 1:
            out["step"] = jnp.zeros((), jnp.int32)
        if momentum > 0.0:
            out["m"] = jax.tree_util.tree_map(jnp.zeros_like, params)
        if compressor is not None:
            out["ef"] = ef_init(params)
        if staleness is not None:
            # per-node sender-side ring, all ring_depth slots primed with
            # the initial payload (a day-one straggler reads the shared
            # init, never garbage); leaves (n, depth, *shape) in f32, the
            # wire dtype
            out["stale"] = {
                "buf": jax.tree_util.tree_map(
                    lambda x: jnp.tile(
                        x.astype(jnp.float32)[:, None],
                        (1, staleness.ring_depth) + (1,) * (x.ndim - 1),
                    ),
                    params,
                ),
                "head": jnp.zeros((), jnp.int32),
            }
        if not out:
            return None
        if set(out) == {"m"}:
            return out["m"]
        return out

    return TrainSetup(
        train_step=train_step,
        init_params=init_params,
        param_specs=param_specs,
        batch_spec=batch_spec_for,
        mode=mode,
        n_nodes=n_nodes,
        online_w=online_w,
        sharded_transport=resolved_transport,
        pool=pool,
        comm_bytes_per_step=comm_bytes,
        compression=compressor,
        staleness=staleness,
        probes=probes,
        _rebuild=rebuild,
        _init_opt_state=init_opt_state,
    )

"""Mesh-sharded large-model trainer: D-SGD over the data axis + tensor
parallelism over the model axis.

Three distribution modes (DESIGN.md Section 3.2):

* ``dsgd``     -- each index of the ``data`` mesh axis is one D-SGD node
                  holding its own model replica (params get a leading node
                  axis sharded over ``data``; each replica is TP-sharded over
                  ``model``). The mixing step executes the learned topology's
                  Birkhoff decomposition as a ``ppermute`` schedule
                  (d_max collective-permutes instead of an all-reduce).
* ``fsdp``     -- C-PSGD baseline / fallback: one global model, params
                  sharded over (data x model), gradients all-reduced by
                  GSPMD. Equivalent to D-SGD with W = 11^T/n.
* ``dsgd_pod`` -- multi-pod: pods are the D-SGD nodes (params stacked over
                  ``pod``); within a pod, classic data parallelism; across
                  pods, the sparse gossip schedule rides the slow DCN links.

``make_train_setup`` returns everything the launcher / dry-run needs:
the jitted-able step function, in/out shardings, and abstract input specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.mixing import BirkhoffSchedule, mix_dense_sharded, mix_ppermute
from repro.models import registry
from repro.models.common import ModelConfig
from .sharding import make_param_specs

PyTree = Any

__all__ = ["TrainSetup", "make_train_setup", "gossip_fn"]


@dataclasses.dataclass
class TrainSetup:
    """Everything needed to jit / lower a distributed train step.

    With ``online_w=True`` the step function takes the mixing matrix as
    a trailing *data* argument -- ``train_step(params, opt_state, batch,
    mix_w)`` -- so an online topology refresh swaps W by passing a
    different (n, n) array, never by rebuilding/retracing the step.
    """

    train_step: Callable  # (params, opt_state, batch[, mix_w]) -> (params, opt_state, loss)
    init_params: Callable  # (rng) -> params (abstract-safe via jax.eval_shape)
    param_specs: PyTree
    batch_spec: PyTree
    mode: str
    n_nodes: int
    online_w: bool = False

    def abstract_params(self) -> PyTree:
        return jax.eval_shape(self.init_params, jax.random.PRNGKey(0))

    def multi_step_fn(self, rollout: str = "scan") -> Callable:
        """Multi-step train fn: ``(params, opt_state, batches) -> (params,
        opt_state, losses)`` where every ``batches`` leaf carries a leading
        time axis ``(k, ...)`` of per-step batches.

        ``rollout="scan"`` compiles all ``k`` inner steps into one
        ``jax.lax.scan`` whose carry holds the (mixed) parameters and the
        opt/step state -- so ``gossip_every`` off-steps, the grad-accum
        microbatch scan, and the Birkhoff ppermute mixing all execute
        with no per-step Python dispatch and no host sync inside the
        segment (the per-step losses come back as one ``(k,)`` array).
        ``rollout="loop"`` dispatches the same jitted ``train_step`` per
        iteration from Python -- same trace per step, bit-identical
        trajectories (verified in tests/test_distributed.py) -- kept for
        debugging and A/B benchmarking, exactly like the simulator
        drivers in ``train/trainer.py``.

        Jit the scan variant (``jax.jit(setup.multi_step_fn())``) and
        feed it segments of ``k`` steps between eval points.

        With ``online_w=True`` both variants take the mixing matrix as a
        trailing argument -- ``multi_step(params, opt_state, batches,
        mix_w)`` -- and thread it through the scan as an ordinary traced
        operand: calling the same jitted multi-step with a refreshed W
        is a value change, not a shape change, so the hot swap compiles
        nothing (asserted in tests/test_distributed.py).
        """
        if rollout == "scan":
            def multi_step(params, momentum_state, batches, *mix_w):
                self._check_online_args(mix_w)

                def body(carry, batch_t):
                    p, m = carry
                    p, m, loss = self.train_step(p, m, batch_t, *mix_w)
                    return (p, m), loss

                (params, momentum_state), losses = jax.lax.scan(
                    body, (params, momentum_state), batches
                )
                return params, momentum_state, losses

            return multi_step
        if rollout == "loop":
            def multi_step(params, momentum_state, batches, *mix_w):
                self._check_online_args(mix_w)
                if self._jitted_step is None:
                    self._jitted_step = jax.jit(self.train_step)
                k = jax.tree_util.tree_leaves(batches)[0].shape[0]
                losses = []
                for t in range(k):
                    batch_t = jax.tree_util.tree_map(lambda x: x[t], batches)
                    params, momentum_state, loss = self._jitted_step(
                        params, momentum_state, batch_t, *mix_w
                    )
                    losses.append(loss)
                return params, momentum_state, jnp.stack(losses)

            return multi_step
        raise ValueError(f"unknown rollout {rollout!r}")

    def _check_online_args(self, mix_w: tuple) -> None:
        if self.online_w and len(mix_w) != 1:
            raise TypeError(
                "online_w setup: call multi_step(params, opt_state, batches, mix_w)"
            )
        if not self.online_w and mix_w:
            raise TypeError(
                "this setup was built without online_w; no mix_w argument expected"
            )

    # cached jax.jit of train_step for the "loop" rollout (recompiling it
    # per multi_step call would defeat the A/B comparison)
    _jitted_step: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False
    )


def gossip_fn(
    mesh: Mesh, schedule: BirkhoffSchedule | None, axis: str, param_specs: PyTree
) -> Callable[[PyTree], PyTree]:
    """Mixing transport over ``axis``: Birkhoff ppermute schedule, or pmean
    when ``schedule`` is None (complete graph / C-PSGD)."""

    node_specs = jax.tree_util.tree_map(
        lambda s: P(axis), param_specs, is_leaf=lambda x: isinstance(x, P)
    )

    def mix(params: PyTree) -> PyTree:
        def inner(p):
            if schedule is None:
                # f32 reduction: numerics + XLA-CPU bf16 all-reduce workaround
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.pmean(x.astype(jnp.float32), axis).astype(x.dtype),
                    p,
                )
            return mix_ppermute(p, schedule, axis)

        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(node_specs,),
            out_specs=node_specs,
            axis_names={axis},
            check_vma=False,
        )(params)

    return mix


def _sgd_update(params, grads, momentum_state, lr, momentum):
    if momentum > 0.0:
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, momentum_state, grads
        )
        new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
        return new_p, new_m
    new_p = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new_p, momentum_state


def make_train_setup(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    mode: str = "dsgd",
    schedule: BirkhoffSchedule | None = None,
    lr: float = 1e-3,
    momentum: float = 0.0,
    impl: str = "xla",
    grad_accum: int = 1,
    gossip_every: int = 1,
    online_w: bool = False,
) -> TrainSetup:
    """Build the distributed train step for (cfg, mesh, mode).

    ``schedule=None`` in dsgd/dsgd_pod modes means complete-graph mixing.
    ``online_w=True`` builds the *online-adaptation* step: the mixing
    matrix is a trailing (n, n) data argument (``train_step(params,
    opt_state, batch, mix_w)``) instead of a baked-in schedule, so a
    mid-training topology refresh swaps W with zero retraces. In dsgd
    mode the per-node mixing then runs as ``mix_dense_sharded``
    (all-gather + row contraction -- O(n P) bytes where the static
    ppermute schedule moves d_max permutes; the documented price of
    hot-swappability, see repro.core.mixing). Incompatible with a
    static ``schedule`` and with fsdp mode (whose all-reduce has no W).
    ``grad_accum > 1`` splits the per-step batch into microbatches and
    accumulates gradients in a scan -- same math, ~grad_accum x smaller
    live-activation footprint (the big lever for DeepSeek-V2 -- §Perf).
    ``gossip_every = k > 1`` mixes only every k-th step (time-varying
    W^(t) with W = I on off-steps -- covered by the paper's changing-
    topology analysis): amortizes gossip bytes by 1/k. The step function
    then takes a step counter through the momentum_state slot convention
    (see train_step signature below: ``step`` is carried in opt state).
    """
    if online_w and mode == "fsdp":
        raise ValueError("online_w needs a node axis (dsgd/dsgd_pod); fsdp has no W")
    if online_w and schedule is not None:
        raise ValueError(
            "online_w and a static schedule are mutually exclusive -- pass the "
            "initial W as the mix_w argument of the step instead"
        )
    axes = mesh.axis_names
    if mode == "dsgd":
        node_axis = "data"
        n_nodes = mesh.shape["data"]
        fsdp_axis = None
    elif mode == "dsgd_pod":
        if "pod" not in axes:
            raise ValueError("dsgd_pod requires a 'pod' mesh axis")
        node_axis = "pod"
        n_nodes = mesh.shape["pod"]
        fsdp_axis = "data"
    elif mode == "fsdp":
        node_axis = None
        n_nodes = 1
        fsdp_axis = "data"
    else:
        raise ValueError(f"unknown mode {mode}")

    if schedule is not None and node_axis is not None and schedule.n_nodes != n_nodes:
        raise ValueError(
            f"schedule has {schedule.n_nodes} nodes, mesh axis '{node_axis}' "
            f"provides {n_nodes}"
        )

    def init_single(rng):
        return registry.init_model(rng, cfg)

    if node_axis is not None:
        def init_params(rng):
            p = init_single(rng)
            # Algorithm 1: theta_i^(0) = theta^(0) -- same init on all nodes.
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape), p
            )
    else:
        init_params = init_single

    params_proto = jax.eval_shape(init_params, jax.random.PRNGKey(0))
    param_specs = make_param_specs(
        params_proto, mesh, node_axis=node_axis, fsdp_axis=fsdp_axis
    )

    # batch sharding:
    #   dsgd:      leaves (n_nodes, per_node, ...) -> P(data, None, ...)
    #   dsgd_pod:  leaves (n_pod, per_pod, ...)    -> P(pod, data, ...)
    #   fsdp:      leaves (batch, ...)             -> P((pod?, data), ...)
    if mode == "dsgd":
        batch_prefix = ("data", None)
    elif mode == "dsgd_pod":
        batch_prefix = ("pod", "data")
    else:
        # true-FSDP batch sharding: batch over data AND model (weights are
        # gathered per layer-group; grads reduce-scatter back)
        dp = ("pod", "data", "model") if "pod" in axes else ("data", "model")
        batch_prefix = (tuple(dp),)

    def batch_spec_for(leaf_ndim: int) -> P:
        pad = [None] * (leaf_ndim - len(batch_prefix))
        return P(*batch_prefix, *pad)

    loss_of = lambda p, b: registry.loss_fn(p, cfg, b, impl=impl)[0]
    grad_of_single = jax.value_and_grad(loss_of)

    if grad_accum > 1:
        def grad_of(p, b):
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
                b,
            )

            def body(acc, mb):
                loss_acc, g_acc = acc
                loss, g = grad_of_single(p, mb)
                g_new = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_new), None

            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p
            )
            (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), micro)
            g_mean = jax.tree_util.tree_map(
                lambda g, x: (g / grad_accum).astype(x.dtype), g_sum, p
            )
            return loss_sum / grad_accum, g_mean
    else:
        grad_of = grad_of_single

    def _step_impl(params, momentum_state, batch, mix_w=None):
        if node_axis is None:
            loss, grads = grad_of(params, batch)
            new_params, new_m = _sgd_update(params, grads, momentum_state, lr, momentum)
            return new_params, new_m, loss

        if mode == "dsgd_pod":
            # Cross-pod gossip as a dense mixing einsum over the (tiny) pod
            # axis: GSPMD lowers the contraction over the pod-sharded axis
            # to cross-pod collectives. (A partial-manual shard_map over
            # `pod` with auto data/model axes crashes this XLA version's
            # SPMD partitioner -- see EXPERIMENTS.md.)
            import numpy as _np

            losses, grads = jax.vmap(grad_of)(params, batch)
            half, new_m = _sgd_update(params, grads, momentum_state, lr, momentum)
            if online_w:
                W_pod = mix_w.astype(jnp.float32)
            else:
                W_pod = (
                    jnp.asarray(schedule.to_matrix(), jnp.float32)
                    if schedule is not None
                    else jnp.full((n_nodes, n_nodes), 1.0 / n_nodes, jnp.float32)
                )
            mixed = jax.tree_util.tree_map(
                lambda x: jnp.einsum(
                    "pq,q...->p...", W_pod, x.astype(jnp.float32)
                ).astype(x.dtype),
                half,
            )
            return mixed, new_m, losses.mean()

        # The node axis is *manual* (shard_map over `node_axis`): each shard
        # owns exactly one node's replica, so node-local activations can
        # never silently replicate across nodes. TP over `model` (and, in
        # dsgd_pod mode, data-parallel grads over `data`) stays automatic
        # inside the shard.
        squeeze = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        unsqueeze = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)

        def per_node(p, m, b, *w_args):
            p1, b1 = squeeze(p), squeeze(b)
            step = m.get("step") if isinstance(m, dict) else None
            m_tree = m.get("m") if isinstance(m, dict) else m
            m1 = squeeze(m_tree) if momentum > 0.0 else None
            # In dsgd_pod mode the within-pod `data` axis stays automatic:
            # GSPMD data-parallelizes the loss/grad over it (the batch input
            # sharding carries P(pod, data, ...)).
            loss, grads = grad_of(p1, b1)
            half, new_m = _sgd_update(p1, grads, m1, lr, momentum)

            def do_mix(h):
                if online_w:
                    return mix_dense_sharded(h, w_args[0], node_axis)
                if schedule is None:
                    return jax.tree_util.tree_map(
                        lambda x: jax.lax.pmean(x.astype(jnp.float32), node_axis).astype(x.dtype),
                        h,
                    )
                return mix_ppermute(h, schedule, node_axis)

            if gossip_every > 1:
                if step is None:
                    raise ValueError(
                        "gossip_every > 1 needs a step counter: pass "
                        "momentum_state={'step': jnp.zeros((), jnp.int32), 'm': ...}"
                    )
                mixed = jax.lax.cond(
                    jnp.mod(step, gossip_every) == 0, do_mix, lambda h: h, half
                )
            else:
                mixed = do_mix(half)
            loss_mean = jax.lax.pmean(loss, node_axis)
            new_m_tree = unsqueeze(new_m) if momentum > 0.0 else m_tree
            if isinstance(m, dict):
                new_m_out = {"step": step + 1, "m": new_m_tree}
            else:
                new_m_out = new_m_tree
            return unsqueeze(mixed), new_m_out, loss_mean

        node_specs = jax.tree_util.tree_map(
            lambda s: P(node_axis), param_specs, is_leaf=lambda x: isinstance(x, P)
        )
        m_inner = node_specs if momentum > 0.0 else None
        if isinstance(momentum_state, dict):
            mom_specs = {"step": P(), "m": m_inner}
        else:
            mom_specs = m_inner
        bspec = jax.tree_util.tree_map(lambda _: P(node_axis), batch)
        in_specs = (node_specs, mom_specs, bspec)
        args = (params, momentum_state, batch)
        if online_w:
            in_specs = in_specs + (P(),)  # W replicated to every node shard
            args = args + (mix_w,)
        return shard_map(
            per_node,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(node_specs, mom_specs, P()),
            axis_names={node_axis},
            check_vma=False,
        )(*args)

    if online_w:
        def train_step(params, momentum_state, batch, mix_w):
            return _step_impl(params, momentum_state, batch, mix_w)
    else:
        def train_step(params, momentum_state, batch):
            return _step_impl(params, momentum_state, batch)

    return TrainSetup(
        train_step=train_step,
        init_params=init_params,
        param_specs=param_specs,
        batch_spec=batch_spec_for,
        mode=mode,
        n_nodes=n_nodes,
        online_w=online_w,
    )

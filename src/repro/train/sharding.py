"""Partition rules: map parameter-tree paths to PartitionSpecs.

Tensor-parallel (Megatron-style) rules over the ``model`` mesh axis, with
optional FSDP-style sharding of the complementary dimension over ``data``
(needed for DeepSeek-V2-236B, which does not fit replicated-per-node).

Every candidate spec is *sanitized* against the actual leaf shape and mesh:
an axis is dropped (set to None) when the dimension is not divisible by the
mesh-axis size, so rules can be written optimistically and remain safe for
every architecture in the zoo.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

__all__ = [
    "tp_spec_for_path",
    "make_param_specs",
    "make_param_shardings",
    "sanitize_spec",
]

# keyword -> (axis_to_shard_over_model, is_expert_tensor)
# axis indices refer to the *unstacked* parameter (no node axis).
_COL_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up", "w_uk", "w_uv",
                 "w_in", "w_rnn_in", "w_a", "w_x", "w_ff_up", "w_dkv",
                 "router")
_ROW_PARALLEL = ("wo", "w_down", "w_out", "w_ff_down")
_EXPERT = ("routed",)
_VOCAB_PARALLEL = ("table", "token_embed", "unembed")


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the corresponding dim."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % size == 0 else None)
    # pad to rank
    while len(out) < len(shape):
        out.append(None)
    return P(*out)


def tp_spec_for_path(path: str, shape: tuple[int, ...], *, fsdp_axis: str | None = None) -> P:
    """Tensor-parallel spec for an unstacked parameter.

    ``fsdp_axis`` additionally shards the complementary matrix dimension
    (weights at rest) over the given axis.
    """
    rank = len(shape)
    d = fsdp_axis

    def spec(*entries):
        ent = list(entries) + [None] * (rank - len(entries))
        return P(*ent[:rank])

    if any(k in path for k in _EXPERT):
        # stacked expert tensors (E, d, f): expert-parallel over model
        return spec("model", d, None)
    if any(path.endswith(k) or f"'{k}'" in path for k in _VOCAB_PARALLEL):
        if "unembed" in path:
            return spec(d, "model")  # (d, V)
        return spec("model", d)  # (V, d)
    if any(f"'{k}'" in path for k in _COL_PARALLEL):
        return spec(d, "model")  # (d, X): shard output features
    if any(f"'{k}'" in path for k in _ROW_PARALLEL):
        return spec("model", d)  # (X, d): shard input features
    if "'r'" in path and rank == 4:  # sLSTM recurrent (4, h, dh, dh)
        return spec(None, "model", None, None)
    if "'lam'" in path and rank == 1:
        return spec("model")
    return P(*([None] * rank))


def make_param_specs(
    params: PyTree,
    mesh: Mesh,
    *,
    node_axis: str | None = None,
    fsdp_axis: str | None = None,
) -> PyTree:
    """PartitionSpec tree for a parameter tree.

    ``node_axis``: mesh axis carrying the leading D-SGD node dimension
    (``dsgd`` mode stacks per-node replicas). ``fsdp_axis``: axis for
    weights-at-rest sharding (``fsdp`` / ``dsgd_pod`` modes).
    """

    def leaf_spec(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        prefix: list = []
        rest = shape
        if node_axis is not None:  # leading D-SGD node-replica axis
            prefix.append(node_axis)
            rest = rest[1:]
        if "stages" in pstr:  # leading layer-scan group axis (stacked params)
            prefix.append(None)
            rest = rest[1:]
        inner = tp_spec_for_path(pstr, rest, fsdp_axis=fsdp_axis)
        spec = sanitize_spec(P(*prefix, *inner), shape, mesh)
        # fallback: a big leaf whose rule got fully sanitized away (e.g. an
        # odd vocab size) still gets model-sharded on any divisible dim.
        body = list(spec)[len(prefix):]
        if all(e is None for e in body) and leaf.size * 2 > 32 * 2**20:
            msize = mesh.shape["model"]
            for i in reversed(range(len(prefix), len(shape))):
                if shape[i] % msize == 0:
                    dims = list(spec)
                    dims[i] = "model"
                    spec = P(*dims)
                    break
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def make_param_shardings(param_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

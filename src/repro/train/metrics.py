"""Training metrics for decentralized runs.

The quantities the paper plots: per-node error/accuracy (min/mean/max across
nodes -- the dashed lines of Fig. 1), consensus distance
``||Theta - Theta_bar||_F^2`` (the quantity controlled by Lemma 3), and
standard loss aggregation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "consensus_distance",
    "node_spread",
    "MetricLogger",
    "mix_bytes_per_step",
    "staleness_transfer_fracs",
    "CommMeter",
]


def staleness_transfer_fracs(
    delays, tau_max: int, mode: str = "wait"
) -> tuple[float, float, float]:
    """Closed-form fate split of one step's n(n-1) directed transfers
    under a raw per-source delay vector: ``(on_time, deferred,
    dropped)``, summing to 1.

    The all-gather model: every node sends to every other node, and a
    source with delay d > 0 delivers ALL its transfers late. Under
    ``"wait"`` nothing is dropped -- late payloads are consumed stale
    (``deferred``). Under ``"degrade"`` a source past the ``tau_max``
    deadline is cut for the step (the repaired schedule self-loops it,
    BOTH directions), so its transfers move from deferred to dropped
    and the delivered support shrinks to the on-time nodes. This is the
    pure-staleness twin of
    :meth:`repro.faults.plan.FaultPlan.transfer_fracs` (which folds in
    crashes and edge drops) and the closed form the CI smoke checks the
    meter against.
    """
    if mode not in ("wait", "degrade"):
        raise ValueError(f"mode must be 'wait' or 'degrade', got {mode!r}")
    d = np.asarray(delays).reshape(-1)
    n = d.shape[0]
    if n < 2:
        return 1.0, 0.0, 0.0
    on = d <= tau_max if mode == "degrade" else np.ones(n, bool)
    n_on = int(on.sum())
    total = n * (n - 1)
    delivered = n_on * (n_on - 1)
    deferred = int(((d > 0) & on).sum()) * (n_on - 1)
    return (
        (delivered - deferred) / total,
        deferred / total,
        (total - delivered) / total,
    )


def mix_bytes_per_step(
    transport: str,
    *,
    n_nodes: int,
    p_total: int,
    n_comm_atoms: int | None = None,
    itemsize: int = 4,
    alive_frac: float = 1.0,
    compression=None,
) -> int:
    """Bytes RECEIVED per node per mixing step, by transport.

    The counter the comm accounting (and the bench acceptance ratios)
    runs on -- a closed-form model of the collective, not a NIC
    counter: every listed transport moves a deterministic byte volume
    per step, so the model IS the measurement up to wire framing.
    ``p_total`` is one node's parameter count; transfers run in f32
    (``itemsize=4``) in all the hot-swappable transports.

    ===========  =========================  ==============================
    transport    bytes/node/step            which mix function
    ===========  =========================  ==============================
    dense        0 (single host)            mix_dense / mix_schedule_*
    allgather    (n - 1) * P * itemsize     mix_dense_sharded /
                                            mix_arrays_sharded
    ppermute     n_comm_atoms * P * item    mix_ppermute (static) --
                                            non-identity atoms only
    pool         n_comm_atoms * P * item    mix_ppermute_pool -- staged
                                            non-identity SLOTS (gamma 0
                                            still transfers)
    allreduce    2 (n-1)/n * P * itemsize   mix_allreduce (ring model)
    ===========  =========================  ==============================

    ``alive_frac`` scales the fleet for degraded runs: with a fraction
    of nodes crashed, a dead peer sends nothing (its repaired atom
    entries are self-loops, which move zero bytes), so the effective
    gather degree shrinks proportionally. ``alive_frac=1.0`` (default)
    is the fault-free model above; the faults runner instead keeps the
    full-rate model here and meters per-step delivery honestly through
    :meth:`CommMeter.tick`'s ``delivered_frac``.

    ``compression`` (a ``repro.core.compression.Compressor``, a spec
    string like ``"bf16"`` / ``"topk:0.25"``, or None) swaps the
    per-payload wire layout: the element count and per-element width
    above become the compressor's ``wire_layout(p_total, itemsize)`` --
    bf16 ships the same elements at 2 bytes (EXACTLY half the f32
    model, including under fractional ``alive_frac``), top-k ships
    ``k = max(1, int(P * frac))`` value+index pairs at ``itemsize + 4``
    bytes each. Only the payload-moving transports compose: ``dense``
    moves nothing, and ``allreduce`` reduces in-network (there is no
    per-edge payload a CHOCO wire could compress), so a non-identity
    compressor there is rejected rather than silently ignored.
    """
    from repro.core.compression import make_compressor

    comp = make_compressor(compression)
    if n_nodes < 1 or p_total < 0:
        raise ValueError(f"bad n_nodes={n_nodes} / p_total={p_total}")
    if not 0.0 <= alive_frac <= 1.0:
        raise ValueError(f"alive_frac must be in [0, 1], got {alive_frac}")
    if comp is None or comp.is_identity or p_total == 0:
        wire_elems, wire_itemsize = p_total, itemsize
    else:
        wire_elems, wire_itemsize = comp.wire_layout(p_total, itemsize)
    if transport == "dense":
        return 0
    if transport == "allgather":
        # (alive - 1) peers actually send; floor at zero for a lone node
        senders = max(alive_frac * n_nodes - 1.0, 0.0)
        return int(senders * wire_elems) * wire_itemsize
    if transport in ("ppermute", "pool"):
        if n_comm_atoms is None:
            raise ValueError(f"transport={transport!r} needs n_comm_atoms")
        return int(alive_frac * n_comm_atoms * wire_elems) * wire_itemsize
    if transport == "allreduce":
        if comp is not None and not comp.is_identity:
            raise ValueError(
                "allreduce has no compressed wire: the ring reduces "
                "in-network, so a CHOCO compressor does not apply -- use a "
                "gossip transport (allgather/ppermute/pool) for compression"
            )
        n_alive = max(alive_frac * n_nodes, 1.0)
        return int(2 * (n_alive - 1) / n_alive * p_total) * itemsize
    raise ValueError(f"unknown transport {transport!r}")


@dataclasses.dataclass
class CommMeter:
    """Accumulates the modeled communication of a training run.

    ``per_step_bytes`` is per NODE per step (the :func:`mix_bytes_per_step`
    unit); a transport change mid-run (e.g. a pool restage that grows
    the staged slot count) updates it via :meth:`set_rate`, which also
    records the change as an event.

    Degraded paths stay honest: ``tick(k, delivered_frac=f)`` splits
    the modeled volume into delivered bytes (``total_bytes``) and bytes
    lost to dead nodes / dropped edges (``dropped_bytes``) -- the BENCH
    curves charge only what actually arrived. Self-loop fallbacks move
    zero bytes so they need no counting; retransmissions DO arrive and
    are added on top via :meth:`retransmit` (``retransmit_bytes``,
    also folded into ``total_bytes``).

    Bounded-delay gossip adds a third fate: a straggler's payload that
    ARRIVES, late. ``tick(k, delivered_frac=f, deferred_frac=d)``
    records that ``d`` of the step's volume was delivered past its
    deadline (``deferred_bytes``, a SUBSET of ``total_bytes`` -- late
    bytes still cross the wire and are charged as delivered, unlike
    dropped bytes, which never arrive). The degrade policy converts
    would-be-deferred transfers into dropped ones (the repaired
    schedule self-loops them), so the deferred/dropped split is exactly
    the wait-vs-degrade policy decision, metered.

    Quarantine adds a fourth fate, also a SUBSET of delivered:
    ``tick(k, ..., quarantined_frac=q)`` records that ``q`` of the
    step's volume crossed the wire touching a quarantined endpoint --
    bytes that were moved but then excluded from consensus by the
    quarantine repair (the repaired W self-loops the node). They are
    the honest cost of the detection window and of keeping a suspect
    isolated; the screen's value proposition (bytes protected vs bytes
    forfeited) is read directly off this counter.
    """

    per_step_bytes: int = 0
    steps: int = 0
    total_bytes: int = 0
    dropped_bytes: int = 0
    deferred_bytes: int = 0
    quarantined_bytes: int = 0
    retransmit_bytes: int = 0
    events: list = dataclasses.field(default_factory=list)

    def tick(
        self,
        k: int = 1,
        delivered_frac: float = 1.0,
        deferred_frac: float = 0.0,
        quarantined_frac: float = 0.0,
    ) -> None:
        if not 0.0 <= delivered_frac <= 1.0:
            raise ValueError(
                f"delivered_frac must be in [0, 1], got {delivered_frac}"
            )
        if not 0.0 <= deferred_frac <= delivered_frac:
            raise ValueError(
                f"deferred_frac must be in [0, delivered_frac="
                f"{delivered_frac}], got {deferred_frac} (deferred bytes "
                f"are a subset of delivered bytes)"
            )
        if not 0.0 <= quarantined_frac <= delivered_frac:
            raise ValueError(
                f"quarantined_frac must be in [0, delivered_frac="
                f"{delivered_frac}], got {quarantined_frac} (quarantined "
                f"bytes are a subset of delivered bytes)"
            )
        self.steps += int(k)
        volume = int(k) * self.per_step_bytes
        delivered = int(volume * delivered_frac)
        self.total_bytes += delivered
        self.dropped_bytes += volume - delivered
        # Derive deferred from the already-truncated delivered volume, not
        # from a second independent int(volume * frac) truncation: the
        # subset invariant (deferred <= delivered, per tick and hence
        # cumulatively) must hold by CONSTRUCTION, not by both roundings
        # happening to land the same way under fractional fates.
        if delivered_frac > 0.0:
            deferred = int(delivered * (deferred_frac / delivered_frac))
            quarantined = int(delivered * (quarantined_frac / delivered_frac))
        else:
            deferred = 0
            quarantined = 0
        self.deferred_bytes += deferred
        self.quarantined_bytes += quarantined

    def retransmit(self, nbytes: int) -> None:
        """Count a successful re-send (delivered, on top of the model)."""
        self.retransmit_bytes += int(nbytes)
        self.total_bytes += int(nbytes)

    def set_rate(self, per_step_bytes: int, step: int | None = None) -> None:
        if per_step_bytes != self.per_step_bytes:
            self.events.append(
                {"step": self.steps if step is None else int(step),
                 "per_step_bytes": int(per_step_bytes)}
            )
        self.per_step_bytes = int(per_step_bytes)

    def summary(self) -> dict:
        return {
            "per_step_bytes": self.per_step_bytes,
            "steps": self.steps,
            "total_bytes": self.total_bytes,
            "dropped_bytes": self.dropped_bytes,
            "deferred_bytes": self.deferred_bytes,
            "quarantined_bytes": self.quarantined_bytes,
            "retransmit_bytes": self.retransmit_bytes,
            "rate_changes": list(self.events),
        }


def consensus_distance(params_stack: PyTree) -> jax.Array:
    """``||Theta - Theta_bar||_F^2`` over stacked per-node parameters."""
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(params_stack):
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        total = total + jnp.sum(jnp.square((leaf - mean).astype(jnp.float32)))
    return total


def node_spread(values: jax.Array) -> dict[str, float]:
    """min/mean/max over the node axis (Fig. 1's solid + dashed lines)."""
    v = np.asarray(values)
    if v.size == 0:
        raise ValueError(
            "node_spread: empty value array -- no nodes to aggregate (did "
            "an eval produce zero rows?)"
        )
    return {"min": float(v.min()), "mean": float(v.mean()), "max": float(v.max())}


@dataclasses.dataclass
class MetricLogger:
    """In-memory metric store with CSV export (offline container: no W&B).

    ``aux`` carries run-level (non-per-step) diagnostics -- e.g. the
    online drivers record ``n_traces`` (compiled-rollout trace count;
    must stay 1 across schedule hot-swaps) and ``swaps`` there.
    """

    history: list[dict] = dataclasses.field(default_factory=list)
    aux: dict = dataclasses.field(default_factory=dict)

    def log(self, step: int, **metrics: float) -> None:
        row = {"step": step}
        row.update({k: float(v) for k, v in metrics.items()})
        self.history.append(row)

    def column(self, key: str, aligned: bool = False) -> np.ndarray:
        """Values of ``key`` across the history.

        By default rows missing the key are skipped (the historical
        behavior -- fine when the key is logged every row, silently
        misaligning otherwise). ``aligned=True`` returns one entry per
        history row, ``nan`` where the key is absent, so two columns
        with different logging cadences can be compared index-to-index.
        """
        if aligned:
            return np.array(
                [float(row.get(key, np.nan)) for row in self.history]
            )
        return np.array([row[key] for row in self.history if key in row])

    @staticmethod
    def _cell(row: dict, key: str) -> str:
        # explicit empty cell for BOTH missing keys and NaN values --
        # previously a missing key wrote "" but a logged NaN wrote the
        # bare token "nan", so the two kinds of absence were
        # indistinguishable from a real column value in some readers
        v = row.get(key)
        if v is None or (isinstance(v, float) and np.isnan(v)):
            return ""
        return str(v)

    def to_csv(self, path: str) -> None:
        if not self.history:
            return
        keys = sorted({k for row in self.history for k in row})
        with open(path, "w") as f:
            f.write(",".join(keys) + "\n")
            for row in self.history:
                f.write(",".join(self._cell(row, k) for k in keys) + "\n")

    def to_jsonl(self, path: str) -> None:
        """One JSON object per history row (the report pipeline's format:
        ragged rows survive verbatim, no column alignment, NaN -> null)."""
        import json

        with open(path, "w") as f:
            for row in self.history:
                clean = {
                    k: (None if isinstance(v, float) and np.isnan(v) else v)
                    for k, v in row.items()
                }
                f.write(json.dumps(clean) + "\n")

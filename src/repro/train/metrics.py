"""Training metrics for decentralized runs.

The quantities the paper plots: per-node error/accuracy (min/mean/max across
nodes -- the dashed lines of Fig. 1), consensus distance
``||Theta - Theta_bar||_F^2`` (the quantity controlled by Lemma 3), and
standard loss aggregation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["consensus_distance", "node_spread", "MetricLogger"]


def consensus_distance(params_stack: PyTree) -> jax.Array:
    """``||Theta - Theta_bar||_F^2`` over stacked per-node parameters."""
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(params_stack):
        mean = jnp.mean(leaf, axis=0, keepdims=True)
        total = total + jnp.sum(jnp.square((leaf - mean).astype(jnp.float32)))
    return total


def node_spread(values: jax.Array) -> dict[str, float]:
    """min/mean/max over the node axis (Fig. 1's solid + dashed lines)."""
    v = np.asarray(values)
    return {"min": float(v.min()), "mean": float(v.mean()), "max": float(v.max())}


@dataclasses.dataclass
class MetricLogger:
    """In-memory metric store with CSV export (offline container: no W&B).

    ``aux`` carries run-level (non-per-step) diagnostics -- e.g. the
    online drivers record ``n_traces`` (compiled-rollout trace count;
    must stay 1 across schedule hot-swaps) and ``swaps`` there.
    """

    history: list[dict] = dataclasses.field(default_factory=list)
    aux: dict = dataclasses.field(default_factory=dict)

    def log(self, step: int, **metrics: float) -> None:
        row = {"step": step}
        row.update({k: float(v) for k, v in metrics.items()})
        self.history.append(row)

    def column(self, key: str) -> np.ndarray:
        return np.array([row[key] for row in self.history if key in row])

    def to_csv(self, path: str) -> None:
        if not self.history:
            return
        keys = sorted({k for row in self.history for k in row})
        with open(path, "w") as f:
            f.write(",".join(keys) + "\n")
            for row in self.history:
                f.write(",".join(str(row.get(k, "")) for k in keys) + "\n")

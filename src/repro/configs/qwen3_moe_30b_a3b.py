"""qwen3-moe-30b-a3b [moe] -- 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]

Qwen3-MoE details: head_dim 128, per-head q/k RMSNorm, no QKV bias, no
shared experts, expert FFN width 768 (the assigned d_ff), RoPE theta 1e6.
"""

from repro.models.common import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
        tie_embeddings=False,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=96,
        vocab_size=512,
        qk_norm=True,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96, capacity_factor=8.0),
        tie_embeddings=False,
    )

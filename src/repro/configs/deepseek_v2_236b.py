"""deepseek-v2-236b [moe] -- 60L d_model=5120 128H d_ff=1536 (expert width)
vocab=102400. MLA with kv_lora=512, decoupled RoPE 64; MoE with 2 shared +
160 routed experts, top-6. [arXiv:2405.04434]

Hardware note (DESIGN.md §Arch-applicability): at ~236B params this arch
does NOT fit the per-node-replica `dsgd` mode on a single 256-chip v5e pod;
it trains in `fsdp` (C-PSGD) mode single-pod and `dsgd_pod` mode multi-pod.
"""

from repro.models.common import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=1536,
        vocab_size=102400,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        mla=MLAConfig(
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            d_ff_expert=1536,
            num_shared_experts=2,
            d_ff_shared=3072,
        ),
        tie_embeddings=False,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=96,
        vocab_size=512,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        mla=MLAConfig(
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=4,
            top_k=2,
            d_ff_expert=96,
            capacity_factor=8.0,
            num_shared_experts=1,
            d_ff_shared=96,
        ),
        tie_embeddings=False,
    )

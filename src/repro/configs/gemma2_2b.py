"""gemma2-2b [dense] -- 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000. Alternating local(4096)/global attention, attention softcap
50, final-logit softcap 30, pre+post block RMSNorms, GeGLU, head_dim 256.
[arXiv:2408.00118]
"""

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        arch_type="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        layer_pattern=("local_attn", "attn"),
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_block_norms=True,
        mlp_type="geglu",
        tie_embeddings=True,
        embedding_scale=True,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        layer_pattern=("local_attn", "attn"),
        sliding_window=8,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_block_norms=True,
        mlp_type="geglu",
        tie_embeddings=True,
        embedding_scale=True,
    )

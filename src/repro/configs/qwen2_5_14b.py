"""qwen2.5-14b [dense] -- 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064. GQA with QKV bias, SwiGLU, RoPE theta 1e6.
[hf:Qwen/Qwen2.5-0.5B family card]
"""

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        arch_type="dense",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        attn_bias=True,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        tie_embeddings=False,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=160,
        num_heads=5,
        num_kv_heads=1,
        head_dim=32,
        d_ff=288,
        vocab_size=512,
        attn_bias=True,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        tie_embeddings=False,
    )

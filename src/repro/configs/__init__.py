"""Architecture configs: one module per assigned architecture.

``get_config(name)`` returns the exact full config; ``get_smoke_config(name)``
returns the reduced same-family variant used by CPU smoke tests
(<= 2 layers, d_model <= 512, <= 4 experts).

Input shapes (assigned):
  train_4k     seq 4096,   global batch 256   (train_step)
  prefill_32k  seq 32768,  global batch 32    (serve prefill)
  decode_32k   seq 32768,  global batch 128   (serve decode: 1 new token)
  long_500k    seq 524288, global batch 1     (sub-quadratic decode)
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

ARCH_NAMES = (
    "qwen3_moe_30b_a3b",
    "gemma_2b",
    "qwen2_5_14b",
    "xlstm_350m",
    "deepseek_v2_236b",
    "gemma2_2b",
    "qwen3_0_6b",
    "whisper_small",
    "llava_next_mistral_7b",
    "recurrentgemma_2b",
)

# canonical ids as assigned (hyphenated) -> module names
ARCH_IDS = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "gemma-2b": "gemma_2b",
    "qwen2.5-14b": "qwen2_5_14b",
    "xlstm-350m": "xlstm_350m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gemma2-2b": "gemma2_2b",
    "qwen3-0.6b": "qwen3_0_6b",
    "whisper-small": "whisper_small",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode_long"},
}


def _module(name: str):
    mod = ARCH_IDS.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def all_configs() -> dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCH_IDS}

"""recurrentgemma-2b [hybrid] -- 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000. Griffin block pattern: (RG-LRU, RG-LRU, local attention),
window 2048, GeGLU MLP after every temporal block, head_dim 256.
[arXiv:2402.19427]
"""

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        arch_type="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        layer_pattern=("rglru", "rglru", "local_attn"),
        sliding_window=2048,
        mlp_type="geglu",
        tie_embeddings=True,
        embedding_scale=True,
        rnn_width=2560,
        conv_width=4,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        arch_type="hybrid",
        num_layers=3,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        layer_pattern=("rglru", "rglru", "local_attn"),
        sliding_window=8,
        mlp_type="geglu",
        tie_embeddings=True,
        embedding_scale=True,
        rnn_width=128,
        conv_width=4,
    )

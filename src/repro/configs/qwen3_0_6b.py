"""qwen3-0.6b [dense] -- 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936. qk-norm, head_dim 128, tied embeddings. [hf:Qwen/Qwen3-8B card]
"""

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        arch_type="dense",
        num_layers=28,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        tie_embeddings=True,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        arch_type="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        qk_norm=True,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        tie_embeddings=True,
    )

"""whisper-small [audio] -- 12L(enc)+12L(dec) d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865. Encoder-decoder; the mel+conv frontend is a STUB:
``input_specs`` provides precomputed (B, 1500, 768) frame embeddings.
[arXiv:2212.04356]

Decode-shape note (DESIGN.md): whisper's decoder max target length is 448,
so the decode_32k / long_500k shapes are skipped for this arch.
"""

from repro.models.common import AudioStubConfig, EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        arch_type="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        layer_pattern=("attn",),
        mlp_type="gelu",
        encoder=EncoderConfig(num_layers=12, num_frames=1500),
        audio=AudioStubConfig(num_mel_bins=80),
        tie_embeddings=True,
        norm_eps=1e-5,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        arch_type="audio",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        layer_pattern=("attn",),
        mlp_type="gelu",
        encoder=EncoderConfig(num_layers=2, num_frames=50),
        audio=AudioStubConfig(num_mel_bins=80),
        tie_embeddings=True,
        norm_eps=1e-5,
    )

"""xlstm-350m [ssm] -- 24L d_model=1024 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks. [arXiv:2405.04517]

Block pattern choice (noted in DESIGN.md): 3 mLSTM : 1 sLSTM
(layer % 4 == 3 -> sLSTM), matching the paper's mLSTM-dominant ratios.
``d_ff = 0``: xLSTM blocks carry their own internal projections
(mLSTM up-factor 2, sLSTM FFN factor 4/3).
"""

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        arch_type="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        tie_embeddings=True,
        conv_width=4,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        arch_type="ssm",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        layer_pattern=("mlstm", "slstm"),
        tie_embeddings=True,
        conv_width=4,
    )

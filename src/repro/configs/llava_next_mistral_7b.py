"""llava-next-mistral-7b [vlm] -- 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 (Mistral-7B backbone). The SigLIP/CLIP vision tower + projector
is a STUB: ``input_specs`` provides precomputed anyres patch embeddings
(2880 patches = 5 tiles x 576) prepended to the text sequence.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""

from repro.models.common import ModelConfig, VisionStubConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        arch_type="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        vision=VisionStubConfig(num_patches=2880),
        tie_embeddings=False,
        dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke",
        arch_type="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        rope_theta=1e6,
        layer_pattern=("attn",),
        mlp_type="swiglu",
        vision=VisionStubConfig(num_patches=16),
        tie_embeddings=False,
    )

"""repro: Decentralized SGD with learned topologies (STL-FW) on JAX/TPU.

Reproduction + systems extension of "Refined Convergence and Topology
Learning for Decentralized SGD with Heterogeneous Data" (Le Bars et al.,
2022). See DESIGN.md for the system map.
"""

__version__ = "0.1.0"

"""Shared layers: norms, rotary embeddings, MLPs, embedding tables."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .common import ModelConfig, dtype_of, truncated_normal

PyTree = Any

__all__ = [
    "rms_norm",
    "init_rms_norm",
    "layer_norm",
    "init_layer_norm",
    "rotary_embedding",
    "apply_rope",
    "init_mlp",
    "mlp_forward",
    "init_embedding",
    "embed",
    "unembed",
    "sinusoidal_positions",
]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rms_norm(dim: int, dtype) -> PyTree:
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(params: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig_dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(orig_dtype)


def init_layer_norm(dim: int, dtype) -> PyTree:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layer_norm(params: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig_dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    normed = (x32 - mean) * jax.lax.rsqrt(var + eps)
    out = normed * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rotary_embedding(
    positions: jax.Array, head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """(cos, sin) of shape ``positions.shape + (head_dim // 2,)``."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs. x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over H."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(length: int, dim: int, dtype) -> jax.Array:
    """Whisper-style fixed sinusoidal position table (length, dim)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    tab = jnp.zeros((length, dim), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab.astype(dtype)


# ---------------------------------------------------------------------------
# Dense MLPs (SwiGLU / GeGLU / GeLU)
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> PyTree:
    dt = dtype_of(cfg)
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std_in = d ** -0.5
    std_out = ff ** -0.5
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": truncated_normal(k1, (d, ff), std_in, dt),
            "w_up": truncated_normal(k2, (d, ff), std_in, dt),
            "w_down": truncated_normal(k3, (ff, d), std_out, dt),
        }
    return {
        "w_up": truncated_normal(k1, (d, ff), std_in, dt),
        "w_down": truncated_normal(k3, (ff, d), std_out, dt),
    }


def mlp_forward(params: PyTree, x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        gate = jax.nn.silu(x @ params["w_gate"])
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    if mlp_type == "geglu":
        gate = jax.nn.gelu(x @ params["w_gate"], approximate=True)
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    if mlp_type == "gelu":
        return jax.nn.gelu(x @ params["w_up"], approximate=True) @ params["w_down"]
    raise ValueError(f"unknown mlp_type {mlp_type}")


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dt = dtype_of(cfg)
    params = {
        "table": truncated_normal(key, (cfg.vocab_size, cfg.d_model), 0.02, dt)
    }
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        params["unembed"] = truncated_normal(
            k2, (cfg.d_model, cfg.vocab_size), cfg.d_model**-0.5, dt
        )
    return params


def embed(params: PyTree, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = params["table"][tokens]
    if cfg.embedding_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def unembed(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["table"].T
    else:
        logits = x @ params["unembed"]
    if cfg.final_logit_softcap > 0.0:
        cap = cfg.final_logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits

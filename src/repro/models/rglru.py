"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)    (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in ``h`` with elementwise coefficients, hence
associative: training/prefill uses ``jax.lax.associative_scan`` (O(log S)
depth); decode is a single fused elementwise step.

Block layout (Griffin's recurrent block):
  norm -> {gate branch: linear+GeLU} x {rnn branch: linear -> causal conv ->
  RG-LRU} -> multiply -> output linear -> residual.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .common import ModelConfig, dtype_of, truncated_normal
from .layers import init_rms_norm, rms_norm

PyTree = Any

__all__ = ["init_rglru_block", "rglru_block", "init_rglru_state"]

_C = 8.0


def init_rglru_block(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dt = dtype_of(cfg)
    d = cfg.d_model
    dr = cfg.resolved_rnn_width
    ks = jax.random.split(key, 6)
    std = d**-0.5
    # Lambda init so that a^(1/c) ~ U[0.9, 0.999] as in the paper
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))  # softplus^{-1}(-log u)
    return {
        "norm": init_rms_norm(d, dt),
        "w_gate": truncated_normal(ks[0], (d, dr), std, dt),
        "w_rnn_in": truncated_normal(ks[1], (d, dr), std, dt),
        "conv_w": truncated_normal(ks[2], (cfg.conv_width, dr), 0.1, dt),
        "w_a": truncated_normal(ks[3], (dr, dr), dr**-0.5, dt),
        "w_x": truncated_normal(ks[4], (dr, dr), dr**-0.5, dt),
        "lam": lam.astype(jnp.float32),
        "w_out": truncated_normal(jax.random.fold_in(key, 7), (dr, d), dr**-0.5, dt),
    }


def init_rglru_state(cfg: ModelConfig, batch: int) -> PyTree:
    dr = cfg.resolved_rnn_width
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype_of(cfg)),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None):
    width = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        if state is None
        else state
    )
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(width))
    return y, xp[:, -(width - 1) :, :]


def rglru_block(
    params: PyTree, cfg: ModelConfig, x: jax.Array, state: PyTree | None = None
) -> tuple[jax.Array, PyTree | None]:
    """x: (B,S,D) -> (B,S,D). Associative scan (state None) or decode step."""
    B, S, D = x.shape
    xn = rms_norm(params["norm"], x, cfg.norm_eps)
    gate = jax.nn.gelu(xn @ params["w_gate"], approximate=True)  # (B,S,dr)
    rnn_in = xn @ params["w_rnn_in"]
    conv_state = None if state is None else state["conv"]
    rnn_in, new_conv = _causal_conv1d(rnn_in, params["conv_w"], conv_state)

    r = jax.nn.sigmoid((rnn_in @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((rnn_in @ params["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # (B,S,dr), <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * rnn_in.astype(jnp.float32)
    )

    if state is None or S > 1:
        if state is not None:
            # fold the carried state into the first step
            b = b.at[:, 0].add(a[:, 0] * state["h"])

        def combine(prev, cur):
            a1, b1 = prev
            a2, b2 = cur
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_state = None if state is None else {"h": h[:, -1], "conv": new_conv}
    else:
        h = a[:, 0] * state["h"] + b[:, 0]
        new_state = {"h": h, "conv": new_conv}
        h = h[:, None, :]

    out = (h.astype(x.dtype) * gate) @ params["w_out"]
    return x + out, new_state

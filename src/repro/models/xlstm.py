"""xLSTM blocks (Beck et al., 2024 -- arXiv:2405.04517): mLSTM and sLSTM.

* mLSTM: matrix-memory LSTM with exponential gating. Training/prefill uses
  the *parallel* (quadratic, attention-like) form; decode uses the O(1)
  recurrent form with state (C, n, m) per head.
* sLSTM: scalar-memory LSTM with recurrent weights and exponential gating;
  inherently sequential -> ``jax.lax.scan`` over time for training, O(1)
  decode step.

Block structure follows the xLSTM paper: the mLSTM block is a pre-norm
up-projection (factor 2) sandwich with a causal conv on the q/k path and a
learnable skip + output gate; the sLSTM block is post-norm with a GeLU
up/down FFN of factor 4/3. The assigned ``xlstm-350m`` config has
``d_ff = 0`` because these internal projections replace the transformer MLP.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .common import ModelConfig, dtype_of, truncated_normal
from .layers import init_rms_norm, rms_norm

PyTree = Any

__all__ = [
    "init_mlstm_block",
    "mlstm_block",
    "init_mlstm_state",
    "init_slstm_block",
    "slstm_block",
    "init_slstm_state",
]

_MLSTM_PROJ = 2.0  # up-projection factor of the mLSTM block
_SLSTM_FF = 4.0 / 3.0  # FFN factor of the sLSTM block


def _causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,C); w: (width, C).

    Returns (y, new_state) where state caches the last ``width-1`` inputs
    for decode. With ``state=None`` the sequence is left-padded with zeros.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+width-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1) :, :] if width > 1 else pad
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_block(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dt = dtype_of(cfg)
    d = cfg.d_model
    d_in = int(d * _MLSTM_PROJ)
    h = cfg.num_heads
    dh = d_in // h
    ks = jax.random.split(key, 9)
    std = d**-0.5
    std_in = d_in**-0.5
    return {
        "norm": init_rms_norm(d, dt),
        "w_up": truncated_normal(ks[0], (d, d_in), std, dt),
        "w_gate": truncated_normal(ks[1], (d, d_in), std, dt),
        "conv_w": truncated_normal(ks[2], (cfg.conv_width, d_in), 0.1, dt),
        "wq": truncated_normal(ks[3], (d_in, d_in), std_in, dt),
        "wk": truncated_normal(ks[4], (d_in, d_in), std_in, dt),
        "wv": truncated_normal(ks[5], (d_in, d_in), std_in, dt),
        "w_if": truncated_normal(ks[6], (d_in, 2 * h), std_in, dt),
        "b_if": jnp.zeros((2 * h,), dt),
        "out_norm": init_rms_norm(d_in, dt),
        "w_down": truncated_normal(ks[8], (d_in, d), std_in, dt),
    }


def _mlstm_parallel(q, k, v, i_tilde, f_tilde):
    """Parallel mLSTM. q/k/v: (B,H,S,Dh); i_tilde/f_tilde: (B,H,S)."""
    B, H, S, Dh = q.shape
    log_f = jax.nn.log_sigmoid(f_tilde.astype(jnp.float32))  # (B,H,S)
    F = jnp.cumsum(log_f, axis=-1)
    # D[t, s] = F_t - F_s + log i_s   for s <= t
    D = F[..., :, None] - F[..., None, :] + i_tilde.astype(jnp.float32)[..., None, :]
    causal = jnp.tril(jnp.ones((S, S), bool))
    D = jnp.where(causal, D, -jnp.inf)
    m = jnp.max(D, axis=-1, keepdims=True)  # (B,H,S,1)
    m = jnp.maximum(m, -1e30)  # guard all -inf rows
    decay = jnp.exp(D - m)
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * (Dh**-0.5) * decay
    norm = jnp.maximum(jnp.abs(scores.sum(axis=-1, keepdims=True)), jnp.exp(-m))
    h_out = jnp.einsum("bhts,bhsd->bhtd", scores / norm, v.astype(jnp.float32))
    return h_out.astype(q.dtype)


_CHUNK_THRESHOLD = 2048
_CHUNK = 512


def _mlstm_chunkwise(q, k, v, i_tilde, f_tilde, chunk: int = _CHUNK):
    """Chunkwise-parallel mLSTM (xLSTM paper App. formulation).

    Splits time into chunks; within a chunk the quadratic parallel form is
    used, across chunks the (C, n, m) recurrent state is carried by a scan.
    Peak memory O(B*H*chunk*S_chunk) instead of O(B*H*S^2).

    q/k/v: (B,H,S,Dh); gates: (B,H,S). Returns (B,H,S,Dh).
    """
    B, H, S, Dh = q.shape
    assert S % chunk == 0
    nc = S // chunk
    log_f = jax.nn.log_sigmoid(f_tilde.astype(jnp.float32))
    i32 = i_tilde.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32) * (Dh**-0.5)
    v32 = v.astype(jnp.float32)

    # reshape to (nc, B, H, chunk, ...)
    def to_chunks(x):
        return x.reshape(B, H, nc, chunk, *x.shape[3:]).transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    qc, kc, vc = to_chunks(q32), to_chunks(k32), to_chunks(v32)
    fc, ic = to_chunks(log_f), to_chunks(i32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inputs):
        C0, n0, m0 = state  # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qx, kx, vx, fx, ix = inputs  # (B,H,chunk,...)
        F = jnp.cumsum(fx, axis=-1)  # (B,H,chunk) decay from chunk start
        # intra-chunk log weights D[t,s] = F_t - F_s + log i_s (s <= t)
        D = F[..., :, None] - F[..., None, :] + ix[..., None, :]
        D = jnp.where(causal, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)  # (B,H,chunk)
        # inter contribution decays from the carried state: b_t = F_t + m0
        b = F + m0[..., None]
        m_t = jnp.maximum(jnp.maximum(m_intra, -1e30), b)
        a = jnp.exp(D - m_t[..., None])  # (B,H,chunk,chunk)
        scores = jnp.einsum("bhtd,bhsd->bhts", qx, kx) * a
        w_inter = jnp.exp(b - m_t)  # (B,H,chunk)
        inter_num = jnp.einsum("bhde,bhte->bhtd", C0, qx)  # contract key dim
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vx) + w_inter[..., None] * inter_num
        den_dot = scores.sum(-1) + w_inter * jnp.einsum("bhd,bhtd->bht", n0, qx)
        den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_t))
        h = num / den[..., None]  # (B,H,chunk,Dh)

        # state update to chunk end
        F_last = F[..., -1]  # (B,H)
        w_log = F_last[..., None] - F + ix  # (B,H,chunk)
        m_new = jnp.maximum(F_last + m0, jnp.max(w_log, axis=-1))
        scale_old = jnp.exp(F_last + m0 - m_new)  # (B,H)
        w = jnp.exp(w_log - m_new[..., None])  # (B,H,chunk)
        C_new = scale_old[..., None, None] * C0 + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w, vx, kx
        )
        n_new = scale_old[..., None] * n0 + jnp.einsum("bhs,bhsd->bhd", w, kx)
        return (C_new, n_new, m_new), h

    init = (
        jnp.zeros((B, H, Dh, Dh), jnp.float32),
        jnp.zeros((B, H, Dh), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    _, hs = jax.lax.scan(jax.checkpoint(step), init, (qc, kc, vc, fc, ic))
    out = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, Dh)
    return out.astype(q.dtype)


def _mlstm_recurrent_step(q, k, v, i_tilde, f_tilde, state):
    """One decode step. q/k/v: (B,H,Dh); gates: (B,H). state: dict(C,n,m)."""
    C, n, m = state["C"], state["n"], state["m"]
    log_f = jax.nn.log_sigmoid(f_tilde.astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, i_tilde.astype(jnp.float32))
    i_p = jnp.exp(i_tilde.astype(jnp.float32) - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    k32, v32, q32 = (t.astype(jnp.float32) for t in (k, v, q))
    Dh = q.shape[-1]
    k32 = k32 * (Dh**-0.5)
    C_new = f_p[..., None] * C + i_p[..., None] * (v32[..., :, None] * k32[..., None, :])
    n_new = f_p * n + i_p * k32
    num = jnp.einsum("bhdk,bhk->bhd", C_new, q32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q32))[..., None], jnp.exp(-m_new)[..., None])
    h = (num / den).astype(q.dtype)
    return h, {"C": C_new, "n": n_new, "m": m_new}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> PyTree:
    d_in = int(cfg.d_model * _MLSTM_PROJ)
    h = cfg.num_heads
    dh = d_in // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype_of(cfg)),
    }


def mlstm_block(
    params: PyTree, cfg: ModelConfig, x: jax.Array, state: PyTree | None = None
) -> tuple[jax.Array, PyTree | None]:
    """x: (B,S,D). Parallel form when state is None, else recurrent decode."""
    B, S, D = x.shape
    h = cfg.num_heads
    xn = rms_norm(params["norm"], x, cfg.norm_eps)
    up = xn @ params["w_up"]  # (B,S,d_in)
    gate = xn @ params["w_gate"]
    d_in = up.shape[-1]
    dh = d_in // h

    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv1d(up, params["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)

    q = (conv_out @ params["wq"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    k = (conv_out @ params["wk"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    v = (up @ params["wv"]).reshape(B, S, h, dh).transpose(0, 2, 1, 3)
    gates = conv_out @ params["w_if"] + params["b_if"]  # (B,S,2h)
    i_tilde = gates[..., :h].transpose(0, 2, 1)  # (B,h,S)
    f_tilde = gates[..., h:].transpose(0, 2, 1)

    if state is None:
        if S > _CHUNK_THRESHOLD and S % _CHUNK == 0:
            h_out = _mlstm_chunkwise(q, k, v, i_tilde, f_tilde)
        else:
            h_out = _mlstm_parallel(q, k, v, i_tilde, f_tilde)  # (B,h,S,dh)
        new_state = None
    elif S == 1:
        h_step, inner = _mlstm_recurrent_step(
            q[:, :, 0], k[:, :, 0], v[:, :, 0], i_tilde[:, :, 0], f_tilde[:, :, 0],
            {"C": state["C"], "n": state["n"], "m": state["m"]},
        )
        h_out = h_step[:, :, None, :]  # (B,h,1,dh)
        new_state = {**inner, "conv": new_conv}
    else:
        # Prefill: parallel output + closed-form final state (assumes the
        # incoming state is fresh/empty, which is how the serve engine
        # initializes prefill).
        h_out = _mlstm_parallel(q, k, v, i_tilde, f_tilde)
        log_f = jax.nn.log_sigmoid(f_tilde.astype(jnp.float32))
        F = jnp.cumsum(log_f, axis=-1)  # (B,h,S)
        last = F[..., -1:]
        w_log = last - F + i_tilde.astype(jnp.float32)  # exp-gate weights at T
        m_T = jnp.max(w_log, axis=-1)  # (B,h)
        w = jnp.exp(w_log - m_T[..., None])  # (B,h,S)
        k_sc = k.astype(jnp.float32) * (dh**-0.5)
        C_T = jnp.einsum("bhs,bhsd,bhse->bhde", w, v.astype(jnp.float32), k_sc)
        n_T = jnp.einsum("bhs,bhsd->bhd", w, k_sc)
        new_state = {"C": C_T, "n": n_T, "m": m_T, "conv": new_conv}

    h_seq = h_out.transpose(0, 2, 1, 3).reshape(B, S, d_in)
    h_seq = rms_norm(params["out_norm"], h_seq, cfg.norm_eps)
    out = (h_seq * jax.nn.silu(gate)) @ params["w_down"]
    return x + out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_block(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dt = dtype_of(cfg)
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 8)
    std = d**-0.5
    ff = int(d * _SLSTM_FF)
    return {
        "norm": init_rms_norm(d, dt),
        # input projections for gates z, i, f, o: (d, 4d)
        "w_in": truncated_normal(ks[0], (d, 4 * d), std, dt),
        "b_in": jnp.zeros((4 * d,), dt),
        # per-head recurrent weights for the 4 gates: (4, h, dh, dh)
        "r": truncated_normal(ks[1], (4, h, dh, dh), dh**-0.5, dt),
        "out_norm": init_rms_norm(d, dt),
        "ffn_norm": init_rms_norm(d, dt),
        "w_ff_up": truncated_normal(ks[2], (d, ff), std, dt),
        "w_ff_down": truncated_normal(ks[3], (ff, d), ff**-0.5, dt),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> PyTree:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}


def _slstm_step(params, cfg, state, x_t):
    """x_t: (B, 4d) pre-projected gate inputs. state: dict(c, n, h, m)."""
    B = x_t.shape[0]
    d = cfg.d_model
    h_heads = cfg.num_heads
    dh = d // h_heads
    c, n, h_prev, m = state["c"], state["n"], state["h"], state["m"]
    # recurrent contribution: per-gate, per-head  h_prev @ r[g, head]
    rec = jnp.einsum("bhd,ghde->gbhe", h_prev, params["r"].astype(jnp.float32))  # (4,B,h,dh)
    gates = x_t.reshape(B, 4, h_heads, dh).transpose(1, 0, 2, 3).astype(jnp.float32) + rec
    z_t = jnp.tanh(gates[0])
    i_tilde = gates[1]
    f_tilde = gates[2]
    o_t = jax.nn.sigmoid(gates[3])
    log_f = jax.nn.log_sigmoid(f_tilde)
    m_new = jnp.maximum(log_f + m, i_tilde)
    i_p = jnp.exp(i_tilde - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = jnp.maximum(f_p * n + i_p, jnp.exp(-m_new))
    h_new = o_t * (c_new / n_new)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_block(
    params: PyTree, cfg: ModelConfig, x: jax.Array, state: PyTree | None = None
) -> tuple[jax.Array, PyTree | None]:
    """x: (B,S,D). lax.scan over time (sequential); O(1) decode with state."""
    B, S, D = x.shape
    h_heads = cfg.num_heads
    dh = D // h_heads
    xn = rms_norm(params["norm"], x, cfg.norm_eps)
    gate_in = xn @ params["w_in"] + params["b_in"]  # (B,S,4D)

    if state is None or S > 1:
        init = state if state is not None else init_slstm_state(cfg, B)

        def step(carry, x_t):
            new = _slstm_step(params, cfg, carry, x_t)
            return new, new["h"]

        final, hs = jax.lax.scan(step, init, gate_in.transpose(1, 0, 2))  # (S,B,h,dh)
        h_seq = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
        new_state = final if state is not None else None
    else:
        new_state = _slstm_step(params, cfg, state, gate_in[:, 0])
        h_seq = new_state["h"].reshape(B, 1, D).astype(x.dtype)

    h_seq = rms_norm(params["out_norm"], h_seq, cfg.norm_eps)
    y = x + h_seq
    # post FFN (factor 4/3, GeLU)
    ffn_in = rms_norm(params["ffn_norm"], y, cfg.norm_eps)
    ffn = jax.nn.gelu(ffn_in @ params["w_ff_up"], approximate=True) @ params["w_ff_down"]
    return y + ffn, new_state

"""KV / recurrent-state caches for serving.

Three cache families, matching the per-layer block kinds:

* ``init_full_cache``    -- (B, S_max, H_kv, D_h) keys/values + write index.
                            Used by global-attention layers in ``decode_32k``.
* ``init_window_cache``  -- ring buffer of size ``window``; used by
                            local-attention layers and by *all* attention
                            layers in the ``long_500k`` sub-quadratic mode.
* recurrent states       -- owned by the xLSTM / RG-LRU blocks themselves
                            (``models.xlstm`` / ``models.rglru``).

Keys are stored *post-RoPE* so decode never re-rotates history.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "init_full_cache",
    "init_window_cache",
    "update_full_cache",
    "update_window_cache",
]


def init_full_cache(batch: int, max_len: int, n_kv: int, head_dim: int, dtype) -> PyTree:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),  # number of valid positions
    }


def init_window_cache(batch: int, window: int, n_kv: int, head_dim: int, dtype) -> PyTree:
    return {
        "k": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),  # absolute position counter
    }


def update_full_cache(cache: PyTree, k_new: jax.Array, v_new: jax.Array) -> PyTree:
    """Append ``S_new`` positions at the current index (decode: S_new = 1)."""
    idx = cache["index"]
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, idx, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, idx, 0, 0))
    return {"k": k, "v": v, "index": idx + k_new.shape[1]}


def update_window_cache(cache: PyTree, k_new: jax.Array, v_new: jax.Array) -> PyTree:
    """Ring-buffer write of ``S_new`` positions (slot = abs_pos mod window)."""
    window = cache["k"].shape[1]
    idx = cache["index"]
    s_new = k_new.shape[1]
    if s_new == 1:
        slot = jnp.mod(idx, window)
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
    else:
        # prefill into the ring: only the last ``window`` positions can
        # survive, so clamp first to keep slot indices unique.
        if s_new > window:
            k_new = k_new[:, -window:]
            v_new = v_new[:, -window:]
            start = idx + s_new - window
            count = window
        else:
            start = idx
            count = s_new
        positions = start + jnp.arange(count)
        slots = jnp.mod(positions, window)
        k = cache["k"].at[:, slots].set(k_new.astype(cache["k"].dtype))
        v = cache["v"].at[:, slots].set(v_new.astype(cache["v"].dtype))
    return {"k": k, "v": v, "index": idx + s_new}

"""Decoder-only language model assembly from a ModelConfig.

Handles every assigned decoder-only architecture through the per-layer block
pattern: 'attn' / 'local_attn' (GQA or MLA + dense-or-MoE MLP), 'mlstm',
'slstm' (self-contained xLSTM blocks), 'rglru' (Griffin recurrent block +
MLP). VLM (llava) inputs are handled by prepending stub patch embeddings.

Layer-stacking: layers are grouped into repetitions of ``cfg.layer_pattern``
and executed with ``jax.lax.scan`` over the repetitions (parameters for each
pattern position are stacked on a leading "group" axis). This keeps the HLO
size and compile time O(pattern) instead of O(num_layers), and bounds live
activation memory to one group (one layer's working set) with per-group
activation checkpointing. Layers that do not fill a whole pattern
repetition (e.g. recurrentgemma's 26 = 8x3 + 2) run unrolled as the "tail".

API:
  init_lm(rng, cfg)                      -> params
  forward(params, cfg, tokens, ...)      -> (logits|hidden, new_cache, aux)
  init_cache(cfg, batch, max_len, ...)   -> cache pytree
  lm_loss(params, cfg, batch)            -> (loss, metrics)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .attention import (
    attention,
    init_attention,
    init_attention_cache,
    init_mla_attention,
    init_mla_cache,
    mla_attention,
)
from .common import ModelConfig, dtype_of
from .layers import (
    embed,
    init_embedding,
    init_mlp,
    init_rms_norm,
    mlp_forward,
    rms_norm,
    unembed,
)
from .moe import init_moe, moe_forward
from .rglru import init_rglru_block, init_rglru_state, rglru_block
from .xlstm import (
    init_mlstm_block,
    init_mlstm_state,
    init_slstm_block,
    init_slstm_state,
    mlstm_block,
    slstm_block,
)

PyTree = Any

__all__ = [
    "init_lm",
    "forward",
    "init_cache",
    "lm_loss",
    "softmax_xent",
    "fused_unembed_xent",
]

_ATTN_KINDS = ("attn", "local_attn")


# ---------------------------------------------------------------------------
# Per-layer init / forward (kind-static)
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, cfg: ModelConfig, kind: str) -> PyTree:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    params: PyTree = {}
    if kind in _ATTN_KINDS:
        params["ln1"] = init_rms_norm(cfg.d_model, dt)
        if cfg.mla is not None:
            params["attn"] = init_mla_attention(ks[0], cfg)
        else:
            params["attn"] = init_attention(ks[0], cfg)
        if cfg.post_block_norms:
            params["post_ln1"] = init_rms_norm(cfg.d_model, dt)
    elif kind == "mlstm":
        params["block"] = init_mlstm_block(ks[0], cfg)
    elif kind == "slstm":
        params["block"] = init_slstm_block(ks[0], cfg)
    elif kind == "rglru":
        params["block"] = init_rglru_block(ks[0], cfg)
    else:
        raise ValueError(f"unknown layer kind {kind}")

    if cfg.d_ff > 0 and kind not in ("mlstm", "slstm"):
        params["ln2"] = init_rms_norm(cfg.d_model, dt)
        if cfg.moe is not None:
            params["mlp"] = init_moe(ks[1], cfg)
        else:
            params["mlp"] = init_mlp(ks[1], cfg)
        if cfg.post_block_norms:
            params["post_ln2"] = init_rms_norm(cfg.d_model, dt)
    return params


def _layer_forward(
    lp: PyTree,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    cache_layer: PyTree | None,
    window_override: int | None,
    impl: str,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if kind in _ATTN_KINDS:
        h = rms_norm(lp["ln1"], x, cfg.norm_eps)
        local = kind == "local_attn" or window_override is not None
        if cfg.mla is not None:
            win = window_override if window_override is not None else (
                cfg.sliding_window if kind == "local_attn" else None
            )
            attn_out, new_cache = mla_attention(
                lp["attn"], cfg, h, positions=positions, cache=cache_layer, window=win
            )
        else:
            attn_out, new_cache = attention(
                lp["attn"], cfg, h,
                positions=positions,
                local=local,
                window=window_override,
                cache=cache_layer,
                impl=impl,
            )
        if cfg.post_block_norms:
            attn_out = rms_norm(lp["post_ln1"], attn_out, cfg.norm_eps)
        x = x + attn_out
    elif kind == "mlstm":
        x, new_cache = mlstm_block(lp["block"], cfg, x, cache_layer)
    elif kind == "slstm":
        x, new_cache = slstm_block(lp["block"], cfg, x, cache_layer)
    elif kind == "rglru":
        x, new_cache = rglru_block(lp["block"], cfg, x, cache_layer)

    if cfg.d_ff > 0 and kind not in ("mlstm", "slstm"):
        h = rms_norm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            mlp_out, aux = moe_forward(lp["mlp"], cfg, h)
        else:
            mlp_out = mlp_forward(lp["mlp"], h, cfg.mlp_type)
        if cfg.post_block_norms:
            mlp_out = rms_norm(lp["post_ln2"], mlp_out, cfg.norm_eps)
        x = x + mlp_out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init: stacked pattern groups + tail
# ---------------------------------------------------------------------------

def _group_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(num_full_groups, num_tail_layers)."""
    plen = len(cfg.layer_pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def init_lm(rng: jax.Array, cfg: ModelConfig) -> PyTree:
    reps, rem = _group_layout(cfg)
    plen = len(cfg.layer_pattern)
    keys = jax.random.split(rng, cfg.num_layers + 2)

    stages = []
    for j, kind in enumerate(cfg.layer_pattern):
        group_params = [
            _init_layer(keys[g * plen + j], cfg, kind) for g in range(reps)
        ]
        stages.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *group_params)
            if reps > 0
            else None
        )
    tail = [
        _init_layer(keys[reps * plen + t], cfg, cfg.layer_pattern[t % plen])
        for t in range(rem)
    ]
    return {
        "embed": init_embedding(keys[-1], cfg),
        "stages": stages,
        "tail": tail,
        "final_norm": init_rms_norm(cfg.d_model, dtype_of(cfg)),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    image_embeds: jax.Array | None = None,
    cache: PyTree | None = None,
    positions: jax.Array | None = None,
    window_override: int | None = None,
    impl: str = "xla",
    remat: bool = True,
    return_hidden: bool = False,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    """Decoder forward.

    Args:
      tokens: (B, S_text) int tokens.
      image_embeds: optional (B, P, D) stub patch embeddings (VLM) prepended
        to the text sequence (prefill / training only).
      cache: cache pytree from init_cache for decode; None = full sequence.
      positions: (B, S_total) absolute positions (required with cache).
      window_override: force all attention layers to a sliding window (the
        long_500k sub-quadratic serving mode).
      impl: 'xla' | 'pallas' attention implementation.
      remat: per-group activation checkpointing (training path).
      return_hidden: skip the unembedding (used by the fused loss).

    Returns (logits | hidden, new_cache, moe_aux_loss).
    """
    x = embed(params["embed"], tokens, cfg)
    if image_embeds is not None:
        x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    pattern = cfg.layer_pattern
    reps, rem = _group_layout(cfg)

    # scan over the stacked groups
    aux_total = jnp.zeros((), jnp.float32)
    new_cache_stages = None
    if reps > 0:
        stage_params = [params["stages"][j] for j in range(len(pattern))]
        stage_caches = (
            [cache["stages"][j] for j in range(len(pattern))]
            if cache is not None
            else None
        )

        def body(carry, xs):
            x = carry["x"]
            aux = carry["aux"]
            sp = xs["params"]
            sc = xs.get("caches")
            new_caches = []
            for j, kind in enumerate(pattern):
                cl = sc[j] if sc is not None else None
                x, nc, a = _layer_forward(
                    sp[j], cfg, kind, x, positions, cl, window_override, impl
                )
                aux = aux + a
                new_caches.append(nc)
            out = {"caches": tuple(new_caches)} if sc is not None else {}
            return {"x": x, "aux": aux}, out

        if remat and cache is None:
            body = jax.checkpoint(body, prevent_cse=False)

        xs = {"params": stage_params}
        if stage_caches is not None:
            xs["caches"] = stage_caches
        carry, ys = jax.lax.scan(body, {"x": x, "aux": aux_total}, xs)
        x = carry["x"]
        aux_total = carry["aux"]
        if cache is not None:
            new_cache_stages = list(ys["caches"])

    # unrolled tail layers
    new_tail = []
    for t, lp in enumerate(params["tail"]):
        kind = pattern[t % len(pattern)]
        cl = cache["tail"][t] if cache is not None else None
        x, nc, a = _layer_forward(
            lp, cfg, kind, x, positions, cl, window_override, impl
        )
        aux_total = aux_total + a
        new_tail.append(nc)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    new_cache = (
        {"stages": new_cache_stages, "tail": new_tail} if cache is not None else None
    )
    if return_hidden:
        return x, new_cache, aux_total
    logits = unembed(params["embed"], x, cfg)
    return logits, new_cache, aux_total


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _init_layer_cache(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, long_context: bool
) -> PyTree:
    if kind in _ATTN_KINDS:
        if cfg.mla is not None:
            L = cfg.long_context_window if long_context else max_len
            return init_mla_cache(cfg, batch, L)
        local = kind == "local_attn" or long_context
        window = cfg.long_context_window if long_context else None
        return init_attention_cache(cfg, batch, max_len, local=local, window=window)
    if kind == "mlstm":
        return init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return init_slstm_state(cfg, batch)
    if kind == "rglru":
        return init_rglru_state(cfg, batch)
    raise ValueError(kind)


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    *,
    long_context: bool = False,
) -> PyTree:
    """Cache pytree matching the stacked-group layout of the model.

    ``long_context=True`` selects the sub-quadratic mode: every attention
    layer gets a ring-buffer window cache of ``cfg.long_context_window``.
    """
    reps, rem = _group_layout(cfg)
    pattern = cfg.layer_pattern
    stages = []
    for j, kind in enumerate(pattern):
        per_group = [
            _init_layer_cache(cfg, kind, batch, max_len, long_context)
            for _ in range(reps)
        ]
        stages.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_group)
            if reps > 0
            else None
        )
    tail = [
        _init_layer_cache(cfg, pattern[t % len(pattern)], batch, max_len, long_context)
        for t in range(rem)
    ]
    return {"stages": stages, "tail": tail}


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Memory-lean cross entropy: logits stay in compute dtype (bf16) and
    vocab-shardable; logsumexp reduces over V in f32; the label logit is a
    one-hot einsum (no gather -- GSPMD keeps the vocab axis sharded)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)  # (B, S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum(
        "bsv,bsv->bs", logits.astype(jnp.float32), onehot.astype(jnp.float32)
    )
    return jnp.mean(lse - label_logit)


_XENT_CHUNK = 512


def fused_unembed_xent(
    params: PyTree, cfg: ModelConfig, hidden: jax.Array, labels: jax.Array
) -> jax.Array:
    """Unembed + cross-entropy fused over sequence chunks: the full (B,S,V)
    logits tensor never materializes -- peak extra memory is one
    (B, chunk, V) block (re-materialized in the backward pass via remat)."""
    B, S, D = hidden.shape
    if S % _XENT_CHUNK != 0:
        return softmax_xent(unembed(params["embed"], hidden, cfg), labels)
    nc = S // _XENT_CHUNK

    def chunk_nll(ci):
        h = jax.lax.dynamic_slice_in_dim(hidden, ci * _XENT_CHUNK, _XENT_CHUNK, 1)
        lab = jax.lax.dynamic_slice_in_dim(labels, ci * _XENT_CHUNK, _XENT_CHUNK, 1)
        logits = unembed(params["embed"], h, cfg)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(lab, logits.shape[-1], dtype=logits.dtype)
        label_logit = jnp.einsum(
            "bsv,bsv->bs", logits.astype(jnp.float32), onehot.astype(jnp.float32)
        )
        return jnp.sum(lse - label_logit)

    totals = jax.lax.map(jax.checkpoint(chunk_nll), jnp.arange(nc))
    return jnp.sum(totals) / (B * S)


def lm_loss(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    *,
    image_embeds: jax.Array | None = None,
    impl: str = "xla",
) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy (+ MoE aux). Labels align with text tokens."""
    hidden, _, aux = forward(
        params, cfg, tokens, image_embeds=image_embeds, impl=impl,
        return_hidden=True,
    )
    if image_embeds is not None:
        hidden = hidden[:, image_embeds.shape[1] :, :]
    loss = fused_unembed_xent(params, cfg, hidden, labels)
    total = loss
    if cfg.moe is not None:
        total = total + cfg.moe.router_aux_coef * aux
    return total, {"nll": loss, "aux": aux}

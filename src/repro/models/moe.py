"""Mixture-of-Experts MLP block with capacity-based scatter dispatch.

Design (TPU/GSPMD-friendly, active-FLOPs-only):

1. Router: softmax over experts, top-k per token, renormalized weights.
2. Dispatch: tokens are scattered into a per-expert buffer of shape
   ``(E, C, D)`` (capacity ``C = ceil(T * k / E * capacity_factor)``),
   computing each token's slot within its expert group via a sort-free
   one-hot cumulative sum. Overflowing tokens are *dropped* (their combine
   weight contribution is simply missing -- standard capacity behaviour).
3. Expert compute: a single batched einsum ``(E, C, D) x (E, D, F)`` -- only
   ``E*C ~ T*k*cf`` token-slots are computed, not ``T*E``.
4. Combine: scatter-add back to tokens with router weights.

Under the production mesh the expert axis ``E`` is sharded over ``model``
and tokens over ``data``; the dispatch/combine scatters lower to
all-to-all-style collectives in GSPMD. Shared experts (DeepSeek) are plain
dense MLPs added unconditionally.

The router load-balancing auxiliary loss (Switch-style) is returned so the
trainer can add ``aux_coef * aux_loss``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .common import ModelConfig, dtype_of, truncated_normal
from .layers import init_mlp, mlp_forward

PyTree = Any

__all__ = ["init_moe", "moe_forward", "router_aux_loss"]


def _constrain_experts(x: jax.Array, ndim_spec: tuple) -> jax.Array:
    """Best-effort sharding constraint (expert axis over 'model').

    No-op outside a mesh context or when the mesh has no 'model' axis, so
    the module stays usable on a single device.
    """
    try:
        from jax.sharding import PartitionSpec as P

        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in (mesh.axis_names or ()):
            return x
        return jax.lax.with_sharding_constraint(x, P(*ndim_spec))
    except Exception:  # pragma: no cover - non-mesh contexts
        return x


def init_moe(key: jax.Array, cfg: ModelConfig) -> PyTree:
    assert cfg.moe is not None
    m = cfg.moe
    dt = dtype_of(cfg)
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)
    params: PyTree = {
        "router": truncated_normal(ks[0], (d, m.num_experts), d**-0.5, dt),
        "routed": {
            "w_gate": truncated_normal(ks[1], (m.num_experts, d, f), d**-0.5, dt),
            "w_up": truncated_normal(ks[2], (m.num_experts, d, f), d**-0.5, dt),
            "w_down": truncated_normal(ks[3], (m.num_experts, f, d), f**-0.5, dt),
        },
    }
    if m.num_shared_experts > 0:
        shared_ff = m.d_ff_shared if m.d_ff_shared > 0 else f * m.num_shared_experts
        params["shared"] = init_mlp(ks[4], cfg, d_ff=shared_ff)
    return params


def router_aux_loss(router_probs: jax.Array, expert_ids: jax.Array, num_experts: int) -> jax.Array:
    """Switch-transformer load-balance loss: E * sum_e f_e * P_e."""
    # fraction of tokens routed (by top-1 assignment) to each expert
    top1 = expert_ids[..., 0]
    f = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=jnp.float32), axis=0)
    p = jnp.mean(router_probs.astype(jnp.float32), axis=0)
    return num_experts * jnp.sum(f * p)


def _dispatch_one_group(tokens, expert_ids, gate_vals, params, cfg, C):
    """Capacity dispatch + expert compute for ONE token group (T, D).

    Grouped (per-sequence) dispatch keeps the batch axis data-sharded: the
    scatter indices are group-local, so GSPMD never gathers tokens across
    data shards (that gather dominated the collective volume of the global
    dispatch -- see EXPERIMENTS.md §Perf).
    """
    m = cfg.moe
    T, D = tokens.shape
    E, K = m.num_experts, m.top_k
    flat_expert = expert_ids.reshape(T * K)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (TK, E)
    slots = jnp.cumsum(onehot, axis=0) * onehot - 1
    slot = jnp.max(slots, axis=1)  # position within the expert's queue
    keep = slot < C
    dest = jnp.where(keep, flat_expert * C + slot, E * C)  # overflow -> scratch

    buf = jnp.zeros((E * C + 1, D), tokens.dtype)
    token_rep = jnp.repeat(tokens, K, axis=0)
    buf = buf.at[dest].set(token_rep)
    expert_in = buf[: E * C].reshape(E, C, D)

    r = params["routed"]
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, r["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", expert_in, r["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", gate * up, r["w_down"])  # (E, C, D)

    flat_out = jnp.concatenate(
        [expert_out.reshape(E * C, D), jnp.zeros((1, D), expert_out.dtype)], axis=0
    )
    gathered = flat_out[dest]  # (TK, D); dropped tokens read zeros
    weights = gate_vals.reshape(T * K, 1).astype(gathered.dtype)
    return jnp.sum((gathered * weights).reshape(T, K, D), axis=1)  # (T, D)


def moe_forward(
    params: PyTree, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).

    Dispatch is *grouped per batch row* (capacity C = ceil(S*k*cf/E) per
    sequence): load balancing is per sequence rather than global, in
    exchange for a fully data-parallel dispatch (no cross-shard token
    exchange). Experts are replicated per data shard and sharded over the
    ``model`` axis by the einsum operands.
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k

    logits = x @ params["router"]  # (B, S, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (B, S, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    aux = router_aux_loss(probs.reshape(B * S, E), expert_ids.reshape(B * S, K), E)

    C = max(1, int(-(-S * K * m.capacity_factor // E)))  # ceil per sequence
    combined = jax.vmap(
        lambda t, e, g: _dispatch_one_group(t, e, g, params, cfg, C)
    )(x, expert_ids, gate_vals)

    out = combined
    if m.num_shared_experts > 0:
        out = out + mlp_forward(params["shared"], x, cfg.mlp_type)
    return out, aux
